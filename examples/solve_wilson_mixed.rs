//! Mixed-precision Wilson solve — the `solve_wilson` variant that runs
//! entirely on the native kernels and contrasts three precision regimes
//! on the same system:
//!
//!   1. plain f32 BiCGStab (the paper's single-precision hot path) —
//!      stalls near the f32 round-off floor when asked for 1e-12;
//!   2. mixed-precision iterative refinement — f64 outer defect
//!      correction, all Krylov work in f32 — reaches f64 accuracy;
//!   3. plain f64 BiCGStab — the reference (every flop at f64 cost).
//!
//! ```sh
//! cargo run --release --example solve_wilson_mixed
//! ```

use lqcd::coordinator::operator::NativeMeo;
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Tiling};
use lqcd::solver::{self, residual, InnerAlgorithm};
use lqcd::util::rng::Rng;
use lqcd::util::timer::Stopwatch;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let kappa = 0.13f64;
    let tol = 1e-12;
    let dims = LatticeDims::new(8, 8, 8, 8)?;
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4)?)
        .map_err(|e| e.to_string())?;

    println!("== workload: random gauge on {dims}, Gaussian source, kappa {kappa}, tol {tol:.0e} ==");
    let mut rng = Rng::seeded(20230227);
    let u64f: GaugeField<f64> = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u64f.plaquette());
    let b64: FermionField<f64> = FermionField::gaussian(&geom, &mut rng);
    let u32f = u64f.to_precision::<f32>();
    let b32 = b64.to_precision::<f32>();

    // ---- 1. plain f32 BiCGStab: hits the single-precision floor -------
    println!("\n== plain f32 BiCGStab (paper hot path) ==");
    let mut op32 = NativeMeo::new(&geom, u32f.clone(), kappa as f32);
    let mut x32 = FermionField::<f32>::zeros(&geom);
    let sw = Stopwatch::start();
    let s32 = solver::bicgstab(&mut op32, &mut x32, &b32, tol, 500);
    let true32 = residual::operator_residual(&mut op32, &x32, &b32);
    println!(
        "f32: {} iters, converged={}, recursive |r|/|b| = {:.2e}, TRUE |r|/|b| = {:.2e}, {:.2}s",
        s32.iterations, s32.converged, s32.rel_residual, true32, sw.secs()
    );
    println!("     (the true residual floors at ~eps_f32 * cond: f32 alone cannot reach {tol:.0e})");

    // ---- 2. mixed: f64 outer refinement, f32 inner BiCGStab -----------
    println!("\n== mixed-precision iterative refinement (f64 outer, f32 inner) ==");
    let mut outer = NativeMeo::new(&geom, u64f.clone(), kappa);
    let mut inner = NativeMeo::new(&geom, u32f, kappa as f32);
    let mut xm = FermionField::<f64>::zeros(&geom);
    let sw = Stopwatch::start();
    let sm = solver::mixed_refinement(
        &mut outer, &mut inner, &mut xm, &b64,
        tol, 40, 1e-4, 500, InnerAlgorithm::BiCgStab,
    );
    let secs_mixed = sw.secs();
    println!(
        "mixed: {} outer steps, {} inner f32 iters, converged={}, true |r|/|b| = {:.2e}, {:.2}s",
        sm.outer_iterations, sm.inner_iterations, sm.converged, sm.rel_residual, secs_mixed
    );
    for (i, r) in sm.history.iter().enumerate() {
        println!("  outer {i:>2}  true |r|/|b| = {r:.3e}");
    }
    assert!(sm.converged, "mixed-precision refinement failed to converge");

    // ---- 3. plain f64 BiCGStab: the reference -------------------------
    println!("\n== plain f64 BiCGStab (reference) ==");
    let mut op64 = NativeMeo::new(&geom, u64f.clone(), kappa);
    let mut x64 = FermionField::<f64>::zeros(&geom);
    let sw = Stopwatch::start();
    let s64 = solver::bicgstab(&mut op64, &mut x64, &b64, tol, 500);
    let secs64 = sw.secs();
    println!(
        "f64: {} iters, converged={}, |r|/|b| = {:.2e}, {:.2}s",
        s64.iterations, s64.converged, s64.rel_residual, secs64
    );

    // mixed and f64 must agree on the solution
    let mut d = xm.clone();
    d.axpy(-1.0, &x64);
    println!(
        "\n|x_mixed - x_f64| / |x_f64| = {:.3e}",
        (d.norm2() / x64.norm2()).sqrt()
    );

    println!("\nOK: mixed precision reaches f64 accuracy with f32 inner iterations.");
    Ok(())
}
