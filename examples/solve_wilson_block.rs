//! Multi-RHS block solve: N right-hand sides through one gauge stream.
//!
//! The even-odd Wilson solve is memory-bandwidth bound and most of the
//! streamed bytes are gauge links, so solving one system at a time pins
//! the arithmetic intensity at the paper's B/F ≈ 1.12. The block-field
//! subsystem interleaves N right-hand sides inside each SIMD site tile
//! and applies the hopping kernel to all of them per link load:
//!
//!   bytes/site/RHS = (gauge bytes + N · spinor bytes) / N
//!
//! which falls toward the pure-spinor floor as N grows.
//!
//! This example solves the same 8⁴ system with N = 4 Gaussian sources
//! twice — once as four independent fused CGNR solves, once as one
//! block solve — and verifies that the per-RHS residual histories are
//! IDENTICAL (the block solver runs N independent recurrences through
//! shared batched sweeps; masking a converged system never perturbs
//! the stragglers), while the block pass streams the gauge field once
//! per sweep instead of four times.
//!
//! ```sh
//! cargo run --release --example solve_wilson_block
//! ```

use lqcd::coordinator::operator::{LinearOperator, MultiMdagM, NativeMdagM, NativeMeo};
use lqcd::coordinator::{BarrierKind, Team};
use lqcd::field::{FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{EoLayout, Geometry, LatticeDims, Tiling};
use lqcd::solver;
use lqcd::util::rng::Rng;
use lqcd::util::timer::Stopwatch;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let nrhs = 4;
    let kappa = 0.13f32;
    let tol = 1e-5;
    let maxiter = 500;
    let dims = LatticeDims::new(8, 8, 8, 8).unwrap();
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap())
        .map_err(|e| e.to_string())?;
    let mut rng = Rng::seeded(20230227);

    println!("== workload: random gauge on {dims}, {nrhs} Gaussian sources ==");
    let u: GaugeField<f32> = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u.plaquette());
    let sources: Vec<FermionField<f32>> =
        (0..nrhs).map(|_| FermionField::gaussian(&geom, &mut rng)).collect();

    // CGNR right-hand sides: Mdag b_r
    let mut meo = NativeMeo::new(&geom, u.clone(), kappa);
    let rhs: Vec<FermionField<f32>> = sources
        .iter()
        .map(|b| {
            let mut bp = b.clone();
            bp.gamma5();
            let mut mbp = FermionField::zeros(&geom);
            meo.apply(&mut mbp, &bp);
            mbp.gamma5();
            mbp
        })
        .collect();

    // ---- reference: N independent fused solves --------------------------
    println!("\n== {nrhs} independent fused CGNR solves (gauge streamed per solve) ==");
    let mut team = Team::new(2, BarrierKind::Sleep);
    let sw = Stopwatch::start();
    let mut independent = Vec::new();
    for (r, b) in rhs.iter().enumerate() {
        let mut op = NativeMdagM::new(&geom, u.clone(), kappa);
        let mut x = FermionField::<f32>::zeros(&geom);
        let stats = solver::fused::cg(&mut op, &mut team, &mut x, b, tol, maxiter);
        println!(
            "  rhs {r}: {} iterations, converged={}, |r|/|b| = {:.3e}",
            stats.iterations, stats.converged, stats.rel_residual
        );
        independent.push((x, stats));
    }
    let indep_secs = sw.secs();

    // ---- block: one batched solve, gauge streamed once per sweep --------
    println!("\n== one block CGNR solve of all {nrhs} systems ==");
    let b_block = MultiFermionField::from_rhs(&rhs);
    let mut op = MultiMdagM::new(&geom, u.clone(), kappa, nrhs);
    let mut x_block = MultiFermionField::<f32>::zeros(&geom, nrhs);
    let sw = Stopwatch::start();
    let stats = solver::block_cg(&mut op, &mut team, &mut x_block, &b_block, tol, maxiter);
    let block_secs = sw.secs();
    for (r, s) in stats.per_rhs.iter().enumerate() {
        println!(
            "  rhs {r}: {} iterations, converged={}, |r|/|b| = {:.3e}",
            s.iterations, s.converged, s.rel_residual
        );
    }

    // per-RHS trajectories must be identical to the independent solves
    let mut worst = 0.0f64;
    for (r, (x_ind, s_ind)) in independent.iter().enumerate() {
        assert_eq!(
            stats.per_rhs[r].history, s_ind.history,
            "rhs {r}: block residual history diverged from the independent solve"
        );
        let xr = x_block.extract_rhs(r);
        let mut d = xr.clone();
        d.axpy(-1.0, x_ind);
        let rel = (d.norm2() / x_ind.norm2().max(1e-300)).sqrt();
        worst = worst.max(rel);
    }
    println!("\nper-RHS residual histories identical to the independent solves");
    println!("worst |x_block - x_independent| / |x| = {worst:.3e}");
    assert!(worst < 1e-6, "block solutions diverged");

    // gauge-amortization arithmetic for this lattice
    let layout = EoLayout::new(&geom);
    let g = (8 * layout.gauge_len() * 4) as f64; // all gauge blocks, f32
    let f = (layout.spinor_len() * 4) as f64; // one spinor field, f32
    let sites = layout.nsites() as f64;
    println!("\n== gauge-stream amortization (one hopping pass, model) ==");
    for n in [1usize, 2, 4, 8] {
        let bytes_per_site_rhs = (g + 2.0 * f * n as f64) / (sites * n as f64);
        println!("  nrhs {n}: {bytes_per_site_rhs:>7.1} bytes/site/RHS");
    }
    println!(
        "\nindependent: {indep_secs:.2}s   block: {block_secs:.2}s   \
         ({} batched iterations, {:.0} sweeps/iter/RHS)",
        stats.iterations, stats.sweeps_per_iter
    );
    println!("\nOK: block solve matches the independent solves.");
    Ok(())
}
