//! Weak-scaling study (Fig. 10 shape): real in-process multi-rank runs at
//! small rank counts plus the TofuD-model projection to 512 nodes.
//!
//! ```sh
//! cargo run --release --example weak_scaling -- [--quick]
//! ```

use lqcd::comm::decompose::{extract_fermion, extract_gauge};
use lqcd::comm::run_world;
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::field::{FermionField, GaugeField};
use lqcd::harness::{fig10, Opts};
use lqcd::lattice::{Geometry, LatticeDims, Parity, ProcGrid, Tiling};
use lqcd::util::rng::Rng;
use lqcd::util::timer::Stopwatch;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = Opts {
        iters: if quick { 5 } else { 20 },
        threads: 1,
        quick,
    };

    println!("== part 1: real in-process multi-rank runs (correct halo traffic) ==");
    println!("(wall-clock on this 1-core host oversubscribes; per-rank work is what matters)\n");
    let local = LatticeDims::new(8, 8, 4, 4)?;
    let tiling = Tiling::new(2, 2)?;
    for grid in [ProcGrid([1, 1, 1, 1]), ProcGrid([1, 1, 2, 1]), ProcGrid([1, 1, 2, 2])] {
        let nranks = grid.size();
        let global = LatticeDims::new(
            local.x * grid.0[0],
            local.y * grid.0[1],
            local.z * grid.0[2],
            local.t * grid.0[3],
        )?;
        let ggeom = Geometry::single_rank(global, tiling).map_err(|e| e.to_string())?;
        let mut rng = Rng::seeded(5);
        let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
        let psi_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);
        let iters = opts.iters;

        let sw = Stopwatch::start();
        run_world(nranks, |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let u = extract_gauge(&u_global, &lgeom);
            let psi = extract_fermion(&psi_global, &ggeom, &lgeom);
            let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Balanced);
            let mut team = Team::new(1, BarrierKind::Spin);
            let prof = Profiler::new(1);
            let mut out = FermionField::zeros(&lgeom);
            for _ in 0..iters {
                dist.hopping(&mut out, &u, &psi, Parity::Odd, comm, &mut team, &prof);
            }
        });
        let secs = sw.secs();
        let flops =
            lqcd::FLOP_PER_SITE as f64 * global.half_volume() as f64 * opts.iters as f64;
        println!(
            "ranks {nranks} (grid {:?}): global {global}, aggregate {:.2} GFlops",
            grid.0,
            flops / secs / 1e9
        );
    }

    println!("\n== part 2: TofuD-model projection to 512 nodes (paper Fig. 10) ==\n");
    let r = fig10::run(opts);
    println!("{}", r.report);
    Ok(())
}
