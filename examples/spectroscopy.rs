//! Spectroscopy on a free-field configuration: compute the quark
//! propagator from a point source through the even-odd solver, validate
//! it against the *analytic* momentum-space free Wilson propagator, and
//! measure the pion correlator + effective mass.
//!
//! This exercises the whole physics pipeline the paper's kernel serves:
//! 12 Schur-preconditioned solves (Eqs. 4-5), propagator assembly, and a
//! hadronic observable — with an exact answer to compare against.
//!
//! ```sh
//! cargo run --release --example spectroscopy
//! ```

use lqcd::algebra::{Complex, Spinor, GAMMA};
use lqcd::coordinator::operator::NativeMeo;
use lqcd::dslash::{full, HoppingEo};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{EvenOdd, Geometry, LatticeDims, Parity, SiteCoord, Tiling};
use lqcd::solver;

const KAPPA: f32 = 0.115; // m = 1/(2k) - 4 ~ 0.348: a fairly heavy quark

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dims = LatticeDims::new(4, 4, 4, 8)?;
    let geom = Geometry::single_rank(dims, Tiling::new(2, 2)?).map_err(|e| e.to_string())?;
    let u = GaugeField::unit(&geom); // free field: U = 1
    println!("free-field spectroscopy on {dims}, kappa = {KAPPA}");
    println!("plaquette = {:.6} (must be exactly 1)", u.plaquette());

    // ---- 12 point-source solves: propagator column S(x; 0)_{sc, s0c0} --
    let hop = HoppingEo::new(&geom);
    let origin = SiteCoord { t: 0, z: 0, y: 0, ix: 0 }; // even site (0,0,0,0)
    let mut columns: Vec<(FermionField, FermionField)> = Vec::new();
    for s0 in 0..4 {
        for c0 in 0..3 {
            let eta_e = FermionField::point_source(&geom, origin, s0, c0);
            let eta_o = FermionField::zeros(&geom);
            // Schur rhs, even solve, odd reconstruction (Eqs. 4-5)
            let mut b = FermionField::zeros(&geom);
            full::schur_rhs(&hop, &mut b, &u, &eta_e, &eta_o, KAPPA);
            let mut op = NativeMeo::new(&geom, u.clone(), KAPPA);
            let mut x_e = FermionField::zeros(&geom);
            let st = solver::bicgstab(&mut op, &mut x_e, &b, 1e-10, 1000);
            assert!(st.converged, "solve ({s0},{c0}) failed");
            let mut x_o = FermionField::zeros(&geom);
            full::reconstruct_odd(&hop, &mut x_o, &u, &eta_o, &x_e, KAPPA);
            columns.push((x_e, x_o));
        }
    }
    println!("12 propagator columns solved");

    // ---- analytic check: momentum-space free Wilson propagator ---------
    // D(p) = A(p) + 2 i kappa sum_mu gamma_mu sin p_mu,
    // A(p) = 1 - 2 kappa sum_mu cos p_mu;  S = D^-1 via (A - i g.b)/(A^2+b^2)
    let mut max_err = 0.0f64;
    let test_sites = [
        (0usize, 0usize, 0usize, 0usize),
        (1, 0, 0, 0),
        (0, 1, 2, 3),
        (2, 2, 2, 4),
        (3, 1, 0, 6),
    ];
    for &(x, y, z, t) in &test_sites {
        let want = analytic_propagator(dims, KAPPA as f64, [x, y, z, t]);
        // our propagator at this site, as a 4x4 spin matrix for color 0,0
        for s0 in 0..4 {
            let (col_e, col_o) = &columns[s0 * 3];
            let p = Parity::of_site(x, y, z, t);
            let phi = EvenOdd::row_parity(y, z, t, p);
            assert_eq!(phi, x % 2);
            let sc = SiteCoord { t, z, y, ix: EvenOdd::compact_x(x) };
            let v: Spinor = match p {
                Parity::Even => col_e.site(sc),
                Parity::Odd => col_o.site(sc),
            };
            for s in 0..4 {
                let got = v.s[s][0];
                let w = want[s][s0];
                max_err = max_err.max((got - w).abs());
            }
        }
    }
    println!("max |S_solver - S_analytic| over sampled sites = {max_err:.3e}");
    assert!(max_err < 5e-4, "propagator disagrees with the analytic result");

    // ---- pion correlator C(t) = sum_x tr S^dag S ------------------------
    let mut corr = vec![0.0f64; dims.t];
    for (col_e, col_o) in &columns {
        for (field, parity) in [(col_e, Parity::Even), (col_o, Parity::Odd)] {
            for s in field.layout.sites() {
                let _ = parity;
                let v = field.site(s);
                corr[s.t] += v.norm2();
            }
        }
    }
    println!("\n t    C(t)          m_eff(t)");
    for t in 0..dims.t {
        let meff = if t + 1 < dims.t && corr[t + 1] > 0.0 {
            (corr[t] / corr[t + 1]).ln()
        } else {
            f64::NAN
        };
        println!("{t:>2}   {:.6e}   {meff:.4}", corr[t]);
    }
    // free-field sanity: C is positive and symmetric about NT/2
    for t in 1..dims.t {
        assert!(corr[t] > 0.0);
        let mirror = corr[(dims.t - t) % dims.t];
        let sym = (corr[t] - mirror).abs() / corr[t].max(mirror);
        assert!(sym < 1e-3, "C(t) not time-symmetric at t={t}: {sym}");
    }
    println!("\nOK: propagator matches the analytic free-field result; C(t) sane.");
    Ok(())
}

/// S(x; 0) spin matrix (color-diagonal) from the exact momentum sum.
fn analytic_propagator(
    dims: LatticeDims,
    kappa: f64,
    x: [usize; 4],
) -> [[Complex; 4]; 4] {
    let ext = [dims.x, dims.y, dims.z, dims.t];
    let vol = dims.volume() as f64;
    let mut s = [[Complex::ZERO; 4]; 4];
    let tau = std::f64::consts::TAU;
    for nx in 0..ext[0] {
        for ny in 0..ext[1] {
            for nz in 0..ext[2] {
                for nt in 0..ext[3] {
                    let p = [
                        tau * nx as f64 / ext[0] as f64,
                        tau * ny as f64 / ext[1] as f64,
                        tau * nz as f64 / ext[2] as f64,
                        tau * nt as f64 / ext[3] as f64,
                    ];
                    let a = 1.0 - 2.0 * kappa * p.iter().map(|&q| q.cos()).sum::<f64>();
                    let b: Vec<f64> = p.iter().map(|&q| 2.0 * kappa * q.sin()).collect();
                    let b2: f64 = b.iter().map(|v| v * v).sum();
                    let denom = a * a + b2;
                    // D^-1(p) = (a - i sum gamma_mu b_mu) / denom
                    let phase = p[0] * x[0] as f64
                        + p[1] * x[1] as f64
                        + p[2] * x[2] as f64
                        + p[3] * x[3] as f64;
                    let e = Complex::new(phase.cos(), phase.sin());
                    for i in 0..4 {
                        for j in 0..4 {
                            let mut dij = if i == j {
                                Complex::new(a, 0.0)
                            } else {
                                Complex::ZERO
                            };
                            for (mu, &bmu) in b.iter().enumerate() {
                                let g = GAMMA[mu].0[i][j];
                                // -i * g * b_mu
                                dij += (g.scale(bmu)).mul_mi();
                            }
                            s[i][j] += (e * dij).scale(1.0 / (denom * vol));
                        }
                    }
                }
            }
        }
    }
    s
}
