//! Quickstart: build a lattice, make a random gauge configuration, apply
//! the even-odd Wilson hopping operator, and time it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use lqcd::dslash::HoppingEo;
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, Tiling};
use lqcd::util::rng::Rng;
use lqcd::util::timer::Bench;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // an 8x8x8x16 local lattice with the paper's 4x4 SIMD tiling
    let dims = LatticeDims::new(8, 8, 8, 16)?;
    let tiling = Tiling::new(4, 4)?;
    let geom = Geometry::single_rank(dims, tiling)?;
    println!("lattice {dims}, tiling {tiling} (VLEN = {})", tiling.vlen());

    // hot-start gauge configuration: independent random SU(3) links
    let mut rng = Rng::seeded(7);
    let u: GaugeField = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6} (hot start: ~0)", u.plaquette());

    // a Gaussian fermion source on the even sites
    let psi: FermionField = FermionField::gaussian(&geom, &mut rng);
    println!("|psi|^2 = {:.3}", psi.norm2());

    // apply the hopping operator H_oe (the paper's kernel)
    let hop = HoppingEo::new(&geom);
    let mut out = FermionField::zeros(&geom);
    hop.apply(&mut out, &u, &psi, Parity::Odd);
    println!("|H psi|^2 = {:.3}", out.norm2());

    // time it: 1368 flop/site in the QXS convention
    let flops = lqcd::FLOP_PER_SITE as f64 * dims.half_volume() as f64;
    let result = Bench::new(2, 5).run(|| {
        for _ in 0..10 {
            hop.apply(&mut out, &u, &psi, Parity::Odd);
        }
        Some(flops * 10.0)
    });
    println!(
        "hopping: {} per apply, {:.2} GFlops sustained",
        lqcd::util::timer::fmt_secs(result.stats.median / 10.0),
        result.gflops().unwrap()
    );
    Ok(())
}
