//! End-to-end driver (the EXPERIMENTS.md E2E validation run): solve the
//! Wilson-fermion linear system D psi = eta on a real small workload with
//! the **AOT PJRT artifacts on the hot path** — the full three-layer
//! stack composed:
//!
//!   L1 Pallas hopping kernel -> L2 jax even-odd operator -> HLO text
//!   -> PJRT CPU executable -> L3 Rust BiCGStab driver (this file).
//!
//! Flow (paper Eqs. 3-5): Schur rhs -> BiCGStab on M-hat (PJRT) -> odd
//! reconstruction -> *full-system* residual check with the native
//! operator, plus a native-solver cross check and a solver-in-XLA run of
//! the `cg_solve` whole-loop artifact.
//!
//! ```sh
//! make artifacts && cargo run --release --example solve_wilson
//! ```

use lqcd::coordinator::operator::{LinearOperator, NativeMeo};
use lqcd::coordinator::{BarrierKind, Team};
use lqcd::dslash::full;
use lqcd::field::io::fermion_from_canonical;
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, Tiling};
use lqcd::runtime::{PjrtMeo, Runtime};
use lqcd::solver::{self, residual};
use lqcd::util::rng::Rng;
use lqcd::util::timer::Stopwatch;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let kappa = 0.13f32;
    let tol = 1e-8;

    println!("== loading AOT artifacts (L1 Pallas + L2 jax -> HLO text) ==");
    let sw = Stopwatch::start();
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    println!(
        "compiled {} artifacts on {} in {:.1}s (lattice {})",
        rt.manifest.artifacts.len(),
        rt.platform(),
        sw.secs(),
        rt.manifest.dims
    );

    let dims = rt.manifest.dims;
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap())
        .or_else(|_| Geometry::single_rank(dims, Tiling::new(2, 2).unwrap()))
        .map_err(|e| e.to_string())?;
    let mut rng = Rng::seeded(20230227);
    println!("\n== workload: random gauge on {dims}, Gaussian source ==");
    let u = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u.plaquette());
    let eta_e = FermionField::gaussian(&geom, &mut rng);
    let eta_o = FermionField::gaussian(&geom, &mut rng);

    // Schur rhs (Eq. 4): b = eta_e + kappa H_eo eta_o
    let hop = lqcd::dslash::HoppingEo::new(&geom);
    let mut b = FermionField::zeros(&geom);
    full::schur_rhs(&hop, &mut b, &u, &eta_e, &eta_o, kappa);

    println!("\n== solve M-hat x_e = b with BiCGStab, PJRT operator on the hot path ==");
    let mut op = PjrtMeo::new(&rt, &geom, &u, kappa)?;
    let mut x_e = FermionField::zeros(&geom);
    let sw = Stopwatch::start();
    let stats = solver::bicgstab(&mut op, &mut x_e, &b, tol, 500);
    let secs = sw.secs();
    println!(
        "bicgstab(pjrt): {} iters, converged={}, recursive |r|/|b| = {:.2e}, {:.2}s ({:.2} GFlops)",
        stats.iterations,
        stats.converged,
        stats.rel_residual,
        secs,
        stats.flops as f64 / secs / 1e9
    );
    for (i, r) in stats.history.iter().enumerate() {
        if i % 10 == 0 || i + 1 == stats.history.len() {
            println!("  iter {i:>4}  |r|/|b| = {r:.3e}");
        }
    }
    assert!(stats.converged, "PJRT solve failed to converge");

    // odd reconstruction (Eq. 5) and FULL-system residual with the native
    // kernels — this crosses the PJRT/native boundary on purpose.
    let mut x_o = FermionField::zeros(&geom);
    full::reconstruct_odd(&hop, &mut x_o, &u, &eta_o, &x_e, kappa);
    let rel = residual::full_system_residual(&hop, &u, &x_e, &x_o, &eta_e, &eta_o, kappa);
    println!("full-system |D psi - eta| / |eta| = {rel:.3e}");
    assert!(rel < 1e-5, "full-system residual too large");

    println!("\n== cross-check: same solve, native fused pipeline on 2 threads ==");
    let mut nop = NativeMeo::new(&geom, u.clone(), kappa);
    let mut team = Team::new(2, BarrierKind::Sleep);
    let mut x_native = FermionField::zeros(&geom);
    let sw = Stopwatch::start();
    let nstats = solver::fused::bicgstab(&mut nop, &mut team, &mut x_native, &b, tol, 500);
    println!(
        "bicgstab(native fused, 2 threads): {} iters in {:.2}s ({:.2} GFlops, {:.0} sweeps/iter)",
        nstats.iterations,
        sw.secs(),
        nstats.flops as f64 / sw.secs() / 1e9,
        nstats.sweeps_per_iter
    );
    let mut d = x_native.clone();
    d.axpy(-1.0, &x_e);
    println!(
        "|x_native - x_pjrt| / |x| = {:.3e}",
        (d.norm2() / x_native.norm2()).sqrt()
    );

    println!("\n== solver-in-XLA: the whole-CG `cg_solve` artifact ==");
    let sw = Stopwatch::start();
    let (x_canon, iters, rr) = op.cg_solve_artifact(&b)?;
    let mut x_xla = FermionField::zeros(&geom);
    fermion_from_canonical(&mut x_xla, &x_canon.iter().map(|&v| v as f64).collect::<Vec<_>>())?;
    println!(
        "cg_solve artifact: {iters} iters, |r|^2/|b|^2 = {rr:.2e}, {:.2}s",
        sw.secs()
    );
    let mut mx = FermionField::zeros(&geom);
    nop.apply(&mut mx, &x_xla);
    mx.axpy(-1.0, &b);
    println!(
        "true residual of XLA solution: {:.3e}",
        (mx.norm2() / b.norm2()).sqrt()
    );

    println!("\nOK: all three layers agree.");
    Ok(())
}
