//! The shipped example configuration must load and describe the paper's
//! setup; error paths must fail loudly, not fall back to defaults.

use std::path::PathBuf;

use lqcd::config::RunConfig;
use lqcd::lattice::{LatticeDims, ProcGrid};

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

#[test]
fn example_config_is_paper_setup() {
    let cfg = RunConfig::load(&repo_path("configs/example.toml")).unwrap();
    assert_eq!(cfg.lattice.global, LatticeDims::new(16, 16, 16, 16).unwrap());
    assert_eq!(cfg.lattice.grid, ProcGrid([1, 1, 2, 2]));
    assert_eq!(cfg.lattice.tiling.to_string(), "4x4");
    assert_eq!(cfg.parallel.threads_per_rank, 12);
    assert!(cfg.parallel.force_comm);
    assert_eq!(cfg.solver.algorithm, "bicgstab");
    assert_eq!(cfg.gauge.compression, lqcd::dslash::Compression::None);
    // the shipped [tune] section spells out the defaults; the EO2 keys
    // are commented out (cache/heuristic decides)
    assert!(cfg.tune.enabled);
    assert_eq!(cfg.tune.cache_dir, PathBuf::from("tune-cache"));
    assert_eq!(cfg.tune.budget_ms, 3000);
    assert!((cfg.tune.roofline_floor - 0.5).abs() < 1e-12);
    assert_eq!(cfg.parallel.eo2_schedule, None);
    assert_eq!(cfg.parallel.eo2_granularity, None);
    // the shipped [comm] section spells out the fault-tolerance
    // defaults; [faults] stays commented out (no injection)
    assert_eq!(cfg.comm.timeout_ms, 30_000);
    assert_eq!(cfg.comm.max_retries, 3);
    assert_eq!(cfg.solver.max_restarts, 3);
    assert!(cfg.faults.is_empty());
    // local volume per rank = 16x16x8x8, the paper's Table 1 first row
    let geom = lqcd::lattice::Geometry::for_rank(
        cfg.lattice.global,
        cfg.lattice.grid,
        0,
        cfg.lattice.tiling,
    )
    .unwrap();
    assert_eq!(geom.local, LatticeDims::new(16, 16, 8, 8).unwrap());
}

#[test]
fn missing_config_errors() {
    assert!(RunConfig::load(&repo_path("configs/nope.toml")).is_err());
}

#[test]
fn missing_artifacts_dir_errors_cleanly() {
    let err = match lqcd::runtime::Runtime::load(&repo_path("no-such-artifacts")) {
        Ok(_) => panic!("load of a missing dir must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("manifest"), "unhelpful error: {err}");
}
