//! Precision-generic behavior tests: the same physics must hold at both
//! `f32` and `f64` field instantiations (with precision-scaled
//! tolerances), and the mixed-precision iterative-refinement solver must
//! reach f64-level residuals that plain f32 CG cannot.

use lqcd::algebra::{Real, Spinor, PROJ};
use lqcd::coordinator::operator::{LinearOperator, NativeMdagM, NativeMeo};
use lqcd::dslash::{HoppingEo, HoppingScalar};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, SiteCoord, Tiling};
use lqcd::solver::{self, InnerAlgorithm};
use lqcd::util::rng::Rng;

fn geom_small() -> Geometry {
    Geometry::single_rank(
        LatticeDims::new(4, 4, 4, 4).unwrap(),
        Tiling::new(2, 2).unwrap(),
    )
    .unwrap()
}

/// (1 -+ g_mu) project/reconstruct round-trip *through field storage at
/// precision R*: storing the reconstruction and reading it back must
/// preserve the projector identity (1 -+ g)^2 = 2 (1 -+ g) within the
/// storage precision.
fn proj_roundtrip_at<R: Real>(tol: f64) {
    let g = geom_small();
    let mut rng = Rng::seeded(501);
    let psi_field = FermionField::<R>::gaussian(&g, &mut rng);
    let mut scratch = FermionField::<R>::zeros(&g);
    let sites: Vec<SiteCoord> = psi_field.layout.sites().step_by(7).collect();
    for s in sites {
        let psi = psi_field.site(s);
        for mu in 0..4 {
            for sign in 0..2 {
                let e = &PROJ[mu][sign];
                // r = (1 -+ g) psi
                let mut r = Spinor::ZERO;
                e.reconstruct_accum(&mut r, &e.project(&psi));
                // round-trip r through R storage
                scratch.set_site(s, &r);
                let r_stored = scratch.site(s);
                // (1 -+ g) r' must equal 2 r' within storage precision
                let mut rr = Spinor::ZERO;
                e.reconstruct_accum(&mut rr, &e.project(&r_stored));
                let err = rr.sub(&r_stored.scale(2.0)).norm2().sqrt();
                let scale = r_stored.norm2().sqrt().max(1e-30);
                assert!(
                    err / scale < tol,
                    "{} mu={mu} sign={sign}: rel err {}",
                    R::NAME,
                    err / scale
                );
            }
        }
    }
}

#[test]
fn proj_reconstruct_roundtrip_f32() {
    proj_roundtrip_at::<f32>(1e-6);
}

#[test]
fn proj_reconstruct_roundtrip_f64() {
    proj_roundtrip_at::<f64>(1e-14);
}

/// gamma5-hermiticity of the hopping blocks at precision R:
/// <x_o, H_oe y_e> == <g5 H_eo g5 x_o, y_e>.
fn hopping_parity_identity_at<R: Real>(tol: f64) {
    let g = geom_small();
    let mut rng = Rng::seeded(502);
    let u = GaugeField::<R>::random(&g, &mut rng);
    let y_e = FermionField::<R>::gaussian(&g, &mut rng);
    let x_o = FermionField::<R>::gaussian(&g, &mut rng);
    let hop = HoppingEo::new(&g);

    let mut hy = FermionField::<R>::zeros(&g);
    hop.apply(&mut hy, &u, &y_e, Parity::Odd);
    let lhs = x_o.dot(&hy);

    let mut g5x = x_o.clone();
    g5x.gamma5();
    let mut hg5x = FermionField::<R>::zeros(&g);
    hop.apply(&mut hg5x, &u, &g5x, Parity::Even);
    hg5x.gamma5();
    let rhs = hg5x.dot(&y_e);

    let scale = (x_o.norm2() * y_e.norm2()).sqrt().max(1.0);
    assert!(
        (lhs - rhs).abs() / scale < tol,
        "{}: lhs {lhs:?} rhs {rhs:?}",
        R::NAME
    );
}

#[test]
fn hopping_parity_identity_f32() {
    hopping_parity_identity_at::<f32>(1e-5);
}

#[test]
fn hopping_parity_identity_f64() {
    hopping_parity_identity_at::<f64>(1e-13);
}

/// M-hat gamma5-hermiticity at both precisions: <x, M y> == <g5 M g5 x, y>.
fn meo_parity_identity_at<R: Real>(kappa: R, tol: f64) {
    let g = geom_small();
    let mut rng = Rng::seeded(503);
    let u = GaugeField::<R>::random(&g, &mut rng);
    let x = FermionField::<R>::gaussian(&g, &mut rng);
    let y = FermionField::<R>::gaussian(&g, &mut rng);
    let mut op = NativeMeo::new(&g, u, kappa);

    let mut my = FermionField::<R>::zeros(&g);
    op.apply(&mut my, &y);
    let lhs = x.dot(&my);

    let mut g5x = x.clone();
    g5x.gamma5();
    let mut mg5x = FermionField::<R>::zeros(&g);
    op.apply(&mut mg5x, &g5x);
    mg5x.gamma5();
    let rhs = mg5x.dot(&y);

    let scale = (x.norm2() * y.norm2()).sqrt().max(1.0);
    assert!(
        (lhs - rhs).abs() / scale < tol,
        "{}: lhs {lhs:?} rhs {rhs:?}",
        R::NAME
    );
}

#[test]
fn meo_parity_identity_f32() {
    meo_parity_identity_at::<f32>(0.13, 1e-5);
}

#[test]
fn meo_parity_identity_f64() {
    meo_parity_identity_at::<f64>(0.13, 1e-13);
}

/// The vectorized kernel must agree with the scalar (f64 algebra) oracle
/// to near machine precision when instantiated at f64 — this pins the
/// generic code path, not just the f32 one the seed tests cover.
#[test]
fn eo_kernel_matches_scalar_oracle_at_f64() {
    let g = geom_small();
    let mut rng = Rng::seeded(504);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let psi = FermionField::<f64>::gaussian(&g, &mut rng);
    for p in Parity::BOTH {
        let mut out_vec = FermionField::<f64>::zeros(&g);
        HoppingEo::new(&g).apply(&mut out_vec, &u, &psi, p);
        let mut out_scalar = FermionField::<f64>::zeros(&g);
        HoppingScalar::new(&g).apply(&mut out_scalar, &u, &psi, p);
        let mut d = out_vec.clone();
        d.axpy(-1.0, &out_scalar);
        let rel = (d.norm2() / out_scalar.norm2()).sqrt();
        assert!(rel < 1e-13, "f64 vectorized vs scalar rel diff {rel}");
    }
}

/// The same physical configuration demoted to f32 must give the same
/// operator as generating at f32 directly (conversion correctness).
#[test]
fn demoted_operator_matches_native_f32() {
    let g = geom_small();
    let u64f = GaugeField::<f64>::random(&g, &mut Rng::seeded(505));
    let u32f = GaugeField::<f32>::random(&g, &mut Rng::seeded(505));
    let psi64 = FermionField::<f64>::gaussian(&g, &mut Rng::seeded(506));
    let psi32: FermionField<f32> = psi64.to_precision();

    let mut op_demoted = NativeMeo::new(&g, u64f.to_precision::<f32>(), 0.13f32);
    let mut op_direct = NativeMeo::new(&g, u32f, 0.13f32);
    let mut a = FermionField::<f32>::zeros(&g);
    let mut b = FermionField::<f32>::zeros(&g);
    op_demoted.apply(&mut a, &psi32);
    op_direct.apply(&mut b, &psi32);
    assert_eq!(a.data, b.data, "demoted gauge must act identically");
}

/// The acceptance scenario: on an 8^4-class lattice, plain f32 CG stalls
/// above 1e-10 relative residual (the single-precision round-off floor),
/// while mixed-precision refinement — f64 outer, ALL Krylov iterations in
/// f32 — reaches <= 1e-10.
#[test]
fn mixed_solver_reaches_1e10_where_f32_cg_stalls() {
    let g = Geometry::single_rank(
        LatticeDims::new(8, 8, 8, 8).unwrap(),
        Tiling::new(4, 2).unwrap(),
    )
    .unwrap();
    let mut rng = Rng::seeded(507);
    let u64f = GaugeField::<f64>::random(&g, &mut rng);
    let b64 = FermionField::<f64>::gaussian(&g, &mut rng);
    let kappa = 0.13f64;
    let tol = 1e-10;

    // ---- plain f32 CG on the HPD normal operator: stalls ----
    let u32f = u64f.to_precision::<f32>();
    let b32: FermionField<f32> = b64.to_precision();
    let mut op32 = NativeMdagM::new(&g, u32f.clone(), kappa as f32);
    let mut x32 = FermionField::<f32>::zeros(&g);
    let s32 = solver::cg(&mut op32, &mut x32, &b32, tol, 500);
    let true32 = solver::residual::operator_residual(&mut op32, &x32, &b32);
    assert!(
        !s32.converged || true32 > tol,
        "plain f32 CG unexpectedly reached {tol:.0e} (true residual {true32:.2e})"
    );
    assert!(
        true32 > 1e-9,
        "f32 true residual {true32:.2e} should floor well above 1e-10"
    );

    // ---- mixed: f64 outer refinement, f32 inner CG ----
    let mut outer = NativeMdagM::new(&g, u64f, kappa);
    let mut inner = NativeMdagM::new(&g, u32f, kappa as f32);
    let mut xm = FermionField::<f64>::zeros(&g);
    let sm = solver::mixed_refinement(
        &mut outer,
        &mut inner,
        &mut xm,
        &b64,
        tol,
        40,
        1e-4,
        500,
        InnerAlgorithm::Cg,
    );
    assert!(sm.converged, "mixed refinement did not converge: {sm:?}");
    assert!(
        sm.rel_residual <= tol,
        "mixed rel residual {:.2e} > {tol:.0e}",
        sm.rel_residual
    );
    assert!(sm.inner_iterations > 0, "inner f32 solver must do the work");
    assert!(
        sm.outer_iterations >= 2,
        "refinement must take multiple outer steps"
    );
    // reported residual is the true f64 residual
    let true_m = solver::residual::operator_residual(&mut outer, &xm, &b64);
    assert!(true_m <= 2.0 * tol, "true residual {true_m:.2e}");
    // and the mixed solution beats the f32 one by orders of magnitude
    assert!(true_m < true32 / 100.0);
}
