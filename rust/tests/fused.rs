//! Fused-kernel and thread-parallel solver equivalence tests.
//!
//! The contract under test: every fused kernel (xpay/gamma5 store
//! tails, in-kernel dot capture, fused BLAS-1 sweeps) bit-matches its
//! unfused two-pass reference at f64 and matches to rounding at f32
//! (in practice also bitwise, since the elementwise expressions and
//! reduction groupings are identical by construction), and the
//! thread-parallel fused solvers produce *identical* iteration counts
//! and residual histories at 1, 2 and 4 threads as the serial unfused
//! reference.

use lqcd::algebra::Real;
use lqcd::coordinator::operator::{LinearOperator, NativeMdagM, NativeMeo, UnfusedMdagM};
use lqcd::coordinator::{BarrierKind, Team};
use lqcd::dslash::{full, HoppingEo};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Tiling};
use lqcd::solver::{self, InnerAlgorithm};
use lqcd::util::rng::Rng;

fn geom() -> Geometry {
    Geometry::single_rank(
        LatticeDims::new(4, 4, 4, 4).unwrap(),
        Tiling::new(2, 2).unwrap(),
    )
    .unwrap()
}

/// Max |a-b| over two fields' raw data.
fn max_abs_diff<R: Real>(a: &FermionField<R>, b: &FermionField<R>) -> f64 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| (x.to_f64() - y.to_f64()).abs())
        .fold(0.0, f64::max)
}


#[test]
fn fused_meo_apply_bit_matches_unfused_f64() {
    let g = geom();
    let mut rng = Rng::seeded(601);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let psi = FermionField::<f64>::gaussian(&g, &mut rng);
    let kappa = 0.137f64;

    // fused: the xpay tail inside the kernel store
    let mut op = NativeMeo::new(&g, u.clone(), kappa);
    let mut got = FermionField::<f64>::zeros(&g);
    op.apply(&mut got, &psi);

    // unfused two-pass reference
    let hop = HoppingEo::new(&g);
    let mut want = FermionField::<f64>::zeros(&g);
    let mut tmp = FermionField::<f64>::zeros(&g);
    full::meo(&hop, &mut want, &mut tmp, &u, &psi, kappa);

    assert_eq!(got.data, want.data, "fused M-hat must bit-match at f64");
}

#[test]
fn fused_meo_apply_matches_unfused_f32() {
    let g = geom();
    let mut rng = Rng::seeded(602);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let psi = FermionField::<f32>::gaussian(&g, &mut rng);
    let kappa = 0.137f32;

    let mut op = NativeMeo::new(&g, u.clone(), kappa);
    let mut got = FermionField::<f32>::zeros(&g);
    op.apply(&mut got, &psi);

    let hop = HoppingEo::new(&g);
    let mut want = FermionField::<f32>::zeros(&g);
    let mut tmp = FermionField::<f32>::zeros(&g);
    full::meo(&hop, &mut want, &mut tmp, &u, &psi, kappa);

    assert!(
        max_abs_diff(&got, &want) <= f32::EPSILON as f64,
        "fused M-hat must match the two-pass reference to rounding at f32"
    );
}

#[test]
fn fused_mdagm_apply_bit_matches_gamma5_sequence() {
    let g = geom();
    let mut rng = Rng::seeded(603);
    let u64f = GaugeField::<f64>::random(&g, &mut rng);
    let psi64 = FermionField::<f64>::gaussian(&g, &mut rng);
    let kappa = 0.12f64;

    let mut fused = NativeMdagM::new(&g, u64f.clone(), kappa);
    let mut got = FermionField::<f64>::zeros(&g);
    fused.apply(&mut got, &psi64);

    let mut unfused = UnfusedMdagM::new(&g, u64f, kappa);
    let mut want = FermionField::<f64>::zeros(&g);
    unfused.apply(&mut want, &psi64);
    assert_eq!(got.data, want.data, "fused M^dag M must bit-match at f64");

    // and to rounding at f32
    let u32f: GaugeField<f32> = GaugeField::<f64>::random(&g, &mut Rng::seeded(604))
        .to_precision();
    let psi32: FermionField<f32> = psi64.to_precision();
    let mut fused = NativeMdagM::new(&g, u32f.clone(), kappa as f32);
    let mut got = FermionField::<f32>::zeros(&g);
    fused.apply(&mut got, &psi32);
    let mut unfused = UnfusedMdagM::new(&g, u32f, kappa as f32);
    let mut want = FermionField::<f32>::zeros(&g);
    unfused.apply(&mut want, &psi32);
    assert!(max_abs_diff(&got, &want) <= f32::EPSILON as f64);
}

#[test]
fn axpy_norm2_bit_matches_two_pass() {
    let g = geom();
    for seed in [605u64, 606] {
        let mut rng = Rng::seeded(seed);
        let mut x = FermionField::<f64>::gaussian(&g, &mut rng);
        let y = FermionField::<f64>::gaussian(&g, &mut rng);
        let mut x2 = x.clone();
        let fused = x.axpy_norm2(-0.73, &y);
        x2.axpy(-0.73, &y);
        let two_pass = x2.norm2();
        assert_eq!(x.data, x2.data, "fused axpy part must be identical");
        assert_eq!(fused, two_pass, "fused norm must bit-match norm2()");
    }
    // f32 fields: the reduction is f64 either way, still identical
    let mut rng = Rng::seeded(607);
    let mut x = FermionField::<f32>::gaussian(&g, &mut rng);
    let y = FermionField::<f32>::gaussian(&g, &mut rng);
    let mut x2 = x.clone();
    let fused = x.axpy_norm2(0.25, &y);
    x2.axpy(0.25, &y);
    assert_eq!(x.data, x2.data);
    assert!((fused - x2.norm2()).abs() <= 1e-7 * fused.abs());
}

/// CG: serial unfused reference vs the fused pipeline at 1, 2 and 4
/// threads — iteration counts and residual histories must be identical
/// (bitwise: same reduction grouping, same elementwise updates).
#[test]
fn threaded_cg_matches_serial_unfused() {
    let g = geom();
    let mut rng = Rng::seeded(611);
    let u: GaugeField<f32> = GaugeField::<f64>::random(&g, &mut rng).to_precision();
    let b: FermionField<f32> =
        FermionField::<f64>::gaussian(&g, &mut rng).to_precision();
    let kappa = 0.12f32;

    // CGNR rhs
    let mut mbp = FermionField::<f32>::zeros(&g);
    {
        let mut op = NativeMdagM::new(&g, u.clone(), kappa);
        let mut bp = b.clone();
        bp.gamma5();
        op.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
    }

    let mut refop = UnfusedMdagM::new(&g, u.clone(), kappa);
    let mut x_ref = FermionField::<f32>::zeros(&g);
    let reference = solver::cg(&mut refop, &mut x_ref, &mbp, 1e-6, 200);
    assert!(reference.iterations > 3, "system must take several iterations");

    for threads in [1usize, 2, 4] {
        let mut op = NativeMdagM::new(&g, u.clone(), kappa);
        let mut team = Team::new(threads, BarrierKind::Sleep);
        let mut x = FermionField::<f32>::zeros(&g);
        let stats = solver::fused::cg(&mut op, &mut team, &mut x, &mbp, 1e-6, 200);
        assert_eq!(
            stats.iterations, reference.iterations,
            "{threads}-thread fused CG iteration count"
        );
        assert_eq!(
            stats.history, reference.history,
            "{threads}-thread fused CG residual history"
        );
        assert_eq!(stats.converged, reference.converged);
        assert_eq!(
            x.data, x_ref.data,
            "{threads}-thread fused CG solution must be identical"
        );
    }

    // the spin barrier flavor must agree too
    let mut op = NativeMdagM::new(&g, u, kappa);
    let mut team = Team::new(2, BarrierKind::Spin);
    let mut x = FermionField::<f32>::zeros(&g);
    let stats = solver::fused::cg(&mut op, &mut team, &mut x, &mbp, 1e-6, 200);
    assert_eq!(stats.history, reference.history, "spin-barrier history");
}

/// BiCGStab: serial unfused vs fused at 1, 2, 4 threads.
#[test]
fn threaded_bicgstab_matches_serial_unfused() {
    let g = geom();
    let mut rng = Rng::seeded(613);
    let u: GaugeField<f32> = GaugeField::<f64>::random(&g, &mut rng).to_precision();
    let b: FermionField<f32> =
        FermionField::<f64>::gaussian(&g, &mut rng).to_precision();
    let kappa = 0.12f32;

    let mut refop = NativeMeo::new(&g, u.clone(), kappa);
    let mut x_ref = FermionField::<f32>::zeros(&g);
    let reference = solver::bicgstab(&mut refop, &mut x_ref, &b, 1e-6, 200);
    assert!(reference.iterations > 3);

    for threads in [1usize, 2, 4] {
        let mut op = NativeMeo::new(&g, u.clone(), kappa);
        let mut team = Team::new(threads, BarrierKind::Sleep);
        let mut x = FermionField::<f32>::zeros(&g);
        let stats =
            solver::fused::bicgstab(&mut op, &mut team, &mut x, &b, 1e-6, 200);
        assert_eq!(stats.iterations, reference.iterations, "{threads} threads");
        assert_eq!(stats.history, reference.history, "{threads} threads");
        assert_eq!(x.data, x_ref.data, "{threads} threads");
    }
}

/// The mixed-precision refinement must be unchanged by running its
/// inner solves on the team.
#[test]
fn mixed_refinement_identical_on_team() {
    let g = geom();
    let mut rng = Rng::seeded(617);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let b = FermionField::<f64>::gaussian(&g, &mut rng);
    let kappa = 0.12f64;

    let run = |team: Option<&mut Team>| {
        let mut outer = NativeMeo::new(&g, u.clone(), kappa);
        let mut inner = NativeMeo::new(&g, u.to_precision::<f32>(), kappa as f32);
        let mut x = FermionField::<f64>::zeros(&g);
        let stats = match team {
            Some(team) => solver::mixed_refinement_team(
                &mut outer,
                &mut inner,
                &mut x,
                &b,
                1e-11,
                40,
                1e-4,
                200,
                InnerAlgorithm::BiCgStab,
                team,
            ),
            None => solver::mixed_refinement(
                &mut outer,
                &mut inner,
                &mut x,
                &b,
                1e-11,
                40,
                1e-4,
                200,
                InnerAlgorithm::BiCgStab,
            ),
        };
        (stats, x)
    };
    let (serial, x_serial) = run(None);
    assert!(serial.converged, "{serial:?}");

    let mut team = Team::new(3, BarrierKind::Sleep);
    let (teamed, x_team) = run(Some(&mut team));
    assert_eq!(teamed.outer_iterations, serial.outer_iterations);
    assert_eq!(teamed.inner_iterations, serial.inner_iterations);
    assert_eq!(teamed.history, serial.history);
    assert_eq!(teamed.inner_histories, serial.inner_histories);
    assert_eq!(x_team.data, x_serial.data);
}

/// A zero initial guess must skip the initial operator apply (cheaper
/// setup, same solve).
#[test]
fn zero_guess_skips_first_apply() {
    let g = geom();
    let mut rng = Rng::seeded(619);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let b = FermionField::<f32>::gaussian(&g, &mut rng);
    let mut op = NativeMeo::new(&g, u.clone(), 0.12f32);

    // tol = 1: |r| = |b| already satisfies |r| <= tol |b| — the solve
    // does zero iterations, so the remaining flops are the setup's
    let mut x = FermionField::<f32>::zeros(&g);
    let cold = solver::bicgstab(&mut op, &mut x, &b, 1.0, 10);
    assert!(cold.converged);
    assert_eq!(cold.iterations, 0);

    let mut xw = FermionField::<f32>::gaussian(&g, &mut rng);
    xw.scale(1e-3);
    let warm = solver::bicgstab(&mut op, &mut xw, &b, 1.0, 10);
    assert!(warm.converged);
    assert!(
        cold.flops < warm.flops,
        "zero guess must not pay the initial operator apply: {} vs {}",
        cold.flops,
        warm.flops
    );

    // and the skip does not change the solution of a real solve
    let mut x1 = FermionField::<f32>::zeros(&g);
    let s1 = solver::bicgstab(&mut op, &mut x1, &b, 1e-6, 200);
    assert!(s1.converged);
    let resid = solver::residual::operator_residual(&mut op, &x1, &b);
    assert!(resid < 1e-5, "true residual {resid}");
}
