//! Distributed-runtime correctness: the EO1 -> bulk ∥ comm -> EO2 pipeline
//! over the simulated-MPI rank world must reproduce the single-rank
//! periodic operator exactly, for every decomposition and for forced
//! self-communication (the paper's measurement mode).

use lqcd::comm::decompose::{extract_fermion, extract_gauge, insert_fermion};
use lqcd::comm::{run_world, Comm};
use lqcd::coordinator::operator::{DistMeo, LinearOperator, NormalOp};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::dslash::HoppingEo;
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, ProcGrid, Tiling};
use lqcd::solver;
use lqcd::util::rng::Rng;

fn run_case(
    global_dims: LatticeDims,
    grid: ProcGrid,
    tiling: Tiling,
    force_comm: bool,
    nthreads: usize,
    schedule: Eo2Schedule,
    p_out: Parity,
    seed: u64,
) {
    let ggeom = Geometry::single_rank(global_dims, tiling).unwrap();
    let mut rng = Rng::seeded(seed);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let psi_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);

    // reference: single-rank periodic
    let mut want = FermionField::zeros(&ggeom);
    HoppingEo::new(&ggeom).apply(&mut want, &u_global, &psi_global, p_out);

    // distributed
    let nranks = grid.size();
    let results = run_world(nranks, |rank, comm| {
        let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let psi = extract_fermion(&psi_global, &ggeom, &lgeom);
        let dist = DistHopping::new(&lgeom, force_comm, nthreads, schedule);
        let mut team = Team::new(nthreads, BarrierKind::Sleep);
        let prof = Profiler::new(nthreads);
        let mut out = FermionField::zeros(&lgeom);
        dist.hopping(&mut out, &u, &psi, p_out, comm, &mut team, &prof);
        out
    });

    let mut got = FermionField::zeros(&ggeom);
    for (rank, local) in results.iter().enumerate() {
        let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
        insert_fermion(&mut got, local, &lgeom);
    }

    let mut d = got.clone();
    d.axpy(-1.0, &want);
    let rel = (d.norm2() / want.norm2()).sqrt();
    assert!(
        rel < 1e-5,
        "distributed vs periodic rel diff {rel} (grid {grid:?}, force={force_comm}, nt={nthreads})"
    );
}

#[test]
fn single_rank_forced_self_comm() {
    // the paper's benchmark mode: one process per direction, comm enforced
    run_case(
        LatticeDims::new(8, 4, 4, 4).unwrap(),
        ProcGrid([1, 1, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        true,
        1,
        Eo2Schedule::Uniform,
        Parity::Odd,
        11,
    );
}

#[test]
fn paper_grid_1122() {
    // the paper's 4-process [1,1,2,2] assignment
    run_case(
        LatticeDims::new(8, 4, 4, 8).unwrap(),
        ProcGrid([1, 1, 2, 2]),
        Tiling::new(2, 2).unwrap(),
        true,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        12,
    );
}

#[test]
fn x_direction_split() {
    // x decomposition exercises the irregular compacted faces hardest
    run_case(
        LatticeDims::new(16, 4, 2, 2).unwrap(),
        ProcGrid([2, 1, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        false,
        1,
        Eo2Schedule::Uniform,
        Parity::Even,
        13,
    );
}

#[test]
fn y_direction_split() {
    run_case(
        LatticeDims::new(8, 8, 2, 2).unwrap(),
        ProcGrid([1, 2, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        false,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        14,
    );
}

#[test]
fn all_directions_split() {
    run_case(
        LatticeDims::new(8, 8, 4, 4).unwrap(),
        ProcGrid([2, 2, 2, 2]),
        Tiling::new(2, 2).unwrap(),
        false,
        1,
        Eo2Schedule::Uniform,
        Parity::Even,
        15,
    );
}

#[test]
fn balanced_schedule_same_result() {
    for schedule in [Eo2Schedule::Uniform, Eo2Schedule::Balanced] {
        run_case(
            LatticeDims::new(8, 4, 4, 8).unwrap(),
            ProcGrid([1, 1, 2, 2]),
            Tiling::new(2, 2).unwrap(),
            true,
            3,
            schedule,
            Parity::Odd,
            16,
        );
    }
}

#[test]
fn many_threads_and_both_parities() {
    for p in Parity::BOTH {
        run_case(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            ProcGrid([1, 1, 1, 2]),
            Tiling::new(2, 2).unwrap(),
            true,
            6,
            Eo2Schedule::Uniform,
            p,
            17 + p.index() as u64,
        );
    }
}

/// The pre-fusion distributed M-hat: two hoppings plus a *separate*
/// xpay sweep — exactly the pipeline `DistMeo`'s fused tail replaces.
/// Kept here as the reference for the bit-match and history pinning.
struct OldDistMeo<'a> {
    dist: &'a DistHopping,
    u: &'a GaugeField<f32>,
    kappa: f32,
    comm: &'a mut Comm,
    team: &'a mut Team,
    prof: &'a Profiler,
    tmp: FermionField<f32>,
}

impl LinearOperator<f32> for OldDistMeo<'_> {
    fn apply(&mut self, out: &mut FermionField<f32>, psi: &FermionField<f32>) {
        self.dist
            .hopping(&mut self.tmp, self.u, psi, Parity::Odd, self.comm, self.team, self.prof);
        self.dist
            .hopping(out, self.u, &self.tmp, Parity::Even, self.comm, self.team, self.prof);
        out.xpay(-(self.kappa * self.kappa), psi);
    }

    fn flops_per_apply(&self) -> u64 {
        lqcd::dslash::flops::meo_flops(self.dist.geom.local.half_volume())
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.comm.allreduce_sum(v)
    }
}

/// DistMeo's fused xpay tail (bulk-store tail without comm, EO2-fused
/// tail with comm) must reproduce the separate-xpay pipeline *bitwise*.
#[test]
fn dist_meo_fused_tail_bit_matches_separate_xpay() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    // (grid, force_comm): no-comm-dirs → bulk StoreTail::Xpay path;
    // forced self-comm and a real split → EO2-fused tail path
    let cases = [
        (ProcGrid([1, 1, 1, 1]), false),
        (ProcGrid([1, 1, 1, 1]), true),
        (ProcGrid([1, 1, 2, 2]), true),
    ];
    for (grid, force_comm) in cases {
        let ggeom = Geometry::single_rank(global, tiling).unwrap();
        let mut rng = Rng::seeded(41);
        let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
        let psi_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);
        let kappa = 0.137f32;
        run_world(grid.size(), |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let u = extract_gauge(&u_global, &lgeom);
            let psi = extract_fermion(&psi_global, &ggeom, &lgeom);
            let dist = DistHopping::new(&lgeom, force_comm, 2, Eo2Schedule::Uniform);
            let mut team = Team::new(2, BarrierKind::Sleep);
            let prof = Profiler::new(2);

            // reference: hopping, hopping, separate xpay
            let mut want = FermionField::zeros(&lgeom);
            let mut tmp = FermionField::zeros(&lgeom);
            dist.hopping(&mut tmp, &u, &psi, Parity::Odd, comm, &mut team, &prof);
            dist.hopping(&mut want, &u, &tmp, Parity::Even, comm, &mut team, &prof);
            want.xpay(-(kappa * kappa), &psi);

            // fused DistMeo
            let mut got = FermionField::zeros(&lgeom);
            let mut op = DistMeo::new(&lgeom, &dist, &u, kappa, comm, &mut team, &prof);
            op.apply(&mut got, &psi);

            assert_eq!(
                got.data, want.data,
                "fused tail must bit-match (grid {grid:?}, force={force_comm}, rank {rank})"
            );
        });
    }
}

/// A distributed CGNR solve through the fused DistMeo must produce a
/// residual history identical to the separate-xpay pipeline's — the
/// fusion changes memory traffic, never arithmetic.
#[test]
fn dist_meo_fused_solve_history_pinned() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let grid = ProcGrid([1, 1, 1, 2]);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(43);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let b_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);
    let kappa = 0.12f32;
    let (tol, maxiter) = (1e-5, 40);

    let histories = run_world(grid.size(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let b = extract_fermion(&b_global, &ggeom, &lgeom);
        let dist = DistHopping::new(&lgeom, true, 2, Eo2Schedule::Uniform);
        let prof = Profiler::new(2);

        // reference solve on the old separate-xpay operator
        let old_hist = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let inner = OldDistMeo {
                dist: &dist,
                u: &u,
                kappa,
                comm: &mut *comm,
                team: &mut team,
                prof: &prof,
                tmp: FermionField::zeros(&lgeom),
            };
            let mut op = NormalOp::new(inner, &lgeom);
            let mut x = FermionField::<f32>::zeros(&lgeom);
            let stats = solver::cg(&mut op, &mut x, &b, tol, maxiter);
            stats.history
        };

        // same solve on the fused operator
        let new_hist = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let inner = DistMeo::new(&lgeom, &dist, &u, kappa, comm, &mut team, &prof);
            let mut op = NormalOp::new(inner, &lgeom);
            let mut x = FermionField::<f32>::zeros(&lgeom);
            let stats = solver::cg(&mut op, &mut x, &b, tol, maxiter);
            stats.history
        };
        (old_hist, new_hist)
    });

    for (rank, (old_hist, new_hist)) in histories.iter().enumerate() {
        assert!(!old_hist.is_empty(), "reference solve ran no iterations");
        assert_eq!(
            old_hist, new_hist,
            "rank {rank}: fused DistMeo residual history diverged from separate-xpay"
        );
    }
}

#[test]
fn larger_tiling_with_comm() {
    run_case(
        LatticeDims::new(16, 8, 2, 4).unwrap(),
        ProcGrid([1, 1, 1, 2]),
        Tiling::new(4, 2).unwrap(),
        true,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        19,
    );
}
