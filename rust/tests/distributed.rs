//! Distributed-runtime correctness: the EO1 -> bulk ∥ comm -> EO2 pipeline
//! over the simulated-MPI rank world must reproduce the single-rank
//! periodic operator exactly, for every decomposition and for forced
//! self-communication (the paper's measurement mode).

use lqcd::comm::decompose::{extract_fermion, extract_gauge, insert_fermion};
use lqcd::comm::run_world;
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::dslash::HoppingEo;
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, ProcGrid, Tiling};
use lqcd::util::rng::Rng;

fn run_case(
    global_dims: LatticeDims,
    grid: ProcGrid,
    tiling: Tiling,
    force_comm: bool,
    nthreads: usize,
    schedule: Eo2Schedule,
    p_out: Parity,
    seed: u64,
) {
    let ggeom = Geometry::single_rank(global_dims, tiling).unwrap();
    let mut rng = Rng::seeded(seed);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let psi_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);

    // reference: single-rank periodic
    let mut want = FermionField::zeros(&ggeom);
    HoppingEo::new(&ggeom).apply(&mut want, &u_global, &psi_global, p_out);

    // distributed
    let nranks = grid.size();
    let results = run_world(nranks, |rank, comm| {
        let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let psi = extract_fermion(&psi_global, &ggeom, &lgeom);
        let dist = DistHopping::new(&lgeom, force_comm, nthreads, schedule);
        let mut team = Team::new(nthreads, BarrierKind::Sleep);
        let prof = Profiler::new(nthreads);
        let mut out = FermionField::zeros(&lgeom);
        dist.hopping(&mut out, &u, &psi, p_out, comm, &mut team, &prof);
        out
    });

    let mut got = FermionField::zeros(&ggeom);
    for (rank, local) in results.iter().enumerate() {
        let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
        insert_fermion(&mut got, local, &lgeom);
    }

    let mut d = got.clone();
    d.axpy(-1.0, &want);
    let rel = (d.norm2() / want.norm2()).sqrt();
    assert!(
        rel < 1e-5,
        "distributed vs periodic rel diff {rel} (grid {grid:?}, force={force_comm}, nt={nthreads})"
    );
}

#[test]
fn single_rank_forced_self_comm() {
    // the paper's benchmark mode: one process per direction, comm enforced
    run_case(
        LatticeDims::new(8, 4, 4, 4).unwrap(),
        ProcGrid([1, 1, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        true,
        1,
        Eo2Schedule::Uniform,
        Parity::Odd,
        11,
    );
}

#[test]
fn paper_grid_1122() {
    // the paper's 4-process [1,1,2,2] assignment
    run_case(
        LatticeDims::new(8, 4, 4, 8).unwrap(),
        ProcGrid([1, 1, 2, 2]),
        Tiling::new(2, 2).unwrap(),
        true,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        12,
    );
}

#[test]
fn x_direction_split() {
    // x decomposition exercises the irregular compacted faces hardest
    run_case(
        LatticeDims::new(16, 4, 2, 2).unwrap(),
        ProcGrid([2, 1, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        false,
        1,
        Eo2Schedule::Uniform,
        Parity::Even,
        13,
    );
}

#[test]
fn y_direction_split() {
    run_case(
        LatticeDims::new(8, 8, 2, 2).unwrap(),
        ProcGrid([1, 2, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        false,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        14,
    );
}

#[test]
fn all_directions_split() {
    run_case(
        LatticeDims::new(8, 8, 4, 4).unwrap(),
        ProcGrid([2, 2, 2, 2]),
        Tiling::new(2, 2).unwrap(),
        false,
        1,
        Eo2Schedule::Uniform,
        Parity::Even,
        15,
    );
}

#[test]
fn balanced_schedule_same_result() {
    for schedule in [Eo2Schedule::Uniform, Eo2Schedule::Balanced] {
        run_case(
            LatticeDims::new(8, 4, 4, 8).unwrap(),
            ProcGrid([1, 1, 2, 2]),
            Tiling::new(2, 2).unwrap(),
            true,
            3,
            schedule,
            Parity::Odd,
            16,
        );
    }
}

#[test]
fn many_threads_and_both_parities() {
    for p in Parity::BOTH {
        run_case(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            ProcGrid([1, 1, 1, 2]),
            Tiling::new(2, 2).unwrap(),
            true,
            6,
            Eo2Schedule::Uniform,
            p,
            17 + p.index() as u64,
        );
    }
}

#[test]
fn larger_tiling_with_comm() {
    run_case(
        LatticeDims::new(16, 8, 2, 4).unwrap(),
        ProcGrid([1, 1, 1, 2]),
        Tiling::new(4, 2).unwrap(),
        true,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        19,
    );
}
