//! Distributed-runtime correctness: the EO1 -> bulk ∥ comm -> EO2 pipeline
//! over the simulated-MPI rank world must reproduce the single-rank
//! periodic operator exactly, for every decomposition and for forced
//! self-communication (the paper's measurement mode).

use lqcd::comm::decompose::{extract_fermion, extract_gauge, insert_fermion};
use lqcd::comm::{run_world, validate_wire_format, Comm};
use lqcd::coordinator::operator::{
    DistMeo, DistMultiMdagM, DistMultiMeo, LinearOperator, MultiMdagM, MultiOperator,
    NormalOp,
};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::dslash::HoppingEo;
use lqcd::field::{CompressedGaugeField, FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, ProcGrid, Tiling};
use lqcd::solver;
use lqcd::util::rng::Rng;

fn run_case(
    global_dims: LatticeDims,
    grid: ProcGrid,
    tiling: Tiling,
    force_comm: bool,
    nthreads: usize,
    schedule: Eo2Schedule,
    p_out: Parity,
    seed: u64,
) {
    let ggeom = Geometry::single_rank(global_dims, tiling).unwrap();
    let mut rng = Rng::seeded(seed);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let psi_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);

    // reference: single-rank periodic
    let mut want = FermionField::zeros(&ggeom);
    HoppingEo::new(&ggeom).apply(&mut want, &u_global, &psi_global, p_out);

    // distributed
    let nranks = grid.size();
    let results = run_world(nranks, |rank, comm| {
        let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let psi = extract_fermion(&psi_global, &ggeom, &lgeom);
        let dist = DistHopping::new(&lgeom, force_comm, nthreads, schedule);
        let mut team = Team::new(nthreads, BarrierKind::Sleep);
        let prof = Profiler::new(nthreads);
        let mut out = FermionField::zeros(&lgeom);
        dist.hopping(&mut out, &u, &psi, p_out, comm, &mut team, &prof);
        out
    });

    let mut got = FermionField::zeros(&ggeom);
    for (rank, local) in results.iter().enumerate() {
        let lgeom = Geometry::for_rank(global_dims, grid, rank, tiling).unwrap();
        insert_fermion(&mut got, local, &lgeom);
    }

    let mut d = got.clone();
    d.axpy(-1.0, &want);
    let rel = (d.norm2() / want.norm2()).sqrt();
    assert!(
        rel < 1e-5,
        "distributed vs periodic rel diff {rel} (grid {grid:?}, force={force_comm}, nt={nthreads})"
    );
}

#[test]
fn single_rank_forced_self_comm() {
    // the paper's benchmark mode: one process per direction, comm enforced
    run_case(
        LatticeDims::new(8, 4, 4, 4).unwrap(),
        ProcGrid([1, 1, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        true,
        1,
        Eo2Schedule::Uniform,
        Parity::Odd,
        11,
    );
}

#[test]
fn paper_grid_1122() {
    // the paper's 4-process [1,1,2,2] assignment
    run_case(
        LatticeDims::new(8, 4, 4, 8).unwrap(),
        ProcGrid([1, 1, 2, 2]),
        Tiling::new(2, 2).unwrap(),
        true,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        12,
    );
}

#[test]
fn x_direction_split() {
    // x decomposition exercises the irregular compacted faces hardest
    run_case(
        LatticeDims::new(16, 4, 2, 2).unwrap(),
        ProcGrid([2, 1, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        false,
        1,
        Eo2Schedule::Uniform,
        Parity::Even,
        13,
    );
}

#[test]
fn y_direction_split() {
    run_case(
        LatticeDims::new(8, 8, 2, 2).unwrap(),
        ProcGrid([1, 2, 1, 1]),
        Tiling::new(2, 2).unwrap(),
        false,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        14,
    );
}

#[test]
fn all_directions_split() {
    run_case(
        LatticeDims::new(8, 8, 4, 4).unwrap(),
        ProcGrid([2, 2, 2, 2]),
        Tiling::new(2, 2).unwrap(),
        false,
        1,
        Eo2Schedule::Uniform,
        Parity::Even,
        15,
    );
}

#[test]
fn balanced_schedule_same_result() {
    for schedule in [Eo2Schedule::Uniform, Eo2Schedule::Balanced] {
        run_case(
            LatticeDims::new(8, 4, 4, 8).unwrap(),
            ProcGrid([1, 1, 2, 2]),
            Tiling::new(2, 2).unwrap(),
            true,
            3,
            schedule,
            Parity::Odd,
            16,
        );
    }
}

#[test]
fn many_threads_and_both_parities() {
    for p in Parity::BOTH {
        run_case(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            ProcGrid([1, 1, 1, 2]),
            Tiling::new(2, 2).unwrap(),
            true,
            6,
            Eo2Schedule::Uniform,
            p,
            17 + p.index() as u64,
        );
    }
}

/// The pre-fusion distributed M-hat: two hoppings plus a *separate*
/// xpay sweep — exactly the pipeline `DistMeo`'s fused tail replaces.
/// Kept here as the reference for the bit-match and history pinning.
struct OldDistMeo<'a> {
    dist: &'a DistHopping,
    u: &'a GaugeField<f32>,
    kappa: f32,
    comm: &'a mut Comm,
    team: &'a mut Team,
    prof: &'a Profiler,
    tmp: FermionField<f32>,
}

impl LinearOperator<f32> for OldDistMeo<'_> {
    fn apply(&mut self, out: &mut FermionField<f32>, psi: &FermionField<f32>) {
        self.dist
            .hopping(&mut self.tmp, self.u, psi, Parity::Odd, self.comm, self.team, self.prof);
        self.dist
            .hopping(out, self.u, &self.tmp, Parity::Even, self.comm, self.team, self.prof);
        out.xpay(-(self.kappa * self.kappa), psi);
    }

    fn flops_per_apply(&self) -> u64 {
        lqcd::dslash::flops::meo_flops(self.dist.geom.local.half_volume())
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.comm.allreduce_sum(v)
    }
}

/// DistMeo's fused xpay tail (bulk-store tail without comm, EO2-fused
/// tail with comm) must reproduce the separate-xpay pipeline *bitwise*.
#[test]
fn dist_meo_fused_tail_bit_matches_separate_xpay() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    // (grid, force_comm): no-comm-dirs → bulk StoreTail::Xpay path;
    // forced self-comm and a real split → EO2-fused tail path
    let cases = [
        (ProcGrid([1, 1, 1, 1]), false),
        (ProcGrid([1, 1, 1, 1]), true),
        (ProcGrid([1, 1, 2, 2]), true),
    ];
    for (grid, force_comm) in cases {
        let ggeom = Geometry::single_rank(global, tiling).unwrap();
        let mut rng = Rng::seeded(41);
        let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
        let psi_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);
        let kappa = 0.137f32;
        run_world(grid.size(), |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let u = extract_gauge(&u_global, &lgeom);
            let psi = extract_fermion(&psi_global, &ggeom, &lgeom);
            let dist = DistHopping::new(&lgeom, force_comm, 2, Eo2Schedule::Uniform);
            let mut team = Team::new(2, BarrierKind::Sleep);
            let prof = Profiler::new(2);

            // reference: hopping, hopping, separate xpay
            let mut want = FermionField::zeros(&lgeom);
            let mut tmp = FermionField::zeros(&lgeom);
            dist.hopping(&mut tmp, &u, &psi, Parity::Odd, comm, &mut team, &prof);
            dist.hopping(&mut want, &u, &tmp, Parity::Even, comm, &mut team, &prof);
            want.xpay(-(kappa * kappa), &psi);

            // fused DistMeo
            let mut got = FermionField::zeros(&lgeom);
            let mut op = DistMeo::new(&lgeom, &dist, &u, kappa, comm, &mut team, &prof);
            op.apply(&mut got, &psi);

            assert_eq!(
                got.data, want.data,
                "fused tail must bit-match (grid {grid:?}, force={force_comm}, rank {rank})"
            );
        });
    }
}

/// A distributed CGNR solve through the fused DistMeo must produce a
/// residual history identical to the separate-xpay pipeline's — the
/// fusion changes memory traffic, never arithmetic.
#[test]
fn dist_meo_fused_solve_history_pinned() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let grid = ProcGrid([1, 1, 1, 2]);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(43);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let b_global: FermionField = FermionField::gaussian(&ggeom, &mut rng);
    let kappa = 0.12f32;
    let (tol, maxiter) = (1e-5, 40);

    let histories = run_world(grid.size(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let b = extract_fermion(&b_global, &ggeom, &lgeom);
        let dist = DistHopping::new(&lgeom, true, 2, Eo2Schedule::Uniform);
        let prof = Profiler::new(2);

        // reference solve on the old separate-xpay operator
        let old_hist = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let inner = OldDistMeo {
                dist: &dist,
                u: &u,
                kappa,
                comm: &mut *comm,
                team: &mut team,
                prof: &prof,
                tmp: FermionField::zeros(&lgeom),
            };
            let mut op = NormalOp::new(inner, &lgeom);
            let mut x = FermionField::<f32>::zeros(&lgeom);
            let stats = solver::cg(&mut op, &mut x, &b, tol, maxiter);
            stats.history
        };

        // same solve on the fused operator
        let new_hist = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let inner = DistMeo::new(&lgeom, &dist, &u, kappa, comm, &mut team, &prof);
            let mut op = NormalOp::new(inner, &lgeom);
            let mut x = FermionField::<f32>::zeros(&lgeom);
            let stats = solver::cg(&mut op, &mut x, &b, tol, maxiter);
            stats.history
        };
        (old_hist, new_hist)
    });

    for (rank, (old_hist, new_hist)) in histories.iter().enumerate() {
        assert!(!old_hist.is_empty(), "reference solve ran no iterations");
        assert_eq!(
            old_hist, new_hist,
            "rank {rank}: fused DistMeo residual history diverged from separate-xpay"
        );
    }
}

#[test]
fn larger_tiling_with_comm() {
    run_case(
        LatticeDims::new(16, 8, 2, 4).unwrap(),
        ProcGrid([1, 1, 1, 2]),
        Tiling::new(4, 2).unwrap(),
        true,
        2,
        Eo2Schedule::Uniform,
        Parity::Odd,
        19,
    );
}

// ===================== batched multi-RHS distributed path ================

/// The batched distributed M-hat must reproduce the single-RHS fused
/// [`DistMeo`] *bitwise* per RHS — one message per direction for all
/// RHS changes the wire format, never the arithmetic — including with
/// a staggered convergence mask (masked RHS frozen, absent from the
/// payload).
#[test]
fn dist_multi_meo_bit_matches_single_rhs_dist_meo() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let nrhs = 3;
    // no-comm bulk-tail path, forced self-comm, and a real split
    let cases = [
        (ProcGrid([1, 1, 1, 1]), false),
        (ProcGrid([1, 1, 1, 1]), true),
        (ProcGrid([1, 1, 2, 2]), true),
        (ProcGrid([2, 1, 1, 1]), false),
    ];
    for (grid, force_comm) in cases {
        let ggeom = Geometry::single_rank(global, tiling).unwrap();
        let mut rng = Rng::seeded(71);
        let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
        let psis_global: Vec<FermionField> =
            (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
        let kappa = 0.131f32;
        run_world(grid.size(), |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let u = extract_gauge(&u_global, &lgeom);
            let psis: Vec<FermionField> = psis_global
                .iter()
                .map(|p| extract_fermion(p, &ggeom, &lgeom))
                .collect();
            let dist = DistHopping::new(&lgeom, force_comm, 2, Eo2Schedule::Uniform);
            let mut team = Team::new(2, BarrierKind::Sleep);
            let prof = Profiler::new(2);

            // reference: the single-RHS fused DistMeo, one RHS at a time
            let mut want = Vec::new();
            for psi in &psis {
                let mut op =
                    DistMeo::new(&lgeom, &dist, &u, kappa, &mut *comm, &mut team, &prof);
                let mut o = FermionField::zeros(&lgeom);
                op.apply(&mut o, psi);
                want.push(o);
            }

            // batched: all RHS through one exchange per direction
            let psi_m = MultiFermionField::from_rhs(&psis);
            let mut out = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            let mut mop =
                DistMultiMeo::new(&lgeom, &dist, &u, kappa, nrhs, comm, &prof).unwrap();
            mop.apply_multi(&mut team, &mut out, &psi_m, &[true; 3], None);
            for (r, w) in want.iter().enumerate() {
                assert_eq!(
                    out.extract_rhs(r).data,
                    w.data,
                    "rhs {r} diverged (grid {grid:?}, force={force_comm}, rank {rank})"
                );
            }

            // staggered mask: active RHS bit-identical, masked frozen
            let mut out2 = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            mop.apply_multi(&mut team, &mut out2, &psi_m, &[true, false, true], None);
            assert_eq!(out2.extract_rhs(0).data, want[0].data);
            assert_eq!(out2.extract_rhs(2).data, want[2].data);
            assert!(out2.extract_rhs(1).data.iter().all(|&v| v == 0.0), "masked rhs written");
        });
    }
}

/// Distributed block BiCGStab at nrhs = N must give per-RHS residual
/// histories bitwise identical to N independent nrhs = 1 distributed
/// solves: the recurrences are independent and masking one RHS never
/// perturbs another, even though all of them share each halo message.
#[test]
fn dist_block_bicgstab_histories_bit_match_nrhs1() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let grid = ProcGrid([1, 1, 1, 2]);
    let nrhs = 3;
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(72);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let bs_global: Vec<FermionField> =
        (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let kappa = 0.12f32;
    let (tol, maxiter) = (1e-4, 60);

    let results = run_world(grid.size(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let bs: Vec<FermionField> = bs_global
            .iter()
            .map(|b| extract_fermion(b, &ggeom, &lgeom))
            .collect();
        let dist = DistHopping::new(&lgeom, true, 2, Eo2Schedule::Uniform);
        let prof = Profiler::new(2);

        // batched solve, all RHS in one wire stream
        let batched = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let b = MultiFermionField::from_rhs(&bs);
            let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            let mut op =
                DistMultiMeo::new(&lgeom, &dist, &u, kappa, nrhs, &mut *comm, &prof)
                    .unwrap();
            solver::block_bicgstab_generic(&mut op, &mut team, &mut x, &b, tol, maxiter)
        };
        // N independent single-RHS batched solves
        let singles: Vec<_> = bs
            .iter()
            .map(|b1| {
                let mut team = Team::new(2, BarrierKind::Sleep);
                let b = MultiFermionField::from_rhs(std::slice::from_ref(b1));
                let mut x = MultiFermionField::<f32>::zeros(&lgeom, 1);
                let mut op =
                    DistMultiMeo::new(&lgeom, &dist, &u, kappa, 1, &mut *comm, &prof)
                        .unwrap();
                solver::block_bicgstab_generic(&mut op, &mut team, &mut x, &b, tol, maxiter)
            })
            .collect();
        (batched, singles)
    });

    for (rank, (batched, singles)) in results.iter().enumerate() {
        for r in 0..nrhs {
            assert!(!singles[r].per_rhs[0].history.is_empty());
            assert_eq!(
                batched.per_rhs[r].history, singles[r].per_rhs[0].history,
                "rank {rank} rhs {r}: batched history diverged from independent solve"
            );
            assert_eq!(batched.per_rhs[r].converged, singles[r].per_rhs[0].converged);
        }
    }
}

/// On one rank without communicated directions the distributed generic
/// block CG is the native pipeline: per-RHS histories must be BITWISE
/// identical to the single-rank fused [`solver::block_cg`] (which PR 3
/// pinned against N independent fused solves).
#[test]
fn dist_block_cg_single_rank_bit_matches_native_block() {
    let global = LatticeDims::new(8, 4, 4, 4).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let nrhs = 2;
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(73);
    let u: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let sources: Vec<FermionField> =
        (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let kappa = 0.13f32;
    let (tol, maxiter) = (1e-5, 80);

    // CGNR right-hand sides via the native operator
    let mut meo = lqcd::coordinator::operator::NativeMeo::new(&ggeom, u.clone(), kappa);
    let rhs: Vec<FermionField> = sources
        .iter()
        .map(|b| {
            let mut bp = b.clone();
            bp.gamma5();
            let mut mbp = FermionField::zeros(&ggeom);
            meo.apply(&mut mbp, &bp);
            mbp.gamma5();
            mbp
        })
        .collect();
    let b = MultiFermionField::from_rhs(&rhs);

    // native fused block solver
    let native = {
        let mut team = Team::new(2, BarrierKind::Sleep);
        let mut op = MultiMdagM::new(&ggeom, u.clone(), kappa, nrhs);
        let mut x = MultiFermionField::<f32>::zeros(&ggeom, nrhs);
        solver::block_cg(&mut op, &mut team, &mut x, &b, tol, maxiter)
    };

    // distributed generic solver, 1 rank, periodic bulk (no comm dirs)
    let dist_stats = run_world(1, |_, comm| {
        let dist = DistHopping::new(&ggeom, false, 2, Eo2Schedule::Uniform);
        let mut team = Team::new(2, BarrierKind::Sleep);
        let prof = Profiler::new(2);
        let mut op =
            DistMultiMdagM::new(&ggeom, &dist, &u, kappa, nrhs, comm, &prof).unwrap();
        let mut x = MultiFermionField::<f32>::zeros(&ggeom, nrhs);
        solver::block_cg_generic(&mut op, &mut team, &mut x, &b, tol, maxiter)
    })
    .pop()
    .unwrap();

    assert!(native.iterations > 0);
    assert_eq!(native.iterations, dist_stats.iterations);
    for r in 0..nrhs {
        assert_eq!(
            native.per_rhs[r].history, dist_stats.per_rhs[r].history,
            "rhs {r}: generic distributed history != native fused block history"
        );
    }
}

/// Across a real decomposition the reductions stay bitwise (global
/// site-tile fold), and the only rounding difference versus the
/// single-rank block solver is the face sites' halo-merge accumulation
/// order — at f64 the per-iteration histories must agree to ~1e-12
/// with identical iteration counts, at 1, 2 and 4 simulated ranks.
#[test]
fn dist_block_cg_f64_tracks_single_rank_block() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let nrhs = 2;
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(74);
    let u: GaugeField<f64> = GaugeField::random(&ggeom, &mut rng);
    let sources: Vec<FermionField<f64>> =
        (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let kappa = 0.125f64;
    // fixed-iteration window far above convergence: deterministic
    // history length, no mask flips near the tolerance edge
    let (tol, maxiter) = (1e-30, 15);

    let mut meo = lqcd::coordinator::operator::NativeMeo::new(&ggeom, u.clone(), kappa);
    let rhs: Vec<FermionField<f64>> = sources
        .iter()
        .map(|b| {
            let mut bp = b.clone();
            bp.gamma5();
            let mut mbp = FermionField::zeros(&ggeom);
            meo.apply(&mut mbp, &bp);
            mbp.gamma5();
            mbp
        })
        .collect();
    let b_global = MultiFermionField::from_rhs(&rhs);

    let native = {
        let mut team = Team::new(1, BarrierKind::Sleep);
        let mut op = MultiMdagM::new(&ggeom, u.clone(), kappa, nrhs);
        let mut x = MultiFermionField::<f64>::zeros(&ggeom, nrhs);
        solver::block_cg(&mut op, &mut team, &mut x, &b_global, tol, maxiter)
    };

    for grid in [ProcGrid([1, 1, 1, 1]), ProcGrid([1, 1, 1, 2]), ProcGrid([1, 1, 2, 2])] {
        let stats = run_world(grid.size(), |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let ul = extract_gauge(&u, &lgeom);
            let rl: Vec<FermionField<f64>> = rhs
                .iter()
                .map(|f| extract_fermion(f, &ggeom, &lgeom))
                .collect();
            let bl = MultiFermionField::from_rhs(&rl);
            let dist = DistHopping::new(&lgeom, true, 2, Eo2Schedule::Uniform);
            let mut team = Team::new(2, BarrierKind::Sleep);
            let prof = Profiler::new(2);
            let mut op =
                DistMultiMdagM::new(&lgeom, &dist, &ul, kappa, nrhs, comm, &prof).unwrap();
            let mut x = MultiFermionField::<f64>::zeros(&lgeom, nrhs);
            solver::block_cg_generic(&mut op, &mut team, &mut x, &bl, tol, maxiter)
        });
        // every rank reports identical stats (global reductions)
        for s in &stats {
            assert_eq!(s.iterations, stats[0].iterations);
            for r in 0..nrhs {
                assert_eq!(s.per_rhs[r].history, stats[0].per_rhs[r].history);
            }
        }
        for r in 0..nrhs {
            let h = &stats[0].per_rhs[r].history;
            assert_eq!(h.len(), native.per_rhs[r].history.len(), "grid {grid:?}");
            for (i, (a, w)) in h.iter().zip(&native.per_rhs[r].history).enumerate() {
                let rel = (a - w).abs() / w.abs();
                assert!(
                    rel < 1e-8,
                    "grid {grid:?} rhs {r} iter {i}: {a} vs {w} (rel {rel:.2e})"
                );
            }
        }
    }
}

/// Two-row compressed links compose with the batched distributed path:
/// on a two-row-projected field the compressed solve's histories are
/// bitwise the full-link solve's.
#[test]
fn dist_block_two_row_bit_matches_full_links() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let grid = ProcGrid([1, 1, 1, 2]);
    let nrhs = 2;
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(75);
    // project through the two-row round trip so compressed == full bitwise
    let u_global: GaugeField<f32> = {
        let raw: GaugeField<f32> = GaugeField::random(&ggeom, &mut rng);
        CompressedGaugeField::compress(&raw).reconstruct()
    };
    let bs_global: Vec<FermionField> =
        (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let kappa = 0.12f32;
    let (tol, maxiter) = (1e-4, 50);

    let results = run_world(grid.size(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let proj = extract_gauge(&u_global, &lgeom);
        let compressed = CompressedGaugeField::compress(&proj);
        let bs: Vec<FermionField> = bs_global
            .iter()
            .map(|b| extract_fermion(b, &ggeom, &lgeom))
            .collect();
        let b = MultiFermionField::from_rhs(&bs);
        let dist = DistHopping::new(&lgeom, true, 2, Eo2Schedule::Uniform);
        let prof = Profiler::new(2);

        let full = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            let mut op =
                DistMultiMeo::new(&lgeom, &dist, &proj, kappa, nrhs, &mut *comm, &prof)
                    .unwrap();
            solver::block_bicgstab_generic(&mut op, &mut team, &mut x, &b, tol, maxiter)
        };
        let two_row = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            let mut op = DistMultiMeo::new(
                &lgeom, &dist, &compressed, kappa, nrhs, comm, &prof,
            )
            .unwrap();
            solver::block_bicgstab_generic(&mut op, &mut team, &mut x, &b, tol, maxiter)
        };
        (full, two_row)
    });
    for (rank, (full, two_row)) in results.iter().enumerate() {
        for r in 0..nrhs {
            assert!(!full.per_rhs[r].history.is_empty());
            assert_eq!(
                full.per_rhs[r].history, two_row.per_rhs[r].history,
                "rank {rank} rhs {r}: two-row distributed history != full links"
            );
        }
    }
}

/// Regression (wire-format handshake): a precision / nrhs / mask desync
/// across ranks is a structured error surfaced BEFORE any halo payload
/// is posted — the pre-batching behavior was a type panic mid-exchange.
#[test]
fn wire_format_desync_is_structured_error_before_send() {
    // nrhs desync at operator construction: both ranks get Err, and the
    // message names both ranks' batch shapes
    let msgs = run_world(2, |rank, comm| {
        let nrhs = if rank == 0 { 2 } else { 4 };
        validate_wire_format::<f32>(comm, nrhs, &vec![true; nrhs])
            .unwrap_err()
            .to_string()
    });
    for m in &msgs {
        assert!(m.contains("rank 0") && m.contains("nrhs 2"), "{m}");
        assert!(m.contains("rank 1") && m.contains("nrhs 4"), "{m}");
    }

    // the same handshake is what DistMultiMeo::new runs: a desynced
    // construction fails as a Result, never touching the wire
    let global = LatticeDims::new(8, 4, 4, 4).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let grid = ProcGrid([1, 1, 1, 2]);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(76);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let errs = run_world(grid.size(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
        let prof = Profiler::new(1);
        let nrhs = if rank == 0 { 1 } else { 2 };
        DistMultiMeo::new(&lgeom, &dist, &u, 0.1f32, nrhs, comm, &prof)
            .err()
            .map(|e| e.to_string())
    });
    for e in errs {
        let e = e.expect("desynced construction must fail on every rank");
        assert!(e.contains("before any payload was sent"), "{e}");
    }
}
