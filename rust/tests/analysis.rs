//! Tier-2: the invariant linter (fixture snippets per rule: violation
//! detected, suppression honored, clean code passes) and the
//! concurrency model checker (shipping protocols pass exhaustively at
//! 2-3 threads; seeded mutants are provably caught).

use std::path::Path;

use lqcd::analysis::lint::{
    check_config_doc, documented_toml_keys, lint_source, lint_tree, parsed_config_keys,
};
use lqcd::analysis::model::{
    check, run_suite, BarrierBug, BarrierKind, BarrierModel, CheckOpts, RecvFault,
    RecvModel, RingModel, RingVariant,
};

fn rules_of(findings: &[lqcd::analysis::Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// -------------------------------------------------------------------
// safety-comment
// -------------------------------------------------------------------

#[test]
fn safety_comment_violation_detected() {
    let src = "fn f(p: *mut u8) {\n    let v = unsafe { *p };\n    drop(v);\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    assert_eq!(rules_of(&findings), ["safety-comment"]);
    assert_eq!(findings[0].line, 2);
}

#[test]
fn safety_comment_satisfied_by_preceding_comment() {
    let src = "fn f(p: *mut u8) {\n    // SAFETY: p is valid for reads.\n    let v = unsafe { *p };\n    drop(v);\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn safety_comment_satisfied_by_multiline_block_and_doc() {
    // the SAFETY text may sit anywhere in the contiguous comment block,
    // and `# Safety` doc sections count for `unsafe fn` declarations
    let src = "\
// SAFETY: the region is disjoint per thread\n// and the barrier orders the reads.\nfn g(p: *mut u8) { let _ = unsafe { *p }; }\n\n/// Reads a raw pointer.\n///\n/// # Safety\n/// `p` must be valid.\nunsafe fn h(p: *const u8) -> u8 {\n    *p\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn safety_comment_blocked_by_blank_line() {
    let src = "// SAFETY: stale justification.\n\nfn f(p: *mut u8) { let _ = unsafe { *p }; }\n";
    let (findings, _) = lint_source("x.rs", src);
    assert_eq!(rules_of(&findings), ["safety-comment"]);
}

#[test]
fn safety_comment_ignores_string_and_comment_mentions() {
    // the token inside a string or comment is not an unsafe block
    let src = "fn f() {\n    let s = \"unsafe\";\n    // unsafe in prose only\n    drop(s);\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

// -------------------------------------------------------------------
// raw-f64-accum
// -------------------------------------------------------------------

#[test]
fn raw_accum_violation_detected() {
    let src = "fn combine(partials: &[f64]) -> f64 {\n    let mut acc = 0.0;\n    for p in partials {\n        acc += p;\n    }\n    acc\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    // the `acc += p` line mentions neither "partial" nor sum(); the
    // loop header does not accumulate. The .sum() form is the one that
    // pairs the accumulation and the partials on one line:
    let src2 = "fn combine(partials: &[f64]) -> f64 {\n    partials.iter().sum()\n}\n";
    let (findings2, _) = lint_source("x.rs", src2);
    let all: Vec<_> = rules_of(&findings).into_iter().chain(rules_of(&findings2)).collect();
    assert!(all.contains(&"raw-f64-accum"), "{findings:?} / {findings2:?}");
}

#[test]
fn raw_accum_inline_accumulation_detected() {
    let src = "fn f(rr_partials: &[f64]) -> f64 {\n    let mut rr = 0.0;\n    for t in 0..rr_partials.len() {\n        rr += rr_partials[t];\n    }\n    rr\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    assert_eq!(rules_of(&findings), ["raw-f64-accum"]);
    assert_eq!(findings[0].line, 4);
}

#[test]
fn raw_accum_allowed_in_reduce_fns_and_blas() {
    // canonical-grouping helpers are exactly where raw sums belong
    let src = "fn reduce_partials_local(partials: &[f64]) -> f64 {\n    partials.iter().sum()\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
    // ...and the blas module is allowlisted wholesale
    let src2 = "fn helper(partials: &[f64]) -> f64 {\n    partials.iter().sum()\n}\n";
    let (findings2, _) = lint_source("rust/src/field/blas.rs", src2);
    assert!(findings2.is_empty(), "{findings2:?}");
}

#[test]
fn raw_accum_ignores_non_partial_sums() {
    let src = "fn f(xs: &[u64]) -> u64 {\n    xs.iter().sum()\n}\n";
    let (findings, _) = lint_source("x.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

// -------------------------------------------------------------------
// tag-registry
// -------------------------------------------------------------------

#[test]
fn tag_registry_violation_detected() {
    let shift = ["1u64 <", "< 63"].concat(); // not a violation in THIS file
    let src = format!("fn f(gen: u64) -> u64 {{\n    ({shift}) | gen\n}}\n");
    let (findings, _) = lint_source("x.rs", &src);
    assert_eq!(rules_of(&findings), ["tag-registry"]);
}

#[test]
fn tag_registry_fn_decl_detected() {
    let decl = ["fn t", "ag("].concat();
    let src = format!("{decl}dir: usize) -> u64 {{\n    dir as u64\n}}\n");
    let (findings, _) = lint_source("x.rs", &src);
    assert_eq!(rules_of(&findings), ["tag-registry"]);
}

#[test]
fn tag_registry_allowed_in_tags_module_and_tests() {
    let shift = ["1u64 <", "< 63"].concat();
    let src = format!("pub const NS: u64 = {shift};\n");
    let (findings, _) = lint_source("rust/src/comm/tags.rs", &src);
    assert!(findings.is_empty(), "{findings:?}");
    let src2 = format!("#[cfg(test)]\nmod tests {{\n    const NS: u64 = {shift};\n}}\n");
    let (findings2, _) = lint_source("x.rs", &src2);
    assert!(findings2.is_empty(), "{findings2:?}");
}

// -------------------------------------------------------------------
// adhoc-json
// -------------------------------------------------------------------

#[test]
fn adhoc_json_violation_detected() {
    let key = ["{{\\", "\"k\\", "\": {}}}"].concat();
    let src = format!("fn f(v: u64) -> String {{\n    format!(\"{key}\", v)\n}}\n");
    let (findings, _) = lint_source("x.rs", &src);
    assert_eq!(rules_of(&findings), ["adhoc-json"]);
}

#[test]
fn adhoc_json_allowed_in_util_json_and_tests() {
    let key = ["{{\\", "\"k\\", "\": {}}}"].concat();
    let src = format!("fn f(v: u64) -> String {{\n    format!(\"{key}\", v)\n}}\n");
    let (findings, _) = lint_source("rust/src/util/json.rs", &src);
    assert!(findings.is_empty(), "{findings:?}");
    let src2 = format!("#[cfg(test)]\nmod tests {{\n    fn f(v: u64) -> String {{\n        format!(\"{key}\", v)\n    }}\n}}\n");
    let (findings2, _) = lint_source("x.rs", &src2);
    assert!(findings2.is_empty(), "{findings2:?}");
}

// -------------------------------------------------------------------
// suppression
// -------------------------------------------------------------------

#[test]
fn suppression_honored_and_counted() {
    let src = "fn f(p: *mut u8) {\n    // lint: allow(safety-comment)\n    let v = unsafe { *p };\n    drop(v);\n}\n";
    let (findings, suppressed) = lint_source("x.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(suppressed, 1);
}

#[test]
fn suppression_same_line_and_lists() {
    let src = "fn f(p: *mut u8) {\n    let v = unsafe { *p }; // lint: allow(raw-f64-accum, safety-comment)\n    drop(v);\n}\n";
    let (findings, suppressed) = lint_source("x.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(suppressed, 1);
}

#[test]
fn suppression_is_rule_specific() {
    // allowing a different rule does not silence safety-comment
    let src = "fn f(p: *mut u8) {\n    // lint: allow(adhoc-json)\n    let v = unsafe { *p };\n    drop(v);\n}\n";
    let (findings, suppressed) = lint_source("x.rs", src);
    assert_eq!(rules_of(&findings), ["safety-comment"]);
    assert_eq!(suppressed, 0);
}

// -------------------------------------------------------------------
// config-doc
// -------------------------------------------------------------------

#[test]
fn config_doc_missing_key_detected() {
    let run_rs = "fn load(doc: &Doc) {\n    let a = doc.float_or(\"solver.tol\", 1e-8);\n    let b = doc.int_or(\"solver.bogus_knob\", 3);\n    drop((a, b));\n}\n";
    let toml = "[solver]\ntol = 1e-8\n";
    let findings = check_config_doc("run.rs", run_rs, toml);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "config-doc");
    assert!(findings[0].msg.contains("solver.bogus_knob"), "{}", findings[0].msg);
}

#[test]
fn config_doc_commented_out_key_counts() {
    let run_rs = "fn load(doc: &Doc) {\n    let a = doc.bool_or(\"solver.use_x\", false);\n    drop(a);\n}\n";
    let toml = "[solver]\n# optional knob, disabled by default:\n#use_x = true\n";
    let findings = check_config_doc("run.rs", run_rs, toml);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn config_doc_key_extraction() {
    let run_rs = "fn load(doc: &Doc) {\n    let a = doc.get(\"telemetry.dir\");\n    let b = doc.str_or(\"solver.algorithm\", \"cg\");\n    drop((a, b));\n}\n";
    let keys: Vec<String> = parsed_config_keys(run_rs).into_iter().map(|(k, _)| k).collect();
    assert_eq!(keys, ["telemetry.dir", "solver.algorithm"]);
    let toml = "top = 1\n[solver]\nalgorithm = \"cg\"\n#[telemetry]\n#dir = \"t\"\n";
    let docd = documented_toml_keys(toml);
    assert!(docd.contains(&"top".to_string()), "{docd:?}");
    assert!(docd.contains(&"solver.algorithm".to_string()), "{docd:?}");
    assert!(docd.contains(&"telemetry.dir".to_string()), "{docd:?}");
}

// -------------------------------------------------------------------
// the real tree
// -------------------------------------------------------------------

/// The shipping tree lints clean: zero findings, zero suppressions.
/// (Cargo runs integration tests from the workspace root, where
/// `rust/src` and `configs/` live.)
#[test]
fn shipping_tree_is_clean() {
    let report = lint_tree(Path::new(".")).expect("tree scan");
    assert!(report.files_scanned > 50, "scanned {}", report.files_scanned);
    assert!(
        report.findings.is_empty(),
        "violations in tree:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert_eq!(report.suppressed, 0, "no suppressions allowed in shipping code");
}

// -------------------------------------------------------------------
// model checker: shipping protocols pass
// -------------------------------------------------------------------

fn opts() -> CheckOpts {
    CheckOpts::default()
}

#[test]
fn barrier_spin_passes_2_and_3_threads() {
    for &(n, iters) in &[(2usize, 3u64), (3, 2)] {
        let m = BarrierModel::new(n, iters, BarrierKind::Spin, None);
        let rep = check(&m, &opts());
        assert!(rep.passed(), "{n} threads: {:?}", rep.violation);
        assert!(rep.schedules > 0);
    }
}

#[test]
fn barrier_sleep_passes_2_and_3_threads() {
    for &(n, iters) in &[(2usize, 3u64), (3, 2)] {
        let m = BarrierModel::new(n, iters, BarrierKind::Sleep, None);
        let rep = check(&m, &opts());
        assert!(rep.passed(), "{n} threads: {:?}", rep.violation);
        assert!(rep.schedules > 0);
    }
}

// -------------------------------------------------------------------
// model checker: seeded mutants are caught
// -------------------------------------------------------------------

/// The classic lost wakeup (arrival registered before the generation is
/// sampled) MUST be caught — this pins the checker's power: if this
/// assertion ever fails, the checker can no longer see the bug class it
/// exists for.
#[test]
fn barrier_lost_wakeup_mutant_caught() {
    for kind in [BarrierKind::Spin, BarrierKind::Sleep] {
        for n in [2usize, 3] {
            let m = BarrierModel::new(n, 1, kind, Some(BarrierBug::LostWakeup));
            let rep = check(&m, &opts());
            let v = rep.violation.unwrap_or_else(|| {
                panic!("mutant not caught at n={n} kind={kind:?}")
            });
            assert!(v.message.contains("lost signal"), "{}", v.message);
            assert!(!v.schedule.is_empty());
        }
    }
}

#[test]
fn ring_shipping_passes() {
    // single writer within capacity, single writer overflowing (drop
    // accounting), and two writers with distinct loads
    for to_write in [vec![2usize], vec![4], vec![3, 2]] {
        let m = RingModel::new(RingVariant::Shipping, 2, &to_write);
        let rep = check(&m, &opts());
        assert!(rep.passed(), "{to_write:?}: {:?}", rep.violation);
    }
}

#[test]
fn ring_torn_publish_mutant_caught() {
    let m = RingModel::new(RingVariant::TornPublish, 2, &[2]);
    let rep = check(&m, &opts());
    let v = rep.violation.expect("torn publish not caught");
    assert!(v.message.contains("torn publish"), "{}", v.message);
}

#[test]
fn recv_state_machine_exactly_once() {
    for fault in [
        RecvFault::None,
        RecvFault::Drop(0),
        RecvFault::Drop(1),
        RecvFault::Drop(2),
        RecvFault::Duplicate(0),
        RecvFault::Duplicate(2),
    ] {
        let m = RecvModel::new(3, fault);
        let rep = check(&m, &opts());
        assert!(rep.passed(), "{fault:?}: {:?}", rep.violation);
        assert!(rep.schedules > 0, "{fault:?}");
    }
}

/// Dropping the preemption budget to zero still covers the
/// round-robin-free schedules; the mutant needs at least one preemption
/// to manifest, so budget 0 must MISS it — pinning that the budget knob
/// actually bounds the search.
#[test]
fn preemption_budget_bounds_the_search() {
    let m = BarrierModel::new(2, 1, BarrierKind::Spin, Some(BarrierBug::LostWakeup));
    let missed = check(&m, &CheckOpts { max_preemptions: 0 });
    assert!(missed.passed(), "budget 0 should not reach the racy schedule");
    let caught = check(&m, &CheckOpts { max_preemptions: 1 });
    assert!(caught.violation.is_some(), "budget 1 must reach it");
}

/// The standard `lqcd lint --model-check` suite: every shipping entry
/// passes, every mutant entry is caught.
#[test]
fn standard_suite_is_green() {
    let results = run_suite(&opts());
    assert!(results.len() >= 10);
    for r in &results {
        assert!(
            r.ok(),
            "{}: expect_violation={} got {:?}",
            r.name,
            r.expect_violation,
            r.report.violation
        );
    }
    // and the suite genuinely contains both polarities
    assert!(results.iter().any(|r| r.expect_violation));
    assert!(results.iter().any(|r| !r.expect_violation));
}
