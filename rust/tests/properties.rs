//! Property-based invariants of the operator algebra, run with the
//! in-repo mini property framework over randomized geometries, tilings,
//! gauge fields and sources.

use lqcd::algebra::Complex;
use lqcd::coordinator::operator::{LinearOperator, NativeMdagM, NativeMeo};
use lqcd::dslash::{full, HoppingEo};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, Tiling};
use lqcd::util::prop::{Gen, Runner};
use lqcd::util::rng::Rng;

/// Draw a random valid (dims, tiling) pair small enough for fast tests.
fn random_geometry(g: &mut Gen) -> Geometry {
    loop {
        let dims = LatticeDims::new(
            2 * g.usize_in(2, 4), // NX in {4,6,8}: XH >= 2
            2 * g.usize_in(1, 3),
            2 * g.usize_in(1, 2),
            2 * g.usize_in(1, 3),
        )
        .unwrap();
        let mut tilings = Vec::new();
        for vx in [2usize, 4] {
            for vy in [1usize, 2, 4] {
                if dims.xh() % vx == 0 && dims.y % vy == 0 {
                    tilings.push((vx, vy));
                }
            }
        }
        if tilings.is_empty() {
            continue;
        }
        let &(vx, vy) = g.choose(&tilings);
        return Geometry::single_rank(dims, Tiling::new(vx, vy).unwrap()).unwrap();
    }
}

#[test]
fn hopping_norm_bounded_by_8() {
    // ||H psi|| <= 8 ||psi||: H is a sum of 8 terms, each a product of a
    // projector (norm 2) and a unitary, but the projected subspaces
    // overlap, giving the factor 8 overall.
    Runner::new("hopping norm bound", 8).run(|g| {
        let geom = random_geometry(g);
        let mut rng = Rng::seeded(g.u64_below(1 << 48));
        let u: GaugeField = GaugeField::random(&geom, &mut rng);
        let psi: FermionField = FermionField::gaussian(&geom, &mut rng);
        let mut out = FermionField::zeros(&geom);
        HoppingEo::new(&geom).apply(&mut out, &u, &psi, Parity::Odd);
        let ratio = (out.norm2() / psi.norm2()).sqrt();
        assert!(ratio <= 8.0 + 1e-3, "||H|| ratio {ratio}");
    });
}

#[test]
fn meo_gamma5_hermiticity_random_geometries() {
    // <x, M y> == <g5 M g5 x, y> for random geometry/tiling/fields
    Runner::new("gamma5 hermiticity", 6).run(|g| {
        let geom = random_geometry(g);
        let mut rng = Rng::seeded(g.u64_below(1 << 48));
        let u = GaugeField::random(&geom, &mut rng);
        let x = FermionField::gaussian(&geom, &mut rng);
        let y = FermionField::gaussian(&geom, &mut rng);
        let kappa = g.f64_in(0.05, 0.14) as f32;
        let mut op = NativeMeo::new(&geom, u, kappa);

        let mut my = FermionField::zeros(&geom);
        op.apply(&mut my, &y);
        let lhs = x.dot(&my);

        let mut g5x = x.clone();
        g5x.gamma5();
        let mut mg5x = FermionField::zeros(&geom);
        op.apply(&mut mg5x, &g5x);
        mg5x.gamma5();
        let rhs = mg5x.dot(&y);

        let scale = (x.norm2() * y.norm2()).sqrt().max(1.0);
        assert!(
            (lhs - rhs).abs() / scale < 1e-5,
            "lhs {lhs:?} rhs {rhs:?}"
        );
    });
}

#[test]
fn mdagm_positive_definite() {
    Runner::new("MdagM > 0", 6).run(|g| {
        let geom = random_geometry(g);
        let mut rng = Rng::seeded(g.u64_below(1 << 48));
        let u = GaugeField::random(&geom, &mut rng);
        let x = FermionField::gaussian(&geom, &mut rng);
        let kappa = g.f64_in(0.05, 0.14) as f32;
        let mut op = NativeMdagM::new(&geom, u, kappa);
        let mut ax = FermionField::zeros(&geom);
        op.apply(&mut ax, &x);
        let q = x.dot(&ax);
        assert!(q.re > 0.0, "non-positive quadratic form {q:?}");
        assert!(q.im.abs() < 1e-4 * q.re, "non-real quadratic form {q:?}");
    });
}

#[test]
fn schur_solution_solves_full_system_random() {
    // Eqs. 4+5 against the full matrix, over random small systems
    Runner::new("schur solves D", 4).run(|g| {
        let geom = random_geometry(g);
        let mut rng = Rng::seeded(g.u64_below(1 << 48));
        let u = GaugeField::random(&geom, &mut rng);
        let b_e = FermionField::gaussian(&geom, &mut rng);
        let b_o = FermionField::gaussian(&geom, &mut rng);
        let kappa = g.f64_in(0.05, 0.13) as f32;
        let hop = HoppingEo::new(&geom);

        let mut rhs = FermionField::zeros(&geom);
        full::schur_rhs(&hop, &mut rhs, &u, &b_e, &b_o, kappa);
        let mut op = NativeMeo::new(&geom, u.clone(), kappa);
        let mut x_e = FermionField::zeros(&geom);
        let stats = lqcd::solver::bicgstab(&mut op, &mut x_e, &rhs, 1e-9, 600);
        assert!(stats.converged, "{stats:?}");
        let mut x_o = FermionField::zeros(&geom);
        full::reconstruct_odd(&hop, &mut x_o, &u, &b_o, &x_e, kappa);
        let rel = lqcd::solver::residual::full_system_residual(
            &hop, &u, &x_e, &x_o, &b_e, &b_o, kappa,
        );
        assert!(rel < 1e-5, "full-system residual {rel}");
    });
}

#[test]
fn hopping_with_unit_gauge_preserves_momentum_zero_mode() {
    // on U = 1, the constant spinor is an H eigenvector with eigenvalue 8
    Runner::new("free zero mode", 5).run(|g| {
        let geom = random_geometry(g);
        let u: GaugeField = GaugeField::unit(&geom);
        let mut psi: FermionField = FermionField::zeros(&geom);
        let mut rng = Rng::seeded(g.u64_below(1 << 48));
        // constant (site-independent) random spinor content
        let mut v = lqcd::algebra::Spinor::ZERO;
        for i in 0..4 {
            for c in 0..3 {
                v.s[i][c] = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        for s in psi.layout.sites().collect::<Vec<_>>() {
            psi.set_site(s, &v);
        }
        let mut out = FermionField::zeros(&geom);
        HoppingEo::new(&geom).apply(&mut out, &u, &psi, Parity::Even);
        let mut want = psi.clone();
        want.scale(8.0);
        want.axpy(-1.0, &out);
        assert!(
            want.norm2() / psi.norm2() < 1e-10,
            "constant mode not preserved"
        );
    });
}

#[test]
fn dslash_full_determinant_free_check() {
    // D_W at kappa=0 is the identity: D psi == psi
    Runner::new("kappa zero identity", 4).run(|g| {
        let geom = random_geometry(g);
        let mut rng = Rng::seeded(g.u64_below(1 << 48));
        let u: GaugeField = GaugeField::random(&geom, &mut rng);
        let psi_e: FermionField = FermionField::gaussian(&geom, &mut rng);
        let psi_o: FermionField = FermionField::gaussian(&geom, &mut rng);
        let hop = HoppingEo::new(&geom);
        let mut out_e = FermionField::zeros(&geom);
        let mut out_o = FermionField::zeros(&geom);
        full::dslash_full(&hop, &mut out_e, &mut out_o, &u, &psi_e, &psi_o, 0.0);
        out_e.axpy(-1.0, &psi_e);
        out_o.axpy(-1.0, &psi_o);
        assert!(out_e.norm2() + out_o.norm2() < 1e-10);
    });
}
