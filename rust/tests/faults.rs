//! Fault-matrix integration tests: injected transport faults against the
//! guarded distributed block solvers. Transport-level faults (drop,
//! delay, corrupt, duplicate, truncate, stall) must heal inside the
//! comm layer — the solver never notices, so residual histories stay
//! BITWISE identical to the fault-free run. Silent data corruption
//! (NaN payload with a recomputed checksum) must be caught by the
//! solver health guard and healed by a Krylov restart. A killed rank
//! must surface a structured [`SolveError`] on every rank within the
//! deadline budget — never a hang, never a panic.

use std::time::{Duration, Instant};

use lqcd::comm::decompose::{extract_fermion, extract_gauge};
use lqcd::comm::{run_world, run_world_cfg, FaultPlan, WorldOpts};
use lqcd::coordinator::operator::{DistMultiMdagM, DistMultiMeo};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::field::snapshot::gauge_hash;
use lqcd::field::{FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{Geometry, LatticeDims, ProcGrid, Tiling};
use lqcd::solver::{
    self, load_latest, BlockSolveStats, Checkpointer, CkptOpts, HealthConfig,
    SolveError, SolveErrorKind,
};
use lqcd::util::rng::Rng;

const TOL: f64 = 1e-4;
const MAXITER: usize = 40;
const KAPPA: f32 = 0.12;

fn world_opts(spec: &str, timeout_ms: u64, max_retries: u32) -> WorldOpts {
    WorldOpts {
        timeout_ms,
        max_retries,
        faults: FaultPlan::parse(spec).unwrap(),
    }
}

/// Deterministic problem setup shared by every case: gauge field and
/// `nrhs` Gaussian sources on an 8x4x4x8 lattice (divisible by both
/// test grids).
fn problem(nrhs: usize) -> (LatticeDims, Tiling, GaugeField, Vec<FermionField>) {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(91);
    let u: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let bs: Vec<FermionField> =
        (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    (global, tiling, u, bs)
}

/// One guarded distributed block-BiCGStab solve under `opts`; returns
/// each rank's `Result`.
fn solve_bicgstab(
    grid: ProcGrid,
    nrhs: usize,
    opts: WorldOpts,
    health: &HealthConfig,
) -> Vec<Result<BlockSolveStats, SolveError>> {
    let (global, tiling, u_global, bs_global) = problem(nrhs);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    run_world_cfg(grid.size(), opts, |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let bs: Vec<FermionField> = bs_global
            .iter()
            .map(|b| extract_fermion(b, &ggeom, &lgeom))
            .collect();
        let b = MultiFermionField::from_rhs(&bs);
        let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
        let mut team = Team::new(1, BarrierKind::Sleep);
        let prof = Profiler::new(1);
        let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
        let mut op =
            DistMultiMeo::new(&lgeom, &dist, &u, KAPPA, nrhs, comm, &prof).unwrap();
        solver::block_bicgstab_generic_guarded(
            &mut op, &mut team, &mut x, &b, TOL, MAXITER, health,
        )
    })
}

fn assert_all_ok(
    results: &[Result<BlockSolveStats, SolveError>],
    ctx: &str,
) -> Vec<BlockSolveStats> {
    results
        .iter()
        .enumerate()
        .map(|(rank, r)| match r {
            Ok(s) => s.clone(),
            Err(e) => panic!("{ctx}: rank {rank} failed: {e}"),
        })
        .collect()
}

/// No faults: the guarded distributed solver must be a zero-cost wrapper
/// — per-RHS residual histories bitwise identical to the unguarded
/// solver, with every recovery counter at zero.
#[test]
fn no_faults_guarded_bit_matches_unguarded() {
    let grid = ProcGrid([1, 1, 1, 2]);
    let nrhs = 2;
    let (global, tiling, u_global, bs_global) = problem(nrhs);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();

    let unguarded = run_world(grid.size(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let bs: Vec<FermionField> = bs_global
            .iter()
            .map(|b| extract_fermion(b, &ggeom, &lgeom))
            .collect();
        let b = MultiFermionField::from_rhs(&bs);
        let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
        let mut team = Team::new(1, BarrierKind::Sleep);
        let prof = Profiler::new(1);
        let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
        let mut op =
            DistMultiMeo::new(&lgeom, &dist, &u, KAPPA, nrhs, comm, &prof).unwrap();
        solver::block_bicgstab_generic(&mut op, &mut team, &mut x, &b, TOL, MAXITER)
    });

    let guarded = assert_all_ok(
        &solve_bicgstab(grid, nrhs, world_opts("", 30_000, 3), &HealthConfig::default()),
        "no faults",
    );
    for (rank, (g, u)) in guarded.iter().zip(&unguarded).enumerate() {
        assert_eq!(g.iterations, u.iterations, "rank {rank}");
        for r in 0..nrhs {
            assert!(!u.per_rhs[r].history.is_empty());
            assert_eq!(
                g.per_rhs[r].history, u.per_rhs[r].history,
                "rank {rank} rhs {r}: guarded history diverged without faults"
            );
            assert_eq!(g.per_rhs[r].converged, u.per_rhs[r].converged);
        }
        assert_eq!(g.restarts, 0, "rank {rank}");
        assert_eq!(g.health_events, 0, "rank {rank}");
        assert_eq!(g.retransmits, 0, "rank {rank}");
        assert_eq!(g.timeouts, 0, "rank {rank}");
    }
}

/// The transport-healed fault matrix: {drop, delay, corrupt, duplicate,
/// truncate, rank-stall} x {nrhs 1, 4} x {1x1x1x2, 1x1x2x2}. Every case
/// must converge with per-RHS histories BITWISE identical to the
/// fault-free run on the same world — recovery happens entirely below
/// the solver. Checksum-detected faults (and expired deadlines) must
/// show up in the recovery counters.
#[test]
fn transport_fault_matrix_heals_bitwise() {
    // (spec, expects retransmits > 0 somewhere in the world)
    let kinds: &[(&str, bool)] = &[
        ("drop:seed=7", true),
        ("delay:seed=8,ms=20", false),
        ("corrupt:seed=9", true),
        ("duplicate:seed=10", false),
        ("truncate:seed=11", true),
        ("stall:seed=12,ms=30,iter=2", false),
    ];
    for grid in [ProcGrid([1, 1, 1, 2]), ProcGrid([1, 1, 2, 2])] {
        for nrhs in [1usize, 4] {
            let baseline = assert_all_ok(
                &solve_bicgstab(
                    grid,
                    nrhs,
                    world_opts("", 300, 3),
                    &HealthConfig::default(),
                ),
                "baseline",
            );
            assert!(baseline[0].converged, "baseline must converge");
            for &(spec, wants_retransmit) in kinds {
                let ctx = format!("{spec} grid {grid:?} nrhs {nrhs}");
                let faulted = assert_all_ok(
                    &solve_bicgstab(
                        grid,
                        nrhs,
                        world_opts(spec, 300, 3),
                        &HealthConfig::default(),
                    ),
                    &ctx,
                );
                let mut retransmits = 0;
                for (rank, (f, b)) in faulted.iter().zip(&baseline).enumerate() {
                    assert_eq!(f.iterations, b.iterations, "{ctx} rank {rank}");
                    for r in 0..nrhs {
                        assert_eq!(
                            f.per_rhs[r].history, b.per_rhs[r].history,
                            "{ctx} rank {rank} rhs {r}: transport healing \
                             must not perturb the solve"
                        );
                    }
                    // the fault never reaches the solver layer
                    assert_eq!(f.restarts, 0, "{ctx} rank {rank}");
                    assert_eq!(f.health_events, 0, "{ctx} rank {rank}");
                    retransmits += f.retransmits;
                }
                if wants_retransmit {
                    assert!(retransmits > 0, "{ctx}: fault healed without the store?");
                }
            }
        }
    }
}

/// Silent data corruption passes every transport check (the checksum is
/// recomputed over the corrupted payload) — it must be the solver health
/// guard that catches the non-finite scalar and heals the solve with a
/// Krylov restart.
#[test]
fn sdc_heals_via_health_guard_restart() {
    // nth=20 lands the corruption inside solver iterations (past the
    // wire-format handshake traffic)
    let results = solve_bicgstab(
        ProcGrid([1, 1, 1, 2]),
        2,
        world_opts("sdc:nth=20", 300, 3),
        &HealthConfig::default(),
    );
    let stats = assert_all_ok(&results, "sdc");
    for (rank, s) in stats.iter().enumerate() {
        assert!(s.converged, "rank {rank}: sdc run must still converge");
        assert!(s.restarts >= 1, "rank {rank}: guard never restarted");
        assert!(s.health_events >= 1, "rank {rank}");
        // transport saw nothing wrong
        assert_eq!(s.retransmits, 0, "rank {rank}");
    }
    // restart decisions come from global reductions: identical everywhere
    for s in &stats[1..] {
        assert_eq!(s.restarts, stats[0].restarts);
        assert_eq!(s.iterations, stats[0].iterations);
    }
}

/// Persistent corruption exhausts the restart budget: the guard gives up
/// with a structured, diagnosable error instead of looping forever.
#[test]
fn persistent_sdc_exhausts_restart_budget() {
    let health = HealthConfig { max_restarts: 2, ..Default::default() };
    let results = solve_bicgstab(
        ProcGrid([1, 1, 1, 2]),
        2,
        world_opts("sdc:nth=20,count=100000", 300, 3),
        &health,
    );
    for (rank, r) in results.iter().enumerate() {
        let e = r.as_ref().expect_err("persistent sdc must fail");
        assert!(
            matches!(e.kind, SolveErrorKind::RestartsExhausted),
            "rank {rank}: {e}"
        );
        // budget + the final fatal event
        assert_eq!(e.events.len(), health.max_restarts + 1, "rank {rank}");
        let mask = e.converged_mask.as_ref().expect("block solves carry a mask");
        assert_eq!(mask.len(), 2, "rank {rank}");
    }
}

/// A killed rank is unrecoverable: the victim reports the kill, its
/// peers run into recv deadlines, and every rank returns a structured
/// [`SolveError`] within the deadline budget — bounded wall time, no
/// hang, no panic.
#[test]
fn kill_surfaces_structured_error_on_every_rank() {
    let sw = Instant::now();
    let results = solve_bicgstab(
        ProcGrid([1, 1, 1, 2]),
        2,
        world_opts("kill:rank=1,iter=2", 200, 1),
        &HealthConfig::default(),
    );
    let elapsed = sw.elapsed();
    assert!(
        elapsed < Duration::from_secs(60),
        "kill recovery exceeded the deadline budget ({elapsed:?})"
    );
    for (rank, r) in results.iter().enumerate() {
        let e = r.as_ref().expect_err("a killed world cannot converge");
        assert!(
            matches!(e.kind, SolveErrorKind::Comm(_)),
            "rank {rank}: expected a comm fault, got {e}"
        );
        let mask = e.converged_mask.as_ref().expect("block solves carry a mask");
        assert_eq!(mask.len(), 2, "rank {rank}");
    }
    // the victim's own diagnostic names the injected kill
    let victim = results[1].as_ref().unwrap_err();
    assert!(
        victim.to_string().contains("killed"),
        "victim diagnostic: {victim}"
    );
}

/// The fault-cursor checkpoint contract at the [`FaultPlan`] level: a
/// state whose cursors were saved mid-schedule and restored into a
/// fresh state fires exactly the REMAINING triggers, at the same
/// (rule, tag, matching-send) points as the uninterrupted schedule.
#[test]
fn fault_cursor_restore_replays_remaining_schedule() {
    let plan =
        FaultPlan::parse("drop:nth=3,count=4;corrupt:tag=9,nth=2,count=2").unwrap();
    // a deterministic send sequence: tags alternating 3 / 9 from rank 0
    let sends: Vec<(usize, u64)> =
        (0..12).map(|i| (0usize, if i % 2 == 0 { 3 } else { 9 })).collect();

    let mut full = plan.new_state();
    for (seq, &(from, tag)) in sends.iter().enumerate() {
        plan.message_action(&mut full, from, tag, seq as u64);
    }
    assert!(!full.fired().is_empty(), "plan never fired");

    // interrupt after 5 sends; checkpoint the cursors
    let mut part = plan.new_state();
    for (seq, &(from, tag)) in sends[..5].iter().enumerate() {
        plan.message_action(&mut part, from, tag, seq as u64);
    }
    let cursors = part.cursors();

    // restart: a fresh state with restored cursors continues mid-plan
    let mut resumed = plan.new_state();
    resumed.restore_cursors(&cursors);
    for (seq, &(from, tag)) in sends[5..].iter().enumerate() {
        plan.message_action(&mut resumed, from, tag, (5 + seq) as u64);
    }
    let mut replay = part.fired().to_vec();
    replay.extend_from_slice(resumed.fired());
    assert_eq!(
        replay,
        full.fired(),
        "resumed schedule diverged from the uninterrupted one"
    );

    // negative control: without the restore, the early triggers replay
    // at the wrong sequence points
    let mut cold = plan.new_state();
    for (seq, &(from, tag)) in sends[5..].iter().enumerate() {
        plan.message_action(&mut cold, from, tag, (5 + seq) as u64);
    }
    assert_ne!(
        cold.fired(),
        resumed.fired(),
        "a cold state must not reproduce the mid-plan continuation"
    );
}

/// End-to-end replay: a distributed solve under a seeded drop schedule,
/// interrupted after a checkpoint and resumed in a NEW world with the
/// same plan, restores the fault cursors with the rest of the solver
/// state — the surviving triggers land at the same points and the
/// final per-RHS histories stay bitwise identical to the uninterrupted
/// faulted run.
#[test]
fn fault_plan_replays_across_checkpoint_restart() {
    let dir = std::env::temp_dir()
        .join(format!("lqcd-faults-replay-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let grid = ProcGrid([1, 1, 1, 2]);
    let nrhs = 2;
    let spec = "drop:seed=7,count=6";
    let (global, tiling, u_global, bs_global) = problem(nrhs);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let run = |maxiter: usize, ckpt_on: bool, resume: bool| {
        run_world_cfg(grid.size(), world_opts(spec, 300, 3), |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let u = extract_gauge(&u_global, &lgeom);
            let ghash = gauge_hash(&u);
            let bs: Vec<FermionField> = bs_global
                .iter()
                .map(|b| extract_fermion(b, &ggeom, &lgeom))
                .collect();
            let b = MultiFermionField::from_rhs(&bs);
            let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
            let mut team = Team::new(1, BarrierKind::Sleep);
            let prof = Profiler::new(1);
            let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            let mut op =
                DistMultiMeo::new(&lgeom, &dist, &u, KAPPA, nrhs, comm, &prof).unwrap();
            let mut ckpt = ckpt_on.then(|| {
                Checkpointer::new(
                    CkptOpts {
                        dir: dir.clone(),
                        every_iters: 4,
                        every_ms: 0,
                        keep: 4,
                        buddy: false,
                    },
                    rank,
                    2,
                    ghash,
                )
                .unwrap()
            });
            let st = resume
                .then(|| load_latest(&dir, rank, 2, ghash).expect("resume state").0);
            solver::block_bicgstab_generic_guarded_ckpt(
                &mut op,
                &mut team,
                &mut x,
                &b,
                TOL,
                maxiter,
                &HealthConfig::default(),
                None,
                ckpt.as_mut(),
                st.as_ref(),
            )
        })
    };

    let full = assert_all_ok(&run(MAXITER, false, false), "faulted reference");
    assert!(full[0].converged, "reference must converge despite drops");

    let part = assert_all_ok(&run(6, true, false), "interrupted");
    assert!(!part[0].converged, "cap of 6 iterations must interrupt");

    let resumed = assert_all_ok(&run(MAXITER, false, true), "resumed");
    for (rank, (r, f)) in resumed.iter().zip(&full).enumerate() {
        assert!(r.converged, "rank {rank}");
        assert_eq!(r.iterations, f.iterations, "rank {rank}");
        for i in 0..nrhs {
            assert_eq!(
                r.per_rhs[i].history, f.per_rhs[i].history,
                "rank {rank} rhs {i}: resumed faulted solve diverged from \
                 the uninterrupted faulted run"
            );
        }
    }
}

/// The CG (normal-equations) distributed path is guarded too: clean runs
/// are bitwise the unguarded solver's, and an injected sdc heals via
/// restart.
#[test]
fn cg_path_guarded_and_heals_sdc() {
    let grid = ProcGrid([1, 1, 1, 2]);
    let nrhs = 2;
    let (global, tiling, u_global, bs_global) = problem(nrhs);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let run = |opts: WorldOpts, guarded: bool| {
        run_world_cfg(grid.size(), opts, |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let u = extract_gauge(&u_global, &lgeom);
            let bs: Vec<FermionField> = bs_global
                .iter()
                .map(|b| extract_fermion(b, &ggeom, &lgeom))
                .collect();
            let b = MultiFermionField::from_rhs(&bs);
            let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
            let mut team = Team::new(1, BarrierKind::Sleep);
            let prof = Profiler::new(1);
            let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            let mut op =
                DistMultiMdagM::new(&lgeom, &dist, &u, KAPPA, nrhs, comm, &prof)
                    .unwrap();
            if guarded {
                solver::block_cg_generic_guarded(
                    &mut op,
                    &mut team,
                    &mut x,
                    &b,
                    TOL,
                    MAXITER,
                    &HealthConfig::default(),
                )
            } else {
                Ok(solver::block_cg_generic(&mut op, &mut team, &mut x, &b, TOL, MAXITER))
            }
        })
    };
    let unguarded = assert_all_ok(&run(world_opts("", 300, 3), false), "cg unguarded");
    let clean = assert_all_ok(&run(world_opts("", 300, 3), true), "cg clean");
    for (rank, (g, u)) in clean.iter().zip(&unguarded).enumerate() {
        for r in 0..nrhs {
            assert!(!u.per_rhs[r].history.is_empty());
            assert_eq!(
                g.per_rhs[r].history, u.per_rhs[r].history,
                "rank {rank} rhs {r}: guarded CG history diverged"
            );
        }
        assert_eq!(g.restarts, 0);
    }
    let sdc = assert_all_ok(&run(world_opts("sdc:nth=20", 300, 3), true), "cg sdc");
    for (rank, s) in sdc.iter().enumerate() {
        assert!(s.converged, "rank {rank}: CG sdc run must converge");
        assert!(s.restarts >= 1, "rank {rank}: CG guard never restarted");
    }
}
