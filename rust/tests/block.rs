//! Multi-RHS block subsystem correctness.
//!
//! The contract under test: the block field's mux/demux are exact; the
//! batched multi-RHS dslash bit-matches the single-RHS kernel per
//! demuxed RHS (f64 exactly, f32 to rounding — in practice bitwise,
//! since the per-RHS arithmetic is the same code); and the block
//! solvers reproduce N independent fused solves per RHS — bitwise
//! residual histories at f64, including *through* per-RHS mask
//! activation, because the batched recurrences are independent.

use lqcd::algebra::Real;
use lqcd::coordinator::operator::{
    LinearOperator, MultiMdagM, MultiNativeMeo, MultiOperator, NativeMdagM, NativeMeo,
};
use lqcd::coordinator::{BarrierKind, Team};
use lqcd::field::{FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{Geometry, LatticeDims, Tiling};
use lqcd::solver;
use lqcd::util::rng::Rng;

fn geom() -> Geometry {
    Geometry::single_rank(
        LatticeDims::new(4, 4, 4, 4).unwrap(),
        Tiling::new(2, 2).unwrap(),
    )
    .unwrap()
}

fn max_abs_diff<R: Real>(a: &FermionField<R>, b: &FermionField<R>) -> f64 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| (x.to_f64() - y.to_f64()).abs())
        .fold(0.0, f64::max)
}

/// CGNR right-hand side Mdag b for one source.
fn cgnr_rhs<R: Real>(geom: &Geometry, u: &GaugeField<R>, kappa: R, b: &FermionField<R>) -> FermionField<R> {
    let mut op = NativeMeo::new(geom, u.clone(), kappa);
    let mut bp = b.clone();
    bp.gamma5();
    let mut mbp = FermionField::zeros(geom);
    op.apply(&mut mbp, &bp);
    mbp.gamma5();
    mbp
}

#[test]
fn mux_demux_roundtrip_across_tilings() {
    for tiling in [Tiling::new(2, 2).unwrap(), Tiling::new(4, 2).unwrap()] {
        let g = Geometry::single_rank(LatticeDims::new(8, 4, 4, 4).unwrap(), tiling).unwrap();
        let mut rng = Rng::seeded(71);
        let fields: Vec<FermionField<f32>> =
            (0..5).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let m = MultiFermionField::from_rhs(&fields);
        for (r, f) in fields.iter().enumerate() {
            assert_eq!(m.extract_rhs(r).data, f.data, "tiling {tiling}, rhs {r}");
        }
        // overwrite one slot, the others must be untouched
        let mut m2 = m.clone();
        m2.set_rhs(2, &fields[0]);
        assert_eq!(m2.extract_rhs(2).data, fields[0].data);
        for r in [0usize, 1, 3, 4] {
            assert_eq!(m2.extract_rhs(r).data, fields[r].data);
        }
    }
}

#[test]
fn multi_apply_bit_matches_single_per_rhs_f64() {
    let g = geom();
    let mut rng = Rng::seeded(72);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let kappa = 0.137f64;
    let nrhs = 3;
    let srcs: Vec<FermionField<f64>> =
        (0..nrhs).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
    let psi = MultiFermionField::from_rhs(&srcs);
    let active = vec![true; nrhs];

    for threads in [1usize, 3] {
        let mut team = Team::new(threads, BarrierKind::Sleep);
        // M-hat
        let mut mop = MultiNativeMeo::new(&g, u.clone(), kappa, nrhs);
        let mut out = psi.zeros_like();
        mop.apply_multi(&mut team, &mut out, &psi, &active, None);
        let mut sop = NativeMeo::new(&g, u.clone(), kappa);
        for (r, s) in srcs.iter().enumerate() {
            let mut want = FermionField::zeros(&g);
            sop.apply(&mut want, s);
            assert_eq!(
                out.extract_rhs(r).data,
                want.data,
                "multi M-hat rhs {r} must bit-match single at f64 ({threads} threads)"
            );
        }
        // normal operator
        let mut mop = MultiMdagM::new(&g, u.clone(), kappa, nrhs);
        let mut out = psi.zeros_like();
        mop.apply_multi(&mut team, &mut out, &psi, &active, None);
        let mut sop = NativeMdagM::new(&g, u.clone(), kappa);
        for (r, s) in srcs.iter().enumerate() {
            let mut want = FermionField::zeros(&g);
            sop.apply(&mut want, s);
            assert_eq!(
                out.extract_rhs(r).data,
                want.data,
                "multi MdagM rhs {r} must bit-match single at f64 ({threads} threads)"
            );
        }
    }
}

#[test]
fn multi_apply_matches_single_per_rhs_f32() {
    let g = geom();
    let mut rng = Rng::seeded(73);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let kappa = 0.137f32;
    let nrhs = 2;
    let srcs: Vec<FermionField<f32>> =
        (0..nrhs).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
    let psi = MultiFermionField::from_rhs(&srcs);
    let mut team = Team::new(2, BarrierKind::Sleep);
    let mut mop = MultiNativeMeo::new(&g, u.clone(), kappa, nrhs);
    let mut out = psi.zeros_like();
    mop.apply_multi(&mut team, &mut out, &psi, &[true, true], None);
    let mut sop = NativeMeo::new(&g, u.clone(), kappa);
    for (r, s) in srcs.iter().enumerate() {
        let mut want = FermionField::zeros(&g);
        sop.apply(&mut want, s);
        assert!(
            max_abs_diff(&out.extract_rhs(r), &want) <= f32::EPSILON as f64,
            "multi M-hat rhs {r} must match single to rounding at f32"
        );
    }
}

#[test]
fn multi_apply_mask_skips_inactive_rhs() {
    let g = geom();
    let mut rng = Rng::seeded(74);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let nrhs = 3;
    let srcs: Vec<FermionField<f32>> =
        (0..nrhs).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
    let psi = MultiFermionField::from_rhs(&srcs);
    let mut mop = MultiNativeMeo::new(&g, u.clone(), 0.13f32, nrhs);
    let mut team = Team::new(1, BarrierKind::Sleep);
    // pre-fill the output with a sentinel; masked sub-tiles must keep it
    let mut out = psi.zeros_like();
    out.fill_rhs(1, 42.0);
    mop.apply_multi(&mut team, &mut out, &psi, &[true, false, true], None);
    assert!(
        out.extract_rhs(1).data.iter().all(|&v| v == 42.0),
        "masked rhs must not be written by the kernel"
    );
    let mut sop = NativeMeo::new(&g, u, 0.13f32);
    for r in [0usize, 2] {
        let mut want = FermionField::zeros(&g);
        sop.apply(&mut want, &srcs[r]);
        assert_eq!(out.extract_rhs(r).data, want.data, "active rhs {r}");
    }
}

#[test]
fn block_cg_matches_independent_fused_solves_f64() {
    let g = geom();
    let mut rng = Rng::seeded(75);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let kappa = 0.12f64;
    let nrhs = 4;
    let tol = 1e-10;
    let maxiter = 400;
    let rhs: Vec<FermionField<f64>> = (0..nrhs)
        .map(|_| cgnr_rhs(&g, &u, kappa, &FermionField::gaussian(&g, &mut rng)))
        .collect();

    // RHS 0 gets a warm start (a presolved solution), so it converges
    // in a couple of iterations and its mask activates while the
    // cold-started stragglers keep iterating — exercising the masked
    // path deterministically.
    let mut team = Team::new(2, BarrierKind::Sleep);
    let warm0 = {
        let mut op = NativeMdagM::new(&g, u.clone(), kappa);
        let mut x = FermionField::<f64>::zeros(&g);
        let s = solver::fused::cg(&mut op, &mut team, &mut x, &rhs[0], tol, maxiter);
        assert!(s.converged);
        x
    };

    // independent fused solves (the reference trajectories)
    let mut xs = Vec::new();
    let mut hist = Vec::new();
    for (r, b) in rhs.iter().enumerate() {
        let mut op = NativeMdagM::new(&g, u.clone(), kappa);
        let mut x = if r == 0 { warm0.clone() } else { FermionField::<f64>::zeros(&g) };
        let s = solver::fused::cg(&mut op, &mut team, &mut x, b, tol, maxiter);
        assert!(s.converged, "independent solve did not converge");
        xs.push(x);
        hist.push(s.history);
    }
    let iters: Vec<usize> = hist.iter().map(|h| h.len()).collect();
    assert!(
        iters.iter().any(|&i| i != iters[0]),
        "want staggered convergence to exercise the masks (got {iters:?})"
    );

    // one block solve of all four, same warm start on RHS 0
    let b_block = MultiFermionField::from_rhs(&rhs);
    let mut op = MultiMdagM::new(&g, u.clone(), kappa, nrhs);
    let mut x_block = MultiFermionField::<f64>::zeros(&g, nrhs);
    x_block.set_rhs(0, &warm0);
    let stats = solver::block_cg(&mut op, &mut team, &mut x_block, &b_block, tol, maxiter);
    assert!(stats.converged, "block solve did not converge: {stats:?}");
    assert_eq!(stats.nrhs, nrhs);
    assert_eq!(stats.threads, 2);
    for r in 0..nrhs {
        assert_eq!(
            stats.per_rhs[r].history, hist[r],
            "rhs {r}: block history must be bitwise identical to the independent solve"
        );
        assert_eq!(stats.per_rhs[r].iterations, iters[r]);
        assert_eq!(
            x_block.extract_rhs(r).data,
            xs[r].data,
            "rhs {r}: block solution must be bitwise identical at f64"
        );
    }
    // batched iteration count is the straggler's
    assert_eq!(stats.iterations, *iters.iter().max().unwrap());
}

#[test]
fn block_cg_matches_independent_fused_solves_f32() {
    let g = geom();
    let mut rng = Rng::seeded(76);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let kappa = 0.12f32;
    let nrhs = 2;
    let tol = 1e-5;
    let rhs: Vec<FermionField<f32>> = (0..nrhs)
        .map(|_| cgnr_rhs(&g, &u, kappa, &FermionField::gaussian(&g, &mut rng)))
        .collect();
    let mut team = Team::new(1, BarrierKind::Sleep);
    let mut hist = Vec::new();
    for b in &rhs {
        let mut op = NativeMdagM::new(&g, u.clone(), kappa);
        let mut x = FermionField::<f32>::zeros(&g);
        let s = solver::fused::cg(&mut op, &mut team, &mut x, b, tol, 400);
        assert!(s.converged);
        hist.push(s.history);
    }
    let b_block = MultiFermionField::from_rhs(&rhs);
    let mut op = MultiMdagM::new(&g, u.clone(), kappa, nrhs);
    let mut x_block = MultiFermionField::<f32>::zeros(&g, nrhs);
    let stats = solver::block_cg(&mut op, &mut team, &mut x_block, &b_block, tol, 400);
    assert!(stats.converged);
    // same arithmetic per RHS: identical trajectories at f32 too
    for r in 0..nrhs {
        assert_eq!(stats.per_rhs[r].history, hist[r], "rhs {r} (f32)");
    }
}

#[test]
fn block_bicgstab_matches_independent_fused_solves_f64() {
    let g = geom();
    let mut rng = Rng::seeded(77);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let kappa = 0.12f64;
    let nrhs = 3;
    let tol = 1e-10;
    let maxiter = 300;
    let rhs: Vec<FermionField<f64>> =
        (0..nrhs).map(|_| FermionField::gaussian(&g, &mut rng)).collect();

    let mut team = Team::new(2, BarrierKind::Sleep);
    let mut hist = Vec::new();
    let mut xs = Vec::new();
    for b in &rhs {
        let mut op = NativeMeo::new(&g, u.clone(), kappa);
        let mut x = FermionField::<f64>::zeros(&g);
        let s = solver::fused::bicgstab(&mut op, &mut team, &mut x, b, tol, maxiter);
        assert!(s.converged, "independent bicgstab did not converge");
        hist.push(s.history);
        xs.push(x);
    }

    let b_block = MultiFermionField::from_rhs(&rhs);
    let mut op = MultiNativeMeo::new(&g, u.clone(), kappa, nrhs);
    let mut x_block = MultiFermionField::<f64>::zeros(&g, nrhs);
    let stats =
        solver::block_bicgstab(&mut op, &mut team, &mut x_block, &b_block, tol, maxiter);
    assert!(stats.converged, "block bicgstab did not converge: {stats:?}");
    for r in 0..nrhs {
        assert_eq!(
            stats.per_rhs[r].history, hist[r],
            "rhs {r}: block bicgstab history must match the independent solve"
        );
        assert_eq!(
            x_block.extract_rhs(r).data,
            xs[r].data,
            "rhs {r}: block bicgstab solution must be bitwise identical at f64"
        );
    }
}

#[test]
fn block_cg_zero_rhs_slot_converges_immediately_and_stays_zero() {
    let g = geom();
    let mut rng = Rng::seeded(78);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let kappa = 0.12f32;
    let b0 = cgnr_rhs(&g, &u, kappa, &FermionField::gaussian(&g, &mut rng));
    let zero = FermionField::<f32>::zeros(&g);
    let b_block = MultiFermionField::from_rhs(&[b0.clone(), zero]);
    let mut op = MultiMdagM::new(&g, u.clone(), kappa, 2);
    let mut team = Team::new(1, BarrierKind::Sleep);
    let mut x = MultiFermionField::<f32>::zeros(&g, 2);
    // seed the zero-RHS slot with garbage: the solver must zero it
    x.fill_rhs(1, 3.0);
    let stats = solver::block_cg(&mut op, &mut team, &mut x, &b_block, 1e-5, 400);
    assert!(stats.converged);
    assert_eq!(stats.per_rhs[1].iterations, 0);
    assert!(stats.per_rhs[1].converged);
    assert_eq!(x.extract_rhs(1).norm2(), 0.0, "zero rhs must give zero solution");
    // and the live system still matches its independent solve
    let mut sop = NativeMdagM::new(&g, u, kappa);
    let mut x_ind = FermionField::<f32>::zeros(&g);
    let s_ind = solver::fused::cg(&mut sop, &mut team, &mut x_ind, &b0, 1e-5, 400);
    assert_eq!(stats.per_rhs[0].history, s_ind.history);
}

#[test]
fn block_stats_flops_scale_with_active_rhs_not_nrhs() {
    // Two solves of the same single system: alone, and padded with a
    // zero RHS that is masked from iteration 0. The padded solve must
    // charge (almost) the same flops — the mask keeps dead RHS free —
    // while a naive nrhs-scaled accounting would double it.
    let g = geom();
    let mut rng = Rng::seeded(79);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let kappa = 0.12f32;
    let b0 = cgnr_rhs(&g, &u, kappa, &FermionField::gaussian(&g, &mut rng));
    let mut team = Team::new(1, BarrierKind::Sleep);

    let one = MultiFermionField::from_rhs(&[b0.clone()]);
    let mut op1 = MultiMdagM::new(&g, u.clone(), kappa, 1);
    let mut x1 = MultiFermionField::<f32>::zeros(&g, 1);
    let s1 = solver::block_cg(&mut op1, &mut team, &mut x1, &one, 1e-5, 400);

    let padded = MultiFermionField::from_rhs(&[b0, FermionField::zeros(&g)]);
    let mut op2 = MultiMdagM::new(&g, u, kappa, 2);
    let mut x2 = MultiFermionField::<f32>::zeros(&g, 2);
    let s2 = solver::block_cg(&mut op2, &mut team, &mut x2, &padded, 1e-5, 400);

    assert_eq!(s1.per_rhs[0].history, s2.per_rhs[0].history);
    // the padded run pays one extra |b|² reduction for the dead slot;
    // everything iteration-scale must be identical
    assert!(
        s2.flops < s1.flops + s1.flops / 100,
        "masked RHS must not be charged: {} vs {}",
        s2.flops,
        s1.flops
    );
}
