//! Two-row compressed gauge links: correctness across every hot path.
//!
//! The contract under test (see `field::compressed`):
//!
//! * compression round-trips exactly (stored rows are copies) and the
//!   rebuilt third row is within ~1e-13 of the stored one at f64 for
//!   exact SU(3) input;
//! * the compressed kernel is **bitwise identical** (f32 and f64) to
//!   the uncompressed kernel on the *projected* field
//!   `compress(u).reconstruct()`, because every reconstruction path
//!   shares one canonical elementwise expression — single-RHS,
//!   multi-RHS, and the distributed EO1/bulk/EO2 pipeline alike;
//! * against the *original* field the difference is bounded by the
//!   cross-product rounding (tiny at f64, a few ulp at f32);
//! * solver trajectories through two-row operators match the full-link
//!   trajectories on the projected field bitwise, so `--gauge-compression
//!   two-row` changes memory traffic, never convergence behavior.

use lqcd::comm::decompose::{extract_fermion, extract_gauge};
use lqcd::comm::run_world;
use lqcd::coordinator::operator::{
    DistMeo, LinearOperator, MultiMdagM, NativeMdagM, NativeMeo,
};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::dslash::{Compression, HoppingEo, Links};
use lqcd::field::{CompressedGaugeField, FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, ProcGrid, Tiling};
use lqcd::solver;
use lqcd::util::rng::Rng;

fn geom() -> Geometry {
    Geometry::single_rank(
        LatticeDims::new(4, 4, 4, 4).unwrap(),
        Tiling::new(2, 2).unwrap(),
    )
    .unwrap()
}

fn max_abs_diff<R: lqcd::algebra::Real>(a: &[R], b: &[R]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x.to_f64() - y.to_f64()).abs())
        .fold(0.0, f64::max)
}

#[test]
fn round_trip_exact_and_third_row_tight_f64() {
    for (dims, tiling) in [
        (LatticeDims::new(4, 4, 4, 4).unwrap(), Tiling::new(2, 2).unwrap()),
        (LatticeDims::new(8, 4, 2, 2).unwrap(), Tiling::new(4, 2).unwrap()),
    ] {
        let g = Geometry::single_rank(dims, tiling).unwrap();
        let mut rng = Rng::seeded(201);
        let u = GaugeField::<f64>::random(&g, &mut rng);
        let c = CompressedGaugeField::compress(&u);
        let back = c.reconstruct();
        // stored rows: exact round trip
        let c2 = CompressedGaugeField::compress(&back);
        for d in 0..4 {
            for p in 0..2 {
                assert_eq!(c.data[d][p], c2.data[d][p], "rows must round-trip bitwise");
            }
        }
        // third row: rebuilt to ~machine precision of the stored row
        let mut worst = 0.0f64;
        for d in 0..4 {
            for p in 0..2 {
                worst = worst.max(max_abs_diff(&u.data[d][p], &back.data[d][p]));
            }
        }
        assert!(worst < 1e-13, "third-row rebuild off by {worst} ({dims})");
    }
}

/// The compressed kernel vs the uncompressed kernel, both hopping
/// parities, on the projected field (bitwise) and the original (close).
fn check_kernel<R: lqcd::algebra::Real>(seed: u64, tol_orig: f64) {
    let g = geom();
    let mut rng = Rng::seeded(seed);
    let u = GaugeField::<R>::random(&g, &mut rng);
    let c = CompressedGaugeField::compress(&u);
    let proj = c.reconstruct();
    let links = Links::TwoRow(c);
    let psi: FermionField<R> = FermionField::gaussian(&g, &mut rng);
    let hop = HoppingEo::new(&g);
    for p_out in Parity::BOTH {
        let mut want_proj = FermionField::<R>::zeros(&g);
        hop.apply(&mut want_proj, &proj, &psi, p_out);
        let mut got = FermionField::<R>::zeros(&g);
        hop.apply(&mut got, &links, &psi, p_out);
        assert_eq!(
            got.data, want_proj.data,
            "two-row kernel must bit-match full links on the projected field ({p_out:?})"
        );
        let mut want_orig = FermionField::<R>::zeros(&g);
        hop.apply(&mut want_orig, &u, &psi, p_out);
        let d = max_abs_diff(&got.data, &want_orig.data);
        assert!(
            d <= tol_orig,
            "two-row kernel vs original field off by {d} ({p_out:?})"
        );
    }
}

#[test]
fn kernel_two_row_bit_matches_projected_f64() {
    // f64: bitwise on the projected field, ~1e-13 on the original
    check_kernel::<f64>(202, 1e-12);
}

#[test]
fn kernel_two_row_bit_matches_projected_f32() {
    // f32: still bitwise on the projected field (same arithmetic in R);
    // a few ulp against the original
    check_kernel::<f32>(203, 1e-4);
}

#[test]
fn single_rhs_solve_history_identical_to_projected_full_links() {
    for threads in [1usize, 2] {
        let g = geom();
        let mut rng = Rng::seeded(204);
        let u = GaugeField::<f64>::random(&g, &mut rng);
        let proj = CompressedGaugeField::compress(&u).reconstruct();
        let b: FermionField<f64> = FermionField::gaussian(&g, &mut rng);
        let kappa = 0.13f64;
        let (tol, maxiter) = (1e-10, 300);

        let mut team = Team::new(threads, BarrierKind::Sleep);
        let full_hist = {
            let mut op = NativeMeo::new(&g, proj.clone(), kappa);
            let mut x = FermionField::<f64>::zeros(&g);
            let s = solver::fused::bicgstab(&mut op, &mut team, &mut x, &b, tol, maxiter);
            assert!(s.converged);
            s.history
        };
        let two_hist = {
            let links = Links::from_gauge(u.clone(), Compression::TwoRow);
            let mut op = NativeMeo::with_links(&g, links, kappa);
            let mut x = FermionField::<f64>::zeros(&g);
            let s = solver::fused::bicgstab(&mut op, &mut team, &mut x, &b, tol, maxiter);
            assert!(s.converged);
            s.history
        };
        assert_eq!(
            full_hist, two_hist,
            "two-row solve history must bit-match full links on the projected field ({threads} threads)"
        );
    }
}

#[test]
fn two_row_operator_charges_reconstruction_flops() {
    let g = geom();
    let mut rng = Rng::seeded(205);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let full = NativeMeo::new(&g, u.clone(), 0.13f32);
    let two = NativeMeo::with_links(&g, Links::from_gauge(u, Compression::TwoRow), 0.13f32);
    assert!(
        two.flops_per_apply() > full.flops_per_apply(),
        "in-kernel reconstruction must be charged"
    );
    let extra = two.flops_per_apply() - full.flops_per_apply();
    // 2 hopping blocks x 8 links/site x 45 flop over the half lattice
    assert_eq!(extra, 2 * 8 * 45 * g.local.half_volume() as u64);

    // multi-RHS: the rebuild is shared across RHS (once per site tile),
    // so it must be charged per APPLY, never per RHS
    use lqcd::coordinator::operator::{MultiNativeMeo, MultiOperator};
    let u2 = two.links().clone();
    let mfull = MultiNativeMeo::new(&g, full.links().to_gauge(), 0.13f32, 4);
    let mtwo = MultiNativeMeo::with_links(&g, u2, 0.13f32, 4);
    assert_eq!(
        mtwo.flops_per_apply_rhs(),
        mfull.flops_per_apply_rhs(),
        "per-RHS arithmetic is independent of link storage"
    );
    assert_eq!(mfull.flops_per_apply_shared(), 0);
    assert_eq!(mtwo.flops_per_apply_shared(), extra);
}

#[test]
fn multi_rhs_two_row_bit_matches_single_and_projected_f64() {
    let g = geom();
    let mut rng = Rng::seeded(206);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let kappa = 0.137f64;
    let nrhs = 3;
    let srcs: Vec<FermionField<f64>> =
        (0..nrhs).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
    let psi = MultiFermionField::from_rhs(&srcs);
    let active = vec![true; nrhs];
    let proj = CompressedGaugeField::compress(&u).reconstruct();

    for threads in [1usize, 3] {
        let mut team = Team::new(threads, BarrierKind::Sleep);
        use lqcd::coordinator::operator::{MultiNativeMeo, MultiOperator};
        // batched two-row apply
        let links = Links::from_gauge(u.clone(), Compression::TwoRow);
        let mut mop = MultiNativeMeo::with_links(&g, links.clone(), kappa, nrhs);
        let mut out = psi.zeros_like();
        mop.apply_multi(&mut team, &mut out, &psi, &active, None);
        // must bit-match the single-RHS two-row operator per RHS...
        let mut sop = NativeMeo::with_links(&g, links, kappa);
        // ...and the full-link batched operator on the projected field
        let mut pop = MultiNativeMeo::new(&g, proj.clone(), kappa, nrhs);
        let mut pout = psi.zeros_like();
        pop.apply_multi(&mut team, &mut pout, &psi, &active, None);
        for (r, s) in srcs.iter().enumerate() {
            let mut want = FermionField::zeros(&g);
            sop.apply(&mut want, s);
            assert_eq!(
                out.extract_rhs(r).data,
                want.data,
                "multi two-row rhs {r} must bit-match single two-row ({threads} threads)"
            );
            assert_eq!(
                out.extract_rhs(r).data,
                pout.extract_rhs(r).data,
                "multi two-row rhs {r} must bit-match projected full links"
            );
        }
    }
}

#[test]
fn block_solve_two_row_histories_identical_to_projected() {
    let g = geom();
    let mut rng = Rng::seeded(207);
    let u = GaugeField::<f32>::random(&g, &mut rng);
    let proj = CompressedGaugeField::compress(&u).reconstruct();
    let kappa = 0.12f32;
    let nrhs = 2;
    let (tol, maxiter) = (1e-5, 400);
    // CGNR right-hand sides Mdag b through the projected operator (the
    // arithmetic both solves below share)
    let rhs: Vec<FermionField<f32>> = (0..nrhs)
        .map(|_| {
            let b: FermionField<f32> = FermionField::gaussian(&g, &mut rng);
            let mut bp = b.clone();
            bp.gamma5();
            let mut meo = NativeMeo::new(&g, proj.clone(), kappa);
            let mut mbp = FermionField::zeros(&g);
            meo.apply(&mut mbp, &bp);
            mbp.gamma5();
            mbp
        })
        .collect();
    let b_block = MultiFermionField::from_rhs(&rhs);
    let mut team = Team::new(2, BarrierKind::Sleep);

    let full_stats = {
        let mut op = MultiMdagM::new(&g, proj.clone(), kappa, nrhs);
        let mut x = MultiFermionField::<f32>::zeros(&g, nrhs);
        solver::block_cg(&mut op, &mut team, &mut x, &b_block, tol, maxiter)
    };
    assert!(full_stats.converged);
    let two_stats = {
        let links = Links::from_gauge(u, Compression::TwoRow);
        let mut op = MultiMdagM::with_links(&g, links, kappa, nrhs);
        let mut x = MultiFermionField::<f32>::zeros(&g, nrhs);
        solver::block_cg(&mut op, &mut team, &mut x, &b_block, tol, maxiter)
    };
    assert!(two_stats.converged);
    for r in 0..nrhs {
        assert_eq!(
            full_stats.per_rhs[r].history, two_stats.per_rhs[r].history,
            "rhs {r}: block two-row history must bit-match projected full links"
        );
    }
}

/// Distributed hopping (EO1 pack / bulk ∥ comm / EO2 merge) with
/// two-row links must bit-match full links on the projected field, for
/// a real decomposition and for forced self-communication.
#[test]
fn distributed_hopping_two_row_bit_matches_projected() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let cases = [
        (ProcGrid([1, 1, 1, 1]), true), // forced self-comm: EO1/EO2 live
        (ProcGrid([1, 1, 2, 2]), true),
        (ProcGrid([2, 1, 1, 1]), false), // x split: irregular faces
    ];
    for (grid, force_comm) in cases {
        let ggeom = Geometry::single_rank(global, tiling).unwrap();
        let mut rng = Rng::seeded(208);
        let u_raw: GaugeField<f32> = GaugeField::random(&ggeom, &mut rng);
        let proj_global = CompressedGaugeField::compress(&u_raw).reconstruct();
        let psi_global: FermionField<f32> = FermionField::gaussian(&ggeom, &mut rng);
        for p_out in Parity::BOTH {
            run_world(grid.size(), |rank, comm| {
                let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
                let proj = extract_gauge(&proj_global, &lgeom);
                let compressed = CompressedGaugeField::compress(&proj);
                let psi = extract_fermion(&psi_global, &ggeom, &lgeom);
                let dist = DistHopping::new(&lgeom, force_comm, 2, Eo2Schedule::Uniform);
                let mut team = Team::new(2, BarrierKind::Sleep);
                let prof = Profiler::new(2);

                let mut want = FermionField::zeros(&lgeom);
                dist.hopping(&mut want, &proj, &psi, p_out, comm, &mut team, &prof);
                let mut got = FermionField::zeros(&lgeom);
                dist.hopping(&mut got, &compressed, &psi, p_out, comm, &mut team, &prof);
                assert_eq!(
                    got.data, want.data,
                    "distributed two-row must bit-match (grid {grid:?}, force={force_comm}, \
                     rank {rank}, {p_out:?})"
                );
            });
        }
    }
}

/// A distributed CGNR solve through a two-row DistMeo must produce the
/// same residual history as the full-link operator on the projected
/// field — compression composes with the fused distributed pipeline.
#[test]
fn distributed_solve_two_row_history_identical() {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let grid = ProcGrid([1, 1, 1, 2]);
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(209);
    let u_raw: GaugeField<f32> = GaugeField::random(&ggeom, &mut rng);
    let proj_global = CompressedGaugeField::compress(&u_raw).reconstruct();
    let b_global: FermionField<f32> = FermionField::gaussian(&ggeom, &mut rng);
    let kappa = 0.12f32;
    let (tol, maxiter) = (1e-5, 40);

    let histories = run_world(grid.size(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let proj = extract_gauge(&proj_global, &lgeom);
        let compressed = CompressedGaugeField::compress(&proj);
        let b = extract_fermion(&b_global, &ggeom, &lgeom);
        let dist = DistHopping::new(&lgeom, true, 2, Eo2Schedule::Uniform);
        let prof = Profiler::new(2);

        let full_hist = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let inner = DistMeo::new(&lgeom, &dist, &proj, kappa, comm, &mut team, &prof);
            let mut op = lqcd::coordinator::operator::NormalOp::new(inner, &lgeom);
            let mut x = FermionField::<f32>::zeros(&lgeom);
            solver::cg(&mut op, &mut x, &b, tol, maxiter).history
        };
        let two_hist = {
            let mut team = Team::new(2, BarrierKind::Sleep);
            let inner =
                DistMeo::new(&lgeom, &dist, &compressed, kappa, comm, &mut team, &prof);
            let mut op = lqcd::coordinator::operator::NormalOp::new(inner, &lgeom);
            let mut x = FermionField::<f32>::zeros(&lgeom);
            solver::cg(&mut op, &mut x, &b, tol, maxiter).history
        };
        (full_hist, two_hist)
    });
    for (rank, (full_hist, two_hist)) in histories.iter().enumerate() {
        assert!(!full_hist.is_empty(), "reference solve ran no iterations");
        assert_eq!(
            full_hist, two_hist,
            "rank {rank}: distributed two-row history diverged from projected full links"
        );
    }
}
