//! CLI/config validation matrix: every flag combination `lqcd solve`
//! rejects must be rejected by `RunConfig::validate_solve` (the single
//! early validation block the launcher calls), exercised through the
//! same `config::run` parsing the `--config` path uses — including the
//! new `parallel (grid) × nrhs` combinations.

use lqcd::config::RunConfig;
use lqcd::dslash::Compression;
use lqcd::lattice::ProcGrid;

/// A default config with targeted overrides, as the CLI layer builds it.
fn cfg(f: impl FnOnce(&mut RunConfig)) -> RunConfig {
    let mut c = RunConfig::default();
    f(&mut c);
    c
}

#[test]
fn default_config_is_valid_for_solve() {
    assert!(cfg(|_| {}).validate_solve(false).is_ok());
    assert!(cfg(|_| {}).validate_solve(true).is_ok(), "pjrt f32 single-RHS is fine");
}

#[test]
fn pjrt_reports_every_offending_flag_at_once() {
    // the historical behavior reported only whichever branch ran first;
    // the hoisted block must name ALL offenses in one error
    let c = cfg(|c| {
        c.solver.precision = "f64".into();
        c.solver.nrhs = 2;
        c.gauge.compression = Compression::TwoRow;
        c.lattice.grid = ProcGrid([1, 1, 1, 2]);
    });
    let err = c.validate_solve(true).unwrap_err();
    assert!(err.contains("--precision f64"), "missing precision offense: {err}");
    assert!(err.contains("--nrhs"), "missing nrhs offense: {err}");
    assert!(err.contains("--gauge-compression"), "missing compression offense: {err}");
    assert!(err.contains("multi-rank"), "missing grid offense: {err}");
    // four distinct lines, one per offense
    assert_eq!(err.lines().count(), 4, "{err}");
}

#[test]
fn pjrt_mixed_precision_rejected() {
    let c = cfg(|c| c.solver.precision = "mixed".into());
    let err = c.validate_solve(true).unwrap_err();
    assert!(err.contains("--pjrt only supports f32"), "{err}");
    // but mixed without pjrt is a supported single-rank path
    assert!(c.validate_solve(false).is_ok());
}

#[test]
fn nrhs_with_mixed_points_at_the_roadmap_gap() {
    let c = cfg(|c| {
        c.solver.nrhs = 4;
        c.solver.precision = "mixed".into();
    });
    let err = c.validate_solve(false).unwrap_err();
    // not a bare "got mixed": the message explains WHAT is missing
    assert!(err.contains("ROADMAP"), "{err}");
    assert!(err.contains("block refinement"), "{err}");
    assert!(err.contains("f32 or f64"), "{err}");
}

#[test]
fn grid_times_nrhs_times_compression_compose() {
    // the combinations this PR makes legal: multi-rank × multi-RHS ×
    // two-row at both uniform precisions
    for precision in ["f32", "f64"] {
        for nrhs in [1usize, 2, 8] {
            for compression in [Compression::None, Compression::TwoRow] {
                let c = cfg(|c| {
                    c.lattice.grid = ProcGrid([1, 1, 2, 2]);
                    c.solver.nrhs = nrhs;
                    c.solver.precision = precision.into();
                    c.gauge.compression = compression;
                });
                assert!(
                    c.validate_solve(false).is_ok(),
                    "grid × nrhs {nrhs} × {compression} × {precision} must be legal"
                );
            }
        }
    }
}

#[test]
fn grid_with_mixed_precision_rejected() {
    let c = cfg(|c| {
        c.lattice.grid = ProcGrid([1, 1, 1, 2]);
        c.solver.precision = "mixed".into();
    });
    let err = c.validate_solve(false).unwrap_err();
    assert!(err.contains("distributed"), "{err}");
    assert!(err.contains("ROADMAP"), "{err}");
    // grid × mixed × nrhs reports both combination offenses
    let c = cfg(|c| {
        c.lattice.grid = ProcGrid([1, 1, 1, 2]);
        c.solver.precision = "mixed".into();
        c.solver.nrhs = 2;
    });
    assert_eq!(c.validate_solve(false).unwrap_err().lines().count(), 2);
}

#[test]
fn distributed_nrhs_capped_by_wire_mask_width() {
    let c = cfg(|c| {
        c.lattice.grid = ProcGrid([1, 1, 1, 2]);
        c.solver.nrhs = 33;
    });
    let err = c.validate_solve(false).unwrap_err();
    assert!(err.contains("at most 32"), "{err}");
    // 32 is fine, and so is 33 on a single rank (native block solver)
    assert!(cfg(|c| {
        c.lattice.grid = ProcGrid([1, 1, 1, 2]);
        c.solver.nrhs = 32;
    })
    .validate_solve(false)
    .is_ok());
    assert!(cfg(|c| c.solver.nrhs = 33).validate_solve(false).is_ok());
}

#[test]
fn unknown_algorithm_rejected() {
    let c = cfg(|c| c.solver.algorithm = "sor".into());
    let err = c.validate_solve(false).unwrap_err();
    assert!(err.contains("solver.algorithm"), "{err}");
    for ok in ["cg", "bicgstab"] {
        assert!(cfg(|c| c.solver.algorithm = ok.into()).validate_solve(false).is_ok());
    }
}

#[test]
fn config_file_driven_combinations() {
    // the same matrix through the TOML-subset parser, like --config
    let doc = lqcd::config::Document::parse(
        "[lattice]\ngrid = [1, 1, 2, 2]\n[solver]\nnrhs = 2\nprecision = \"f64\"",
    )
    .unwrap();
    let c = RunConfig::from_document(&doc).unwrap();
    assert_eq!(c.lattice.grid.size(), 4);
    assert!(c.validate_solve(false).is_ok());

    let doc = lqcd::config::Document::parse(
        "[lattice]\ngrid = [1, 1, 1, 2]\n[solver]\nnrhs = 2\nprecision = \"mixed\"",
    )
    .unwrap();
    let c = RunConfig::from_document(&doc).unwrap();
    let err = c.validate_solve(false).unwrap_err();
    assert!(err.contains("block refinement") && err.contains("distributed"), "{err}");

    // per-key range checks still fail at parse time, before validate
    let doc = lqcd::config::Document::parse("[solver]\nnrhs = 0").unwrap();
    assert!(RunConfig::from_document(&doc).is_err());
    let doc = lqcd::config::Document::parse("[solver]\nprecision = \"f16\"").unwrap();
    assert!(RunConfig::from_document(&doc).is_err());
}

#[test]
fn grid_cli_spelling_parses_like_the_config_array() {
    let from_cli = ProcGrid::parse("1x1x2x2").unwrap();
    let doc = lqcd::config::Document::parse("[lattice]\ngrid = [1, 1, 2, 2]").unwrap();
    let from_cfg = RunConfig::from_document(&doc).unwrap().lattice.grid;
    assert_eq!(from_cli, from_cfg);
    assert!(ProcGrid::parse("1x1x0x2").is_err());
    assert!(ProcGrid::parse("2x2").is_err());
}
