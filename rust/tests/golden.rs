//! Golden-data tests: the Rust native kernels must reproduce the Python
//! reference (pure-jnp oracle, f64) bit-for-convention. This pins the two
//! sides of the AOT boundary to the same gamma basis, site ordering,
//! even-odd compaction and hopping normalization.
//!
//! Requires `make artifacts` to have produced `artifacts/golden/`.

use std::path::PathBuf;

use lqcd::dslash::{full, HoppingEo};
use lqcd::field::io::{
    fermion_from_canonical, gauge_from_canonical, read_tensor,
};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, Tiling};

const KAPPA: f32 = 0.13;

/// Golden data is produced by `make artifacts` (needs the Python/JAX
/// toolchain). When absent — e.g. in the offline Rust-only build — the
/// golden tests skip instead of failing; kernel correctness is still
/// covered by the in-crate scalar oracle (`kernel_equivalence`).
/// Set `LQCD_REQUIRE_ARTIFACTS=1` (artifact-enabled CI) to make a
/// missing golden set a hard failure instead of a silent skip.
fn golden_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden");
    if dir.join("u_eo.bin").exists() {
        Some(dir)
    } else if std::env::var_os("LQCD_REQUIRE_ARTIFACTS").is_some() {
        panic!(
            "LQCD_REQUIRE_ARTIFACTS set but {} missing (run `make artifacts`)",
            dir.display()
        );
    } else {
        eprintln!(
            "skipping golden test: {} missing (run `make artifacts`)",
            dir.display()
        );
        None
    }
}

fn geom(tiling: Tiling) -> Geometry {
    // golden lattice is 4x4x4x4 (aot.py --golden-dims)
    Geometry::single_rank(LatticeDims::new(4, 4, 4, 4).unwrap(), tiling).unwrap()
}

fn load_gauge(dir: &std::path::Path, g: &Geometry) -> GaugeField {
    let t = read_tensor(&dir.join("u_eo.bin")).unwrap();
    assert_eq!(t.dims[..2], [4, 2], "gauge canonical shape");
    let mut u = GaugeField::unit(g);
    gauge_from_canonical(&mut u, &t.data).unwrap();
    u
}

fn load_fermion(dir: &std::path::Path, g: &Geometry, name: &str) -> FermionField {
    let t = read_tensor(&dir.join(format!("{name}.bin"))).unwrap();
    let mut f = FermionField::zeros(g);
    fermion_from_canonical(&mut f, &t.data).unwrap();
    f
}

fn assert_close(got: &FermionField, want: &FermionField, tol: f64, what: &str) {
    let mut d = got.clone();
    d.axpy(-1.0, want);
    let rel = (d.norm2() / want.norm2()).sqrt();
    assert!(rel < tol, "{what}: rel diff {rel}");
}

#[test]
fn hopping_oe_matches_python_oracle() {
    let Some(dir) = golden_dir() else { return };
    for tiling in [Tiling::new(2, 2).unwrap(), Tiling::new(2, 4).unwrap()] {
        let g = geom(tiling);
        let u = load_gauge(&dir, &g);
        let psi_e = load_fermion(&dir, &g, "psi_e");
        let want = load_fermion(&dir, &g, "hop_oe");
        let mut got = FermionField::zeros(&g);
        HoppingEo::new(&g).apply(&mut got, &u, &psi_e, Parity::Odd);
        assert_close(&got, &want, 1e-5, &format!("H_oe ({tiling})"));
    }
}

#[test]
fn hopping_eo_matches_python_oracle() {
    let Some(dir) = golden_dir() else { return };
    let g = geom(Tiling::new(2, 2).unwrap());
    let u = load_gauge(&dir, &g);
    let psi_o = load_fermion(&dir, &g, "psi_o");
    let want = load_fermion(&dir, &g, "hop_eo");
    let mut got = FermionField::zeros(&g);
    HoppingEo::new(&g).apply(&mut got, &u, &psi_o, Parity::Even);
    assert_close(&got, &want, 1e-5, "H_eo");
}

#[test]
fn meo_matches_python_oracle() {
    let Some(dir) = golden_dir() else { return };
    let g = geom(Tiling::new(2, 2).unwrap());
    let u = load_gauge(&dir, &g);
    let psi_e = load_fermion(&dir, &g, "psi_e");
    let want = load_fermion(&dir, &g, "meo");
    let hop = HoppingEo::new(&g);
    let mut got = FermionField::zeros(&g);
    let mut tmp = FermionField::zeros(&g);
    full::meo(&hop, &mut got, &mut tmp, &u, &psi_e, KAPPA);
    assert_close(&got, &want, 1e-5, "M-hat");
}

#[test]
fn plaquette_matches_python_oracle() {
    let Some(dir) = golden_dir() else { return };
    let g = geom(Tiling::new(2, 2).unwrap());
    let u = load_gauge(&dir, &g);
    let t = read_tensor(&dir.join("plaq.bin")).unwrap();
    let want = t.data[0];
    let got = u.plaquette();
    assert!(
        (got - want).abs() < 1e-5,
        "plaquette: got {got}, want {want}"
    );
}

#[test]
fn dslash_full_matches_python_oracle() {
    // full-lattice D_W check through the even/odd pair: scatter the golden
    // full-lattice fields into (even, odd) halves using compaction, apply,
    // and compare against the golden full result.
    use lqcd::lattice::{EvenOdd, SiteCoord};

    let Some(dir) = golden_dir() else { return };
    let g = geom(Tiling::new(2, 2).unwrap());
    let u = load_gauge(&dir, &g);
    let psi_t = read_tensor(&dir.join("psi_full.bin")).unwrap();
    let want_t = read_tensor(&dir.join("dslash_full.bin")).unwrap();
    let dims = g.local;

    // canonical full-lattice order: (T, Z, Y, X, spin, color, reim)
    let full_index = |t: usize, z: usize, y: usize, x: usize,
                      s: usize, c: usize, r: usize| {
        ((((((t * dims.z + z) * dims.y + y) * dims.x + x) * 4 + s) * 3 + c) * 2) + r
    };
    let mut psi_e = FermionField::zeros(&g);
    let mut psi_o = FermionField::zeros(&g);
    for (parity, field) in [(Parity::Even, &mut psi_e), (Parity::Odd, &mut psi_o)] {
        for sc in field.layout.sites().collect::<Vec<SiteCoord>>() {
            let phi = EvenOdd::row_parity(sc.y, sc.z, sc.t, parity);
            let x = EvenOdd::lexical_x(sc.ix, phi);
            for s in 0..4 {
                for c in 0..3 {
                    for r in 0..2 {
                        let off = field.layout.spinor_elem(sc, s, c, r);
                        field.data[off] =
                            psi_t.data[full_index(sc.t, sc.z, sc.y, x, s, c, r)] as f32;
                    }
                }
            }
        }
    }

    let hop = HoppingEo::new(&g);
    let mut out_e = FermionField::zeros(&g);
    let mut out_o = FermionField::zeros(&g);
    full::dslash_full(&hop, &mut out_e, &mut out_o, &u, &psi_e, &psi_o, KAPPA);

    let mut err2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for (parity, field) in [(Parity::Even, &out_e), (Parity::Odd, &out_o)] {
        for sc in field.layout.sites() {
            let phi = EvenOdd::row_parity(sc.y, sc.z, sc.t, parity);
            let x = EvenOdd::lexical_x(sc.ix, phi);
            for s in 0..4 {
                for c in 0..3 {
                    for r in 0..2 {
                        let got = field.data[field.layout.spinor_elem(sc, s, c, r)] as f64;
                        let want = want_t.data[full_index(sc.t, sc.z, sc.y, x, s, c, r)];
                        err2 += (got - want) * (got - want);
                        norm2 += want * want;
                    }
                }
            }
        }
    }
    let rel = (err2 / norm2).sqrt();
    assert!(rel < 1e-5, "D_W full: rel diff {rel}");
}
