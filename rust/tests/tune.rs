//! Tuner contract tests: deterministic cache serialization, fingerprint
//! invalidation, corrupt-cache fallback, knob precedence, and — the
//! acceptance property — that tuning can only ever pick *which*
//! configuration runs, never change what it computes: residual
//! histories are bitwise identical between a tuned-resolution run and
//! an explicit-knob run, across thread counts, and across EO2
//! chunkings.

use std::path::PathBuf;

use lqcd::comm::run_world;
use lqcd::coordinator::operator::NativeMdagM;
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, Tiling};
use lqcd::perf::tune::{
    candidate_tilings, choose, volume_class, ChunkSample, Measurements, ThreadSample,
    TilingSample,
};
use lqcd::perf::{
    resolve_knobs, run_tune, CacheLookup, ExplicitKnobs, HostFingerprint, KnobSource,
    TuneCache, TuneOptions, TUNE_CACHE_VERSION,
};
use lqcd::solver::fused;
use lqcd::util::rng::Rng;

/// Fresh scratch dir per test (no tempfile crate in the offline build).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lqcd-tune-test-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn dims() -> LatticeDims {
    LatticeDims::new(8, 8, 4, 4).unwrap()
}

fn sample_measurements() -> Measurements {
    Measurements {
        dims: dims(),
        stream_1t_gbs: 8.5,
        stream_sat_gbs: 27.25,
        tilings: vec![
            TilingSample {
                tiling: Tiling::new(4, 4).unwrap(),
                seconds_per_apply: 1.25e-4,
                gbs: 21.0,
            },
            TilingSample {
                tiling: Tiling::new(2, 8).unwrap(),
                seconds_per_apply: 1.5e-4,
                gbs: 17.5,
            },
        ],
        threads: vec![
            ThreadSample {
                threads: 1,
                seconds_per_iter: 9e-4,
                gbs: 11.0,
            },
            ThreadSample {
                threads: 2,
                seconds_per_iter: 4.8e-4,
                gbs: 20.6,
            },
            ThreadSample {
                threads: 4,
                seconds_per_iter: 4.6e-4,
                gbs: 21.5,
            },
        ],
        chunks: vec![
            ChunkSample {
                schedule: Eo2Schedule::Uniform,
                granularity: 1,
                seconds_per_apply: 2e-4,
                eo2_imbalance: 1.9,
            },
            ChunkSample {
                schedule: Eo2Schedule::Balanced,
                granularity: 4,
                seconds_per_apply: 1.7e-4,
                eo2_imbalance: 1.05,
            },
        ],
    }
}

fn sample_cache() -> TuneCache {
    TuneCache::from_measurements(
        HostFingerprint::new(8, 27.25, dims()),
        sample_measurements(),
    )
}

// ---------------------------------------------------------------------
// determinism + persistence
// ---------------------------------------------------------------------

#[test]
fn cache_serialization_is_deterministic() {
    // same measurements in → byte-identical JSON out, twice, and after a
    // parse round trip; no timestamps or run-dependent state anywhere
    let a = sample_cache();
    let b = sample_cache();
    assert_eq!(a.to_json(), b.to_json());
    let reparsed = TuneCache::parse(&a.to_json()).unwrap();
    assert_eq!(reparsed.to_json(), a.to_json());
    for banned in ["time", "date", "stamp"] {
        assert!(
            !a.to_json().to_lowercase().contains(banned),
            "cache JSON must not contain {banned:?}"
        );
    }
}

#[test]
fn save_load_hit() {
    let dir = scratch("hit");
    let cache = sample_cache();
    let path = cache.save(&dir).unwrap();
    assert!(path.to_string_lossy().contains(&cache.fingerprint.key()));
    match TuneCache::load_for(&dir, &cache.fingerprint) {
        CacheLookup::Hit(c) => assert_eq!(c.choice, cache.choice),
        other => panic!("expected Hit, got {other:?}"),
    }
    // the solve-path lookup (no calibration available) also hits
    match TuneCache::load_for_host(&dir, 8, dims()) {
        CacheLookup::Hit(c) => assert_eq!(c.choice, cache.choice),
        other => panic!("expected Hit, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stale_version_and_fingerprint_are_refused() {
    let dir = scratch("stale");
    let cache = sample_cache();
    let path = cache.save(&dir).unwrap();

    // a version bump invalidates the cache in place
    let tampered = cache
        .to_json()
        .replace(
            &format!("\"version\":{TUNE_CACHE_VERSION}"),
            &format!("\"version\":{}", TUNE_CACHE_VERSION + 1),
        );
    std::fs::write(&path, tampered).unwrap();
    match TuneCache::load_for(&dir, &cache.fingerprint) {
        CacheLookup::Stale { found, want } => {
            assert!(found.contains(&format!("{}", TUNE_CACHE_VERSION + 1)), "{found}");
            assert!(want.contains(&format!("{TUNE_CACHE_VERSION}")), "{want}");
        }
        other => panic!("expected Stale, got {other:?}"),
    }

    // a cache written by a host in a far bandwidth class is stale for
    // this one (strict lookup only — the solve path ignores bandwidth)
    cache.save(&dir).unwrap();
    let fast_host = HostFingerprint::new(8, 27.25 * 16.0, dims());
    match TuneCache::load_for(&dir, &fast_host) {
        CacheLookup::Stale { .. } => {}
        other => panic!("expected Stale for distant bw class, got {other:?}"),
    }

    // different core count or volume class looks up a different file:
    // plain Missing, not Stale
    match TuneCache::load_for_host(&dir, 4, dims()) {
        CacheLookup::Missing => {}
        other => panic!("expected Missing for other core count, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_cache_reports_not_panics() {
    let dir = scratch("corrupt");
    let cache = sample_cache();
    let path = cache.save(&dir).unwrap();
    std::fs::write(&path, "{\"version\": not json").unwrap();
    match TuneCache::load_for_host(&dir, 8, dims()) {
        CacheLookup::Corrupt(msg) => {
            assert!(msg.contains("tune-"), "message should name the file: {msg}")
        }
        other => panic!("expected Corrupt, got {other:?}"),
    }
    // truncated-but-valid JSON (missing fields) is corrupt too
    std::fs::write(&path, "{\"version\": 1}").unwrap();
    match TuneCache::load_for_host(&dir, 8, dims()) {
        CacheLookup::Corrupt(_) => {}
        other => panic!("expected Corrupt for truncated doc, got {other:?}"),
    }
    // and a corrupt cache must leave knob resolution on the heuristics
    let r = resolve_knobs(
        &ExplicitKnobs::default(),
        None,
        dims(),
        Tiling::new(2, 2).unwrap(),
        3,
    );
    assert_eq!(r.tiling, (Tiling::new(2, 2).unwrap(), KnobSource::Heuristic));
    assert_eq!(r.threads, (3, KnobSource::Heuristic));
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// precedence
// ---------------------------------------------------------------------

#[test]
fn precedence_is_cli_then_cache_then_heuristic() {
    let cache = sample_cache();
    let h_tiling = Tiling::new(2, 2).unwrap();

    // cache fills everything the user left open
    let r = resolve_knobs(&ExplicitKnobs::default(), Some(&cache), dims(), h_tiling, 5);
    assert_eq!(r.tiling, (cache.choice.tiling, KnobSource::Cache));
    assert_eq!(r.threads, (cache.choice.threads, KnobSource::Cache));
    assert_eq!(
        r.eo2_schedule,
        (cache.choice.eo2_schedule, KnobSource::Cache)
    );

    // a CLI/config value wins over the cache, per knob
    let explicit = ExplicitKnobs {
        threads: Some(7),
        ..Default::default()
    };
    let r = resolve_knobs(&explicit, Some(&cache), dims(), h_tiling, 5);
    assert_eq!(r.threads, (7, KnobSource::Cli));
    assert_eq!(r.tiling.1, KnobSource::Cache, "other knobs stay cached");
    let s = r.summary();
    assert!(s.contains("threads=7[cli/config]"), "{s}");
    assert!(s.contains("[tune-cache]"), "{s}");

    // no cache → heuristics
    let r = resolve_knobs(&ExplicitKnobs::default(), None, dims(), h_tiling, 5);
    assert_eq!(r.tiling, (h_tiling, KnobSource::Heuristic));
    assert_eq!(r.threads, (5, KnobSource::Heuristic));
    assert_eq!(r.eo2_schedule, (Eo2Schedule::Uniform, KnobSource::Heuristic));
    assert_eq!(r.eo2_granularity, (1, KnobSource::Heuristic));
}

#[test]
fn cached_tiling_is_validated_against_the_lattice() {
    // cache tuned on 8x8x4x4 chose 4x4; a 4x8x4x8 lattice (xh = 2)
    // cannot lay that out — the tiling knob falls back, the rest stay
    let slim = LatticeDims::new(4, 8, 4, 8).unwrap();
    let cache = sample_cache();
    let h_tiling = Tiling::new(2, 2).unwrap();
    let r = resolve_knobs(&ExplicitKnobs::default(), Some(&cache), slim, h_tiling, 5);
    assert_eq!(r.tiling, (h_tiling, KnobSource::Heuristic));
    assert_eq!(r.threads.1, KnobSource::Cache);
}

// ---------------------------------------------------------------------
// the acceptance property: tuning never changes numerics
// ---------------------------------------------------------------------

/// Fused CGNR at a given tiling/thread count; returns the residual
/// history (the canonical-reduction contract makes it a pure function
/// of (lattice, seed, tiling) — threads must not appear).
fn cg_history(dims: LatticeDims, tiling: Tiling, threads: usize) -> Vec<f64> {
    let geom = Geometry::single_rank(dims, tiling).unwrap();
    let mut rng = Rng::seeded(2023);
    let u: GaugeField<f32> = GaugeField::random(&geom, &mut rng);
    let b: FermionField<f32> = FermionField::gaussian(&geom, &mut rng);
    let mut op = NativeMdagM::new(&geom, u, 0.12f32);
    let mut team = Team::new(threads, BarrierKind::Spin);
    let mut x = FermionField::zeros(&geom);
    let stats = fused::cg(&mut op, &mut team, &mut x, &b, 1e-5, 300);
    assert!(stats.converged);
    assert!(stats.iterations > 3, "system must take several iterations");
    stats.history
}

#[test]
fn tuned_resolution_is_bitwise_equal_to_explicit_knobs() {
    // resolve knobs from a synthetic cache (threads 2, tiling 4x4) and
    // run; then pin the same knobs explicitly and run again — the
    // histories must be bitwise identical (resolution only selects the
    // configuration, it cannot touch the arithmetic)
    let cache = sample_cache();
    let tuned = resolve_knobs(
        &ExplicitKnobs::default(),
        Some(&cache),
        dims(),
        Tiling::new(2, 2).unwrap(),
        1,
    );
    assert_eq!(tuned.tiling.1, KnobSource::Cache);
    let explicit = resolve_knobs(
        &ExplicitKnobs {
            tiling: Some(tuned.tiling.0),
            threads: Some(tuned.threads.0),
            eo2_schedule: Some(tuned.eo2_schedule.0),
            eo2_granularity: Some(tuned.eo2_granularity.0),
        },
        None,
        dims(),
        Tiling::new(2, 2).unwrap(),
        1,
    );
    assert_eq!(explicit.tiling.1, KnobSource::Cli);
    let h_tuned = cg_history(dims(), tuned.tiling.0, tuned.threads.0);
    let h_explicit = cg_history(dims(), explicit.tiling.0, explicit.threads.0);
    assert_eq!(h_tuned, h_explicit);
}

#[test]
fn thread_knob_does_not_change_residual_history() {
    let t = Tiling::new(4, 4).unwrap();
    let h1 = cg_history(dims(), t, 1);
    for threads in [2usize, 3, 4] {
        assert_eq!(
            cg_history(dims(), t, threads),
            h1,
            "residual history changed at {threads} threads"
        );
    }
}

#[test]
fn eo2_chunking_is_bitwise_invariant() {
    // the chunking knob only moves which thread merges which boundary
    // sites — the distributed hopping output must be bitwise identical
    // across every (schedule, granularity) the tuner can pick
    let d = dims();
    let tiling = Tiling::new(4, 4).unwrap();
    let fields: Vec<Vec<f32>> = [
        (Eo2Schedule::Uniform, 1usize),
        (Eo2Schedule::Balanced, 1),
        (Eo2Schedule::Balanced, 4),
        (Eo2Schedule::Balanced, 16),
    ]
    .iter()
    .map(|&(schedule, granularity)| {
        run_world(1, |_rank, comm| {
            let geom = Geometry::single_rank(d, tiling).unwrap();
            let mut rng = Rng::seeded(99);
            let u: GaugeField<f32> = GaugeField::random(&geom, &mut rng);
            let psi: FermionField<f32> = FermionField::gaussian(&geom, &mut rng);
            let mut out = psi.zeros_like();
            let threads = 3;
            let hop = DistHopping::with_chunking(&geom, true, threads, schedule, granularity);
            let mut team = Team::new(threads, BarrierKind::Spin);
            let prof = Profiler::new(threads);
            hop.hopping(&mut out, &u, &psi, Parity::Even, comm, &mut team, &prof);
            out.data
        })
        .pop()
        .unwrap()
    })
    .collect();
    for (i, f) in fields.iter().enumerate().skip(1) {
        assert_eq!(
            f, &fields[0],
            "EO2 chunking candidate {i} changed the hopping output"
        );
    }
}

// ---------------------------------------------------------------------
// an actual (tiny) tune run, end to end
// ---------------------------------------------------------------------

#[test]
fn quick_tune_produces_a_cache_a_solve_consumes() {
    let d = dims();
    // synthetic calibration: the sweep itself measures the kernels; the
    // STREAM numbers only seed the fingerprint and the roofline fallback
    let host = lqcd::perf::HostCalibration {
        core_sp_gflops: 10.0,
        mem_bw_gbs: 8.0,
        mem_bw_saturated_gbs: 24.0,
        saturation_threads: 2,
    };
    let opts = TuneOptions {
        dims: d,
        seed: 11,
        budget_ms: 150,
        quick: true,
    };
    let m = run_tune(&host, &opts);
    assert!(!m.tilings.is_empty(), "tiling sweep must produce samples");
    assert!(!m.threads.is_empty(), "thread sweep must produce samples");
    assert!(!m.chunks.is_empty(), "chunk sweep must produce samples");
    for s in &m.tilings {
        assert!(s.tiling.divides(d));
        assert!(s.gbs > 0.0 && s.seconds_per_apply > 0.0);
    }
    let choice = choose(&m);
    assert!(choice.roofline_gbs > 0.0);
    assert!(choice.threads >= 1);

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let fp = HostFingerprint::new(cores, host.mem_bw_saturated_gbs, d);
    let dir = scratch("e2e");
    let cache = TuneCache::from_measurements(fp, m);
    cache.save(&dir).unwrap();

    // ... and a later solve on the same host/volume resolves from it
    let hit = match TuneCache::load_for_host(&dir, cores, d) {
        CacheLookup::Hit(c) => c,
        other => panic!("solve-path lookup failed: {other:?}"),
    };
    let r = resolve_knobs(
        &ExplicitKnobs::default(),
        Some(&hit),
        d,
        Tiling::new(2, 2).unwrap(),
        1,
    );
    assert_eq!(r.tiling, (choice.tiling, KnobSource::Cache));
    assert_eq!(r.threads, (choice.threads, KnobSource::Cache));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn candidate_sweeps_respect_quick_and_volume() {
    let d = dims();
    let full = candidate_tilings(d, false);
    let quick = candidate_tilings(d, true);
    assert!(!quick.is_empty());
    assert!(full.len() >= quick.len());
    for t in quick {
        assert_eq!(t.vlen(), 16, "--quick sweeps the paper's VLEN=16 family only");
    }
    assert_eq!(volume_class(d), volume_class(LatticeDims::new(8, 4, 8, 4).unwrap()));
}
