//! Checkpoint/restart integration tests.
//!
//! The contract under test: a solve interrupted after a checkpoint and
//! resumed via `load_latest` continues with residual history and final
//! iterate BITWISE identical to the uninterrupted run — for every
//! solver family (cg, bicgstab, fused cg/bicgstab, mixed refinement,
//! block cg/bicgstab, distributed block). Corrupted checkpoint files
//! are detected by structured errors naming the generation and older
//! generations are used instead — a bad file is never silently loaded.
//! On a 2-rank world the buddy scheme re-materializes a lost rank's
//! checkpoint from its ring neighbor's in-memory copy.

use std::fs;
use std::path::PathBuf;

use lqcd::comm::decompose::{extract_fermion, extract_gauge};
use lqcd::comm::{run_world_cfg, FaultPlan, WorldOpts};
use lqcd::coordinator::operator::{
    DistMultiMeo, MultiMdagM, MultiNativeMeo, NativeMdagM, NativeMeo,
};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::field::snapshot::gauge_hash;
use lqcd::field::{FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{Geometry, LatticeDims, ProcGrid, Tiling};
use lqcd::solver::checkpoint::{ckpt_path, commit_path, committed_generations};
use lqcd::solver::{
    self, load_latest, read_state_file, restore_from_buddy, BuddyCopy,
    CheckpointError, Checkpointer, CkptOpts, HealthConfig, InnerAlgorithm,
    SolveErrorKind, SolverState,
};
use lqcd::util::rng::Rng;

/// Fresh scratch dir per test (no tempfile crate in the offline build).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lqcd-ckpt-test-{}-{tag}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn opts(dir: &PathBuf, every_iters: u64, keep: usize, buddy: bool) -> CkptOpts {
    CkptOpts {
        dir: dir.clone(),
        every_iters,
        every_ms: 0,
        keep,
        buddy,
    }
}

fn geom() -> Geometry {
    Geometry::single_rank(
        LatticeDims::new(4, 4, 4, 4).unwrap(),
        Tiling::new(2, 2).unwrap(),
    )
    .unwrap()
}

// ---------------------------------------------------------------------------
// corruption matrix
// ---------------------------------------------------------------------------

/// Every corruption class is detected with a structured error naming
/// the generation; `load_latest` falls back to an older intact
/// generation, and errors out (rather than silently loading) when no
/// generation survives.
#[test]
fn corruption_matrix_detects_and_falls_back() {
    let dir = scratch("corrupt");
    let g = geom();
    let mut rng = Rng::seeded(701);
    let u = GaugeField::random(&g, &mut rng);
    let b = FermionField::gaussian(&g, &mut rng);
    let ghash = gauge_hash(&u);
    let mut op = NativeMdagM::new(&g, u, 0.12f32);
    let mut x = FermionField::zeros(&g);
    let mut ckpt = Checkpointer::new(opts(&dir, 2, 8, false), 0, 1, ghash).unwrap();
    let stats = solver::cg_guarded_ckpt(
        &mut op, &mut x, &b, 1e-8, 500, &HealthConfig::default(),
        Some(&mut ckpt), None,
    )
    .expect("clean checkpointed solve");
    assert!(stats.converged);
    assert!(ckpt.committed() >= 2, "need several generations on disk");

    let gens = committed_generations(&dir, 0);
    assert!(gens.len() >= 2, "{gens:?}");
    let newest = *gens.last().unwrap();
    let path = ckpt_path(&dir, 0, newest);
    let pristine = fs::read(&path).unwrap();

    // truncated file
    fs::write(&path, &pristine[..pristine.len() / 2]).unwrap();
    let e = read_state_file(&dir, 0, newest, ghash).unwrap_err();
    assert!(matches!(e, CheckpointError::Truncated { gen, .. } if gen == newest), "{e}");
    assert!(e.to_string().contains(&format!("generation {newest}")), "{e}");

    // bad magic
    let mut bytes = pristine.clone();
    bytes[0] ^= 0xFF;
    fs::write(&path, &bytes).unwrap();
    let e = read_state_file(&dir, 0, newest, ghash).unwrap_err();
    assert!(matches!(e, CheckpointError::BadMagic { gen } if gen == newest), "{e}");

    // stale format version
    let mut bytes = pristine.clone();
    bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
    fs::write(&path, &bytes).unwrap();
    let e = read_state_file(&dir, 0, newest, ghash).unwrap_err();
    assert!(
        matches!(e, CheckpointError::StaleVersion { gen, found: 99 } if gen == newest),
        "{e}"
    );

    // gauge hash of a different configuration
    fs::write(&path, &pristine).unwrap();
    let e = read_state_file(&dir, 0, newest, ghash ^ 1).unwrap_err();
    assert!(matches!(e, CheckpointError::GaugeMismatch { gen, .. } if gen == newest), "{e}");

    // flipped payload bit
    let mut bytes = pristine.clone();
    bytes[40] ^= 0x01;
    fs::write(&path, &bytes).unwrap();
    let e = read_state_file(&dir, 0, newest, ghash).unwrap_err();
    assert!(matches!(e, CheckpointError::BadCrc { gen, .. } if gen == newest), "{e}");

    // flipped CRC trailer
    let mut bytes = pristine.clone();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    fs::write(&path, &bytes).unwrap();
    let e = read_state_file(&dir, 0, newest, ghash).unwrap_err();
    assert!(matches!(e, CheckpointError::BadCrc { gen, .. } if gen == newest), "{e}");

    // newest is corrupt (payload flip still on disk): load_latest must
    // fall back to the previous intact generation, not fail, not load
    // the bad file
    let mut bytes = pristine.clone();
    bytes[40] ^= 0x01;
    fs::write(&path, &bytes).unwrap();
    let (st, gen) = load_latest(&dir, 0, 1, ghash).expect("fallback generation");
    assert!(gen < newest, "fell back from {newest} to {gen}");
    let want = read_state_file(&dir, 0, gen, ghash).unwrap();
    assert_eq!(st, want);
    assert_eq!(st.history.len() as u64, st.iteration);

    // every generation corrupt: a hard error, never a silent load
    for &gn in &gens {
        let p = ckpt_path(&dir, 0, gn);
        let mut by = fs::read(&p).unwrap();
        by[40] ^= 0x01;
        fs::write(&p, &by).unwrap();
    }
    let e = load_latest(&dir, 0, 1, ghash).unwrap_err();
    assert!(matches!(e, CheckpointError::BadCrc { .. }), "{e}");

    // restoring the newest file restores service
    fs::write(&path, &pristine).unwrap();
    let (_, gen) = load_latest(&dir, 0, 1, ghash).unwrap();
    assert_eq!(gen, newest);
}

/// A checkpoint written by one family is refused by another with a
/// typed error, not misinterpreted.
#[test]
fn wrong_family_resume_is_typed_error() {
    let dir = scratch("family");
    let g = geom();
    let mut rng = Rng::seeded(703);
    let u = GaugeField::random(&g, &mut rng);
    let b = FermionField::gaussian(&g, &mut rng);
    let ghash = gauge_hash(&u);
    let mut op = NativeMdagM::new(&g, u.clone(), 0.12f32);
    let mut x = FermionField::zeros(&g);
    let mut ckpt = Checkpointer::new(opts(&dir, 5, 2, false), 0, 1, ghash).unwrap();
    solver::cg_guarded_ckpt(
        &mut op, &mut x, &b, 1e-8, 500, &HealthConfig::default(),
        Some(&mut ckpt), None,
    )
    .unwrap();
    let (st, _) = load_latest(&dir, 0, 1, ghash).unwrap();

    let mut meo = NativeMeo::new(&g, u, 0.12f32);
    let mut x2 = FermionField::zeros(&g);
    let e = solver::bicgstab_guarded_ckpt(
        &mut meo, &mut x2, &b, 1e-7, 300, &HealthConfig::default(),
        None, Some(&st),
    )
    .expect_err("cg state fed to bicgstab");
    assert!(matches!(e.kind, SolveErrorKind::Checkpoint(_)), "{e}");
}

// ---------------------------------------------------------------------------
// bitwise resume pins, one per solver family
// ---------------------------------------------------------------------------

/// Runs `solve(maxiter, ckpt, resume)` three ways — uninterrupted,
/// interrupted at `cap` iterations with a checkpoint sink, resumed from
/// the latest generation — and returns (full stats+iterate, resumed
/// stats+iterate, checkpoint iteration).
fn interrupt_and_resume<S>(
    dir: &PathBuf,
    ghash: u64,
    every: u64,
    cap: usize,
    maxiter: usize,
    mut solve: S,
) -> (solver::SolveStats, FermionField<f32>, solver::SolveStats, FermionField<f32>, u64)
where
    S: FnMut(
        usize,
        Option<&mut Checkpointer>,
        Option<&SolverState>,
    ) -> (solver::SolveStats, FermionField<f32>),
{
    let (full, x_full) = solve(maxiter, None, None);
    assert!(full.converged, "reference run must converge: {full:?}");
    assert!(full.iterations > cap, "cap {cap} must interrupt the solve");

    let mut ckpt = Checkpointer::new(
        CkptOpts { dir: dir.clone(), every_iters: every, every_ms: 0, keep: 4, buddy: false },
        0, 1, ghash,
    )
    .unwrap();
    let (part, _) = solve(cap, Some(&mut ckpt), None);
    assert!(!part.converged, "interrupted run must stop early");
    assert!(ckpt.committed() >= 1, "no generation committed before the cap");

    let (st, _) = load_latest(dir, 0, 1, ghash).expect("latest generation");
    let at = st.iteration;
    assert!(at > 0 && (at as usize) < full.iterations);
    let (resumed, x_resumed) = solve(maxiter, None, Some(&st));
    (full, x_full, resumed, x_resumed, at)
}

#[test]
fn cg_resume_bitwise_identical() {
    let dir = scratch("cg");
    let g = geom();
    let mut rng = Rng::seeded(705);
    let u = GaugeField::random(&g, &mut rng);
    let b = FermionField::gaussian(&g, &mut rng);
    let ghash = gauge_hash(&u);
    let (full, x_full, resumed, x_resumed, at) =
        interrupt_and_resume(&dir, ghash, 5, 12, 500, |maxiter, ckpt, resume| {
            let mut op = NativeMdagM::new(&g, u.clone(), 0.12f32);
            let mut x = FermionField::zeros(&g);
            let stats = solver::cg_guarded_ckpt(
                &mut op, &mut x, &b, 1e-8, maxiter, &HealthConfig::default(),
                ckpt, resume,
            )
            .expect("clean solve");
            (stats, x)
        });
    assert!(resumed.converged);
    assert_eq!(resumed.iterations, full.iterations);
    assert_eq!(
        resumed.history, full.history,
        "cg history diverged after resume from iteration {at}"
    );
    assert_eq!(x_resumed.data, x_full.data, "cg iterate diverged");
}

#[test]
fn bicgstab_resume_bitwise_identical() {
    let dir = scratch("bicgstab");
    let g = geom();
    let mut rng = Rng::seeded(707);
    let u = GaugeField::random(&g, &mut rng);
    let b = FermionField::gaussian(&g, &mut rng);
    let ghash = gauge_hash(&u);
    let (full, x_full, resumed, x_resumed, at) =
        interrupt_and_resume(&dir, ghash, 5, 12, 300, |maxiter, ckpt, resume| {
            let mut op = NativeMeo::new(&g, u.clone(), 0.12f32);
            let mut x = FermionField::zeros(&g);
            let stats = solver::bicgstab_guarded_ckpt(
                &mut op, &mut x, &b, 1e-6, maxiter, &HealthConfig::default(),
                ckpt, resume,
            )
            .expect("clean solve");
            (stats, x)
        });
    assert!(resumed.converged);
    assert_eq!(
        resumed.history, full.history,
        "bicgstab history diverged after resume from iteration {at}"
    );
    assert_eq!(x_resumed.data, x_full.data, "bicgstab iterate diverged");
}

#[test]
fn fused_cg_resume_bitwise_identical() {
    let dir = scratch("fused-cg");
    let g = geom();
    let mut rng = Rng::seeded(709);
    let u = GaugeField::random(&g, &mut rng);
    let b = FermionField::gaussian(&g, &mut rng);
    let ghash = gauge_hash(&u);
    let (full, x_full, resumed, x_resumed, at) =
        interrupt_and_resume(&dir, ghash, 5, 12, 500, |maxiter, ckpt, resume| {
            let mut op = NativeMdagM::new(&g, u.clone(), 0.12f32);
            let mut team = Team::new(2, BarrierKind::Sleep);
            let mut x = FermionField::zeros(&g);
            let stats = solver::fused::cg_guarded_ckpt(
                &mut op, &mut team, &mut x, &b, 1e-8, maxiter, None,
                &HealthConfig::default(), ckpt, resume,
            )
            .expect("clean solve");
            (stats, x)
        });
    assert!(resumed.converged);
    assert_eq!(
        resumed.history, full.history,
        "fused cg history diverged after resume from iteration {at}"
    );
    assert_eq!(x_resumed.data, x_full.data, "fused cg iterate diverged");
}

#[test]
fn fused_bicgstab_resume_bitwise_identical() {
    let dir = scratch("fused-bicgstab");
    let g = geom();
    let mut rng = Rng::seeded(711);
    let u = GaugeField::random(&g, &mut rng);
    let b = FermionField::gaussian(&g, &mut rng);
    let ghash = gauge_hash(&u);
    let (full, x_full, resumed, x_resumed, at) =
        interrupt_and_resume(&dir, ghash, 5, 12, 300, |maxiter, ckpt, resume| {
            let mut op = NativeMeo::new(&g, u.clone(), 0.12f32);
            let mut team = Team::new(2, BarrierKind::Sleep);
            let mut x = FermionField::zeros(&g);
            let stats = solver::fused::bicgstab_guarded_ckpt(
                &mut op, &mut team, &mut x, &b, 1e-6, maxiter, None,
                &HealthConfig::default(), ckpt, resume,
            )
            .expect("clean solve");
            (stats, x)
        });
    assert!(resumed.converged);
    assert_eq!(
        resumed.history, full.history,
        "fused bicgstab history diverged after resume from iteration {at}"
    );
    assert_eq!(x_resumed.data, x_full.data, "fused bicgstab iterate diverged");
}

#[test]
fn mixed_resume_bitwise_identical() {
    let dir = scratch("mixed");
    let g = geom();
    let mut rng = Rng::seeded(713);
    let u = GaugeField::<f64>::random(&g, &mut rng);
    let b = FermionField::<f64>::gaussian(&g, &mut rng);
    let ghash = gauge_hash(&u);
    let kappa = 0.12f64;
    let mut run = |max_outer: usize,
                   ckpt: Option<&mut Checkpointer>,
                   resume: Option<&SolverState>| {
        let mut outer = NativeMeo::new(&g, u.clone(), kappa);
        let mut inner = NativeMeo::new(&g, u.to_precision::<f32>(), kappa as f32);
        let mut team = Team::new(2, BarrierKind::Sleep);
        let mut x = FermionField::<f64>::zeros(&g);
        let stats = solver::mixed_refinement_team_profiled_ckpt(
            &mut outer, &mut inner, &mut x, &b, 1e-11, max_outer, 1e-2, 200,
            InnerAlgorithm::BiCgStab, &mut team, None, ckpt, resume,
        );
        (stats, x)
    };

    let (full, x_full) = run(40, None, None);
    assert!(full.converged, "{full:?}");
    assert!(full.outer_iterations > 2);

    let mut ckpt = Checkpointer::new(opts(&dir, 1, 4, false), 0, 1, ghash).unwrap();
    let (part, _) = run(2, Some(&mut ckpt), None);
    assert!(!part.converged);
    assert!(ckpt.committed() >= 1);

    let (st, _) = load_latest(&dir, 0, 1, ghash).unwrap();
    assert!(st.iteration > 0);
    let (resumed, x_resumed) = run(40, None, Some(&st));
    assert!(resumed.converged);
    assert_eq!(resumed.outer_iterations, full.outer_iterations);
    assert_eq!(resumed.history, full.history, "mixed outer history diverged");
    assert_eq!(
        resumed.inner_histories, full.inner_histories,
        "mixed inner histories diverged"
    );
    assert_eq!(x_resumed.data, x_full.data, "mixed iterate diverged");
}

#[test]
fn block_cg_resume_bitwise_identical() {
    let dir = scratch("block-cg");
    let g = geom();
    let nrhs = 3;
    let mut rng = Rng::seeded(715);
    let u = GaugeField::random(&g, &mut rng);
    let bs: Vec<FermionField<f32>> =
        (0..nrhs).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
    let b = MultiFermionField::from_rhs(&bs);
    let ghash = gauge_hash(&u);
    let mut run = |maxiter: usize,
                   ckpt: Option<&mut Checkpointer>,
                   resume: Option<&SolverState>| {
        let mut op = MultiMdagM::new(&g, u.clone(), 0.12f32, nrhs);
        let mut team = Team::new(1, BarrierKind::Sleep);
        let mut x = MultiFermionField::<f32>::zeros(&g, nrhs);
        let stats = solver::block_cg_generic_guarded_ckpt(
            &mut op, &mut team, &mut x, &b, 1e-5, maxiter,
            &HealthConfig::default(), None, ckpt, resume,
        )
        .expect("clean solve");
        (stats, x)
    };

    let (full, x_full) = run(300, None, None);
    assert!(full.converged, "{full:?}");
    assert!(full.iterations > 12);

    let mut ckpt = Checkpointer::new(opts(&dir, 5, 4, false), 0, 1, ghash).unwrap();
    let (part, _) = run(12, Some(&mut ckpt), None);
    assert!(!part.converged);
    assert!(ckpt.committed() >= 1);

    let (st, _) = load_latest(&dir, 0, 1, ghash).unwrap();
    let (resumed, x_resumed) = run(300, None, Some(&st));
    assert!(resumed.converged);
    assert_eq!(resumed.iterations, full.iterations);
    for r in 0..nrhs {
        assert_eq!(
            resumed.per_rhs[r].history, full.per_rhs[r].history,
            "block cg rhs {r} history diverged after resume from iteration {}",
            st.iteration
        );
        assert_eq!(resumed.per_rhs[r].converged, full.per_rhs[r].converged);
    }
    assert_eq!(x_resumed.data, x_full.data, "block cg iterate diverged");
}

#[test]
fn block_bicgstab_resume_bitwise_identical() {
    let dir = scratch("block-bicgstab");
    let g = geom();
    let nrhs = 3;
    let mut rng = Rng::seeded(717);
    let u = GaugeField::random(&g, &mut rng);
    let bs: Vec<FermionField<f32>> =
        (0..nrhs).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
    let b = MultiFermionField::from_rhs(&bs);
    let ghash = gauge_hash(&u);
    let mut run = |maxiter: usize,
                   ckpt: Option<&mut Checkpointer>,
                   resume: Option<&SolverState>| {
        let mut op = MultiNativeMeo::new(&g, u.clone(), 0.12f32, nrhs);
        let mut team = Team::new(1, BarrierKind::Sleep);
        let mut x = MultiFermionField::<f32>::zeros(&g, nrhs);
        let stats = solver::block_bicgstab_generic_guarded_ckpt(
            &mut op, &mut team, &mut x, &b, 1e-5, maxiter,
            &HealthConfig::default(), None, ckpt, resume,
        )
        .expect("clean solve");
        (stats, x)
    };

    let (full, x_full) = run(300, None, None);
    assert!(full.converged, "{full:?}");
    assert!(full.iterations > 12);

    let mut ckpt = Checkpointer::new(opts(&dir, 5, 4, false), 0, 1, ghash).unwrap();
    let (part, _) = run(12, Some(&mut ckpt), None);
    assert!(!part.converged);
    assert!(ckpt.committed() >= 1);

    let (st, _) = load_latest(&dir, 0, 1, ghash).unwrap();
    let (resumed, x_resumed) = run(300, None, Some(&st));
    assert!(resumed.converged);
    assert_eq!(resumed.iterations, full.iterations);
    for r in 0..nrhs {
        assert_eq!(
            resumed.per_rhs[r].history, full.per_rhs[r].history,
            "block bicgstab rhs {r} history diverged after resume from iteration {}",
            st.iteration
        );
    }
    assert_eq!(x_resumed.data, x_full.data, "block bicgstab iterate diverged");
}

// ---------------------------------------------------------------------------
// 2-rank distributed: collective generations, buddy restore, bitwise resume
// ---------------------------------------------------------------------------

#[test]
fn two_rank_resume_and_buddy_restore() {
    let dir = scratch("dist");
    let grid = ProcGrid([1, 1, 1, 2]);
    let nrhs = 2;
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(719);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let bs_global: Vec<FermionField> =
        (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let world = || WorldOpts {
        timeout_ms: 30_000,
        max_retries: 3,
        faults: FaultPlan::none(),
    };

    // ckpt: None = no sink, Some(cap) = checkpoint with maxiter capped;
    // resume loads the last globally-consistent generation per rank.
    let run = |ckpt_cap: Option<usize>, resume: bool| {
        run_world_cfg(grid.size(), world(), |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let u = extract_gauge(&u_global, &lgeom);
            let ghash = gauge_hash(&u);
            let bs: Vec<FermionField> = bs_global
                .iter()
                .map(|b| extract_fermion(b, &ggeom, &lgeom))
                .collect();
            let b = MultiFermionField::from_rhs(&bs);
            let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
            let mut team = Team::new(1, BarrierKind::Sleep);
            let prof = Profiler::new(1);
            let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
            let mut op =
                DistMultiMeo::new(&lgeom, &dist, &u, 0.12f32, nrhs, comm, &prof).unwrap();
            let mut ckpt = ckpt_cap.map(|_| {
                Checkpointer::new(opts(&dir, 4, 4, true), rank, 2, ghash).unwrap()
            });
            let st = resume.then(|| {
                let (st, gen) = load_latest(&dir, rank, 2, ghash).expect("resume state");
                (st, gen)
            });
            let maxiter = ckpt_cap.unwrap_or(80);
            let stats = solver::block_bicgstab_generic_guarded_ckpt(
                &mut op, &mut team, &mut x, &b, 1e-5, maxiter,
                &HealthConfig::default(), None,
                ckpt.as_mut(), st.as_ref().map(|(s, _)| s),
            )
            .expect("solve");
            let buddy = ckpt.as_mut().and_then(|c| c.take_buddy());
            (stats, ghash, st.map(|(_, g)| g), buddy)
        })
    };

    // reference: the uninterrupted 2-rank run
    let full = run(None, false);
    assert!(full[0].0.converged, "{:?}", full[0].0);

    // interrupted checkpointed run: stops at 10 iterations with
    // generations committed at iterations 4 and 8 on both ranks
    let part = run(Some(10), false);
    assert!(!part[0].0.converged);
    for rank in 0..2 {
        assert_eq!(committed_generations(&dir, rank), vec![0, 1], "rank {rank}");
    }

    // buddy copies crossed the ring: each rank carried its neighbor's
    // newest generation out of the world, bitwise the on-disk file
    let b0 = part[0].3.clone().expect("rank 0 buddy");
    let b1 = part[1].3.clone().expect("rank 1 buddy");
    assert_eq!(b0.owner, 1);
    assert_eq!(b1.owner, 0);
    assert_eq!(b0.gen, 1);
    assert_eq!(b1.gen, 1);
    assert_eq!(b0.bytes, fs::read(ckpt_path(&dir, 1, 1)).unwrap());
    assert_eq!(b1.bytes, fs::read(ckpt_path(&dir, 0, 1)).unwrap());

    // simulate losing rank 1's local storage entirely
    for gen in committed_generations(&dir, 1) {
        fs::remove_file(ckpt_path(&dir, 1, gen)).unwrap();
        fs::remove_file(commit_path(&dir, 1, gen)).unwrap();
    }
    let ghash1 = part[1].1;
    assert!(load_latest(&dir, 1, 2, ghash1).is_err(), "rank 1 must have nothing left");

    // the survivor's buddy copy re-materializes the dead rank's
    // checkpoint; afterwards both ranks agree on generation 1
    restore_from_buddy(&dir, &b0).unwrap();
    let (st1, gen1) = load_latest(&dir, 1, 2, ghash1).unwrap();
    assert_eq!(gen1, 1);
    assert_eq!(st1.iteration, 8);

    // resume: both ranks load the last generation committed by all and
    // continue bitwise identically to the uninterrupted run
    let resumed = run(None, true);
    for rank in 0..2 {
        assert_eq!(resumed[rank].2, Some(1), "rank {rank} resumed generation");
        let (rs, fs_) = (&resumed[rank].0, &full[rank].0);
        assert!(rs.converged, "rank {rank}");
        assert_eq!(rs.iterations, fs_.iterations, "rank {rank}");
        for r in 0..nrhs {
            assert_eq!(
                rs.per_rhs[r].history, fs_.per_rhs[r].history,
                "rank {rank} rhs {r}: resumed history diverged from the \
                 uninterrupted run"
            );
        }
    }
}

/// Buddy transport helpers: the f64 bit-packing used to ship checkpoint
/// images over `Comm` must round-trip raw bytes exactly.
#[test]
fn buddy_copy_roundtrip_via_restore() {
    let dir = scratch("buddy-rt");
    fs::create_dir_all(&dir).unwrap();
    let copy = BuddyCopy { owner: 3, gen: 7, bytes: vec![1, 2, 3, 250, 251, 252] };
    restore_from_buddy(&dir, &copy).unwrap();
    assert_eq!(fs::read(ckpt_path(&dir, 3, 7)).unwrap(), copy.bytes);
    assert_eq!(committed_generations(&dir, 3), vec![7]);
}
