//! Telemetry integration tests: the span tracer attached to real solves.
//!
//! The contract under test is two-sided. Disabled telemetry must be a
//! bitwise no-op — every solver family produces residual histories
//! identical to its untraced run, because the tracer only ever brackets
//! existing phase scopes with clock reads. Enabled telemetry must
//! actually observe the run: per-rank×thread spans for the solver
//! phases and transport events, a Perfetto-loadable Chrome trace, and a
//! slowdown detector that pins an injected stall to the iteration it
//! fired at.

use std::sync::Arc;

use lqcd::comm::decompose::{extract_fermion, extract_gauge};
use lqcd::comm::{run_world_cfg, FaultPlan, WorldOpts};
use lqcd::coordinator::operator::{
    DistMultiMeo, MultiNativeMeo, NativeMdagM, NativeMeo,
};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::field::{FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{Geometry, LatticeDims, ProcGrid, Tiling};
use lqcd::perf::{detect_slowdowns, SlowdownConfig, TraceData, Tracer};
use lqcd::solver::{self, HealthConfig, InnerAlgorithm};
use lqcd::util::json::Json;
use lqcd::util::rng::Rng;

const KAPPA: f32 = 0.12;
const TOL: f64 = 1e-4;
const MAXITER: usize = 40;
const THREADS: usize = 2;

fn single_rank() -> (Geometry, GaugeField, FermionField) {
    let dims = LatticeDims::new(8, 4, 4, 4).unwrap();
    let geom = Geometry::single_rank(dims, Tiling::new(2, 2).unwrap()).unwrap();
    let mut rng = Rng::seeded(37);
    let u: GaugeField = GaugeField::random(&geom, &mut rng);
    let b: FermionField = FermionField::gaussian(&geom, &mut rng);
    (geom, u, b)
}

/// Traced profiler for `THREADS` workers on rank 0, plus its tracer.
fn traced_profiler() -> (Arc<Tracer>, Profiler) {
    let tracer = Arc::new(Tracer::new(THREADS, 65_536, 0));
    let prof = Profiler::with_tracer(THREADS, tracer.clone());
    (tracer, prof)
}

/// Fused single-RHS BiCGStab: history with `prof` attached.
fn fused_bicgstab_history(prof: Option<&Profiler>) -> Vec<f64> {
    let (geom, u, b) = single_rank();
    let mut team = Team::new(THREADS, BarrierKind::Sleep);
    let mut op = NativeMeo::new(&geom, u, KAPPA);
    let mut x = FermionField::zeros(&geom);
    solver::fused::bicgstab_profiled(&mut op, &mut team, &mut x, &b, TOL, MAXITER, prof)
        .history
}

/// Fused single-RHS CGNR on the normal operator.
fn fused_cg_history(prof: Option<&Profiler>) -> Vec<f64> {
    let (geom, u, b) = single_rank();
    let mut team = Team::new(THREADS, BarrierKind::Sleep);
    let mut op = NativeMdagM::new(&geom, u, KAPPA);
    let mut bp = b.clone();
    bp.gamma5();
    let mut mbp = FermionField::zeros(&geom);
    op.meo().apply(&mut mbp, &bp);
    mbp.gamma5();
    let mut x = FermionField::zeros(&geom);
    solver::fused::cg_profiled(&mut op, &mut team, &mut x, &mbp, TOL, MAXITER, prof)
        .history
}

/// Native block BiCGStab (nrhs = 2): per-RHS histories.
fn block_bicgstab_histories(prof: Option<&Profiler>) -> Vec<Vec<f64>> {
    let (geom, u, b0) = single_rank();
    let mut rng = Rng::seeded(38);
    let b1: FermionField = FermionField::gaussian(&geom, &mut rng);
    let b = MultiFermionField::from_rhs(&[b0, b1]);
    let mut team = Team::new(THREADS, BarrierKind::Sleep);
    let mut op = MultiNativeMeo::new(&geom, u, KAPPA, 2);
    let mut x = MultiFermionField::<f32>::zeros(&geom, 2);
    let stats =
        solver::block_bicgstab_profiled(&mut op, &mut team, &mut x, &b, TOL, MAXITER, prof);
    stats.per_rhs.into_iter().map(|s| s.history).collect()
}

/// Mixed-precision refinement (f64 outer, f32 inner CG).
fn mixed_history(prof: Option<&Profiler>) -> Vec<f64> {
    let dims = LatticeDims::new(8, 4, 4, 4).unwrap();
    let geom = Geometry::single_rank(dims, Tiling::new(2, 2).unwrap()).unwrap();
    let mut rng = Rng::seeded(37);
    let u: GaugeField<f64> = GaugeField::random(&geom, &mut rng);
    let b: FermionField<f64> = FermionField::gaussian(&geom, &mut rng);
    let u32f = u.to_precision::<f32>();
    let mut outer = NativeMdagM::new(&geom, u, KAPPA as f64);
    let mut inner = NativeMdagM::new(&geom, u32f, KAPPA);
    let mut bp = b.clone();
    bp.gamma5();
    let mut mbp = FermionField::zeros(&geom);
    outer.meo().apply(&mut mbp, &bp);
    mbp.gamma5();
    let mut team = Team::new(THREADS, BarrierKind::Sleep);
    let mut x = FermionField::<f64>::zeros(&geom);
    solver::mixed_refinement_team_profiled(
        &mut outer,
        &mut inner,
        &mut x,
        &mbp,
        1e-8,
        20,
        1e-4,
        MAXITER,
        InnerAlgorithm::Cg,
        &mut team,
        prof,
    )
    .history
}

/// Disabled telemetry is a bitwise no-op on every single-rank solver
/// family: the traced run's residual history equals the untraced run's
/// exactly, and the traced run really did record spans.
#[test]
fn tracing_is_bitwise_noop_single_rank_families() {
    // fused BiCGStab
    let base = fused_bicgstab_history(None);
    let (tracer, prof) = traced_profiler();
    let traced = fused_bicgstab_history(Some(&prof));
    assert!(!base.is_empty());
    assert_eq!(base, traced, "fused bicgstab history diverged under tracing");
    assert!(!tracer.drain().spans.is_empty(), "fused bicgstab recorded no spans");

    // fused CGNR
    let base = fused_cg_history(None);
    let (tracer, prof) = traced_profiler();
    let traced = fused_cg_history(Some(&prof));
    assert!(!base.is_empty());
    assert_eq!(base, traced, "fused cg history diverged under tracing");
    assert!(!tracer.drain().spans.is_empty(), "fused cg recorded no spans");

    // native block BiCGStab
    let base = block_bicgstab_histories(None);
    let (tracer, prof) = traced_profiler();
    let traced = block_bicgstab_histories(Some(&prof));
    for (r, (b, t)) in base.iter().zip(&traced).enumerate() {
        assert!(!b.is_empty());
        assert_eq!(b, t, "block bicgstab rhs {r} history diverged under tracing");
    }
    assert!(!tracer.drain().spans.is_empty(), "block solver recorded no spans");

    // mixed refinement
    let base = mixed_history(None);
    let (tracer, prof) = traced_profiler();
    let traced = mixed_history(Some(&prof));
    assert!(!base.is_empty());
    assert_eq!(base, traced, "mixed history diverged under tracing");
    assert!(!tracer.drain().spans.is_empty(), "mixed solve recorded no spans");
}

/// One traced 2-rank distributed guarded solve; returns per-rank
/// (per-RHS histories, drained trace).
fn traced_distributed(
    spec: &str,
    tol: f64,
    maxiter: usize,
) -> Vec<(Vec<Vec<f64>>, TraceData)> {
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(91);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let bs_global: Vec<FermionField> =
        (0..2).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let grid = ProcGrid([1, 1, 1, 2]);
    let opts = WorldOpts {
        timeout_ms: 30_000,
        max_retries: 3,
        faults: FaultPlan::parse(spec).unwrap(),
    };
    run_world_cfg(grid.size(), opts, |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let bs: Vec<FermionField> = bs_global
            .iter()
            .map(|b| extract_fermion(b, &ggeom, &lgeom))
            .collect();
        let b = MultiFermionField::from_rhs(&bs);
        let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
        let mut team = Team::new(1, BarrierKind::Sleep);
        let tracer = Arc::new(Tracer::new(1, 65_536, rank));
        let prof = Profiler::with_tracer(1, tracer.clone());
        comm.set_tracer(tracer.clone());
        let mut x = MultiFermionField::<f32>::zeros(&lgeom, 2);
        let mut op =
            DistMultiMeo::new(&lgeom, &dist, &u, KAPPA, 2, comm, &prof).unwrap();
        let stats = solver::block_bicgstab_generic_guarded_profiled(
            &mut op,
            &mut team,
            &mut x,
            &b,
            tol,
            maxiter,
            &HealthConfig::default(),
            Some(&prof),
        )
        .unwrap_or_else(|e| panic!("rank {rank}: {e}"));
        let histories = stats.per_rhs.iter().map(|s| s.history.clone()).collect();
        (histories, tracer.drain())
    })
}

/// Tracing the distributed solve (operator phases, transport events AND
/// the in-solver BLAS sweeps) must not perturb the numerics, and the
/// merged world trace must carry spans from every rank.
#[test]
fn traced_distributed_matches_untraced_and_covers_ranks() {
    let base = traced_distributed("", TOL, MAXITER);
    // untraced reference via the plain guarded entry point
    let global = LatticeDims::new(8, 4, 4, 8).unwrap();
    let tiling = Tiling::new(2, 2).unwrap();
    let ggeom = Geometry::single_rank(global, tiling).unwrap();
    let mut rng = Rng::seeded(91);
    let u_global: GaugeField = GaugeField::random(&ggeom, &mut rng);
    let bs_global: Vec<FermionField> =
        (0..2).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let grid = ProcGrid([1, 1, 1, 2]);
    let untraced = run_world_cfg(grid.size(), WorldOpts::default(), |rank, comm| {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        let u = extract_gauge(&u_global, &lgeom);
        let bs: Vec<FermionField> = bs_global
            .iter()
            .map(|b| extract_fermion(b, &ggeom, &lgeom))
            .collect();
        let b = MultiFermionField::from_rhs(&bs);
        let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
        let mut team = Team::new(1, BarrierKind::Sleep);
        let prof = Profiler::new(1);
        let mut x = MultiFermionField::<f32>::zeros(&lgeom, 2);
        let mut op =
            DistMultiMeo::new(&lgeom, &dist, &u, KAPPA, 2, comm, &prof).unwrap();
        let stats = solver::block_bicgstab_generic_guarded(
            &mut op, &mut team, &mut x, &b, TOL, MAXITER,
            &HealthConfig::default(),
        )
        .unwrap_or_else(|e| panic!("rank {rank}: {e}"));
        stats.per_rhs.iter().map(|s| s.history.clone()).collect::<Vec<_>>()
    });
    for (rank, ((traced, _), plain)) in base.iter().zip(&untraced).enumerate() {
        for (r, (t, p)) in traced.iter().zip(plain).enumerate() {
            assert!(!p.is_empty());
            assert_eq!(t, p, "rank {rank} rhs {r}: tracing perturbed the solve");
        }
    }
    let data = TraceData::merge(base.into_iter().map(|(_, t)| t).collect());
    assert_eq!(data.dropped, 0, "rings overflowed on a smoke-sized solve");
    for rank in 0..2u32 {
        assert!(
            data.spans.iter().any(|s| s.rank == rank),
            "no spans from rank {rank}"
        );
    }
    // operator phases and transport sends are both on the trace
    for code in [0u8, 1, 2, 3, 16] {
        assert!(
            data.spans.iter().any(|s| s.code == code),
            "span code {code} missing from the world trace"
        );
    }
    // the Chrome trace is well-formed JSON with one event per span
    let doc = Json::parse(&data.chrome_trace_json()).expect("trace.json parses");
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert_eq!(events.len(), data.spans.len());
    let first = &events[0];
    for key in ["name", "ph", "ts", "dur", "pid", "tid"] {
        assert!(first.get(key).is_some(), "trace event missing {key:?}");
    }
}

/// An injected rank stall must surface as a flagged comm-wait/barrier
/// outlier at the iteration the stall fired — the waiting peer sees a
/// 60 ms spike against a microsecond-scale trailing window.
#[test]
fn injected_stall_flagged_at_correct_iteration() {
    // tol below the f32 floor + hard maxiter = exactly 20 iterations,
    // so the stall at iteration 12 always fires and the detector has a
    // full trailing window (8) of clean samples in front of it
    let results = traced_distributed("stall:rank=1,iter=12,ms=60", 1e-12, 20);
    let data = TraceData::merge(results.into_iter().map(|(_, t)| t).collect());
    let slow = detect_slowdowns(&data.spans, &SlowdownConfig::default());
    assert!(
        slow.iter().any(|s| s.iter == 12 && (s.code == 2 || s.code == 4)),
        "stall at iteration 12 not flagged; flagged = {:?}",
        slow.iter().map(|s| (s.rank, s.code, s.iter)).collect::<Vec<_>>()
    );
    let hit = slow
        .iter()
        .find(|s| s.iter == 12 && (s.code == 2 || s.code == 4))
        .unwrap();
    assert!(
        hit.seconds > 0.04,
        "flagged outlier should carry the ~60 ms stall, got {}s",
        hit.seconds
    );
    assert!(
        hit.seconds > hit.median * SlowdownConfig::default().factor,
        "flagged sample does not clear the median guard"
    );
}
