//! Cross-kernel equivalence: the vectorized shuffle kernel, the gather
//! variant, and the scalar baseline must agree exactly on the same
//! operator, for every tiling, parity and a sweep of lattice shapes.

use lqcd::dslash::{HoppingEo, HoppingGather, HoppingScalar};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, Tiling};
use lqcd::util::prop::Runner;
use lqcd::util::rng::Rng;

fn rel_diff(a: &FermionField, b: &FermionField) -> f64 {
    let mut d = a.clone();
    d.axpy(-1.0, b);
    (d.norm2() / a.norm2().max(1e-30)).sqrt()
}

fn check_geom(geom: Geometry, seed: u64, p_out: Parity) {
    let mut rng = Rng::seeded(seed);
    let u: GaugeField = GaugeField::random(&geom, &mut rng);
    let psi: FermionField = FermionField::gaussian(&geom, &mut rng);

    let mut out_vec = FermionField::zeros(&geom);
    HoppingEo::new(&geom).apply(&mut out_vec, &u, &psi, p_out);

    let mut out_scalar = FermionField::zeros(&geom);
    HoppingScalar::new(&geom).apply(&mut out_scalar, &u, &psi, p_out);

    let d = rel_diff(&out_scalar, &out_vec);
    assert!(d < 1e-5, "vectorized vs scalar rel diff {d} ({geom:?})");

    let mut out_gather = FermionField::zeros(&geom);
    HoppingGather::new(&geom).apply(&mut out_gather, &u, &psi, p_out);
    let d = rel_diff(&out_scalar, &out_gather);
    assert!(d < 1e-5, "gather vs scalar rel diff {d} ({geom:?})");
}

#[test]
fn all_tilings_4x4x4x4() {
    let dims = LatticeDims::new(4, 4, 4, 4).unwrap();
    // 4^4 has XH = 2, so VLENX = 2 is the only option; sweep VLENY
    for (vx, vy) in [(2, 1), (2, 2), (2, 4)] {
        let geom = Geometry::single_rank(dims, Tiling::new(vx, vy).unwrap()).unwrap();
        for p in Parity::BOTH {
            check_geom(geom, 1000 + vx as u64 * 10 + vy as u64, p);
        }
    }
}

#[test]
fn paper_tilings_on_16x16_xy_plane() {
    // all four Table 1 tilings (VLEN = 16) on a lattice where they fit
    let dims = LatticeDims::new(32, 16, 2, 2).unwrap();
    for t in Tiling::table1_sweep() {
        let geom = Geometry::single_rank(dims, t).unwrap();
        check_geom(geom, 77, Parity::Odd);
    }
}

#[test]
fn asymmetric_lattices() {
    for (x, y, z, t) in [(8, 2, 2, 4), (4, 8, 4, 2), (12, 4, 2, 8), (4, 6, 8, 2)] {
        let dims = LatticeDims::new(x, y, z, t).unwrap();
        let geom = Geometry::single_rank(dims, Tiling::new(2, 2).unwrap()).unwrap();
        check_geom(geom, (x * 100 + y * 10 + z) as u64, Parity::Even);
    }
}

#[test]
fn property_random_shapes_and_tilings() {
    Runner::new("kernel equivalence", 12).run(|g| {
        let x = 2 * g.usize_in(1, 4);
        let y = 2 * g.usize_in(1, 3);
        let z = 2 * g.usize_in(1, 2);
        let t = 2 * g.usize_in(1, 2);
        let dims = LatticeDims::new(x, y, z, t).unwrap();
        // any tiling that divides (XH, Y)
        let mut choices = Vec::new();
        for vx in [2usize, 4, 8] {
            for vy in [1usize, 2, 4] {
                if dims.xh() % vx == 0 && dims.y % vy == 0 {
                    choices.push((vx, vy));
                }
            }
        }
        if choices.is_empty() {
            return;
        }
        let &(vx, vy) = g.choose(&choices);
        let geom = Geometry::single_rank(dims, Tiling::new(vx, vy).unwrap()).unwrap();
        let p = if g.bool() { Parity::Even } else { Parity::Odd };
        check_geom(geom, g.u64_below(1 << 32), p);
    });
}

#[test]
fn skip_boundary_plus_edges_equals_periodic_minus_interior() {
    // SkipBoundary must zero exactly the boundary-crossing contributions:
    // on a lattice with one rank, periodic == skip + (periodic - skip),
    // and skip must differ from periodic only on edge tiles.
    use lqcd::dslash::WrapMode;
    let dims = LatticeDims::new(8, 4, 4, 4).unwrap();
    let geom = Geometry::single_rank(dims, Tiling::new(2, 2).unwrap()).unwrap();
    let mut rng = Rng::seeded(42);
    let u: GaugeField = GaugeField::random(&geom, &mut rng);
    let psi: FermionField = FermionField::gaussian(&geom, &mut rng);

    let mut periodic = FermionField::zeros(&geom);
    HoppingEo::new(&geom).apply(&mut periodic, &u, &psi, Parity::Odd);

    let mut skipped = FermionField::zeros(&geom);
    HoppingEo::with_wrap(&geom, [WrapMode::SkipBoundary; 4])
        .apply(&mut skipped, &u, &psi, Parity::Odd);

    // the skipped result must never exceed the periodic one in norm and
    // must differ (the boundary terms are missing)
    assert!(skipped.norm2() < periodic.norm2());
    assert!(rel_diff(&periodic, &skipped) > 1e-3);

    // interior sites (no face neighbor) must agree exactly
    let l = skipped.layout;
    for s in l.sites() {
        let xl = l.lexical_x(s, Parity::Odd);
        let interior = xl > 0
            && xl < dims.x - 1
            && s.y > 0
            && s.y < dims.y - 1
            && s.z > 0
            && s.z < dims.z - 1
            && s.t > 0
            && s.t < dims.t - 1;
        if interior {
            let a = periodic.site(s);
            let b = skipped.site(s);
            assert!(a.sub(&b).norm2() < 1e-12, "interior site {s:?} touched");
        }
    }
}
