//! Regenerates **Fig. 10**: weak scaling of the even-odd Wilson multiply
//! to 512 nodes — measured per-rank phases + TofuD model (id F10).

mod common;

fn main() {
    let opts = common::opts(20, 1);
    println!("{}", lqcd::harness::fig10::run(opts).report);
}
