//! Shared bench-option handling: `cargo bench` passes `--bench`; we also
//! honor LQCD_BENCH_QUICK / LQCD_BENCH_ITERS / LQCD_BENCH_THREADS.

use lqcd::harness::Opts;

pub fn opts(default_iters: usize, default_threads: usize) -> Opts {
    let quick = std::env::var("LQCD_BENCH_QUICK").is_ok();
    let iters = std::env::var("LQCD_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { default_iters / 5 + 1 } else { default_iters });
    let threads = std::env::var("LQCD_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_threads);
    Opts {
        iters,
        threads,
        quick,
    }
}
