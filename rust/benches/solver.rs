//! Solver benchmark: CGNR vs BiCGStab on the even-odd preconditioned
//! system — iterations, operator applications, and sustained GFlops.

mod common;

use lqcd::coordinator::operator::NativeMdagM;
use lqcd::coordinator::operator::{LinearOperator, NativeMeo};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Tiling};
use lqcd::solver;
use lqcd::util::rng::Rng;
use lqcd::util::tables::Table;
use lqcd::util::timer::Stopwatch;

fn main() {
    let opts = common::opts(1, 1);
    let dims = if opts.quick {
        LatticeDims::new(8, 8, 4, 4).unwrap()
    } else {
        LatticeDims::new(8, 8, 8, 16).unwrap()
    };
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap()).unwrap();
    let mut rng = Rng::seeded(9001);
    let u = GaugeField::random(&geom, &mut rng);
    let b = FermionField::gaussian(&geom, &mut rng);
    let kappa = 0.13f32;
    let tol = 1e-8;

    let mut table = Table::new(
        &format!("Solver comparison on {dims} (kappa = {kappa}, tol = {tol:.0e})"),
        &["solver", "iterations", "GFlops", "seconds", "true residual"],
    );

    // BiCGStab on M-hat
    {
        let mut op = NativeMeo::new(&geom, u.clone(), kappa);
        let mut x = FermionField::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::bicgstab(&mut op, &mut x, &b, tol, 1000);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &b);
        table.row(vec![
            "bicgstab(M)".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        assert!(stats.converged);
    }

    // CGNR on M^dag M
    {
        let mut op = NativeMdagM::new(&geom, u, kappa);
        let mut bp = b.clone();
        bp.gamma5();
        let mut mbp = FermionField::zeros(&geom);
        op.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
        let mut x = FermionField::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::cg(&mut op, &mut x, &mbp, tol, 1000);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &mbp);
        table.row(vec![
            "cgnr(MdagM)".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        assert!(stats.converged);
    }

    println!("{}", table.render());
}
