//! Solver benchmark: CGNR vs BiCGStab on the even-odd preconditioned
//! system, across precisions — f32 (paper hot path), mixed-precision
//! iterative refinement (f64 outer / f32 inner), and f64 reference.
//!
//! Besides the human-readable table, the bench emits a JSON report with
//! per-precision iteration counts and residual histories (default
//! `solver_bench.json`, override with `LQCD_BENCH_JSON=path` or disable
//! with `LQCD_BENCH_JSON=-`) so future PRs can track the f32 / mixed /
//! f64 trade-off quantitatively.

mod common;

use lqcd::coordinator::operator::NativeMdagM;
use lqcd::coordinator::operator::{LinearOperator, NativeMeo};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, LatticeDims, Tiling};
use lqcd::solver::{self, InnerAlgorithm};
use lqcd::util::rng::Rng;
use lqcd::util::tables::Table;
use lqcd::util::timer::Stopwatch;

/// One benchmark row headed for the JSON report.
struct Run {
    name: &'static str,
    precision: &'static str,
    /// relative-residual target this run solved to
    tol: f64,
    iterations: usize,
    inner_iterations: usize,
    seconds: f64,
    gflops: f64,
    true_residual: f64,
    history: Vec<f64>,
}

/// JSON number, with NaN/inf (e.g. from a solver breakdown) mapped to
/// null so the report stays parseable exactly when a run went wrong.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6e}")
    } else {
        "null".to_string()
    }
}

fn json_escape_history(h: &[f64]) -> String {
    let items: Vec<String> = h.iter().map(|&v| json_f64(v)).collect();
    format!("[{}]", items.join(", "))
}

fn emit_json(dims: &str, kappa: f64, runs: &[Run]) {
    let path = std::env::var("LQCD_BENCH_JSON")
        .unwrap_or_else(|_| "solver_bench.json".to_string());
    if path == "-" {
        return;
    }
    let mut entries = Vec::new();
    for r in runs {
        entries.push(format!(
            "    {{\n      \"solver\": \"{}\",\n      \"precision\": \"{}\",\n      \
             \"tol\": {:.1e},\n      \
             \"iterations\": {},\n      \"inner_iterations\": {},\n      \
             \"seconds\": {:.4},\n      \"gflops\": {:.3},\n      \
             \"true_residual\": {},\n      \"residual_history\": {}\n    }}",
            r.name,
            r.precision,
            r.tol,
            r.iterations,
            r.inner_iterations,
            r.seconds,
            r.gflops,
            json_f64(r.true_residual),
            json_escape_history(&r.history),
        ));
    }
    let doc = format!(
        "{{\n  \"bench\": \"solver\",\n  \"lattice\": \"{dims}\",\n  \
         \"kappa\": {kappa},\n  \"runs\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    match std::fs::write(&path, doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let opts = common::opts(1, 1);
    let dims = if opts.quick {
        LatticeDims::new(8, 8, 4, 4).unwrap()
    } else {
        LatticeDims::new(8, 8, 8, 16).unwrap()
    };
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap()).unwrap();
    let mut rng = Rng::seeded(9001);
    // generate at f64, demote: all precisions see the same configuration
    let u64f: GaugeField<f64> = GaugeField::random(&geom, &mut rng);
    let b64: FermionField<f64> = FermionField::gaussian(&geom, &mut rng);
    let u32f = u64f.to_precision::<f32>();
    let b32 = b64.to_precision::<f32>();
    let kappa = 0.13f64;
    let tol = 1e-8;
    let mut runs: Vec<Run> = Vec::new();

    let mut table = Table::new(
        &format!("Solver comparison on {dims} (kappa = {kappa}, tol = {tol:.0e})"),
        &["solver", "precision", "iters", "GFlops", "seconds", "true residual"],
    );

    // BiCGStab on M-hat, f32
    {
        let mut op = NativeMeo::new(&geom, u32f.clone(), kappa as f32);
        let mut x = FermionField::<f32>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::bicgstab(&mut op, &mut x, &b32, tol, 1000);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &b32);
        table.row(vec![
            "bicgstab(M)".into(),
            "f32".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        if !stats.converged {
            eprintln!("warning: f32 bicgstab stalled at {:.2e}", stats.rel_residual);
        }
        runs.push(Run {
            name: "bicgstab",
            precision: "f32",
            tol,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            true_residual: resid,
            history: stats.history,
        });
    }

    // CGNR on M^dag M, f32
    {
        let mut op = NativeMdagM::new(&geom, u32f.clone(), kappa as f32);
        let mut bp = b32.clone();
        bp.gamma5();
        let mut mbp = FermionField::<f32>::zeros(&geom);
        op.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
        let mut x = FermionField::<f32>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::cg(&mut op, &mut x, &mbp, tol, 1000);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &mbp);
        table.row(vec![
            "cgnr(MdagM)".into(),
            "f32".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        if !stats.converged {
            eprintln!("warning: f32 cgnr stalled at {:.2e}", stats.rel_residual);
        }
        runs.push(Run {
            name: "cgnr",
            precision: "f32",
            tol,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            true_residual: resid,
            history: stats.history,
        });
    }

    // Mixed: f64 outer refinement, f32 inner BiCGStab, to f64 accuracy
    {
        let mut outer = NativeMeo::new(&geom, u64f.clone(), kappa);
        let mut inner = NativeMeo::new(&geom, u32f.clone(), kappa as f32);
        let mut x = FermionField::<f64>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::mixed_refinement(
            &mut outer, &mut inner, &mut x, &b64,
            1e-12, 40, 1e-4, 1000, InnerAlgorithm::BiCgStab,
        );
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut outer, &x, &b64);
        table.row(vec![
            "bicgstab(M) + refine".into(),
            "mixed".into(),
            format!("{}+{}", stats.outer_iterations, stats.inner_iterations),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        assert!(stats.converged);
        runs.push(Run {
            name: "bicgstab+refine",
            precision: "mixed",
            tol: 1e-12,
            iterations: stats.outer_iterations,
            inner_iterations: stats.inner_iterations,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            true_residual: resid,
            history: stats.history,
        });
    }

    // BiCGStab on M-hat, f64 reference (same 1e-12 target as mixed)
    {
        let mut op = NativeMeo::new(&geom, u64f.clone(), kappa);
        let mut x = FermionField::<f64>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::bicgstab(&mut op, &mut x, &b64, 1e-12, 2000);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &b64);
        table.row(vec![
            "bicgstab(M)".into(),
            "f64".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        assert!(stats.converged);
        runs.push(Run {
            name: "bicgstab",
            precision: "f64",
            tol: 1e-12,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            true_residual: resid,
            history: stats.history,
        });
    }

    println!("{}", table.render());
    emit_json(&dims.to_string(), kappa, &runs);
}
