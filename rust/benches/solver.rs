//! Solver benchmark: CGNR vs BiCGStab on the even-odd preconditioned
//! system, across precisions — f32 (paper hot path), mixed-precision
//! iterative refinement (f64 outer / f32 inner), f64 reference — plus
//! the fused thread-parallel pipeline vs the unfused reference on 8⁴
//! (sweeps/iteration, effective bandwidth, and thread scaling).
//!
//! Besides the human-readable tables, the bench emits a JSON report
//! with per-run iteration counts, residual histories, sweeps/iteration
//! and effective bandwidth (default `solver_bench.json`, override with
//! `LQCD_BENCH_JSON=path` or disable with `LQCD_BENCH_JSON=-`) so the
//! perf trajectory of the fused-vs-unfused gain is tracked across PRs.
//!
//! The multi-RHS section sweeps gauge compression × nrhs: the same
//! systems solved with full (18 reals/link) and two-row compressed
//! (12 reals/link) gauge storage, recording `gauge_reals_per_link` and
//! the modeled bytes/site drop in the JSON — compression and multi-RHS
//! amortization compose, and the bench asserts two-row is strictly
//! below full at every nrhs.
//!
//! `cargo bench --bench solver -- --smoke` (or `LQCD_BENCH_SMOKE=1`)
//! runs a seconds-scale variant for CI: same code paths, smaller
//! lattice and iteration caps.

mod common;

use lqcd::comm::decompose::{extract_fermion, extract_gauge};
use lqcd::comm::{netmodel, run_world, HaloPlans};
use lqcd::coordinator::operator::{
    DistMultiMeo, LinearOperator, MultiMdagM, NativeMdagM, NativeMeo, UnfusedMdagM,
};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Team};
use lqcd::dslash::{Compression, Links};
use lqcd::field::{CompressedGaugeField, FermionField, GaugeField, MultiFermionField};
use lqcd::lattice::{Geometry, LatticeDims, Parity, ProcGrid, Tiling};
// byte models shared with `lqcd tune` (identical formulas by construction:
// the tuner fits the roofline the floor below asserts against)
use lqcd::perf::roofline::{block_cg_iter_bytes, bytes_per_site, cg_iter_bytes};
use lqcd::solver::{self, InnerAlgorithm};
use lqcd::util::rng::Rng;
use lqcd::util::tables::Table;
use lqcd::util::timer::Stopwatch;

/// One benchmark row headed for the JSON report.
struct Run {
    name: String,
    precision: &'static str,
    /// relative-residual target this run solved to
    tol: f64,
    /// worker-team threads (1 = serial)
    threads: usize,
    /// right-hand sides solved per batched sweep (1 = single-RHS)
    nrhs: usize,
    /// simulated MPI ranks (1 = single-rank native pipeline)
    ranks: usize,
    /// halo messages one operator application posts per rank (0 for
    /// non-distributed runs); batching makes this independent of nrhs
    messages_per_iter: u64,
    /// wire bytes one operator application moves per rank (model)
    halo_bytes_per_iter: u64,
    iterations: usize,
    inner_iterations: usize,
    seconds: f64,
    gflops: f64,
    /// full-field memory sweeps per iteration
    sweeps_per_iter: f64,
    /// bytes one iteration streams through memory (model, see
    /// [`cg_iter_bytes`] / [`block_cg_iter_bytes`])
    bytes_per_iter: u64,
    /// modeled bytes per site per RHS of one iteration — the gauge
    /// stream is shared across RHS, so this falls as nrhs grows
    bytes_per_site: f64,
    /// reals streamed per gauge link (18 full, 12 two-row compressed) —
    /// makes the perf trajectory self-describing
    gauge_reals_per_link: usize,
    true_residual: f64,
    history: Vec<f64>,
}

/// JSON number, with NaN/inf (e.g. from a solver breakdown) mapped to
/// null so the report stays parseable exactly when a run went wrong.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6e}")
    } else {
        "null".to_string()
    }
}

fn json_escape_history(h: &[f64]) -> String {
    let items: Vec<String> = h.iter().map(|&v| json_f64(v)).collect();
    format!("[{}]", items.join(", "))
}

/// Effective streamed bandwidth of a run, GB/s.
fn eff_bw_gbs(r: &Run) -> f64 {
    if r.seconds > 0.0 {
        r.bytes_per_iter as f64 * r.iterations as f64 / r.seconds / 1e9
    } else {
        0.0
    }
}

fn emit_json(dims: &str, kappa: f64, runs: &[Run]) {
    let path = std::env::var("LQCD_BENCH_JSON")
        .unwrap_or_else(|_| "solver_bench.json".to_string());
    if path == "-" {
        return;
    }
    let mut entries = Vec::new();
    for r in runs {
        entries.push(format!(
            "    {{\n      \"solver\": \"{}\",\n      \"precision\": \"{}\",\n      \
             \"tol\": {:.1e},\n      \"threads\": {},\n      \"nrhs\": {},\n      \
             \"ranks\": {},\n      \"messages_per_iter\": {},\n      \
             \"halo_bytes_per_iter\": {},\n      \
             \"iterations\": {},\n      \"inner_iterations\": {},\n      \
             \"seconds\": {:.4},\n      \"gflops\": {:.3},\n      \
             \"sweeps_per_iter\": {:.1},\n      \"bytes_per_iter\": {},\n      \
             \"bytes_per_site\": {:.3},\n      \
             \"gauge_reals_per_link\": {},\n      \
             \"eff_bw_gbs\": {:.3},\n      \
             \"true_residual\": {},\n      \"residual_history\": {}\n    }}",
            r.name,
            r.precision,
            r.tol,
            r.threads,
            r.nrhs,
            r.ranks,
            r.messages_per_iter,
            r.halo_bytes_per_iter,
            r.iterations,
            r.inner_iterations,
            r.seconds,
            r.gflops,
            r.sweeps_per_iter,
            r.bytes_per_iter,
            r.bytes_per_site,
            r.gauge_reals_per_link,
            eff_bw_gbs(r),
            json_f64(r.true_residual),
            json_escape_history(&r.history),
        ));
    }
    let doc = format!(
        "{{\n  \"bench\": \"solver\",\n  \"lattice\": \"{dims}\",\n  \
         \"kappa\": {kappa},\n  \"runs\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    match std::fs::write(&path, doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let opts = common::opts(1, 1);
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("LQCD_BENCH_SMOKE").is_ok();
    let quick = opts.quick || smoke;
    let dims = if quick {
        LatticeDims::new(8, 8, 4, 4).unwrap()
    } else {
        LatticeDims::new(8, 8, 8, 16).unwrap()
    };
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap()).unwrap();
    let mut rng = Rng::seeded(9001);
    // generate at f64, demote: all precisions see the same configuration
    let u64f: GaugeField<f64> = GaugeField::random(&geom, &mut rng);
    let b64: FermionField<f64> = FermionField::gaussian(&geom, &mut rng);
    let u32f = u64f.to_precision::<f32>();
    let b32 = b64.to_precision::<f32>();
    let kappa = 0.13f64;
    let tol = 1e-8;
    let maxiter = if smoke { 60 } else { 1000 };
    let mut runs: Vec<Run> = Vec::new();

    let mut table = Table::new(
        &format!("Solver comparison on {dims} (kappa = {kappa}, tol = {tol:.0e})"),
        &["solver", "precision", "iters", "GFlops", "seconds", "true residual"],
    );

    // BiCGStab on M-hat, f32
    {
        let mut op = NativeMeo::new(&geom, u32f.clone(), kappa as f32);
        let mut x = FermionField::<f32>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::bicgstab(&mut op, &mut x, &b32, tol, maxiter);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &b32);
        table.row(vec![
            "bicgstab(M)".into(),
            "f32".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        if !stats.converged && !smoke {
            eprintln!("warning: f32 bicgstab stalled at {:.2e}", stats.rel_residual);
        }
        runs.push(Run {
            name: "bicgstab".into(),
            precision: "f32",
            tol,
            threads: 1,
            nrhs: 1,
            ranks: 1,
            messages_per_iter: 0,
            halo_bytes_per_iter: 0,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            sweeps_per_iter: stats.sweeps_per_iter,
            bytes_per_iter: 0,
            bytes_per_site: 0.0,
            gauge_reals_per_link: 18,
            true_residual: resid,
            history: stats.history,
        });
    }

    // CGNR on M^dag M, f32
    {
        let mut op = NativeMdagM::new(&geom, u32f.clone(), kappa as f32);
        let mut bp = b32.clone();
        bp.gamma5();
        let mut mbp = FermionField::<f32>::zeros(&geom);
        op.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
        let mut x = FermionField::<f32>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::cg(&mut op, &mut x, &mbp, tol, maxiter);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &mbp);
        table.row(vec![
            "cgnr(MdagM)".into(),
            "f32".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        if !stats.converged && !smoke {
            eprintln!("warning: f32 cgnr stalled at {:.2e}", stats.rel_residual);
        }
        runs.push(Run {
            name: "cgnr".into(),
            precision: "f32",
            tol,
            threads: 1,
            nrhs: 1,
            ranks: 1,
            messages_per_iter: 0,
            halo_bytes_per_iter: 0,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            sweeps_per_iter: stats.sweeps_per_iter,
            bytes_per_iter: cg_iter_bytes(&geom, 4, false),
            bytes_per_site: bytes_per_site(&geom, cg_iter_bytes(&geom, 4, false), 1),
            gauge_reals_per_link: 18,
            true_residual: resid,
            history: stats.history,
        });
    }

    // Mixed: f64 outer refinement, f32 inner BiCGStab, to f64 accuracy
    {
        let mut outer = NativeMeo::new(&geom, u64f.clone(), kappa);
        let mut inner = NativeMeo::new(&geom, u32f.clone(), kappa as f32);
        let mut x = FermionField::<f64>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::mixed_refinement(
            &mut outer, &mut inner, &mut x, &b64,
            1e-12, 40, 1e-4, maxiter, InnerAlgorithm::BiCgStab,
        );
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut outer, &x, &b64);
        table.row(vec![
            "bicgstab(M) + refine".into(),
            "mixed".into(),
            format!("{}+{}", stats.outer_iterations, stats.inner_iterations),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        assert!(stats.converged || smoke);
        runs.push(Run {
            name: "bicgstab+refine".into(),
            precision: "mixed",
            tol: 1e-12,
            threads: 1,
            nrhs: 1,
            ranks: 1,
            messages_per_iter: 0,
            halo_bytes_per_iter: 0,
            iterations: stats.outer_iterations,
            inner_iterations: stats.inner_iterations,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            sweeps_per_iter: 0.0,
            bytes_per_iter: 0,
            bytes_per_site: 0.0,
            gauge_reals_per_link: 18,
            true_residual: resid,
            history: stats.history,
        });
    }

    // BiCGStab on M-hat, f64 reference (same 1e-12 target as mixed)
    {
        let mut op = NativeMeo::new(&geom, u64f.clone(), kappa);
        let mut x = FermionField::<f64>::zeros(&geom);
        let sw = Stopwatch::start();
        let stats = solver::bicgstab(&mut op, &mut x, &b64, 1e-12, 2 * maxiter);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &b64);
        table.row(vec![
            "bicgstab(M)".into(),
            "f64".into(),
            stats.iterations.to_string(),
            format!("{:.2}", stats.flops as f64 / secs / 1e9),
            format!("{secs:.2}"),
            format!("{resid:.2e}"),
        ]);
        assert!(stats.converged || smoke);
        runs.push(Run {
            name: "bicgstab".into(),
            precision: "f64",
            tol: 1e-12,
            threads: 1,
            nrhs: 1,
            ranks: 1,
            messages_per_iter: 0,
            halo_bytes_per_iter: 0,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            sweeps_per_iter: stats.sweeps_per_iter,
            bytes_per_iter: 0,
            bytes_per_site: 0.0,
            gauge_reals_per_link: 18,
            true_residual: resid,
            history: stats.history,
        });
    }

    println!("{}", table.render());

    // ---- fused thread-parallel pipeline vs unfused reference on 8⁴ ----
    //
    // Same system solved four ways: the generic unfused CG (the 6
    // sweeps/iteration reference) and the fused pipeline (3 fused
    // sweeps/iteration) on worker teams of 1, 2 and 4 threads. The
    // residual histories must be bitwise identical across all four —
    // the fused pipeline changes memory traffic and parallelism, never
    // arithmetic.
    let fdims = if smoke {
        LatticeDims::new(4, 4, 4, 4).unwrap()
    } else {
        LatticeDims::new(8, 8, 8, 8).unwrap()
    };
    // 4^4 only tiles as 2x2 (xh = 2); the acceptance lattice 8^4 uses
    // the paper's 4x4
    let ftiling = if smoke {
        Tiling::new(2, 2).unwrap()
    } else {
        Tiling::new(4, 4).unwrap()
    };
    let fgeom = Geometry::single_rank(fdims, ftiling).unwrap();
    let mut frng = Rng::seeded(4242);
    // project the configuration through the two-row round trip: the
    // third row becomes the canonical cross-product rebuild, so the
    // compressed runs below are BITWISE comparable to the full-link
    // reference histories (physics unchanged — the projection is a
    // ~1-ulp re-unitarization)
    let fu: GaugeField<f32> = {
        let raw: GaugeField<f32> =
            GaugeField::<f64>::random(&fgeom, &mut frng).to_precision();
        CompressedGaugeField::compress(&raw).reconstruct()
    };
    let fb: FermionField<f32> =
        FermionField::<f64>::gaussian(&fgeom, &mut frng).to_precision();
    let ftol = 1e-5;
    let fmaxiter = if smoke { 40 } else { 500 };
    let fkappa = 0.13f32;

    // CGNR right-hand side: Mdag b
    let mut mbp = FermionField::<f32>::zeros(&fgeom);
    {
        let mut op = NativeMdagM::new(&fgeom, fu.clone(), fkappa);
        let mut bp = fb.clone();
        bp.gamma5();
        op.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
    }

    let mut ftable = Table::new(
        &format!(
            "Fused thread-parallel CG vs unfused on {fdims} (f32, tol = {ftol:.0e})"
        ),
        &["pipeline", "threads", "iters", "sweeps/iter", "seconds", "speedup", "eff GB/s"],
    );

    // unfused single-thread reference (the pre-fusion pipeline)
    let (ref_secs, ref_history) = {
        let mut op = UnfusedMdagM::new(&fgeom, fu.clone(), fkappa);
        let mut x = FermionField::<f32>::zeros(&fgeom);
        let sw = Stopwatch::start();
        let stats = solver::cg(&mut op, &mut x, &mbp, ftol, fmaxiter);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &mbp);
        let run = Run {
            name: "cgnr-unfused".into(),
            precision: "f32",
            tol: ftol,
            threads: 1,
            nrhs: 1,
            ranks: 1,
            messages_per_iter: 0,
            halo_bytes_per_iter: 0,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            sweeps_per_iter: stats.sweeps_per_iter,
            bytes_per_iter: cg_iter_bytes(&fgeom, 4, false),
            bytes_per_site: bytes_per_site(&fgeom, cg_iter_bytes(&fgeom, 4, false), 1),
            gauge_reals_per_link: 18,
            true_residual: resid,
            history: stats.history.clone(),
        };
        ftable.row(vec![
            "unfused".into(),
            "1".into(),
            stats.iterations.to_string(),
            format!("{:.0}", stats.sweeps_per_iter),
            format!("{secs:.3}"),
            "1.00x".into(),
            format!("{:.2}", eff_bw_gbs(&run)),
        ]);
        runs.push(run);
        (secs, stats.history)
    };

    for threads in [1usize, 2, 4] {
        let mut op = NativeMdagM::new(&fgeom, fu.clone(), fkappa);
        let mut team = Team::new(threads, BarrierKind::Sleep);
        let mut x = FermionField::<f32>::zeros(&fgeom);
        let sw = Stopwatch::start();
        let stats = solver::fused::cg(&mut op, &mut team, &mut x, &mbp, ftol, fmaxiter);
        let secs = sw.secs();
        let resid = solver::residual::operator_residual(&mut op, &x, &mbp);
        assert_eq!(
            stats.history, ref_history,
            "fused({threads}t) residual history diverged from the unfused reference"
        );
        let run = Run {
            name: "cgnr-fused".into(),
            precision: "f32",
            tol: ftol,
            threads,
            nrhs: 1,
            ranks: 1,
            messages_per_iter: 0,
            halo_bytes_per_iter: 0,
            iterations: stats.iterations,
            inner_iterations: 0,
            seconds: secs,
            gflops: stats.flops as f64 / secs / 1e9,
            sweeps_per_iter: stats.sweeps_per_iter,
            bytes_per_iter: cg_iter_bytes(&fgeom, 4, true),
            bytes_per_site: bytes_per_site(&fgeom, cg_iter_bytes(&fgeom, 4, true), 1),
            gauge_reals_per_link: 18,
            true_residual: resid,
            history: stats.history.clone(),
        };
        ftable.row(vec![
            "fused".into(),
            threads.to_string(),
            stats.iterations.to_string(),
            format!("{:.0}", stats.sweeps_per_iter),
            format!("{secs:.3}"),
            format!("{:.2}x", ref_secs / secs),
            format!("{:.2}", eff_bw_gbs(&run)),
        ]);
        runs.push(run);
    }

    println!("{}", ftable.render());
    println!(
        "fused pipeline: 3 full-field sweeps/iteration (vs 6 unfused); residual \
         histories bitwise identical across pipelines and thread counts"
    );

    // ---- multi-RHS block solver: compression × nrhs sweep --------------
    //
    // The same lattice solved with N ∈ {1, 2, 4, 8} stacked Gaussian
    // sources through the block solver, once with full 18-real links and
    // once with two-row compressed 12-real links. Each batched sweep
    // streams the gauge field once for all N systems, so the modeled
    // bytes/site per RHS fall monotonically toward the pure-spinor floor
    // — and the two-row rows sit strictly below the full rows at every
    // nrhs (asserted), because compression cuts exactly the stream that
    // multi-RHS cannot amortize away. RHS 0 is the single-RHS system
    // above, and its residual history must stay bitwise identical to
    // the fused reference at every N and either compression (the gauge
    // field is two-row projected, see above).
    let mut btable = Table::new(
        &format!("Block CGNR compression × nrhs sweep on {fdims} (f32, tol = {ftol:.0e})"),
        &["links", "nrhs", "iters (max)", "seconds", "bytes/site/RHS", "eff GB/s"],
    );
    let bsources: Vec<FermionField<f32>> = {
        let mut brng = Rng::seeded(7777);
        // RHS 0 is the fused-reference system; the rest are fresh sources
        let mut v = vec![mbp.clone()];
        for _ in 1..8 {
            let b: FermionField<f32> =
                FermionField::<f64>::gaussian(&fgeom, &mut brng).to_precision();
            let mut bp = b.clone();
            bp.gamma5();
            let mut op = NativeMdagM::new(&fgeom, fu.clone(), fkappa);
            let mut m = FermionField::<f32>::zeros(&fgeom);
            op.meo().apply(&mut m, &bp);
            m.gamma5();
            v.push(m);
        }
        v
    };
    let nrhs_sweep = [1usize, 2, 4, 8];
    // bytes/site of the full-link rows, indexed like nrhs_sweep, for the
    // cross-compression assertion
    let mut full_bps = [0.0f64; 4];
    for compression in [Compression::None, Compression::TwoRow] {
        let reals = compression.reals_per_link();
        let mut prev_bytes_per_site = f64::INFINITY;
        for (ni, &nrhs) in nrhs_sweep.iter().enumerate() {
            let b = MultiFermionField::from_rhs(&bsources[..nrhs]);
            let links = Links::from_gauge(fu.clone(), compression);
            let mut op = MultiMdagM::with_links(&fgeom, links, fkappa, nrhs);
            let mut team = Team::new(1, BarrierKind::Sleep);
            let mut x = MultiFermionField::<f32>::zeros(&fgeom, nrhs);
            let sw = Stopwatch::start();
            let stats = solver::block_cg(&mut op, &mut team, &mut x, &b, ftol, fmaxiter);
            let secs = sw.secs();
            // bit-exactness across compression: the projected gauge field
            // makes the two-row kernel arithmetic identical to full links
            assert_eq!(
                stats.per_rhs[0].history, ref_history,
                "block({compression}, nrhs={nrhs}) rhs 0 history diverged from the fused reference"
            );
            let bytes = block_cg_iter_bytes(&fgeom, 4, nrhs as u64, reals);
            let bps = bytes_per_site(&fgeom, bytes, nrhs as u64);
            assert!(
                bps < prev_bytes_per_site,
                "bytes/site/RHS must strictly decrease with nrhs ({bps} !< {prev_bytes_per_site})"
            );
            prev_bytes_per_site = bps;
            match compression {
                Compression::None => full_bps[ni] = bps,
                Compression::TwoRow => assert!(
                    bps < full_bps[ni],
                    "two-row bytes/site must be strictly below full links at nrhs {nrhs} \
                     ({bps} !< {})",
                    full_bps[ni]
                ),
            }
            // worst TRUE residual over the RHS, like every other JSON row
            let resid = {
                let mut rop = NativeMdagM::new(&fgeom, fu.clone(), fkappa);
                (0..nrhs)
                    .map(|r| {
                        let xr = x.extract_rhs(r);
                        solver::residual::operator_residual(&mut rop, &xr, &bsources[r])
                    })
                    .fold(0.0f64, f64::max)
            };
            let run = Run {
                name: match compression {
                    Compression::None => "block-cgnr".into(),
                    Compression::TwoRow => "block-cgnr-2row".into(),
                },
                precision: "f32",
                tol: ftol,
                threads: 1,
                nrhs,
                ranks: 1,
                messages_per_iter: 0,
                halo_bytes_per_iter: 0,
                iterations: stats.iterations,
                inner_iterations: 0,
                seconds: secs,
                gflops: stats.flops as f64 / secs / 1e9,
                sweeps_per_iter: stats.sweeps_per_iter,
                bytes_per_iter: bytes,
                bytes_per_site: bps,
                gauge_reals_per_link: reals,
                true_residual: resid,
                history: stats.per_rhs[0].history.clone(),
            };
            btable.row(vec![
                compression.to_string(),
                nrhs.to_string(),
                stats.iterations.to_string(),
                format!("{secs:.3}"),
                format!("{bps:.1}"),
                format!("{:.2}", eff_bw_gbs(&run)),
            ]);
            runs.push(run);
        }
    }
    println!("{}", btable.render());
    println!(
        "block solver: gauge links streamed once per sweep for all RHS, and two-row \
         compression cuts that stream by a third — bytes/site/RHS strictly \
         decreasing with nrhs, two-row strictly below full at every nrhs \
         (both asserted; gauge_reals_per_link recorded in the JSON)"
    );

    // ---- distributed multi-RHS: ranks × nrhs sweep ---------------------
    //
    // The same block systems solved over the simulated rank world with
    // batched halo exchange (one message per direction/orientation for
    // ALL active RHS). Acceptance properties, asserted per grid:
    // halo messages/iteration are INDEPENDENT of nrhs (batching
    // amortizes the per-message latency over the whole batch), modeled
    // memory bytes/site/RHS strictly DECREASE in nrhs (shared gauge
    // stream), and RHS 0's residual history is bitwise the nrhs = 1
    // run's (independent recurrences share the wire, not the math).
    let ddims = if smoke {
        LatticeDims::new(8, 4, 4, 4).unwrap()
    } else {
        LatticeDims::new(8, 8, 4, 8).unwrap()
    };
    let dtiling = Tiling::new(2, 2).unwrap();
    let dgeom = Geometry::single_rank(ddims, dtiling).unwrap();
    let mut drng = Rng::seeded(3131);
    let du: GaugeField<f32> = GaugeField::random(&dgeom, &mut drng);
    let dsources: Vec<FermionField<f32>> =
        (0..4).map(|_| FermionField::gaussian(&dgeom, &mut drng)).collect();
    let dkappa = 0.12f32;
    let dtol = 1e-4;
    let dmaxiter = if smoke { 40 } else { 200 };
    let mut dtable = Table::new(
        &format!("Distributed block BiCGStab ranks × nrhs sweep on {ddims} (f32, tol = {dtol:.0e})"),
        &["ranks", "nrhs", "iters (max)", "msgs/iter", "wire B/site/RHS", "mem B/site/RHS", "seconds"],
    );
    for (nranks, grid) in [
        (1usize, ProcGrid([1, 1, 1, 1])),
        (2, ProcGrid([1, 1, 1, 2])),
        (4, ProcGrid([1, 1, 2, 2])),
    ] {
        let lgeom0 = Geometry::for_rank(ddims, grid, 0, dtiling).unwrap();
        // forced self-communication everywhere (the paper's measurement
        // mode): traffic is uniform across the rank counts
        let comm_dirs = [true; 4];
        let plans = HaloPlans::new(&lgeom0, Parity::Even, comm_dirs);
        let mut msgs_ref: Option<u64> = None;
        let mut prev_bps = f64::INFINITY;
        let mut rhs0_ref: Option<Vec<f64>> = None;
        for nrhs in [1usize, 2, 4] {
            let sw = Stopwatch::start();
            let results = run_world(nranks, |rank, comm| {
                let lgeom = Geometry::for_rank(ddims, grid, rank, dtiling).unwrap();
                let u = extract_gauge(&du, &lgeom);
                let bs: Vec<FermionField<f32>> = dsources[..nrhs]
                    .iter()
                    .map(|b| extract_fermion(b, &dgeom, &lgeom))
                    .collect();
                let b = MultiFermionField::from_rhs(&bs);
                let dist = DistHopping::new(&lgeom, true, 1, Eo2Schedule::Uniform);
                let mut team = Team::new(1, BarrierKind::Sleep);
                let prof = Profiler::new(1);
                let mut op =
                    DistMultiMeo::new(&lgeom, &dist, &u, dkappa, nrhs, comm, &prof)
                        .expect("wire-format handshake");
                let mut x = MultiFermionField::<f32>::zeros(&lgeom, nrhs);
                let stats = solver::block_bicgstab_generic(
                    &mut op, &mut team, &mut x, &b, dtol, dmaxiter,
                );
                (stats, x.demux())
            });
            let secs = sw.secs();
            let stats = &results[0].0;
            // rhs 0 history is bitwise the nrhs = 1 run's
            match &rhs0_ref {
                None => rhs0_ref = Some(stats.per_rhs[0].history.clone()),
                Some(want) => assert_eq!(
                    &stats.per_rhs[0].history, want,
                    "ranks {nranks}: rhs 0 history changed with nrhs {nrhs}"
                ),
            }
            // one BiCGStab iteration = 2 M-hat applies = 4 batched hoppings
            let traffic =
                netmodel::batched_hopping_traffic(plans.face_count, comm_dirs, nrhs, 4);
            let messages_per_iter = 4 * traffic.messages;
            let halo_bytes_per_iter = 4 * traffic.bytes;
            match msgs_ref {
                None => msgs_ref = Some(messages_per_iter),
                Some(want) => assert_eq!(
                    messages_per_iter, want,
                    "halo messages/iteration must be independent of nrhs"
                ),
            }
            let wire_bps = netmodel::halo_bytes_per_site_rhs(
                netmodel::HaloTraffic {
                    messages: messages_per_iter,
                    bytes: halo_bytes_per_iter,
                },
                lgeom0.local.half_volume(),
                nrhs,
            );
            // memory-side model: same 4 hopping passes as block CGNR,
            // gauge streamed once per pass for all RHS
            let mem_bytes = block_cg_iter_bytes(&lgeom0, 4, nrhs as u64, 18);
            let mem_bps = bytes_per_site(&lgeom0, mem_bytes, nrhs as u64);
            assert!(
                mem_bps < prev_bps,
                "distributed bytes/site/RHS must strictly decrease in nrhs \
                 ({mem_bps} !< {prev_bps})"
            );
            prev_bps = mem_bps;
            // worst TRUE residual via the single-rank operator on the
            // joined solutions
            let resid = {
                use lqcd::comm::decompose::insert_fermion;
                let mut xs: Vec<FermionField<f32>> =
                    (0..nrhs).map(|_| FermionField::zeros(&dgeom)).collect();
                for (rank, (_, xl)) in results.iter().enumerate() {
                    let lg = Geometry::for_rank(ddims, grid, rank, dtiling).unwrap();
                    for r in 0..nrhs {
                        insert_fermion(&mut xs[r], &xl[r], &lg);
                    }
                }
                let mut rop = NativeMeo::new(&dgeom, du.clone(), dkappa);
                (0..nrhs)
                    .map(|r| {
                        solver::residual::operator_residual(&mut rop, &xs[r], &dsources[r])
                    })
                    .fold(0.0f64, f64::max)
            };
            dtable.row(vec![
                nranks.to_string(),
                nrhs.to_string(),
                stats.iterations.to_string(),
                messages_per_iter.to_string(),
                format!("{wire_bps:.1}"),
                format!("{mem_bps:.1}"),
                format!("{secs:.3}"),
            ]);
            runs.push(Run {
                name: "dist-block-bicgstab".into(),
                precision: "f32",
                tol: dtol,
                threads: 1,
                nrhs,
                ranks: nranks,
                messages_per_iter,
                halo_bytes_per_iter,
                iterations: stats.iterations,
                inner_iterations: 0,
                seconds: secs,
                gflops: stats.flops as f64 / secs / 1e9,
                sweeps_per_iter: stats.sweeps_per_iter,
                bytes_per_iter: mem_bytes,
                bytes_per_site: mem_bps,
                gauge_reals_per_link: 18,
                true_residual: resid,
                history: stats.per_rhs[0].history.clone(),
            });
        }
    }
    println!("{}", dtable.render());
    println!(
        "distributed block solver: batched halos keep messages/iteration constant \
         in nrhs while memory bytes/site/RHS fall with the shared gauge stream \
         (both asserted); wire bytes/site/RHS are nrhs-independent by design"
    );

    emit_json(&dims.to_string(), kappa, &runs);
    assert_roofline_floor(&runs);
    assert_bench_baseline(&runs);
}

/// CI bandwidth floor: the best fused-CG run must reach a configurable
/// fraction of the fitted host roofline, or the bench fails loudly.
///
/// Opt-in via `LQCD_ROOFLINE_FLOOR` (a fraction in (0, 1]) so local
/// `cargo bench` runs are never gated. The roofline itself comes from
/// the tune cache when `LQCD_TUNE_JSON` points at one (the GB/s the
/// tuner's best measured configuration achieved, through the same byte
/// models this bench reports), otherwise from a live STREAM-triad
/// calibration.
fn assert_roofline_floor(runs: &[Run]) {
    let floor: f64 = match std::env::var("LQCD_ROOFLINE_FLOOR") {
        Ok(v) => v
            .parse()
            .unwrap_or_else(|_| panic!("LQCD_ROOFLINE_FLOOR must be a number, got {v:?}")),
        Err(_) => {
            println!("roofline floor: LQCD_ROOFLINE_FLOOR unset, assertion skipped");
            return;
        }
    };
    assert!(
        floor > 0.0 && floor <= 1.0,
        "LQCD_ROOFLINE_FLOOR must be in (0, 1], got {floor}"
    );
    let best = runs
        .iter()
        .filter(|r| r.name == "cgnr-fused")
        .map(eff_bw_gbs)
        .fold(0.0, f64::max);
    let (roofline, source) = match std::env::var("LQCD_TUNE_JSON") {
        Ok(path) => {
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("LQCD_TUNE_JSON={path}: {e}"));
            let cache = lqcd::perf::TuneCache::parse(&text)
                .unwrap_or_else(|e| panic!("LQCD_TUNE_JSON={path}: {e}"));
            (cache.choice.roofline_gbs, format!("tune cache {path}"))
        }
        Err(_) => {
            let host = lqcd::perf::calibrate_host();
            (
                host.mem_bw_saturated_gbs,
                "live STREAM-triad calibration".to_string(),
            )
        }
    };
    let need = floor * roofline;
    if best < need {
        eprintln!(
            "ROOFLINE FLOOR VIOLATION\n\
             \x20 best fused-CG effective bandwidth: {best:.2} GB/s\n\
             \x20 fitted roofline ({source}): {roofline:.2} GB/s\n\
             \x20 required: {:.0}% of roofline = {need:.2} GB/s\n\
             The solver hot path fell below the bandwidth floor. Either a perf\n\
             regression landed, or the floor is mis-calibrated for this machine\n\
             (re-run `lqcd tune` to refresh the cache, or lower LQCD_ROOFLINE_FLOOR).",
            floor * 100.0
        );
        std::process::exit(1);
    }
    println!(
        "roofline floor OK: best fused-CG {best:.2} GB/s >= {:.0}% of \
         {roofline:.2} GB/s ({source})",
        floor * 100.0
    );
}

/// Perf-regression gate against the committed baseline
/// (`configs/bench_baseline.json`): for every run name the baseline
/// lists, the best measured effective bandwidth must stay inside the
/// tolerance band of the committed GB/s value.
///
/// Opt-in via `LQCD_BENCH_BASELINE` (path to the baseline JSON) so
/// local runs are never gated; the band is `LQCD_BENCH_TOLERANCE`, the
/// allowed fractional drop below baseline (default 0.5 — shared CI
/// runners are noisy, so the committed values are collapse-scale
/// floors, not percent-level trip wires). A failure prints measured vs
/// required numbers for every violated row.
fn assert_bench_baseline(runs: &[Run]) {
    let path = match std::env::var("LQCD_BENCH_BASELINE") {
        Ok(p) => p,
        Err(_) => {
            println!("bench baseline: LQCD_BENCH_BASELINE unset, gate skipped");
            return;
        }
    };
    let tolerance: f64 = match std::env::var("LQCD_BENCH_TOLERANCE") {
        Ok(v) => v
            .parse()
            .unwrap_or_else(|_| panic!("LQCD_BENCH_TOLERANCE must be a number, got {v:?}")),
        Err(_) => 0.5,
    };
    assert!(
        (0.0..1.0).contains(&tolerance),
        "LQCD_BENCH_TOLERANCE must be in [0, 1), got {tolerance}"
    );
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("LQCD_BENCH_BASELINE={path}: {e}"));
    let doc = lqcd::util::json::Json::parse(&text)
        .unwrap_or_else(|e| panic!("LQCD_BENCH_BASELINE={path}: {e}"));
    let Some(lqcd::util::json::Json::Obj(baselines)) = doc.get("baseline_gbs") else {
        panic!("LQCD_BENCH_BASELINE={path}: missing baseline_gbs object");
    };
    let mut failures = Vec::new();
    for (name, v) in baselines {
        let baseline = v
            .as_f64()
            .unwrap_or_else(|| panic!("baseline_gbs.{name} must be a number"));
        // NaN seed: max(NaN, x) = x, and an all-miss fold stays NaN so a
        // baseline row naming a run this bench no longer emits fails loudly
        let best = runs
            .iter()
            .filter(|r| &r.name == name)
            .map(eff_bw_gbs)
            .fold(f64::NAN, f64::max);
        if best.is_nan() {
            panic!("baseline_gbs.{name}: no bench run with that name");
        }
        let need = baseline * (1.0 - tolerance);
        if best < need {
            failures.push(format!(
                "  {name}: measured {best:.3} GB/s < required {need:.3} GB/s \
                 (baseline {baseline:.3} GB/s - {:.0}% tolerance)",
                tolerance * 100.0
            ));
        } else {
            println!(
                "bench baseline OK: {name} {best:.3} GB/s >= {need:.3} GB/s \
                 (baseline {baseline:.3} GB/s, tolerance {:.0}%)",
                tolerance * 100.0
            );
        }
    }
    if !failures.is_empty() {
        eprintln!(
            "BENCH BASELINE VIOLATION ({path})\n{}\n\
             The solver bench fell below the committed perf baseline. Either a\n\
             perf regression landed, or the baseline needs re-measuring on this\n\
             class of machine (edit configs/bench_baseline.json, or widen\n\
             LQCD_BENCH_TOLERANCE).",
            failures.join("\n")
        );
        std::process::exit(1);
    }
}
