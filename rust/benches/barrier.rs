//! FLIB_BARRIER=HARD ablation: spin vs sleeping barrier in the thread
//! team (paper §4: ~20% at the smallest lattice; id A2).

mod common;

fn main() {
    let opts = common::opts(30, 4);
    println!("{}", lqcd::harness::barrier::run(opts).report);
}
