//! PJRT path benchmark: the AOT `meo` artifact vs the native kernel on
//! the same fields — operator latency and the interchange overhead.
//! Requires `make artifacts`.

mod common;

use lqcd::coordinator::operator::{LinearOperator, NativeMeo};
use lqcd::field::{FermionField, GaugeField};
use lqcd::lattice::{Geometry, Tiling};
use lqcd::runtime::{PjrtMeo, Runtime};
use lqcd::util::rng::Rng;
use lqcd::util::tables::Table;
use lqcd::util::timer::Bench;

fn main() {
    let opts = common::opts(10, 1);
    let rt = match Runtime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping pjrt_overhead: {e}");
            return;
        }
    };
    let dims = rt.manifest.dims;
    let geom = Geometry::single_rank(dims, Tiling::new(4, 4).unwrap())
        .or_else(|_| Geometry::single_rank(dims, Tiling::new(2, 2).unwrap()))
        .unwrap();
    let mut rng = Rng::seeded(31415);
    let u = GaugeField::random(&geom, &mut rng);
    let psi = FermionField::gaussian(&geom, &mut rng);
    let mut out = FermionField::zeros(&geom);
    let kappa = 0.13f32;
    let flops = lqcd::dslash::flops::meo_flops(dims.half_volume()) as f64 * opts.iters as f64;

    let bench = Bench::new(1, 3);
    let mut table = Table::new(
        &format!("M-hat operator on {dims}: PJRT artifact vs native kernel"),
        &["operator", "per apply", "GFlops"],
    );

    let mut pjrt = PjrtMeo::new(&rt, &geom, &u, kappa).unwrap();
    let r = bench.run(|| {
        for _ in 0..opts.iters {
            pjrt.apply(&mut out, &psi);
        }
        Some(flops)
    });
    table.row(vec![
        "pjrt (L1 pallas + L2 jax AOT)".into(),
        lqcd::util::timer::fmt_secs(r.stats.median / opts.iters as f64),
        format!("{:.2}", r.gflops().unwrap()),
    ]);

    let mut native = NativeMeo::new(&geom, u, kappa);
    let r = bench.run(|| {
        for _ in 0..opts.iters {
            native.apply(&mut out, &psi);
        }
        Some(flops)
    });
    table.row(vec![
        "native (L3 lane kernel)".into(),
        lqcd::util::timer::fmt_secs(r.stats.median / opts.iters as f64),
        format!("{:.2}", r.gflops().unwrap()),
    ]);

    println!("{}", table.render());
}
