//! Regenerates **Table 1** of the paper: 2D SIMD tiling sweep of the
//! even-odd Wilson matrix multiplication (see DESIGN.md section 6, id T1).

mod common;

fn main() {
    let opts = common::opts(20, 1);
    println!("running Table 1 sweep (iters = {}, threads = {}) ...", opts.iters, opts.threads);
    let (report, _) = lqcd::harness::table1::run(opts);
    println!("{report}");
}
