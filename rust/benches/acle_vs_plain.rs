//! Regenerates the §4.2 comparison: tuned SIMD kernel vs the plain
//! "without ACLE" implementation (~10x on A64FX; id A1).

mod common;

fn main() {
    let opts = common::opts(10, 1);
    println!("{}", lqcd::harness::acle::run(opts).report);
}
