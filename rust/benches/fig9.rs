//! Regenerates **Fig. 9**: EO1/EO2 per-thread accounting, the EO2 load
//! imbalance, and the balanced-EO2 extension (id F9).

mod common;

fn main() {
    let opts = common::opts(20, 4);
    println!("{}", lqcd::harness::fig9::run(opts).report);
}
