//! Regenerates **Fig. 8**: per-thread cycle accounting of the bulk kernel
//! before (gather/scatter) and after (lane shuffles) tuning (id F8).

mod common;

fn main() {
    let opts = common::opts(20, 4);
    println!("{}", lqcd::harness::fig8::run(opts).report);
}
