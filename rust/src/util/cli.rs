//! Minimal command-line parser (the `clap` substrate).
//!
//! Supports `lqcd <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`. Unknown options
//! are errors so typos never silently fall back to defaults.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments: subcommand, options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
    /// names consumed by typed getters, used by `finish` to reject typos
    consumed: std::cell::RefCell<Vec<String>>,
}

/// Option names that take a value (everything else is a boolean flag).
pub fn parse<I: IntoIterator<Item = String>>(
    argv: I,
    value_opts: &[&str],
) -> Result<Args, CliError> {
    let mut args = Args::default();
    let mut it = argv.into_iter().peekable();
    while let Some(tok) = it.next() {
        if let Some(body) = tok.strip_prefix("--") {
            let (key, inline_val) = match body.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            if value_opts.contains(&key.as_str()) {
                let val = match inline_val {
                    Some(v) => v,
                    None => it
                        .next()
                        .ok_or_else(|| CliError(format!("--{key} needs a value")))?,
                };
                args.opts.insert(key, val);
            } else if inline_val.is_some() {
                return Err(CliError(format!("--{key} does not take a value")));
            } else {
                args.flags.push(key);
            }
        } else if args.command.is_none() && args.positional.is_empty() {
            args.command = Some(tok);
        } else {
            args.positional.push(tok);
        }
    }
    Ok(args)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("--{name}: cannot parse {s:?}"))),
        }
    }

    /// Error on any option/flag that no getter asked about.
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        for k in self.opts.keys() {
            if !consumed.iter().any(|c| c == k) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        for f in &self.flags {
            if !consumed.iter().any(|c| c == f) {
                return Err(CliError(format!("unknown flag --{f}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = parse(
            sv(&["bench", "--dims", "16x16x8x8", "--verbose", "extra"]),
            &["dims"],
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("dims"), Some("16x16x8x8"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, sv(&["extra"]));
        a.finish().unwrap();
    }

    #[test]
    fn equals_form() {
        let a = parse(sv(&["run", "--reps=7"]), &["reps"]).unwrap();
        assert_eq!(a.get_parse("reps", 0usize).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(sv(&["run", "--reps"]), &["reps"]).is_err());
    }

    #[test]
    fn unknown_option_rejected_by_finish() {
        let a = parse(sv(&["run", "--oops", "1"]), &["oops"]).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn flag_with_value_is_error() {
        assert!(parse(sv(&["run", "--verbose=yes"]), &[]).is_err());
    }

    #[test]
    fn default_used_when_absent() {
        let a = parse(sv(&["run"]), &["reps"]).unwrap();
        assert_eq!(a.get_parse("reps", 42usize).unwrap(), 42);
    }
}
