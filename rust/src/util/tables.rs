//! ASCII table and bar-chart rendering for the benchmark harness.
//!
//! The harness prints the same rows/series the paper reports: Table 1 as a
//! table, Figs. 8-10 as per-thread stacked bars / scaling series rendered
//! in text.

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:>w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }
}

/// Horizontal bar chart: one labelled bar per entry, scaled to `width`.
/// Used for the Fig. 8/9 per-thread cycle-account renderings.
pub fn bar_chart(title: &str, entries: &[(String, f64)], width: usize) -> String {
    let max = entries
        .iter()
        .map(|(_, v)| *v)
        .fold(f64::MIN_POSITIVE, f64::max);
    let label_w = entries.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("## {title}\n");
    for (label, v) in entries {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:<label_w$} | {:<width$} {:.3e}\n",
            label,
            "#".repeat(n.min(width)),
            v,
        ));
    }
    out
}

/// Stacked horizontal bars: each entry has per-segment values; segments are
/// rendered with distinct characters. Returns the chart plus a legend.
pub fn stacked_bars(
    title: &str,
    labels: &[String],
    segments: &[String],
    values: &[Vec<f64>],
    width: usize,
) -> String {
    const CHARS: &[char] = &['#', '=', '+', ':', '.', '%', '@', '*'];
    let totals: Vec<f64> = values.iter().map(|v| v.iter().sum()).collect();
    let max = totals.iter().cloned().fold(f64::MIN_POSITIVE, f64::max);
    let label_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("## {title}\n");
    for (i, label) in labels.iter().enumerate() {
        let mut bar = String::new();
        for (j, v) in values[i].iter().enumerate() {
            let n = ((v / max) * width as f64).round() as usize;
            let ch = CHARS[j % CHARS.len()];
            bar.extend(std::iter::repeat(ch).take(n));
        }
        out.push_str(&format!(
            "{:<label_w$} | {:<width$} {:.3e} s\n",
            label, bar, totals[i]
        ));
    }
    out.push_str("legend: ");
    for (j, s) in segments.iter().enumerate() {
        out.push_str(&format!("{}={} ", CHARS[j % CHARS.len()], s));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["lattice", "GFlops"]);
        t.row(vec!["16x16x8x8".into(), "448".into()]);
        t.row(vec!["64x32x16x8".into(), "343".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("16x16x8x8 |"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn bars_scale_to_width() {
        let s = bar_chart(
            "b",
            &[("t0".into(), 1.0), ("t1".into(), 2.0)],
            10,
        );
        assert!(s.contains("##########"));
        assert!(s.contains("#####"));
    }

    #[test]
    fn stacked_has_legend() {
        let s = stacked_bars(
            "f",
            &["t0".into()],
            &["bulk".into(), "wait".into()],
            &[vec![1.0, 1.0]],
            8,
        );
        assert!(s.contains("legend:"));
        assert!(s.contains("#=")); // both segments present
    }
}
