//! Miniature property-based testing framework (the `proptest` substrate).
//!
//! Runs a property over `cases` randomly generated inputs; on failure it
//! reports the seed and the case index so the exact input can be replayed
//! deterministically (`Runner::replay`).
//!
//! ```no_run
//! use lqcd::util::prop::Runner;
//! Runner::new("addition commutes", 100).run(|g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Input generator handed to each property case.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.i64_in(lo as i64, hi as i64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn gaussian(&mut self) -> f64 {
        self.rng.gaussian()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u64) as usize]
    }

    /// An even lattice extent in [2, max] (lattice dims must be even).
    pub fn even_extent(&mut self, max: usize) -> usize {
        2 * self.usize_in(1, max / 2)
    }
}

/// Property runner.
pub struct Runner {
    name: String,
    cases: usize,
    seed: u64,
}

impl Runner {
    pub fn new(name: &str, cases: usize) -> Self {
        // Allow overriding the seed for replay via env var.
        let seed = std::env::var("LQCD_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_CAFE);
        Runner {
            name: name.to_string(),
            cases,
            seed,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the property across all cases; panics with replay info on failure.
    pub fn run<F: FnMut(&mut Gen)>(&self, mut property: F) {
        for case in 0..self.cases {
            let rng = Rng::seeded(self.seed).split(case as u64);
            let mut g = Gen { rng };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || property(&mut g),
            ));
            if let Err(payload) = result {
                eprintln!(
                    "property '{}' failed at case {case} \
                     (replay: LQCD_PROP_SEED={} case {case})",
                    self.name, self.seed
                );
                std::panic::resume_unwind(payload);
            }
        }
    }

    /// Re-run exactly one case (for debugging a reported failure).
    pub fn replay<F: FnMut(&mut Gen)>(&self, case: usize, mut property: F) {
        let rng = Rng::seeded(self.seed).split(case as u64);
        let mut g = Gen { rng };
        property(&mut g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        Runner::new("count", 25).run(|_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn deterministic_inputs() {
        let mut first = Vec::new();
        Runner::new("gen", 5).run(|g| first.push(g.i64_in(0, 1000)));
        let mut second = Vec::new();
        Runner::new("gen", 5).run(|g| second.push(g.i64_in(0, 1000)));
        assert_eq!(first, second);
    }

    #[test]
    fn ranges_respected() {
        Runner::new("ranges", 200).run(|g| {
            let v = g.i64_in(-3, 7);
            assert!((-3..=7).contains(&v));
            let e = g.even_extent(12);
            assert!(e >= 2 && e <= 12 && e % 2 == 0);
            let f = g.f64_in(1.5, 2.5);
            assert!((1.5..2.5).contains(&f));
        });
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        Runner::new("fails", 10).run(|g| {
            assert!(g.i64_in(0, 100) > 1000);
        });
    }

    #[test]
    fn replay_single_case() {
        let r = Runner::new("replay", 3).with_seed(99);
        let mut vals = Vec::new();
        r.run(|g| vals.push(g.u64_below(1 << 20)));
        let mut replayed = 0;
        r.replay(1, |g| replayed = g.u64_below(1 << 20));
        assert_eq!(replayed, vals[1]);
    }
}
