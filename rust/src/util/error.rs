//! Minimal error substrate (the `anyhow` analog). The build is fully
//! offline, so instead of depending on `anyhow` the crate carries this
//! string-message error with the same ergonomics: the [`anyhow!`] /
//! [`bail!`] macros and a [`Context`] extension trait.

use std::fmt;

/// A string-message error.
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (the `anyhow::Context` analog).
pub trait Context<T> {
    fn context(self, msg: impl Into<String>) -> Result<T>;
    fn with_context<F, S>(self, f: F) -> Result<T>
    where
        F: FnOnce() -> S,
        S: Into<String>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", msg.into())))
    }

    fn with_context<F, S>(self, f: F) -> Result<T>
    where
        F: FnOnce() -> S,
        S: Into<String>,
    {
        self.map_err(|e| Error(format!("{}: {e}", f().into())))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad {}", 42)
    }

    #[test]
    fn bail_and_context() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad 42");
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.with_context(|| "open x".to_string()).unwrap_err();
        assert!(e.to_string().starts_with("open x: "));
    }
}
