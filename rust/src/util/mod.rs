//! Foundation substrates built from scratch (no external crates available
//! offline beyond the `xla` closure): RNG, CLI parsing, timing/statistics,
//! table rendering for the benchmark harness, and a miniature
//! property-based-testing framework used across the test suite.

pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tables;
pub mod timer;
