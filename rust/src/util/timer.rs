//! Timing and measurement statistics for the benchmark harness.
//!
//! `criterion` is not available offline, so benches use this substrate:
//! warmup + repeated timed runs, robust summary statistics, and GFlops
//! conversion using the paper's 1368 flop/site convention.

use std::time::{Duration, Instant};

/// Summary statistics over repeated measurements (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub samples: Vec<f64>,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "no samples");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let min = samples[0];
        let max = samples[n - 1];
        let median = if n % 2 == 1 {
            samples[n / 2]
        } else {
            0.5 * (samples[n / 2 - 1] + samples[n / 2])
        };
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / n as f64;
        Stats {
            min,
            median,
            mean,
            max,
            stddev: var.sqrt(),
            samples,
        }
    }

    /// Relative spread (stddev / mean) — used to decide convergence.
    pub fn rel_spread(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// A benchmark runner: warms up, then times `reps` runs of `f`.
///
/// `f` receives the iteration index and returns an optional amount of work
/// (e.g. flops) done, summed into the result.
pub struct Bench {
    pub warmup: usize,
    pub reps: usize,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub stats: Stats,
    /// work units (flops) per run, if reported
    pub work_per_run: Option<f64>,
}

impl BenchResult {
    /// GFlops based on the *median* run time.
    pub fn gflops(&self) -> Option<f64> {
        self.work_per_run
            .map(|w| w / self.stats.median / 1.0e9)
    }
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 2,
            reps: 5,
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bench { warmup, reps }
    }

    pub fn run<F>(&self, mut f: F) -> BenchResult
    where
        F: FnMut() -> Option<f64>,
    {
        let mut work = None;
        for _ in 0..self.warmup {
            work = f();
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let start = Instant::now();
            work = f();
            samples.push(start.elapsed().as_secs_f64());
        }
        BenchResult {
            stats: Stats::from_samples(samples),
            work_per_run: work,
        }
    }
}

/// Simple scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Format seconds human-readably (ns/us/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_even_median() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn stats_empty_panics() {
        Stats::from_samples(vec![]);
    }

    #[test]
    fn bench_counts_runs() {
        let mut calls = 0;
        let b = Bench::new(1, 3);
        let r = b.run(|| {
            calls += 1;
            Some(10.0)
        });
        assert_eq!(calls, 4); // 1 warmup + 3 timed
        assert_eq!(r.stats.samples.len(), 3);
        assert!(r.gflops().unwrap() > 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-6).contains("us"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
    }
}
