//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through `splitmix64` — the standard pairing
//! recommended by the xoshiro authors. No `rand` crate is available in the
//! offline registry, so this substrate is self-contained. Deterministic
//! seeding matters here: gauge configurations are reproduced from a seed in
//! both tests and benchmarks, and golden data from the Python side uses its
//! own seeds (interchange happens through files, never through RNG parity).

/// splitmix64: used to expand a single `u64` seed into the xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second member of a Box-Muller pair
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (for per-rank / per-thread RNGs).
    pub fn split(&self, stream: u64) -> Self {
        // Mix the stream id through splitmix so nearby ids decorrelate.
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method.
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box-Muller (pair cached).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn split_streams_decorrelate() {
        let base = Rng::seeded(7);
        let mut a = base.split(0);
        let mut b = base.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Rng::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::seeded(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::seeded(11);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }
}
