//! Minimal JSON parser (the `serde_json` substrate) — enough for
//! `artifacts/manifest.json`: objects, arrays, strings (with basic
//! escapes), numbers, booleans, null — plus the streaming [`JsonWriter`]
//! every machine-readable artifact (profile.json, trace.json,
//! metrics.json, the `recovery:`/`slowdowns:` CLI lines, solver-bench
//! report, tune cache) is emitted through, so artifacts diff cleanly
//! run-to-run: keys in the order the caller writes them, floats in the
//! repo-wide [`fnum`] convention.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: m.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// The repo-wide float convention for machine-readable artifacts
/// (established by the tune cache): deterministic, round-trippable
/// `{:.9e}`. Never call with non-finite values — NaN/inf are not JSON.
pub fn fnum(v: f64) -> String {
    format!("{v:.9e}")
}

/// Streaming JSON writer: compact output (no whitespace), automatic
/// comma placement, escaped strings. The caller controls key order, so
/// the same sequence of calls always produces the same bytes.
///
/// ```text
/// let mut w = JsonWriter::new();
/// w.obj_begin();
/// w.key("converged"); w.boolean(true);
/// w.key("rr"); w.num(1.5e-9);
/// w.obj_end();
/// let text = w.finish();
/// ```
#[derive(Debug, Default)]
pub struct JsonWriter {
    out: String,
    /// one entry per open container: whether it already holds an element
    stack: Vec<bool>,
    /// a key was just written; the next value must not emit a comma
    pending_key: bool,
}

impl JsonWriter {
    pub fn new() -> JsonWriter {
        JsonWriter::default()
    }

    /// Comma bookkeeping before an element (value or container start).
    fn sep(&mut self) {
        if self.pending_key {
            self.pending_key = false;
            return;
        }
        if let Some(has) = self.stack.last_mut() {
            if *has {
                self.out.push(',');
            }
            *has = true;
        }
    }

    pub fn obj_begin(&mut self) {
        self.sep();
        self.out.push('{');
        self.stack.push(false);
    }

    pub fn obj_end(&mut self) {
        self.stack.pop();
        self.out.push('}');
    }

    pub fn arr_begin(&mut self) {
        self.sep();
        self.out.push('[');
        self.stack.push(false);
    }

    pub fn arr_end(&mut self) {
        self.stack.pop();
        self.out.push(']');
    }

    pub fn key(&mut self, k: &str) {
        if let Some(has) = self.stack.last_mut() {
            if *has {
                self.out.push(',');
            }
            *has = true;
        }
        self.out.push('"');
        escape_into(&mut self.out, k);
        self.out.push_str("\":");
        self.pending_key = true;
    }

    pub fn str_val(&mut self, s: &str) {
        self.sep();
        self.out.push('"');
        escape_into(&mut self.out, s);
        self.out.push('"');
    }

    /// A float in the [`fnum`] convention.
    pub fn num(&mut self, v: f64) {
        self.sep();
        self.out.push_str(&fnum(v));
    }

    pub fn uint(&mut self, v: u64) {
        self.sep();
        self.out.push_str(&v.to_string());
    }

    pub fn int(&mut self, v: i64) {
        self.sep();
        self.out.push_str(&v.to_string());
    }

    pub fn boolean(&mut self, v: bool) {
        self.sep();
        self.out.push_str(if v { "true" } else { "false" });
    }

    /// A pre-formatted JSON token (e.g. a fixed-decimal float where the
    /// `fnum` convention is too wide). The caller guarantees validity.
    pub fn raw(&mut self, token: &str) {
        self.sep();
        self.out.push_str(token);
    }

    pub fn finish(self) -> String {
        debug_assert!(self.stack.is_empty(), "unclosed JSON container");
        self.out
    }
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
          "version": 1,
          "dims": [8, 8, 8, 16],
          "cg_tol": 1e-10,
          "artifacts": [
            {"name": "meo", "file": "meo.hlo.txt",
             "inputs": [{"shape": [4, 2], "dtype": "f32"}],
             "outputs": []}
          ],
          "golden": null,
          "flag": true
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let dims: Vec<usize> = j
            .get("dims")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(dims, vec![8, 8, 8, 16]);
        assert_eq!(j.get("cg_tol").unwrap().as_f64(), Some(1e-10));
        let art = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(art.get("name").unwrap().as_str(), Some("meo"));
        assert_eq!(j.get("golden"), Some(&Json::Null));
        assert_eq!(j.get("flag").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1, 2], [3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let j = Json::parse("[-1.5, 2e3, -4E-2]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
        assert_eq!(a[2].as_f64(), Some(-0.04));
    }

    #[test]
    fn writer_round_trips_through_parser() {
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("converged");
        w.boolean(true);
        w.key("rr");
        w.num(1.5e-9);
        w.key("name");
        w.str_val("a\"b\\c\nd");
        w.key("list");
        w.arr_begin();
        w.uint(1);
        w.uint(2);
        w.num(0.0);
        w.arr_end();
        w.key("nested");
        w.obj_begin();
        w.obj_end();
        w.key("neg");
        w.int(-3);
        w.obj_end();
        let text = w.finish();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("converged").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("rr").unwrap().as_f64(), Some(1.5e-9));
        assert_eq!(j.get("name").unwrap().as_str(), Some("a\"b\\c\nd"));
        assert_eq!(j.get("list").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("neg").unwrap().as_f64(), Some(-3.0));
        // compact, deterministic bytes: no spaces after separators
        assert!(text.starts_with("{\"converged\":true,\"rr\":"), "{text}");
    }

    #[test]
    fn writer_empty_containers_and_fnum() {
        let mut w = JsonWriter::new();
        w.arr_begin();
        w.arr_end();
        assert_eq!(w.finish(), "[]");
        assert_eq!(fnum(0.0), "0.000000000e0");
        assert_eq!(fnum(1.5e-9), "1.500000000e-9");
        // the convention is itself valid JSON
        assert_eq!(Json::parse(&fnum(0.0)).unwrap().as_f64(), Some(0.0));
    }
}
