//! Bitwise field snapshots for the checkpoint/restart layer.
//!
//! A [`FieldSnap`] captures one named fermion (or block fermion) field as
//! f64 values plus a dtype tag. The f32 -> f64 -> f32 round trip is
//! value-exact for every finite float, so restoring a snapshot at the
//! field's original precision reproduces the original bit patterns —
//! which is what makes the solver resume contract of
//! [`crate::solver::checkpoint`] (residual history bitwise identical
//! from the checkpoint iteration onward) achievable at both precisions.
//!
//! [`gauge_hash`] fingerprints a gauge configuration's content (FNV-1a
//! over the f64 bit patterns of every link element); the checkpoint
//! header carries it so a resume against the wrong configuration is a
//! structured error, never a silently wrong solve.

use crate::algebra::Real;
use crate::field::{FermionField, GaugeField, MultiFermionField};

/// Dtype codes shared with the `field::io` LQCD0001 convention
/// (0 = f32, 1 = f64).
fn dtype_of<R: Real>() -> u32 {
    match R::NAME {
        "f64" => 1,
        _ => 0,
    }
}

/// One named field captured at an iteration boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct FieldSnap {
    pub name: String,
    /// dtype code of the source field (0 = f32, 1 = f64)
    pub dtype: u32,
    /// the field values widened to f64 (loss-free for both precisions)
    pub data: Vec<f64>,
}

impl FieldSnap {
    /// Snapshot a raw value slice (the building block the field
    /// wrappers share).
    pub fn of_slice<R: Real>(name: &str, data: &[R]) -> FieldSnap {
        FieldSnap {
            name: name.to_string(),
            dtype: dtype_of::<R>(),
            data: data.iter().map(|v| v.to_f64()).collect(),
        }
    }

    pub fn of_fermion<R: Real>(name: &str, f: &FermionField<R>) -> FieldSnap {
        FieldSnap::of_slice(name, &f.data)
    }

    pub fn of_multi<R: Real>(name: &str, f: &MultiFermionField<R>) -> FieldSnap {
        FieldSnap::of_slice(name, &f.data)
    }

    /// Restore into a raw value slice; the destination must already have
    /// the snapshot's length and precision (a mismatch is a structured
    /// error, never a cast).
    pub fn restore_slice<R: Real>(&self, out: &mut [R]) -> Result<(), String> {
        if self.dtype != dtype_of::<R>() {
            return Err(format!(
                "snapshot {:?} holds dtype {} but the solve runs at {}",
                self.name,
                if self.dtype == 1 { "f64" } else { "f32" },
                R::NAME,
            ));
        }
        if self.data.len() != out.len() {
            return Err(format!(
                "snapshot {:?} holds {} values, the field wants {}",
                self.name,
                self.data.len(),
                out.len(),
            ));
        }
        for (o, &v) in out.iter_mut().zip(&self.data) {
            *o = R::from_f64(v);
        }
        Ok(())
    }

    pub fn restore_fermion<R: Real>(&self, f: &mut FermionField<R>) -> Result<(), String> {
        self.restore_slice(&mut f.data)
    }

    pub fn restore_multi<R: Real>(&self, f: &mut MultiFermionField<R>) -> Result<(), String> {
        self.restore_slice(&mut f.data)
    }
}

/// FNV-1a content hash of a gauge configuration (dims folded in, every
/// link element's f64 bit pattern eaten in storage order). Cheap, and
/// any single changed link moves it; not cryptographic — it guards
/// against resuming a solve on the wrong configuration, not tampering.
pub fn gauge_hash<R: Real>(u: &GaugeField<R>) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |w: u64| {
        h ^= w;
        h = h.wrapping_mul(PRIME);
    };
    for dir in 0..4 {
        for p in 0..2 {
            let arr = &u.data[dir][p];
            eat(arr.len() as u64 | ((dir as u64) << 32) | ((p as u64) << 40));
            for v in arr {
                eat(v.to_f64().to_bits());
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{Geometry, LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 2, 2).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn fermion_roundtrip_is_bitwise_both_precisions() {
        let g = geom();
        let mut rng = Rng::seeded(3);
        let f32f: FermionField<f32> = FermionField::gaussian(&g, &mut rng);
        let snap = FieldSnap::of_fermion("x", &f32f);
        let mut back: FermionField<f32> = FermionField::zeros(&g);
        snap.restore_fermion(&mut back).unwrap();
        let bits = |f: &FermionField<f32>| -> Vec<u32> {
            f.data.iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(bits(&f32f), bits(&back));

        let f64f: FermionField<f64> = FermionField::gaussian(&g, &mut rng);
        let snap = FieldSnap::of_fermion("x", &f64f);
        let mut back: FermionField<f64> = FermionField::zeros(&g);
        snap.restore_fermion(&mut back).unwrap();
        let bits64 = |f: &FermionField<f64>| -> Vec<u64> {
            f.data.iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(bits64(&f64f), bits64(&back));
    }

    #[test]
    fn restore_rejects_precision_and_length_mismatch() {
        let g = geom();
        let mut rng = Rng::seeded(4);
        let f: FermionField<f32> = FermionField::gaussian(&g, &mut rng);
        let snap = FieldSnap::of_fermion("r", &f);
        let mut wrong: FermionField<f64> = FermionField::zeros(&g);
        let e = snap.restore_fermion(&mut wrong).unwrap_err();
        assert!(e.contains("f32") && e.contains("f64"), "{e}");
        let mut short = [0.0f32; 3];
        let e = snap.restore_slice(&mut short).unwrap_err();
        assert!(e.contains("values"), "{e}");
    }

    #[test]
    fn gauge_hash_moves_with_content() {
        let g = geom();
        let mut rng = Rng::seeded(5);
        let u: GaugeField<f32> = GaugeField::random(&g, &mut rng);
        let h1 = gauge_hash(&u);
        assert_eq!(h1, gauge_hash(&u), "hash is deterministic");
        let mut u2 = u.clone();
        u2.data[1][0][0] += 1e-3;
        assert_ne!(h1, gauge_hash(&u2), "one changed link moves the hash");
        let unit: GaugeField<f32> = GaugeField::unit(&g);
        assert_ne!(h1, gauge_hash(&unit));
    }
}
