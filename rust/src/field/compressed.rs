//! Two-row compressed gauge links.
//!
//! An SU(3) matrix is fully determined by its first two rows: unitarity
//! plus det = 1 force the third row to be the conjugate cross product
//! `row2 = conj(row0 × row1)`. Storing only the first two rows cuts a
//! link from 18 to 12 reals — a 1/3 reduction of the gauge stream, which
//! the bandwidth-bound hopping kernel (B/F ≈ 1.12) converts directly
//! into throughput: the reconstruction flops are free under the memory
//! roofline. This is the standard compression of the AVX-512/KNL Wilson
//! kernels by the same authors (arXiv:1811.00893, 1712.01505) and of
//! QPhiX/Grid/QUDA.
//!
//! ## Layout
//!
//! [`CompressedGaugeField`] mirrors [`GaugeField`]'s AoSoA layout with
//! the row axis truncated to 2:
//!
//! ```text
//! full link tile : [a: 0..3][b: 0..3][re/im][VLEN]   (CC2 = 18 vectors)
//! two-row tile   : [a: 0..2][b: 0..3][re/im][VLEN]   (CT2 = 12 vectors)
//! ```
//!
//! The first [`CT2`] component vectors of a full tile *are* the two-row
//! tile, so compression is a pure copy and in-tile reconstruction only
//! appends the 6 third-row vectors.
//!
//! ## Reconstruction contract
//!
//! Every reconstruction path — the whole-field [`reconstruct`], the
//! per-tile [`reconstruct_third_row`] the kernels use, and the per-site
//! [`CompressedGaugeField::link`] the EO1/EO2 halo helpers use — runs
//! the *same* elementwise arithmetic ([`third_row_elem`]) in the storage
//! scalar `R`. Consequences, relied on by kernels and tests:
//!
//! * `compress(reconstruct(c)) == c` **bitwise** (rows 0-1 are copies);
//! * the compressed kernel output is **bitwise identical** (at f32 and
//!   f64) to the uncompressed kernel applied to `c.reconstruct()`;
//! * against the *original* field the third row differs only by the
//!   rounding of the cross product (≤ ~1e-13 relative at f64 for exact
//!   SU(3) input).
//!
//! Do **not** compress non-unitary links (e.g. stout/APE-smeared fields
//! before reprojection): the cross-product rebuild silently projects
//! them onto SU(3) and the operator would no longer match the input
//! configuration. Compression is correct exactly when the links are.
//!
//! [`reconstruct`]: CompressedGaugeField::reconstruct

use crate::algebra::{Complex, Real, Su3};
use crate::lattice::{Dir, EoLayout, Geometry, Parity, SiteCoord, CC2, NCOL, NREIM};

/// Component vectors per two-row link tile (2 rows x 3 cols x re/im).
pub const CT2: usize = 2 * NCOL * NREIM; // 12

/// One third-row complex entry from the first two rows, elementwise in
/// `R`: `conj(a*d - b*c)` where, for output column `j`,
/// `a = u[0][j+1], b = u[0][j+2], c = u[1][j+1], d = u[1][j+2]`
/// (column indices mod 3). This is the *single canonical expression*
/// shared by all reconstruction paths — tile, per-site, and whole-field
/// — so their outputs agree bitwise at any precision.
#[inline(always)]
pub fn third_row_elem<R: Real>(a: (R, R), b: (R, R), c: (R, R), d: (R, R)) -> (R, R) {
    let re = (a.0 * d.0 - a.1 * d.1) - (b.0 * c.0 - b.1 * c.1);
    let im = (a.0 * d.1 + a.1 * d.0) - (b.0 * c.1 + b.1 * c.0);
    (re, -im)
}

/// Fill the 6 third-row component vectors of a full-layout (`CC2 * v`)
/// link tile whose first [`CT2`]` * v` values hold the two stored rows.
/// Lanewise in `R`; lane `l` sees exactly [`third_row_elem`], so the
/// rebuild commutes bitwise with any pure lane permutation of the
/// stored rows (the backward-link shuffle relies on this).
#[inline]
pub fn reconstruct_third_row<R: Real>(tile: &mut [R], v: usize) {
    debug_assert!(tile.len() >= CC2 * v);
    let go = |a: usize, b: usize, reim: usize| ((a * NCOL + b) * NREIM + reim) * v;
    for j in 0..NCOL {
        let j1 = (j + 1) % NCOL;
        let j2 = (j + 2) % NCOL;
        for l in 0..v {
            let a = (tile[go(0, j1, 0) + l], tile[go(0, j1, 1) + l]);
            let b = (tile[go(0, j2, 0) + l], tile[go(0, j2, 1) + l]);
            let c = (tile[go(1, j1, 0) + l], tile[go(1, j1, 1) + l]);
            let d = (tile[go(1, j2, 0) + l], tile[go(1, j2, 1) + l]);
            let (re, im) = third_row_elem(a, b, c, d);
            tile[go(2, j, 0) + l] = re;
            tile[go(2, j, 1) + l] = im;
        }
    }
}

/// Gauge field storing only the first two rows of every link:
/// `data[dir][parity]` is one AoSoA array of [`CT2`]-vector tiles.
#[derive(Clone, Debug)]
pub struct CompressedGaugeField<R: Real = f32> {
    pub layout: EoLayout,
    pub geom: Geometry,
    pub data: [[Vec<R>; 2]; 4],
}

impl<R: Real> CompressedGaugeField<R> {
    /// Scalar length of one direction+parity array (cf.
    /// [`EoLayout::gauge_len`], with 12 vectors per tile instead of 18).
    pub fn two_row_len(layout: &EoLayout) -> usize {
        layout.ntiles() * CT2 * layout.vlen()
    }

    /// Compress: copy rows 0-1 of every link tile (exact — the stored
    /// values are untouched; only the third row is dropped).
    pub fn compress(u: &crate::field::GaugeField<R>) -> CompressedGaugeField<R> {
        let layout = u.layout;
        let v = layout.vlen();
        let len = Self::two_row_len(&layout);
        let data = std::array::from_fn(|dir| {
            std::array::from_fn(|p| {
                let src = &u.data[dir][p];
                let mut dst = vec![R::ZERO; len];
                for tile in 0..layout.ntiles() {
                    dst[tile * CT2 * v..(tile + 1) * CT2 * v]
                        .copy_from_slice(&src[tile * CC2 * v..tile * CC2 * v + CT2 * v]);
                }
                dst
            })
        });
        CompressedGaugeField {
            layout,
            geom: u.geom,
            data,
        }
    }

    /// Reconstruct the full field: rows 0-1 are bit-exact copies of the
    /// stored data, row 2 is the canonical cross-product rebuild in `R`.
    /// The uncompressed kernel applied to this field is bitwise
    /// identical to the compressed kernel applied to `self`.
    pub fn reconstruct(&self) -> crate::field::GaugeField<R> {
        let layout = self.layout;
        let v = layout.vlen();
        let len = layout.gauge_len();
        let data = std::array::from_fn(|dir| {
            std::array::from_fn(|p| {
                let src = &self.data[dir][p];
                let mut dst = vec![R::ZERO; len];
                for tile in 0..layout.ntiles() {
                    let full = &mut dst[tile * CC2 * v..(tile + 1) * CC2 * v];
                    full[..CT2 * v].copy_from_slice(&src[tile * CT2 * v..(tile + 1) * CT2 * v]);
                    reconstruct_third_row(full, v);
                }
                dst
            })
        });
        crate::field::GaugeField {
            layout,
            geom: self.geom,
            data,
        }
    }

    /// Offset of the `[VLEN]` vector for stored-row component
    /// (a ∈ {0, 1}, b, reim) of one tile.
    #[inline]
    pub fn two_row_vec(&self, tile: usize, a: usize, b: usize, reim: usize) -> usize {
        debug_assert!(a < 2 && b < NCOL && reim < NREIM);
        (tile * CT2 + (a * NCOL + b) * NREIM + reim) * self.layout.vlen()
    }

    /// One link as an f64 matrix, third row rebuilt in `R` first (the
    /// same values a reconstructed [`GaugeField`]'s `link` would give).
    ///
    /// [`GaugeField`]: crate::field::GaugeField
    pub fn link(&self, dir: Dir, p: Parity, s: SiteCoord) -> Su3 {
        let arr = &self.data[dir.index()][p.index()];
        let lc = self.layout.site_to_lane(s);
        // read the two stored rows in R
        let mut rows = [[(R::ZERO, R::ZERO); NCOL]; 2];
        for (a, row) in rows.iter_mut().enumerate() {
            for (b, e) in row.iter_mut().enumerate() {
                let ro = self.two_row_vec(lc.tile, a, b, 0) + lc.lane;
                let io = self.two_row_vec(lc.tile, a, b, 1) + lc.lane;
                *e = (arr[ro], arr[io]);
            }
        }
        let mut u = Su3::default();
        for b in 0..NCOL {
            u.m[0][b] = Complex::new(rows[0][b].0.to_f64(), rows[0][b].1.to_f64());
            u.m[1][b] = Complex::new(rows[1][b].0.to_f64(), rows[1][b].1.to_f64());
            let j1 = (b + 1) % NCOL;
            let j2 = (b + 2) % NCOL;
            let (re, im) =
                third_row_elem(rows[0][j1], rows[0][j2], rows[1][j1], rows[1][j2]);
            u.m[2][b] = Complex::new(re.to_f64(), im.to_f64());
        }
        u
    }

    /// Convert into another precision (promotion exact, demotion rounds
    /// each stored component — reconstruction then happens at the new
    /// precision, like demoting the full field and recompressing).
    pub fn to_precision<S: Real>(&self) -> CompressedGaugeField<S> {
        CompressedGaugeField {
            layout: self.layout,
            geom: self.geom,
            data: std::array::from_fn(|d| {
                std::array::from_fn(|p| {
                    self.data[d][p]
                        .iter()
                        .map(|&v| S::from_f64(v.to_f64()))
                        .collect()
                })
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::GaugeField;
    use crate::lattice::{LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn compress_roundtrip_is_exact() {
        let mut rng = Rng::seeded(91);
        let u = GaugeField::<f64>::random(&geom(), &mut rng);
        let c = CompressedGaugeField::compress(&u);
        let back = c.reconstruct();
        // stored rows are bit-exact through the round trip
        let c2 = CompressedGaugeField::compress(&back);
        for d in 0..4 {
            for p in 0..2 {
                assert_eq!(c.data[d][p], c2.data[d][p], "rows must round-trip bitwise");
            }
        }
        // projection is idempotent: reconstructing again changes nothing
        let back2 = CompressedGaugeField::compress(&back).reconstruct();
        for d in 0..4 {
            for p in 0..2 {
                assert_eq!(back.data[d][p], back2.data[d][p]);
            }
        }
    }

    #[test]
    fn reconstructed_third_row_close_to_stored_f64() {
        let g = geom();
        let mut rng = Rng::seeded(92);
        let u = GaugeField::<f64>::random(&g, &mut rng);
        let back = CompressedGaugeField::compress(&u).reconstruct();
        let mut worst = 0.0f64;
        for d in 0..4 {
            for p in 0..2 {
                for (a, b) in u.data[d][p].iter().zip(&back.data[d][p]) {
                    worst = worst.max((a - b).abs());
                }
            }
        }
        assert!(worst < 1e-13, "third-row rebuild off by {worst}");
    }

    #[test]
    fn site_link_matches_reconstructed_field_exactly() {
        let g = geom();
        let mut rng = Rng::seeded(93);
        let u = GaugeField::<f32>::random(&g, &mut rng);
        let c = CompressedGaugeField::compress(&u);
        let back = c.reconstruct();
        let s = SiteCoord { t: 1, z: 2, y: 3, ix: 0 };
        for dir in Dir::ALL {
            for p in Parity::BOTH {
                let got = c.link(dir, p, s);
                let want = back.link(dir, p, s);
                for a in 0..3 {
                    for b in 0..3 {
                        assert_eq!(got.m[a][b], want.m[a][b], "{dir:?} {p:?} [{a}][{b}]");
                    }
                }
            }
        }
    }

    #[test]
    fn reconstructed_links_are_su3() {
        let g = geom();
        let mut rng = Rng::seeded(94);
        let u = GaugeField::<f64>::random(&g, &mut rng);
        let c = CompressedGaugeField::compress(&u);
        let s = SiteCoord { t: 0, z: 1, y: 2, ix: 1 };
        for dir in Dir::ALL {
            let w = c.link(dir, Parity::Even, s);
            assert!(w.unitarity_error() < 1e-12);
            assert!((w.det() - Complex::ONE).abs() < 1e-12);
        }
    }

    #[test]
    fn precision_demotion_commutes_with_compression() {
        let g = geom();
        let u = GaugeField::<f64>::random(&g, &mut Rng::seeded(95));
        let a = CompressedGaugeField::compress(&u).to_precision::<f32>();
        let lo: GaugeField<f32> = u.to_precision();
        let b = CompressedGaugeField::compress(&lo);
        for d in 0..4 {
            for p in 0..2 {
                assert_eq!(a.data[d][p], b.data[d][p]);
            }
        }
    }
}
