//! The gauge (link) field: one SU(3) matrix per site and direction,
//! stored per parity in the AoSoA layout (paper Eq. 7, gauge case),
//! generic over the [`Real`] storage scalar (default `f32`).

use crate::algebra::{Complex, Real, Su3};
use crate::lattice::{
    Dir, EoLayout, EvenOdd, Geometry, Parity, SiteCoord, IM, RE,
};
use crate::util::rng::Rng;

/// Gauge field: `data[dir][parity]` is one AoSoA array of 3x3 links.
#[derive(Clone, Debug)]
pub struct GaugeField<R: Real = f32> {
    pub layout: EoLayout,
    pub geom: Geometry,
    pub data: [[Vec<R>; 2]; 4],
}

impl<R: Real> GaugeField<R> {
    /// Cold start: all links are the identity.
    pub fn unit(geom: &Geometry) -> GaugeField<R> {
        let mut g = GaugeField::filled(geom, R::ZERO);
        for dir in 0..4 {
            for p in 0..2 {
                for tile in 0..g.layout.ntiles() {
                    for c in 0..3 {
                        let off = g.layout.gauge_vec(tile, c, c, RE);
                        for l in 0..g.layout.vlen() {
                            g.data[dir][p][off + l] = R::ONE;
                        }
                    }
                }
            }
        }
        g
    }

    /// Hot start: independent random SU(3) on every link.
    ///
    /// The RNG draw sequence is independent of `R`: the same seed gives
    /// the same physical configuration at every precision.
    pub fn random(geom: &Geometry, rng: &mut Rng) -> GaugeField<R> {
        let mut g = GaugeField::filled(geom, R::ZERO);
        for dir in Dir::ALL {
            for p in Parity::BOTH {
                // canonical site order for layout-independent content
                let sites: Vec<SiteCoord> = g.layout.sites().collect();
                for s in sites {
                    g.set_link(dir, p, s, &Su3::random(rng));
                }
            }
        }
        g
    }

    fn filled(geom: &Geometry, v: R) -> GaugeField<R> {
        let layout = EoLayout::new(geom);
        let len = layout.gauge_len();
        GaugeField {
            layout,
            geom: *geom,
            data: std::array::from_fn(|_| std::array::from_fn(|_| vec![v; len])),
        }
    }

    /// Convert into another precision (promotion is exact, demotion
    /// rounds each component).
    pub fn to_precision<S: Real>(&self) -> GaugeField<S> {
        GaugeField {
            layout: self.layout,
            geom: self.geom,
            data: std::array::from_fn(|d| {
                std::array::from_fn(|p| {
                    self.data[d][p]
                        .iter()
                        .map(|&v| S::from_f64(v.to_f64()))
                        .collect()
                })
            }),
        }
    }

    /// The link U_dir at a compacted site of the given parity.
    pub fn link(&self, dir: Dir, p: Parity, s: SiteCoord) -> Su3 {
        let arr = &self.data[dir.index()][p.index()];
        let lc = self.layout.site_to_lane(s);
        let mut u = Su3::default();
        for a in 0..3 {
            for b in 0..3 {
                let ro = self.layout.gauge_vec(lc.tile, a, b, RE) + lc.lane;
                let io = self.layout.gauge_vec(lc.tile, a, b, IM) + lc.lane;
                u.m[a][b] = Complex::new(arr[ro].to_f64(), arr[io].to_f64());
            }
        }
        u
    }

    pub fn set_link(&mut self, dir: Dir, p: Parity, s: SiteCoord, u: &Su3) {
        let layout = self.layout;
        let arr = &mut self.data[dir.index()][p.index()];
        for a in 0..3 {
            for b in 0..3 {
                arr[layout.gauge_elem(s, a, b, RE)] = R::from_f64(u.m[a][b].re);
                arr[layout.gauge_elem(s, a, b, IM)] = R::from_f64(u.m[a][b].im);
            }
        }
    }

    /// Link at a *lexical* local coordinate (x, y, z, t).
    pub fn link_at(&self, dir: Dir, x: usize, y: usize, z: usize, t: usize) -> Su3 {
        let p = Parity::of_site(x, y, z, t);
        debug_assert_eq!(EvenOdd::row_parity(y, z, t, p), x % 2);
        self.link(
            dir,
            p,
            SiteCoord {
                t,
                z,
                y,
                ix: EvenOdd::compact_x(x),
            },
        )
    }

    /// Average plaquette `<Re tr P>/3` over all sites and the 6 planes.
    /// Scalar implementation: an observable / test oracle, not a kernel.
    ///
    /// Links are materialized by tile passes over the AoSoA storage —
    /// tile offset computed once per tile, each `Su3` built exactly once
    /// — instead of a `link_at` lookup per plaquette corner (which
    /// re-derived the site's parity and tile index and rebuilt the same
    /// matrix ~12 times). Because tiles are t-outermost, the cache is a
    /// *per-t-slab* ring: the corner loop at time `t` only touches
    /// links at `t` and `t+1`, so at most three slabs (current, next,
    /// and slab 0 pinned for the periodic wrap) are live — O(V/T)
    /// memory, not O(V). The corner loop reads the slabs in the
    /// original lexical order with the original `mul`/`adj`/`trace`
    /// chain, so the accumulated f64 total is bit-for-bit the value the
    /// per-corner lookup produced (pinned by
    /// `plaquette_bit_matches_link_at_oracle`).
    pub fn plaquette(&self) -> f64 {
        let d = self.geom.local;
        let ext = [d.x, d.y, d.z, d.t];
        let slab_vol = d.x * d.y * d.z;
        let slex = |x: usize, y: usize, z: usize| (z * d.y + y) * d.x + x;

        let l = &self.layout;
        let vlen = l.vlen();
        // tiles are (t, z, yt, xt) with t outermost: one t-slab is the
        // contiguous tile range [t * tpt, (t + 1) * tpt)
        let tpt = l.nz * l.nyt * l.nxt;
        let build_slab = |t: usize| -> [Vec<Su3>; 4] {
            let mut slab: [Vec<Su3>; 4] =
                std::array::from_fn(|_| vec![Su3::IDENTITY; slab_vol]);
            for p in Parity::BOTH {
                for (dir, cache) in slab.iter_mut().enumerate() {
                    let arr = &self.data[dir][p.index()];
                    for tile in t * tpt..(t + 1) * tpt {
                        let base = tile * crate::lattice::CC2 * vlen;
                        for lane in 0..vlen {
                            let mut u = Su3::default();
                            for a in 0..3 {
                                for b in 0..3 {
                                    let off = base + ((a * 3 + b) * 2) * vlen + lane;
                                    u.m[a][b] = Complex::new(
                                        arr[off].to_f64(),
                                        arr[off + vlen].to_f64(),
                                    );
                                }
                            }
                            let s = l.lane_to_site(crate::lattice::LaneCoord {
                                tile,
                                lane,
                            });
                            let x = l.lexical_x(s, p);
                            cache[slex(x, s.y, s.z)] = u;
                        }
                    }
                }
            }
            slab
        };

        // slab ring: slab 0 stays pinned for the wrap at t = T-1
        let slab0 = build_slab(0);
        let mut cur: Option<[Vec<Su3>; 4]> = None;
        let mut next: Option<[Vec<Su3>; 4]> = if d.t > 1 { Some(build_slab(1)) } else { None };

        // corner loop: identical iteration and accumulation order (and
        // per-plaquette arithmetic) as the per-site lookup reference
        let mut total = 0.0;
        let mut count = 0usize;
        for t in 0..d.t {
            let cur_s: &[Vec<Su3>; 4] = if t == 0 { &slab0 } else { cur.as_ref().unwrap() };
            // mu < nu, so only cnu with nu = 3 ever leaves the slab
            let next_s: &[Vec<Su3>; 4] =
                if (t + 1) % d.t == 0 { &slab0 } else { next.as_ref().unwrap() };
            for z in 0..d.z {
                for y in 0..d.y {
                    for x in 0..d.x {
                        let coords = [x, y, z, t];
                        for mu in 0..4 {
                            for nu in (mu + 1)..4 {
                                let mut cmu = coords;
                                cmu[mu] = (cmu[mu] + 1) % ext[mu];
                                let mut cnu = coords;
                                cnu[nu] = (cnu[nu] + 1) % ext[nu];
                                let u1 = &cur_s[mu][slex(x, y, z)];
                                // cmu shifts mu <= 2: stays in this slab
                                let u2 = &cur_s[nu][slex(cmu[0], cmu[1], cmu[2])];
                                let u3 = if nu == 3 {
                                    &next_s[mu][slex(x, y, z)]
                                } else {
                                    &cur_s[mu][slex(cnu[0], cnu[1], cnu[2])]
                                };
                                let u4 = &cur_s[nu][slex(x, y, z)];
                                let p = u1.mul(u2).mul(&u3.adj()).mul(&u4.adj());
                                total += p.trace().re;
                                count += 1;
                            }
                        }
                    }
                }
            }
            // advance the ring: next becomes current, build t + 2
            cur = next.take();
            if t + 2 < d.t {
                next = Some(build_slab(t + 2));
            }
        }
        total / (3.0 * count as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{LatticeDims, Tiling};

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn unit_gauge_plaquette_is_one() {
        let g = GaugeField::<f32>::unit(&geom());
        assert!((g.plaquette() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_links_are_su3() {
        let mut rng = Rng::seeded(6);
        let g = GaugeField::<f32>::random(&geom(), &mut rng);
        let s = SiteCoord { t: 1, z: 2, y: 3, ix: 1 };
        for dir in Dir::ALL {
            for p in Parity::BOTH {
                let u = g.link(dir, p, s);
                // f32 storage => looser tolerance than the f64 Su3 tests
                assert!(u.unitarity_error() < 1e-5);
                assert!((u.det() - Complex::ONE).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn random_links_are_su3_tightly_at_f64() {
        let mut rng = Rng::seeded(6);
        let g = GaugeField::<f64>::random(&geom(), &mut rng);
        let s = SiteCoord { t: 1, z: 2, y: 3, ix: 1 };
        for dir in Dir::ALL {
            for p in Parity::BOTH {
                let u = g.link(dir, p, s);
                assert!(u.unitarity_error() < 1e-12);
                assert!((u.det() - Complex::ONE).abs() < 1e-12);
            }
        }
    }

    /// The per-corner `link_at` implementation `plaquette` replaced —
    /// kept verbatim as the oracle for the bit-for-bit pinning below.
    fn plaquette_link_at_oracle<R: crate::algebra::Real>(g: &GaugeField<R>) -> f64 {
        let d = g.geom.local;
        let mut total = 0.0;
        let mut count = 0usize;
        let ext = [d.x, d.y, d.z, d.t];
        for t in 0..d.t {
            for z in 0..d.z {
                for y in 0..d.y {
                    for x in 0..d.x {
                        let coords = [x, y, z, t];
                        for mu in 0..4 {
                            for nu in (mu + 1)..4 {
                                let mut cmu = coords;
                                cmu[mu] = (cmu[mu] + 1) % ext[mu];
                                let mut cnu = coords;
                                cnu[nu] = (cnu[nu] + 1) % ext[nu];
                                let u1 = g.link_at(
                                    Dir::from_index(mu),
                                    coords[0], coords[1], coords[2], coords[3],
                                );
                                let u2 =
                                    g.link_at(Dir::from_index(nu), cmu[0], cmu[1], cmu[2], cmu[3]);
                                let u3 =
                                    g.link_at(Dir::from_index(mu), cnu[0], cnu[1], cnu[2], cnu[3]);
                                let u4 = g.link_at(
                                    Dir::from_index(nu),
                                    coords[0], coords[1], coords[2], coords[3],
                                );
                                let p = u1.mul(&u2).mul(&u3.adj()).mul(&u4.adj());
                                total += p.trace().re;
                                count += 1;
                            }
                        }
                    }
                }
            }
        }
        total / (3.0 * count as f64)
    }

    #[test]
    fn plaquette_bit_matches_link_at_oracle() {
        // the tile-cached implementation must reproduce the per-corner
        // lookup EXACTLY — same accumulation order, same f64 bits
        for seed in [21u64, 22] {
            let mut rng = Rng::seeded(seed);
            let g32 = GaugeField::<f32>::random(&geom(), &mut rng);
            assert_eq!(g32.plaquette(), plaquette_link_at_oracle(&g32));
        }
        let mut rng = Rng::seeded(23);
        let g64 = GaugeField::<f64>::random(&geom(), &mut rng);
        assert_eq!(g64.plaquette(), plaquette_link_at_oracle(&g64));
        // and on an asymmetric lattice with a different tiling
        let geom = Geometry::single_rank(
            crate::lattice::LatticeDims::new(8, 4, 2, 6).unwrap(),
            Tiling::new(4, 2).unwrap(),
        )
        .unwrap();
        let g = GaugeField::<f32>::random(&geom, &mut Rng::seeded(24));
        assert_eq!(g.plaquette(), plaquette_link_at_oracle(&g));
    }

    #[test]
    fn random_plaquette_is_small() {
        // <P> ~ 0 for a strongly disordered (hot) configuration
        let mut rng = Rng::seeded(7);
        let g = GaugeField::<f32>::random(&geom(), &mut rng);
        let p = g.plaquette();
        assert!(p.abs() < 0.1, "hot plaquette {p}");
    }

    #[test]
    fn link_roundtrip() {
        let mut rng = Rng::seeded(8);
        let mut g = GaugeField::<f32>::unit(&geom());
        let u = Su3::random(&mut rng);
        let s = SiteCoord { t: 0, z: 1, y: 2, ix: 0 };
        g.set_link(Dir::Z, Parity::Odd, s, &u);
        assert!(g.link(Dir::Z, Parity::Odd, s).dist(&u) < 1e-6);
    }

    #[test]
    fn link_at_consistent_with_parity_storage() {
        let mut rng = Rng::seeded(9);
        let g = GaugeField::<f32>::random(&geom(), &mut rng);
        // lexical (3,2,1,0): parity = 0 (even), ix = 1
        let via_lex = g.link_at(Dir::X, 3, 2, 1, 0);
        let via_eo = g.link(
            Dir::X,
            Parity::Even,
            SiteCoord { t: 0, z: 1, y: 2, ix: 1 },
        );
        assert!(via_lex.dist(&via_eo) < 1e-12);
    }

    #[test]
    fn precision_demotion_matches_direct_f32_generation() {
        // generating at f64 then demoting equals generating at f32
        let g = geom();
        let hi = GaugeField::<f64>::random(&g, &mut Rng::seeded(10));
        let lo = GaugeField::<f32>::random(&g, &mut Rng::seeded(10));
        let demoted: GaugeField<f32> = hi.to_precision();
        for d in 0..4 {
            for p in 0..2 {
                assert_eq!(demoted.data[d][p], lo.data[d][p]);
            }
        }
    }
}
