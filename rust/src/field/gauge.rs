//! The gauge (link) field: one SU(3) matrix per site and direction,
//! stored per parity in the AoSoA layout (paper Eq. 7, gauge case),
//! generic over the [`Real`] storage scalar (default `f32`).

use crate::algebra::{Complex, Real, Su3};
use crate::lattice::{
    Dir, EoLayout, EvenOdd, Geometry, Parity, SiteCoord, IM, RE,
};
use crate::util::rng::Rng;

/// Gauge field: `data[dir][parity]` is one AoSoA array of 3x3 links.
#[derive(Clone, Debug)]
pub struct GaugeField<R: Real = f32> {
    pub layout: EoLayout,
    pub geom: Geometry,
    pub data: [[Vec<R>; 2]; 4],
}

impl<R: Real> GaugeField<R> {
    /// Cold start: all links are the identity.
    pub fn unit(geom: &Geometry) -> GaugeField<R> {
        let mut g = GaugeField::filled(geom, R::ZERO);
        for dir in 0..4 {
            for p in 0..2 {
                for tile in 0..g.layout.ntiles() {
                    for c in 0..3 {
                        let off = g.layout.gauge_vec(tile, c, c, RE);
                        for l in 0..g.layout.vlen() {
                            g.data[dir][p][off + l] = R::ONE;
                        }
                    }
                }
            }
        }
        g
    }

    /// Hot start: independent random SU(3) on every link.
    ///
    /// The RNG draw sequence is independent of `R`: the same seed gives
    /// the same physical configuration at every precision.
    pub fn random(geom: &Geometry, rng: &mut Rng) -> GaugeField<R> {
        let mut g = GaugeField::filled(geom, R::ZERO);
        for dir in Dir::ALL {
            for p in Parity::BOTH {
                // canonical site order for layout-independent content
                let sites: Vec<SiteCoord> = g.layout.sites().collect();
                for s in sites {
                    g.set_link(dir, p, s, &Su3::random(rng));
                }
            }
        }
        g
    }

    fn filled(geom: &Geometry, v: R) -> GaugeField<R> {
        let layout = EoLayout::new(geom);
        let len = layout.gauge_len();
        GaugeField {
            layout,
            geom: *geom,
            data: std::array::from_fn(|_| std::array::from_fn(|_| vec![v; len])),
        }
    }

    /// Convert into another precision (promotion is exact, demotion
    /// rounds each component).
    pub fn to_precision<S: Real>(&self) -> GaugeField<S> {
        GaugeField {
            layout: self.layout,
            geom: self.geom,
            data: std::array::from_fn(|d| {
                std::array::from_fn(|p| {
                    self.data[d][p]
                        .iter()
                        .map(|&v| S::from_f64(v.to_f64()))
                        .collect()
                })
            }),
        }
    }

    /// The link U_dir at a compacted site of the given parity.
    pub fn link(&self, dir: Dir, p: Parity, s: SiteCoord) -> Su3 {
        let arr = &self.data[dir.index()][p.index()];
        let lc = self.layout.site_to_lane(s);
        let mut u = Su3::default();
        for a in 0..3 {
            for b in 0..3 {
                let ro = self.layout.gauge_vec(lc.tile, a, b, RE) + lc.lane;
                let io = self.layout.gauge_vec(lc.tile, a, b, IM) + lc.lane;
                u.m[a][b] = Complex::new(arr[ro].to_f64(), arr[io].to_f64());
            }
        }
        u
    }

    pub fn set_link(&mut self, dir: Dir, p: Parity, s: SiteCoord, u: &Su3) {
        let layout = self.layout;
        let arr = &mut self.data[dir.index()][p.index()];
        for a in 0..3 {
            for b in 0..3 {
                arr[layout.gauge_elem(s, a, b, RE)] = R::from_f64(u.m[a][b].re);
                arr[layout.gauge_elem(s, a, b, IM)] = R::from_f64(u.m[a][b].im);
            }
        }
    }

    /// Link at a *lexical* local coordinate (x, y, z, t).
    pub fn link_at(&self, dir: Dir, x: usize, y: usize, z: usize, t: usize) -> Su3 {
        let p = Parity::of_site(x, y, z, t);
        debug_assert_eq!(EvenOdd::row_parity(y, z, t, p), x % 2);
        self.link(
            dir,
            p,
            SiteCoord {
                t,
                z,
                y,
                ix: EvenOdd::compact_x(x),
            },
        )
    }

    /// Average plaquette `<Re tr P>/3` over all sites and the 6 planes.
    /// Scalar implementation: an observable / test oracle, not a kernel.
    pub fn plaquette(&self) -> f64 {
        let d = self.geom.local;
        let mut total = 0.0;
        let mut count = 0usize;
        let ext = [d.x, d.y, d.z, d.t];
        let mut coords = [0usize; 4];
        for t in 0..d.t {
            for z in 0..d.z {
                for y in 0..d.y {
                    for x in 0..d.x {
                        coords[0] = x;
                        coords[1] = y;
                        coords[2] = z;
                        coords[3] = t;
                        for mu in 0..4 {
                            for nu in (mu + 1)..4 {
                                let mut cmu = coords;
                                cmu[mu] = (cmu[mu] + 1) % ext[mu];
                                let mut cnu = coords;
                                cnu[nu] = (cnu[nu] + 1) % ext[nu];
                                let u1 = self.link_at(
                                    Dir::from_index(mu),
                                    coords[0], coords[1], coords[2], coords[3],
                                );
                                let u2 = self.link_at(
                                    Dir::from_index(nu),
                                    cmu[0], cmu[1], cmu[2], cmu[3],
                                );
                                let u3 = self.link_at(
                                    Dir::from_index(mu),
                                    cnu[0], cnu[1], cnu[2], cnu[3],
                                );
                                let u4 = self.link_at(
                                    Dir::from_index(nu),
                                    coords[0], coords[1], coords[2], coords[3],
                                );
                                let p = u1.mul(&u2).mul(&u3.adj()).mul(&u4.adj());
                                total += p.trace().re;
                                count += 1;
                            }
                        }
                    }
                }
            }
        }
        total / (3.0 * count as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{LatticeDims, Tiling};

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn unit_gauge_plaquette_is_one() {
        let g = GaugeField::<f32>::unit(&geom());
        assert!((g.plaquette() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_links_are_su3() {
        let mut rng = Rng::seeded(6);
        let g = GaugeField::<f32>::random(&geom(), &mut rng);
        let s = SiteCoord { t: 1, z: 2, y: 3, ix: 1 };
        for dir in Dir::ALL {
            for p in Parity::BOTH {
                let u = g.link(dir, p, s);
                // f32 storage => looser tolerance than the f64 Su3 tests
                assert!(u.unitarity_error() < 1e-5);
                assert!((u.det() - Complex::ONE).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn random_links_are_su3_tightly_at_f64() {
        let mut rng = Rng::seeded(6);
        let g = GaugeField::<f64>::random(&geom(), &mut rng);
        let s = SiteCoord { t: 1, z: 2, y: 3, ix: 1 };
        for dir in Dir::ALL {
            for p in Parity::BOTH {
                let u = g.link(dir, p, s);
                assert!(u.unitarity_error() < 1e-12);
                assert!((u.det() - Complex::ONE).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn random_plaquette_is_small() {
        // <P> ~ 0 for a strongly disordered (hot) configuration
        let mut rng = Rng::seeded(7);
        let g = GaugeField::<f32>::random(&geom(), &mut rng);
        let p = g.plaquette();
        assert!(p.abs() < 0.1, "hot plaquette {p}");
    }

    #[test]
    fn link_roundtrip() {
        let mut rng = Rng::seeded(8);
        let mut g = GaugeField::<f32>::unit(&geom());
        let u = Su3::random(&mut rng);
        let s = SiteCoord { t: 0, z: 1, y: 2, ix: 0 };
        g.set_link(Dir::Z, Parity::Odd, s, &u);
        assert!(g.link(Dir::Z, Parity::Odd, s).dist(&u) < 1e-6);
    }

    #[test]
    fn link_at_consistent_with_parity_storage() {
        let mut rng = Rng::seeded(9);
        let g = GaugeField::<f32>::random(&geom(), &mut rng);
        // lexical (3,2,1,0): parity = 0 (even), ix = 1
        let via_lex = g.link_at(Dir::X, 3, 2, 1, 0);
        let via_eo = g.link(
            Dir::X,
            Parity::Even,
            SiteCoord { t: 0, z: 1, y: 2, ix: 1 },
        );
        assert!(via_lex.dist(&via_eo) < 1e-12);
    }

    #[test]
    fn precision_demotion_matches_direct_f32_generation() {
        // generating at f64 then demoting equals generating at f32
        let g = geom();
        let hi = GaugeField::<f64>::random(&g, &mut Rng::seeded(10));
        let lo = GaugeField::<f32>::random(&g, &mut Rng::seeded(10));
        let demoted: GaugeField<f32> = hi.to_precision();
        for d in 0..4 {
            for p in 0..2 {
                assert_eq!(demoted.data[d][p], lo.data[d][p]);
            }
        }
    }
}
