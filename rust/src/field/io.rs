//! Binary tensor I/O shared with `python/compile/fieldio.py` and the
//! canonical-order conversions between Python/PJRT arrays and the AoSoA
//! fields.
//!
//! Format (little-endian): magic `LQCD0001`, u32 dtype (0 = f32, 1 = f64),
//! u32 ndim, u32 dims[ndim], then the data in C (row-major) order.
//!
//! Canonical array orders (matching the JAX side):
//!   spinor  (T, Z, Y, XH, spin, color, reim)
//!   gauge   (dir, parity, T, Z, Y, XH, colrow, colcol, reim)

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

use super::{FermionField, GaugeField};
use crate::algebra::Real;
use crate::lattice::{NCOL, NSPIN, SiteCoord};

const MAGIC: &[u8; 8] = b"LQCD0001";

/// A dense tensor read from disk.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f64>,
    /// dtype code as stored (0 = f32, 1 = f64)
    pub dtype: u32,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

pub fn read_tensor(path: &Path) -> Result<Tensor> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let dtype = u32::from_le_bytes(u32buf);
    f.read_exact(&mut u32buf)?;
    let ndim = u32::from_le_bytes(u32buf) as usize;
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        f.read_exact(&mut u32buf)?;
        dims.push(u32::from_le_bytes(u32buf) as usize);
    }
    let count: usize = dims.iter().product();
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    let data: Vec<f64> = match dtype {
        0 => {
            if raw.len() != count * 4 {
                bail!("{}: size mismatch", path.display());
            }
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
                .collect()
        }
        1 => {
            if raw.len() != count * 8 {
                bail!("{}: size mismatch", path.display());
            }
            raw.chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect()
        }
        other => bail!("{}: unknown dtype code {other}", path.display()),
    };
    Ok(Tensor { dims, data, dtype })
}

pub fn write_tensor_f32(path: &Path, dims: &[usize], data: &[f32]) -> Result<()> {
    let count: usize = dims.iter().product();
    if data.len() != count {
        bail!("write {}: dims/product mismatch", path.display());
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&0u32.to_le_bytes())?;
    f.write_all(&(dims.len() as u32).to_le_bytes())?;
    for &d in dims {
        f.write_all(&(d as u32).to_le_bytes())?;
    }
    for &v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Canonical <-> AoSoA conversions
// ---------------------------------------------------------------------------

/// Expected canonical element count of one parity spinor field.
pub fn canonical_spinor_len<R: Real>(field: &FermionField<R>) -> usize {
    field.layout.nsites() * NSPIN * NCOL * 2
}

/// Fill a fermion field from a canonical-order buffer
/// (T, Z, Y, XH, spin, color, reim).
pub fn fermion_from_canonical<R: Real>(
    field: &mut FermionField<R>,
    canon: &[f64],
) -> Result<()> {
    if canon.len() != canonical_spinor_len(field) {
        bail!(
            "canonical spinor length {} != expected {}",
            canon.len(),
            canonical_spinor_len(field)
        );
    }
    let l = field.layout;
    for (sidx, s) in l.sites().enumerate() {
        for spin in 0..NSPIN {
            for color in 0..NCOL {
                for reim in 0..2 {
                    let cidx = ((sidx * NSPIN + spin) * NCOL + color) * 2 + reim;
                    let off = l.spinor_elem(s, spin, color, reim);
                    field.data[off] = R::from_f64(canon[cidx]);
                }
            }
        }
    }
    Ok(())
}

/// Dump a fermion field to canonical order (T, Z, Y, XH, spin, color, reim).
pub fn fermion_to_canonical<R: Real>(field: &FermionField<R>) -> Vec<R> {
    let l = field.layout;
    let mut out = vec![R::ZERO; canonical_spinor_len(field)];
    for (sidx, s) in l.sites().enumerate() {
        for spin in 0..NSPIN {
            for color in 0..NCOL {
                for reim in 0..2 {
                    let cidx = ((sidx * NSPIN + spin) * NCOL + color) * 2 + reim;
                    out[cidx] = field.data[l.spinor_elem(s, spin, color, reim)];
                }
            }
        }
    }
    out
}

/// Fill a gauge field from a canonical-order buffer
/// (dir, parity, T, Z, Y, XH, a, b, reim).
pub fn gauge_from_canonical<R: Real>(
    gauge: &mut GaugeField<R>,
    canon: &[f64],
) -> Result<()> {
    let l = gauge.layout;
    let per_par = l.nsites() * NCOL * NCOL * 2;
    if canon.len() != 4 * 2 * per_par {
        bail!(
            "canonical gauge length {} != expected {}",
            canon.len(),
            4 * 2 * per_par
        );
    }
    let sites: Vec<SiteCoord> = l.sites().collect();
    for dir in 0..4 {
        for p in 0..2 {
            let base = (dir * 2 + p) * per_par;
            let arr = &mut gauge.data[dir][p];
            for (sidx, &s) in sites.iter().enumerate() {
                for a in 0..3 {
                    for b in 0..3 {
                        for reim in 0..2 {
                            let cidx =
                                base + ((sidx * NCOL + a) * NCOL + b) * 2 + reim;
                            arr[l.gauge_elem(s, a, b, reim)] = R::from_f64(canon[cidx]);
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Dump a gauge field to canonical order (dir, parity, T, Z, Y, XH, a, b, reim).
pub fn gauge_to_canonical<R: Real>(gauge: &GaugeField<R>) -> Vec<R> {
    let l = gauge.layout;
    let per_par = l.nsites() * NCOL * NCOL * 2;
    let mut out = vec![R::ZERO; 4 * 2 * per_par];
    let sites: Vec<SiteCoord> = l.sites().collect();
    for dir in 0..4 {
        for p in 0..2 {
            let base = (dir * 2 + p) * per_par;
            let arr = &gauge.data[dir][p];
            for (sidx, &s) in sites.iter().enumerate() {
                for a in 0..3 {
                    for b in 0..3 {
                        for reim in 0..2 {
                            let cidx =
                                base + ((sidx * NCOL + a) * NCOL + b) * 2 + reim;
                            out[cidx] = arr[l.gauge_elem(s, a, b, reim)];
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{Geometry, LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 2, 2).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn tensor_roundtrip(){
        let dir = std::env::temp_dir().join("lqcd_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        write_tensor_f32(&path, &[2, 3, 4], &data).unwrap();
        let t = read_tensor(&path).unwrap();
        assert_eq!(t.dims, vec![2, 3, 4]);
        assert_eq!(t.dtype, 0);
        assert_eq!(t.as_f32(), data);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fermion_canonical_roundtrip() {
        let g = geom();
        let mut rng = Rng::seeded(10);
        let f = crate::field::FermionField::gaussian(&g, &mut rng);
        let canon: Vec<f64> = fermion_to_canonical(&f).iter().map(|&v| v as f64).collect();
        let mut f2 = crate::field::FermionField::zeros(&g);
        fermion_from_canonical(&mut f2, &canon).unwrap();
        assert_eq!(f.data, f2.data);
    }

    #[test]
    fn gauge_canonical_roundtrip() {
        let g = geom();
        let mut rng = Rng::seeded(11);
        let u = crate::field::GaugeField::random(&g, &mut rng);
        let canon: Vec<f64> = gauge_to_canonical(&u).iter().map(|&v| v as f64).collect();
        let mut u2 = crate::field::GaugeField::unit(&g);
        gauge_from_canonical(&mut u2, &canon).unwrap();
        for d in 0..4 {
            for p in 0..2 {
                assert_eq!(u.data[d][p], u2.data[d][p]);
            }
        }
    }

    #[test]
    fn length_mismatch_rejected() {
        let g = geom();
        let mut f = crate::field::FermionField::zeros(&g);
        assert!(fermion_from_canonical(&mut f, &[0.0; 3]).is_err());
    }
}
