//! Fused, tile-grouped BLAS-1 kernels on raw AoSoA spinor slices.
//!
//! These are the building blocks the fused solver pipeline shards over
//! the thread team: every function operates on a slice covering whole
//! SIMD tiles (`len = ntiles * SC2 * vlen`), so a thread can be handed a
//! contiguous tile range of a [`super::FermionField`] and work with the
//! same ownership granularity as the hopping kernel.
//!
//! ## Reduction contract
//!
//! Every reduction in the stack groups identically: an f64 accumulator
//! per *tile* (iterating component-pair, then lane, inside the tile),
//! and tile partials combined in tile order. The serial field methods
//! (`norm2`/`dot_re`/`dot`), the fused kernels here, and the in-kernel
//! dot capture of [`crate::dslash::HoppingEo`] all share this grouping,
//! which is what makes solver residual histories *bitwise* independent
//! of fusion and of the team's thread count: a different thread count
//! only changes who computes a tile partial, never how any sum is
//! associated.
//!
//! The updates themselves (`axpy`-family) are elementwise and replicate
//! the exact expression shapes of the unfused field methods, so a fused
//! kernel produces bit-identical field contents to its two-pass
//! reference at any precision.

use crate::algebra::Real;
use crate::lattice::SC2;

/// Number of scalar values in one spinor tile.
#[inline(always)]
pub fn vals_per_tile(vlen: usize) -> usize {
    SC2 * vlen
}

/// Combine per-tile scalar partials in ascending tile order — the one
/// canonical fold every reduction history in the stack uses (see the
/// module doc). Call sites must route scalar partial sums through this
/// (or the `reduce_caps*` family for `[f64; 3]` captures) rather than
/// open-coding `+=`/`.sum()`; the invariant linter (`lqcd lint`, rule
/// `raw-f64-accum`) enforces it.
#[inline]
pub fn reduce_partials(partials: &[f64]) -> f64 {
    partials.iter().sum()
}

/// Column `i` of per-(site tile, RHS) scalar partials laid out
/// `partials[t * nrhs + i]`, combined in ascending tile order — the
/// strided sibling of [`reduce_partials`], bitwise identical to the
/// single-RHS fold over that RHS's tile partials.
#[inline]
pub fn reduce_partials_col(partials: &[f64], nrhs: usize, i: usize) -> f64 {
    debug_assert!(i < nrhs && partials.len() % nrhs == 0);
    partials.iter().skip(i).step_by(nrhs).sum()
}

/// Per-tile |x|²: component-pair → lane order, f64 accumulation.
#[inline]
pub fn norm2_tile<R: Real>(x: &[R], vlen: usize) -> f64 {
    debug_assert_eq!(x.len(), vals_per_tile(vlen));
    let mut acc = 0.0f64;
    for k in 0..SC2 / 2 {
        let ro = 2 * k * vlen;
        let io = ro + vlen;
        for l in 0..vlen {
            let xr = x[ro + l].to_f64();
            let xi = x[io + l].to_f64();
            acc += xr * xr + xi * xi;
        }
    }
    acc
}

/// Per-tile Re⟨a, b⟩ in the canonical order (equals the real part of the
/// sesquilinear dot; for split re/im storage this is the plain product
/// sum, grouped pair-by-pair).
#[inline]
pub fn dot_re_tile<R: Real>(a: &[R], b: &[R], vlen: usize) -> f64 {
    debug_assert_eq!(a.len(), vals_per_tile(vlen));
    debug_assert_eq!(b.len(), vals_per_tile(vlen));
    let mut acc = 0.0f64;
    for k in 0..SC2 / 2 {
        let ro = 2 * k * vlen;
        let io = ro + vlen;
        for l in 0..vlen {
            acc += a[ro + l].to_f64() * b[ro + l].to_f64()
                + a[io + l].to_f64() * b[io + l].to_f64();
        }
    }
    acc
}

/// Per-tile complex ⟨d, x⟩ (d conjugated) plus |x|², in the canonical
/// order: returns `[re, im, norm2]`. This is the capture the fused
/// kernels and the hopping kernel's dot tail share.
#[inline]
pub fn cdot_norm2_tile<R: Real>(d: &[R], x: &[R], vlen: usize) -> [f64; 3] {
    debug_assert_eq!(d.len(), vals_per_tile(vlen));
    debug_assert_eq!(x.len(), vals_per_tile(vlen));
    let (mut re, mut im, mut n2) = (0.0f64, 0.0f64, 0.0f64);
    for k in 0..SC2 / 2 {
        let ro = 2 * k * vlen;
        let io = ro + vlen;
        for l in 0..vlen {
            let dr = d[ro + l].to_f64();
            let di = d[io + l].to_f64();
            let xr = x[ro + l].to_f64();
            let xi = x[io + l].to_f64();
            re += dr * xr + di * xi;
            im += dr * xi - di * xr;
            n2 += xr * xr + xi * xi;
        }
    }
    [re, im, n2]
}

/// x += a * y, elementwise (bit-matches `FermionField::axpy`).
#[inline]
pub fn axpy_slice<R: Real>(x: &mut [R], a: R, y: &[R]) {
    debug_assert_eq!(x.len(), y.len());
    for (x, y) in x.iter_mut().zip(y) {
        *x += a * *y;
    }
}

/// x = a * x + y, elementwise (bit-matches `FermionField::xpay`).
#[inline]
pub fn xpay_slice<R: Real>(x: &mut [R], a: R, y: &[R]) {
    debug_assert_eq!(x.len(), y.len());
    for (x, y) in x.iter_mut().zip(y) {
        *x = a * *x + *y;
    }
}

/// Fused `x += a * y` and per-tile |x|² partials in one sweep.
///
/// `partials[i]` receives the canonical norm² of tile `i` of the range.
pub fn axpy_norm2_slice<R: Real>(
    x: &mut [R],
    a: R,
    y: &[R],
    vlen: usize,
    partials: &mut [f64],
) {
    let vpt = vals_per_tile(vlen);
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), partials.len() * vpt);
    for (i, p) in partials.iter_mut().enumerate() {
        let xt = &mut x[i * vpt..(i + 1) * vpt];
        axpy_slice(xt, a, &y[i * vpt..(i + 1) * vpt]);
        *p = norm2_tile(xt, vlen);
    }
}

/// The fused CG update: `x += alpha * p`, `r += neg_alpha * ap`, and
/// per-tile |r|² partials — three two-pass sweeps collapsed into one
/// pass over the tile range. Elementwise identical to the sequential
/// `axpy`/`axpy`/`norm2` reference.
#[allow(clippy::too_many_arguments)]
pub fn cg_update_slice<R: Real>(
    x: &mut [R],
    r: &mut [R],
    p: &[R],
    ap: &[R],
    alpha: R,
    neg_alpha: R,
    vlen: usize,
    partials: &mut [f64],
) {
    let vpt = vals_per_tile(vlen);
    debug_assert_eq!(x.len(), partials.len() * vpt);
    for (i, pt) in partials.iter_mut().enumerate() {
        let span = i * vpt..(i + 1) * vpt;
        axpy_slice(&mut x[span.clone()], alpha, &p[span.clone()]);
        let rt = &mut r[span.clone()];
        axpy_slice(rt, neg_alpha, &ap[span]);
        *pt = norm2_tile(rt, vlen);
    }
}

/// Complex x += (ar + i·ai) * y (bit-matches `FermionField::caxpy`).
pub fn caxpy_slice<R: Real>(x: &mut [R], ar: R, ai: R, y: &[R], vlen: usize) {
    debug_assert_eq!(x.len(), y.len());
    let pairs = x.len() / (2 * vlen);
    for k in 0..pairs {
        let ro = 2 * k * vlen;
        let io = ro + vlen;
        for l in 0..vlen {
            let or = y[ro + l];
            let oi = y[io + l];
            x[ro + l] += ar * or - ai * oi;
            x[io + l] += ar * oi + ai * or;
        }
    }
}

/// Fused complex `r += (ar + i·ai) * t` with per-tile capture of
/// `[Re⟨d, r⟩, Im⟨d, r⟩, |r|²]` (d conjugated). With `d = None` the
/// dot slots are zero and only the norm² slot is meaningful.
#[allow(clippy::too_many_arguments)]
pub fn caxpy_capture_slice<R: Real>(
    r: &mut [R],
    ar: R,
    ai: R,
    t: &[R],
    d: Option<&[R]>,
    vlen: usize,
    partials: &mut [[f64; 3]],
) {
    let vpt = vals_per_tile(vlen);
    debug_assert_eq!(r.len(), partials.len() * vpt);
    for (i, p) in partials.iter_mut().enumerate() {
        let span = i * vpt..(i + 1) * vpt;
        let rt = &mut r[span.clone()];
        caxpy_slice(rt, ar, ai, &t[span.clone()], vlen);
        *p = match d {
            Some(d) => cdot_norm2_tile(&d[span], rt, vlen),
            None => [0.0, 0.0, norm2_tile(rt, vlen)],
        };
    }
}

/// Fused `x += a * p + w * s` (complex): the two sequential `caxpy`
/// sweeps of the BiCGStab x-update collapsed into one pass, evaluating
/// the two updates in the same order elementwise.
#[allow(clippy::too_many_arguments)]
pub fn caxpy2_slice<R: Real>(
    x: &mut [R],
    ar: R,
    ai: R,
    p: &[R],
    wr: R,
    wi: R,
    s: &[R],
    vlen: usize,
) {
    debug_assert_eq!(x.len(), p.len());
    debug_assert_eq!(x.len(), s.len());
    let pairs = x.len() / (2 * vlen);
    for k in 0..pairs {
        let ro = 2 * k * vlen;
        let io = ro + vlen;
        for l in 0..vlen {
            let (pr, pi) = (p[ro + l], p[io + l]);
            let (sr, si) = (s[ro + l], s[io + l]);
            let xr = x[ro + l] + (ar * pr - ai * pi);
            let xi = x[io + l] + (ar * pi + ai * pr);
            x[ro + l] = xr + (wr * sr - wi * si);
            x[io + l] = xi + (wr * si + wi * sr);
        }
    }
}

/// Fused BiCGStab search-direction update:
/// `p = beta * (p + (-omega) * v) + r` in one sweep, evaluating the
/// unfused `caxpy(-omega, v)` → `cscale(beta)` → `axpy(1, r)` sequence
/// elementwise so the result is bit-identical to the three-pass
/// reference.
#[allow(clippy::too_many_arguments)]
pub fn p_update_slice<R: Real>(
    p: &mut [R],
    mor: R,
    moi: R,
    v: &[R],
    br: R,
    bi: R,
    r: &[R],
    vlen: usize,
) {
    debug_assert_eq!(p.len(), v.len());
    debug_assert_eq!(p.len(), r.len());
    let pairs = p.len() / (2 * vlen);
    for k in 0..pairs {
        let ro = 2 * k * vlen;
        let io = ro + vlen;
        for l in 0..vlen {
            let (vr, vi) = (v[ro + l], v[io + l]);
            // caxpy(-omega, v)
            let t1r = p[ro + l] + (mor * vr - moi * vi);
            let t1i = p[io + l] + (mor * vi + moi * vr);
            // cscale(beta)
            let t2r = br * t1r - bi * t1i;
            let t2i = br * t1i + bi * t1r;
            // axpy(ONE, r)
            p[ro + l] = t2r + R::ONE * r[ro + l];
            p[io + l] = t2i + R::ONE * r[io + l];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vecs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::seeded(seed);
        let a = (0..n).map(|_| rng.gaussian()).collect();
        let b = (0..n).map(|_| rng.gaussian()).collect();
        (a, b)
    }

    #[test]
    fn axpy_norm2_matches_two_pass_bitwise() {
        let vlen = 4;
        let vpt = vals_per_tile(vlen);
        let (mut x, y) = vecs(3 * vpt, 11);
        let mut x2 = x.clone();
        let mut partials = vec![0.0; 3];
        axpy_norm2_slice(&mut x, 0.37, &y, vlen, &mut partials);
        // reference: separate axpy, then canonical per-tile norm
        axpy_slice(&mut x2, 0.37, &y);
        assert_eq!(x, x2);
        let want: f64 = (0..3)
            .map(|i| norm2_tile(&x2[i * vpt..(i + 1) * vpt], vlen))
            .sum();
        let got: f64 = partials.iter().sum();
        assert_eq!(got, want, "partials must reproduce the canonical grouping");
    }

    #[test]
    fn cdot_norm2_tile_consistent_with_parts() {
        let vlen = 2;
        let vpt = vals_per_tile(vlen);
        let (d, x) = vecs(vpt, 13);
        let [re, _im, n2] = cdot_norm2_tile(&d, &x, vlen);
        assert_eq!(re, dot_re_tile(&d, &x, vlen));
        assert_eq!(n2, norm2_tile(&x, vlen));
        let [sre, sim, sn2] = cdot_norm2_tile(&x, &x, vlen);
        assert_eq!(sre, sn2, "self dot re == norm2");
        assert_eq!(sim, 0.0, "self dot is real");
    }

    #[test]
    fn p_update_matches_three_pass() {
        let vlen = 4;
        let vpt = vals_per_tile(vlen);
        let (mut p, v) = vecs(2 * vpt, 17);
        let (r, _) = vecs(2 * vpt, 19);
        let (mor, moi, br, bi) = (-0.3, 0.7, 1.1, -0.2);
        let mut p2 = p.clone();
        p_update_slice(&mut p, mor, moi, &v, br, bi, &r, vlen);
        // three-pass reference
        caxpy_slice(&mut p2, mor, moi, &v, vlen);
        for k in 0..p2.len() / (2 * vlen) {
            let (ro, io) = (2 * k * vlen, 2 * k * vlen + vlen);
            for l in 0..vlen {
                let (re, im) = (p2[ro + l], p2[io + l]);
                p2[ro + l] = br * re - bi * im;
                p2[io + l] = br * im + bi * re;
            }
        }
        axpy_slice(&mut p2, 1.0, &r);
        assert_eq!(p, p2);
    }

    #[test]
    fn caxpy2_matches_two_caxpys() {
        let vlen = 8;
        let vpt = vals_per_tile(vlen);
        let (mut x, p) = vecs(vpt, 23);
        let (s, _) = vecs(vpt, 29);
        let mut x2 = x.clone();
        caxpy2_slice(&mut x, 0.5, -0.25, &p, 0.125, 2.0, &s, vlen);
        caxpy_slice(&mut x2, 0.5, -0.25, &p, vlen);
        caxpy_slice(&mut x2, 0.125, 2.0, &s, vlen);
        assert_eq!(x, x2);
    }
}
