//! Lattice fields in the AoSoA layout: even/odd spinor fields and the
//! gauge field, plus binary I/O shared with the Python compile path.

pub mod blas;
mod fermion;
mod gauge;
pub mod io;

pub use fermion::FermionField;
pub use gauge::GaugeField;
