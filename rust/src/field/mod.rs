//! Lattice fields in the AoSoA layout: even/odd spinor fields and the
//! gauge field, plus binary I/O shared with the Python compile path,
//! and the multi-RHS block field ([`block`]) that interleaves N
//! right-hand sides for gauge-stream amortization.

pub mod blas;
pub mod block;
pub mod compressed;
mod fermion;
mod gauge;
pub mod io;
pub mod snapshot;

pub use block::MultiFermionField;
pub use compressed::{CompressedGaugeField, CT2};
pub use fermion::FermionField;
pub use gauge::GaugeField;
