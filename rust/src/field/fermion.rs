//! One-parity (even or odd) fermion field in the AoSoA layout, with the
//! linear-algebra kernels an iterative solver needs (axpy / dot / norm).
//!
//! The field is generic over the [`Real`] scalar (default `f32`, the
//! paper's single-precision benchmark case; `f64` backs the oracle and
//! the mixed-precision outer solve). Dot products always accumulate in
//! f64 regardless of `R`: CG stagnates if reductions are accumulated in
//! f32 over ~10^5 terms. All reductions use the canonical per-tile
//! grouping of [`super::blas`], so they are bitwise identical whether
//! computed serially, fused into another sweep, or sharded over the
//! thread team.

use super::blas;
use crate::algebra::{Complex, Real, Spinor};
use crate::lattice::{EoLayout, Geometry, SiteCoord, IM, NCOL, NSPIN, RE};
use crate::util::rng::Rng;

/// A fermion field on the sites of one parity.
#[derive(Clone, Debug)]
pub struct FermionField<R: Real = f32> {
    pub layout: EoLayout,
    pub data: Vec<R>,
}

impl<R: Real> FermionField<R> {
    pub fn zeros(geom: &Geometry) -> FermionField<R> {
        let layout = EoLayout::new(geom);
        FermionField {
            data: vec![R::ZERO; layout.spinor_len()],
            layout,
        }
    }

    /// Same layout and length as `self`, zero content.
    pub fn zeros_like(&self) -> FermionField<R> {
        FermionField {
            layout: self.layout,
            data: vec![R::ZERO; self.data.len()],
        }
    }

    /// Internal placeholder swapped out during normal-operator applies
    /// (zero-length; immediately replaced).
    pub(crate) fn placeholder() -> FermionField<R> {
        FermionField {
            layout: EoLayout {
                nt: 0,
                nz: 0,
                nyt: 0,
                nxt: 0,
                tiling: crate::lattice::Tiling::new(2, 1).unwrap(),
            },
            data: Vec::new(),
        }
    }

    /// Gaussian random source (mean 0, unit variance per component).
    ///
    /// The RNG draw sequence is independent of `R`, so the same seed
    /// produces the same physical field at every precision (modulo
    /// rounding into `R`).
    pub fn gaussian(geom: &Geometry, rng: &mut Rng) -> FermionField<R> {
        let mut f = FermionField::zeros(geom);
        // fill in canonical site order so the content is layout-independent
        for s in f.layout.sites() {
            for spin in 0..NSPIN {
                for color in 0..NCOL {
                    let re = R::from_f64(rng.gaussian());
                    let im = R::from_f64(rng.gaussian());
                    let off = f.layout.spinor_elem(s, spin, color, RE);
                    f.data[off] = re;
                    let off = f.layout.spinor_elem(s, spin, color, IM);
                    f.data[off] = im;
                }
            }
        }
        f
    }

    /// A point source: one spin/color component at one site.
    pub fn point_source(
        geom: &Geometry,
        site: SiteCoord,
        spin: usize,
        color: usize,
    ) -> FermionField<R> {
        let mut f = FermionField::zeros(geom);
        let off = f.layout.spinor_elem(site, spin, color, RE);
        f.data[off] = R::ONE;
        f
    }

    /// Convert into another precision (promotion is exact, demotion
    /// rounds each component).
    pub fn to_precision<S: Real>(&self) -> FermionField<S> {
        FermionField {
            layout: self.layout,
            data: self.data.iter().map(|&v| S::from_f64(v.to_f64())).collect(),
        }
    }

    pub fn site(&self, s: SiteCoord) -> Spinor {
        // resolve the (tile, lane) position once; component vectors are
        // then plain strided reads
        let lc = self.layout.site_to_lane(s);
        let mut out = Spinor::ZERO;
        for spin in 0..NSPIN {
            for color in 0..NCOL {
                let ro = self.layout.spinor_vec(lc.tile, spin, color, RE) + lc.lane;
                let io = self.layout.spinor_vec(lc.tile, spin, color, IM) + lc.lane;
                out.s[spin][color] =
                    Complex::new(self.data[ro].to_f64(), self.data[io].to_f64());
            }
        }
        out
    }

    pub fn set_site(&mut self, s: SiteCoord, v: &Spinor) {
        let lc = self.layout.site_to_lane(s);
        for spin in 0..NSPIN {
            for color in 0..NCOL {
                let ro = self.layout.spinor_vec(lc.tile, spin, color, RE) + lc.lane;
                let io = self.layout.spinor_vec(lc.tile, spin, color, IM) + lc.lane;
                self.data[ro] = R::from_f64(v.s[spin][color].re);
                self.data[io] = R::from_f64(v.s[spin][color].im);
            }
        }
    }

    pub fn fill(&mut self, v: R) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Number of SIMD tiles (the sharding unit of the thread team).
    #[inline]
    pub fn ntiles(&self) -> usize {
        self.layout.ntiles()
    }

    /// Scalar values per SIMD tile.
    #[inline]
    pub fn vals_per_tile(&self) -> usize {
        blas::vals_per_tile(self.layout.vlen())
    }

    /// View of the contiguous tile range `[b, e)` — the same ownership
    /// granularity the hopping kernel's `apply_tiles` uses, so BLAS-1
    /// work can be sharded over the team with kernel-compatible ranges.
    #[inline]
    pub fn tiles(&self, b: usize, e: usize) -> &[R] {
        let vpt = self.vals_per_tile();
        &self.data[b * vpt..e * vpt]
    }

    /// Mutable view of the contiguous tile range `[b, e)`.
    #[inline]
    pub fn tiles_mut(&mut self, b: usize, e: usize) -> &mut [R] {
        let vpt = self.vals_per_tile();
        &mut self.data[b * vpt..e * vpt]
    }

    /// True when every component is (±)0 — used by the solvers to skip
    /// the initial operator apply for a zero initial guess.
    pub fn is_zero(&self) -> bool {
        self.data.iter().all(|&v| v == R::ZERO)
    }

    /// self += a * o
    pub fn axpy(&mut self, a: R, o: &FermionField<R>) {
        debug_assert_eq!(self.data.len(), o.data.len());
        blas::axpy_slice(&mut self.data, a, &o.data);
    }

    /// self = a * self + o
    pub fn xpay(&mut self, a: R, o: &FermionField<R>) {
        debug_assert_eq!(self.data.len(), o.data.len());
        blas::xpay_slice(&mut self.data, a, &o.data);
    }

    /// Fused `self += a * o` returning |self|² from the same sweep —
    /// the residual update + reduction of one CG iteration in a single
    /// pass instead of two. Bit-identical to `axpy` followed by `norm2`.
    pub fn axpy_norm2(&mut self, a: R, o: &FermionField<R>) -> f64 {
        debug_assert_eq!(self.data.len(), o.data.len());
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        let mut total = 0.0f64;
        for tile in 0..self.layout.ntiles() {
            let span = tile * vpt..(tile + 1) * vpt;
            let xt = &mut self.data[span.clone()];
            blas::axpy_slice(xt, a, &o.data[span]);
            total += blas::norm2_tile(xt, vlen);
        }
        total
    }

    pub fn scale(&mut self, a: R) {
        self.data.iter_mut().for_each(|x| *x *= a);
    }

    /// self += a * o with a *complex* scalar (couples the re/im planes).
    pub fn caxpy(&mut self, a: Complex, o: &FermionField<R>) {
        debug_assert_eq!(self.data.len(), o.data.len());
        let (ar, ai) = (R::from_f64(a.re), R::from_f64(a.im));
        blas::caxpy_slice(&mut self.data, ar, ai, &o.data, self.layout.vlen());
    }

    /// Re <self, o>, accumulated in f64 per tile (canonical grouping).
    pub fn dot_re(&self, o: &FermionField<R>) -> f64 {
        debug_assert_eq!(self.data.len(), o.data.len());
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        (0..self.layout.ntiles())
            .map(|t| {
                let span = t * vpt..(t + 1) * vpt;
                blas::dot_re_tile(&self.data[span.clone()], &o.data[span], vlen)
            })
            .sum()
    }

    /// Full complex <self, o> (conjugating self), accumulated in f64
    /// per tile (canonical grouping).
    pub fn dot(&self, o: &FermionField<R>) -> Complex {
        debug_assert_eq!(self.data.len(), o.data.len());
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        let (mut re, mut im) = (0.0f64, 0.0f64);
        for t in 0..self.layout.ntiles() {
            let span = t * vpt..(t + 1) * vpt;
            let [tre, tim, _] =
                blas::cdot_norm2_tile(&self.data[span.clone()], &o.data[span], vlen);
            re += tre;
            im += tim;
        }
        Complex::new(re, im)
    }

    pub fn norm2(&self) -> f64 {
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        (0..self.layout.ntiles())
            .map(|t| blas::norm2_tile(&self.data[t * vpt..(t + 1) * vpt], vlen))
            .sum()
    }

    /// gamma5 in place: negate spin components 2 and 3.
    pub fn gamma5(&mut self) {
        let vlen = self.layout.vlen();
        for tile in 0..self.layout.ntiles() {
            for spin in 2..NSPIN {
                for color in 0..NCOL {
                    for reim in 0..2 {
                        let off = self.layout.spinor_vec(tile, spin, color, reim);
                        for l in 0..vlen {
                            self.data[off + l] = -self.data[off + l];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{LatticeDims, Tiling};

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            Tiling::new(4, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn site_roundtrip() {
        let g = geom();
        let mut f = FermionField::<f32>::zeros(&g);
        let mut rng = Rng::seeded(1);
        let mut v = Spinor::ZERO;
        for i in 0..4 {
            for c in 0..3 {
                v.s[i][c] = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        let s = SiteCoord { t: 1, z: 2, y: 3, ix: 2 };
        f.set_site(s, &v);
        assert!((f.site(s).sub(&v)).norm2() < 1e-12);
        // nothing else touched
        assert!(
            (f.norm2() - f.site(s).norm2()) < 1e-10,
            "other sites contaminated"
        );
    }

    #[test]
    fn site_roundtrip_is_exact_at_f64() {
        let g = geom();
        let mut f = FermionField::<f64>::zeros(&g);
        let mut rng = Rng::seeded(1);
        let mut v = Spinor::ZERO;
        for i in 0..4 {
            for c in 0..3 {
                v.s[i][c] = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        let s = SiteCoord { t: 1, z: 2, y: 3, ix: 2 };
        f.set_site(s, &v);
        assert_eq!((f.site(s).sub(&v)).norm2(), 0.0, "f64 storage is lossless");
    }

    #[test]
    fn axpy_dot_norm() {
        let g = geom();
        let mut rng = Rng::seeded(2);
        let a = FermionField::<f32>::gaussian(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        let want = a.norm2() + 4.0 * b.norm2() + 4.0 * a.dot_re(&b);
        assert!((c.norm2() - want).abs() / want.abs() < 1e-6);
    }

    #[test]
    fn dot_conjugate_symmetry() {
        let g = geom();
        let mut rng = Rng::seeded(3);
        let a = FermionField::<f32>::gaussian(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let ab = a.dot(&b);
        let ba = b.dot(&a);
        assert!((ab.re - ba.re).abs() < 1e-8);
        assert!((ab.im + ba.im).abs() < 1e-8);
        assert!((a.dot(&a).re - a.norm2()).abs() < 1e-8);
    }

    #[test]
    fn gamma5_involution_and_site_consistency() {
        let g = geom();
        let mut rng = Rng::seeded(4);
        let a = FermionField::<f32>::gaussian(&g, &mut rng);
        let mut b = a.clone();
        b.gamma5();
        let s = SiteCoord { t: 0, z: 1, y: 2, ix: 3 };
        assert!((b.site(s).sub(&a.site(s).gamma5())).norm2() < 1e-12);
        b.gamma5();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn point_source_norm() {
        let g = geom();
        let s = SiteCoord { t: 0, z: 0, y: 0, ix: 0 };
        let f = FermionField::<f32>::point_source(&g, s, 2, 1);
        assert_eq!(f.norm2(), 1.0);
        assert_eq!(f.site(s).s[2][1], Complex::ONE);
    }

    #[test]
    fn gaussian_content_independent_of_tiling() {
        // the same seed must produce the same *physical* field under any
        // tiling — storage order differs, site values must not.
        let d = LatticeDims::new(8, 4, 4, 4).unwrap();
        let g1 = Geometry::single_rank(d, Tiling::new(4, 2).unwrap()).unwrap();
        let g2 = Geometry::single_rank(d, Tiling::new(2, 4).unwrap()).unwrap();
        let f1 = FermionField::<f32>::gaussian(&g1, &mut Rng::seeded(9));
        let f2 = FermionField::<f32>::gaussian(&g2, &mut Rng::seeded(9));
        for s in f1.layout.sites() {
            assert!((f1.site(s).sub(&f2.site(s))).norm2() < 1e-12, "{s:?}");
        }
    }

    #[test]
    fn gaussian_content_independent_of_precision() {
        // same seed, same draws: the f32 field is the rounded f64 field
        let g = geom();
        let f32f = FermionField::<f32>::gaussian(&g, &mut Rng::seeded(17));
        let f64f = FermionField::<f64>::gaussian(&g, &mut Rng::seeded(17));
        for (a, b) in f32f.data.iter().zip(&f64f.data) {
            assert_eq!(*a, *b as f32);
        }
    }

    #[test]
    fn precision_roundtrip() {
        let g = geom();
        let f = FermionField::<f32>::gaussian(&g, &mut Rng::seeded(21));
        // f32 -> f64 -> f32 is lossless
        let back: FermionField<f32> = f.to_precision::<f64>().to_precision();
        assert_eq!(f.data, back.data);
        // f64 -> f32 rounds
        let wide = FermionField::<f64>::gaussian(&g, &mut Rng::seeded(22));
        let narrow: FermionField<f32> = wide.to_precision();
        let mut err = 0.0f64;
        for (a, b) in wide.data.iter().zip(&narrow.data) {
            err = err.max((a - *b as f64).abs());
        }
        assert!(err > 0.0, "demotion must actually round");
        assert!(err < 1e-6, "demotion error too large: {err}");
    }
}
