//! Multi-RHS block fermion field: N right-hand sides interleaved
//! RHS-innermost so one pass over the gauge field can feed all of them.
//!
//! ## Layout
//!
//! A [`MultiFermionField`] stores its N spinors tile-interleaved:
//!
//! ```text
//! [site_tile][rhs][ND][NC][2][VLEN]
//! ```
//!
//! i.e. the RHS axis sits *inside* the site-tile axis and *outside* the
//! component/lane axes. Each `(site_tile, rhs)` block is exactly one
//! standard AoSoA spinor tile (`SC2 * VLEN` values), so every tile-level
//! kernel of [`super::blas`] and the hopping kernel's per-tile machinery
//! apply unchanged to one RHS sub-tile — and the N sub-tiles of one site
//! tile are contiguous in memory, which is what lets the multi-RHS
//! dslash ([`crate::dslash::multi`]) stream the site's gauge links once
//! while applying them to all N spinors back to back in cache.
//!
//! ## Reduction contract
//!
//! All per-RHS reductions iterate site tiles in tile order for a fixed
//! RHS and use the canonical per-tile grouping of [`super::blas`]; a
//! per-RHS reduction over the block field is therefore **bitwise
//! identical** to the same reduction on the demuxed
//! [`FermionField`] — the property the block solver's "per-RHS history
//! matches the independent solve" guarantee rests on.
//!
//! ## Masking
//!
//! Masked sweeps take an `active` mask (one flag per RHS); masked-out
//! RHS are skipped entirely, so a converged system stops costing BLAS-1
//! (and, via the masked dslash, kernel) work while the stragglers keep
//! iterating. Masked data is left untouched — frozen at its converged
//! value. Only the warm-start residual helper lives here: the block
//! solvers' per-iteration sweeps run tile-sharded inside one team
//! region ([`crate::solver::block`]) directly on the [`blas`] slice
//! kernels, sharing this module's sub-tile indexing.

use super::blas;
use super::FermionField;
use crate::algebra::{Complex, Real, Spinor};
use crate::lattice::{EoLayout, Geometry, SiteCoord, IM, NCOL, NSPIN, RE};

/// N right-hand-side spinor fields of one parity, tile-interleaved.
#[derive(Clone, Debug)]
pub struct MultiFermionField<R: Real = f32> {
    pub layout: EoLayout,
    pub nrhs: usize,
    /// `[site_tile][rhs][SC2][vlen]`
    pub data: Vec<R>,
}

impl<R: Real> MultiFermionField<R> {
    pub fn zeros(geom: &Geometry, nrhs: usize) -> MultiFermionField<R> {
        assert!(nrhs >= 1, "nrhs must be at least 1");
        let layout = EoLayout::new(geom);
        MultiFermionField {
            data: vec![R::ZERO; layout.spinor_len() * nrhs],
            nrhs,
            layout,
        }
    }

    /// Same layout, RHS count and length as `self`, zero content.
    pub fn zeros_like(&self) -> MultiFermionField<R> {
        MultiFermionField {
            layout: self.layout,
            nrhs: self.nrhs,
            data: vec![R::ZERO; self.data.len()],
        }
    }

    /// Mux N ordinary fields (all of the same layout) into one block
    /// field; RHS `r` becomes sub-tile `r` of every site tile.
    pub fn from_rhs(fields: &[FermionField<R>]) -> MultiFermionField<R> {
        assert!(!fields.is_empty(), "need at least one RHS");
        let mut m = MultiFermionField {
            layout: fields[0].layout,
            nrhs: fields.len(),
            data: vec![R::ZERO; fields[0].data.len() * fields.len()],
        };
        for (r, f) in fields.iter().enumerate() {
            m.set_rhs(r, f);
        }
        m
    }

    /// Number of SIMD site tiles (the sharding unit of the thread team;
    /// each holds `nrhs` RHS sub-tiles).
    #[inline]
    pub fn site_tiles(&self) -> usize {
        self.layout.ntiles()
    }

    /// Scalar values per RHS sub-tile.
    #[inline]
    pub fn vals_per_tile(&self) -> usize {
        blas::vals_per_tile(self.layout.vlen())
    }

    /// Scalar values of one RHS (= an ordinary field's `data.len()`).
    #[inline]
    pub fn rhs_len(&self) -> usize {
        self.layout.spinor_len()
    }

    /// The `[site_tile][rhs]` sub-tile span start, in scalar values.
    #[inline]
    fn sub_tile_off(&self, site_tile: usize, r: usize) -> usize {
        (site_tile * self.nrhs + r) * self.vals_per_tile()
    }

    /// Demux RHS `r` into an ordinary field (exact copy).
    pub fn extract_rhs(&self, r: usize) -> FermionField<R> {
        assert!(r < self.nrhs);
        let vpt = self.vals_per_tile();
        let mut f = FermionField {
            layout: self.layout,
            data: vec![R::ZERO; self.rhs_len()],
        };
        for t in 0..self.site_tiles() {
            let src = self.sub_tile_off(t, r);
            f.data[t * vpt..(t + 1) * vpt]
                .copy_from_slice(&self.data[src..src + vpt]);
        }
        f
    }

    /// Demux all RHS.
    pub fn demux(&self) -> Vec<FermionField<R>> {
        (0..self.nrhs).map(|r| self.extract_rhs(r)).collect()
    }

    /// Mux an ordinary field into RHS slot `r` (exact copy).
    pub fn set_rhs(&mut self, r: usize, f: &FermionField<R>) {
        assert!(r < self.nrhs);
        assert_eq!(f.data.len(), self.rhs_len(), "layout mismatch");
        let vpt = self.vals_per_tile();
        for t in 0..self.site_tiles() {
            let dst = self.sub_tile_off(t, r);
            self.data[dst..dst + vpt].copy_from_slice(&f.data[t * vpt..(t + 1) * vpt]);
        }
    }

    /// Zero the data of RHS `r` only.
    pub fn fill_rhs(&mut self, r: usize, v: R) {
        assert!(r < self.nrhs);
        let vpt = self.vals_per_tile();
        for t in 0..self.site_tiles() {
            let dst = self.sub_tile_off(t, r);
            self.data[dst..dst + vpt].iter_mut().for_each(|x| *x = v);
        }
    }

    /// True when every component of every RHS is (±)0.
    pub fn is_zero(&self) -> bool {
        self.data.iter().all(|&v| v == R::ZERO)
    }

    /// Per-RHS |x_r|², canonical per-tile grouping in site-tile order —
    /// bitwise equal to `extract_rhs(r).norm2()`.
    pub fn norm2_per_rhs(&self) -> Vec<f64> {
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        let mut out = vec![0.0f64; self.nrhs];
        for t in 0..self.site_tiles() {
            for (r, acc) in out.iter_mut().enumerate() {
                let off = (t * self.nrhs + r) * vpt;
                *acc += blas::norm2_tile(&self.data[off..off + vpt], vlen);
            }
        }
        out
    }

    /// Per-RHS complex ⟨self_r, o_r⟩ (self conjugated), canonical
    /// grouping — bitwise equal to the demuxed `FermionField::dot`.
    pub fn dot_per_rhs(&self, o: &MultiFermionField<R>) -> Vec<Complex> {
        debug_assert_eq!(self.data.len(), o.data.len());
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        let mut out = vec![Complex::ZERO; self.nrhs];
        for t in 0..self.site_tiles() {
            for (r, acc) in out.iter_mut().enumerate() {
                let off = (t * self.nrhs + r) * vpt;
                let [re, im, _] = blas::cdot_norm2_tile(
                    &self.data[off..off + vpt],
                    &o.data[off..off + vpt],
                    vlen,
                );
                acc.re += re;
                acc.im += im;
            }
        }
        out
    }

    /// One site of RHS `r` as an f64 spinor — the block-field analog of
    /// [`FermionField::site`] (the halo pack reads through this, so the
    /// value is bitwise the demuxed field's).
    pub fn site_rhs(&self, s: SiteCoord, r: usize) -> Spinor {
        debug_assert!(r < self.nrhs);
        let lc = self.layout.site_to_lane(s);
        let sub = lc.tile * self.nrhs + r;
        let mut out = Spinor::ZERO;
        for spin in 0..NSPIN {
            for color in 0..NCOL {
                let ro = self.layout.spinor_vec(sub, spin, color, RE) + lc.lane;
                let io = self.layout.spinor_vec(sub, spin, color, IM) + lc.lane;
                out.s[spin][color] =
                    Complex::new(self.data[ro].to_f64(), self.data[io].to_f64());
            }
        }
        out
    }

    /// In-place gamma5 on every RHS: negate the lower two spins — the
    /// same expression as [`FermionField::gamma5`], applied per sub-tile,
    /// so the result bit-matches the demuxed fields'.
    pub fn gamma5(&mut self) {
        let vlen = self.layout.vlen();
        for sub in 0..self.site_tiles() * self.nrhs {
            for spin in 2..NSPIN {
                for color in 0..NCOL {
                    for reim in 0..2 {
                        let off = self.layout.spinor_vec(sub, spin, color, reim);
                        for v in &mut self.data[off..off + vlen] {
                            *v = -*v;
                        }
                    }
                }
            }
        }
    }

    /// Per-(site tile, RHS) `[Re⟨self_r, o_r⟩, Im⟨self_r, o_r⟩, |o_r|²]`
    /// capture partials for active RHS (`partials[tile * nrhs + r]`;
    /// masked entries untouched) — the post-pass analog of the kernels'
    /// fused [`crate::dslash::MultiDotCapture`], producing identical
    /// values on identical data. The distributed operators use this
    /// because their stores complete only after the EO2 halo merge.
    pub fn cdot_norm2_partials(
        &self,
        o: &MultiFermionField<R>,
        active: &[bool],
        partials: &mut [[f64; 3]],
    ) {
        debug_assert_eq!(self.data.len(), o.data.len());
        debug_assert_eq!(partials.len(), self.site_tiles() * self.nrhs);
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        for t in 0..self.site_tiles() {
            for (r, &on) in active.iter().enumerate() {
                if !on {
                    continue;
                }
                let off = (t * self.nrhs + r) * vpt;
                partials[t * self.nrhs + r] = blas::cdot_norm2_tile(
                    &self.data[off..off + vpt],
                    &o.data[off..off + vpt],
                    vlen,
                );
            }
        }
    }

    /// Per-RHS fused `self_r += a_r * o_r` with |self_r|² capture, for
    /// active RHS only. `rr[r]` is overwritten for active RHS and left
    /// untouched for masked ones.
    pub fn axpy_norm2_masked(
        &mut self,
        a: &[R],
        o: &MultiFermionField<R>,
        active: &[bool],
        rr: &mut [f64],
    ) {
        debug_assert_eq!(self.data.len(), o.data.len());
        let vlen = self.layout.vlen();
        let vpt = self.vals_per_tile();
        for (r, on) in active.iter().enumerate() {
            if *on {
                rr[r] = 0.0;
            }
        }
        for t in 0..self.site_tiles() {
            for r in 0..self.nrhs {
                if !active[r] {
                    continue;
                }
                let off = (t * self.nrhs + r) * vpt;
                let xt = &mut self.data[off..off + vpt];
                blas::axpy_slice(xt, a[r], &o.data[off..off + vpt]);
                rr[r] += blas::norm2_tile(xt, vlen);
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(8, 4, 4, 4).unwrap(),
            Tiling::new(4, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn mux_demux_roundtrip_is_exact() {
        let g = geom();
        let mut rng = Rng::seeded(31);
        let fields: Vec<FermionField<f32>> =
            (0..3).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let m = MultiFermionField::from_rhs(&fields);
        assert_eq!(m.nrhs, 3);
        for (r, f) in fields.iter().enumerate() {
            assert_eq!(m.extract_rhs(r).data, f.data, "rhs {r} not bit-exact");
        }
        let back = m.demux();
        for (a, b) in back.iter().zip(&fields) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn per_rhs_reductions_match_demuxed_bitwise() {
        let g = geom();
        let mut rng = Rng::seeded(32);
        let fields: Vec<FermionField<f32>> =
            (0..4).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let others: Vec<FermionField<f32>> =
            (0..4).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let m = MultiFermionField::from_rhs(&fields);
        let o = MultiFermionField::from_rhs(&others);
        let n2 = m.norm2_per_rhs();
        let dots = m.dot_per_rhs(&o);
        for r in 0..4 {
            assert_eq!(n2[r], fields[r].norm2(), "norm2 grouping differs at rhs {r}");
            let want = fields[r].dot(&others[r]);
            assert_eq!(dots[r].re, want.re);
            assert_eq!(dots[r].im, want.im);
        }
    }

    #[test]
    fn masked_sweeps_freeze_inactive_rhs() {
        let g = geom();
        let mut rng = Rng::seeded(33);
        let fields: Vec<FermionField<f32>> =
            (0..3).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let o_fields: Vec<FermionField<f32>> =
            (0..3).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let mut m = MultiFermionField::from_rhs(&fields);
        let o = MultiFermionField::from_rhs(&o_fields);
        let active = [true, false, true];
        let mut rr = [0.0f64; 3];
        m.axpy_norm2_masked(&[2.0, 3.0, -1.0], &o, &active, &mut rr);
        // active rhs match the single-field fused op bitwise
        for r in [0usize, 2] {
            let mut want = fields[r].clone();
            let a = [2.0f32, 3.0, -1.0][r];
            let wrr = want.axpy_norm2(a, &o_fields[r]);
            assert_eq!(m.extract_rhs(r).data, want.data);
            assert_eq!(rr[r], wrr);
        }
        // masked rhs untouched, rr slot untouched
        assert_eq!(m.extract_rhs(1).data, fields[1].data);
        assert_eq!(rr[1], 0.0);
    }

    #[test]
    fn site_rhs_and_gamma5_match_demuxed() {
        let g = geom();
        let mut rng = Rng::seeded(35);
        let fields: Vec<FermionField<f32>> =
            (0..3).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let mut m = MultiFermionField::from_rhs(&fields);
        let l = m.layout;
        for (i, s) in l.sites().enumerate() {
            if i % 7 != 0 {
                continue; // spot-check
            }
            for (r, f) in fields.iter().enumerate() {
                let a = m.site_rhs(s, r);
                let b = f.site(s);
                for spin in 0..4 {
                    for c in 0..3 {
                        assert_eq!(a.s[spin][c], b.s[spin][c], "rhs {r} site {s:?}");
                    }
                }
            }
        }
        m.gamma5();
        for (r, f) in fields.iter().enumerate() {
            let mut want = f.clone();
            want.gamma5();
            assert_eq!(m.extract_rhs(r).data, want.data, "gamma5 rhs {r}");
        }
    }

    #[test]
    fn cdot_norm2_partials_match_fused_capture_semantics() {
        let g = geom();
        let mut rng = Rng::seeded(36);
        let fields: Vec<FermionField<f32>> =
            (0..2).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let others: Vec<FermionField<f32>> =
            (0..2).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let w = MultiFermionField::from_rhs(&fields);
        let o = MultiFermionField::from_rhs(&others);
        let mut parts = vec![[f64::NAN; 3]; w.site_tiles() * 2];
        w.cdot_norm2_partials(&o, &[true, false], &mut parts);
        // active rhs: summing the partials in tile order reproduces the
        // canonical whole-field reductions bitwise
        let re: f64 = (0..w.site_tiles()).map(|t| parts[t * 2][0]).sum();
        let n2: f64 = (0..w.site_tiles()).map(|t| parts[t * 2][2]).sum();
        let dot = fields[0].dot(&others[0]);
        assert_eq!(re, dot.re);
        assert_eq!(n2, others[0].norm2());
        // masked rhs untouched
        assert!(parts.iter().skip(1).step_by(2).all(|p| p[0].is_nan()));
    }

    #[test]
    fn fill_rhs_touches_only_its_slot() {
        let g = geom();
        let mut rng = Rng::seeded(34);
        let fields: Vec<FermionField<f32>> =
            (0..2).map(|_| FermionField::gaussian(&g, &mut rng)).collect();
        let mut m = MultiFermionField::from_rhs(&fields);
        m.fill_rhs(0, 0.0);
        assert_eq!(m.extract_rhs(0).norm2(), 0.0);
        assert_eq!(m.extract_rhs(1).data, fields[1].data);
    }
}
