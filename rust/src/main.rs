//! `lqcd` — launcher for the even-odd Wilson matrix runtime.
//!
//! Subcommands:
//!   info                     machine model + host calibration + manifest
//!   solve                    even-odd CG/BiCGStab solve (native or PJRT)
//!   bench-table1             Table 1: 2D tiling sweep
//!   bench-fig8               Fig 8: gather vs shuffle cycle accounting
//!   bench-fig9               Fig 9: EO1/EO2 thread accounting (+balanced)
//!   bench-fig10              Fig 10: weak scaling projection
//!   bench-acle               §4.2: vectorized vs plain (~10x claim)
//!   bench-barrier            FLIB_BARRIER ablation

use std::process::ExitCode;

use lqcd::config::RunConfig;
use lqcd::coordinator::operator::{LinearOperator, NativeMdagM, NativeMeo};
use lqcd::field::{FermionField, GaugeField};
use lqcd::harness::{self, Opts};
use lqcd::lattice::{Geometry, LatticeDims, Tiling};
use lqcd::perf::{calibrate_host, A64fx};
use lqcd::solver;
use lqcd::util::cli;
use lqcd::util::rng::Rng;

const VALUE_OPTS: &[&str] = &[
    "dims", "tiling", "threads", "iters", "config", "kappa", "tol", "maxiter",
    "algorithm", "artifacts", "seed",
];

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<(), Box<dyn std::error::Error>> {
    let args = cli::parse(std::env::args().skip(1), VALUE_OPTS)?;
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());

    // config file as base, CLI overrides
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(d) = args.get("dims") {
        cfg.lattice.global = LatticeDims::parse(d)?;
    }
    if let Some(t) = args.get("tiling") {
        cfg.lattice.tiling = Tiling::parse(t)?;
    }
    cfg.solver.kappa = args.get_parse("kappa", cfg.solver.kappa)?;
    cfg.solver.tol = args.get_parse("tol", cfg.solver.tol)?;
    cfg.solver.maxiter = args.get_parse("maxiter", cfg.solver.maxiter)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.into();
    }
    if let Some(alg) = args.get("algorithm") {
        cfg.solver.algorithm = alg.to_string();
    }
    let use_pjrt = args.flag("pjrt") || cfg.solver.use_pjrt;
    let opts = Opts {
        iters: args.get_parse("iters", if args.flag("quick") { 10 } else { 50 })?,
        threads: args.get_parse("threads", cfg.parallel.threads_per_rank)?,
        quick: args.flag("quick"),
    };
    args.finish()?;

    match cmd.as_str() {
        "info" => info(&cfg),
        "solve" => solve(&cfg, use_pjrt),
        "bench-table1" => {
            let (report, _) = harness::table1::run(opts);
            println!("{report}");
            Ok(())
        }
        "bench-fig8" => {
            println!("{}", harness::fig8::run(opts).report);
            Ok(())
        }
        "bench-fig9" => {
            println!("{}", harness::fig9::run(opts).report);
            Ok(())
        }
        "bench-fig10" => {
            println!("{}", harness::fig10::run(opts).report);
            Ok(())
        }
        "bench-acle" => {
            println!("{}", harness::acle::run(opts).report);
            Ok(())
        }
        "bench-barrier" => {
            println!("{}", harness::barrier::run(opts).report);
            Ok(())
        }
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

fn info(cfg: &RunConfig) -> Result<(), Box<dyn std::error::Error>> {
    let a64 = A64fx::fugaku_normal();
    println!("# lqcd — even-odd Wilson matrix on a SIMD-tiled lattice");
    println!(
        "paper target: A64FX node, {:.0} GFlops f32 peak, {:.0} GB/s,",
        a64.peak_sp_gflops, a64.mem_bw_gbs
    );
    println!(
        "  B/F=1.12 memory roofline = {:.0} GFlops/node",
        a64.mem_roofline_gflops(1.12)
    );
    let host = calibrate_host();
    println!(
        "this host: ~{:.1} GFlops/core f32 (measured), ~{:.1} GB/s stream,",
        host.core_sp_gflops, host.mem_bw_gbs
    );
    println!(
        "  host B/F=1.12 roofline = {:.1} GFlops",
        host.mem_roofline_gflops(1.12)
    );
    println!(
        "config: lattice {} tiling {} kappa {}",
        cfg.lattice.global, cfg.lattice.tiling, cfg.solver.kappa
    );
    match lqcd::runtime::Runtime::load(&cfg.artifacts_dir) {
        Ok(rt) => {
            println!(
                "artifacts: {} compiled on {} (lattice {})",
                rt.manifest.artifacts.len(),
                rt.platform(),
                rt.manifest.dims
            );
            for a in &rt.manifest.artifacts {
                println!("  - {}", a.name);
            }
        }
        Err(e) => println!("artifacts: not loaded ({e})"),
    }
    Ok(())
}

fn solve(cfg: &RunConfig, use_pjrt: bool) -> Result<(), Box<dyn std::error::Error>> {
    let geom = Geometry::single_rank(cfg.lattice.global, cfg.lattice.tiling)
        .map_err(|e| e.to_string())?;
    let mut rng = Rng::seeded(cfg.seed);
    println!(
        "generating random gauge configuration on {} ...",
        cfg.lattice.global
    );
    let u = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u.plaquette());
    let b = FermionField::gaussian(&geom, &mut rng);
    let kappa = cfg.solver.kappa as f32;

    let sw = lqcd::util::timer::Stopwatch::start();
    let stats = if use_pjrt {
        let rt = lqcd::runtime::Runtime::load(&cfg.artifacts_dir)?;
        println!("PJRT platform: {}", rt.platform());
        let mut op = lqcd::runtime::PjrtMeo::new(&rt, &geom, &u, kappa)?;
        let mut x = FermionField::zeros(&geom);
        let stats =
            solver::bicgstab(&mut op, &mut x, &b, cfg.solver.tol, cfg.solver.maxiter);
        println!(
            "true |Mx-b|/|b| = {:.3e}",
            solver::residual::operator_residual(&mut op, &x, &b)
        );
        stats
    } else if cfg.solver.algorithm == "bicgstab" {
        let mut op = NativeMeo::new(&geom, u, kappa);
        let mut x = FermionField::zeros(&geom);
        let stats =
            solver::bicgstab(&mut op, &mut x, &b, cfg.solver.tol, cfg.solver.maxiter);
        println!(
            "true |Mx-b|/|b| = {:.3e}",
            solver::residual::operator_residual(&mut op, &x, &b)
        );
        stats
    } else {
        // CGNR: solve M^dag M x = M^dag b
        let mut op = NativeMdagM::new(&geom, u, kappa);
        let mut bp = b.clone();
        bp.gamma5();
        let mut mbp = FermionField::zeros(&geom);
        op.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
        let mut x = FermionField::zeros(&geom);
        let stats = solver::cg(&mut op, &mut x, &mbp, cfg.solver.tol, cfg.solver.maxiter);
        println!(
            "true |MdagM x - Mdag b|/|Mdag b| = {:.3e}",
            solver::residual::operator_residual(&mut op, &x, &mbp)
        );
        stats
    };
    let secs = sw.secs();
    println!(
        "{}: {} iterations, converged={}, rel residual {:.3e}, {:.2}s, {:.2} GFlops",
        if use_pjrt {
            "pjrt-bicgstab"
        } else {
            &cfg.solver.algorithm
        },
        stats.iterations,
        stats.converged,
        stats.rel_residual,
        secs,
        stats.flops as f64 / secs / 1e9,
    );
    Ok(())
}

const HELP: &str = "\
lqcd — even-odd Wilson fermion matrix for lattice QCD (A64FX paper repro)

USAGE: lqcd <command> [options]

COMMANDS:
  info          machine model, host calibration, artifact inventory
  solve         even-odd preconditioned solve on a random gauge field
  bench-table1  Table 1: 2D SIMD tiling sweep (GFlops)
  bench-fig8    Fig 8: gather/scatter vs shuffle bulk kernel accounting
  bench-fig9    Fig 9: EO1/EO2 per-thread load (+ balanced extension)
  bench-fig10   Fig 10: weak scaling to 512 nodes (TofuD model)
  bench-acle    vectorized vs plain scalar kernel (~10x claim)
  bench-barrier FLIB_BARRIER ablation (spin vs sleep barrier)

OPTIONS:
  --dims NXxNYxNZxNT   lattice (default 8x8x8x16)
  --tiling VXxVY       SIMD tiling (default 4x4)
  --threads N          threads per rank
  --iters N            measurement iterations
  --kappa X --tol X --maxiter N
  --algorithm cg|bicgstab
  --pjrt               execute the AOT artifacts on the hot path
  --artifacts DIR      artifact directory (default ./artifacts)
  --config FILE        TOML-subset run configuration
  --quick              smaller lattices/iterations
";
