//! `lqcd` — launcher for the even-odd Wilson matrix runtime.
//!
//! Subcommands:
//!   info                     machine model + host calibration + manifest
//!   solve                    even-odd CG/BiCGStab solve (native or PJRT)
//!   tune                     autotune tiling/threads/EO2 chunking, cache result
//!   bench-table1             Table 1: 2D tiling sweep
//!   bench-fig8               Fig 8: gather vs shuffle cycle accounting
//!   bench-fig9               Fig 9: EO1/EO2 thread accounting (+balanced)
//!   bench-fig10              Fig 10: weak scaling projection
//!   bench-acle               §4.2: vectorized vs plain (~10x claim)
//!   bench-barrier            FLIB_BARRIER ablation
//!   lint                     invariant linter + concurrency model checker

use std::process::ExitCode;
use std::sync::Arc;

use lqcd::algebra::Real;
use lqcd::comm::decompose::{extract_fermion, extract_gauge, insert_fermion};
use lqcd::comm::{
    netmodel, run_world_cfg, CommError, CommScalar, FaultPlan, HaloPlans, WorldOpts,
};
use lqcd::config::RunConfig;
use lqcd::coordinator::operator::{
    DistMultiMdagM, DistMultiMeo, LinearOperator, MultiMdagM, MultiNativeMeo,
    MultiOperator, NativeMdagM, NativeMeo,
};
use lqcd::coordinator::{BarrierKind, DistHopping, Eo2Schedule, Profiler, Report, Team};
use lqcd::dslash::{Compression, Links};
use lqcd::field::snapshot::gauge_hash;
use lqcd::field::{FermionField, GaugeField, MultiFermionField};
use lqcd::harness::{self, Opts};
use lqcd::lattice::{Geometry, LatticeDims, Parity, ProcGrid, Tiling};
use lqcd::perf::tune::{
    CacheLookup, ExplicitKnobs, HostFingerprint, KnobSource, TuneCache, TuneOptions,
};
use lqcd::perf::{
    auto_solver_threads_capped, calibrate_host, detect_slowdowns, run_tune,
    slowdown_summary, span_label, A64fx, AutoThreadBound, Metrics,
    SlowdownConfig, TraceData, Tracer,
};
use lqcd::solver::{
    self, load_latest, restore_from_buddy, BuddyCopy, Checkpointer, CkptOpts,
    HealthConfig, HealthEventKind, InnerAlgorithm, SolveErrorKind, SolverState,
};
use lqcd::util::cli;
use lqcd::util::json::JsonWriter;
use lqcd::util::rng::Rng;

const VALUE_OPTS: &[&str] = &[
    "dims", "tiling", "threads", "iters", "config", "kappa", "tol", "maxiter",
    "algorithm", "artifacts", "seed", "precision", "inner-tol", "max-outer",
    "nrhs", "gauge-compression", "grid", "eo2-schedule", "eo2-granularity",
    "tune-cache", "budget-ms", "inject-faults", "comm-timeout-ms",
    "comm-max-retries", "max-restarts", "trace", "checkpoint-dir",
    "checkpoint-every", "resume", "root", "json", "max-preemptions",
];

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<(), Box<dyn std::error::Error>> {
    let args = cli::parse(std::env::args().skip(1), VALUE_OPTS)?;
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());

    // config file as base, CLI overrides
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(d) = args.get("dims") {
        cfg.lattice.global = LatticeDims::parse(d)?;
    }
    if let Some(t) = args.get("tiling") {
        cfg.lattice.tiling = Tiling::parse(t)?;
        cfg.lattice.tiling_explicit = true;
    }
    if let Some(g) = args.get("grid") {
        cfg.lattice.grid = ProcGrid::parse(g)?;
    }
    cfg.solver.kappa = args.get_parse("kappa", cfg.solver.kappa)?;
    cfg.solver.tol = args.get_parse("tol", cfg.solver.tol)?;
    cfg.solver.maxiter = args.get_parse("maxiter", cfg.solver.maxiter)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.into();
    }
    if let Some(alg) = args.get("algorithm") {
        cfg.solver.algorithm = alg.to_string();
    }
    if let Some(p) = args.get("precision") {
        match p {
            "f32" | "f64" | "mixed" => cfg.solver.precision = p.to_string(),
            other => return Err(format!("--precision must be f32, f64 or mixed (got {other})").into()),
        }
    }
    cfg.solver.inner_tol = args.get_parse("inner-tol", cfg.solver.inner_tol)?;
    if !(cfg.solver.inner_tol > 0.0 && cfg.solver.inner_tol < 1.0) {
        return Err(format!(
            "--inner-tol must be in (0, 1) (got {})",
            cfg.solver.inner_tol
        )
        .into());
    }
    cfg.solver.max_outer = args.get_parse("max-outer", cfg.solver.max_outer)?;
    if cfg.solver.max_outer == 0 {
        return Err("--max-outer must be positive".into());
    }
    if let Some(t) = args.get("threads") {
        let t: usize = t
            .parse()
            .map_err(|_| format!("--threads: cannot parse {t:?}"))?;
        if t == 0 {
            return Err("--threads must be positive".into());
        }
        cfg.solver.threads = Some(t);
    }
    cfg.solver.nrhs = args.get_parse("nrhs", cfg.solver.nrhs)?;
    if cfg.solver.nrhs == 0 {
        return Err("--nrhs must be positive".into());
    }
    if let Some(c) = args.get("gauge-compression") {
        cfg.gauge.compression = Compression::parse(c)?;
    }
    if let Some(s) = args.get("eo2-schedule") {
        cfg.parallel.eo2_schedule = Some(Eo2Schedule::parse(s)?);
    }
    if let Some(g) = args.get("eo2-granularity") {
        let g: usize = g
            .parse()
            .map_err(|_| format!("--eo2-granularity: cannot parse {g:?}"))?;
        if g == 0 {
            return Err("--eo2-granularity must be positive".into());
        }
        cfg.parallel.eo2_granularity = Some(g);
    }
    if let Some(d) = args.get("tune-cache") {
        cfg.tune.cache_dir = d.into();
    }
    cfg.tune.budget_ms = args.get_parse("budget-ms", cfg.tune.budget_ms)?;
    if cfg.tune.budget_ms == 0 {
        return Err("--budget-ms must be positive".into());
    }
    if args.flag("no-tune") {
        cfg.tune.enabled = false;
    }
    if let Some(spec) = args.get("inject-faults") {
        FaultPlan::parse(spec).map_err(|m| format!("--inject-faults: {m}"))?;
        cfg.faults = spec.to_string();
    }
    cfg.comm.timeout_ms = args.get_parse("comm-timeout-ms", cfg.comm.timeout_ms)?;
    cfg.comm.max_retries = args.get_parse("comm-max-retries", cfg.comm.max_retries)?;
    cfg.solver.max_restarts = args.get_parse("max-restarts", cfg.solver.max_restarts)?;
    if let Some(dir) = args.get("trace") {
        cfg.telemetry.enabled = true;
        cfg.telemetry.dir = Some(dir.into());
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.checkpoint.dir = Some(dir.into());
    }
    cfg.checkpoint.every_iters =
        args.get_parse("checkpoint-every", cfg.checkpoint.every_iters)?;
    let resume: Option<std::path::PathBuf> = args.get("resume").map(Into::into);
    if let Some(d) = &resume {
        // --resume DIR implies reading and writing checkpoints there
        cfg.checkpoint.dir.get_or_insert_with(|| d.clone());
    }
    let profile = args.flag("profile");
    let use_pjrt = args.flag("pjrt") || cfg.solver.use_pjrt;
    let opts = Opts {
        iters: args.get_parse("iters", if args.flag("quick") { 10 } else { 50 })?,
        threads: args.get_parse("threads", cfg.parallel.threads_per_rank)?,
        quick: args.flag("quick"),
    };
    let lint_opts = LintCmd {
        root: args.get("root").map(Into::into),
        json: args.get("json").map(Into::into),
        rules: args.flag("rules"),
        model_check: args.flag("model-check"),
        max_preemptions: args.get_parse("max-preemptions", 4usize)?,
    };
    args.finish()?;

    match cmd.as_str() {
        "info" => info(&cfg),
        "solve" => solve(&cfg, use_pjrt, profile, resume.as_deref()),
        "tune" => tune(&cfg, opts.quick),
        "bench-table1" => {
            let (report, _) = harness::table1::run(opts);
            println!("{report}");
            Ok(())
        }
        "bench-fig8" => {
            println!("{}", harness::fig8::run(opts).report);
            Ok(())
        }
        "bench-fig9" => {
            println!("{}", harness::fig9::run(opts).report);
            Ok(())
        }
        "bench-fig10" => {
            println!("{}", harness::fig10::run(opts).report);
            Ok(())
        }
        "bench-acle" => {
            println!("{}", harness::acle::run(opts).report);
            Ok(())
        }
        "bench-barrier" => {
            println!("{}", harness::barrier::run(opts).report);
            Ok(())
        }
        "lint" => lint(&lint_opts),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

/// Options for the `lint` subcommand (parsed in [`run`] so the shared
/// `finish` typo check accepts them).
struct LintCmd {
    root: Option<std::path::PathBuf>,
    json: Option<std::path::PathBuf>,
    rules: bool,
    model_check: bool,
    max_preemptions: usize,
}

/// `lqcd lint [--root DIR] [--json PATH] [--model-check] [--rules]`:
/// run the in-tree invariant linter (and optionally the concurrency
/// model-checker suite), printing findings as `file:line: [rule] msg`
/// and exiting non-zero on any violation.
fn lint(cmd: &LintCmd) -> Result<(), Box<dyn std::error::Error>> {
    use lqcd::analysis::{lint as linter, model};

    if cmd.rules {
        for (name, desc) in linter::RULES {
            println!("{name:<16} {desc}");
        }
        return Ok(());
    }

    let root = cmd.root.clone().unwrap_or_else(|| ".".into());
    let report = linter::lint_tree(&root)?;
    for f in &report.findings {
        eprintln!("{f}");
    }
    println!(
        "lint: {} files scanned, {} finding(s), {} suppressed",
        report.files_scanned,
        report.findings.len(),
        report.suppressed
    );

    let mut suite = Vec::new();
    if cmd.model_check {
        let opts = model::CheckOpts { max_preemptions: cmd.max_preemptions };
        suite = model::run_suite(&opts);
        for r in &suite {
            let status = if r.ok() { "ok" } else { "FAIL" };
            let detail = match &r.report.violation {
                Some(v) => format!("violation: {}", v.message),
                None => format!(
                    "{} schedules, {} states",
                    r.report.schedules, r.report.states
                ),
            };
            println!("model {status:4} {:<36} {detail}", r.name);
        }
    }

    if let Some(path) = &cmd.json {
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("lint");
        w.raw(&report.to_json());
        w.key("model");
        w.arr_begin();
        for r in &suite {
            w.obj_begin();
            w.key("name");
            w.str_val(r.name);
            w.key("expect_violation");
            w.boolean(r.expect_violation);
            w.key("ok");
            w.boolean(r.ok());
            w.key("schedules");
            w.uint(r.report.schedules);
            w.key("states");
            w.uint(r.report.states);
            if let Some(v) = &r.report.violation {
                w.key("violation");
                w.str_val(&v.message);
            }
            w.obj_end();
        }
        w.arr_end();
        w.obj_end();
        std::fs::write(path, w.finish())?;
    }

    let model_failures = suite.iter().filter(|r| !r.ok()).count();
    if !report.clean() || model_failures > 0 {
        return Err(format!(
            "lint failed: {} finding(s), {} model-check failure(s)",
            report.findings.len(),
            model_failures
        )
        .into());
    }
    Ok(())
}

fn info(cfg: &RunConfig) -> Result<(), Box<dyn std::error::Error>> {
    let a64 = A64fx::fugaku_normal();
    println!("# lqcd — even-odd Wilson matrix on a SIMD-tiled lattice");
    println!(
        "paper target: A64FX node, {:.0} GFlops f32 peak, {:.0} GB/s,",
        a64.peak_sp_gflops, a64.mem_bw_gbs
    );
    println!(
        "  B/F=1.12 memory roofline = {:.0} GFlops/node",
        a64.mem_roofline_gflops(1.12)
    );
    let host = calibrate_host();
    println!(
        "this host: ~{:.1} GFlops/core f32 (measured), ~{:.1} GB/s triad (1 thread),",
        host.core_sp_gflops, host.mem_bw_gbs
    );
    println!(
        "  ~{:.1} GB/s saturated at {} threads,",
        host.mem_bw_saturated_gbs, host.saturation_threads
    );
    println!(
        "  host B/F=1.12 roofline = {:.1} GFlops",
        host.mem_roofline_gflops(1.12)
    );
    println!(
        "config: lattice {} tiling {} kappa {}",
        cfg.lattice.global, cfg.lattice.tiling, cfg.solver.kappa
    );
    match lqcd::runtime::Runtime::load(&cfg.artifacts_dir) {
        Ok(rt) => {
            println!(
                "artifacts: {} compiled on {} (lattice {})",
                rt.manifest.artifacts.len(),
                rt.platform(),
                rt.manifest.dims
            );
            for a in &rt.manifest.artifacts {
                println!("  - {}", a.name);
            }
        }
        Err(e) => println!("artifacts: not loaded ({e})"),
    }
    Ok(())
}

/// `lqcd tune`: calibrate the host, sweep the empirical knobs on the
/// configured lattice, and persist the per-machine cache that
/// subsequent `lqcd solve` runs resolve their knobs from.
fn tune(cfg: &RunConfig, quick: bool) -> Result<(), Box<dyn std::error::Error>> {
    let dims = cfg.lattice.global;
    println!("calibrating host (STREAM-triad thread sweep + FMA chains) ...");
    let host = calibrate_host();
    println!(
        "  ~{:.1} GFlops/core f32, triad {:.1} GB/s (1 thread), \
         {:.1} GB/s saturated at {} threads",
        host.core_sp_gflops,
        host.mem_bw_gbs,
        host.mem_bw_saturated_gbs,
        host.saturation_threads,
    );
    let opts = TuneOptions {
        dims,
        seed: cfg.seed,
        budget_ms: cfg.tune.budget_ms,
        quick,
    };
    println!(
        "tuning on {} (budget {} ms{}) ...",
        dims,
        cfg.tune.budget_ms,
        if quick { ", --quick" } else { "" },
    );
    let m = run_tune(&host, &opts);
    for s in &m.tilings {
        println!(
            "  tiling {:>5}: {:9.3} us/apply, {:6.1} GB/s",
            s.tiling.to_string(),
            s.seconds_per_apply * 1e6,
            s.gbs,
        );
    }
    for s in &m.threads {
        println!(
            "  threads {:>3}: {:9.3} us/iter,  {:6.1} GB/s",
            s.threads,
            s.seconds_per_iter * 1e6,
            s.gbs,
        );
    }
    for s in &m.chunks {
        println!(
            "  eo2 {:>8}/{:<2}: {:9.3} us/apply, EO2 imbalance {:.2}",
            s.schedule.to_string(),
            s.granularity,
            s.seconds_per_apply * 1e6,
            s.eo2_imbalance,
        );
    }
    let fp = HostFingerprint::new(num_cores(), host.mem_bw_saturated_gbs, dims);
    let cache = TuneCache::from_measurements(fp, m);
    let c = &cache.choice;
    println!(
        "chosen: tiling {}, threads {} (bandwidth knee), eo2 {}/{}; \
         fitted roofline {:.1} GB/s",
        c.tiling, c.threads, c.eo2_schedule, c.eo2_granularity, c.roofline_gbs,
    );
    let path = cache.save(&cfg.tune.cache_dir)?;
    println!("tune cache written: {}", path.display());
    Ok(())
}

fn num_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The per-rank lattice the solve actually runs — what the tune cache
/// is keyed by (tuning measures single-rank kernels at local volume).
fn local_dims_for(cfg: &RunConfig, nranks: usize) -> LatticeDims {
    if nranks <= 1 {
        return cfg.lattice.global;
    }
    let g = cfg.lattice.global;
    let p = cfg.lattice.grid.0;
    LatticeDims::new(
        g.x / p[0].max(1),
        g.y / p[1].max(1),
        g.z / p[2].max(1),
        g.t / p[3].max(1),
    )
    .unwrap_or(g)
}

/// Knob values every solve path consumes, after full resolution.
struct Knobs {
    threads: usize,
    eo2_schedule: Eo2Schedule,
    eo2_granularity: usize,
    /// per-knob provenance line (also stored in `SolveStats`)
    summary: String,
}

/// Resolve every performance knob as CLI/config → tune cache → static
/// heuristic, logging the cache-lookup outcome and which source won
/// each knob. The resolved tiling is written back into `cfg` so the
/// geometry construction downstream picks it up. Distributed configs
/// (`nranks > 1`) clamp the auto/tuned team size by
/// `parallel.threads_per_rank`: every rank lives on this one simulated
/// node, so sizing each team from the whole machine's core count would
/// oversubscribe it nranks-fold.
fn resolve_solve_knobs(cfg: &mut RunConfig, nranks: usize) -> Knobs {
    let local_dims = local_dims_for(cfg, nranks);
    let cache: Option<TuneCache> = if cfg.tune.enabled {
        match TuneCache::load_for_host(&cfg.tune.cache_dir, num_cores(), local_dims) {
            CacheLookup::Hit(c) => {
                println!(
                    "tune cache: hit in {} (tiling {}, threads {}, eo2 {}/{})",
                    cfg.tune.cache_dir.display(),
                    c.choice.tiling,
                    c.choice.threads,
                    c.choice.eo2_schedule,
                    c.choice.eo2_granularity,
                );
                Some(*c)
            }
            CacheLookup::Stale { found, want } => {
                println!(
                    "tune cache: stale ({found}; this run wants {want}) — ignoring it; \
                     re-run `lqcd tune` to refresh"
                );
                None
            }
            CacheLookup::Corrupt(msg) => {
                eprintln!("warning: tune cache unreadable ({msg}); using heuristics");
                None
            }
            CacheLookup::Missing => None,
        }
    } else {
        None
    };
    let explicit = ExplicitKnobs {
        tiling: cfg.lattice.tiling_explicit.then_some(cfg.lattice.tiling),
        threads: cfg.solver.threads,
        eo2_schedule: cfg.parallel.eo2_schedule,
        eo2_granularity: cfg.parallel.eo2_granularity,
    };
    let cap = (nranks > 1).then_some(cfg.parallel.threads_per_rank);
    let (auto_threads, auto_bound) = auto_solver_threads_capped(cap);
    let r = lqcd::perf::resolve_knobs(
        &explicit,
        cache.as_ref(),
        local_dims,
        cfg.lattice.tiling,
        auto_threads,
    );
    cfg.lattice.tiling = r.tiling.0;
    let threads = match r.threads {
        (t, KnobSource::Cli) => t,
        (t, KnobSource::Cache) => {
            let t = match cap {
                Some(c) => t.min(c.max(1)),
                None => t,
            };
            println!(
                "solver.threads unset: auto-selected {t} worker threads ({})",
                AutoThreadBound::Tuned
            );
            t
        }
        (t, KnobSource::Heuristic) => {
            println!("solver.threads unset: auto-selected {t} worker threads ({auto_bound})");
            t
        }
    };
    let summary = r.summary();
    println!("knob resolution: {summary}");
    Knobs {
        threads,
        eo2_schedule: r.eo2_schedule.0,
        eo2_granularity: r.eo2_granularity.0,
        summary,
    }
}

/// Render the profiler snapshot and write the machine-readable
/// `profile.json` next to the artifacts (`lqcd solve --profile`).
fn emit_profile(
    report: &Report,
    dir: &std::path::Path,
) -> Result<(), Box<dyn std::error::Error>> {
    println!("{}", report.render("solve: per-thread phase seconds"));
    std::fs::create_dir_all(dir)?;
    let path = dir.join("profile.json");
    std::fs::write(&path, report.to_json())?;
    println!("profile written: {}", path.display());
    Ok(())
}

/// Per-rank span tracer when telemetry is on (`--trace DIR` or config
/// `[telemetry] enabled`); `None` keeps every solver on the untraced
/// path with bitwise-identical residual histories.
fn make_tracer(cfg: &RunConfig, threads: usize, rank: usize) -> Option<Arc<Tracer>> {
    cfg.telemetry
        .enabled
        .then(|| Arc::new(Tracer::new(threads, cfg.telemetry.buffer_spans, rank)))
}

/// Profiler for a solve path: tracer-backed when telemetry is enabled,
/// plain when only `--profile` asked for phase accounting, `None` when
/// neither (zero instrumentation).
fn make_profiler(
    profile: bool,
    threads: usize,
    tracer: &Option<Arc<Tracer>>,
) -> Option<Profiler> {
    match tracer {
        Some(t) => Some(Profiler::with_tracer(threads, t.clone())),
        None => profile.then(|| Profiler::new(threads)),
    }
}

/// Checkpoint sink for one rank when `[checkpoint] dir` (or
/// `--checkpoint-dir` / `--resume`) is set; `None` keeps every solver
/// on the uncheckpointed path.
fn make_checkpointer(
    cfg: &RunConfig,
    rank: usize,
    nranks: usize,
    ghash: u64,
) -> Result<Option<Checkpointer>, String> {
    match &cfg.checkpoint.dir {
        None => Ok(None),
        Some(dir) => {
            let opts = CkptOpts {
                dir: dir.clone(),
                every_iters: cfg.checkpoint.every_iters,
                every_ms: cfg.checkpoint.every_ms,
                keep: cfg.checkpoint.keep,
                buddy: cfg.checkpoint.buddy,
            };
            Checkpointer::new(opts, rank, nranks, ghash)
                .map(Some)
                .map_err(|e| format!("checkpoint: {e}"))
        }
    }
}

/// Load the resume state for one rank (`--resume DIR`): the newest
/// generation every rank committed, falling back to older generations
/// when a file fails validation.
fn load_resume(
    dir: &std::path::Path,
    rank: usize,
    nranks: usize,
    ghash: u64,
) -> Result<SolverState, String> {
    let (st, gen) =
        load_latest(dir, rank, nranks, ghash).map_err(|e| format!("resume: {e}"))?;
    println!(
        "resume: rank {rank} restored generation {gen} (iteration {})",
        st.iteration
    );
    Ok(st)
}

/// The machine-readable `checkpoint:` line the CI smoke greps.
fn print_checkpoint_summary(generations: u64, restores: u64) {
    let mut w = JsonWriter::new();
    w.obj_begin();
    w.key("generations");
    w.uint(generations);
    w.key("restores");
    w.uint(restores);
    w.obj_end();
    println!("checkpoint: {}", w.finish());
}

/// Per-rank checkpoint outcome carried out of the distributed world
/// closure: commit count, whether the rank resumed, and the in-memory
/// buddy copy of the ring-neighbor's newest generation.
struct CkptOutcome {
    generations: u64,
    restores: u64,
    buddy: Option<BuddyCopy>,
}

fn slowdown_config(cfg: &RunConfig) -> SlowdownConfig {
    SlowdownConfig {
        window: cfg.telemetry.slowdown_window,
        k: cfg.telemetry.slowdown_k,
        factor: cfg.telemetry.slowdown_factor,
        min_secs: cfg.telemetry.slowdown_min_ms * 1e-3,
    }
}

/// Write `trace.json` (Chrome-trace / Perfetto, one track per
/// rank×thread) and `metrics.json` (phase-time histograms with
/// p50/p95/p99, transport counters, slowdown report) from the drained
/// per-rank span buffers, and print the machine-readable `slowdowns:`
/// summary line the CI smoke greps.
fn emit_telemetry(
    cfg: &RunConfig,
    parts: Vec<TraceData>,
) -> Result<(), Box<dyn std::error::Error>> {
    let data = TraceData::merge(parts);
    let dir = cfg
        .telemetry
        .dir
        .clone()
        .unwrap_or_else(|| cfg.artifacts_dir.clone());
    std::fs::create_dir_all(&dir)?;
    let trace_path = dir.join("trace.json");
    std::fs::write(&trace_path, data.chrome_trace_json())?;

    let mut m = Metrics::new();
    m.counter("spans", data.spans.len() as u64);
    m.counter("spans_dropped", data.dropped);
    for s in &data.spans {
        let secs = (s.t_end_ns - s.t_start_ns) as f64 * 1e-9;
        m.observe(&format!("phase.{}", span_label(s.code)), secs);
        if s.bytes > 0 {
            m.counter(&format!("bytes.{}", span_label(s.code)), s.bytes);
        }
    }
    let slow = detect_slowdowns(&data.spans, &slowdown_config(cfg));
    let metrics_path = dir.join("metrics.json");
    std::fs::write(&metrics_path, m.to_json(&slow))?;
    println!("slowdowns: {}", slowdown_summary(&slow));
    println!(
        "trace written: {} ({} spans, {} dropped); metrics: {}",
        trace_path.display(),
        data.spans.len(),
        data.dropped,
        metrics_path.display(),
    );
    Ok(())
}

fn solve(
    cfg: &RunConfig,
    use_pjrt: bool,
    profile: bool,
    resume: Option<&std::path::Path>,
) -> Result<(), Box<dyn std::error::Error>> {
    // every rejected flag combination is reported here, all at once —
    // the per-branch checks this replaces each only saw the first
    // offense on their own path
    cfg.validate_solve(use_pjrt)?;
    let nranks = cfg.lattice.grid.size();
    let mut cfg = cfg.clone();
    let knobs = resolve_solve_knobs(&mut cfg, nranks);
    let cfg = &cfg;
    if nranks > 1 {
        // rank-decomposed path: grid × nrhs × compression compose
        return match cfg.solver.precision.as_str() {
            "f64" => solve_distributed::<f64>(cfg, &knobs, profile, resume),
            _ => solve_distributed::<f32>(cfg, &knobs, profile, resume),
        };
    }
    if cfg.solver.nrhs > 1 {
        return match cfg.solver.precision.as_str() {
            "f64" => solve_block::<f64>(cfg, &knobs, profile, resume),
            _ => solve_block::<f32>(cfg, &knobs, profile, resume),
        };
    }
    match cfg.solver.precision.as_str() {
        "f64" => return solve_native::<f64>(cfg, &knobs, profile, resume),
        "mixed" => return solve_mixed(cfg, &knobs, profile, resume),
        _ if !use_pjrt => return solve_native::<f32>(cfg, &knobs, profile, resume),
        _ => {}
    }
    if profile {
        eprintln!("warning: --profile is not wired into the PJRT path; ignoring");
    }
    if cfg.telemetry.enabled {
        eprintln!("warning: --trace is not wired into the PJRT path; ignoring");
    }
    if cfg.checkpoint.dir.is_some() || resume.is_some() {
        eprintln!("warning: checkpointing is not wired into the PJRT path; ignoring");
    }
    let geom = Geometry::single_rank(cfg.lattice.global, cfg.lattice.tiling)
        .map_err(|e| e.to_string())?;
    let mut rng = Rng::seeded(cfg.seed);
    println!(
        "generating random gauge configuration on {} ...",
        cfg.lattice.global
    );
    let u: GaugeField = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u.plaquette());
    let b: FermionField = FermionField::gaussian(&geom, &mut rng);
    let kappa = cfg.solver.kappa as f32;

    let sw = lqcd::util::timer::Stopwatch::start();
    let rt = lqcd::runtime::Runtime::load(&cfg.artifacts_dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut op = lqcd::runtime::PjrtMeo::new(&rt, &geom, &u, kappa)?;
    let mut x = FermionField::zeros(&geom);
    let stats = solver::bicgstab(&mut op, &mut x, &b, cfg.solver.tol, cfg.solver.maxiter);
    println!(
        "true |Mx-b|/|b| = {:.3e}",
        solver::residual::operator_residual(&mut op, &x, &b)
    );
    let secs = sw.secs();
    println!(
        "pjrt-bicgstab: {} iterations, converged={}, rel residual {:.3e}, {:.2}s, {:.2} GFlops",
        stats.iterations,
        stats.converged,
        stats.rel_residual,
        secs,
        stats.flops as f64 / secs / 1e9,
    );
    Ok(())
}

/// Uniform-precision native solve at `R` (`--precision f32` without
/// `--pjrt`, and `--precision f64`), on the fused thread-parallel
/// pipeline: whole iterations run on the worker team
/// (`solver.threads` / `--threads`), with the kernel tails and
/// reductions fused into 3 (CG) / 6 (BiCGStab) sweeps per iteration.
fn solve_native<R: Real>(
    cfg: &RunConfig,
    knobs: &Knobs,
    profile: bool,
    resume: Option<&std::path::Path>,
) -> Result<(), Box<dyn std::error::Error>> {
    let geom = Geometry::single_rank(cfg.lattice.global, cfg.lattice.tiling)
        .map_err(|e| e.to_string())?;
    let threads = knobs.threads;
    let mut rng = Rng::seeded(cfg.seed);
    println!(
        "generating random gauge configuration on {} ({}, {} threads) ...",
        cfg.lattice.global,
        R::NAME,
        threads
    );
    let u: GaugeField<R> = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u.plaquette());
    let ghash = gauge_hash(&u);
    let b: FermionField<R> = FermionField::gaussian(&geom, &mut rng);
    let kappa = R::from_f64(cfg.solver.kappa);
    let links = Links::from_gauge(u, cfg.gauge.compression);
    if cfg.gauge.compression == Compression::TwoRow {
        println!("gauge compression: two-row (12 reals/link streamed, third row rebuilt in-kernel)");
    }
    let mut team = Team::new(threads, BarrierKind::Sleep);
    let tracer = make_tracer(cfg, threads, 0);
    let prof = make_profiler(profile, threads, &tracer);
    let health = HealthConfig {
        max_restarts: cfg.solver.max_restarts,
        ..Default::default()
    };
    let mut ckpt = make_checkpointer(cfg, 0, 1, ghash)?;
    let resume_state = match resume {
        Some(dir) => Some(load_resume(dir, 0, 1, ghash)?),
        None => None,
    };

    let sw = lqcd::util::timer::Stopwatch::start();
    let mut stats = if cfg.solver.algorithm == "bicgstab" {
        let mut op = NativeMeo::with_links(&geom, links, kappa);
        let mut x = FermionField::zeros(&geom);
        let stats = solver::fused::bicgstab_guarded_ckpt(
            &mut op,
            &mut team,
            &mut x,
            &b,
            cfg.solver.tol,
            cfg.solver.maxiter,
            prof.as_ref(),
            &health,
            ckpt.as_mut(),
            resume_state.as_ref(),
        )
        .map_err(|e| format!("solve failed: {e}"))?;
        println!(
            "true |Mx-b|/|b| = {:.3e}",
            solver::residual::operator_residual(&mut op, &x, &b)
        );
        stats
    } else {
        let mut op = NativeMdagM::with_links(&geom, links, kappa);
        let mut bp = b.clone();
        bp.gamma5();
        let mut mbp = FermionField::zeros(&geom);
        op.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
        let mut x = FermionField::zeros(&geom);
        let stats = solver::fused::cg_guarded_ckpt(
            &mut op,
            &mut team,
            &mut x,
            &mbp,
            cfg.solver.tol,
            cfg.solver.maxiter,
            prof.as_ref(),
            &health,
            ckpt.as_mut(),
            resume_state.as_ref(),
        )
        .map_err(|e| format!("solve failed: {e}"))?;
        println!(
            "true |MdagM x - Mdag b|/|Mdag b| = {:.3e}",
            solver::residual::operator_residual(&mut op, &x, &mbp)
        );
        stats
    };
    let secs = sw.secs();
    stats.knob_sources = Some(knobs.summary.clone());
    println!(
        "{}({}): {} iterations, converged={}, rel residual {:.3e}, {:.2}s, \
         {:.2} GFlops, {:.0} sweeps/iter, {} threads",
        cfg.solver.algorithm,
        R::NAME,
        stats.iterations,
        stats.converged,
        stats.rel_residual,
        secs,
        stats.flops as f64 / secs / 1e9,
        stats.sweeps_per_iter,
        stats.threads,
    );
    if cfg.checkpoint.dir.is_some() {
        print_checkpoint_summary(
            ckpt.as_ref().map(|c| c.committed()).unwrap_or(0),
            resume_state.is_some() as u64,
        );
    }
    if let (true, Some(p)) = (profile, &prof) {
        emit_profile(&p.snapshot(), &cfg.artifacts_dir)?;
    }
    if let Some(t) = &tracer {
        emit_telemetry(cfg, vec![t.drain()])?;
    }
    Ok(())
}

/// Multi-RHS block solve (`--nrhs N`, N > 1): N Gaussian sources
/// interleaved into one block field, solved together by the batched
/// solver — the gauge field is streamed once per sweep for all N
/// systems, and converged systems drop out of the kernel work via the
/// per-RHS masks.
fn solve_block<R: Real>(
    cfg: &RunConfig,
    knobs: &Knobs,
    profile: bool,
    resume: Option<&std::path::Path>,
) -> Result<(), Box<dyn std::error::Error>> {
    let geom = Geometry::single_rank(cfg.lattice.global, cfg.lattice.tiling)
        .map_err(|e| e.to_string())?;
    let threads = knobs.threads;
    let nrhs = cfg.solver.nrhs;
    let mut rng = Rng::seeded(cfg.seed);
    println!(
        "generating random gauge configuration on {} ({}, {} threads, {} rhs) ...",
        cfg.lattice.global,
        R::NAME,
        threads,
        nrhs
    );
    let u: GaugeField<R> = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u.plaquette());
    let ghash = gauge_hash(&u);
    let sources: Vec<FermionField<R>> =
        (0..nrhs).map(|_| FermionField::gaussian(&geom, &mut rng)).collect();
    let kappa = R::from_f64(cfg.solver.kappa);
    let links = Links::from_gauge(u, cfg.gauge.compression);
    if cfg.gauge.compression == Compression::TwoRow {
        println!("gauge compression: two-row (12 reals/link streamed once for all {nrhs} rhs)");
    }
    let mut team = Team::new(threads, BarrierKind::Sleep);
    let tracer = make_tracer(cfg, threads, 0);
    let prof = make_profiler(profile, threads, &tracer);
    let health = HealthConfig {
        max_restarts: cfg.solver.max_restarts,
        ..Default::default()
    };
    let mut ckpt = make_checkpointer(cfg, 0, 1, ghash)?;
    let resume_state = match resume {
        Some(dir) => Some(load_resume(dir, 0, 1, ghash)?),
        None => None,
    };
    // the checkpoint hooks live in the generic guarded block solver;
    // without them the fused batched pipeline keeps the hot path
    let ckpt_on = ckpt.is_some() || resume_state.is_some();

    let sw = lqcd::util::timer::Stopwatch::start();
    let (stats, resid) = if cfg.solver.algorithm == "bicgstab" {
        let b = MultiFermionField::from_rhs(&sources);
        let mut op = MultiNativeMeo::with_links(&geom, links.clone(), kappa, nrhs);
        let mut x = MultiFermionField::<R>::zeros(&geom, nrhs);
        let stats = if ckpt_on {
            solver::block_bicgstab_generic_guarded_ckpt(
                &mut op,
                &mut team,
                &mut x,
                &b,
                cfg.solver.tol,
                cfg.solver.maxiter,
                &health,
                prof.as_ref(),
                ckpt.as_mut(),
                resume_state.as_ref(),
            )
            .map_err(|e| format!("solve failed: {e}"))?
        } else {
            solver::block_bicgstab_profiled(
                &mut op,
                &mut team,
                &mut x,
                &b,
                cfg.solver.tol,
                cfg.solver.maxiter,
                prof.as_ref(),
            )
        };
        // worst true per-RHS residual, via the single-RHS operator
        let mut meo = NativeMeo::with_links(&geom, links, kappa);
        let resid = worst_true_residual(&mut meo, &x, &sources);
        (stats, resid)
    } else {
        // CGNR: per-RHS right-hand side is Mdag b_r
        let mut op = MultiMdagM::with_links(&geom, links.clone(), kappa, nrhs);
        let mut meo = NativeMeo::with_links(&geom, links.clone(), kappa);
        let rhs: Vec<FermionField<R>> = sources
            .iter()
            .map(|b| {
                let mut bp = b.clone();
                bp.gamma5();
                let mut mbp = FermionField::zeros(&geom);
                meo.apply(&mut mbp, &bp);
                mbp.gamma5();
                mbp
            })
            .collect();
        let b = MultiFermionField::from_rhs(&rhs);
        let mut x = MultiFermionField::<R>::zeros(&geom, nrhs);
        let stats = if ckpt_on {
            solver::block_cg_generic_guarded_ckpt(
                &mut op,
                &mut team,
                &mut x,
                &b,
                cfg.solver.tol,
                cfg.solver.maxiter,
                &health,
                prof.as_ref(),
                ckpt.as_mut(),
                resume_state.as_ref(),
            )
            .map_err(|e| format!("solve failed: {e}"))?
        } else {
            solver::block_cg_profiled(
                &mut op,
                &mut team,
                &mut x,
                &b,
                cfg.solver.tol,
                cfg.solver.maxiter,
                prof.as_ref(),
            )
        };
        let mut ndag = NativeMdagM::with_links(&geom, links, kappa);
        let resid = worst_true_residual(&mut ndag, &x, &rhs);
        (stats, resid)
    };
    let secs = sw.secs();
    for (r, s) in stats.per_rhs.iter().enumerate() {
        println!(
            "  rhs {r:>2}: {} iterations, converged={}, rel residual {:.3e}",
            s.iterations, s.converged, s.rel_residual
        );
    }
    println!(
        "block-{}({}, nrhs={}): {} batched iterations, all converged={}, \
         worst true |r|/|b| = {:.3e}, {:.2}s, {:.2} GFlops, {} threads",
        cfg.solver.algorithm,
        R::NAME,
        stats.nrhs,
        stats.iterations,
        stats.converged,
        resid,
        secs,
        stats.flops as f64 / secs / 1e9,
        stats.threads,
    );
    println!("knobs: {}", knobs.summary);
    if cfg.checkpoint.dir.is_some() {
        print_checkpoint_summary(
            ckpt.as_ref().map(|c| c.committed()).unwrap_or(0),
            resume_state.is_some() as u64,
        );
    }
    if let (true, Some(p)) = (profile, &prof) {
        emit_profile(&p.snapshot(), &cfg.artifacts_dir)?;
    }
    if let Some(t) = &tracer {
        emit_telemetry(cfg, vec![t.drain()])?;
    }
    Ok(())
}

/// Distributed multi-RHS solve (`lattice.grid` / `--grid` with more
/// than one rank): the global lattice is decomposed over a simulated
/// MPI world, each rank runs the batched distributed operator
/// (`DistMultiMeo` / `DistMultiMdagM`) under the generic block solver —
/// one halo message per direction per hopping for ALL active RHS
/// (RHS-innermost on the wire; converged RHS drop out of the payload),
/// the gauge stream consumed once per site tile for all systems, and
/// two-row compression composing with both. `--grid`, `--nrhs` and
/// `--gauge-compression` compose freely at f32/f64.
fn solve_distributed<R: Real + CommScalar>(
    cfg: &RunConfig,
    knobs: &Knobs,
    profile: bool,
    resume: Option<&std::path::Path>,
) -> Result<(), Box<dyn std::error::Error>> {
    let grid = cfg.lattice.grid;
    let nranks = grid.size();
    let nrhs = cfg.solver.nrhs;
    let ggeom = Geometry::single_rank(cfg.lattice.global, cfg.lattice.tiling)
        .map_err(|e| e.to_string())?;
    // validate the decomposition up front (nice error instead of a rank
    // thread panic)
    Geometry::for_rank(cfg.lattice.global, grid, 0, cfg.lattice.tiling)
        .map_err(|e| e.to_string())?;
    let threads = knobs.threads;
    let mut rng = Rng::seeded(cfg.seed);
    println!(
        "generating random gauge configuration on {} ({}, grid {:?} = {} ranks, \
         {} threads/rank, {} rhs) ...",
        cfg.lattice.global,
        R::NAME,
        grid.0,
        nranks,
        threads,
        nrhs
    );
    let u_global: GaugeField<R> = GaugeField::random(&ggeom, &mut rng);
    println!("plaquette = {:.6}", u_global.plaquette());
    let sources: Vec<FermionField<R>> =
        (0..nrhs).map(|_| FermionField::gaussian(&ggeom, &mut rng)).collect();
    let kappa = R::from_f64(cfg.solver.kappa);
    if cfg.gauge.compression == Compression::TwoRow {
        println!(
            "gauge compression: two-row (12 reals/link streamed once per site \
             tile for all {nrhs} rhs on every rank)"
        );
    }
    let algorithm = cfg.solver.algorithm.clone();
    let (global, tiling) = (cfg.lattice.global, cfg.lattice.tiling);
    let (tol, maxiter) = (cfg.solver.tol, cfg.solver.maxiter);
    let force_comm = cfg.parallel.force_comm;
    let compression = cfg.gauge.compression;
    let (eo2_schedule, eo2_granularity) = (knobs.eo2_schedule, knobs.eo2_granularity);
    let health = HealthConfig {
        max_restarts: cfg.solver.max_restarts,
        ..Default::default()
    };
    let faults = FaultPlan::parse(&cfg.faults)
        .map_err(|m| format!("faults.spec: {m}"))?;
    if !faults.is_empty() {
        println!("fault injection: {}", cfg.faults);
    }
    let world = WorldOpts {
        timeout_ms: cfg.comm.timeout_ms,
        max_retries: cfg.comm.max_retries,
        faults: faults.clone(),
    };
    let telemetry_on = cfg.telemetry.enabled;
    let buffer_spans = cfg.telemetry.buffer_spans;
    let ckpt_cfg = cfg.checkpoint.clone();

    let sw = lqcd::util::timer::Stopwatch::start();
    let run_once = |world: WorldOpts, resume_now: bool| {
        run_world_cfg(nranks, world, |rank, comm| {
            let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
            let lu = extract_gauge(&u_global, &lgeom);
            // each rank hashes (and is checkpoint-guarded against) its
            // own slice of the configuration
            let ghash = gauge_hash(&lu);
            let links = Links::from_gauge(lu, compression);
            let local_sources: Vec<FermionField<R>> = sources
                .iter()
                .map(|s| extract_fermion(s, &ggeom, &lgeom))
                .collect();
            let dist = DistHopping::with_chunking(
                &lgeom,
                force_comm,
                threads,
                eo2_schedule,
                eo2_granularity,
            );
            let mut team = Team::new(threads, BarrierKind::Sleep);
            let tracer = telemetry_on
                .then(|| Arc::new(Tracer::new(threads, buffer_spans, rank)));
            let prof = match &tracer {
                Some(t) => Profiler::with_tracer(threads, t.clone()),
                None => Profiler::new(threads),
            };
            if let Some(t) = &tracer {
                // transport events (sends, retransmits, timeouts, injected
                // faults) land on the same per-rank trace as the phases
                comm.set_tracer(t.clone());
            }
            let mut ckpt = match &ckpt_cfg.dir {
                Some(dir) => {
                    let opts = CkptOpts {
                        dir: dir.clone(),
                        every_iters: ckpt_cfg.every_iters,
                        every_ms: ckpt_cfg.every_ms,
                        keep: ckpt_cfg.keep,
                        buddy: ckpt_cfg.buddy,
                    };
                    match Checkpointer::new(opts, rank, nranks, ghash) {
                        Ok(c) => Some(c),
                        Err(e) => {
                            eprintln!(
                                "checkpoint: rank {rank}: {e}; checkpointing disabled"
                            );
                            None
                        }
                    }
                }
                None => None,
            };
            let resume_state = match (resume_now, ckpt_cfg.dir.as_deref()) {
                (true, Some(dir)) => match load_latest(dir, rank, nranks, ghash) {
                    Ok((st, gen)) => {
                        println!(
                            "resume: rank {rank} restored generation {gen} (iteration {})",
                            st.iteration
                        );
                        Some(st)
                    }
                    Err(e) => {
                        eprintln!("resume: rank {rank}: {e}; starting from scratch");
                        None
                    }
                },
                _ => None,
            };
            let mut x = MultiFermionField::<R>::zeros(&lgeom, nrhs);
            let all_active = vec![true; nrhs];
            let (rhs, stats) = if algorithm == "bicgstab" {
                let b = MultiFermionField::from_rhs(&local_sources);
                let mut op = DistMultiMeo::new(
                    &lgeom, &dist, &links, kappa, nrhs, comm, &prof,
                )
                .expect("wire-format handshake");
                let stats = solver::block_bicgstab_generic_guarded_ckpt(
                    &mut op,
                    &mut team,
                    &mut x,
                    &b,
                    tol,
                    maxiter,
                    &health,
                    Some(&prof),
                    ckpt.as_mut(),
                    resume_state.as_ref(),
                );
                (b, stats)
            } else {
                // CGNR: per-RHS right-hand side is Mdag b_r, prepared with
                // the distributed operator itself
                let mut bp = MultiFermionField::from_rhs(&local_sources);
                bp.gamma5();
                let mut mbp = MultiFermionField::<R>::zeros(&lgeom, nrhs);
                {
                    let mut meo = DistMultiMeo::new(
                        &lgeom, &dist, &links, kappa, nrhs, comm, &prof,
                    )
                    .expect("wire-format handshake");
                    meo.apply_multi(&mut team, &mut mbp, &bp, &all_active, None);
                }
                mbp.gamma5();
                let mut op = DistMultiMdagM::new(
                    &lgeom, &dist, &links, kappa, nrhs, comm, &prof,
                )
                .expect("wire-format handshake");
                let stats = solver::block_cg_generic_guarded_ckpt(
                    &mut op,
                    &mut team,
                    &mut x,
                    &mbp,
                    tol,
                    maxiter,
                    &health,
                    Some(&prof),
                    ckpt.as_mut(),
                    resume_state.as_ref(),
                );
                (mbp, stats)
            };
            let trace = tracer.map(|t| t.drain());
            let outcome = CkptOutcome {
                generations: ckpt.as_ref().map(|c| c.committed()).unwrap_or(0),
                restores: resume_state.is_some() as u64,
                buddy: ckpt.as_mut().and_then(|c| c.take_buddy()),
            };
            (x.demux(), rhs.demux(), stats, prof.snapshot(), trace, outcome)
        })
    };
    let mut results = run_once(world, resume.is_some());

    // kill-fault escalation: a killed rank surfaces a structured
    // `CommError::Killed`. With checkpointing on, rewrite any buddy
    // copies the survivors hold (re-materializing checkpoint files the
    // dead rank may have lost), defuse the kill rules, and re-launch the
    // world resuming from the newest generation committed by ALL ranks.
    let killed = results.iter().any(|r| {
        matches!(
            r.2.as_ref().err().map(|e| &e.kind),
            Some(SolveErrorKind::Comm(CommError::Killed { .. }))
        )
    });
    if killed && ckpt_cfg.dir.is_some() {
        let dir = ckpt_cfg.dir.clone().unwrap();
        let mut rewritten = 0usize;
        for r in &mut results {
            if let Some(copy) = r.5.buddy.take() {
                match restore_from_buddy(&dir, &copy) {
                    Ok(()) => rewritten += 1,
                    Err(e) => eprintln!("buddy restore: {e}"),
                }
            }
        }
        println!(
            "recovery: rank killed mid-solve; {rewritten} buddy checkpoint(s) \
             rewritten, re-launching {nranks} ranks from the last generation \
             committed by all"
        );
        let world = WorldOpts {
            timeout_ms: cfg.comm.timeout_ms,
            max_retries: cfg.comm.max_retries,
            faults: faults.without_kills(),
        };
        results = run_once(world, true);
    }
    let secs = sw.secs();

    // a rank that diagnosed an unrecoverable fault (killed peer,
    // exhausted restart budget) carries a structured SolveError; report
    // the first one and exit non-zero instead of printing garbage
    // residuals
    if let Some((rank, e)) = results
        .iter()
        .enumerate()
        .find_map(|(r, (_, _, res, _, _, _))| res.as_ref().err().map(|e| (r, e)))
    {
        let kind = match &e.kind {
            SolveErrorKind::Comm(_) => "comm-fault",
            _ => "restarts-exhausted",
        };
        let restarts = e
            .events
            .iter()
            .filter(|ev| ev.kind != HealthEventKind::CommFault)
            .count();
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("converged");
        w.boolean(false);
        w.key("error");
        w.str_val(kind);
        w.key("rank");
        w.uint(rank as u64);
        w.key("iteration");
        w.uint(e.iteration as u64);
        w.key("restarts");
        w.uint(restarts as u64);
        w.key("health_events");
        w.uint(e.events.len() as u64);
        w.key("retransmits");
        w.uint(e.retransmits);
        w.key("timeouts");
        w.uint(e.timeouts);
        w.key("zero_fills");
        w.uint(e.zero_fills);
        w.obj_end();
        println!("recovery: {}", w.finish());
        return Err(format!("rank {rank}: {e}").into());
    }
    let stats_by_rank: Vec<&solver::BlockSolveStats> =
        results.iter().map(|(_, _, res, _, _, _)| res.as_ref().unwrap()).collect();

    // join the per-rank solutions / right-hand sides back to the global
    // lattice and measure the true residual with the single-rank operator
    let mut xs: Vec<FermionField<R>> =
        (0..nrhs).map(|_| FermionField::zeros(&ggeom)).collect();
    let mut rhs: Vec<FermionField<R>> =
        (0..nrhs).map(|_| FermionField::zeros(&ggeom)).collect();
    for (rank, (xl, rl, _, _, _, _)) in results.iter().enumerate() {
        let lgeom = Geometry::for_rank(global, grid, rank, tiling).unwrap();
        for r in 0..nrhs {
            insert_fermion(&mut xs[r], &xl[r], &lgeom);
            insert_fermion(&mut rhs[r], &rl[r], &lgeom);
        }
    }
    let glinks = Links::from_gauge(u_global, compression);
    let resid = {
        let mut worst = 0.0f64;
        if algorithm == "bicgstab" {
            let mut op = NativeMeo::with_links(&ggeom, glinks, kappa);
            for r in 0..nrhs {
                worst = worst
                    .max(solver::residual::operator_residual(&mut op, &xs[r], &rhs[r]));
            }
        } else {
            let mut op = NativeMdagM::with_links(&ggeom, glinks, kappa);
            for r in 0..nrhs {
                worst = worst
                    .max(solver::residual::operator_residual(&mut op, &xs[r], &rhs[r]));
            }
        }
        worst
    };

    // solver stats are identical on every rank (all scalars come from
    // the global-tile-order reductions); report rank 0's. The transport
    // recovery counters are per-rank — sum them for the fleet view.
    let stats = stats_by_rank[0];
    let (retransmits, timeouts, zero_fills) =
        stats_by_rank.iter().fold((0u64, 0u64, 0u64), |acc, s| {
            (acc.0 + s.retransmits, acc.1 + s.timeouts, acc.2 + s.zero_fills)
        });
    for (r, s) in stats.per_rhs.iter().enumerate() {
        println!(
            "  rhs {r:>2}: {} iterations, converged={}, rel residual {:.3e}",
            s.iterations, s.converged, s.rel_residual
        );
    }
    // batched-halo accounting: message count per hopping is independent
    // of nrhs, payload scales with the ACTIVE batch width only
    let lgeom0 = Geometry::for_rank(global, grid, 0, tiling).unwrap();
    let comm_dirs: [bool; 4] = std::array::from_fn(|d| force_comm || grid.0[d] > 1);
    let plans = HaloPlans::new(&lgeom0, Parity::Even, comm_dirs);
    let traffic = netmodel::batched_hopping_traffic(
        plans.face_count,
        comm_dirs,
        nrhs,
        std::mem::size_of::<R>(),
    );
    let hops_per_apply: u64 = if algorithm == "bicgstab" { 2 } else { 4 };
    println!(
        "batched halos: {} messages per operator apply (independent of nrhs), \
         {:.1} wire bytes/site/RHS",
        traffic.messages * hops_per_apply,
        netmodel::halo_bytes_per_site_rhs(traffic, lgeom0.local.half_volume(), nrhs),
    );
    println!(
        "dist-block-{}({}, {} ranks, nrhs={}): {} batched iterations, all \
         converged={}, worst true |r|/|b| = {:.3e}, {:.2}s, {} threads/rank",
        algorithm,
        R::NAME,
        nranks,
        stats.nrhs,
        stats.iterations,
        stats.converged,
        resid,
        secs,
        stats.threads,
    );
    // machine-readable recovery summary (CI chaos smoke greps this):
    // restarts/health_events are the guard's collective decisions
    // (identical on every rank), retransmits/timeouts sum the per-rank
    // transport counters
    let mut w = JsonWriter::new();
    w.obj_begin();
    w.key("converged");
    w.boolean(stats.converged);
    w.key("restarts");
    w.uint(stats.restarts as u64);
    w.key("health_events");
    w.uint(stats.health_events as u64);
    w.key("retransmits");
    w.uint(retransmits);
    w.key("timeouts");
    w.uint(timeouts);
    w.key("zero_fills");
    w.uint(zero_fills);
    w.obj_end();
    println!("recovery: {}", w.finish());
    if ckpt_cfg.dir.is_some() {
        // commit counts agree on every rank (the commit is collective);
        // restores count how many ranks resumed from disk
        let generations = results.iter().map(|r| r.5.generations).max().unwrap_or(0);
        let restores = results.iter().map(|r| r.5.restores).sum();
        print_checkpoint_summary(generations, restores);
    }
    println!("knobs: {}", knobs.summary);
    if profile {
        // rank 0's per-thread phase stacks rendered + profile.json, plus
        // one profile.rank<N>.json per rank for the fleet view
        emit_profile(&results[0].3, &cfg.artifacts_dir)?;
        for (rank, r) in results.iter().enumerate() {
            let path = cfg.artifacts_dir.join(format!("profile.rank{rank}.json"));
            std::fs::write(&path, r.3.to_json())?;
        }
        println!(
            "per-rank profiles written: {}/profile.rank<N>.json ({} ranks)",
            cfg.artifacts_dir.display(),
            nranks,
        );
    }
    if telemetry_on {
        let parts: Vec<TraceData> =
            results.into_iter().filter_map(|r| r.4).collect();
        emit_telemetry(cfg, parts)?;
    }
    Ok(())
}

/// Max over RHS of the true relative residual |A x_r - b_r| / |b_r|.
fn worst_true_residual<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &MultiFermionField<R>,
    bs: &[FermionField<R>],
) -> f64 {
    let mut worst = 0.0f64;
    for (r, b) in bs.iter().enumerate() {
        let xr = x.extract_rhs(r);
        worst = worst.max(solver::residual::operator_residual(op, &xr, b));
    }
    worst
}

/// Mixed-precision solve: f64 outer iterative refinement, f32 inner
/// CG/BiCGStab (`--precision mixed`).
fn solve_mixed(
    cfg: &RunConfig,
    knobs: &Knobs,
    profile: bool,
    resume: Option<&std::path::Path>,
) -> Result<(), Box<dyn std::error::Error>> {
    let geom = Geometry::single_rank(cfg.lattice.global, cfg.lattice.tiling)
        .map_err(|e| e.to_string())?;
    let threads = knobs.threads;
    let mut rng = Rng::seeded(cfg.seed);
    println!(
        "generating random gauge configuration on {} (mixed f64/f32, {} threads) ...",
        cfg.lattice.global,
        threads
    );
    let u: GaugeField<f64> = GaugeField::random(&geom, &mut rng);
    println!("plaquette = {:.6}", u.plaquette());
    let ghash = gauge_hash(&u);
    let b: FermionField<f64> = FermionField::gaussian(&geom, &mut rng);
    let kappa = cfg.solver.kappa;
    let u32 = u.to_precision::<f32>();
    // both the f64 outer and f32 inner operators honor the compression
    let links64 = Links::from_gauge(u, cfg.gauge.compression);
    let links32 = Links::from_gauge(u32, cfg.gauge.compression);
    if cfg.gauge.compression == Compression::TwoRow {
        println!("gauge compression: two-row (outer f64 and inner f32 operators)");
    }
    let mut team = Team::new(threads, BarrierKind::Sleep);
    let tracer = make_tracer(cfg, threads, 0);
    let prof = make_profiler(profile, threads, &tracer);
    let mut ckpt = make_checkpointer(cfg, 0, 1, ghash)?;
    let resume_state = match resume {
        Some(dir) => Some(load_resume(dir, 0, 1, ghash)?),
        None => None,
    };

    let sw = lqcd::util::timer::Stopwatch::start();
    let stats = if cfg.solver.algorithm == "bicgstab" {
        let mut outer = NativeMeo::with_links(&geom, links64, kappa);
        let mut inner = NativeMeo::with_links(&geom, links32, kappa as f32);
        let mut x = FermionField::<f64>::zeros(&geom);
        let stats = solver::mixed_refinement_team_profiled_ckpt(
            &mut outer,
            &mut inner,
            &mut x,
            &b,
            cfg.solver.tol,
            cfg.solver.max_outer,
            cfg.solver.inner_tol,
            cfg.solver.maxiter,
            InnerAlgorithm::BiCgStab,
            &mut team,
            prof.as_ref(),
            ckpt.as_mut(),
            resume_state.as_ref(),
        );
        println!(
            "true |Mx-b|/|b| = {:.3e}",
            solver::residual::operator_residual(&mut outer, &x, &b)
        );
        stats
    } else {
        // CGNR at f64: MdagM x = Mdag b, inner CG on the f32 normal operator
        let mut outer = NativeMdagM::with_links(&geom, links64, kappa);
        let mut inner = NativeMdagM::with_links(&geom, links32, kappa as f32);
        let mut bp = b.clone();
        bp.gamma5();
        let mut mbp = FermionField::zeros(&geom);
        outer.meo().apply(&mut mbp, &bp);
        mbp.gamma5();
        let mut x = FermionField::<f64>::zeros(&geom);
        let stats = solver::mixed_refinement_team_profiled_ckpt(
            &mut outer,
            &mut inner,
            &mut x,
            &mbp,
            cfg.solver.tol,
            cfg.solver.max_outer,
            cfg.solver.inner_tol,
            cfg.solver.maxiter,
            InnerAlgorithm::Cg,
            &mut team,
            prof.as_ref(),
            ckpt.as_mut(),
            resume_state.as_ref(),
        );
        println!(
            "true |MdagM x - Mdag b|/|Mdag b| = {:.3e}",
            solver::residual::operator_residual(&mut outer, &x, &mbp)
        );
        stats
    };
    let secs = sw.secs();
    println!(
        "{}(mixed): {} outer steps, {} inner f32 iterations, converged={}, \
         rel residual {:.3e}, {:.2}s, {:.2} GFlops",
        cfg.solver.algorithm,
        stats.outer_iterations,
        stats.inner_iterations,
        stats.converged,
        stats.rel_residual,
        secs,
        stats.flops as f64 / secs / 1e9,
    );
    for (i, r) in stats.history.iter().enumerate() {
        println!("  outer {i:>2}  true |r|/|b| = {r:.3e}");
    }
    if cfg.checkpoint.dir.is_some() {
        print_checkpoint_summary(
            ckpt.as_ref().map(|c| c.committed()).unwrap_or(0),
            resume_state.is_some() as u64,
        );
    }
    if let (true, Some(p)) = (profile, &prof) {
        emit_profile(&p.snapshot(), &cfg.artifacts_dir)?;
    }
    if let Some(t) = &tracer {
        emit_telemetry(cfg, vec![t.drain()])?;
    }
    Ok(())
}

const HELP: &str = "\
lqcd — even-odd Wilson fermion matrix for lattice QCD (A64FX paper repro)

USAGE: lqcd <command> [options]

COMMANDS:
  info          machine model, host calibration, artifact inventory
  solve         even-odd preconditioned solve on a random gauge field
  tune          measure tiling/threads/EO2-chunking on this host and write
                the per-machine tune cache that later solves resolve their
                performance knobs from (knob precedence: CLI/config >
                tune cache > static heuristic; --quick for a CI-sized sweep)
  bench-table1  Table 1: 2D SIMD tiling sweep (GFlops)
  bench-fig8    Fig 8: gather/scatter vs shuffle bulk kernel accounting
  bench-fig9    Fig 9: EO1/EO2 per-thread load (+ balanced extension)
  bench-fig10   Fig 10: weak scaling to 512 nodes (TofuD model)
  bench-acle    vectorized vs plain scalar kernel (~10x claim)
  bench-barrier FLIB_BARRIER ablation (spin vs sleep barrier)
  lint          in-tree invariant linter (SAFETY comments, canonical f64
                reductions, comm-tag registry, config-doc coverage,
                util::json-only JSON) + deterministic concurrency
                model checker; non-zero exit on any violation

OPTIONS:
  --dims NXxNYxNZxNT   lattice (default 8x8x8x16)
  --tiling VXxVY       SIMD tiling (default 4x4)
  --grid PXxPYxPZxPT   process decomposition (default 1x1x1x1); more than
                       one rank runs the solve on the simulated MPI world:
                       batched halo exchange (one message per direction for
                       all right-hand sides), composes with --nrhs and
                       --gauge-compression (f32/f64)
  --threads N          worker-team threads: for `solve`, the fused solver
                       pipeline runs whole iterations on the team
                       (solver.threads; residual histories are identical
                       at any thread count; unset = auto from the machine
                       model); for benches, threads per rank
  --nrhs N             right-hand sides per batched sweep (default 1);
                       N > 1 solves N systems through the multi-RHS block
                       solver, streaming the gauge field once for all
  --iters N            measurement iterations
  --kappa X --tol X --maxiter N
  --algorithm cg|bicgstab
  --precision f32|f64|mixed   field/kernel precision (mixed = f64 outer
                       iterative refinement around an f32 inner solve)
  --gauge-compression none|two-row
                       gauge-link storage: two-row streams 12 reals per
                       link (instead of 18) and rebuilds the third SU(3)
                       row in-register — 1/3 less gauge traffic on the
                       bandwidth-bound kernel; links must be unitary
  --inner-tol X        mixed: relative tolerance of each inner f32 solve
  --max-outer N        mixed: cap on outer refinement steps
  --pjrt               execute the AOT artifacts on the hot path (f32)
  --artifacts DIR      artifact directory (default ./artifacts)
  --config FILE        TOML-subset run configuration
  --quick              smaller lattices/iterations; for `tune`, a CI-sized sweep
  --eo2-schedule uniform|balanced
                       distributed EO2 merge partition (unset = tune cache
                       or heuristic)
  --eo2-granularity N  boundary-site granularity of the balanced EO2
                       partition (unset = tune cache or heuristic)
  --tune-cache DIR     tune-cache directory (default ./tune-cache)
  --budget-ms N        total wall budget of a `tune` sweep (default 3000)
  --no-tune            ignore the tune cache: knobs come from CLI/config
                       or the static heuristics only
  --profile            render per-thread phase bars after the solve and
                       write profile.json to the artifacts dir (all
                       native paths; distributed solves additionally
                       write one profile.rank<N>.json per rank)
  --trace DIR          enable span telemetry: write Chrome-trace/Perfetto
                       trace.json (one track per rank x thread: solver
                       phases, BLAS sweeps, transport events) and
                       metrics.json (phase-time p50/p95/p99, counters,
                       slowdown report) to DIR, and print the
                       machine-readable `slowdowns:` summary line.
                       Detector knobs come from the config [telemetry]
                       section. Off = zero instrumentation; residual
                       histories are bitwise identical either way
  --inject-faults SPEC deterministic fault injection into the simulated
                       transport (multi-rank solves only). SPEC is
                       ';'-separated rules: kind[:key=value,...] with
                       kinds drop|delay|corrupt|sdc|duplicate|truncate|
                       stall|kill and keys seed|rank|tag|nth|count|ms|iter,
                       e.g. 'drop:seed=7' or 'kill:rank=1,iter=2'.
                       Transport faults heal via checksum-verified
                       retransmit; sdc/stagnation heal via health-guard
                       restarts; kill surfaces a structured error
  --comm-timeout-ms N  recv/collective deadline per message (default
                       30000; 0 waits forever)
  --comm-max-retries N retransmit attempts per lost/corrupt message
                       (default 3)
  --max-restarts N     Krylov restarts the solver health guard may spend
                       on recoverable events before giving up (default 3)
  --checkpoint-dir DIR write versioned, CRC-protected solver checkpoints
                       to DIR on a fixed iteration cadence (atomic
                       temp+fsync+rename; [checkpoint] config section
                       sets cadence/rotation/buddy). Distributed solves
                       commit a generation only once every rank wrote it
                       (two-phase commit) and exchange in-memory buddy
                       copies ring-wise; a kill-fault then auto-recovers:
                       buddy files are rewritten and the world relaunches
                       resuming from the last generation committed by all
  --checkpoint-every N checkpoint cadence in solver iterations
                       (default 25; 0 disables the iteration cadence)
  --resume DIR         resume a solve from the newest valid checkpoint
                       generation in DIR (corrupt generations fall back
                       to older ones); the residual history continues
                       bitwise identically to the uninterrupted run
  --root DIR           lint: repository root to scan (default .)
  --json PATH          lint: write the findings + model-check report as
                       JSON (util::json format) to PATH
  --rules              lint: list the rule names and exit
  --model-check        lint: also run the exhaustive concurrency
                       model-checker suite (TeamBarrier both kinds,
                       telemetry span ring, retransmit recv state
                       machine, at 2-3 threads, plus seeded mutants
                       that must be caught)
  --max-preemptions N  lint: model-checker preemption bound (default 4)
";
