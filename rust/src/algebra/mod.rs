//! Scalar SU(3) / spinor algebra.
//!
//! These types back the plain-scalar dslash (the paper's "without ACLE"
//! baseline, §4.2), field initialization, observables, and the test
//! oracles. The vectorized kernels in [`crate::dslash`] work on lane
//! arrays directly and never allocate these structs in the hot loop.

mod complex;
mod gamma;
mod project;
mod real;
mod spinor;
mod su3;

pub use complex::Complex;
pub use gamma::{Gamma, GAMMA, GAMMA5};
pub use project::{Coef, ProjEntry, PROJ};
pub use real::Real;
pub use spinor::{HalfSpinor, Spinor};
pub use su3::Su3;
