//! Explicit gamma matrices (DeGrand-Rossi chiral basis), direction order
//! (x, y, z, t). These mirror `python/compile/kernels/ref.py::GAMMA`
//! exactly; the projection tables in [`super::project`] are verified
//! against them in tests, never trusted by hand.

use super::{Complex, Spinor};

const Z: Complex = Complex { re: 0.0, im: 0.0 };
const ONE: Complex = Complex { re: 1.0, im: 0.0 };
const MONE: Complex = Complex { re: -1.0, im: 0.0 };
const I: Complex = Complex { re: 0.0, im: 1.0 };
const MI: Complex = Complex { re: 0.0, im: -1.0 };

/// The four Euclidean gamma matrices.
#[derive(Clone, Copy, Debug)]
pub struct Gamma(pub [[Complex; 4]; 4]);

/// gamma_mu for mu = x, y, z, t.
pub const GAMMA: [Gamma; 4] = [
    // gamma_x
    Gamma([
        [Z, Z, Z, I],
        [Z, Z, I, Z],
        [Z, MI, Z, Z],
        [MI, Z, Z, Z],
    ]),
    // gamma_y
    Gamma([
        [Z, Z, Z, MONE],
        [Z, Z, ONE, Z],
        [Z, ONE, Z, Z],
        [MONE, Z, Z, Z],
    ]),
    // gamma_z
    Gamma([
        [Z, Z, I, Z],
        [Z, Z, Z, MI],
        [MI, Z, Z, Z],
        [Z, I, Z, Z],
    ]),
    // gamma_t
    Gamma([
        [Z, Z, ONE, Z],
        [Z, Z, Z, ONE],
        [ONE, Z, Z, Z],
        [Z, ONE, Z, Z],
    ]),
];

/// gamma_5 = gamma_x gamma_y gamma_z gamma_t = diag(1, 1, -1, -1).
pub const GAMMA5: Gamma = Gamma([
    [ONE, Z, Z, Z],
    [Z, ONE, Z, Z],
    [Z, Z, MONE, Z],
    [Z, Z, Z, MONE],
]);

impl Gamma {
    /// Apply to the spinor index: (g psi)_i = sum_j g[i][j] psi_j.
    pub fn mul(&self, psi: &Spinor) -> Spinor {
        let mut out = Spinor::ZERO;
        for i in 0..4 {
            for j in 0..4 {
                let g = self.0[i][j];
                if g == Z {
                    continue;
                }
                for c in 0..3 {
                    out.s[i][c] = out.s[i][c].madd(g, psi.s[j][c]);
                }
            }
        }
        out
    }

    pub fn matmul(&self, o: &Gamma) -> Gamma {
        let mut out = [[Z; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                let mut acc = Z;
                for k in 0..4 {
                    acc = acc.madd(self.0[i][k], o.0[k][j]);
                }
                out[i][j] = acc;
            }
        }
        Gamma(out)
    }

    pub fn dist(&self, o: &Gamma) -> f64 {
        let mut s = 0.0;
        for i in 0..4 {
            for j in 0..4 {
                s += (self.0[i][j] - o.0[i][j]).norm2();
            }
        }
        s.sqrt()
    }

    pub fn identity() -> Gamma {
        Gamma([
            [ONE, Z, Z, Z],
            [Z, ONE, Z, Z],
            [Z, Z, ONE, Z],
            [Z, Z, Z, ONE],
        ])
    }

    pub fn scaled(&self, a: f64) -> Gamma {
        let mut out = self.0;
        for row in out.iter_mut() {
            for e in row.iter_mut() {
                *e = e.scale(a);
            }
        }
        Gamma(out)
    }

    pub fn add(&self, o: &Gamma) -> Gamma {
        let mut out = self.0;
        for i in 0..4 {
            for j in 0..4 {
                out[i][j] += o.0[i][j];
            }
        }
        Gamma(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_squares_to_one() {
        for g in &GAMMA {
            assert!(g.matmul(g).dist(&Gamma::identity()) < 1e-14);
        }
    }

    #[test]
    fn anticommutation() {
        for (mu, g) in GAMMA.iter().enumerate() {
            for (nu, h) in GAMMA.iter().enumerate() {
                let anti = g.matmul(h).add(&h.matmul(g));
                let want = Gamma::identity().scaled(if mu == nu { 2.0 } else { 0.0 });
                assert!(anti.dist(&want) < 1e-14, "mu={mu} nu={nu}");
            }
        }
    }

    #[test]
    fn gamma5_is_product() {
        let p = GAMMA[0]
            .matmul(&GAMMA[1])
            .matmul(&GAMMA[2])
            .matmul(&GAMMA[3]);
        assert!(p.dist(&GAMMA5) < 1e-14);
    }

    #[test]
    fn hermitian() {
        for g in &GAMMA {
            let mut adj = [[Z; 4]; 4];
            for i in 0..4 {
                for j in 0..4 {
                    adj[i][j] = g.0[j][i].conj();
                }
            }
            assert!(g.dist(&Gamma(adj)) < 1e-14);
        }
    }

    #[test]
    fn spinor_gamma5_matches_matrix() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seeded(8);
        let mut psi = Spinor::ZERO;
        for i in 0..4 {
            for c in 0..3 {
                psi.s[i][c] = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        assert!((GAMMA5.mul(&psi).sub(&psi.gamma5())).norm2() < 1e-24);
    }
}
