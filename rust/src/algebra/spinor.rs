//! 4-component (Dirac) spinors over color, and 2-component half-spinors.

use super::{Complex, Su3};

/// A full spinor: 4 spin x 3 color complex components.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Spinor {
    pub s: [[Complex; 3]; 4],
}

/// A projected half-spinor: 2 spin x 3 color complex components.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HalfSpinor {
    pub h: [[Complex; 3]; 2],
}

impl Spinor {
    pub const ZERO: Spinor = Spinor {
        s: [[Complex { re: 0.0, im: 0.0 }; 3]; 4],
    };

    pub fn add(&self, o: &Spinor) -> Spinor {
        let mut out = *self;
        for i in 0..4 {
            for c in 0..3 {
                out.s[i][c] += o.s[i][c];
            }
        }
        out
    }

    pub fn sub(&self, o: &Spinor) -> Spinor {
        let mut out = *self;
        for i in 0..4 {
            for c in 0..3 {
                out.s[i][c] -= o.s[i][c];
            }
        }
        out
    }

    pub fn scale(&self, a: f64) -> Spinor {
        let mut out = *self;
        for i in 0..4 {
            for c in 0..3 {
                out.s[i][c] = out.s[i][c].scale(a);
            }
        }
        out
    }

    /// axpy: self + a * o
    pub fn axpy(&self, a: f64, o: &Spinor) -> Spinor {
        let mut out = *self;
        for i in 0..4 {
            for c in 0..3 {
                out.s[i][c] += o.s[i][c].scale(a);
            }
        }
        out
    }

    pub fn norm2(&self) -> f64 {
        let mut n = 0.0;
        for i in 0..4 {
            for c in 0..3 {
                n += self.s[i][c].norm2();
            }
        }
        n
    }

    /// <self, o> with conjugation on self.
    pub fn dot(&self, o: &Spinor) -> Complex {
        let mut acc = Complex::ZERO;
        for i in 0..4 {
            for c in 0..3 {
                acc = acc.madd_conj(self.s[i][c], o.s[i][c]);
            }
        }
        acc
    }

    /// gamma5 in the chiral basis: negate spin components 2 and 3.
    pub fn gamma5(&self) -> Spinor {
        let mut out = *self;
        for i in 2..4 {
            for c in 0..3 {
                out.s[i][c] = -out.s[i][c];
            }
        }
        out
    }
}

impl HalfSpinor {
    /// Multiply each spin row by the link: w_s = U h_s.
    pub fn link_mul(&self, u: &Su3) -> HalfSpinor {
        HalfSpinor {
            h: [u.mul_vec(&self.h[0]), u.mul_vec(&self.h[1])],
        }
    }

    /// Multiply each spin row by the adjoint link: w_s = U^dag h_s.
    pub fn link_adj_mul(&self, u: &Su3) -> HalfSpinor {
        HalfSpinor {
            h: [u.adj_mul_vec(&self.h[0]), u.adj_mul_vec(&self.h[1])],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_spinor(rng: &mut Rng) -> Spinor {
        let mut s = Spinor::ZERO;
        for i in 0..4 {
            for c in 0..3 {
                s.s[i][c] = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        s
    }

    #[test]
    fn linear_ops() {
        let mut rng = Rng::seeded(2);
        let a = rand_spinor(&mut rng);
        let b = rand_spinor(&mut rng);
        let got = a.add(&b).sub(&b);
        for i in 0..4 {
            for c in 0..3 {
                assert!((got.s[i][c] - a.s[i][c]).abs() < 1e-12);
            }
        }
        assert!((a.axpy(2.0, &b).sub(&a).sub(&b.scale(2.0))).norm2() < 1e-24);
    }

    #[test]
    fn dot_and_norm_agree() {
        let mut rng = Rng::seeded(3);
        let a = rand_spinor(&mut rng);
        assert!((a.dot(&a).re - a.norm2()).abs() < 1e-12);
        assert!(a.dot(&a).im.abs() < 1e-12);
    }

    #[test]
    fn gamma5_squares_to_identity() {
        let mut rng = Rng::seeded(4);
        let a = rand_spinor(&mut rng);
        assert!((a.gamma5().gamma5().sub(&a)).norm2() < 1e-24);
    }

    #[test]
    fn link_mul_unitary_preserves_norm() {
        let mut rng = Rng::seeded(5);
        let u = Su3::random(&mut rng);
        let h = HalfSpinor {
            h: [
                [Complex::new(1.0, 0.0), Complex::new(0.0, 1.0), Complex::new(0.5, 0.5)],
                [Complex::new(-1.0, 2.0), Complex::ZERO, Complex::new(0.25, 0.0)],
            ],
        };
        let n = |hs: &HalfSpinor| -> f64 {
            hs.h.iter().flatten().map(|e| e.norm2()).sum()
        };
        assert!((n(&h.link_mul(&u)) - n(&h)).abs() < 1e-12);
        assert!((n(&h.link_adj_mul(&u)) - n(&h)).abs() < 1e-12);
        // U^dag U h == h
        let round = h.link_mul(&u).link_adj_mul(&u);
        for s in 0..2 {
            for c in 0..3 {
                assert!((round.h[s][c] - h.h[s][c]).abs() < 1e-12);
            }
        }
    }
}
