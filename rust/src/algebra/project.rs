//! Spin projection / reconstruction tables for `(1 -+ gamma_mu)`.
//!
//! `(1 -+ gamma_mu)` has rank 2; the kernels apply it as a 4 -> 2 spinor
//! projection, multiply the link into the half-spinor, and reconstruct
//! (paper Fig. 2, lines 4-9). These tables are the single source of truth
//! for the native kernels and are verified against the explicit gamma
//! matrices in tests. They match `python/compile/kernels/wilson.py::PROJ`.

use super::{Complex, HalfSpinor, Spinor};

/// Coefficient: one of +-1, +-i — stored so kernels can branch to
/// add/sub/i-mul instead of a general complex multiply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Coef {
    One,
    MinusOne,
    I,
    MinusI,
}

impl Coef {
    #[inline]
    pub fn apply(self, v: Complex) -> Complex {
        match self {
            Coef::One => v,
            Coef::MinusOne => -v,
            Coef::I => v.mul_i(),
            Coef::MinusI => v.mul_mi(),
        }
    }

    /// As split re/im factors acting on (re, im): returns (new_re, new_im)
    /// as linear combinations; used by the lane kernels.
    #[inline]
    pub fn apply_split(self, re: f32, im: f32) -> (f32, f32) {
        match self {
            Coef::One => (re, im),
            Coef::MinusOne => (-re, -im),
            Coef::I => (-im, re),
            Coef::MinusI => (im, -re),
        }
    }
}

/// Projection/reconstruction rule for one (direction, sign):
///
/// ```text
/// h1 = psi_0 + c1 * psi_j1          r_0 = h1
/// h2 = psi_1 + c2 * psi_j2          r_1 = h2
///                                   r_2 = d1 * h_k1
///                                   r_3 = d2 * h_k2
/// ```
#[derive(Clone, Copy, Debug)]
pub struct ProjEntry {
    pub j1: usize,
    pub c1: Coef,
    pub j2: usize,
    pub c2: Coef,
    pub k1: usize,
    pub d1: Coef,
    pub k2: usize,
    pub d2: Coef,
}

use Coef::{MinusI as MI, MinusOne as MONE, One as ONE, I};

/// `PROJ[mu][sign]`: sign 0 = forward hop `(1 - gamma_mu)`,
/// sign 1 = backward hop `(1 + gamma_mu)`.
pub const PROJ: [[ProjEntry; 2]; 4] = [
    // mu = 0 (x)
    [
        ProjEntry { j1: 3, c1: MI, j2: 2, c2: MI, k1: 1, d1: I, k2: 0, d2: I },
        ProjEntry { j1: 3, c1: I, j2: 2, c2: I, k1: 1, d1: MI, k2: 0, d2: MI },
    ],
    // mu = 1 (y)
    [
        ProjEntry { j1: 3, c1: ONE, j2: 2, c2: MONE, k1: 1, d1: MONE, k2: 0, d2: ONE },
        ProjEntry { j1: 3, c1: MONE, j2: 2, c2: ONE, k1: 1, d1: ONE, k2: 0, d2: MONE },
    ],
    // mu = 2 (z)
    [
        ProjEntry { j1: 2, c1: MI, j2: 3, c2: I, k1: 0, d1: I, k2: 1, d2: MI },
        ProjEntry { j1: 2, c1: I, j2: 3, c2: MI, k1: 0, d1: MI, k2: 1, d2: I },
    ],
    // mu = 3 (t)
    [
        ProjEntry { j1: 2, c1: MONE, j2: 3, c2: MONE, k1: 0, d1: MONE, k2: 1, d2: MONE },
        ProjEntry { j1: 2, c1: ONE, j2: 3, c2: ONE, k1: 0, d1: ONE, k2: 1, d2: ONE },
    ],
];

impl ProjEntry {
    /// Project a full spinor to the half-spinor.
    #[inline]
    pub fn project(&self, psi: &Spinor) -> HalfSpinor {
        let mut h = HalfSpinor::default();
        for c in 0..3 {
            h.h[0][c] = psi.s[0][c] + self.c1.apply(psi.s[self.j1][c]);
            h.h[1][c] = psi.s[1][c] + self.c2.apply(psi.s[self.j2][c]);
        }
        h
    }

    /// Reconstruct the full spinor and accumulate into `acc`.
    #[inline]
    pub fn reconstruct_accum(&self, acc: &mut Spinor, w: &HalfSpinor) {
        for c in 0..3 {
            acc.s[0][c] += w.h[0][c];
            acc.s[1][c] += w.h[1][c];
            acc.s[2][c] += self.d1.apply(w.h[self.k1][c]);
            acc.s[3][c] += self.d2.apply(w.h[self.k2][c]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::gamma::GAMMA;
    use super::*;
    use crate::util::rng::Rng;

    fn rand_spinor(rng: &mut Rng) -> Spinor {
        let mut s = Spinor::ZERO;
        for i in 0..4 {
            for c in 0..3 {
                s.s[i][c] = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        s
    }

    /// The tables must reproduce (1 -+ gamma_mu) psi exactly — the same
    /// derivation check as python/tests/test_kernel.py.
    #[test]
    fn tables_match_explicit_gammas() {
        let mut rng = Rng::seeded(31);
        for mu in 0..4 {
            for sign in 0..2 {
                let psi = rand_spinor(&mut rng);
                let gp = GAMMA[mu].mul(&psi);
                let s = if sign == 0 { -1.0 } else { 1.0 };
                let want = psi.add(&gp.scale(s));

                let e = &PROJ[mu][sign];
                let h = e.project(&psi);
                let mut got = Spinor::ZERO;
                e.reconstruct_accum(&mut got, &h);
                assert!(
                    got.sub(&want).norm2() < 1e-24,
                    "mu={mu} sign={sign}"
                );
            }
        }
    }

    /// (1 - g)(1 + g) = 0: projecting one way then the other annihilates.
    #[test]
    fn opposite_projectors_annihilate() {
        let mut rng = Rng::seeded(32);
        for mu in 0..4 {
            let psi = rand_spinor(&mut rng);
            let h = PROJ[mu][0].project(&psi);
            let mut r = Spinor::ZERO;
            PROJ[mu][0].reconstruct_accum(&mut r, &h);
            // r = (1 - g) psi; then (1 + g) r must vanish
            let h2 = PROJ[mu][1].project(&r);
            let mut r2 = Spinor::ZERO;
            PROJ[mu][1].reconstruct_accum(&mut r2, &h2);
            assert!(r2.norm2() < 1e-22, "mu={mu}: {}", r2.norm2());
        }
    }

    /// (1 -+ g)^2 = 2 (1 -+ g): twice a projector.
    #[test]
    fn projector_idempotent_up_to_2() {
        let mut rng = Rng::seeded(33);
        for mu in 0..4 {
            for sign in 0..2 {
                let psi = rand_spinor(&mut rng);
                let e = &PROJ[mu][sign];
                let mut r = Spinor::ZERO;
                e.reconstruct_accum(&mut r, &e.project(&psi));
                let mut rr = Spinor::ZERO;
                e.reconstruct_accum(&mut rr, &e.project(&r));
                assert!(rr.sub(&r.scale(2.0)).norm2() < 1e-22);
            }
        }
    }

    #[test]
    fn coef_split_matches_complex() {
        for coef in [Coef::One, Coef::MinusOne, Coef::I, Coef::MinusI] {
            let v = Complex::new(0.75, -0.5);
            let (re, im) = coef.apply_split(v.re as f32, v.im as f32);
            let want = coef.apply(v);
            assert!((re as f64 - want.re).abs() < 1e-6);
            assert!((im as f64 - want.im).abs() < 1e-6);
        }
    }
}
