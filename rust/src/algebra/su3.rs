//! 3x3 complex (SU(3)) matrices: gauge links.

use super::Complex;
use crate::util::rng::Rng;

/// A 3x3 complex matrix; gauge links live in SU(3).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Su3 {
    pub m: [[Complex; 3]; 3],
}

impl Su3 {
    pub const IDENTITY: Su3 = {
        let mut m = [[Complex { re: 0.0, im: 0.0 }; 3]; 3];
        m[0][0] = Complex { re: 1.0, im: 0.0 };
        m[1][1] = Complex { re: 1.0, im: 0.0 };
        m[2][2] = Complex { re: 1.0, im: 0.0 };
        Su3 { m }
    };

    /// Hermitian conjugate.
    pub fn adj(&self) -> Su3 {
        let mut out = Su3::default();
        for a in 0..3 {
            for b in 0..3 {
                out.m[a][b] = self.m[b][a].conj();
            }
        }
        out
    }

    /// Matrix product.
    pub fn mul(&self, o: &Su3) -> Su3 {
        let mut out = Su3::default();
        for a in 0..3 {
            for b in 0..3 {
                let mut acc = Complex::ZERO;
                for c in 0..3 {
                    acc = acc.madd(self.m[a][c], o.m[c][b]);
                }
                out.m[a][b] = acc;
            }
        }
        out
    }

    /// Matrix-vector product w_a = sum_b U[a][b] v_b.
    pub fn mul_vec(&self, v: &[Complex; 3]) -> [Complex; 3] {
        let mut out = [Complex::ZERO; 3];
        for a in 0..3 {
            for b in 0..3 {
                out[a] = out[a].madd(self.m[a][b], v[b]);
            }
        }
        out
    }

    /// w_a = sum_b conj(U[b][a]) v_b (adjoint times vector).
    pub fn adj_mul_vec(&self, v: &[Complex; 3]) -> [Complex; 3] {
        let mut out = [Complex::ZERO; 3];
        for a in 0..3 {
            for b in 0..3 {
                out[a] = out[a].madd_conj(self.m[b][a], v[b]);
            }
        }
        out
    }

    pub fn trace(&self) -> Complex {
        self.m[0][0] + self.m[1][1] + self.m[2][2]
    }

    pub fn det(&self) -> Complex {
        let m = &self.m;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }

    /// Random SU(3) matrix: Gaussian entries, Gram-Schmidt, det fixed to 1.
    pub fn random(rng: &mut Rng) -> Su3 {
        let mut rows = [[Complex::ZERO; 3]; 3];
        for row in rows.iter_mut() {
            for e in row.iter_mut() {
                *e = Complex::new(rng.gaussian(), rng.gaussian());
            }
        }
        // Gram-Schmidt orthonormalization of the rows
        for i in 0..3 {
            for j in 0..i {
                // rows[i] -= <rows[j], rows[i]> rows[j]
                let mut dot = Complex::ZERO;
                for c in 0..3 {
                    dot = dot.madd_conj(rows[j][c], rows[i][c]);
                }
                for c in 0..3 {
                    rows[i][c] = rows[i][c] - rows[j][c] * dot;
                }
            }
            let norm: f64 = rows[i].iter().map(|e| e.norm2()).sum::<f64>().sqrt();
            for c in 0..3 {
                rows[i][c] = rows[i][c].scale(1.0 / norm);
            }
        }
        let mut u = Su3 { m: rows };
        // rescale a row by conj(det) to make det exactly 1 (|det| = 1 already)
        let d = u.det();
        for c in 0..3 {
            u.m[2][c] = u.m[2][c] * d.conj();
        }
        u
    }

    /// Frobenius distance to another matrix.
    pub fn dist(&self, o: &Su3) -> f64 {
        let mut s = 0.0;
        for a in 0..3 {
            for b in 0..3 {
                s += (self.m[a][b] - o.m[a][b]).norm2();
            }
        }
        s.sqrt()
    }

    /// How far from unitary: || U U^dag - 1 ||.
    pub fn unitarity_error(&self) -> f64 {
        self.mul(&self.adj()).dist(&Su3::IDENTITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_properties() {
        let id = Su3::IDENTITY;
        assert_eq!(id.mul(&id), id);
        assert!((id.det() - Complex::ONE).abs() < 1e-14);
        assert!((id.trace() - Complex::new(3.0, 0.0)).abs() < 1e-14);
    }

    #[test]
    fn random_is_special_unitary() {
        let mut rng = Rng::seeded(17);
        for _ in 0..50 {
            let u = Su3::random(&mut rng);
            assert!(u.unitarity_error() < 1e-12, "not unitary");
            assert!((u.det() - Complex::ONE).abs() < 1e-12, "det != 1");
        }
    }

    #[test]
    fn adj_reverses_products() {
        let mut rng = Rng::seeded(5);
        let a = Su3::random(&mut rng);
        let b = Su3::random(&mut rng);
        assert!(a.mul(&b).adj().dist(&b.adj().mul(&a.adj())) < 1e-12);
    }

    #[test]
    fn adj_mul_vec_matches_explicit_adjoint() {
        let mut rng = Rng::seeded(9);
        let u = Su3::random(&mut rng);
        let v = [
            Complex::new(rng.gaussian(), rng.gaussian()),
            Complex::new(rng.gaussian(), rng.gaussian()),
            Complex::new(rng.gaussian(), rng.gaussian()),
        ];
        let got = u.adj_mul_vec(&v);
        let want = u.adj().mul_vec(&v);
        for c in 0..3 {
            assert!((got[c] - want[c]).abs() < 1e-12);
        }
    }

    #[test]
    fn mul_vec_preserves_norm() {
        let mut rng = Rng::seeded(23);
        let u = Su3::random(&mut rng);
        let v = [
            Complex::new(1.0, 0.5),
            Complex::new(-2.0, 0.25),
            Complex::new(0.0, -1.0),
        ];
        let w = u.mul_vec(&v);
        let nv: f64 = v.iter().map(|e| e.norm2()).sum();
        let nw: f64 = w.iter().map(|e| e.norm2()).sum();
        assert!((nv - nw).abs() < 1e-12);
    }
}
