//! The [`Real`] scalar trait: the one abstraction that makes the whole
//! field / kernel / solver stack precision-generic.
//!
//! The paper's kernel is single-precision by design (A64FX peaks at 2x
//! the f32 throughput), but production workflows wrap a fast f32 inner
//! solve in an f64 outer iteration (mixed-precision iterative
//! refinement; see [`crate::solver::mixed`]). Everything that stores or
//! moves field data — [`crate::field`], [`crate::dslash`],
//! [`crate::comm`], the operators in [`crate::coordinator::operator`]
//! and the solvers in [`crate::solver`] — is generic over `Real`, with
//! `f32` as the default type parameter so the paper-faithful hot path
//! stays the default everywhere.
//!
//! Reductions (dot products, norms) deliberately do *not* happen in `R`:
//! every accumulation goes through [`Real::to_f64`] and sums in f64,
//! regardless of the field precision. CG stagnates when ~10^5 f32 terms
//! are accumulated in f32; keeping the reduction precision fixed also
//! means `SolveStats` are comparable across precisions.

use std::fmt::{Debug, Display};
use std::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A real scalar the lattice stack can be instantiated at.
///
/// Implemented for `f32` (the paper's benchmark precision) and `f64`
/// (the outer-solve / oracle precision). The bounds are exactly what the
/// kernels and solvers need: plain arithmetic, comparison, and loss-free
/// round-trips through `f64` for reductions and cross-precision
/// conversion.
pub trait Real:
    Copy
    + Clone
    + Default
    + Debug
    + Display
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
{
    const ZERO: Self;
    const ONE: Self;
    /// Machine epsilon of this precision (reported by solvers and used
    /// by tests to scale tolerances).
    const EPSILON: f64;
    /// Short name for reports and JSON output ("f32" / "f64").
    const NAME: &'static str;

    /// Round an f64 into this precision.
    fn from_f64(v: f64) -> Self;

    /// Widen into f64 (exact for both instantiations).
    fn to_f64(self) -> f64;
}

impl Real for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const EPSILON: f64 = f32::EPSILON as f64;
    const NAME: &'static str = "f32";

    #[inline(always)]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Real for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const EPSILON: f64 = f64::EPSILON;
    const NAME: &'static str = "f64";

    #[inline(always)]
    fn from_f64(v: f64) -> f64 {
        v
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_as_f64<R: Real>(xs: &[R]) -> f64 {
        xs.iter().map(|&x| x.to_f64()).sum()
    }

    #[test]
    fn roundtrip_and_constants() {
        assert_eq!(f32::from_f64(1.5), 1.5f32);
        assert_eq!(f64::from_f64(1.5), 1.5f64);
        assert_eq!(<f32 as Real>::ZERO, 0.0);
        assert_eq!(<f64 as Real>::ONE, 1.0);
        assert_eq!(<f32 as Real>::NAME, "f32");
        assert_eq!(<f64 as Real>::NAME, "f64");
        assert!(<f32 as Real>::EPSILON > <f64 as Real>::EPSILON);
    }

    #[test]
    fn f64_accumulation_beats_native_f32_sum() {
        // 1 + eps/2 summed repeatedly: a pure-f32 accumulator never moves,
        // the f64 accumulator tracks every term.
        let tiny = (f32::EPSILON / 4.0) as f64;
        let xs: Vec<f32> = std::iter::once(1.0f32)
            .chain(std::iter::repeat(tiny as f32).take(1000))
            .collect();
        let naive: f32 = xs.iter().sum();
        let wide = sum_as_f64(&xs);
        assert_eq!(naive, 1.0, "f32 accumulation silently drops the tail");
        assert!((wide - (1.0 + 1000.0 * (tiny as f32) as f64)).abs() < 1e-9);
    }

    #[test]
    fn generic_arithmetic_compiles_at_both_precisions() {
        fn axpy<R: Real>(a: R, x: R, y: R) -> R {
            a * x + y
        }
        assert_eq!(axpy(2.0f32, 3.0, 1.0), 7.0);
        assert_eq!(axpy(2.0f64, 3.0, 1.0), 7.0);
    }
}
