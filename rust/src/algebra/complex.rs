//! Minimal complex number type (f64; the vectorized kernels use split
//! re/im f32 lanes instead and never touch this type).

use std::ops::{Add, AddAssign, Mul, Neg, Sub, SubAssign};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };
    pub const I: Complex = Complex { re: 0.0, im: 1.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Complex {
        Complex { re, im }
    }

    #[inline]
    pub fn conj(self) -> Complex {
        Complex::new(self.re, -self.im)
    }

    #[inline]
    pub fn norm2(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.norm2().sqrt()
    }

    #[inline]
    pub fn scale(self, s: f64) -> Complex {
        Complex::new(self.re * s, self.im * s)
    }

    /// self * i
    #[inline]
    pub fn mul_i(self) -> Complex {
        Complex::new(-self.im, self.re)
    }

    /// self * (-i)
    #[inline]
    pub fn mul_mi(self) -> Complex {
        Complex::new(self.im, -self.re)
    }

    /// Fused a + b*c.
    #[inline]
    pub fn madd(self, b: Complex, c: Complex) -> Complex {
        Complex::new(
            self.re + b.re * c.re - b.im * c.im,
            self.im + b.re * c.im + b.im * c.re,
        )
    }

    /// Fused a + conj(b)*c.
    #[inline]
    pub fn madd_conj(self, b: Complex, c: Complex) -> Complex {
        Complex::new(
            self.re + b.re * c.re + b.im * c.im,
            self.im + b.re * c.im - b.im * c.re,
        )
    }
}

impl Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }
}

impl AddAssign for Complex {
    #[inline]
    fn add_assign(&mut self, o: Complex) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }
}

impl SubAssign for Complex {
    #[inline]
    fn sub_assign(&mut self, o: Complex) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Neg for Complex {
    type Output = Complex;
    #[inline]
    fn neg(self) -> Complex {
        Complex::new(-self.re, -self.im)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: Complex, b: Complex) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn arithmetic() {
        let a = Complex::new(1.0, 2.0);
        let b = Complex::new(3.0, -1.0);
        assert!(close(a + b, Complex::new(4.0, 1.0)));
        assert!(close(a - b, Complex::new(-2.0, 3.0)));
        assert!(close(a * b, Complex::new(5.0, 5.0)));
        assert!(close(-a, Complex::new(-1.0, -2.0)));
    }

    #[test]
    fn i_multiplication() {
        let a = Complex::new(1.0, 2.0);
        assert!(close(a.mul_i(), a * Complex::I));
        assert!(close(a.mul_mi(), a * Complex::new(0.0, -1.0)));
    }

    #[test]
    fn fused_ops_match_expanded() {
        let a = Complex::new(0.5, -0.25);
        let b = Complex::new(2.0, 1.0);
        let c = Complex::new(-1.0, 3.0);
        assert!(close(a.madd(b, c), a + b * c));
        assert!(close(a.madd_conj(b, c), a + b.conj() * c));
    }

    #[test]
    fn conj_and_norm() {
        let a = Complex::new(3.0, 4.0);
        assert_eq!(a.conj(), Complex::new(3.0, -4.0));
        assert_eq!(a.norm2(), 25.0);
        assert_eq!(a.abs(), 5.0);
        assert!(close((a * a.conj()).scale(1.0 / a.norm2()), Complex::ONE));
    }
}
