//! AoSoA memory layout (paper section 3.2, Eq. 7).
//!
//! For one parity, single precision, the paper's layout is
//!
//! ```text
//! spinor: [NT][NZ][NY/VLENY][NX/NEO/VLENX][ND][NC][2][VLEN]
//! gauge : [NDIM][NEO][NT][NZ][NY/VLENY][NX/NEO/VLENX][NC][NC][2][VLEN]
//! ```
//!
//! i.e. "Array of Structure of Array": the trailing `[VLEN]` axis is the
//! SIMD vector, holding a `VLENX x VLENY` tile of the x-compacted x-y
//! plane (lane = `ly * VLENX + lx`, x fastest). Real and imaginary parts
//! occupy separate vectors (`[2]` axis), matching QWS.

use super::{EvenOdd, Geometry, Parity, Tiling};

pub const NSPIN: usize = 4;
pub const NCOL: usize = 3;
pub const NREIM: usize = 2;
/// spin x color x re/im components per site of a spinor field
pub const SC2: usize = NSPIN * NCOL * NREIM; // 24
/// color x color x re/im components per site of one gauge link
pub const CC2: usize = NCOL * NCOL * NREIM; // 18
pub const RE: usize = 0;
pub const IM: usize = 1;

/// A site of one parity in compacted coordinates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SiteCoord {
    pub t: usize,
    pub z: usize,
    pub y: usize,
    /// compacted x index (lexical x = 2*ix + phi)
    pub ix: usize,
}

/// Position of a site inside the AoSoA storage: which tile, which lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneCoord {
    pub tile: usize,
    pub lane: usize,
}

/// Index calculator for the AoSoA layout of one parity.
#[derive(Clone, Copy, Debug)]
pub struct EoLayout {
    pub nt: usize,
    pub nz: usize,
    /// tiles along y: NY / VLENY
    pub nyt: usize,
    /// tiles along compacted x: XH / VLENX
    pub nxt: usize,
    pub tiling: Tiling,
}

impl EoLayout {
    pub fn new(geom: &Geometry) -> EoLayout {
        let d = geom.local;
        let tl = geom.tiling;
        debug_assert_eq!(d.xh() % tl.vx(), 0);
        debug_assert_eq!(d.y % tl.vy(), 0);
        EoLayout {
            nt: d.t,
            nz: d.z,
            nyt: d.y / tl.vy(),
            nxt: d.xh() / tl.vx(),
            tiling: tl,
        }
    }

    #[inline]
    pub fn vlen(&self) -> usize {
        self.tiling.vlen()
    }

    /// Number of SIMD tiles in one parity field.
    #[inline]
    pub fn ntiles(&self) -> usize {
        self.nt * self.nz * self.nyt * self.nxt
    }

    /// Number of sites in one parity field.
    #[inline]
    pub fn nsites(&self) -> usize {
        self.ntiles() * self.vlen()
    }

    /// f32 length of a spinor field in this layout.
    #[inline]
    pub fn spinor_len(&self) -> usize {
        self.ntiles() * SC2 * self.vlen()
    }

    /// f32 length of one direction+parity of the gauge field.
    #[inline]
    pub fn gauge_len(&self) -> usize {
        self.ntiles() * CC2 * self.vlen()
    }

    /// Tile index of tile coordinates (t, z, yt, xt); xt fastest.
    #[inline]
    pub fn tile_index(&self, t: usize, z: usize, yt: usize, xt: usize) -> usize {
        debug_assert!(t < self.nt && z < self.nz && yt < self.nyt && xt < self.nxt);
        ((t * self.nz + z) * self.nyt + yt) * self.nxt + xt
    }

    /// Inverse of [`tile_index`]: tile -> (t, z, yt, xt).
    #[inline]
    pub fn tile_coords(&self, tile: usize) -> (usize, usize, usize, usize) {
        let xt = tile % self.nxt;
        let r = tile / self.nxt;
        let yt = r % self.nyt;
        let r = r / self.nyt;
        let z = r % self.nz;
        let t = r / self.nz;
        (t, z, yt, xt)
    }

    /// Storage position of a compacted site.
    #[inline]
    pub fn site_to_lane(&self, s: SiteCoord) -> LaneCoord {
        let (vx, vy) = (self.tiling.vx(), self.tiling.vy());
        let tile = self.tile_index(s.t, s.z, s.y / vy, s.ix / vx);
        LaneCoord {
            tile,
            lane: self.tiling.lane(s.ix % vx, s.y % vy),
        }
    }

    /// Inverse of [`site_to_lane`].
    #[inline]
    pub fn lane_to_site(&self, lc: LaneCoord) -> SiteCoord {
        let (t, z, yt, xt) = self.tile_coords(lc.tile);
        let (lx, ly) = self.tiling.coords(lc.lane);
        SiteCoord {
            t,
            z,
            y: yt * self.tiling.vy() + ly,
            ix: xt * self.tiling.vx() + lx,
        }
    }

    /// Offset of the `[VLEN]` vector for spinor component (spin, color, reim).
    #[inline]
    pub fn spinor_vec(&self, tile: usize, spin: usize, color: usize, reim: usize) -> usize {
        debug_assert!(spin < NSPIN && color < NCOL && reim < NREIM);
        ((tile * NSPIN + spin) * NCOL + color) * NREIM * self.vlen()
            + reim * self.vlen()
    }

    /// Offset of the `[VLEN]` vector for link component (row a, col b, reim).
    #[inline]
    pub fn gauge_vec(&self, tile: usize, a: usize, b: usize, reim: usize) -> usize {
        debug_assert!(a < NCOL && b < NCOL && reim < NREIM);
        ((tile * NCOL + a) * NCOL + b) * NREIM * self.vlen() + reim * self.vlen()
    }

    /// Scalar f32 offset of one spinor component of one site.
    #[inline]
    pub fn spinor_elem(
        &self,
        s: SiteCoord,
        spin: usize,
        color: usize,
        reim: usize,
    ) -> usize {
        let lc = self.site_to_lane(s);
        self.spinor_vec(lc.tile, spin, color, reim) + lc.lane
    }

    /// Scalar f32 offset of one link component of one site.
    #[inline]
    pub fn gauge_elem(&self, s: SiteCoord, a: usize, b: usize, reim: usize) -> usize {
        let lc = self.site_to_lane(s);
        self.gauge_vec(lc.tile, a, b, reim) + lc.lane
    }

    /// Iterate all compacted sites of this parity (t, z, y, ix order).
    pub fn sites(&self) -> impl Iterator<Item = SiteCoord> + '_ {
        let (vy, vx) = (self.tiling.vy(), self.tiling.vx());
        let (ny, nxh) = (self.nyt * vy, self.nxt * vx);
        (0..self.nt).flat_map(move |t| {
            (0..self.nz).flat_map(move |z| {
                (0..ny).flat_map(move |y| {
                    (0..nxh).map(move |ix| SiteCoord { t, z, y, ix })
                })
            })
        })
    }

    /// Lexical x of a compacted site for output parity `p`.
    #[inline]
    pub fn lexical_x(&self, s: SiteCoord, p: Parity) -> usize {
        EvenOdd::lexical_x(s.ix, EvenOdd::row_parity(s.y, s.z, s.t, p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::LatticeDims;

    fn layout(tiling: Tiling) -> EoLayout {
        let dims = LatticeDims::new(16, 8, 4, 6).unwrap();
        let geom = Geometry::single_rank(dims, tiling).unwrap();
        EoLayout::new(&geom)
    }

    #[test]
    fn site_lane_bijection() {
        for tiling in [Tiling::new(4, 4).unwrap(), Tiling::new(8, 2).unwrap(), Tiling::new(2, 8).unwrap()] {
            let l = layout(tiling);
            let mut seen = vec![false; l.nsites()];
            for s in l.sites() {
                let lc = l.site_to_lane(s);
                assert_eq!(l.lane_to_site(lc), s);
                let flat = lc.tile * l.vlen() + lc.lane;
                assert!(!seen[flat], "collision at {s:?}");
                seen[flat] = true;
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn offsets_disjoint_and_dense() {
        let l = layout(Tiling::new(4, 2).unwrap());
        // every (site, spin, color, reim) must map to a unique offset
        let mut seen = vec![false; l.spinor_len()];
        for s in l.sites() {
            for spin in 0..NSPIN {
                for color in 0..NCOL {
                    for reim in 0..NREIM {
                        let off = l.spinor_elem(s, spin, color, reim);
                        assert!(!seen[off]);
                        seen[off] = true;
                    }
                }
            }
        }
        assert!(seen.iter().all(|&b| b), "layout leaves holes");
    }

    #[test]
    fn vectors_are_contiguous_lanes() {
        let l = layout(Tiling::new(4, 4).unwrap());
        let base = l.spinor_vec(3, 2, 1, IM);
        // lane n of the same vector is base + n
        let (t, z, yt, xt) = l.tile_coords(3);
        for lane in 0..l.vlen() {
            let (lx, ly) = l.tiling.coords(lane);
            let s = SiteCoord {
                t,
                z,
                y: yt * l.tiling.vy() + ly,
                ix: xt * l.tiling.vx() + lx,
            };
            assert_eq!(l.spinor_elem(s, 2, 1, IM), base + lane);
        }
    }

    #[test]
    fn tile_coords_roundtrip() {
        let l = layout(Tiling::new(2, 2).unwrap());
        for tile in 0..l.ntiles() {
            let (t, z, yt, xt) = l.tile_coords(tile);
            assert_eq!(l.tile_index(t, z, yt, xt), tile);
        }
    }

    #[test]
    fn gauge_len_ratio() {
        let l = layout(Tiling::new(4, 4).unwrap());
        // 18 components per link vs 24 per spinor site
        assert_eq!(l.gauge_len() * SC2, l.spinor_len() * CC2);
    }
}
