//! Even-odd (red-black) site decomposition, Fig. 4 of the paper.
//!
//! Site parity is `(x + y + z + t) mod 2`. Sites of one parity are stored
//! *compacted in the x-direction*: a site of parity `p` at compact index
//! `ix` in row `(y, z, t)` has lexical `x = 2*ix + phi` with the row parity
//! `phi = (y + z + t + p) mod 2`.

/// Site parity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Parity {
    Even = 0,
    Odd = 1,
}

impl Parity {
    pub const BOTH: [Parity; 2] = [Parity::Even, Parity::Odd];

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    #[inline]
    pub fn flip(self) -> Parity {
        match self {
            Parity::Even => Parity::Odd,
            Parity::Odd => Parity::Even,
        }
    }

    #[inline]
    pub fn from_index(i: usize) -> Parity {
        if i % 2 == 0 {
            Parity::Even
        } else {
            Parity::Odd
        }
    }

    /// Parity of a site from its coordinates.
    #[inline]
    pub fn of_site(x: usize, y: usize, z: usize, t: usize) -> Parity {
        Parity::from_index(x + y + z + t)
    }
}

/// Helper for even-odd coordinate arithmetic on a row basis.
#[derive(Clone, Copy, Debug)]
pub struct EvenOdd;

impl EvenOdd {
    /// Row parity `phi = (y + z + t + p) mod 2`.
    #[inline]
    pub fn row_parity(y: usize, z: usize, t: usize, p: Parity) -> usize {
        (y + z + t + p.index()) % 2
    }

    /// Lexical x coordinate of compact index `ix` in a row of parity `phi`.
    #[inline]
    pub fn lexical_x(ix: usize, phi: usize) -> usize {
        2 * ix + phi
    }

    /// Compact x index of a lexical coordinate `x` (must match parity).
    #[inline]
    pub fn compact_x(x: usize) -> usize {
        x / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parity_basic() {
        assert_eq!(Parity::of_site(0, 0, 0, 0), Parity::Even);
        assert_eq!(Parity::of_site(1, 0, 0, 0), Parity::Odd);
        assert_eq!(Parity::of_site(1, 1, 0, 0), Parity::Even);
        assert_eq!(Parity::Even.flip(), Parity::Odd);
        assert_eq!(Parity::Odd.flip(), Parity::Even);
    }

    #[test]
    fn row_parity_reconstructs_x() {
        // every lexical site maps to (parity, ix) and back
        let (ny, nz, nt, nx) = (4, 2, 2, 8);
        for t in 0..nt {
            for z in 0..nz {
                for y in 0..ny {
                    for x in 0..nx {
                        let p = Parity::of_site(x, y, z, t);
                        let phi = EvenOdd::row_parity(y, z, t, p);
                        assert_eq!(x % 2, phi, "x parity must equal row parity");
                        let ix = EvenOdd::compact_x(x);
                        assert_eq!(EvenOdd::lexical_x(ix, phi), x);
                    }
                }
            }
        }
    }
}
