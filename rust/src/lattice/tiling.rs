//! 2D x-y SIMD tiling (paper Fig. 3): a `VLENX x VLENY` patch of the
//! x-compacted x-y plane is packed into one SIMD vector of
//! `VLEN = VLENX * VLENY` lanes. Lane order within a vector is
//! x-fastest: `lane = ly * VLENX + lx`.

use std::fmt;

/// A 2D SIMD tiling choice. The paper's single-precision sweep uses
/// VLEN = 16 with shapes 16x1, 8x2, 4x4 and 2x8 (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiling {
    vx: usize,
    vy: usize,
}

impl Tiling {
    /// Create a tiling; `vx >= 2` because even-odd compaction halves the
    /// x extent (the paper's VLENX >= 2 restriction), `vy >= 1`.
    pub fn new(vx: usize, vy: usize) -> Result<Tiling, String> {
        if vx < 2 {
            return Err(format!(
                "VLENX must be >= 2 (even-odd halves x), got {vx}"
            ));
        }
        if vy < 1 {
            return Err("VLENY must be >= 1".to_string());
        }
        Ok(Tiling { vx, vy })
    }

    /// Parse "4x4" style strings.
    pub fn parse(s: &str) -> Result<Tiling, String> {
        let (a, b) = s
            .split_once('x')
            .ok_or_else(|| format!("tiling must be VXxVY, got {s:?}"))?;
        let vx = a.parse().map_err(|_| format!("bad VLENX in {s:?}"))?;
        let vy = b.parse().map_err(|_| format!("bad VLENY in {s:?}"))?;
        Tiling::new(vx, vy)
    }

    /// The Table 1 sweep for VLEN = 16.
    pub fn table1_sweep() -> Vec<Tiling> {
        Tiling::sweep_for_vlen(16)
    }

    /// All legal `VLENX x VLENY` shapes at a fixed vector length: every
    /// divisor pair with `vx >= 2` (the even-odd restriction), largest
    /// `vx` first, so `sweep_for_vlen(16)` is exactly the Table 1 family
    /// 16x1, 8x2, 4x4, 2x8.
    pub fn sweep_for_vlen(vlen: usize) -> Vec<Tiling> {
        let mut out = Vec::new();
        let mut vx = vlen;
        while vx >= 2 {
            if vlen % vx == 0 {
                out.push(Tiling {
                    vx,
                    vy: vlen / vx,
                });
            }
            vx -= 1;
        }
        out
    }

    /// Whether a local lattice can be laid out with this tiling: the
    /// x-compacted extent must split into `vx` columns and y into `vy`
    /// rows (the same constraint `Geometry::for_rank` enforces).
    pub fn divides(self, dims: crate::lattice::LatticeDims) -> bool {
        dims.xh() % self.vx == 0 && dims.y % self.vy == 0
    }

    #[inline]
    pub fn vx(self) -> usize {
        self.vx
    }

    #[inline]
    pub fn vy(self) -> usize {
        self.vy
    }

    /// SIMD vector length (lanes).
    #[inline]
    pub fn vlen(self) -> usize {
        self.vx * self.vy
    }

    /// Lane index of in-tile coordinates (x-fastest).
    #[inline]
    pub fn lane(self, lx: usize, ly: usize) -> usize {
        debug_assert!(lx < self.vx && ly < self.vy);
        ly * self.vx + lx
    }

    /// Inverse of [`Tiling::lane`]: lane -> (lx, ly).
    #[inline]
    pub fn coords(self, lane: usize) -> (usize, usize) {
        debug_assert!(lane < self.vlen());
        (lane % self.vx, lane / self.vx)
    }
}

impl fmt::Display for Tiling {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.vx, self.vy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_roundtrip() {
        let t = Tiling::new(4, 4).unwrap();
        for lane in 0..t.vlen() {
            let (lx, ly) = t.coords(lane);
            assert_eq!(t.lane(lx, ly), lane);
        }
    }

    #[test]
    fn vlenx_1_rejected() {
        assert!(Tiling::new(1, 16).is_err());
    }

    #[test]
    fn parse_and_display() {
        let t = Tiling::parse("8x2").unwrap();
        assert_eq!((t.vx(), t.vy(), t.vlen()), (8, 2, 16));
        assert_eq!(t.to_string(), "8x2");
        assert!(Tiling::parse("8").is_err());
        assert!(Tiling::parse("axb").is_err());
    }

    #[test]
    fn table1_sweep_shapes() {
        let shapes: Vec<(usize, usize)> = Tiling::table1_sweep()
            .iter()
            .map(|t| (t.vx(), t.vy()))
            .collect();
        assert_eq!(shapes, vec![(16, 1), (8, 2), (4, 4), (2, 8)]);
        assert!(Tiling::table1_sweep().iter().all(|t| t.vlen() == 16));
    }

    #[test]
    fn sweep_for_vlen_families() {
        let shapes = |v: usize| -> Vec<(usize, usize)> {
            Tiling::sweep_for_vlen(v)
                .iter()
                .map(|t| (t.vx(), t.vy()))
                .collect()
        };
        assert_eq!(shapes(4), vec![(4, 1), (2, 2)]);
        assert_eq!(shapes(8), vec![(8, 1), (4, 2), (2, 4)]);
        assert_eq!(shapes(16), vec![(16, 1), (8, 2), (4, 4), (2, 8)]);
        // vx = 1 shapes are excluded even though they divide vlen
        assert!(Tiling::sweep_for_vlen(8).iter().all(|t| t.vx() >= 2));
    }

    #[test]
    fn divides_checks_compacted_x_and_y() {
        let dims = crate::lattice::LatticeDims::new(8, 4, 4, 4).unwrap();
        // xh = 4
        assert!(Tiling::new(4, 4).unwrap().divides(dims));
        assert!(Tiling::new(2, 2).unwrap().divides(dims));
        assert!(!Tiling::new(8, 2).unwrap().divides(dims)); // 4 % 8 != 0
        assert!(!Tiling::new(2, 8).unwrap().divides(dims)); // 4 % 8 != 0
    }
}
