//! Lattice extents and the 4D process decomposition.

use std::fmt;

use super::{Dir, Tiling};

#[derive(Debug, Clone)]
pub struct GeometryError(pub String);

impl fmt::Display for GeometryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for GeometryError {}

/// Lattice extents, all even (even-odd parity must survive the periodic
/// wrap) and >= 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatticeDims {
    pub x: usize,
    pub y: usize,
    pub z: usize,
    pub t: usize,
}

impl LatticeDims {
    pub fn new(x: usize, y: usize, z: usize, t: usize) -> Result<Self, GeometryError> {
        for (name, v) in [("NX", x), ("NY", y), ("NZ", z), ("NT", t)] {
            if v < 2 {
                return Err(GeometryError(format!("{name} must be >= 2, got {v}")));
            }
            if v % 2 != 0 {
                return Err(GeometryError(format!(
                    "{name} must be even for the even-odd layout, got {v}"
                )));
            }
        }
        Ok(LatticeDims { x, y, z, t })
    }

    /// Parse "16x16x8x8" (paper order NX x NY x NZ x NT).
    pub fn parse(s: &str) -> Result<Self, GeometryError> {
        let parts: Vec<usize> = s
            .split('x')
            .map(|p| p.parse().map_err(|_| GeometryError(format!("bad dims {s:?}"))))
            .collect::<Result<_, _>>()?;
        if parts.len() != 4 {
            return Err(GeometryError(format!("dims must be NXxNYxNZxNT, got {s:?}")));
        }
        LatticeDims::new(parts[0], parts[1], parts[2], parts[3])
    }

    #[inline]
    pub fn extent(&self, d: Dir) -> usize {
        match d {
            Dir::X => self.x,
            Dir::Y => self.y,
            Dir::Z => self.z,
            Dir::T => self.t,
        }
    }

    /// Compacted x extent (NX / 2).
    #[inline]
    pub fn xh(&self) -> usize {
        self.x / 2
    }

    #[inline]
    pub fn volume(&self) -> usize {
        self.x * self.y * self.z * self.t
    }

    #[inline]
    pub fn half_volume(&self) -> usize {
        self.volume() / 2
    }
}

impl fmt::Display for LatticeDims {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}x{}", self.x, self.y, self.z, self.t)
    }
}

/// 4D process grid (paper notation `[px, py, pz, pt]`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProcGrid(pub [usize; 4]);

impl ProcGrid {
    pub fn size(&self) -> usize {
        self.0.iter().product()
    }

    /// Parse "1x1x2x2" (PX x PY x PZ x PT, the `--grid` CLI spelling).
    pub fn parse(s: &str) -> Result<ProcGrid, GeometryError> {
        let parts: Vec<usize> = s
            .split('x')
            .map(|p| p.parse().map_err(|_| GeometryError(format!("bad grid {s:?}"))))
            .collect::<Result<_, _>>()?;
        if parts.len() != 4 {
            return Err(GeometryError(format!(
                "grid must be PXxPYxPZxPT, got {s:?}"
            )));
        }
        if parts.iter().any(|&p| p == 0) {
            return Err(GeometryError(format!(
                "grid extents must be >= 1, got {s:?}"
            )));
        }
        Ok(ProcGrid([parts[0], parts[1], parts[2], parts[3]]))
    }

    /// Rank id of grid coordinates (x fastest).
    pub fn rank_of(&self, c: [usize; 4]) -> usize {
        ((c[3] * self.0[2] + c[2]) * self.0[1] + c[1]) * self.0[0] + c[0]
    }

    /// Grid coordinates of a rank id.
    pub fn coords_of(&self, rank: usize) -> [usize; 4] {
        let mut r = rank;
        let mut c = [0usize; 4];
        for d in 0..4 {
            c[d] = r % self.0[d];
            r /= self.0[d];
        }
        c
    }

    /// Neighbor rank in direction `d`, displacement `sign` (periodic).
    pub fn neighbor(&self, rank: usize, d: Dir, sign: i64) -> usize {
        let mut c = self.coords_of(rank);
        let n = self.0[d.index()] as i64;
        c[d.index()] = ((c[d.index()] as i64 + sign).rem_euclid(n)) as usize;
        self.rank_of(c)
    }
}

/// Per-rank geometry: local extents, tiling, and placement in the global
/// lattice. Single-rank geometry has a trivial 1x1x1x1 grid.
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    /// local (per-process) lattice extents
    pub local: LatticeDims,
    /// global lattice extents
    pub global: LatticeDims,
    pub tiling: Tiling,
    pub grid: ProcGrid,
    pub rank: usize,
}

impl Geometry {
    pub fn single_rank(local: LatticeDims, tiling: Tiling) -> Result<Self, GeometryError> {
        Self::for_rank(local, ProcGrid([1, 1, 1, 1]), 0, tiling)
    }

    /// Geometry of `rank` in a decomposition of `global` over `grid`.
    pub fn for_rank(
        global: LatticeDims,
        grid: ProcGrid,
        rank: usize,
        tiling: Tiling,
    ) -> Result<Self, GeometryError> {
        if rank >= grid.size() {
            return Err(GeometryError(format!(
                "rank {rank} out of range for grid of {}",
                grid.size()
            )));
        }
        let g = grid.0;
        for (d, name) in [(0, "NX"), (1, "NY"), (2, "NZ"), (3, "NT")] {
            let ext = global.extent(Dir::from_index(d));
            if ext % g[d] != 0 {
                return Err(GeometryError(format!(
                    "{name} = {ext} not divisible by grid[{d}] = {}",
                    g[d]
                )));
            }
        }
        let local = LatticeDims::new(
            global.x / g[0],
            global.y / g[1],
            global.z / g[2],
            global.t / g[3],
        )?;
        if local.xh() % tiling.vx() != 0 {
            return Err(GeometryError(format!(
                "XH = {} not divisible by VLENX = {} (tiling {tiling} unavailable)",
                local.xh(),
                tiling.vx()
            )));
        }
        if local.y % tiling.vy() != 0 {
            return Err(GeometryError(format!(
                "NY = {} not divisible by VLENY = {} (tiling {tiling} unavailable)",
                local.y,
                tiling.vy()
            )));
        }
        Ok(Geometry {
            local,
            global,
            tiling,
            grid,
            rank,
        })
    }

    /// Grid coordinates of this rank.
    pub fn coords(&self) -> [usize; 4] {
        self.grid.coords_of(self.rank)
    }

    /// Global coordinate of the local origin. All local extents are even,
    /// so the origin offset is even in every direction and local parity
    /// equals global parity.
    pub fn origin(&self) -> [usize; 4] {
        let c = self.coords();
        [
            c[0] * self.local.x,
            c[1] * self.local.y,
            c[2] * self.local.z,
            c[3] * self.local.t,
        ]
    }

    /// Is this rank alone in direction `d` (wrap stays on-rank)?
    pub fn self_neighbor(&self, d: Dir) -> bool {
        self.grid.0[d.index()] == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_validation() {
        assert!(LatticeDims::new(4, 4, 4, 4).is_ok());
        assert!(LatticeDims::new(3, 4, 4, 4).is_err());
        assert!(LatticeDims::new(4, 4, 4, 0).is_err());
        assert_eq!(LatticeDims::parse("16x16x8x8").unwrap().volume(), 16 * 16 * 8 * 8);
        assert!(LatticeDims::parse("16x16x8").is_err());
    }

    #[test]
    fn grid_parse() {
        assert_eq!(ProcGrid::parse("1x1x2x2").unwrap(), ProcGrid([1, 1, 2, 2]));
        assert_eq!(ProcGrid::parse("1x1x2x2").unwrap().size(), 4);
        assert!(ProcGrid::parse("1x1x2").is_err());
        assert!(ProcGrid::parse("0x1x1x1").is_err());
        assert!(ProcGrid::parse("axbxcxd").is_err());
    }

    #[test]
    fn grid_rank_roundtrip() {
        let g = ProcGrid([1, 1, 2, 2]);
        for r in 0..g.size() {
            assert_eq!(g.rank_of(g.coords_of(r)), r);
        }
    }

    #[test]
    fn grid_neighbors_periodic() {
        let g = ProcGrid([2, 1, 2, 1]);
        // rank 0 at [0,0,0,0]; +x neighbor is rank 1, -x wraps to rank 1 too
        assert_eq!(g.neighbor(0, Dir::X, 1), 1);
        assert_eq!(g.neighbor(0, Dir::X, -1), 1);
        assert_eq!(g.neighbor(0, Dir::Z, 1), 2);
        assert_eq!(g.neighbor(2, Dir::Z, 1), 0);
    }

    #[test]
    fn paper_decomposition() {
        // 16^4 over [1,1,2,2] -> local 16x16x8x8 (paper section 4.1)
        let global = LatticeDims::new(16, 16, 16, 16).unwrap();
        let grid = ProcGrid([1, 1, 2, 2]);
        let t = Tiling::new(4, 4).unwrap();
        let geo = Geometry::for_rank(global, grid, 3, t).unwrap();
        assert_eq!(geo.local, LatticeDims::new(16, 16, 8, 8).unwrap());
        assert_eq!(geo.coords(), [0, 0, 1, 1]);
        assert_eq!(geo.origin(), [0, 0, 8, 8]);
        assert!(geo.self_neighbor(Dir::X));
        assert!(!geo.self_neighbor(Dir::Z));
    }

    #[test]
    fn tiling_divisibility_enforced() {
        let local = LatticeDims::new(16, 16, 8, 8).unwrap();
        // XH = 8 < VLENX = 16: unavailable, like the Table 1 dash
        assert!(Geometry::single_rank(local, Tiling::new(16, 1).unwrap()).is_err());
        assert!(Geometry::single_rank(local, Tiling::new(4, 4).unwrap()).is_ok());
    }
}
