//! Lattice geometry: extents, even-odd site indexing (Fig. 4), the 2D x-y
//! SIMD tiling (Fig. 3), and the AoSoA memory layout shared by all native
//! kernels.

mod evenodd;
mod geometry;
mod layout;
mod tiling;

pub use evenodd::{EvenOdd, Parity};
pub use geometry::{Geometry, GeometryError, LatticeDims};
pub use geometry::ProcGrid;
pub use layout::{EoLayout, LaneCoord, SiteCoord, CC2, IM, NCOL, NREIM, NSPIN, RE, SC2};
pub use tiling::Tiling;

/// Direction labels, paper order: x, y, z, t.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    X = 0,
    Y = 1,
    Z = 2,
    T = 3,
}

impl Dir {
    pub const ALL: [Dir; 4] = [Dir::X, Dir::Y, Dir::Z, Dir::T];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Dir {
        Dir::ALL[i]
    }
}
