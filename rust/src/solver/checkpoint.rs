//! Deterministic checkpoint/restart for long Krylov solves.
//!
//! A checkpoint captures *complete* solver state at an iteration
//! boundary — the iterate, every cross-iteration Krylov vector, the
//! iteration scalars, per-RHS convergence masks and statistics, the
//! health-guard restart counter, and the fault-plan sequence cursors —
//! plus a content hash of the gauge configuration the solve runs
//! against. Because the repo's reductions use canonical grouping
//! (bitwise identical across thread counts and rank layouts), restoring
//! that state reproduces the uninterrupted run's residual history
//! *bitwise* from the checkpoint iteration onward; the corruption tests
//! in `rust/tests/checkpoint.rs` pin that contract per solver family.
//!
//! ## On-disk format (all integers little-endian)
//!
//! ```text
//! +--------+---------+------------+------------+-------------+---------+-------+
//! | magic  | version | gauge_hash | generation | payload_len | payload | crc32 |
//! | 8 B    | u32     | u64        | u64        | u64         | ...     | u32   |
//! +--------+---------+------------+------------+-------------+---------+-------+
//! magic = "LQCKPT01"; crc32 = IEEE CRC-32 of the payload bytes.
//! ```
//!
//! Files are written atomically (temp file + fsync + rename) as
//! `ckpt-r<rank>-g<gen>.lqckpt`; a generation *counts* only once its
//! commit marker `ckpt-r<rank>-g<gen>.ok` exists. On the distributed
//! path the marker is written only after an all-ranks collective agrees
//! every rank durably wrote the generation (two-phase commit), so the
//! highest generation committed by *all* ranks is always a globally
//! consistent resume point. Older generations are kept (`keep`-deep
//! rotation) so a corrupted newest checkpoint falls back instead of
//! failing; each rank can additionally hold an in-memory buddy copy of
//! its ring-neighbor's latest checkpoint, exchanged over the existing
//! `Comm` transport, to re-materialize a lost file after a rank death.

use std::fmt;
use std::fs;
use std::io::Write as _;
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::algebra::Real;
use crate::coordinator::operator::{LinearOperator, MultiOperator};
use crate::field::snapshot::FieldSnap;

const MAGIC: &[u8; 8] = b"LQCKPT01";
/// Bump on any payload layout change; older files become `StaleVersion`.
pub const FORMAT_VERSION: u32 = 1;

/// Solver family tags stored in the payload (resume refuses a family
/// mismatch rather than misinterpreting vectors).
pub const FAMILY_CG: u8 = 0;
pub const FAMILY_BICGSTAB: u8 = 1;
pub const FAMILY_MIXED: u8 = 2;
pub const FAMILY_FUSED_CG: u8 = 3;
pub const FAMILY_FUSED_BICGSTAB: u8 = 4;
pub const FAMILY_BLOCK_CG: u8 = 5;
pub const FAMILY_BLOCK_BICGSTAB: u8 = 6;

/// IEEE CRC-32 (reflected, poly 0xEDB88320), bitwise — fast enough for
/// checkpoint cadences and keeps the crate dependency-free.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Structured checkpoint failures; every variant that concerns a file
/// names the generation so operators know which one was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointError {
    Io { gen: Option<u64>, msg: String },
    Truncated { gen: u64, len: usize },
    BadMagic { gen: u64 },
    StaleVersion { gen: u64, found: u32 },
    BadCrc { gen: u64, want: u32, found: u32 },
    GaugeMismatch { gen: u64, want: u64, found: u64 },
    Malformed { gen: u64, what: &'static str },
    NoCheckpoint,
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io { gen: Some(g), msg } => {
                write!(f, "checkpoint generation {g}: io error: {msg}")
            }
            CheckpointError::Io { gen: None, msg } => write!(f, "checkpoint io error: {msg}"),
            CheckpointError::Truncated { gen, len } => {
                write!(f, "checkpoint generation {gen}: truncated ({len} bytes)")
            }
            CheckpointError::BadMagic { gen } => {
                write!(f, "checkpoint generation {gen}: bad magic (not a checkpoint file)")
            }
            CheckpointError::StaleVersion { gen, found } => write!(
                f,
                "checkpoint generation {gen}: format version {found}, this build reads {FORMAT_VERSION}"
            ),
            CheckpointError::BadCrc { gen, want, found } => write!(
                f,
                "checkpoint generation {gen}: payload crc mismatch (stored {want:#010x}, computed {found:#010x})"
            ),
            CheckpointError::GaugeMismatch { gen, want, found } => write!(
                f,
                "checkpoint generation {gen}: gauge hash {found:#018x} does not match this configuration ({want:#018x})"
            ),
            CheckpointError::Malformed { gen, what } => {
                write!(f, "checkpoint generation {gen}: malformed payload ({what})")
            }
            CheckpointError::NoCheckpoint => write!(f, "no committed checkpoint generation found"),
        }
    }
}

impl std::error::Error for CheckpointError {}

/// Per-RHS statistics captured so a resumed block solve reports the
/// same per-RHS histories as the uninterrupted run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RhsRecord {
    pub iterations: u64,
    pub converged: bool,
    pub rel_residual: f64,
    pub history: Vec<f64>,
}

/// Complete solver state at one iteration boundary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SolverState {
    pub family: u8,
    pub iteration: u64,
    pub restarts: u64,
    pub flops: u64,
    /// family-specific iteration scalars (rr, rho, alpha, omega, ...)
    pub scalars: Vec<f64>,
    pub history: Vec<f64>,
    /// per-RHS active mask (empty for single-RHS families)
    pub masks: Vec<bool>,
    pub per_rhs: Vec<RhsRecord>,
    /// fault-plan sequence cursors (see `Comm::fault_cursors`)
    pub fault_cursors: Vec<u64>,
    pub fields: Vec<FieldSnap>,
}

impl SolverState {
    pub fn new(family: u8, iteration: u64) -> SolverState {
        SolverState {
            family,
            iteration,
            ..SolverState::default()
        }
    }

    pub fn field(&self, name: &str) -> Option<&FieldSnap> {
        self.fields.iter().find(|f| f.name == name)
    }

    /// Restore one named snapshot into a value slice; missing names and
    /// shape mismatches are plain-text errors the caller wraps.
    pub fn restore_into<R: Real>(&self, name: &str, out: &mut [R]) -> Result<(), String> {
        match self.field(name) {
            Some(snap) => snap.restore_slice(out),
            None => Err(format!("checkpoint holds no field {name:?}")),
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut e = Enc::default();
        e.u8(self.family);
        e.u64(self.iteration);
        e.u64(self.restarts);
        e.u64(self.flops);
        e.f64s(&self.scalars);
        e.f64s(&self.history);
        e.u64(self.masks.len() as u64);
        for &m in &self.masks {
            e.u8(u8::from(m));
        }
        e.u64(self.per_rhs.len() as u64);
        for r in &self.per_rhs {
            e.u64(r.iterations);
            e.u8(u8::from(r.converged));
            e.f64(r.rel_residual);
            e.f64s(&r.history);
        }
        e.u64(self.fault_cursors.len() as u64);
        for &c in &self.fault_cursors {
            e.u64(c);
        }
        e.u64(self.fields.len() as u64);
        for f in &self.fields {
            e.str(&f.name);
            e.u32(f.dtype);
            e.f64s(&f.data);
        }
        e.b
    }

    fn decode(bytes: &[u8]) -> Result<SolverState, &'static str> {
        let mut d = Dec { b: bytes, pos: 0 };
        let mut st = SolverState::new(d.u8()?, 0);
        st.iteration = d.u64()?;
        st.restarts = d.u64()?;
        st.flops = d.u64()?;
        st.scalars = d.f64s()?;
        st.history = d.f64s()?;
        let nmask = d.len()?;
        st.masks = (0..nmask)
            .map(|_| d.u8().map(|v| v != 0))
            .collect::<Result<_, _>>()?;
        let nrhs = d.len()?;
        for _ in 0..nrhs {
            st.per_rhs.push(RhsRecord {
                iterations: d.u64()?,
                converged: d.u8()? != 0,
                rel_residual: d.f64()?,
                history: d.f64s()?,
            });
        }
        let ncur = d.len()?;
        st.fault_cursors = (0..ncur).map(|_| d.u64()).collect::<Result<_, _>>()?;
        let nfields = d.len()?;
        for _ in 0..nfields {
            st.fields.push(FieldSnap {
                name: d.str()?,
                dtype: d.u32()?,
                data: d.f64s()?,
            });
        }
        if d.pos != bytes.len() {
            return Err("trailing bytes");
        }
        Ok(st)
    }
}

#[derive(Default)]
struct Enc {
    b: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.b.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn f64s(&mut self, vs: &[f64]) {
        self.u64(vs.len() as u64);
        for &v in vs {
            self.f64(v);
        }
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.b.extend_from_slice(s.as_bytes());
    }
}

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], &'static str> {
        if self.pos + n > self.b.len() {
            return Err("payload ran out of bytes");
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, &'static str> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, &'static str> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, &'static str> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, &'static str> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// A length prefix, sanity-capped so a corrupt length cannot ask for
    /// an absurd allocation.
    fn len(&mut self) -> Result<usize, &'static str> {
        let n = self.u64()?;
        if n > (1 << 40) {
            return Err("implausible length prefix");
        }
        Ok(n as usize)
    }
    fn f64s(&mut self) -> Result<Vec<f64>, &'static str> {
        let n = self.len()?;
        if self.pos + 8 * n > self.b.len() {
            return Err("vector ran past payload end");
        }
        (0..n).map(|_| self.f64()).collect()
    }
    fn str(&mut self) -> Result<String, &'static str> {
        let n = self.len()?;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| "non-utf8 field name")
    }
}

/// Assemble the full file image (header + payload + trailing CRC).
pub fn encode_file(state: &SolverState, gauge_hash: u64, gen: u64) -> Vec<u8> {
    let payload = state.encode();
    let mut b = Vec::with_capacity(payload.len() + 40);
    b.extend_from_slice(MAGIC);
    b.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    b.extend_from_slice(&gauge_hash.to_le_bytes());
    b.extend_from_slice(&gen.to_le_bytes());
    b.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    let crc = crc32(&payload);
    b.extend_from_slice(&payload);
    b.extend_from_slice(&crc.to_le_bytes());
    b
}

/// Validate and decode a file image. `gen` labels errors and is
/// cross-checked against the header; `expect_gauge` guards against
/// resuming on the wrong configuration.
pub fn decode_file(bytes: &[u8], gen: u64, expect_gauge: u64) -> Result<SolverState, CheckpointError> {
    const HEADER: usize = 8 + 4 + 8 + 8 + 8;
    if bytes.len() < HEADER + 4 {
        return Err(CheckpointError::Truncated { gen, len: bytes.len() });
    }
    if &bytes[..8] != MAGIC {
        return Err(CheckpointError::BadMagic { gen });
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != FORMAT_VERSION {
        return Err(CheckpointError::StaleVersion { gen, found: version });
    }
    let found_gauge = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    if found_gauge != expect_gauge {
        return Err(CheckpointError::GaugeMismatch {
            gen,
            want: expect_gauge,
            found: found_gauge,
        });
    }
    let stored_gen = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
    if stored_gen != gen {
        return Err(CheckpointError::Malformed {
            gen,
            what: "header generation does not match file name",
        });
    }
    let plen = u64::from_le_bytes(bytes[28..36].try_into().unwrap()) as usize;
    if bytes.len() != HEADER + plen + 4 {
        return Err(CheckpointError::Truncated { gen, len: bytes.len() });
    }
    let payload = &bytes[HEADER..HEADER + plen];
    let want = u32::from_le_bytes(bytes[HEADER + plen..].try_into().unwrap());
    let found = crc32(payload);
    if want != found {
        return Err(CheckpointError::BadCrc { gen, want, found });
    }
    SolverState::decode(payload).map_err(|what| CheckpointError::Malformed { gen, what })
}

pub fn ckpt_path(dir: &Path, rank: usize, gen: u64) -> PathBuf {
    dir.join(format!("ckpt-r{rank}-g{gen:08}.lqckpt"))
}

pub fn commit_path(dir: &Path, rank: usize, gen: u64) -> PathBuf {
    dir.join(format!("ckpt-r{rank}-g{gen:08}.ok"))
}

/// Read + validate one on-disk generation for one rank.
pub fn read_state_file(
    dir: &Path,
    rank: usize,
    gen: u64,
    expect_gauge: u64,
) -> Result<SolverState, CheckpointError> {
    let path = ckpt_path(dir, rank, gen);
    let bytes = fs::read(&path).map_err(|e| CheckpointError::Io {
        gen: Some(gen),
        msg: format!("{}: {e}", path.display()),
    })?;
    decode_file(&bytes, gen, expect_gauge)
}

/// Generations whose commit marker exists for `rank`, ascending.
pub fn committed_generations(dir: &Path, rank: usize) -> Vec<u64> {
    let prefix = format!("ckpt-r{rank}-g");
    let mut gens = Vec::new();
    if let Ok(entries) = fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name.strip_prefix(&prefix) {
                if let Some(digits) = rest.strip_suffix(".ok") {
                    if let Ok(g) = digits.parse::<u64>() {
                        gens.push(g);
                    }
                }
            }
        }
    }
    gens.sort_unstable();
    gens
}

/// Load the newest generation for `rank` that every rank of an
/// `nranks`-wide world committed, falling back to older common
/// generations when a file fails validation. Returns the state and the
/// generation it came from; the first validation failure (if any) is
/// what you get when nothing loads.
pub fn load_latest(
    dir: &Path,
    rank: usize,
    nranks: usize,
    expect_gauge: u64,
) -> Result<(SolverState, u64), CheckpointError> {
    let mut common = committed_generations(dir, rank);
    for r in (0..nranks).filter(|&r| r != rank) {
        let theirs = committed_generations(dir, r);
        common.retain(|g| theirs.contains(g));
    }
    let mut first_err = None;
    for &gen in common.iter().rev() {
        match read_state_file(dir, rank, gen, expect_gauge) {
            Ok(st) => return Ok((st, gen)),
            Err(e) => {
                eprintln!("checkpoint: {e}; trying previous generation");
                first_err.get_or_insert(e);
            }
        }
    }
    Err(first_err.unwrap_or(CheckpointError::NoCheckpoint))
}

/// Pack raw bytes into f64 bit patterns for transport over `Comm`
/// (length first, then 8 bytes per lane; no FP arithmetic ever touches
/// the lanes, so the bits survive).
pub fn bytes_to_f64s(bytes: &[u8]) -> Vec<f64> {
    let mut v = Vec::with_capacity(1 + bytes.len().div_ceil(8));
    v.push(f64::from_bits(bytes.len() as u64));
    for chunk in bytes.chunks(8) {
        let mut b = [0u8; 8];
        b[..chunk.len()].copy_from_slice(chunk);
        v.push(f64::from_bits(u64::from_le_bytes(b)));
    }
    v
}

pub fn f64s_to_bytes(v: &[f64]) -> Option<Vec<u8>> {
    let n = v.first()?.to_bits() as usize;
    if n > (v.len() - 1) * 8 {
        return None;
    }
    let mut out = Vec::with_capacity(n);
    for lane in &v[1..] {
        out.extend_from_slice(&lane.to_bits().to_le_bytes());
    }
    out.truncate(n);
    Some(out)
}

/// In-memory copy of a neighbor's checkpoint file, good for
/// re-materializing a dead rank's state on the surviving side.
#[derive(Clone, Debug)]
pub struct BuddyCopy {
    pub owner: usize,
    pub gen: u64,
    pub bytes: Vec<u8>,
}

/// Write a buddy copy back to disk as the owner's committed generation
/// (file first, then marker — same commit order as a live rank).
pub fn restore_from_buddy(dir: &Path, copy: &BuddyCopy) -> Result<(), CheckpointError> {
    let io = |e: std::io::Error| CheckpointError::Io {
        gen: Some(copy.gen),
        msg: e.to_string(),
    };
    fs::create_dir_all(dir).map_err(io)?;
    fs::write(ckpt_path(dir, copy.owner, copy.gen), &copy.bytes).map_err(io)?;
    fs::write(commit_path(dir, copy.owner, copy.gen), format!("{}\n", copy.gen)).map_err(io)?;
    Ok(())
}

/// Cadence / placement knobs for one solve attempt.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CkptOpts {
    pub dir: PathBuf,
    /// checkpoint every N iterations (0 disables the iteration cadence)
    pub every_iters: u64,
    /// wall-clock cadence in ms (0 disables; ignored when nranks > 1
    /// because clocks may disagree across ranks and the commit protocol
    /// is collective)
    pub every_ms: u64,
    /// how many committed generations to keep on disk
    pub keep: usize,
    /// exchange in-memory buddy copies with the ring neighbor
    pub buddy: bool,
}

impl CkptOpts {
    pub fn new(dir: impl Into<PathBuf>) -> CkptOpts {
        CkptOpts {
            dir: dir.into(),
            every_iters: 25,
            every_ms: 0,
            keep: 2,
            buddy: true,
        }
    }
}

/// Internal adapter so one `&mut op` serves both collective hooks
/// during a save.
trait CommitHooks {
    fn all_committed(&mut self, ok: bool) -> bool;
    fn buddy_exchange(&mut self, payload: &[f64], gen: u64) -> Option<Vec<f64>>;
}

struct LinHooks<'a, R: Real, A: LinearOperator<R> + ?Sized>(&'a mut A, PhantomData<R>);

impl<'a, R: Real, A: LinearOperator<R> + ?Sized> CommitHooks for LinHooks<'a, R, A> {
    fn all_committed(&mut self, ok: bool) -> bool {
        self.0.ckpt_all_committed(ok)
    }
    fn buddy_exchange(&mut self, payload: &[f64], gen: u64) -> Option<Vec<f64>> {
        self.0.ckpt_buddy_exchange(payload, gen)
    }
}

struct MultiHooks<'a, R: Real, O: MultiOperator<R> + ?Sized>(&'a mut O, PhantomData<R>);

impl<'a, R: Real, O: MultiOperator<R> + ?Sized> CommitHooks for MultiHooks<'a, R, O> {
    fn all_committed(&mut self, ok: bool) -> bool {
        self.0.ckpt_all_committed(ok)
    }
    fn buddy_exchange(&mut self, payload: &[f64], gen: u64) -> Option<Vec<f64>> {
        self.0.ckpt_buddy_exchange(payload, gen)
    }
}

/// The sink the solvers drive: owns cadence, atomic writes, generation
/// rotation, the two-phase commit, and the buddy copy. Checkpoint
/// failures never fail the solve — a save that cannot commit logs to
/// stderr and disables further attempts for this solve.
pub struct Checkpointer {
    opts: CkptOpts,
    rank: usize,
    nranks: usize,
    gauge_hash: u64,
    next_gen: u64,
    committed: u64,
    last_save: Instant,
    degraded: bool,
    buddy_payload: Option<BuddyCopy>,
}

impl Checkpointer {
    pub fn new(
        opts: CkptOpts,
        rank: usize,
        nranks: usize,
        gauge_hash: u64,
    ) -> Result<Checkpointer, CheckpointError> {
        fs::create_dir_all(&opts.dir).map_err(|e| CheckpointError::Io {
            gen: None,
            msg: format!("{}: {e}", opts.dir.display()),
        })?;
        let next_gen = committed_generations(&opts.dir, rank)
            .last()
            .map(|g| g + 1)
            .unwrap_or(0);
        Ok(Checkpointer {
            opts,
            rank,
            nranks,
            gauge_hash,
            next_gen,
            committed: 0,
            last_save: Instant::now(),
            degraded: false,
            buddy_payload: None,
        })
    }

    /// Should this iteration boundary checkpoint? Deterministic across
    /// ranks for the iteration cadence; the wall-clock cadence only
    /// applies single-rank (see `CkptOpts::every_ms`).
    pub fn due(&self, iteration: u64) -> bool {
        if self.degraded || iteration == 0 {
            return false;
        }
        if self.opts.every_iters > 0 && iteration % self.opts.every_iters == 0 {
            return true;
        }
        self.nranks == 1
            && self.opts.every_ms > 0
            && self.last_save.elapsed().as_millis() as u64 >= self.opts.every_ms
    }

    /// Generations committed by this sink during this solve.
    pub fn committed(&self) -> u64 {
        self.committed
    }

    pub fn take_buddy(&mut self) -> Option<BuddyCopy> {
        self.buddy_payload.take()
    }

    /// Save from a single-RHS solver; fills the fault cursors from the
    /// operator before encoding.
    pub fn save_lin<R: Real, A: LinearOperator<R> + ?Sized>(
        &mut self,
        mut state: SolverState,
        op: &mut A,
    ) -> bool {
        state.fault_cursors = op.fault_cursors();
        self.save_inner(state, &mut LinHooks(op, PhantomData))
    }

    /// Save from a block solver.
    pub fn save_multi<R: Real, O: MultiOperator<R> + ?Sized>(
        &mut self,
        mut state: SolverState,
        op: &mut O,
    ) -> bool {
        state.fault_cursors = op.fault_cursors();
        self.save_inner(state, &mut MultiHooks(op, PhantomData))
    }

    fn save_inner(&mut self, state: SolverState, hooks: &mut dyn CommitHooks) -> bool {
        if self.degraded {
            return false;
        }
        let gen = self.next_gen;
        self.next_gen = gen + 1;
        self.last_save = Instant::now();
        let bytes = encode_file(&state, self.gauge_hash, gen);
        let wrote = match self.write_atomic(gen, &bytes) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("checkpoint: generation {gen} write failed: {e}");
                false
            }
        };
        // Phase 2: the generation counts only if every rank wrote it.
        let all = hooks.all_committed(wrote);
        if !all {
            if wrote {
                let _ = fs::remove_file(ckpt_path(&self.opts.dir, self.rank, gen));
            }
            self.degraded = true;
            eprintln!(
                "checkpoint: generation {gen} not durable on all ranks; checkpointing disabled for this attempt"
            );
            return false;
        }
        if let Err(e) = fs::write(
            commit_path(&self.opts.dir, self.rank, gen),
            format!("{gen}\n"),
        ) {
            eprintln!("checkpoint: generation {gen} commit marker failed: {e}");
            self.degraded = true;
            return false;
        }
        self.committed += 1;
        if self.opts.buddy && self.nranks > 1 {
            if let Some(reply) = hooks.buddy_exchange(&bytes_to_f64s(&bytes), gen) {
                if let Some(raw) = f64s_to_bytes(&reply) {
                    self.buddy_payload = Some(BuddyCopy {
                        owner: (self.rank + self.nranks - 1) % self.nranks,
                        gen,
                        bytes: raw,
                    });
                }
            }
        }
        self.rotate();
        true
    }

    fn write_atomic(&self, gen: u64, bytes: &[u8]) -> std::io::Result<()> {
        let tmp = self.opts.dir.join(format!(".tmp-r{}-g{gen:08}", self.rank));
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, ckpt_path(&self.opts.dir, self.rank, gen))?;
        // Best-effort directory fsync so the rename itself is durable.
        if let Ok(d) = fs::File::open(&self.opts.dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    fn rotate(&self) {
        let gens = committed_generations(&self.opts.dir, self.rank);
        if gens.len() <= self.opts.keep {
            return;
        }
        for &g in &gens[..gens.len() - self.opts.keep] {
            let _ = fs::remove_file(ckpt_path(&self.opts.dir, self.rank, g));
            let _ = fs::remove_file(commit_path(&self.opts.dir, self.rank, g));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vector() {
        // The classic IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn sample_state() -> SolverState {
        let mut st = SolverState::new(FAMILY_BICGSTAB, 17);
        st.restarts = 2;
        st.flops = 123_456;
        st.scalars = vec![1.5, -2.25, 1e-300];
        st.history = vec![1.0, 0.5, 0.25];
        st.masks = vec![true, false, true];
        st.per_rhs = vec![RhsRecord {
            iterations: 9,
            converged: true,
            rel_residual: 1e-7,
            history: vec![1.0, 1e-7],
        }];
        st.fault_cursors = vec![3, 0, 8];
        st.fields = vec![FieldSnap {
            name: "r".into(),
            dtype: 1,
            data: vec![0.125, -3.5],
        }];
        st
    }

    #[test]
    fn payload_roundtrip_is_exact() {
        let st = sample_state();
        let back = SolverState::decode(&st.encode()).unwrap();
        assert_eq!(st, back);
    }

    #[test]
    fn file_roundtrip_and_header_checks() {
        let st = sample_state();
        let img = encode_file(&st, 0xDEAD_BEEF, 4);
        assert_eq!(decode_file(&img, 4, 0xDEAD_BEEF).unwrap(), st);
        assert!(matches!(
            decode_file(&img, 4, 0xBAD),
            Err(CheckpointError::GaugeMismatch { gen: 4, .. })
        ));
        assert!(matches!(
            decode_file(&img[..10], 4, 0xDEAD_BEEF),
            Err(CheckpointError::Truncated { gen: 4, .. })
        ));
        let mut flipped = img.clone();
        let mid = 40 + flipped.len().saturating_sub(44) / 2;
        flipped[mid] ^= 0x10;
        assert!(matches!(
            decode_file(&flipped, 4, 0xDEAD_BEEF),
            Err(CheckpointError::BadCrc { gen: 4, .. })
        ));
    }

    #[test]
    fn byte_packing_roundtrip() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65] {
            let bytes: Vec<u8> = (0..n).map(|i| (i * 37 + 5) as u8).collect();
            let lanes = bytes_to_f64s(&bytes);
            assert_eq!(f64s_to_bytes(&lanes).unwrap(), bytes);
        }
        assert!(f64s_to_bytes(&[]).is_none());
        // A length lane that promises more than the payload carries.
        assert!(f64s_to_bytes(&[f64::from_bits(64)]).is_none());
    }
}
