//! Mixed-precision iterative refinement (defect correction).
//!
//! The production pattern for lattice QCD solvers (Kanamori & Matsufuru,
//! arXiv:1811.00893; Dürr, arXiv:2112.14640): run the expensive Krylov
//! iteration in fast low precision and wrap it in a cheap high-precision
//! outer loop that repairs the rounding error.
//!
//! One outer step of `A x = b` at f64:
//!
//! 1. true residual  `r = b - A_64 x`           (f64 operator apply)
//! 2. scale           `r' = r / |r|`             (keeps f32 in range)
//! 3. demote          `r32 = f32(r')`
//! 4. inner solve     `A_32 d ~= r32`            (CG or BiCGStab, f32)
//! 5. promote+correct `x += |r| * f64(d)`
//!
//! The recursion floor of a pure f32 solve is `~eps_f32 * cond(A)`
//! relative residual — typically 1e-6..1e-7. The outer loop recomputes
//! the *true* residual in f64 each cycle, so the combined iteration
//! converges to f64 accuracy (1e-10 and below) while every inner matrix
//! application runs at f32 speed. Each inner solve only needs to shave a
//! couple of orders of magnitude (`inner_tol` ~ 1e-4), far above the f32
//! floor, so the inner solver never stalls.
//!
//! The refinement loop runs under the solver health guard: a correction
//! that drives the true residual non-finite is *rolled back* (the
//! pre-correction iterate is restored) and retried, bounded by
//! `solver.max_restarts`; transport faults surface as typed
//! [`SolveError`]s through the guarded entry points.

use crate::algebra::Real;
use crate::coordinator::operator::{FusedSolvable, LinearOperator};
use crate::coordinator::profiler::Profiler;
use crate::coordinator::Team;
use crate::dslash::flops as fl;
use crate::field::snapshot::FieldSnap;
use crate::field::FermionField;

use super::checkpoint::{Checkpointer, RhsRecord, SolverState, FAMILY_MIXED};
use super::health::{HealthConfig, HealthGuard, Interrupt, SolveError};
use super::{bicgstab, cg, fused};

/// Inner Krylov algorithm of the refinement loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InnerAlgorithm {
    /// CG — the inner operator must be hermitian positive definite
    /// (use the normal operator M-hat^dag M-hat).
    Cg,
    /// BiCGStab — works directly on the non-hermitian M-hat.
    BiCgStab,
}

/// Convergence record of a mixed-precision solve.
#[derive(Clone, Debug)]
pub struct MixedStats {
    /// outer (f64 defect-correction) steps taken
    pub outer_iterations: usize,
    /// total inner (f32 Krylov) iterations across all outer steps
    pub inner_iterations: usize,
    pub converged: bool,
    /// |r| / |b| of the *true* f64 residual at exit
    pub rel_residual: f64,
    /// true |r|/|b| after each outer step (index 0 = initial residual)
    pub history: Vec<f64>,
    /// per-outer-step inner relative-residual histories (inner solver's
    /// recursion, relative to its own defect rhs)
    pub inner_histories: Vec<Vec<f64>>,
    /// total flops across outer applies and inner solves
    pub flops: u64,
    /// health-guard restarts: rolled-back outer corrections plus inner
    /// Krylov restarts
    pub restarts: usize,
    /// health-guard events across the outer loop and all inner solves
    pub health_events: usize,
    /// transport retransmits across the outer and inner operators
    pub retransmits: u64,
    /// transport timeouts across the outer and inner operators
    pub timeouts: u64,
    /// halo buffers zero-filled after failed recvs across both operators
    /// — nonzero means some sweeps ran on fabricated data
    pub zero_fills: u64,
}

/// Solve `A x = b` at f64 accuracy with f32 inner iterations.
///
/// `outer` and `inner` must represent the *same* operator at the two
/// precisions (e.g. `NativeMeo<f64>` / `NativeMeo<f32>` built from the
/// same gauge configuration via [`crate::field::GaugeField::to_precision`]).
/// For `InnerAlgorithm::Cg` both must be the normal operator.
///
/// Works with *any* inner [`LinearOperator`] (native, distributed,
/// PJRT-backed) and runs the inner solves serially; use
/// [`mixed_refinement_team`] to run them on the worker team through the
/// fused pipeline. The inner residual recursion is bitwise identical
/// either way.
///
/// `x` holds the initial guess on entry and the solution on exit. Runs
/// under a default health guard; failures fold into non-converged
/// stats. Use [`mixed_refinement_guarded`] for the typed error.
#[allow(clippy::too_many_arguments)]
pub fn mixed_refinement<Hi, Lo>(
    outer: &mut Hi,
    inner: &mut Lo,
    x: &mut FermionField<f64>,
    b: &FermionField<f64>,
    tol: f64,
    max_outer: usize,
    inner_tol: f64,
    inner_maxiter: usize,
    alg: InnerAlgorithm,
) -> MixedStats
where
    Hi: LinearOperator<f64>,
    Lo: LinearOperator<f32>,
{
    mixed_refinement_guarded(
        outer,
        inner,
        x,
        b,
        tol,
        max_outer,
        inner_tol,
        inner_maxiter,
        alg,
        &HealthConfig::default(),
    )
    .unwrap_or_else(err_to_mixed)
}

/// [`mixed_refinement`] under an explicit health guard, with the typed
/// failure surfaced.
#[allow(clippy::too_many_arguments)]
pub fn mixed_refinement_guarded<Hi, Lo>(
    outer: &mut Hi,
    inner: &mut Lo,
    x: &mut FermionField<f64>,
    b: &FermionField<f64>,
    tol: f64,
    max_outer: usize,
    inner_tol: f64,
    inner_maxiter: usize,
    alg: InnerAlgorithm,
    health: &HealthConfig,
) -> Result<MixedStats, SolveError>
where
    Hi: LinearOperator<f64>,
    Lo: LinearOperator<f32>,
{
    refine(
        outer,
        inner,
        x,
        b,
        tol,
        max_outer,
        health,
        None,
        None,
        move |op, x32, b32| match alg {
            InnerAlgorithm::Cg => cg(op, x32, b32, inner_tol, inner_maxiter),
            InnerAlgorithm::BiCgStab => bicgstab(op, x32, b32, inner_tol, inner_maxiter),
        },
    )
}

/// [`mixed_refinement`] with every inner f32 solve — where essentially
/// all the work happens — running on the worker team through the fused
/// pipeline ([`fused`]). Requires a native ([`FusedSolvable`]) inner
/// operator; results are bitwise identical to the serial entry point.
#[allow(clippy::too_many_arguments)]
pub fn mixed_refinement_team<Hi, Lo>(
    outer: &mut Hi,
    inner: &mut Lo,
    x: &mut FermionField<f64>,
    b: &FermionField<f64>,
    tol: f64,
    max_outer: usize,
    inner_tol: f64,
    inner_maxiter: usize,
    alg: InnerAlgorithm,
    team: &mut Team,
) -> MixedStats
where
    Hi: LinearOperator<f64>,
    Lo: LinearOperator<f32> + FusedSolvable<f32>,
{
    mixed_refinement_team_profiled(
        outer,
        inner,
        x,
        b,
        tol,
        max_outer,
        inner_tol,
        inner_maxiter,
        alg,
        team,
        None,
    )
}

/// [`mixed_refinement_team`] with optional per-phase profiling and span
/// tracing of the inner fused solves (where essentially all the work
/// happens). The instrumentation never feeds back into the arithmetic:
/// histories are bitwise identical with `prof` `Some` or `None`.
#[allow(clippy::too_many_arguments)]
pub fn mixed_refinement_team_profiled<Hi, Lo>(
    outer: &mut Hi,
    inner: &mut Lo,
    x: &mut FermionField<f64>,
    b: &FermionField<f64>,
    tol: f64,
    max_outer: usize,
    inner_tol: f64,
    inner_maxiter: usize,
    alg: InnerAlgorithm,
    team: &mut Team,
    prof: Option<&Profiler>,
) -> MixedStats
where
    Hi: LinearOperator<f64>,
    Lo: LinearOperator<f32> + FusedSolvable<f32>,
{
    mixed_refinement_team_profiled_ckpt(
        outer,
        inner,
        x,
        b,
        tol,
        max_outer,
        inner_tol,
        inner_maxiter,
        alg,
        team,
        prof,
        None,
        None,
    )
}

/// [`mixed_refinement_team_profiled`] with a checkpoint sink and/or a
/// resume state. Checkpoints land at outer-iteration boundaries: the
/// f64 iterate, outer residual history, the per-outer-step inner
/// histories, and accumulated counters. Resume recomputes the f64
/// defect `r = b - A x` from the restored iterate — bit-for-bit the
/// same value the interrupted run held — so the continued outer and
/// inner histories are bitwise identical to the uninterrupted run.
#[allow(clippy::too_many_arguments)]
pub fn mixed_refinement_team_profiled_ckpt<Hi, Lo>(
    outer: &mut Hi,
    inner: &mut Lo,
    x: &mut FermionField<f64>,
    b: &FermionField<f64>,
    tol: f64,
    max_outer: usize,
    inner_tol: f64,
    inner_maxiter: usize,
    alg: InnerAlgorithm,
    team: &mut Team,
    prof: Option<&Profiler>,
    ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
) -> MixedStats
where
    Hi: LinearOperator<f64>,
    Lo: LinearOperator<f32> + FusedSolvable<f32>,
{
    let health = HealthConfig::default();
    refine(
        outer,
        inner,
        x,
        b,
        tol,
        max_outer,
        &health,
        ckpt,
        resume,
        move |op, x32, b32| match alg {
            InnerAlgorithm::Cg => fused::cg_profiled(
                op,
                &mut *team,
                x32,
                b32,
                inner_tol,
                inner_maxiter,
                prof,
            ),
            InnerAlgorithm::BiCgStab => fused::bicgstab_profiled(
                op,
                &mut *team,
                x32,
                b32,
                inner_tol,
                inner_maxiter,
                prof,
            ),
        },
    )
    .unwrap_or_else(err_to_mixed)
}

/// Fold a guarded failure into non-converged [`MixedStats`] for the
/// legacy entry points.
fn err_to_mixed(e: SolveError) -> MixedStats {
    MixedStats {
        outer_iterations: e.history.len().saturating_sub(1),
        inner_iterations: 0,
        converged: false,
        rel_residual: e.last_residual,
        history: e.history.clone(),
        inner_histories: vec![],
        flops: 0,
        restarts: e
            .events
            .iter()
            .filter(|ev| ev.kind != super::HealthEventKind::CommFault)
            .count(),
        health_events: e.events.len(),
        retransmits: e.retransmits,
        timeouts: e.timeouts,
        zero_fills: e.zero_fills,
    }
}

/// The shared defect-correction loop; `solve` runs one inner f32 solve
/// of `A d ~= r/|r|` and returns its stats.
#[allow(clippy::too_many_arguments)]
fn refine<Hi, Lo, S>(
    outer: &mut Hi,
    inner: &mut Lo,
    x: &mut FermionField<f64>,
    b: &FermionField<f64>,
    tol: f64,
    max_outer: usize,
    health: &HealthConfig,
    mut ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
    mut solve: S,
) -> Result<MixedStats, SolveError>
where
    Hi: LinearOperator<f64>,
    Lo: LinearOperator<f32>,
    S: FnMut(&mut Lo, &mut FermionField<f32>, &FermionField<f32>) -> super::SolveStats,
{
    let mut guard = HealthGuard::new(health);
    let co0 = outer.comm_counters();
    let ci0 = inner.comm_counters();
    let zo0 = outer.comm_zero_fills();
    let zi0 = inner.comm_zero_fills();
    let counters = |outer: &Hi, inner: &Lo| {
        let co1 = outer.comm_counters();
        let ci1 = inner.comm_counters();
        (
            co1.0 - co0.0 + ci1.0 - ci0.0,
            co1.1 - co0.1 + ci1.1 - ci0.1,
            outer.comm_zero_fills() - zo0 + inner.comm_zero_fills() - zi0,
        )
    };

    let bnorm2 = outer.reduce_sum(b.norm2());
    if bnorm2 == 0.0 {
        x.fill(0.0);
        return Ok(MixedStats {
            outer_iterations: 0,
            inner_iterations: 0,
            converged: true,
            rel_residual: 0.0,
            history: vec![],
            inner_histories: vec![],
            flops: 0,
            restarts: 0,
            health_events: 0,
            retransmits: 0,
            timeouts: 0,
            zero_fills: 0,
        });
    }
    let bnorm = bnorm2.sqrt();

    let nreal = b.data.len() as u64;

    let mut history = Vec::new();
    let mut inner_histories = Vec::new();
    let mut inner_iterations = 0usize;
    let mut inner_restarts = 0usize;
    let mut inner_events = 0usize;
    let mut outer_iterations = 0usize;
    let mut flops;

    let mut r = b.clone();
    let mut ax = b.zeros_like();
    let mut rnorm;

    if let Some(st) = resume {
        if st.family != FAMILY_MIXED {
            return Err(SolveError::checkpoint(format!(
                "checkpoint holds family tag {}, not mixed",
                st.family
            )));
        }
        st.restore_into("x", &mut x.data).map_err(SolveError::checkpoint)?;
        guard.restarts = st.restarts as usize;
        history = st.history.clone();
        inner_histories = st.per_rhs.iter().map(|rec| rec.history.clone()).collect();
        if st.scalars.len() < 3 {
            return Err(SolveError::checkpoint("missing mixed counters"));
        }
        inner_iterations = st.scalars[0] as usize;
        inner_restarts = st.scalars[1] as usize;
        inner_events = st.scalars[2] as usize;
        outer_iterations = st.iteration as usize;
        flops = st.flops;
        outer.restore_fault_cursors(&st.fault_cursors);
        // Recompute the f64 defect from the restored iterate. The
        // computation is the same one the interrupted run performed at
        // the end of its last outer step, on bitwise-identical inputs,
        // so r and rnorm come back bit-for-bit (history stays pinned).
        outer.apply(&mut ax, x);
        r.axpy(-1.0, &ax);
        rnorm = outer.reduce_sum(r.norm2()).sqrt();
        if !rnorm.is_finite() {
            return Err(SolveError::checkpoint("restored iterate has non-finite residual"));
        }
    } else {
        // r = b - A x (f64); a zero initial guess skips the operator
        // apply. Agreed globally (reduce_sum is collective) so
        // distributed outer operators never mismatch the collectives.
        let x_zero = outer.reduce_sum(if x.is_zero() { 0.0 } else { 1.0 }) == 0.0;
        flops = fl::norm2_flops(nreal);
        if x_zero {
            rnorm = bnorm;
        } else {
            outer.apply(&mut ax, x);
            r.axpy(-1.0, &ax);
            rnorm = outer.reduce_sum(r.norm2()).sqrt();
            flops +=
                outer.flops_per_apply() + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
        }
        history.push(rnorm / bnorm);
    }

    while outer_iterations < max_outer && rnorm > tol * bnorm {
        if let Err(err) = outer.fault_hook(outer_iterations) {
            let int = Interrupt::Comm { err, iteration: outer_iterations };
            guard.absorb(int, &history, counters(outer, inner))?;
            unreachable!("comm interrupts are fatal");
        }
        if let Some(ck) = ckpt.as_deref_mut() {
            if ck.due(outer_iterations as u64) {
                let mut st = SolverState::new(FAMILY_MIXED, outer_iterations as u64);
                st.restarts = guard.restarts as u64;
                st.flops = flops;
                st.scalars = vec![
                    inner_iterations as f64,
                    inner_restarts as f64,
                    inner_events as f64,
                ];
                st.history = history.clone();
                st.per_rhs = inner_histories
                    .iter()
                    .map(|h: &Vec<f64>| RhsRecord {
                        iterations: h.len() as u64,
                        converged: true,
                        rel_residual: h.last().copied().unwrap_or(f64::NAN),
                        history: h.clone(),
                    })
                    .collect();
                st.fields = vec![FieldSnap::of_fermion("x", x)];
                ck.save_lin(st, outer);
            }
        }
        // unit-norm defect, demoted to the inner precision
        let mut defect = r.clone();
        defect.scale(1.0 / rnorm);
        let d32: FermionField<f32> = defect.to_precision();

        // inner solve A d ~= r/|r| at f32
        let mut corr32: FermionField<f32> = d32.zeros_like();
        let stats = solve(inner, &mut corr32, &d32);
        inner_iterations += stats.iterations;
        inner_restarts += stats.restarts;
        inner_events += stats.health_events;
        inner_histories.push(stats.history);
        flops += stats.flops;
        if let Some(err) = inner.comm_fault() {
            let int = Interrupt::Comm { err, iteration: outer_iterations };
            guard.absorb(int, &history, counters(outer, inner))?;
            unreachable!("comm interrupts are fatal");
        }

        // x += |r| * promote(d); recompute the true residual at f64.
        // Keep the pre-correction iterate so a correction that drives
        // the residual non-finite can be rolled back and retried.
        let x_prev = x.clone();
        let corr: FermionField<f64> = corr32.to_precision();
        x.axpy(rnorm, &corr);
        outer.apply(&mut ax, x);
        flops += outer.flops_per_apply()
            + 2 * fl::axpy_flops(nreal)
            + fl::norm2_flops(nreal);
        r = b.clone();
        r.axpy(-1.0, &ax);
        let rnorm_new = outer.reduce_sum(r.norm2()).sqrt();
        if !rnorm_new.is_finite() {
            *x = x_prev;
            guard.absorb(
                Interrupt::NonFinite { what: "outer |r|", iteration: outer_iterations },
                &history,
                counters(outer, inner),
            )?;
            // restore the residual of the rolled-back iterate
            outer.apply(&mut ax, x);
            r = b.clone();
            r.axpy(-1.0, &ax);
            rnorm = outer.reduce_sum(r.norm2()).sqrt();
            flops += outer.flops_per_apply()
                + fl::axpy_flops(nreal)
                + fl::norm2_flops(nreal);
            if !rnorm.is_finite() {
                // the rolled-back iterate is itself poisoned: go cold
                x.fill(0.0);
                r = b.clone();
                rnorm = bnorm;
            }
            continue;
        }
        rnorm = rnorm_new;
        outer_iterations += 1;
        history.push(rnorm / bnorm);

        // an inner breakdown that produced no progress cannot be repaired
        // by more outer steps with the same settings
        if stats.iterations == 0 && !stats.converged {
            break;
        }
    }

    if let Some(err) = outer.comm_fault() {
        let int = Interrupt::Comm { err, iteration: outer_iterations };
        guard.absorb(int, &history, counters(outer, inner))?;
        unreachable!("comm interrupts are fatal");
    }

    let (retransmits, timeouts, zero_fills) = counters(outer, inner);
    Ok(MixedStats {
        outer_iterations,
        inner_iterations,
        converged: rnorm <= tol * bnorm,
        rel_residual: rnorm / bnorm,
        history,
        inner_histories,
        flops,
        restarts: guard.restarts + inner_restarts,
        health_events: guard.events.len() + inner_events,
        retransmits,
        timeouts,
        zero_fills,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::operator::NativeMeo;
    use crate::field::GaugeField;
    use crate::lattice::{Geometry, LatticeDims, Tiling};
    use crate::solver::residual::operator_residual;
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn refinement_reaches_f64_accuracy_with_f32_inner() {
        let g = geom();
        let mut rng = Rng::seeded(401);
        let u = GaugeField::<f64>::random(&g, &mut rng);
        let b = FermionField::<f64>::gaussian(&g, &mut rng);
        let kappa = 0.12f64;

        let mut outer = NativeMeo::new(&g, u.clone(), kappa);
        let mut inner = NativeMeo::new(&g, u.to_precision::<f32>(), kappa as f32);
        let mut x = FermionField::<f64>::zeros(&g);
        let stats = mixed_refinement(
            &mut outer,
            &mut inner,
            &mut x,
            &b,
            1e-12,
            60,
            1e-4,
            200,
            InnerAlgorithm::BiCgStab,
        );
        assert!(stats.converged, "{stats:?}");
        assert!(stats.rel_residual <= 1e-12);
        assert!(stats.outer_iterations >= 2, "must actually refine");
        assert!(stats.inner_iterations > 0);
        // clean path: no guard activity
        assert_eq!(stats.restarts, 0);
        assert_eq!(stats.health_events, 0);
        // true residual agrees with the reported one
        let true_rel = operator_residual(&mut outer, &x, &b);
        assert!(true_rel < 1e-11, "true residual {true_rel}");
        // one history entry per outer step plus the initial residual, and
        // the loop made real progress overall (strict per-step monotonicity
        // is NOT guaranteed near the f64 floor, so don't assert it)
        assert_eq!(stats.history.len(), stats.outer_iterations + 1);
        let first = stats.history[0];
        let last = *stats.history.last().unwrap();
        assert!(last < first / 1e6, "insufficient progress: {first} -> {last}");
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let g = geom();
        let mut rng = Rng::seeded(402);
        let u = GaugeField::<f64>::random(&g, &mut rng);
        let mut outer = NativeMeo::new(&g, u.clone(), 0.1f64);
        let mut inner = NativeMeo::new(&g, u.to_precision::<f32>(), 0.1f32);
        let b = FermionField::<f64>::zeros(&g);
        let mut x = FermionField::<f64>::gaussian(&g, &mut rng);
        let stats = mixed_refinement(
            &mut outer,
            &mut inner,
            &mut x,
            &b,
            1e-12,
            10,
            1e-4,
            100,
            InnerAlgorithm::BiCgStab,
        );
        assert!(stats.converged);
        assert_eq!(stats.outer_iterations, 0);
        assert_eq!(x.norm2(), 0.0);
    }
}
