//! True-residual verification helpers (the solvers report the recursive
//! residual; examples and tests verify against the real operator).

use crate::algebra::Real;
use crate::dslash::{full, HoppingEo};
use crate::field::{FermionField, GaugeField};

/// |D x - b| / |b| on the full even/odd system.
pub fn full_system_residual<R: Real>(
    hop: &HoppingEo,
    u: &GaugeField<R>,
    x_e: &FermionField<R>,
    x_o: &FermionField<R>,
    b_e: &FermionField<R>,
    b_o: &FermionField<R>,
    kappa: R,
) -> f64 {
    let mut out_e = x_e.zeros_like();
    let mut out_o = x_e.zeros_like();
    full::dslash_full(hop, &mut out_e, &mut out_o, u, x_e, x_o, kappa);
    out_e.axpy(-R::ONE, b_e);
    out_o.axpy(-R::ONE, b_o);
    let num = out_e.norm2() + out_o.norm2();
    let den = b_e.norm2() + b_o.norm2();
    (num / den).sqrt()
}

/// |A x - b| / |b| for any operator.
pub fn operator_residual<R: Real, A: crate::coordinator::operator::LinearOperator<R>>(
    op: &mut A,
    x: &FermionField<R>,
    b: &FermionField<R>,
) -> f64 {
    let mut ax = x.zeros_like();
    op.apply(&mut ax, x);
    ax.axpy(-R::ONE, b);
    (op.reduce_sum(ax.norm2()) / op.reduce_sum(b.norm2())).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::operator::NativeMeo;
    use crate::lattice::{Geometry, LatticeDims, Parity, Tiling};
    use crate::solver::bicgstab;
    use crate::util::rng::Rng;

    /// End-to-end Schur solve: Eq. 4 for x_e, Eq. 5 for x_o, then verify
    /// the *full* system D psi = eta — the same check as the Python test.
    #[test]
    fn schur_solve_solves_full_system() {
        let g = Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap();
        let mut rng = Rng::seeded(301);
        let u = GaugeField::random(&g, &mut rng);
        let b_e = FermionField::gaussian(&g, &mut rng);
        let b_o = FermionField::gaussian(&g, &mut rng);
        let kappa = 0.12f32;
        let hop = HoppingEo::new(&g);

        // rhs of Eq. 4
        let mut rhs = FermionField::zeros(&g);
        full::schur_rhs(&hop, &mut rhs, &u, &b_e, &b_o, kappa);

        let mut op = NativeMeo::new(&g, u.clone(), kappa);
        let mut x_e = FermionField::zeros(&g);
        let stats = bicgstab(&mut op, &mut x_e, &rhs, 1e-9, 500);
        assert!(stats.converged);

        // Eq. 5
        let mut x_o = FermionField::zeros(&g);
        full::reconstruct_odd(&hop, &mut x_o, &u, &b_o, &x_e, kappa);

        let rel = full_system_residual(&hop, &u, &x_e, &x_o, &b_e, &b_o, kappa);
        assert!(rel < 1e-5, "full-system residual {rel}");
        let _ = Parity::Even;
    }
}
