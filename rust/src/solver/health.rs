//! Solver health guard: per-iteration scalar checks, bounded Krylov
//! restarts, and typed solve errors.
//!
//! Krylov recurrences are fragile: one non-finite reduction (silent data
//! corruption in a halo payload, overflow in a breakdown-adjacent step)
//! poisons every later iterate, and in release builds the unguarded
//! solvers would happily iterate on NaN until `maxiter`. The guard
//! classifies per-iteration events into
//!
//! * **recoverable** — non-finite iteration scalars, stagnation of the
//!   recursive residual, drift between the recursive and true residual.
//!   The solver recomputes the true residual `r = b - A x` from the
//!   current (warm) iterate and restarts the Krylov process, bounded by
//!   [`HealthConfig::max_restarts`].
//! * **fatal** — transport faults ([`CommError`]: timeouts, unhealed
//!   corruption, a killed rank) and an exhausted restart budget. These
//!   surface as a typed [`SolveError`] carrying the full diagnostic
//!   context (iteration, rank, residual history, event log).
//!
//! Restart decisions are made from globally reduced scalars
//! (`reduce_sum`/`reduce_caps` are bitwise identical across ranks by the
//! canonical-reduction contract), so every rank of a distributed solve
//! takes the same branch and the collectives stay matched.

use std::fmt;

use crate::algebra::Real;
use crate::comm::CommError;
use crate::coordinator::operator::LinearOperator;
use crate::dslash::flops as fl;
use crate::field::FermionField;

use super::SolveStats;

/// Health-guard policy knobs (config `[solver]` section).
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// Krylov restarts allowed before a recoverable event becomes
    /// fatal ([`SolveErrorKind::RestartsExhausted`]).
    pub max_restarts: usize,
    /// Iterations without a new best relative residual before the guard
    /// declares stagnation and restarts. `0` disables the check.
    pub stagnation_window: usize,
    /// Allowed ratio `true residual / recursive residual` at (apparent)
    /// convergence before the guard declares drift and restarts.
    /// `0.0` disables the check.
    pub drift_tol: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            max_restarts: 3,
            stagnation_window: 0,
            drift_tol: 0.0,
        }
    }
}

/// What a guard observed (recoverable events and the fatal ones).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthEventKind {
    /// A per-iteration scalar (alpha/beta/rho/omega/pAp/|r|²) went
    /// non-finite.
    NonFiniteScalar,
    /// No new best relative residual within the stagnation window.
    Stagnation,
    /// True residual disagreed with the recursive one beyond tolerance.
    ResidualDrift,
    /// The transport surfaced a structured [`CommError`].
    CommFault,
}

impl HealthEventKind {
    pub fn name(&self) -> &'static str {
        match self {
            HealthEventKind::NonFiniteScalar => "non-finite-scalar",
            HealthEventKind::Stagnation => "stagnation",
            HealthEventKind::ResidualDrift => "residual-drift",
            HealthEventKind::CommFault => "comm-fault",
        }
    }
}

/// One observed event, with where and what.
#[derive(Clone, Debug)]
pub struct HealthEvent {
    pub kind: HealthEventKind,
    /// Global iteration (across restarts) at which it fired.
    pub iteration: usize,
    pub detail: String,
}

impl fmt::Display for HealthEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[iter {}] {}: {}", self.iteration, self.kind.name(), self.detail)
    }
}

/// Why an attempt stopped early. Produced by the solver iteration
/// bodies, classified by [`HealthGuard::absorb`].
#[derive(Clone, Debug)]
pub enum Interrupt {
    /// A named iteration scalar went non-finite (recoverable).
    NonFinite { what: &'static str, iteration: usize },
    /// The recursive residual stagnated (recoverable).
    Stagnation { iteration: usize },
    /// Recursive and true residual drifted apart (recoverable).
    Drift { iteration: usize, ratio: f64 },
    /// The transport failed (fatal at solver level).
    Comm { err: CommError, iteration: usize },
}

/// Fatal failure class of a guarded solve.
#[derive(Clone, Debug)]
pub enum SolveErrorKind {
    /// A structured transport fault (timeout, unhealed corruption, a
    /// killed rank, precision confusion).
    Comm(CommError),
    /// Recoverable events exhausted `solver.max_restarts`.
    RestartsExhausted,
    /// A checkpoint resume could not restore solver state (missing or
    /// mismatched field snapshot, wrong solver family).
    Checkpoint(String),
}

/// Typed failure of a guarded solve, with full diagnostics.
#[derive(Clone, Debug)]
pub struct SolveError {
    pub kind: SolveErrorKind,
    /// Global iteration (across restarts) at which the solve died.
    pub iteration: usize,
    /// Rank that observed the failure (0 for single-rank solves; for
    /// comm faults, the rank recorded in the [`CommError`]).
    pub rank: usize,
    /// Last known |r|/|b| (NaN if none was ever computed).
    pub last_residual: f64,
    /// |r|/|b| after each completed iteration, across restarts.
    pub history: Vec<f64>,
    /// Per-RHS converged mask at failure (block solvers only).
    pub converged_mask: Option<Vec<bool>>,
    /// Everything the guard observed up to the failure.
    pub events: Vec<HealthEvent>,
    /// Transport recovery counters at failure (retransmits, timeouts,
    /// zero-filled halos).
    pub retransmits: u64,
    pub timeouts: u64,
    pub zero_fills: u64,
}

impl fmt::Display for SolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.kind {
            SolveErrorKind::Comm(e) => write!(
                f,
                "solve failed at iteration {} (rank {}): {}",
                self.iteration, self.rank, e
            )?,
            SolveErrorKind::RestartsExhausted => write!(
                f,
                "solve failed at iteration {}: restart budget exhausted \
                 after {} health events",
                self.iteration,
                self.events.len()
            )?,
            SolveErrorKind::Checkpoint(msg) => {
                write!(f, "checkpoint resume failed: {msg}")?
            }
        }
        if let Some(mask) = &self.converged_mask {
            let done = mask.iter().filter(|c| **c).count();
            write!(f, "; {done}/{} RHS converged", mask.len())?;
        }
        write!(f, "; last |r|/|b| = {:.3e}", self.last_residual)?;
        for ev in &self.events {
            write!(f, "\n  {ev}")?;
        }
        Ok(())
    }
}

impl SolveError {
    /// A resume-time failure (before any iteration ran).
    pub fn checkpoint(msg: impl Into<String>) -> SolveError {
        SolveError {
            kind: SolveErrorKind::Checkpoint(msg.into()),
            iteration: 0,
            rank: 0,
            last_residual: f64::NAN,
            history: Vec::new(),
            converged_mask: None,
            events: Vec::new(),
            retransmits: 0,
            timeouts: 0,
            zero_fills: 0,
        }
    }

    /// Fold the failure into a (non-converged) [`SolveStats`] for
    /// callers that only consume stats.
    pub fn into_stats(self, sweeps_per_iter: f64, threads: usize) -> SolveStats {
        SolveStats {
            iterations: self.history.len(),
            converged: false,
            rel_residual: self.last_residual,
            history: self.history,
            flops: 0,
            sweeps_per_iter,
            threads,
            knob_sources: None,
            restarts: self
                .events
                .iter()
                .filter(|e| e.kind != HealthEventKind::CommFault)
                .count(),
            health_events: self.events.len(),
            retransmits: self.retransmits,
            timeouts: self.timeouts,
            zero_fills: self.zero_fills,
        }
    }
}

/// Restart bookkeeping shared by all guarded solvers.
#[derive(Clone, Debug)]
pub struct HealthGuard {
    pub cfg: HealthConfig,
    /// Recoverable events absorbed so far (= restarts performed).
    pub restarts: usize,
    pub events: Vec<HealthEvent>,
}

impl HealthGuard {
    pub fn new(cfg: &HealthConfig) -> Self {
        HealthGuard {
            cfg: cfg.clone(),
            restarts: 0,
            events: Vec::new(),
        }
    }

    /// Classify an interrupt. `Ok(())` means "restart the Krylov
    /// process from the warm iterate"; `Err` is the final, typed
    /// failure. `history` is the residual history so far and
    /// `(retransmits, timeouts, zero_fills)` the transport counters at
    /// this point — both are moved into the error on the fatal paths.
    pub fn absorb(
        &mut self,
        int: Interrupt,
        history: &[f64],
        counters: (u64, u64, u64),
    ) -> Result<(), SolveError> {
        let last_residual = history.last().copied().unwrap_or(f64::NAN);
        let fail = |kind, iteration, rank, events: Vec<HealthEvent>| SolveError {
            kind,
            iteration,
            rank,
            last_residual,
            history: history.to_vec(),
            converged_mask: None,
            events,
            retransmits: counters.0,
            timeouts: counters.1,
            zero_fills: counters.2,
        };
        match int {
            Interrupt::Comm { err, iteration } => {
                let rank = match &err {
                    CommError::Timeout { rank, .. }
                    | CommError::CollectiveTimeout { rank, .. }
                    | CommError::Corrupt { rank, .. }
                    | CommError::PrecisionMismatch { rank, .. }
                    | CommError::Killed { rank, .. } => *rank,
                    CommError::Protocol(_) => 0,
                };
                self.events.push(HealthEvent {
                    kind: HealthEventKind::CommFault,
                    iteration,
                    detail: err.to_string(),
                });
                Err(fail(SolveErrorKind::Comm(err), iteration, rank, self.events.clone()))
            }
            recoverable => {
                let (kind, iteration, detail) = match recoverable {
                    Interrupt::NonFinite { what, iteration } => (
                        HealthEventKind::NonFiniteScalar,
                        iteration,
                        format!("{what} went non-finite; restarting from warm iterate"),
                    ),
                    Interrupt::Stagnation { iteration } => (
                        HealthEventKind::Stagnation,
                        iteration,
                        format!(
                            "no residual improvement for {} iterations",
                            self.cfg.stagnation_window
                        ),
                    ),
                    Interrupt::Drift { iteration, ratio } => (
                        HealthEventKind::ResidualDrift,
                        iteration,
                        format!("true/recursive residual ratio {ratio:.3e}"),
                    ),
                    Interrupt::Comm { .. } => unreachable!("handled above"),
                };
                self.events.push(HealthEvent { kind, iteration, detail });
                if self.restarts >= self.cfg.max_restarts {
                    return Err(fail(
                        SolveErrorKind::RestartsExhausted,
                        iteration,
                        0,
                        self.events.clone(),
                    ));
                }
                self.restarts += 1;
                Ok(())
            }
        }
    }

    /// Copy the guard's tallies and the transport counters into a
    /// finished attempt's stats.
    pub fn finish(&self, stats: &mut SolveStats, counters: (u64, u64, u64)) {
        stats.restarts = self.restarts;
        stats.health_events = self.events.len();
        stats.retransmits = counters.0;
        stats.timeouts = counters.1;
        stats.zero_fills = counters.2;
    }
}

/// Ratio `true residual / recursive residual` at apparent convergence
/// (the drift check): recomputes `r = b - A x` with one extra operator
/// apply, accounted into `flops`. Returns `INFINITY` when the recursive
/// residual claims exact zero but the true one disagrees.
pub(crate) fn drift_ratio<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &FermionField<R>,
    b: &FermionField<R>,
    recursive_rel: f64,
    flops: &mut u64,
) -> f64 {
    let nreal = b.data.len() as u64;
    let mut ax = b.zeros_like();
    op.apply(&mut ax, x);
    ax.axpy(-R::ONE, b);
    let true2 = op.reduce_sum(ax.norm2());
    let bnorm2 = op.reduce_sum(b.norm2());
    *flops +=
        op.flops_per_apply() + fl::axpy_flops(nreal) + 2 * fl::norm2_flops(nreal);
    let true_rel = (true2 / bnorm2).sqrt();
    if recursive_rel > 0.0 {
        true_rel / recursive_rel
    } else if true_rel > 0.0 {
        f64::INFINITY
    } else {
        1.0
    }
}

/// Inline tracker for the stagnation check: counts iterations since the
/// last new best residual. Zero-cost when the window is 0 (disabled).
#[derive(Clone, Copy, Debug)]
pub struct StagnationTracker {
    window: usize,
    best: f64,
    since_best: usize,
}

impl StagnationTracker {
    pub fn new(window: usize) -> Self {
        StagnationTracker {
            window,
            best: f64::INFINITY,
            since_best: 0,
        }
    }

    /// Record one iteration's relative residual; `true` means the
    /// window elapsed without improvement (stagnation).
    pub fn stalled(&mut self, rel: f64) -> bool {
        if self.window == 0 {
            return false;
        }
        if rel < self.best {
            self.best = rel;
            self.since_best = 0;
            false
        } else {
            self.since_best += 1;
            self.since_best >= self.window
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_allows_max_restarts_then_fails() {
        let cfg = HealthConfig {
            max_restarts: 2,
            ..Default::default()
        };
        let mut g = HealthGuard::new(&cfg);
        let h = [0.5, 0.25];
        for i in 0..2 {
            g.absorb(
                Interrupt::NonFinite { what: "pAp", iteration: i },
                &h,
                (0, 0, 0),
            )
            .expect("within budget");
        }
        let err = g
            .absorb(
                Interrupt::NonFinite { what: "pAp", iteration: 2 },
                &h,
                (3, 1, 2),
            )
            .expect_err("budget exhausted");
        assert!(matches!(err.kind, SolveErrorKind::RestartsExhausted));
        assert_eq!(err.iteration, 2);
        assert_eq!(err.last_residual, 0.25);
        assert_eq!(err.events.len(), 3);
        assert_eq!((err.retransmits, err.timeouts, err.zero_fills), (3, 1, 2));
        let stats = err.into_stats(6.0, 1);
        assert!(!stats.converged);
        assert_eq!(stats.restarts, 3);
        assert_eq!(stats.health_events, 3);
    }

    #[test]
    fn comm_fault_is_always_fatal() {
        let mut g = HealthGuard::new(&HealthConfig::default());
        let err = g
            .absorb(
                Interrupt::Comm {
                    err: CommError::Killed { rank: 1, iteration: 4 },
                    iteration: 4,
                },
                &[],
                (0, 2, 0),
            )
            .expect_err("comm faults never restart");
        assert!(matches!(err.kind, SolveErrorKind::Comm(CommError::Killed { .. })));
        assert_eq!(err.rank, 1);
        assert!(err.last_residual.is_nan());
        let msg = err.to_string();
        assert!(msg.contains("killed by fault injection"), "{msg}");
    }

    #[test]
    fn stagnation_tracker_windows() {
        let mut t = StagnationTracker::new(3);
        assert!(!t.stalled(1.0));
        assert!(!t.stalled(0.5)); // new best
        assert!(!t.stalled(0.6));
        assert!(!t.stalled(0.6));
        assert!(t.stalled(0.55)); // 3rd iteration with no new best
        // disabled tracker never fires
        let mut off = StagnationTracker::new(0);
        for _ in 0..100 {
            assert!(!off.stalled(1.0));
        }
    }
}
