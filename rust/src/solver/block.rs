//! Block (multi-RHS) Krylov solvers with per-RHS convergence masking.
//!
//! [`block_cg`] runs N *independent* CG recurrences — one per right-hand
//! side of a [`MultiFermionField`] — through **shared** batched sweeps:
//! every iteration is the fused 3-sweep CG pipeline (operator apply with
//! fused tails + in-kernel `p·Ap` capture, combined x/r update with |r|²
//! capture, p xpay), but the operator sweep streams the gauge field
//! *once* for all N systems ([`crate::dslash::multi`]). Scalars
//! (alpha/beta) are per-RHS, so each system follows exactly the
//! trajectory the single-RHS fused solver would give it: per-RHS
//! residual histories are **bitwise identical** to N independent
//! [`super::fused::cg`] solves at any precision.
//!
//! Per-RHS stopping masks: when system r reaches `|r_r| <= tol |b_r|`
//! it is deactivated — the batched kernel skips its sub-tiles and every
//! BLAS sweep skips its data — so converged systems stop costing kernel
//! work while stragglers continue. Because the recurrences are
//! independent, deactivating one RHS does not perturb the others (that
//! is what makes the bitwise guarantee hold *through* mask activation,
//! unlike a genuinely coupled block-Krylov method).
//!
//! [`block_bicgstab`] is the same construction around the BiCGStab
//! recurrence (complex per-RHS scalars, per-RHS breakdown handling
//! mirroring [`super::fused::bicgstab`]'s early exits).
//!
//! Flop accounting scales with the number of *active* RHS at each
//! sweep; the bytes/site amortization of the shared gauge stream is
//! modeled and reported by the solver benchmark.

use crate::algebra::{Complex, Real};
use crate::coordinator::operator::MultiOperator;
use crate::coordinator::Team;
use crate::dslash::flops as fl;
use crate::field::block::{cg_update_masked, MultiFermionField};

use super::fused::{BICGSTAB_FUSED_SWEEPS, CG_FUSED_SWEEPS};

/// Convergence record of one right-hand side of a block solve.
#[derive(Clone, Debug)]
pub struct RhsStats {
    /// iterations this RHS ran before converging (or the block cap)
    pub iterations: usize,
    pub converged: bool,
    /// |r_r| / |b_r| at deactivation (recursive residual)
    pub rel_residual: f64,
    /// |r_r|/|b_r| after each iteration this RHS participated in
    pub history: Vec<f64>,
}

/// Convergence record of one block solve.
#[derive(Clone, Debug)]
pub struct BlockSolveStats {
    pub nrhs: usize,
    /// batched iterations executed (the max over per-RHS iterations)
    pub iterations: usize,
    /// all RHS converged
    pub converged: bool,
    pub per_rhs: Vec<RhsStats>,
    /// total flops, counting each sweep once per *active* RHS
    pub flops: u64,
    /// full-field sweeps per iteration per RHS (the gauge stream is
    /// shared: bytes do NOT scale like this with nrhs — see the bench's
    /// bytes/site model)
    pub sweeps_per_iter: f64,
    /// worker-team threads the batched sweeps ran on
    pub threads: usize,
}

impl BlockSolveStats {
    fn finish(nrhs: usize, iterations: usize, per_rhs: Vec<RhsStats>, flops: u64, sweeps: f64, threads: usize) -> BlockSolveStats {
        BlockSolveStats {
            nrhs,
            iterations,
            converged: per_rhs.iter().all(|s| s.converged),
            per_rhs,
            flops,
            sweeps_per_iter: sweeps,
            threads,
        }
    }
}

/// Batched CG on a hermitian positive-definite multi-RHS operator
/// (normal-operator CGNR): solve `A x_r = b_r` for every RHS, with
/// per-RHS convergence masks. `x` holds the initial guesses on entry.
pub fn block_cg<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
) -> BlockSolveStats {
    let nrhs = op.nrhs();
    assert_eq!(b.nrhs, nrhs, "rhs count mismatch");
    assert_eq!(x.nrhs, nrhs, "solution count mismatch");
    let ntiles = b.site_tiles();
    let nreal = b.rhs_len() as u64;

    let bnorm2 = b.norm2_per_rhs();
    let mut flops = nrhs as u64 * fl::norm2_flops(nreal);
    let mut active = vec![true; nrhs];
    let mut stats: Vec<RhsStats> = (0..nrhs)
        .map(|_| RhsStats { iterations: 0, converged: false, rel_residual: 0.0, history: vec![] })
        .collect();
    for r in 0..nrhs {
        if bnorm2[r] == 0.0 {
            // zero RHS: exact solution is zero, like the single solver
            x.fill_rhs(r, R::ZERO);
            active[r] = false;
            stats[r].converged = true;
        }
    }
    let limit: Vec<f64> = bnorm2.iter().map(|&bn| tol * tol * bn).collect();

    let mut r = b.clone();
    let mut ap = b.zeros_like();
    let mut rr = bnorm2.clone();
    if !x.is_zero() {
        // r = b - A x fused with per-RHS |r|² (zero guesses skip this)
        op.apply_multi(team, &mut ap, x, &active, None);
        let neg = vec![-R::ONE; nrhs];
        r.axpy_norm2_masked(&neg, &ap, &active, &mut rr);
        let nact = active.iter().filter(|&&a| a).count() as u64;
        flops += nact
            * (op.flops_per_apply_rhs() + fl::axpy_flops(nreal) + fl::norm2_flops(nreal));
    }
    // RHS already at tolerance (warm starts) never enter the loop, like
    // the single solver's `rr > limit` entry condition
    for i in 0..nrhs {
        if active[i] && rr[i] <= limit[i] {
            active[i] = false;
            stats[i].converged = true;
        }
    }
    let mut p = r.clone();

    let mut dot_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut alphas = vec![R::ZERO; nrhs];
    let mut betas = vec![R::ZERO; nrhs];
    let mut rr_new = vec![0.0f64; nrhs];
    let mut iterations = 0;

    while iterations < maxiter && active.iter().any(|&a| a) {
        let nact = active.iter().filter(|&&a| a).count() as u64;
        // sweep 1: ap = A p, gauge streamed once for all active RHS,
        // per-(tile, RHS) p·Ap capture fused into the kernel store
        op.apply_multi(team, &mut ap, &p, &active, Some((&p, &mut dot_partials)));
        for i in 0..nrhs {
            if !active[i] {
                continue;
            }
            // combine partials in site-tile order: the same grouping the
            // single-RHS fused solver uses, hence bit-identical alphas
            let pap: f64 = (0..ntiles).map(|t| dot_partials[t * nrhs + i][0]).sum();
            alphas[i] = R::from_f64(rr[i] / pap);
        }
        // sweep 2: x += alpha p ; r -= alpha ap ; per-RHS |r|²
        cg_update_masked(x, &mut r, &p, &ap, &alphas, &active, &mut rr_new);
        for i in 0..nrhs {
            if active[i] {
                betas[i] = R::from_f64(rr_new[i] / rr[i]);
            }
        }
        // sweep 3: p = beta p + r
        p.xpay_masked(&betas, &r, &active);
        flops += nact
            * (op.flops_per_apply_rhs()
                + fl::dot_re_flops(nreal)
                + 2 * fl::axpy_flops(nreal)
                + fl::norm2_flops(nreal)
                + fl::xpay_flops(nreal));
        iterations += 1;
        for i in 0..nrhs {
            if !active[i] {
                continue;
            }
            rr[i] = rr_new[i];
            stats[i].history.push((rr[i] / bnorm2[i]).sqrt());
            stats[i].iterations = iterations;
            if rr[i] <= limit[i] {
                // converged: mask this RHS out of every further sweep
                active[i] = false;
                stats[i].converged = true;
            }
        }
    }

    for i in 0..nrhs {
        if bnorm2[i] > 0.0 {
            stats[i].rel_residual = (rr[i] / bnorm2[i]).sqrt();
        }
    }
    BlockSolveStats::finish(nrhs, iterations, stats, flops, CG_FUSED_SWEEPS, team.nthreads())
}

/// Batched BiCGStab on a (non-hermitian) multi-RHS M-hat operator, with
/// per-RHS complex scalars, per-RHS convergence masks, and per-RHS
/// breakdown handling mirroring the single-RHS solver's early exits
/// (a broken-down RHS is deactivated unconverged; the others continue).
pub fn block_bicgstab<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
) -> BlockSolveStats {
    let nrhs = op.nrhs();
    assert_eq!(b.nrhs, nrhs, "rhs count mismatch");
    assert_eq!(x.nrhs, nrhs, "solution count mismatch");
    let ntiles = b.site_tiles();
    let nreal = b.rhs_len() as u64;
    let count = |m: &[bool]| m.iter().filter(|&&a| a).count() as u64;

    let bnorm2 = b.norm2_per_rhs();
    let mut flops = nrhs as u64 * fl::norm2_flops(nreal);
    let mut active = vec![true; nrhs];
    let mut stats: Vec<RhsStats> = (0..nrhs)
        .map(|_| RhsStats { iterations: 0, converged: false, rel_residual: 0.0, history: vec![] })
        .collect();
    for r in 0..nrhs {
        if bnorm2[r] == 0.0 {
            x.fill_rhs(r, R::ZERO);
            active[r] = false;
            stats[r].converged = true;
        }
    }
    let limit: Vec<f64> = bnorm2.iter().map(|&bn| tol * tol * bn).collect();

    let mut r = b.clone();
    let mut t = b.zeros_like();
    let mut rr = bnorm2.clone();
    if !x.is_zero() {
        op.apply_multi(team, &mut t, x, &active, None);
        let neg = vec![-R::ONE; nrhs];
        r.axpy_norm2_masked(&neg, &t, &active, &mut rr);
        flops += count(&active)
            * (op.flops_per_apply_rhs() + fl::axpy_flops(nreal) + fl::norm2_flops(nreal));
    }
    // RHS already at tolerance (warm starts) never enter the loop, like
    // the single solver's `rr > limit` entry condition
    for i in 0..nrhs {
        if active[i] && rr[i] <= limit[i] {
            active[i] = false;
            stats[i].converged = true;
        }
    }
    let rhat = r.clone();
    let mut p = r.clone();
    let mut v = b.zeros_like();
    let mut rho = rhat.dot_per_rhs(&r);
    flops += count(&active) * fl::cdot_flops(nreal);

    let mut v_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut t_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut s_caps = vec![[0.0f64; 3]; nrhs];
    let mut r_caps = vec![[0.0f64; 3]; nrhs];
    let mut alpha = vec![Complex::ZERO; nrhs];
    let mut omega = vec![Complex::ZERO; nrhs];
    let mut beta = vec![Complex::ZERO; nrhs];
    let mut neg = vec![Complex::ZERO; nrhs];
    let mut iterations = 0;

    while iterations < maxiter && active.iter().any(|&a| a) {
        // sweep 1: v = A p with fused per-RHS <rhat, v> capture
        op.apply_multi(team, &mut v, &p, &active, Some((&rhat, &mut v_partials)));
        flops += count(&active) * (op.flops_per_apply_rhs() + fl::cdot_flops(nreal));
        let mut mask_b = active.clone();
        for i in 0..nrhs {
            if !active[i] {
                continue;
            }
            let (re, im) = (0..ntiles).fold((0.0, 0.0), |(re, im), tl| {
                let p = v_partials[tl * nrhs + i];
                (re + p[0], im + p[1])
            });
            let rhat_v = Complex::new(re, im);
            if rhat_v.abs() < 1e-300 {
                // breakdown: deactivate unconverged (single solver: break)
                active[i] = false;
                mask_b[i] = false;
                continue;
            }
            alpha[i] = rho[i] * rhat_v.conj().scale(1.0 / rhat_v.norm2());
            neg[i] = -alpha[i];
        }
        // sweep 2: s = r - alpha v (in place in r) with |s|² capture
        r.caxpy_capture_masked(&neg, &v, None, &mask_b, &mut s_caps);
        flops += count(&mask_b) * (fl::caxpy_flops(nreal) + fl::norm2_flops(nreal));
        let mut mask_c = mask_b.clone();
        let mut mask_half = vec![false; nrhs];
        for i in 0..nrhs {
            if !mask_b[i] {
                continue;
            }
            if s_caps[i][2] <= limit[i] {
                // converged at the half step: x += alpha p, then stop
                mask_half[i] = true;
                mask_c[i] = false;
            }
        }
        if mask_half.iter().any(|&h| h) {
            x.caxpy_masked(&alpha, &p, &mask_half);
            flops += count(&mask_half) * fl::caxpy_flops(nreal);
            for i in 0..nrhs {
                if mask_half[i] {
                    rr[i] = s_caps[i][2];
                    stats[i].history.push((rr[i] / bnorm2[i]).sqrt());
                    stats[i].iterations = iterations + 1;
                    stats[i].converged = true;
                    active[i] = false;
                }
            }
        }
        // sweep 3: t = A s with fused per-RHS <s, t>, |t|² capture
        if mask_c.iter().any(|&a| a) {
            op.apply_multi(team, &mut t, &r, &mask_c, Some((&r, &mut t_partials)));
            flops += count(&mask_c)
                * (op.flops_per_apply_rhs() + fl::cdot_flops(nreal) + fl::norm2_flops(nreal));
        }
        let mut mask_d = mask_c.clone();
        for i in 0..nrhs {
            if !mask_c[i] {
                continue;
            }
            let (re, im, n2) = (0..ntiles).fold((0.0, 0.0, 0.0), |(re, im, n2), tl| {
                let p = t_partials[tl * nrhs + i];
                (re + p[0], im + p[1], n2 + p[2])
            });
            // the capture conjugates s; ts = <t, s> flips the imaginary part
            let ts = Complex::new(re, -im);
            if n2 == 0.0 {
                active[i] = false;
                mask_d[i] = false;
                continue; // breakdown
            }
            omega[i] = ts.scale(1.0 / n2);
            neg[i] = -omega[i];
        }
        if mask_d.iter().any(|&a| a) {
            // sweep 4: x += alpha p + omega s (s lives in r)
            x.caxpy2_masked(&alpha, &p, &omega, &r, &mask_d);
            // sweep 5: r = s - omega t with <rhat, r> and |r|² capture
            r.caxpy_capture_masked(&neg, &t, Some(&rhat), &mask_d, &mut r_caps);
            flops += count(&mask_d)
                * (3 * fl::caxpy_flops(nreal) + fl::cdot_flops(nreal) + fl::norm2_flops(nreal));
        }
        let mut mask_e = mask_d.clone();
        for i in 0..nrhs {
            if !mask_d[i] {
                continue;
            }
            let rr_new = r_caps[i][2];
            let rho_new = Complex::new(r_caps[i][0], r_caps[i][1]);
            rr[i] = rr_new;
            stats[i].history.push((rr[i] / bnorm2[i]).sqrt());
            stats[i].iterations = iterations + 1;
            if rho[i].abs() < 1e-300 || omega[i].abs() < 1e-300 {
                // post-update breakdown, like the single solver's exit
                stats[i].converged = rr[i] <= limit[i];
                active[i] = false;
                mask_e[i] = false;
                continue;
            }
            if rr[i] <= limit[i] {
                stats[i].converged = true;
                active[i] = false;
                mask_e[i] = false;
                continue;
            }
            beta[i] = (rho_new * alpha[i])
                * (rho[i] * omega[i]).conj().scale(1.0 / (rho[i] * omega[i]).norm2());
            rho[i] = rho_new;
            neg[i] = -omega[i];
        }
        if mask_e.iter().any(|&a| a) {
            // sweep 6: p = beta (p - omega v) + r
            p.p_update_masked(&neg, &v, &beta, &r, &mask_e);
            flops += count(&mask_e)
                * (fl::caxpy_flops(nreal) + fl::cscale_flops(nreal) + fl::axpy_flops(nreal));
        }
        iterations += 1;
    }

    for i in 0..nrhs {
        if bnorm2[i] > 0.0 {
            stats[i].rel_residual = (rr[i] / bnorm2[i]).sqrt();
        }
    }
    // a pass that ended entirely in breakdowns counted no per-RHS
    // iteration (mirroring the single solver's uncounted early exits),
    // so report the max over per-RHS counts, not the loop counter
    let done = stats.iter().map(|s| s.iterations).max().unwrap_or(0);
    BlockSolveStats::finish(nrhs, done, stats, flops, BICGSTAB_FUSED_SWEEPS, team.nthreads())
}
