//! Block (multi-RHS) Krylov solvers with per-RHS convergence masking.
//!
//! [`block_cg`] runs N *independent* CG recurrences — one per right-hand
//! side of a [`MultiFermionField`] — through **shared** batched sweeps:
//! every iteration is the fused 3-sweep CG pipeline (operator apply with
//! fused tails + in-kernel `p·Ap` capture, combined x/r update with |r|²
//! capture, p xpay), but the operator sweep streams the gauge field
//! *once* for all N systems ([`crate::dslash::multi`]). Scalars
//! (alpha/beta) are per-RHS, so each system follows exactly the
//! trajectory the single-RHS fused solver would give it: per-RHS
//! residual histories are **bitwise identical** to N independent
//! [`super::fused::cg`] solves at any precision.
//!
//! Each batched iteration runs as **one** [`crate::coordinator::Team`]
//! parallel region (the [`crate::coordinator::operator::MultiFusedView`]
//! pipeline): the operator's
//! multi-hopping phases *and* the masked BLAS-1 sweeps are tile-sharded
//! over the persistent workers, synchronized by the in-region
//! [`crate::coordinator::TeamBarrier`] — the same structure as
//! [`super::fused`], rather than one team region per phase. Every
//! reduction keeps the canonical per-(site tile, RHS) f64 grouping
//! (partials combined in site-tile order), so the one-region pipeline is
//! bitwise identical to the per-phase one at any thread count.
//!
//! Per-RHS stopping masks: when system r reaches `|r_r| <= tol |b_r|`
//! it is deactivated — the batched kernel skips its sub-tiles and every
//! BLAS sweep skips its data — so converged systems stop costing kernel
//! work while stragglers continue. Because the recurrences are
//! independent, deactivating one RHS does not perturb the others (that
//! is what makes the bitwise guarantee hold *through* mask activation,
//! unlike a genuinely coupled block-Krylov method).
//!
//! [`block_bicgstab`] is the same construction around the BiCGStab
//! recurrence (complex per-RHS scalars, per-RHS breakdown handling
//! mirroring [`super::fused::bicgstab`]'s early exits). Its per-RHS
//! stage scalars (alpha, omega, beta, masks) are pure functions of the
//! shared tile partials, computed redundantly — and identically — by
//! every thread inside the region and once more by the master for the
//! bookkeeping.
//!
//! Flop accounting scales with the number of *active* RHS at each
//! sweep; the bytes/site amortization of the shared gauge stream is
//! modeled and reported by the solver benchmark.
//!
//! [`block_cg_generic`]/[`block_bicgstab_generic`] drive the same
//! per-RHS recurrences over **any** [`MultiOperator`] — in particular
//! the distributed [`crate::coordinator::operator::DistMultiMeo`] /
//! [`crate::coordinator::operator::DistMultiMdagM`], whose batched halo
//! exchange cannot run inside one team region (FUNNELED comm). All
//! reductions go through the operator's `reduce_caps` hook, so the
//! distributed operators fold every rank's per-tile partials in global
//! site-tile order and the solver scalars are bitwise independent of
//! the rank decomposition.

use crate::algebra::{Complex, Real};
use crate::coordinator::operator::{
    reduce_caps_tile_order, MultiFusedSolvable, MultiOperator,
};
use crate::coordinator::profiler::{Phase, Profiler};
use crate::coordinator::team::{chunk_range, SendPtr};
use crate::coordinator::Team;
use crate::dslash::flops as fl;
use crate::field::blas;
use crate::field::block::MultiFermionField;
use crate::field::snapshot::FieldSnap;

use super::checkpoint::{
    Checkpointer, RhsRecord, SolverState, FAMILY_BLOCK_BICGSTAB, FAMILY_BLOCK_CG,
};
use super::fused::{
    charge_flops, ro, ro_at, scoped, BICGSTAB_FUSED_SWEEPS, CG_FUSED_SWEEPS,
};
use super::health::{
    HealthConfig, HealthEventKind, HealthGuard, Interrupt, SolveError,
    StagnationTracker,
};

/// Convergence record of one right-hand side of a block solve.
#[derive(Clone, Debug)]
pub struct RhsStats {
    /// iterations this RHS ran before converging (or the block cap)
    pub iterations: usize,
    pub converged: bool,
    /// |r_r| / |b_r| at deactivation (recursive residual)
    pub rel_residual: f64,
    /// |r_r|/|b_r| after each iteration this RHS participated in
    pub history: Vec<f64>,
}

/// Convergence record of one block solve.
#[derive(Clone, Debug)]
pub struct BlockSolveStats {
    pub nrhs: usize,
    /// batched iterations executed (the max over per-RHS iterations)
    pub iterations: usize,
    /// all RHS converged
    pub converged: bool,
    pub per_rhs: Vec<RhsStats>,
    /// total flops, counting each sweep once per *active* RHS
    pub flops: u64,
    /// full-field sweeps per iteration per RHS (the gauge stream is
    /// shared: bytes do NOT scale like this with nrhs — see the bench's
    /// bytes/site model)
    pub sweeps_per_iter: f64,
    /// worker-team threads the batched sweeps ran on
    pub threads: usize,
    /// Krylov restarts the health guard performed (guarded `_generic`
    /// solvers; always 0 on the native in-region paths)
    pub restarts: usize,
    /// health-guard events observed (restarts plus fatal diagnoses)
    pub health_events: usize,
    /// halo messages healed from the sender-side retransmit store
    pub retransmits: u64,
    /// recv/collective deadlines that expired (including recovered ones)
    pub timeouts: u64,
    /// halo buffers the transport zero-filled after failed recvs — any
    /// nonzero value means sweeps ran on fabricated data and the solve
    /// ended in (or recovered through) a transport fault
    pub zero_fills: u64,
}

impl BlockSolveStats {
    fn finish(nrhs: usize, iterations: usize, per_rhs: Vec<RhsStats>, flops: u64, sweeps: f64, threads: usize) -> BlockSolveStats {
        BlockSolveStats {
            nrhs,
            iterations,
            converged: per_rhs.iter().all(|s| s.converged),
            per_rhs,
            flops,
            sweeps_per_iter: sweeps,
            threads,
            restarts: 0,
            health_events: 0,
            retransmits: 0,
            timeouts: 0,
            zero_fills: 0,
        }
    }
}

/// Fold a guarded-solve failure into a (non-converged)
/// [`BlockSolveStats`] for callers that only consume stats: per-RHS
/// converged flags come from the error's mask, histories are dropped.
fn err_to_block(e: SolveError, nrhs: usize, sweeps: f64, threads: usize) -> BlockSolveStats {
    let mask = e.converged_mask.clone().unwrap_or_else(|| vec![false; nrhs]);
    BlockSolveStats {
        nrhs,
        iterations: e.iteration,
        converged: false,
        per_rhs: mask
            .iter()
            .map(|&c| RhsStats {
                iterations: e.iteration,
                converged: c,
                rel_residual: f64::NAN,
                history: vec![],
            })
            .collect(),
        flops: 0,
        sweeps_per_iter: sweeps,
        threads,
        restarts: e
            .events
            .iter()
            .filter(|ev| ev.kind != HealthEventKind::CommFault)
            .count(),
        health_events: e.events.len(),
        retransmits: e.retransmits,
        timeouts: e.timeouts,
        zero_fills: e.zero_fills,
    }
}

/// Sum one component of the per-(site tile, RHS) capture partials for
/// RHS `i`, in site-tile order — the canonical reduction grouping that
/// matches the single-RHS fused solver bitwise.
#[inline]
fn reduce_cap_col(partials: &[[f64; 3]], ntiles: usize, nrhs: usize, i: usize, c: usize) -> f64 {
    (0..ntiles).map(|t| partials[t * nrhs + i][c]).sum()
}

/// Batched CG on a hermitian positive-definite multi-RHS operator
/// (normal-operator CGNR): solve `A x_r = b_r` for every RHS, with
/// per-RHS convergence masks. `x` holds the initial guesses on entry.
pub fn block_cg<R: Real, A: MultiFusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
) -> BlockSolveStats {
    block_cg_profiled(op, team, x, b, tol, maxiter, None)
}

/// [`block_cg`] with optional per-phase profiling/tracing. The
/// instrumentation never touches the arithmetic: histories are bitwise
/// identical with `prof` `Some` or `None`.
pub fn block_cg_profiled<R: Real, A: MultiFusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
) -> BlockSolveStats {
    let nrhs = op.nrhs();
    assert_eq!(b.nrhs, nrhs, "rhs count mismatch");
    assert_eq!(x.nrhs, nrhs, "solution count mismatch");
    let ntiles = b.site_tiles();
    let nreal = b.rhs_len() as u64;
    let vpt = b.vals_per_tile();
    let vlen = b.layout.vlen();
    let n = team.nthreads();
    let flops_apply = op.flops_per_apply_rhs();
    let flops_shared = op.flops_per_apply_shared();

    let bnorm2 = b.norm2_per_rhs();
    let mut flops = nrhs as u64 * fl::norm2_flops(nreal);
    let mut active = vec![true; nrhs];
    let mut stats: Vec<RhsStats> = (0..nrhs)
        .map(|_| RhsStats { iterations: 0, converged: false, rel_residual: 0.0, history: vec![] })
        .collect();
    for r in 0..nrhs {
        if bnorm2[r] == 0.0 {
            // zero RHS: exact solution is zero, like the single solver
            x.fill_rhs(r, R::ZERO);
            active[r] = false;
            stats[r].converged = true;
        }
    }
    let limit: Vec<f64> = bnorm2.iter().map(|&bn| tol * tol * bn).collect();

    let mut r = b.clone();
    let mut ap = b.zeros_like();
    let mut rr = bnorm2.clone();
    if !x.is_zero() {
        // r = b - A x fused with per-RHS |r|² (zero guesses skip this)
        op.apply_multi(team, &mut ap, x, &active, None);
        let neg = vec![-R::ONE; nrhs];
        r.axpy_norm2_masked(&neg, &ap, &active, &mut rr);
        let nact = active.iter().filter(|&&a| a).count() as u64;
        flops += nact
            * (flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal));
        if nact > 0 {
            flops += flops_shared;
        }
    }
    // RHS already at tolerance (warm starts) never enter the loop, like
    // the single solver's `rr > limit` entry condition
    for i in 0..nrhs {
        if active[i] && rr[i] <= limit[i] {
            active[i] = false;
            stats[i].converged = true;
        }
    }
    let mut p = r.clone();

    let view = op.multi_fused_view();
    let mut dot_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut rr_partials: Vec<f64> = vec![0.0; ntiles * nrhs];
    let mut iterations = 0;

    let x_ptr = SendPtr(x.data.as_mut_ptr());
    let r_ptr = SendPtr(r.data.as_mut_ptr());
    let p_ptr = SendPtr(p.data.as_mut_ptr());
    let ap_ptr = SendPtr(ap.data.as_mut_ptr());
    let dot_ptr = SendPtr(dot_partials.as_mut_ptr());
    let rrp_ptr = SendPtr(rr_partials.as_mut_ptr());

    while iterations < maxiter && active.iter().any(|&a| a) {
        if let Some(p) = prof {
            p.set_iter(iterations);
        }
        let nact = active.iter().filter(|&&a| a).count() as u64;
        let rr_iter = rr.clone();
        let mask = active.clone();
        // one region: operator phases + both BLAS sweeps, all sharded
        // SAFETY: all raw access in this region is sharded per tid
        // (chunk_range tile shards / apply_team); shared partial buffers
        // are read only after a barrier publishes every thread's writes.
        team.run(|tid, bar| unsafe {
            // sweep 1: ap = A p, gauge streamed once for all active RHS,
            // per-(site tile, RHS) p·Ap capture fused into the store
            // SAFETY: apply_team writes only this thread's output tile
            // shard and its internal barriers order cross-thread halo
            // reads; the input field is not written during the sweep.
            scoped(prof, tid, Phase::Bulk, || unsafe {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    ap_ptr,
                    p_ptr.0 as *const R,
                    &mask,
                    Some((p_ptr.0 as *const R, dot_ptr)),
                );
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            // every thread combines the same partials in site-tile
            // order, so the per-RHS alphas are identical everywhere
            // (and to the single-RHS fused solver)
            let dp = ro::<[f64; 3]>(dot_ptr, ntiles * nrhs);
            // nrhs-sized per-thread scratch: redundant tiny allocations
            // (a few words per thread per iteration) are accepted — the
            // region's work is O(volume) field sweeps, and sharing the
            // buffers would need per-tid slots or an extra barrier
            let mut alphas = vec![R::ZERO; nrhs];
            for i in 0..nrhs {
                if mask[i] {
                    let pap = reduce_cap_col(dp, ntiles, nrhs, i, 0);
                    alphas[i] = R::from_f64(rr_iter[i] / pap);
                }
            }
            let (tb, te) = chunk_range(ntiles, tid, n);
            // sweep 2: x += alpha p ; r -= alpha ap ; per-sub-tile |r|²
            // SAFETY: every slice written here lies in this thread's [tb,
            // te) tile shard; ro/ro_at operands are not written
            // concurrently within this sweep.
            scoped(prof, tid, Phase::Blas, || unsafe {
                for t in tb..te {
                    for i in 0..nrhs {
                        if !mask[i] {
                            continue;
                        }
                        let off = (t * nrhs + i) * vpt;
                        blas::axpy_slice(
                            x_ptr.slice_mut(off, vpt),
                            alphas[i],
                            ro_at::<R>(p_ptr, off, vpt),
                        );
                        let rt = r_ptr.slice_mut(off, vpt);
                        blas::axpy_slice(rt, -alphas[i], ro_at::<R>(ap_ptr, off, vpt));
                        rrp_ptr.slice_mut(t * nrhs + i, 1)[0] = blas::norm2_tile(rt, vlen);
                    }
                }
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let rrp = ro::<f64>(rrp_ptr, ntiles * nrhs);
            let mut betas = vec![R::ZERO; nrhs];
            for i in 0..nrhs {
                if mask[i] {
                    let rr_new = blas::reduce_partials_col(rrp, nrhs, i);
                    betas[i] = R::from_f64(rr_new / rr_iter[i]);
                }
            }
            // sweep 3: p = beta p + r
            // SAFETY: every slice written here lies in this thread's [tb,
            // te) tile shard; ro/ro_at operands are not written
            // concurrently within this sweep.
            scoped(prof, tid, Phase::Blas, || unsafe {
                for t in tb..te {
                    for i in 0..nrhs {
                        if !mask[i] {
                            continue;
                        }
                        let off = (t * nrhs + i) * vpt;
                        blas::xpay_slice(
                            p_ptr.slice_mut(off, vpt),
                            betas[i],
                            ro_at::<R>(r_ptr, off, vpt),
                        );
                    }
                }
            });
        });
        flops += flops_shared
            + nact
                * (flops_apply
                    + fl::dot_re_flops(nreal)
                    + 2 * fl::axpy_flops(nreal)
                    + fl::norm2_flops(nreal)
                    + fl::xpay_flops(nreal));
        iterations += 1;
        for i in 0..nrhs {
            if !active[i] {
                continue;
            }
            rr[i] = blas::reduce_partials_col(&rr_partials, nrhs, i);
            stats[i].history.push((rr[i] / bnorm2[i]).sqrt());
            stats[i].iterations = iterations;
            if rr[i] <= limit[i] {
                // converged: mask this RHS out of every further sweep
                active[i] = false;
                stats[i].converged = true;
            }
        }
    }

    for i in 0..nrhs {
        if bnorm2[i] > 0.0 {
            stats[i].rel_residual = (rr[i] / bnorm2[i]).sqrt();
        }
    }
    charge_flops(prof, n, ntiles, flops);
    BlockSolveStats::finish(nrhs, iterations, stats, flops, CG_FUSED_SWEEPS, team.nthreads())
}

// ---- BiCGStab stage scalars --------------------------------------------
//
// Each stage turns the per-RHS *reduced* captures (`red[r]` = the
// canonical site-tile-order fold of the per-(tile, RHS) partials, see
// [`reduce_caps_tile_order`] / [`MultiOperator::reduce_caps`]) into
// per-RHS scalars and the next sweep's mask. They are pure functions:
// every thread of the fused region calls them on identical inputs (and
// the master calls them again after the region for stats/flops
// bookkeeping), and the generic/distributed solvers call them on
// globally reduced captures — all parties agree exactly.

/// Stage 1 (after `v = A p` with ⟨rhat, v⟩ capture): per-RHS alpha, and
/// the `rhat·v ≈ 0` breakdown mask. Returns `(mask_b, alpha)`.
fn stage_alpha(
    active: &[bool],
    rho: &[Complex],
    vred: &[[f64; 3]],
    nrhs: usize,
) -> (Vec<bool>, Vec<Complex>) {
    let mut mask_b = active.to_vec();
    let mut alpha = vec![Complex::ZERO; nrhs];
    for i in 0..nrhs {
        if !active[i] {
            continue;
        }
        let rhat_v = Complex::new(vred[i][0], vred[i][1]);
        if rhat_v.abs() < 1e-300 {
            // breakdown: deactivate unconverged (single solver: break)
            mask_b[i] = false;
            continue;
        }
        alpha[i] = rho[i] * rhat_v.conj().scale(1.0 / rhat_v.norm2());
    }
    (mask_b, alpha)
}

/// Stage 2 (after `s = r - alpha v` with |s|² capture): which RHS
/// converged at the half step. Returns `(mask_half, mask_c, snorm)`.
fn stage_half(
    mask_b: &[bool],
    sred: &[[f64; 3]],
    limit: &[f64],
    nrhs: usize,
) -> (Vec<bool>, Vec<bool>, Vec<f64>) {
    let mut mask_half = vec![false; nrhs];
    let mut mask_c = mask_b.to_vec();
    let mut snorm = vec![0.0f64; nrhs];
    for i in 0..nrhs {
        if !mask_b[i] {
            continue;
        }
        snorm[i] = sred[i][2];
        if snorm[i] <= limit[i] {
            mask_half[i] = true;
            mask_c[i] = false;
        }
    }
    (mask_half, mask_c, snorm)
}

/// Stage 3 (after `t = A s` with ⟨s, t⟩ / |t|² capture): per-RHS omega
/// and the `|t|² = 0` breakdown mask. Returns `(mask_d, omega)`.
fn stage_omega(
    mask_c: &[bool],
    tred: &[[f64; 3]],
    nrhs: usize,
) -> (Vec<bool>, Vec<Complex>) {
    let mut mask_d = mask_c.to_vec();
    let mut omega = vec![Complex::ZERO; nrhs];
    for i in 0..nrhs {
        if !mask_c[i] {
            continue;
        }
        // the capture conjugates s; ts = <t, s> flips the imaginary part
        let ts = Complex::new(tred[i][0], -tred[i][1]);
        let n2 = tred[i][2];
        if n2 == 0.0 {
            mask_d[i] = false;
            continue; // breakdown
        }
        omega[i] = ts.scale(1.0 / n2);
    }
    (mask_d, omega)
}

/// Stage 4 (after `r = s - omega t` with ⟨rhat, r⟩ / |r|² capture):
/// post-update breakdowns, convergence, and the next search-direction
/// beta. Returns `(mask_e, beta, rr_new, rho_new)`.
#[allow(clippy::too_many_arguments)]
fn stage_final(
    mask_d: &[bool],
    rred: &[[f64; 3]],
    rho: &[Complex],
    omega: &[Complex],
    alpha: &[Complex],
    limit: &[f64],
    nrhs: usize,
) -> (Vec<bool>, Vec<Complex>, Vec<f64>, Vec<Complex>) {
    let mut mask_e = mask_d.to_vec();
    let mut beta = vec![Complex::ZERO; nrhs];
    let mut rr_new = vec![0.0f64; nrhs];
    let mut rho_new = vec![Complex::ZERO; nrhs];
    for i in 0..nrhs {
        if !mask_d[i] {
            continue;
        }
        rr_new[i] = rred[i][2];
        rho_new[i] = Complex::new(rred[i][0], rred[i][1]);
        if rho[i].abs() < 1e-300 || omega[i].abs() < 1e-300 {
            // post-update breakdown, like the single solver's exit
            mask_e[i] = false;
            continue;
        }
        if rr_new[i] <= limit[i] {
            mask_e[i] = false;
            continue;
        }
        beta[i] = (rho_new[i] * alpha[i])
            * (rho[i] * omega[i]).conj().scale(1.0 / (rho[i] * omega[i]).norm2());
    }
    (mask_e, beta, rr_new, rho_new)
}

/// Batched BiCGStab on a (non-hermitian) multi-RHS M-hat operator, with
/// per-RHS complex scalars, per-RHS convergence masks, and per-RHS
/// breakdown handling mirroring the single-RHS solver's early exits
/// (a broken-down RHS is deactivated unconverged; the others continue).
/// Each batched iteration is ONE team region of up to 6 fused sweeps.
pub fn block_bicgstab<R: Real, A: MultiFusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
) -> BlockSolveStats {
    block_bicgstab_profiled(op, team, x, b, tol, maxiter, None)
}

/// [`block_bicgstab`] with optional per-phase profiling/tracing; the
/// instrumentation never touches the arithmetic.
pub fn block_bicgstab_profiled<R: Real, A: MultiFusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
) -> BlockSolveStats {
    let nrhs = op.nrhs();
    assert_eq!(b.nrhs, nrhs, "rhs count mismatch");
    assert_eq!(x.nrhs, nrhs, "solution count mismatch");
    let ntiles = b.site_tiles();
    let nreal = b.rhs_len() as u64;
    let vpt = b.vals_per_tile();
    let vlen = b.layout.vlen();
    let n = team.nthreads();
    let flops_apply = op.flops_per_apply_rhs();
    let flops_shared = op.flops_per_apply_shared();
    let count = |m: &[bool]| m.iter().filter(|&&a| a).count() as u64;

    let bnorm2 = b.norm2_per_rhs();
    let mut flops = nrhs as u64 * fl::norm2_flops(nreal);
    let mut active = vec![true; nrhs];
    let mut stats: Vec<RhsStats> = (0..nrhs)
        .map(|_| RhsStats { iterations: 0, converged: false, rel_residual: 0.0, history: vec![] })
        .collect();
    for r in 0..nrhs {
        if bnorm2[r] == 0.0 {
            x.fill_rhs(r, R::ZERO);
            active[r] = false;
            stats[r].converged = true;
        }
    }
    let limit: Vec<f64> = bnorm2.iter().map(|&bn| tol * tol * bn).collect();

    let mut r = b.clone();
    let mut t = b.zeros_like();
    let mut rr = bnorm2.clone();
    if !x.is_zero() {
        op.apply_multi(team, &mut t, x, &active, None);
        let neg = vec![-R::ONE; nrhs];
        r.axpy_norm2_masked(&neg, &t, &active, &mut rr);
        flops += count(&active)
            * (flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal));
        if active.iter().any(|&a| a) {
            flops += flops_shared;
        }
    }
    // RHS already at tolerance (warm starts) never enter the loop, like
    // the single solver's `rr > limit` entry condition
    for i in 0..nrhs {
        if active[i] && rr[i] <= limit[i] {
            active[i] = false;
            stats[i].converged = true;
        }
    }
    let rhat = r.clone();
    let mut p = r.clone();
    let mut v = b.zeros_like();
    let mut rho = rhat.dot_per_rhs(&r);
    flops += count(&active) * fl::cdot_flops(nreal);

    let view = op.multi_fused_view();
    let mut v_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut s_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut t_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut r_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut iterations = 0;

    let x_ptr = SendPtr(x.data.as_mut_ptr());
    let r_ptr = SendPtr(r.data.as_mut_ptr());
    let p_ptr = SendPtr(p.data.as_mut_ptr());
    let v_ptr = SendPtr(v.data.as_mut_ptr());
    let t_ptr = SendPtr(t.data.as_mut_ptr());
    let rhat_raw = SendPtr(rhat.data.as_ptr() as *mut R);
    let vp_ptr = SendPtr(v_partials.as_mut_ptr());
    let sp_ptr = SendPtr(s_partials.as_mut_ptr());
    let tp_ptr = SendPtr(t_partials.as_mut_ptr());
    let rp_ptr = SendPtr(r_partials.as_mut_ptr());

    while iterations < maxiter && active.iter().any(|&a| a) {
        if let Some(p) = prof {
            p.set_iter(iterations);
        }
        let rho_iter = rho.clone();
        let mask = active.clone();
        // SAFETY: all raw access in this region is sharded per tid
        // (chunk_range tile shards / apply_team); shared partial buffers
        // are read only after a barrier publishes every thread's writes.
        team.run(|tid, bar| unsafe {
            let (tb, te) = chunk_range(ntiles, tid, n);
            // sweep 1: v = A p with fused per-RHS <rhat, v> capture
            // SAFETY: apply_team writes only this thread's output tile
            // shard and its internal barriers order cross-thread halo
            // reads; the input field is not written during the sweep.
            scoped(prof, tid, Phase::Bulk, || unsafe {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    v_ptr,
                    p_ptr.0 as *const R,
                    &mask,
                    Some((rhat_raw.0 as *const R, vp_ptr)),
                );
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            // the reduce/stage helpers allocate nrhs-sized vectors per
            // thread per iteration — accepted, as above: O(nrhs) words
            // against O(volume) sweep work, redundant by design so every
            // thread (and the master replay) agrees without communication
            let vred =
                reduce_caps_tile_order(ro::<[f64; 3]>(vp_ptr, ntiles * nrhs), nrhs);
            let (mask_b, alpha) = stage_alpha(&mask, &rho_iter, &vred, nrhs);
            if !mask_b.iter().any(|&a| a) {
                return; // every live RHS broke down (uniform decision)
            }
            // sweep 2: s = r - alpha v (in place in r) with per-sub-tile
            // |s|² capture
            // SAFETY: every slice written here lies in this thread's [tb,
            // te) tile shard; ro/ro_at operands are not written
            // concurrently within this sweep.
            scoped(prof, tid, Phase::Blas, || unsafe {
                for tl in tb..te {
                    for i in 0..nrhs {
                        if !mask_b[i] {
                            continue;
                        }
                        let off = (tl * nrhs + i) * vpt;
                        let ma = -alpha[i];
                        let rt = r_ptr.slice_mut(off, vpt);
                        blas::caxpy_slice(
                            rt,
                            R::from_f64(ma.re),
                            R::from_f64(ma.im),
                            ro_at::<R>(v_ptr, off, vpt),
                            vlen,
                        );
                        sp_ptr.slice_mut(tl * nrhs + i, 1)[0] =
                            [0.0, 0.0, blas::norm2_tile(rt, vlen)];
                    }
                }
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let sred =
                reduce_caps_tile_order(ro::<[f64; 3]>(sp_ptr, ntiles * nrhs), nrhs);
            let (mask_half, mask_c, _snorm) = stage_half(&mask_b, &sred, &limit, nrhs);
            if mask_half.iter().any(|&h| h) {
                // converged at the half step: x += alpha p (own shard)
                // SAFETY: every slice written here lies in this thread's
                // [tb, te) tile shard; ro/ro_at operands are not written
                // concurrently within this sweep.
                scoped(prof, tid, Phase::Blas, || unsafe {
                    for tl in tb..te {
                        for i in 0..nrhs {
                            if !mask_half[i] {
                                continue;
                            }
                            let off = (tl * nrhs + i) * vpt;
                            blas::caxpy_slice(
                                x_ptr.slice_mut(off, vpt),
                                R::from_f64(alpha[i].re),
                                R::from_f64(alpha[i].im),
                                ro_at::<R>(p_ptr, off, vpt),
                                vlen,
                            );
                        }
                    }
                });
            }
            if !mask_c.iter().any(|&a| a) {
                return; // all live RHS done at the half step
            }
            // sweep 3: t = A s with fused per-RHS <s, t>, |t|² capture
            // SAFETY: apply_team writes only this thread's output tile
            // shard and its internal barriers order cross-thread halo
            // reads; the input field is not written during the sweep.
            scoped(prof, tid, Phase::Bulk, || unsafe {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    t_ptr,
                    r_ptr.0 as *const R,
                    &mask_c,
                    Some((r_ptr.0 as *const R, tp_ptr)),
                );
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let tred =
                reduce_caps_tile_order(ro::<[f64; 3]>(tp_ptr, ntiles * nrhs), nrhs);
            let (mask_d, omega) = stage_omega(&mask_c, &tred, nrhs);
            if !mask_d.iter().any(|&a| a) {
                return; // breakdown (|t|² = 0) on every remaining RHS
            }
            // sweep 4: x += alpha p + omega s (s lives in r), and
            // sweep 5: r = s - omega t with <rhat, r> / |r|² capture
            // SAFETY: every slice written here lies in this thread's [tb,
            // te) tile shard; ro/ro_at operands are not written
            // concurrently within this sweep.
            scoped(prof, tid, Phase::Blas, || unsafe {
                for tl in tb..te {
                    for i in 0..nrhs {
                        if !mask_d[i] {
                            continue;
                        }
                        let off = (tl * nrhs + i) * vpt;
                        blas::caxpy2_slice(
                            x_ptr.slice_mut(off, vpt),
                            R::from_f64(alpha[i].re),
                            R::from_f64(alpha[i].im),
                            ro_at::<R>(p_ptr, off, vpt),
                            R::from_f64(omega[i].re),
                            R::from_f64(omega[i].im),
                            ro_at::<R>(r_ptr, off, vpt),
                            vlen,
                        );
                        let mo = -omega[i];
                        let rt = r_ptr.slice_mut(off, vpt);
                        blas::caxpy_slice(
                            rt,
                            R::from_f64(mo.re),
                            R::from_f64(mo.im),
                            ro_at::<R>(t_ptr, off, vpt),
                            vlen,
                        );
                        rp_ptr.slice_mut(tl * nrhs + i, 1)[0] = blas::cdot_norm2_tile(
                            ro_at::<R>(rhat_raw, off, vpt),
                            rt,
                            vlen,
                        );
                    }
                }
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let rred =
                reduce_caps_tile_order(ro::<[f64; 3]>(rp_ptr, ntiles * nrhs), nrhs);
            let (mask_e, beta, _rr_new, _rho_new) =
                stage_final(&mask_d, &rred, &rho_iter, &omega, &alpha, &limit, nrhs);
            if !mask_e.iter().any(|&a| a) {
                return;
            }
            // sweep 6: p = beta (p - omega v) + r
            // SAFETY: every slice written here lies in this thread's [tb,
            // te) tile shard; ro/ro_at operands are not written
            // concurrently within this sweep.
            scoped(prof, tid, Phase::Blas, || unsafe {
                for tl in tb..te {
                    for i in 0..nrhs {
                        if !mask_e[i] {
                            continue;
                        }
                        let off = (tl * nrhs + i) * vpt;
                        let mo = -omega[i];
                        blas::p_update_slice(
                            p_ptr.slice_mut(off, vpt),
                            R::from_f64(mo.re),
                            R::from_f64(mo.im),
                            ro_at::<R>(v_ptr, off, vpt),
                            R::from_f64(beta[i].re),
                            R::from_f64(beta[i].im),
                            ro_at::<R>(r_ptr, off, vpt),
                            vlen,
                        );
                    }
                }
            });
        });

        // master bookkeeping: replay the stage cascade on the (final)
        // shared partials — the same pure functions the threads ran, so
        // masks and scalars agree exactly
        let (mask_b, alpha) = stage_alpha(
            &mask,
            &rho_iter,
            &reduce_caps_tile_order(&v_partials, nrhs),
            nrhs,
        );
        flops += count(&mask) * (flops_apply + fl::cdot_flops(nreal)) + flops_shared;
        for i in 0..nrhs {
            if mask[i] && !mask_b[i] {
                active[i] = false; // rhat·v breakdown
            }
        }
        if !mask_b.iter().any(|&a| a) {
            iterations += 1;
            continue;
        }
        let (mask_half, mask_c, snorm) = stage_half(
            &mask_b,
            &reduce_caps_tile_order(&s_partials, nrhs),
            &limit,
            nrhs,
        );
        flops += count(&mask_b) * (fl::caxpy_flops(nreal) + fl::norm2_flops(nreal));
        if mask_half.iter().any(|&h| h) {
            flops += count(&mask_half) * fl::caxpy_flops(nreal);
            for i in 0..nrhs {
                if mask_half[i] {
                    rr[i] = snorm[i];
                    stats[i].history.push((rr[i] / bnorm2[i]).sqrt());
                    stats[i].iterations = iterations + 1;
                    stats[i].converged = true;
                    active[i] = false;
                }
            }
        }
        if !mask_c.iter().any(|&a| a) {
            iterations += 1;
            continue;
        }
        let (mask_d, omega) =
            stage_omega(&mask_c, &reduce_caps_tile_order(&t_partials, nrhs), nrhs);
        flops += count(&mask_c)
            * (flops_apply + fl::cdot_flops(nreal) + fl::norm2_flops(nreal))
            + flops_shared;
        for i in 0..nrhs {
            if mask_c[i] && !mask_d[i] {
                active[i] = false; // |t|² = 0 breakdown
            }
        }
        if mask_d.iter().any(|&a| a) {
            let (mask_e, _beta, rr_new, rho_new) = stage_final(
                &mask_d,
                &reduce_caps_tile_order(&r_partials, nrhs),
                &rho_iter,
                &omega,
                &alpha,
                &limit,
                nrhs,
            );
            flops += count(&mask_d)
                * (3 * fl::caxpy_flops(nreal) + fl::cdot_flops(nreal) + fl::norm2_flops(nreal));
            for i in 0..nrhs {
                if !mask_d[i] {
                    continue;
                }
                rr[i] = rr_new[i];
                stats[i].history.push((rr[i] / bnorm2[i]).sqrt());
                stats[i].iterations = iterations + 1;
                if rho_iter[i].abs() < 1e-300 || omega[i].abs() < 1e-300 {
                    // post-update breakdown, like the single solver
                    stats[i].converged = rr[i] <= limit[i];
                    active[i] = false;
                } else if rr[i] <= limit[i] {
                    stats[i].converged = true;
                    active[i] = false;
                } else {
                    rho[i] = rho_new[i];
                }
            }
            if mask_e.iter().any(|&a| a) {
                flops += count(&mask_e)
                    * (fl::caxpy_flops(nreal) + fl::cscale_flops(nreal) + fl::axpy_flops(nreal));
            }
        }
        iterations += 1;
    }

    for i in 0..nrhs {
        if bnorm2[i] > 0.0 {
            stats[i].rel_residual = (rr[i] / bnorm2[i]).sqrt();
        }
    }
    // a pass that ended entirely in breakdowns counted no per-RHS
    // iteration (mirroring the single solver's uncounted early exits),
    // so report the max over per-RHS counts, not the loop counter
    let done = stats.iter().map(|s| s.iterations).max().unwrap_or(0);
    charge_flops(prof, n, ntiles, flops);
    BlockSolveStats::finish(nrhs, done, stats, flops, BICGSTAB_FUSED_SWEEPS, team.nthreads())
}

// ---- generic block solvers (any MultiOperator, incl. distributed) ------
//
// [`block_cg`]/[`block_bicgstab`] above require [`MultiFusedSolvable`]:
// a native operator whose kernel phases can run inside ONE team region.
// A distributed operator cannot expose that (its halo exchange is
// FUNNELED through the master thread), so the `_generic` variants below
// drive any [`MultiOperator`] — `apply_multi` runs the operator's own
// pipeline (team regions + wire), the BLAS-1 sweeps run tile-sharded on
// the team here, and every reduction goes through the operator's
// `reduce_caps`/`reduce_any` hooks so the distributed impls can fold
// each rank's per-tile partials in GLOBAL site-tile order.
//
// Arithmetic contract: the per-RHS scalar cascade (alpha/beta/omega,
// masks, histories) and the per-sub-tile BLAS kernels are exactly the
// fused solvers' — on a single-rank operator without communicated
// directions the `_generic` histories are **bitwise identical** to
// [`block_cg`]/[`block_bicgstab`]. Across a real decomposition the
// reductions stay bitwise rank-count-independent (global-tile-order
// fold); the operator's face sites are the one place a multi-rank run
// rounds differently (bulk-partial + EO2 merge vs the single-rank
// kernel's one accumulation chain), so multi-rank histories track the
// single-rank ones to f64 tightness rather than bit equality — see
// ARCHITECTURE.md and `rust/tests/distributed.rs`.

/// Batched CG over any [`MultiOperator`] (CGNR on a normal operator):
/// the distributed analog of [`block_cg`], with per-RHS convergence
/// masks propagated into the operator (and thence the halo payload).
///
/// Runs under a default health guard; failures fold into a
/// non-converged [`BlockSolveStats`]. Use [`block_cg_generic_guarded`]
/// for the typed error.
pub fn block_cg_generic<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
) -> BlockSolveStats {
    let nrhs = op.nrhs();
    let threads = team.nthreads();
    match block_cg_generic_guarded(op, team, x, b, tol, maxiter, &HealthConfig::default()) {
        Ok(stats) => stats,
        Err(e) => err_to_block(e, nrhs, CG_FUSED_SWEEPS, threads),
    }
}

/// Attach the per-RHS converged mask to a fatal guard error: the block
/// guard loops own the per-RHS bookkeeping, [`HealthGuard::absorb`]
/// does not.
fn with_mask(mut e: SolveError, stats: &[RhsStats]) -> SolveError {
    e.converged_mask = Some(stats.iter().map(|s| s.converged).collect());
    e
}

/// Batched CG under the solver health guard: non-finite per-RHS
/// iteration scalars abort the batched iteration *before* the combined
/// x/r sweep where possible, the guard restarts the Krylov processes
/// from the warm iterates (bounded by `solver.max_restarts`), and
/// transport faults surface as a typed [`SolveError`] whose
/// `converged_mask` records which RHS had already finished. The
/// fault-free path is bitwise identical to [`block_cg_generic`]'s
/// histories (the checks never alter the arithmetic).
pub fn block_cg_generic_guarded<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
) -> Result<BlockSolveStats, SolveError> {
    block_cg_generic_guarded_profiled(op, team, x, b, tol, maxiter, health, None)
}

/// [`block_cg_generic_guarded`] with optional per-phase profiling and
/// span tracing. On a guarded restart the profiler's accumulators fold
/// into the `restart` bucket so the emitted per-phase times describe
/// only the surviving attempt; the instrumentation never touches the
/// arithmetic.
#[allow(clippy::too_many_arguments)]
pub fn block_cg_generic_guarded_profiled<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    prof: Option<&Profiler>,
) -> Result<BlockSolveStats, SolveError> {
    block_cg_generic_guarded_ckpt(op, team, x, b, tol, maxiter, health, prof, None, None)
}

/// Cross-iteration block-CG state restored on resume (per-RHS masks,
/// stats, and iteration counters live in the guarded driver and are
/// restored there).
struct BlockCgResume<R: Real> {
    r: MultiFermionField<R>,
    p: MultiFermionField<R>,
    rr: Vec<f64>,
}

/// Restore the per-RHS bookkeeping shared by both generic block guards
/// from a checkpoint: masks → `active`, per-RHS records → `stats`.
fn restore_block_rhs(
    st: &SolverState,
    nrhs: usize,
    active: &mut [bool],
    stats: &mut [RhsStats],
) -> Result<(), SolveError> {
    if st.masks.len() != nrhs || st.per_rhs.len() != nrhs {
        return Err(SolveError::checkpoint(format!(
            "checkpoint holds {} rhs, operator has {nrhs}",
            st.masks.len()
        )));
    }
    for i in 0..nrhs {
        active[i] = st.masks[i];
        stats[i] = RhsStats {
            iterations: st.per_rhs[i].iterations as usize,
            converged: st.per_rhs[i].converged,
            rel_residual: st.per_rhs[i].rel_residual,
            history: st.per_rhs[i].history.clone(),
        };
    }
    Ok(())
}

/// [`block_cg_generic_guarded_profiled`] with a checkpoint sink and/or
/// resume state (see [`super::cg_guarded_ckpt`] for the bitwise-resume
/// contract — here it covers every RHS history and the per-RHS masks).
#[allow(clippy::too_many_arguments)]
pub fn block_cg_generic_guarded_ckpt<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    prof: Option<&Profiler>,
    mut ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
) -> Result<BlockSolveStats, SolveError> {
    let nrhs = op.nrhs();
    assert_eq!(b.nrhs, nrhs, "rhs count mismatch");
    assert_eq!(x.nrhs, nrhs, "solution count mismatch");
    let ntiles = b.site_tiles();
    let vpt = b.vals_per_tile();
    let vlen = b.layout.vlen();
    let nreal = b.rhs_len() as u64;

    let mut guard = HealthGuard::new(health);
    let mut history: Vec<f64> = Vec::new();
    let mut iterations = 0usize;
    let mut flops = 0u64;
    let c0 = op.comm_counters();
    let z0 = op.comm_zero_fills();
    let counters = |op: &A| {
        let c1 = op.comm_counters();
        (c1.0 - c0.0, c1.1 - c0.1, op.comm_zero_fills() - z0)
    };

    let mut caps: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    // |b_r|² through the operator's reduction: canonical site-tile
    // grouping locally, global-tile-order fold when distributed
    for t in 0..ntiles {
        for r in 0..nrhs {
            let off = (t * nrhs + r) * vpt;
            caps[t * nrhs + r] = [0.0, 0.0, blas::norm2_tile(&b.data[off..off + vpt], vlen)];
        }
    }
    let bnorm2: Vec<f64> = op.reduce_caps(&caps).iter().map(|c| c[2]).collect();
    flops += nrhs as u64 * fl::norm2_flops(nreal);

    let mut active = vec![true; nrhs];
    let mut stats: Vec<RhsStats> = (0..nrhs)
        .map(|_| RhsStats { iterations: 0, converged: false, rel_residual: 0.0, history: vec![] })
        .collect();
    for r in 0..nrhs {
        if bnorm2[r] == 0.0 {
            x.fill_rhs(r, R::ZERO);
            active[r] = false;
            stats[r].converged = true;
        }
    }
    let limit: Vec<f64> = bnorm2.iter().map(|&bn| tol * tol * bn).collect();
    // a zero-filled |b|² after a transport fault must not masquerade as
    // an all-trivial solve
    if let Some(err) = op.comm_fault() {
        let e = guard
            .absorb(Interrupt::Comm { err, iteration: 0 }, &history, counters(op))
            .expect_err("comm faults are fatal");
        return Err(with_mask(e, &stats));
    }

    let mut pack = None;
    if let Some(st) = resume {
        if st.family != FAMILY_BLOCK_CG {
            return Err(SolveError::checkpoint(format!(
                "checkpoint holds family tag {}, not block cg",
                st.family
            )));
        }
        st.restore_into("x", &mut x.data).map_err(SolveError::checkpoint)?;
        let mut r = b.zeros_like();
        st.restore_into("r", &mut r.data).map_err(SolveError::checkpoint)?;
        let mut p = b.zeros_like();
        st.restore_into("p", &mut p.data).map_err(SolveError::checkpoint)?;
        if st.scalars.len() != nrhs {
            return Err(SolveError::checkpoint("missing per-rhs rr scalars"));
        }
        restore_block_rhs(st, nrhs, &mut active, &mut stats)?;
        guard.restarts = st.restarts as usize;
        history = st.history.clone();
        iterations = st.iteration as usize;
        flops = st.flops;
        op.restore_fault_cursors(&st.fault_cursors);
        pack = Some(BlockCgResume { r, p, rr: st.scalars.clone() });
    }

    let mut flops_at_restart = 0u64;
    loop {
        match block_cg_generic_attempt(
            op,
            team,
            x,
            b,
            maxiter,
            health,
            &bnorm2,
            &limit,
            &mut active,
            &mut stats,
            &mut iterations,
            &mut history,
            &mut flops,
            prof,
            guard.restarts,
            ckpt.as_deref_mut(),
            &mut pack,
        ) {
            Ok(mut out) => {
                // Drift check at apparent convergence: a recursive
                // residual that silently diverged from the true one
                // reactivates the affected RHS and restarts them.
                if health.drift_tol > 0.0 {
                    let (redo, worst) = block_drift_reactivate(
                        op,
                        team,
                        x,
                        b,
                        &stats,
                        &bnorm2,
                        health.drift_tol,
                        &mut flops,
                    );
                    if redo.iter().any(|&a| a) {
                        guard
                            .absorb(
                                Interrupt::Drift { iteration: iterations, ratio: worst },
                                &history,
                                counters(op),
                            )
                            .map_err(|e| with_mask(e, &stats))?;
                        if let Some(p) = prof {
                            p.restart_reset();
                        }
                        flops_at_restart = flops;
                        for i in 0..nrhs {
                            if redo[i] {
                                active[i] = true;
                                stats[i].converged = false;
                            }
                        }
                        continue;
                    }
                    out.flops = flops;
                }
                charge_flops(prof, team.nthreads(), ntiles, flops - flops_at_restart);
                let c = counters(op);
                out.restarts = guard.restarts;
                out.health_events = guard.events.len();
                out.retransmits = c.0;
                out.timeouts = c.1;
                out.zero_fills = c.2;
                return Ok(out);
            }
            Err(int) => {
                guard
                    .absorb(int, &history, counters(op))
                    .map_err(|e| with_mask(e, &stats))?;
                if let Some(p) = prof {
                    p.restart_reset();
                }
                flops_at_restart = flops;
            }
        }
    }
}

/// One guarded batched-CG attempt: re-derives every active residual
/// from the warm iterates, then runs the batched 3-sweep iteration
/// until all RHS converge, the (global) `maxiter` budget, or an
/// interrupt. `active`/`stats`/`iterations`/`history`/`flops` persist
/// across attempts; `iterations` is the global batched-iteration count.
#[allow(clippy::too_many_arguments)]
fn block_cg_generic_attempt<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    maxiter: usize,
    health: &HealthConfig,
    bnorm2: &[f64],
    limit: &[f64],
    active: &mut [bool],
    stats: &mut [RhsStats],
    iterations: &mut usize,
    history: &mut Vec<f64>,
    flops: &mut u64,
    prof: Option<&Profiler>,
    restarts: usize,
    mut ckpt: Option<&mut Checkpointer>,
    resume: &mut Option<BlockCgResume<R>>,
) -> Result<BlockSolveStats, Interrupt> {
    let nrhs = b.nrhs;
    let ntiles = b.site_tiles();
    let nreal = b.rhs_len() as u64;
    let vpt = b.vals_per_tile();
    let vlen = b.layout.vlen();
    let n = team.nthreads();
    let flops_apply = op.flops_per_apply_rhs();
    let flops_shared = op.flops_per_apply_shared();

    let resumed = resume.take();
    op.fault_hook(*iterations)
        .map_err(|err| Interrupt::Comm { err, iteration: *iterations })?;

    let mut caps: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut ap = b.zeros_like();
    let (mut r, mut p, mut rr);
    if let Some(rs) = resumed {
        // checkpoint resume: every per-RHS recurrence continues from
        // its restored iteration boundary bit-for-bit (masks/stats were
        // restored by the guarded driver)
        r = rs.r;
        p = rs.p;
        rr = rs.rr;
    } else {
        r = b.clone();
        rr = bnorm2.to_vec();
        // globally consistent warm-start decision (a rank whose local
        // shard happens to be zero must still join the collective apply)
        if op.reduce_any(!x.is_zero()) {
            op.apply_multi(team, &mut ap, x, active, None);
            // r = b - A x with per-(tile, RHS) |r|² capture (serial
            // entry phase, like the fused solver's axpy_norm2_masked)
            for t in 0..ntiles {
                for i in 0..nrhs {
                    if !active[i] {
                        continue;
                    }
                    let off = (t * nrhs + i) * vpt;
                    let rt = &mut r.data[off..off + vpt];
                    blas::axpy_slice(rt, -R::ONE, &ap.data[off..off + vpt]);
                    caps[t * nrhs + i] = [0.0, 0.0, blas::norm2_tile(rt, vlen)];
                }
            }
            let red = op.reduce_caps(&caps);
            let nact = active.iter().filter(|&&a| a).count() as u64;
            for i in 0..nrhs {
                if active[i] {
                    rr[i] = red[i][2];
                }
            }
            *flops += nact * (flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal));
            if nact > 0 {
                *flops += flops_shared;
            }
        }
        // a poisoned warm iterate has nothing worth preserving:
        // cold-restart just that RHS (zero guess) and charge the budget
        let mut poisoned = false;
        for i in 0..nrhs {
            if active[i] && !rr[i].is_finite() {
                x.fill_rhs(i, R::ZERO);
                poisoned = true;
            }
        }
        if poisoned {
            return Err(Interrupt::NonFinite { what: "initial |r|^2", iteration: *iterations });
        }
        for i in 0..nrhs {
            if active[i] {
                stats[i].rel_residual = (rr[i] / bnorm2[i]).sqrt();
                if rr[i] <= limit[i] {
                    active[i] = false;
                    stats[i].converged = true;
                }
            }
        }
        p = r.clone();
    }
    let mut stag = StagnationTracker::new(health.stagnation_window);

    while *iterations < maxiter && active.iter().any(|&a| a) {
        if let Some(p) = prof {
            p.set_iter(*iterations);
        }
        op.fault_hook(*iterations)
            .map_err(|err| Interrupt::Comm { err, iteration: *iterations })?;
        if let Some(ck) = ckpt.as_deref_mut() {
            if ck.due(*iterations as u64) {
                let mut st = SolverState::new(FAMILY_BLOCK_CG, *iterations as u64);
                st.restarts = restarts as u64;
                st.flops = *flops;
                st.scalars = rr.clone();
                st.history = history.clone();
                st.masks = active.to_vec();
                st.per_rhs = stats
                    .iter()
                    .map(|s| RhsRecord {
                        iterations: s.iterations as u64,
                        converged: s.converged,
                        rel_residual: s.rel_residual,
                        history: s.history.clone(),
                    })
                    .collect();
                st.fields = vec![
                    FieldSnap::of_multi("x", x),
                    FieldSnap::of_multi("r", &r),
                    FieldSnap::of_multi("p", &p),
                ];
                scoped(prof, 0, Phase::Checkpoint, || ck.save_multi(st, op));
            }
        }
        let nact = active.iter().filter(|&&a| a).count() as u64;
        let rr_iter = rr.clone();
        let mask: Vec<bool> = active.to_vec();
        // sweep 1: ap = A p with per-(tile, RHS) p·Ap capture
        op.apply_multi(team, &mut ap, &p, &mask, Some((&p, &mut caps)));
        let red = op.reduce_caps(&caps);
        let mut alphas = vec![R::ZERO; nrhs];
        for i in 0..nrhs {
            if mask[i] {
                let a = rr_iter[i] / red[i][0];
                // checked before the combined x/r sweep: the solution
                // iterates are still warm if this reduction was poisoned
                if !a.is_finite() {
                    return Err(Interrupt::NonFinite { what: "pAp", iteration: *iterations });
                }
                alphas[i] = R::from_f64(a);
            }
        }
        // sweep 2: x += alpha p ; r -= alpha ap ; per-(tile, RHS) |r|²
        {
            let x_ptr = SendPtr(x.data.as_mut_ptr());
            let r_ptr = SendPtr(r.data.as_mut_ptr());
            let p_raw = SendPtr(p.data.as_ptr() as *mut R);
            let ap_raw = SendPtr(ap.data.as_ptr() as *mut R);
            let caps_ptr = SendPtr(caps.as_mut_ptr());
            let mask = &mask;
            let alphas = &alphas;
            team.parallel(|tid| {
                // SAFETY: every slice written here lies in this thread's
                // [tb, te) tile shard; ro/ro_at operands are not written
                // concurrently within this sweep.
                scoped(prof, tid, Phase::Blas, || unsafe {
                    let (tb, te) = chunk_range(ntiles, tid, n);
                    for t in tb..te {
                        for i in 0..nrhs {
                            if !mask[i] {
                                continue;
                            }
                            let off = (t * nrhs + i) * vpt;
                            blas::axpy_slice(
                                x_ptr.slice_mut(off, vpt),
                                alphas[i],
                                ro_at::<R>(p_raw, off, vpt),
                            );
                            let rt = r_ptr.slice_mut(off, vpt);
                            blas::axpy_slice(rt, -alphas[i], ro_at::<R>(ap_raw, off, vpt));
                            caps_ptr.slice_mut(t * nrhs + i, 1)[0] =
                                [0.0, 0.0, blas::norm2_tile(rt, vlen)];
                        }
                    }
                })
            });
        }
        let red = op.reduce_caps(&caps);
        for i in 0..nrhs {
            // x was updated this sweep, but with a finite alpha: the
            // restart re-derives r = b - A x from that warm iterate
            if mask[i] && !red[i][2].is_finite() {
                return Err(Interrupt::NonFinite { what: "|r|^2", iteration: *iterations });
            }
        }
        let mut betas = vec![R::ZERO; nrhs];
        for i in 0..nrhs {
            if mask[i] {
                betas[i] = R::from_f64(red[i][2] / rr_iter[i]);
            }
        }
        // sweep 3: p = beta p + r
        {
            let p_ptr = SendPtr(p.data.as_mut_ptr());
            let r_raw = SendPtr(r.data.as_ptr() as *mut R);
            let mask = &mask;
            let betas = &betas;
            team.parallel(|tid| {
                // SAFETY: every slice written here lies in this thread's
                // [tb, te) tile shard; ro/ro_at operands are not written
                // concurrently within this sweep.
                scoped(prof, tid, Phase::Blas, || unsafe {
                    let (tb, te) = chunk_range(ntiles, tid, n);
                    for t in tb..te {
                        for i in 0..nrhs {
                            if !mask[i] {
                                continue;
                            }
                            let off = (t * nrhs + i) * vpt;
                            blas::xpay_slice(
                                p_ptr.slice_mut(off, vpt),
                                betas[i],
                                ro_at::<R>(r_raw, off, vpt),
                            );
                        }
                    }
                })
            });
        }
        *flops += flops_shared
            + nact
                * (flops_apply
                    + fl::dot_re_flops(nreal)
                    + 2 * fl::axpy_flops(nreal)
                    + fl::norm2_flops(nreal)
                    + fl::xpay_flops(nreal));
        *iterations += 1;
        for i in 0..nrhs {
            if !active[i] {
                continue;
            }
            rr[i] = red[i][2];
            let rel = (rr[i] / bnorm2[i]).sqrt();
            stats[i].history.push(rel);
            stats[i].rel_residual = rel;
            stats[i].iterations = *iterations;
            if rr[i] <= limit[i] {
                active[i] = false;
                stats[i].converged = true;
            }
        }
        // guard diagnostics track the worst system that ran this
        // iteration
        let worst = (0..nrhs)
            .filter(|&i| mask[i])
            .map(|i| (rr[i] / bnorm2[i]).sqrt())
            .fold(0.0f64, f64::max);
        history.push(worst);
        if active.iter().any(|&a| a) && stag.stalled(worst) {
            return Err(Interrupt::Stagnation { iteration: *iterations });
        }
    }

    // A transport fault zero-fills halos rather than panicking, so a
    // "converged" residual after a fault is not trustworthy: surface
    // the recorded fault instead of the stats.
    if let Some(err) = op.comm_fault() {
        return Err(Interrupt::Comm { err, iteration: *iterations });
    }
    Ok(BlockSolveStats::finish(
        nrhs,
        *iterations,
        stats.to_vec(),
        *flops,
        CG_FUSED_SWEEPS,
        team.nthreads(),
    ))
}

/// Per-RHS drift check at (apparent) convergence: recompute the true
/// residuals `r_i = b_i - A x_i` with one batched apply and compare
/// each converged RHS against the recursive residual it stopped on.
/// Returns which RHS must be reactivated and the worst ratio seen.
fn block_drift_reactivate<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    stats: &[RhsStats],
    bnorm2: &[f64],
    drift_tol: f64,
    flops: &mut u64,
) -> (Vec<bool>, f64) {
    let nrhs = b.nrhs;
    let ntiles = b.site_tiles();
    let vpt = b.vals_per_tile();
    let vlen = b.layout.vlen();
    let nreal = b.rhs_len() as u64;
    let check: Vec<bool> = (0..nrhs)
        .map(|i| stats[i].converged && bnorm2[i] > 0.0)
        .collect();
    if !check.iter().any(|&c| c) {
        return (vec![false; nrhs], 1.0);
    }
    let mut ax = b.zeros_like();
    op.apply_multi(team, &mut ax, x, &check, None);
    let mut r = b.clone();
    let mut caps: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    for t in 0..ntiles {
        for i in 0..nrhs {
            if !check[i] {
                continue;
            }
            let off = (t * nrhs + i) * vpt;
            let rt = &mut r.data[off..off + vpt];
            blas::axpy_slice(rt, -R::ONE, &ax.data[off..off + vpt]);
            caps[t * nrhs + i] = [0.0, 0.0, blas::norm2_tile(rt, vlen)];
        }
    }
    let red = op.reduce_caps(&caps);
    let nact = check.iter().filter(|&&c| c).count() as u64;
    *flops += op.flops_per_apply_shared()
        + nact
            * (op.flops_per_apply_rhs() + fl::axpy_flops(nreal) + fl::norm2_flops(nreal));
    let mut redo = vec![false; nrhs];
    let mut worst = 1.0f64;
    for i in 0..nrhs {
        if !check[i] {
            continue;
        }
        let true_rel = (red[i][2] / bnorm2[i]).sqrt();
        let recursive = stats[i].rel_residual;
        let ratio = if recursive > 0.0 {
            true_rel / recursive
        } else if true_rel > 0.0 {
            f64::INFINITY
        } else {
            1.0
        };
        if !ratio.is_finite() || ratio > drift_tol {
            redo[i] = true;
        }
        if !ratio.is_finite() || ratio > worst {
            worst = ratio;
        }
    }
    (redo, worst)
}

/// Batched BiCGStab over any [`MultiOperator`]: the distributed analog
/// of [`block_bicgstab`] (same per-RHS stage cascade, breakdown
/// handling, masks and histories; reductions through the operator).
///
/// Runs under a default health guard; failures fold into a
/// non-converged [`BlockSolveStats`]. Use
/// [`block_bicgstab_generic_guarded`] for the typed error.
pub fn block_bicgstab_generic<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
) -> BlockSolveStats {
    let nrhs = op.nrhs();
    let threads = team.nthreads();
    match block_bicgstab_generic_guarded(op, team, x, b, tol, maxiter, &HealthConfig::default())
    {
        Ok(stats) => stats,
        Err(e) => err_to_block(e, nrhs, BICGSTAB_FUSED_SWEEPS, threads),
    }
}

/// Batched BiCGStab under the solver health guard — the BiCGStab analog
/// of [`block_cg_generic_guarded`]: per-RHS stage scalars (alpha,
/// |s|², omega, |r|², rho, beta) are checked before the sweep they
/// feed, recoverable events restart the affected Krylov processes from
/// the warm iterates, transport faults surface as typed
/// [`SolveError`]s with the per-RHS converged mask.
pub fn block_bicgstab_generic_guarded<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
) -> Result<BlockSolveStats, SolveError> {
    block_bicgstab_generic_guarded_profiled(op, team, x, b, tol, maxiter, health, None)
}

/// [`block_bicgstab_generic_guarded`] with optional per-phase profiling
/// and span tracing — same restart-bucket contract as
/// [`block_cg_generic_guarded_profiled`].
#[allow(clippy::too_many_arguments)]
pub fn block_bicgstab_generic_guarded_profiled<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    prof: Option<&Profiler>,
) -> Result<BlockSolveStats, SolveError> {
    block_bicgstab_generic_guarded_ckpt(
        op, team, x, b, tol, maxiter, health, prof, None, None,
    )
}

/// Cross-iteration block-BiCGStab state restored on resume; `v`/`t`
/// are recomputed before first read each iteration, so only the
/// residuals, search directions, shadow residual, and the per-RHS
/// `rr`/`rho` scalars are part of the checkpoint.
struct BlockBiCgResume<R: Real> {
    r: MultiFermionField<R>,
    p: MultiFermionField<R>,
    rhat: MultiFermionField<R>,
    rr: Vec<f64>,
    rho: Vec<Complex>,
}

/// [`block_bicgstab_generic_guarded_profiled`] with a checkpoint sink
/// and/or resume state — the BiCGStab analog of
/// [`block_cg_generic_guarded_ckpt`].
#[allow(clippy::too_many_arguments)]
pub fn block_bicgstab_generic_guarded_ckpt<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    prof: Option<&Profiler>,
    mut ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
) -> Result<BlockSolveStats, SolveError> {
    let nrhs = op.nrhs();
    assert_eq!(b.nrhs, nrhs, "rhs count mismatch");
    assert_eq!(x.nrhs, nrhs, "solution count mismatch");
    let ntiles = b.site_tiles();
    let vpt = b.vals_per_tile();
    let vlen = b.layout.vlen();
    let nreal = b.rhs_len() as u64;

    let mut guard = HealthGuard::new(health);
    let mut history: Vec<f64> = Vec::new();
    let mut iterations = 0usize;
    let mut flops = 0u64;
    let c0 = op.comm_counters();
    let z0 = op.comm_zero_fills();
    let counters = |op: &A| {
        let c1 = op.comm_counters();
        (c1.0 - c0.0, c1.1 - c0.1, op.comm_zero_fills() - z0)
    };

    let mut caps: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    for t in 0..ntiles {
        for r in 0..nrhs {
            let off = (t * nrhs + r) * vpt;
            caps[t * nrhs + r] = [0.0, 0.0, blas::norm2_tile(&b.data[off..off + vpt], vlen)];
        }
    }
    let bnorm2: Vec<f64> = op.reduce_caps(&caps).iter().map(|c| c[2]).collect();
    flops += nrhs as u64 * fl::norm2_flops(nreal);

    let mut active = vec![true; nrhs];
    let mut stats: Vec<RhsStats> = (0..nrhs)
        .map(|_| RhsStats { iterations: 0, converged: false, rel_residual: 0.0, history: vec![] })
        .collect();
    for r in 0..nrhs {
        if bnorm2[r] == 0.0 {
            x.fill_rhs(r, R::ZERO);
            active[r] = false;
            stats[r].converged = true;
        }
    }
    let limit: Vec<f64> = bnorm2.iter().map(|&bn| tol * tol * bn).collect();
    if let Some(err) = op.comm_fault() {
        let e = guard
            .absorb(Interrupt::Comm { err, iteration: 0 }, &history, counters(op))
            .expect_err("comm faults are fatal");
        return Err(with_mask(e, &stats));
    }

    let mut pack = None;
    if let Some(st) = resume {
        if st.family != FAMILY_BLOCK_BICGSTAB {
            return Err(SolveError::checkpoint(format!(
                "checkpoint holds family tag {}, not block bicgstab",
                st.family
            )));
        }
        st.restore_into("x", &mut x.data).map_err(SolveError::checkpoint)?;
        let mut r = b.zeros_like();
        st.restore_into("r", &mut r.data).map_err(SolveError::checkpoint)?;
        let mut p = b.zeros_like();
        st.restore_into("p", &mut p.data).map_err(SolveError::checkpoint)?;
        let mut rhat = b.zeros_like();
        st.restore_into("rhat", &mut rhat.data)
            .map_err(SolveError::checkpoint)?;
        // scalars: per-RHS rr, then per-RHS (rho.re, rho.im) pairs
        if st.scalars.len() != 3 * nrhs {
            return Err(SolveError::checkpoint("missing per-rhs rr/rho scalars"));
        }
        let rr = st.scalars[..nrhs].to_vec();
        let rho: Vec<Complex> = (0..nrhs)
            .map(|i| Complex::new(st.scalars[nrhs + 2 * i], st.scalars[nrhs + 2 * i + 1]))
            .collect();
        restore_block_rhs(st, nrhs, &mut active, &mut stats)?;
        guard.restarts = st.restarts as usize;
        history = st.history.clone();
        iterations = st.iteration as usize;
        flops = st.flops;
        op.restore_fault_cursors(&st.fault_cursors);
        pack = Some(BlockBiCgResume { r, p, rhat, rr, rho });
    }

    let mut flops_at_restart = 0u64;
    loop {
        match block_bicgstab_generic_attempt(
            op,
            team,
            x,
            b,
            maxiter,
            health,
            &bnorm2,
            &limit,
            &mut active,
            &mut stats,
            &mut iterations,
            &mut history,
            &mut flops,
            prof,
            guard.restarts,
            ckpt.as_deref_mut(),
            &mut pack,
        ) {
            Ok(mut out) => {
                if health.drift_tol > 0.0 {
                    let (redo, worst) = block_drift_reactivate(
                        op,
                        team,
                        x,
                        b,
                        &stats,
                        &bnorm2,
                        health.drift_tol,
                        &mut flops,
                    );
                    if redo.iter().any(|&a| a) {
                        guard
                            .absorb(
                                Interrupt::Drift { iteration: iterations, ratio: worst },
                                &history,
                                counters(op),
                            )
                            .map_err(|e| with_mask(e, &stats))?;
                        if let Some(p) = prof {
                            p.restart_reset();
                        }
                        flops_at_restart = flops;
                        for i in 0..nrhs {
                            if redo[i] {
                                active[i] = true;
                                stats[i].converged = false;
                            }
                        }
                        continue;
                    }
                    out.flops = flops;
                }
                charge_flops(prof, team.nthreads(), ntiles, flops - flops_at_restart);
                let c = counters(op);
                out.restarts = guard.restarts;
                out.health_events = guard.events.len();
                out.retransmits = c.0;
                out.timeouts = c.1;
                out.zero_fills = c.2;
                return Ok(out);
            }
            Err(int) => {
                guard
                    .absorb(int, &history, counters(op))
                    .map_err(|e| with_mask(e, &stats))?;
                if let Some(p) = prof {
                    p.restart_reset();
                }
                flops_at_restart = flops;
            }
        }
    }
}

/// One guarded batched-BiCGStab attempt — see
/// [`block_cg_generic_attempt`] for the shared restart contract.
#[allow(clippy::too_many_arguments)]
fn block_bicgstab_generic_attempt<R: Real, A: MultiOperator<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut MultiFermionField<R>,
    b: &MultiFermionField<R>,
    maxiter: usize,
    health: &HealthConfig,
    bnorm2: &[f64],
    limit: &[f64],
    active: &mut [bool],
    stats: &mut [RhsStats],
    iterations: &mut usize,
    history: &mut Vec<f64>,
    flops: &mut u64,
    prof: Option<&Profiler>,
    restarts: usize,
    mut ckpt: Option<&mut Checkpointer>,
    resume: &mut Option<BlockBiCgResume<R>>,
) -> Result<BlockSolveStats, Interrupt> {
    let nrhs = b.nrhs;
    let ntiles = b.site_tiles();
    let nreal = b.rhs_len() as u64;
    let vpt = b.vals_per_tile();
    let vlen = b.layout.vlen();
    let n = team.nthreads();
    let flops_apply = op.flops_per_apply_rhs();
    let flops_shared = op.flops_per_apply_shared();
    let count = |m: &[bool]| m.iter().filter(|&&a| a).count() as u64;
    let cfin = |c: Complex| c.re.is_finite() && c.im.is_finite();

    let resumed = resume.take();
    op.fault_hook(*iterations)
        .map_err(|err| Interrupt::Comm { err, iteration: *iterations })?;

    let mut caps: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles * nrhs];
    let mut t = b.zeros_like();
    let (mut r, rhat, mut p, mut rr, mut rho);
    if let Some(rs) = resumed {
        // Bitwise continuation: the restored pack carries the exact
        // r/p/rhat/rr/rho of the checkpointed iteration; the warm-start
        // re-derivation below is only for health restarts.
        r = rs.r;
        rhat = rs.rhat;
        p = rs.p;
        rr = rs.rr;
        rho = rs.rho;
    } else {
        r = b.clone();
        rr = bnorm2.to_vec();
        if op.reduce_any(!x.is_zero()) {
            op.apply_multi(team, &mut t, x, active, None);
            for tl in 0..ntiles {
                for i in 0..nrhs {
                    if !active[i] {
                        continue;
                    }
                    let off = (tl * nrhs + i) * vpt;
                    let rt = &mut r.data[off..off + vpt];
                    blas::axpy_slice(rt, -R::ONE, &t.data[off..off + vpt]);
                    caps[tl * nrhs + i] = [0.0, 0.0, blas::norm2_tile(rt, vlen)];
                }
            }
            let red = op.reduce_caps(&caps);
            for i in 0..nrhs {
                if active[i] {
                    rr[i] = red[i][2];
                }
            }
            *flops += count(active)
                * (flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal));
            if active.iter().any(|&a| a) {
                *flops += flops_shared;
            }
        }
        let mut poisoned = false;
        for i in 0..nrhs {
            if active[i] && !rr[i].is_finite() {
                x.fill_rhs(i, R::ZERO);
                poisoned = true;
            }
        }
        if poisoned {
            return Err(Interrupt::NonFinite { what: "initial |r|^2", iteration: *iterations });
        }
        for i in 0..nrhs {
            if active[i] {
                stats[i].rel_residual = (rr[i] / bnorm2[i]).sqrt();
                if rr[i] <= limit[i] {
                    active[i] = false;
                    stats[i].converged = true;
                }
            }
        }
        rhat = r.clone();
        p = r.clone();
        // rho = <rhat, r> through the operator's reduction (bitwise the
        // local dot_per_rhs on a single rank)
        rhat.cdot_norm2_partials(&r, active, &mut caps);
        let red = op.reduce_caps(&caps);
        rho = red.iter().map(|c| Complex::new(c[0], c[1])).collect();
        for i in 0..nrhs {
            if active[i] && !cfin(rho[i]) {
                return Err(Interrupt::NonFinite { what: "rho", iteration: *iterations });
            }
        }
        *flops += count(active) * fl::cdot_flops(nreal);
    }
    let mut v = b.zeros_like();
    let mut stag = StagnationTracker::new(health.stagnation_window);

    while *iterations < maxiter && active.iter().any(|&a| a) {
        if let Some(p) = prof {
            p.set_iter(*iterations);
        }
        op.fault_hook(*iterations)
            .map_err(|err| Interrupt::Comm { err, iteration: *iterations })?;
        if let Some(ck) = ckpt.as_deref_mut() {
            if ck.due(*iterations as u64) {
                let mut st = SolverState::new(FAMILY_BLOCK_BICGSTAB, *iterations as u64);
                st.restarts = restarts as u64;
                st.flops = *flops;
                st.scalars = rr
                    .iter()
                    .copied()
                    .chain(rho.iter().flat_map(|c| [c.re, c.im]))
                    .collect();
                st.history = history.clone();
                st.masks = active.to_vec();
                st.per_rhs = stats
                    .iter()
                    .map(|s| RhsRecord {
                        iterations: s.iterations as u64,
                        converged: s.converged,
                        rel_residual: s.rel_residual,
                        history: s.history.clone(),
                    })
                    .collect();
                st.fields = vec![
                    FieldSnap::of_multi("x", x),
                    FieldSnap::of_multi("r", &r),
                    FieldSnap::of_multi("p", &p),
                    FieldSnap::of_multi("rhat", &rhat),
                ];
                scoped(prof, 0, Phase::Checkpoint, || ck.save_multi(st, op));
            }
        }
        let rho_iter = rho.clone();
        let mask: Vec<bool> = active.to_vec();
        // sweep 1: v = A p with per-RHS <rhat, v> capture
        op.apply_multi(team, &mut v, &p, &mask, Some((&rhat, &mut caps)));
        let vred = op.reduce_caps(&caps);
        let (mask_b, alpha) = stage_alpha(&mask, &rho_iter, &vred, nrhs);
        for i in 0..nrhs {
            // checked before any update this iteration touches x or r
            if mask_b[i] && !cfin(alpha[i]) {
                return Err(Interrupt::NonFinite { what: "alpha", iteration: *iterations });
            }
        }
        *flops += count(&mask) * (flops_apply + fl::cdot_flops(nreal)) + flops_shared;
        for i in 0..nrhs {
            if mask[i] && !mask_b[i] {
                active[i] = false; // rhat·v breakdown
            }
        }
        if !mask_b.iter().any(|&a| a) {
            *iterations += 1;
            continue;
        }
        // sweep 2: s = r - alpha v (in place in r) with |s|² capture
        {
            let r_ptr = SendPtr(r.data.as_mut_ptr());
            let v_raw = SendPtr(v.data.as_ptr() as *mut R);
            let caps_ptr = SendPtr(caps.as_mut_ptr());
            let mask_b = &mask_b;
            let alpha = &alpha;
            team.parallel(|tid| {
                // SAFETY: every slice written here lies in this thread's
                // [tb, te) tile shard; ro/ro_at operands are not written
                // concurrently within this sweep.
                scoped(prof, tid, Phase::Blas, || unsafe {
                    let (tb, te) = chunk_range(ntiles, tid, n);
                    for tl in tb..te {
                        for i in 0..nrhs {
                            if !mask_b[i] {
                                continue;
                            }
                            let off = (tl * nrhs + i) * vpt;
                            let ma = -alpha[i];
                            let rt = r_ptr.slice_mut(off, vpt);
                            blas::caxpy_slice(
                                rt,
                                R::from_f64(ma.re),
                                R::from_f64(ma.im),
                                ro_at::<R>(v_raw, off, vpt),
                                vlen,
                            );
                            caps_ptr.slice_mut(tl * nrhs + i, 1)[0] =
                                [0.0, 0.0, blas::norm2_tile(rt, vlen)];
                        }
                    }
                })
            });
        }
        let sred = op.reduce_caps(&caps);
        let (mask_half, mask_c, snorm) = stage_half(&mask_b, &sred, limit, nrhs);
        for i in 0..nrhs {
            // checked before the half-step x update: x is still warm
            if mask_b[i] && !snorm[i].is_finite() {
                return Err(Interrupt::NonFinite { what: "|s|^2", iteration: *iterations });
            }
        }
        *flops += count(&mask_b) * (fl::caxpy_flops(nreal) + fl::norm2_flops(nreal));
        if mask_half.iter().any(|&h| h) {
            // converged at the half step: x += alpha p
            let x_ptr = SendPtr(x.data.as_mut_ptr());
            let p_raw = SendPtr(p.data.as_ptr() as *mut R);
            let mh = &mask_half;
            let alpha_ref = &alpha;
            team.parallel(|tid| {
                // SAFETY: every slice written here lies in this thread's
                // [tb, te) tile shard; ro/ro_at operands are not written
                // concurrently within this sweep.
                scoped(prof, tid, Phase::Blas, || unsafe {
                    let (tb, te) = chunk_range(ntiles, tid, n);
                    for tl in tb..te {
                        for i in 0..nrhs {
                            if !mh[i] {
                                continue;
                            }
                            let off = (tl * nrhs + i) * vpt;
                            blas::caxpy_slice(
                                x_ptr.slice_mut(off, vpt),
                                R::from_f64(alpha_ref[i].re),
                                R::from_f64(alpha_ref[i].im),
                                ro_at::<R>(p_raw, off, vpt),
                                vlen,
                            );
                        }
                    }
                })
            });
            *flops += count(&mask_half) * fl::caxpy_flops(nreal);
            for i in 0..nrhs {
                if mask_half[i] {
                    rr[i] = snorm[i];
                    let rel = (rr[i] / bnorm2[i]).sqrt();
                    stats[i].history.push(rel);
                    stats[i].rel_residual = rel;
                    stats[i].iterations = *iterations + 1;
                    stats[i].converged = true;
                    active[i] = false;
                }
            }
        }
        if !mask_c.iter().any(|&a| a) {
            *iterations += 1;
            continue;
        }
        // sweep 3: t = A s (s lives in r) with <s, t> / |t|² capture
        op.apply_multi(team, &mut t, &r, &mask_c, Some((&r, &mut caps)));
        let tred = op.reduce_caps(&caps);
        let (mask_d, omega) = stage_omega(&mask_c, &tred, nrhs);
        for i in 0..nrhs {
            // checked before the combined x update of sweeps 4/5
            if mask_d[i] && !cfin(omega[i]) {
                return Err(Interrupt::NonFinite { what: "omega", iteration: *iterations });
            }
        }
        *flops += count(&mask_c)
            * (flops_apply + fl::cdot_flops(nreal) + fl::norm2_flops(nreal))
            + flops_shared;
        for i in 0..nrhs {
            if mask_c[i] && !mask_d[i] {
                active[i] = false; // |t|² = 0 breakdown
            }
        }
        if mask_d.iter().any(|&a| a) {
            // sweep 4: x += alpha p + omega s, and
            // sweep 5: r = s - omega t with <rhat, r> / |r|² capture
            {
                let x_ptr = SendPtr(x.data.as_mut_ptr());
                let r_ptr = SendPtr(r.data.as_mut_ptr());
                let p_raw = SendPtr(p.data.as_ptr() as *mut R);
                let t_raw = SendPtr(t.data.as_ptr() as *mut R);
                let rhat_raw = SendPtr(rhat.data.as_ptr() as *mut R);
                let caps_ptr = SendPtr(caps.as_mut_ptr());
                let md = &mask_d;
                let alpha_ref = &alpha;
                let omega_ref = &omega;
                team.parallel(|tid| {
                    // SAFETY: every slice written here lies in this
                    // thread's [tb, te) tile shard; ro/ro_at operands are
                    // not written concurrently within this sweep.
                    scoped(prof, tid, Phase::Blas, || unsafe {
                        let (tb, te) = chunk_range(ntiles, tid, n);
                        for tl in tb..te {
                            for i in 0..nrhs {
                                if !md[i] {
                                    continue;
                                }
                                let off = (tl * nrhs + i) * vpt;
                                blas::caxpy2_slice(
                                    x_ptr.slice_mut(off, vpt),
                                    R::from_f64(alpha_ref[i].re),
                                    R::from_f64(alpha_ref[i].im),
                                    ro_at::<R>(p_raw, off, vpt),
                                    R::from_f64(omega_ref[i].re),
                                    R::from_f64(omega_ref[i].im),
                                    ro_at::<R>(r_ptr, off, vpt),
                                    vlen,
                                );
                                let mo = -omega_ref[i];
                                let rt = r_ptr.slice_mut(off, vpt);
                                blas::caxpy_slice(
                                    rt,
                                    R::from_f64(mo.re),
                                    R::from_f64(mo.im),
                                    ro_at::<R>(t_raw, off, vpt),
                                    vlen,
                                );
                                caps_ptr.slice_mut(tl * nrhs + i, 1)[0] =
                                    blas::cdot_norm2_tile(
                                        ro_at::<R>(rhat_raw, off, vpt),
                                        rt,
                                        vlen,
                                    );
                            }
                        }
                    })
                });
            }
            let rred = op.reduce_caps(&caps);
            let (mask_e, beta, rr_new, rho_new) =
                stage_final(&mask_d, &rred, &rho_iter, &omega, &alpha, limit, nrhs);
            for i in 0..nrhs {
                // x was updated this sweep, but with finite alpha/omega:
                // the restart re-derives r from that warm iterate
                if mask_d[i] && !rr_new[i].is_finite() {
                    return Err(Interrupt::NonFinite { what: "|r|^2", iteration: *iterations });
                }
            }
            *flops += count(&mask_d)
                * (3 * fl::caxpy_flops(nreal) + fl::cdot_flops(nreal) + fl::norm2_flops(nreal));
            for i in 0..nrhs {
                if !mask_d[i] {
                    continue;
                }
                rr[i] = rr_new[i];
                let rel = (rr[i] / bnorm2[i]).sqrt();
                stats[i].history.push(rel);
                stats[i].rel_residual = rel;
                stats[i].iterations = *iterations + 1;
                if rho_iter[i].abs() < 1e-300 || omega[i].abs() < 1e-300 {
                    stats[i].converged = rr[i] <= limit[i];
                    active[i] = false;
                } else if rr[i] <= limit[i] {
                    stats[i].converged = true;
                    active[i] = false;
                } else {
                    rho[i] = rho_new[i];
                }
            }
            for i in 0..nrhs {
                // counted-then-interrupted (the histories above stay):
                // a poisoned rho/beta would corrupt the next direction
                if mask_e[i] && active[i] && (!cfin(rho_new[i]) || !cfin(beta[i])) {
                    *iterations += 1;
                    return Err(Interrupt::NonFinite { what: "beta", iteration: *iterations });
                }
            }
            if mask_e.iter().any(|&a| a) {
                // sweep 6: p = beta (p - omega v) + r
                let p_ptr = SendPtr(p.data.as_mut_ptr());
                let v_raw = SendPtr(v.data.as_ptr() as *mut R);
                let r_raw = SendPtr(r.data.as_ptr() as *mut R);
                let me = &mask_e;
                let beta_ref = &beta;
                let omega_ref = &omega;
                team.parallel(|tid| {
                    // SAFETY: every slice written here lies in this
                    // thread's [tb, te) tile shard; ro/ro_at operands are
                    // not written concurrently within this sweep.
                    scoped(prof, tid, Phase::Blas, || unsafe {
                        let (tb, te) = chunk_range(ntiles, tid, n);
                        for tl in tb..te {
                            for i in 0..nrhs {
                                if !me[i] {
                                    continue;
                                }
                                let off = (tl * nrhs + i) * vpt;
                                let mo = -omega_ref[i];
                                blas::p_update_slice(
                                    p_ptr.slice_mut(off, vpt),
                                    R::from_f64(mo.re),
                                    R::from_f64(mo.im),
                                    ro_at::<R>(v_raw, off, vpt),
                                    R::from_f64(beta_ref[i].re),
                                    R::from_f64(beta_ref[i].im),
                                    ro_at::<R>(r_raw, off, vpt),
                                    vlen,
                                );
                            }
                        }
                    })
                });
                *flops += count(&mask_e)
                    * (fl::caxpy_flops(nreal) + fl::cscale_flops(nreal) + fl::axpy_flops(nreal));
            }
        }
        *iterations += 1;
        let worst = (0..nrhs)
            .filter(|&i| mask[i])
            .map(|i| (rr[i] / bnorm2[i]).sqrt())
            .fold(0.0f64, f64::max);
        history.push(worst);
        if active.iter().any(|&a| a) && stag.stalled(worst) {
            return Err(Interrupt::Stagnation { iteration: *iterations });
        }
    }

    if let Some(err) = op.comm_fault() {
        return Err(Interrupt::Comm { err, iteration: *iterations });
    }
    let done = stats.iter().map(|s| s.iterations).max().unwrap_or(0);
    Ok(BlockSolveStats::finish(
        nrhs,
        done,
        stats.to_vec(),
        *flops,
        BICGSTAB_FUSED_SWEEPS,
        team.nthreads(),
    ))
}
