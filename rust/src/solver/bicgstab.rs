//! BiCGStab for the non-hermitian even-odd operator M-hat.
//!
//! Complex-coefficient variant (the fields are complex; dot products use
//! the sesquilinear inner product). Often converges in ~half the operator
//! applications of CGNR on the same system.
//!
//! The guarded entry point [`bicgstab_guarded`] runs the iteration under
//! the solver health guard. The non-finite checks deliberately precede
//! the `< 1e-300` breakdown tests: `NaN.abs() < 1e-300` is *false*, so
//! without them a poisoned rho/omega would sail straight through the
//! breakdown guards and corrupt the solution update.

use crate::algebra::{Complex, Real};
use crate::coordinator::operator::LinearOperator;
use crate::dslash::flops as fl;
use crate::field::snapshot::FieldSnap;
use crate::field::FermionField;

use super::checkpoint::{Checkpointer, SolverState, FAMILY_BICGSTAB};
use super::fused::BICGSTAB_UNFUSED_SWEEPS;
use super::health::{
    HealthConfig, HealthGuard, Interrupt, SolveError, StagnationTracker,
};
use super::SolveStats;

/// Global sesquilinear dot through the operator's reducer.
fn gdot<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    a: &FermionField<R>,
    b: &FermionField<R>,
) -> Complex {
    let local = a.dot(b);
    Complex::new(op.reduce_sum(local.re), op.reduce_sum(local.im))
}

fn cfinite(c: Complex) -> bool {
    c.re.is_finite() && c.im.is_finite()
}

/// Solve `A x = b` with BiCGStab. `x` holds the initial guess on entry.
///
/// Runs under a default health guard; failures fold into a
/// non-converged [`SolveStats`]. Use [`bicgstab_guarded`] for the typed
/// error.
pub fn bicgstab<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
) -> SolveStats {
    match bicgstab_guarded(op, x, b, tol, maxiter, &HealthConfig::default()) {
        Ok(stats) => stats,
        Err(e) => e.into_stats(BICGSTAB_UNFUSED_SWEEPS, 1),
    }
}

/// BiCGStab under the solver health guard (see [`super::cg_guarded`]
/// for the restart semantics; recoverable events re-enter the iteration
/// from the warm iterate with a fresh shadow residual).
pub fn bicgstab_guarded<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
) -> Result<SolveStats, SolveError> {
    bicgstab_guarded_ckpt(op, x, b, tol, maxiter, health, None, None)
}

/// Cross-iteration BiCGStab state restored on resume. `v` and `t` are
/// recomputed before first read at the iteration boundary, so only the
/// residual, search direction, shadow residual, and the carried
/// `rr`/`rho` scalars are part of the checkpoint.
struct BiCgResume<R: Real> {
    r: FermionField<R>,
    p: FermionField<R>,
    rhat: FermionField<R>,
    rr: f64,
    rho: Complex,
}

/// [`bicgstab_guarded`] with optional checkpointing and resume (the
/// same contract as [`super::cg_guarded_ckpt`]: resumed runs continue
/// bitwise identically from the checkpointed iteration boundary).
#[allow(clippy::too_many_arguments)]
pub fn bicgstab_guarded_ckpt<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    mut ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
) -> Result<SolveStats, SolveError> {
    let mut guard = HealthGuard::new(health);
    let mut history = Vec::new();
    let mut flops = 0u64;
    let mut pack = None;
    if let Some(st) = resume {
        if st.family != FAMILY_BICGSTAB {
            return Err(SolveError::checkpoint(format!(
                "checkpoint family {} is not bicgstab",
                st.family
            )));
        }
        let mut r = b.zeros_like();
        let mut p = b.zeros_like();
        let mut rhat = b.zeros_like();
        st.restore_into("x", &mut x.data).map_err(SolveError::checkpoint)?;
        st.restore_into("r", &mut r.data).map_err(SolveError::checkpoint)?;
        st.restore_into("p", &mut p.data).map_err(SolveError::checkpoint)?;
        st.restore_into("rhat", &mut rhat.data)
            .map_err(SolveError::checkpoint)?;
        if st.scalars.len() < 3 {
            return Err(SolveError::checkpoint("missing bicgstab scalars"));
        }
        let rr = st.scalars[0];
        let rho = Complex::new(st.scalars[1], st.scalars[2]);
        guard.restarts = st.restarts as usize;
        history = st.history.clone();
        flops = st.flops;
        op.restore_fault_cursors(&st.fault_cursors);
        pack = Some(BiCgResume { r, p, rhat, rr, rho });
    }
    let c0 = op.comm_counters();
    let z0 = op.comm_zero_fills();
    let counters = |op: &A| {
        let c1 = op.comm_counters();
        (c1.0 - c0.0, c1.1 - c0.1, op.comm_zero_fills() - z0)
    };
    loop {
        match bicgstab_attempt(
            op,
            x,
            b,
            tol,
            maxiter,
            health,
            &mut history,
            &mut flops,
            guard.restarts,
            ckpt.as_deref_mut(),
            &mut pack,
        ) {
            Ok(mut stats) => {
                if stats.converged && health.drift_tol > 0.0 {
                    let ratio = super::health::drift_ratio(
                        op,
                        x,
                        b,
                        stats.rel_residual,
                        &mut flops,
                    );
                    if !ratio.is_finite() || ratio > health.drift_tol {
                        guard.absorb(
                            Interrupt::Drift { iteration: history.len(), ratio },
                            &history,
                            counters(op),
                        )?;
                        continue;
                    }
                    stats.flops = flops;
                }
                guard.finish(&mut stats, counters(op));
                return Ok(stats);
            }
            Err(int) => {
                guard.absorb(int, &history, counters(op))?;
            }
        }
    }
}

/// One guarded BiCGStab attempt (see [`super::cg`]'s `cg_attempt` for
/// the shared conventions: `history`/`flops` accumulate across
/// attempts, the global iteration number is `history.len()`).
#[allow(clippy::too_many_arguments)]
fn bicgstab_attempt<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    history: &mut Vec<f64>,
    flops: &mut u64,
    restarts: usize,
    mut ckpt: Option<&mut Checkpointer>,
    resume: &mut Option<BiCgResume<R>>,
) -> Result<SolveStats, Interrupt> {
    let finish = |history: &[f64], flops: u64, converged: bool, rel: f64| SolveStats {
        iterations: history.len(),
        converged,
        rel_residual: rel,
        history: history.to_vec(),
        flops,
        sweeps_per_iter: BICGSTAB_UNFUSED_SWEEPS,
        threads: 1,
        knob_sources: None,
        restarts: 0,
        health_events: 0,
        retransmits: 0,
        timeouts: 0,
        zero_fills: 0,
    };
    let resumed = resume.take();
    op.fault_hook(history.len())
        .map_err(|err| Interrupt::Comm { err, iteration: history.len() })?;
    let bnorm2 = op.reduce_sum(b.norm2());
    let nreal = b.data.len() as u64;
    if resumed.is_none() {
        *flops += fl::norm2_flops(nreal);
    }
    if bnorm2 == 0.0 {
        x.fill(R::ZERO);
        return Ok(finish(&[], 0, true, 0.0));
    }
    let limit = tol * tol * bnorm2;

    let mut t = b.zeros_like();
    let mut v = b.zeros_like();
    let (mut r, rhat, mut p, mut rr, mut rho);
    if let Some(rs) = resumed {
        // Checkpoint resume: the restored state reproduces the
        // interrupted run's iteration boundary bit-for-bit.
        r = rs.r;
        p = rs.p;
        rhat = rs.rhat;
        rr = rs.rr;
        rho = rs.rho;
    } else {
        // r = b - A x; a zero initial guess skips the first operator
        // apply. The skip is agreed globally (reduce_sum is collective)
        // so ranks of a distributed operator never mismatch the apply's
        // collectives.
        let x_zero = op.reduce_sum(if x.is_zero() { 0.0 } else { 1.0 }) == 0.0;
        r = b.clone();
        if x_zero {
            rr = bnorm2;
        } else {
            op.apply(&mut t, x);
            r.axpy(-R::ONE, &t);
            rr = op.reduce_sum(r.norm2());
            *flops +=
                op.flops_per_apply() + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
        }
        if !rr.is_finite() {
            // poisoned warm iterate: fall back to a cold restart
            x.fill(R::ZERO);
            return Err(Interrupt::NonFinite {
                what: "initial |r|^2",
                iteration: history.len(),
            });
        }
        rhat = r.clone();
        p = r.clone();
        rho = gdot(op, &rhat, &r);
        *flops += fl::cdot_flops(nreal);
        if !cfinite(rho) {
            return Err(Interrupt::NonFinite {
                what: "rho",
                iteration: history.len(),
            });
        }
    }
    let mut stag = StagnationTracker::new(health.stagnation_window);

    while history.len() < maxiter && rr > limit {
        let iteration = history.len();
        op.fault_hook(iteration)
            .map_err(|err| Interrupt::Comm { err, iteration })?;
        if let Some(ck) = ckpt.as_deref_mut() {
            if ck.due(iteration as u64) {
                let mut st = SolverState::new(FAMILY_BICGSTAB, iteration as u64);
                st.restarts = restarts as u64;
                st.flops = *flops;
                st.scalars = vec![rr, rho.re, rho.im];
                st.history = history.clone();
                st.fields = vec![
                    FieldSnap::of_fermion("x", x),
                    FieldSnap::of_fermion("r", &r),
                    FieldSnap::of_fermion("p", &p),
                    FieldSnap::of_fermion("rhat", &rhat),
                ];
                ck.save_lin(st, op);
            }
        }
        // v = A p
        op.apply(&mut v, &p);
        *flops += op.flops_per_apply() + fl::cdot_flops(nreal);
        let rhat_v = gdot(op, &rhat, &v);
        if !cfinite(rhat_v) {
            return Err(Interrupt::NonFinite { what: "rhat·v", iteration });
        }
        if rhat_v.abs() < 1e-300 {
            break; // breakdown
        }
        let alpha = rho * rhat_v.conj().scale(1.0 / rhat_v.norm2());
        if !cfinite(alpha) {
            return Err(Interrupt::NonFinite { what: "alpha", iteration });
        }
        // s = r - alpha v   (reuse r as s)
        r.caxpy(-alpha, &v);
        let snorm = op.reduce_sum(r.norm2());
        *flops += fl::caxpy_flops(nreal) + fl::norm2_flops(nreal);
        if !snorm.is_finite() {
            // x has not been touched this iteration — still warm
            return Err(Interrupt::NonFinite { what: "|s|^2", iteration });
        }
        if snorm <= limit {
            x.caxpy(alpha, &p);
            *flops += fl::caxpy_flops(nreal);
            rr = snorm;
            history.push((rr / bnorm2).sqrt());
            break;
        }
        // t = A s
        op.apply(&mut t, &r);
        *flops += op.flops_per_apply() + fl::cdot_flops(nreal) + fl::norm2_flops(nreal);
        let ts = gdot(op, &t, &r);
        let tt = op.reduce_sum(t.norm2());
        if !cfinite(ts) || !tt.is_finite() {
            return Err(Interrupt::NonFinite { what: "t·s / |t|^2", iteration });
        }
        if tt == 0.0 {
            break;
        }
        let omega = ts.scale(1.0 / tt);
        if !cfinite(omega) {
            return Err(Interrupt::NonFinite { what: "omega", iteration });
        }
        // x += alpha p + omega s
        x.caxpy(alpha, &p);
        x.caxpy(omega, &r);
        // r = s - omega t
        r.caxpy(-omega, &t);
        rr = op.reduce_sum(r.norm2());
        *flops += 3 * fl::caxpy_flops(nreal) + fl::norm2_flops(nreal) + fl::cdot_flops(nreal);
        if !rr.is_finite() {
            return Err(Interrupt::NonFinite { what: "|r|^2", iteration });
        }
        let rel = (rr / bnorm2).sqrt();
        history.push(rel);

        let rho_new = gdot(op, &rhat, &r);
        if !cfinite(rho_new) {
            return Err(Interrupt::NonFinite {
                what: "rho",
                iteration: history.len(),
            });
        }
        if rho.abs() < 1e-300 || omega.abs() < 1e-300 {
            break;
        }
        let beta = (rho_new * alpha)
            * (rho * omega).conj().scale(1.0 / (rho * omega).norm2());
        if !cfinite(beta) {
            return Err(Interrupt::NonFinite {
                what: "beta",
                iteration: history.len(),
            });
        }
        // p = r + beta (p - omega v)
        p.caxpy(-omega, &v);
        // p = beta * p + r: do it via scale trick
        cscale(&mut p, beta);
        p.axpy(R::ONE, &r);
        *flops += fl::caxpy_flops(nreal) + fl::cscale_flops(nreal) + fl::axpy_flops(nreal);
        rho = rho_new;
        if rr > limit && stag.stalled(rel) {
            return Err(Interrupt::Stagnation { iteration: history.len() });
        }
    }

    // A transport fault zero-fills halos rather than panicking: surface
    // the recorded fault instead of untrustworthy stats.
    if let Some(err) = op.comm_fault() {
        return Err(Interrupt::Comm { err, iteration: history.len() });
    }
    Ok(finish(history, *flops, rr <= limit, (rr / bnorm2).sqrt()))
}

/// In-place complex scale of a field.
fn cscale<R: Real>(f: &mut FermionField<R>, a: Complex) {
    let layout = f.layout;
    let vlen = layout.vlen();
    let (ar, ai) = (R::from_f64(a.re), R::from_f64(a.im));
    for tile in 0..layout.ntiles() {
        for spin in 0..4 {
            for color in 0..3 {
                let ro = layout.spinor_vec(tile, spin, color, 0);
                let io = layout.spinor_vec(tile, spin, color, 1);
                for l in 0..vlen {
                    let re = f.data[ro + l];
                    let im = f.data[io + l];
                    f.data[ro + l] = ar * re - ai * im;
                    f.data[io + l] = ar * im + ai * re;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::operator::{LinearOperator, NativeMeo};
    use crate::field::GaugeField;
    use crate::lattice::{Geometry, LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn bicgstab_converges_on_meo() {
        let g = geom();
        let mut rng = Rng::seeded(201);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMeo::new(&g, u, 0.12f32);
        let mut x = FermionField::zeros(&g);
        let stats = bicgstab(&mut op, &mut x, &b, 1e-8, 300);
        assert!(stats.converged, "{stats:?}");
        let mut ax = FermionField::zeros(&g);
        op.apply(&mut ax, &x);
        ax.axpy(-1.0, &b);
        let rel = (ax.norm2() / b.norm2()).sqrt();
        assert!(rel < 1e-5, "true residual {rel}");
        assert_eq!(stats.restarts, 0);
        assert_eq!(stats.health_events, 0);
    }

    #[test]
    fn bicgstab_guarded_matches_unguarded_bitwise() {
        let g = geom();
        let mut rng = Rng::seeded(203);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMeo::new(&g, u, 0.12f32);

        let mut x1 = FermionField::zeros(&g);
        let plain = bicgstab(&mut op, &mut x1, &b, 1e-8, 300);
        let mut x2 = FermionField::zeros(&g);
        let strict = bicgstab_guarded(
            &mut op,
            &mut x2,
            &b,
            1e-8,
            300,
            &HealthConfig {
                stagnation_window: 50,
                drift_tol: 1000.0,
                ..Default::default()
            },
        )
        .expect("clean solve");
        assert_eq!(plain.history, strict.history, "guard changed the history");
        assert_eq!(x1.data, x2.data, "guard changed the iterates");
        assert_eq!(strict.restarts, 0);
    }

    #[test]
    fn bicgstab_cheaper_than_cgnr_in_applies() {
        // BiCGStab on M vs CG on M^dag M: compare operator applications
        use crate::coordinator::operator::NativeMdagM;
        use crate::solver::cg;
        let g = geom();
        let mut rng = Rng::seeded(202);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);

        let mut op_m = NativeMeo::new(&g, u.clone(), 0.12f32);
        let mut x1 = FermionField::zeros(&g);
        let s_b = bicgstab(&mut op_m, &mut x1, &b, 1e-8, 300);

        let mut op_n = NativeMdagM::new(&g, u, 0.12f32);
        // CGNR solves M^dag M x = M^dag b
        let mut bp = FermionField::zeros(&g);
        {
            let mut g5b = b.clone();
            g5b.gamma5();
            let mut mg5b = FermionField::zeros(&g);
            op_n.meo().apply(&mut mg5b, &g5b);
            mg5b.gamma5();
            bp = mg5b;
        }
        let mut x2 = FermionField::zeros(&g);
        let s_c = cg(&mut op_n, &mut x2, &bp, 1e-8, 300);

        // both must reach the same solution of M x = b
        let mut d = x1.clone();
        d.axpy(-1.0, &x2);
        let rel = (d.norm2() / x2.norm2()).sqrt();
        assert!(rel < 1e-3, "solutions differ {rel}");
        // and BiCGStab uses fewer M-applications (2/iter vs 4/iter)
        assert!(
            s_b.flops < s_c.flops,
            "bicgstab {} vs cgnr {}",
            s_b.flops,
            s_c.flops
        );
    }
}
