//! Thread-parallel fused solver pipeline.
//!
//! Each Krylov iteration runs as **one** [`Team`] parallel region: the
//! operator's kernel phases and the BLAS-1 sweeps are tile-sharded over
//! the persistent workers, synchronized by the in-region
//! [`crate::coordinator::TeamBarrier`], with reductions accumulated as
//! per-tile f64 partials and combined (in tile order) at the barriers.
//! Relative to the generic [`super::cg`] / [`super::bicgstab`] loops
//! this collapses a CG iteration from 6 full-field memory sweeps
//! (operator, p·Ap dot, two axpy, norm², xpay) to 3 fused passes:
//!
//! 1. `Ap = A p` with the `-kappa²`/gamma5 tails *and* the `p·Ap`
//!    reduction folded into the kernel's store loop;
//! 2. `x += alpha p` ∥ `r -= alpha Ap` ∥ `|r|²` in one pass;
//! 3. `p = beta p + r`.
//!
//! BiCGStab drops from 15 passes to 6 the same way.
//!
//! Because every reduction uses the canonical per-tile grouping of
//! [`crate::field::blas`] and every fused update replicates the
//! elementwise expressions of its two-pass reference, the residual
//! histories are **bitwise identical** to the unfused single-threaded
//! solvers at any thread count — threading changes who computes a tile,
//! never how a sum is associated. The unfused generic solvers remain
//! the reference implementation (and serve operators, like the
//! distributed or PJRT-backed ones, that cannot expose tile phases).

use crate::algebra::{Complex, Real};
use crate::coordinator::operator::FusedSolvable;
use crate::coordinator::profiler::{Phase, Profiler};
use crate::coordinator::team::{chunk_range, SendPtr, Team};
use crate::dslash::flops as fl;
use crate::field::{blas, FermionField};

use super::SolveStats;

/// Time `f` into (tid, phase) when a profiler is attached, else just
/// run it — lets one solver body serve both the bare and the
/// `--profile` paths with zero overhead when `prof` is `None`.
#[inline]
fn scoped<T>(prof: Option<&Profiler>, tid: usize, phase: Phase, f: impl FnOnce() -> T) -> T {
    match prof {
        Some(p) => p.scope(tid, phase, f),
        None => f(),
    }
}

/// Charge each thread its tile-share of the solve's total flops (the
/// fused pipeline shards every sweep by `chunk_range` over tiles, so
/// the share is exact up to the chunk remainder).
fn charge_flops(prof: Option<&Profiler>, n: usize, ntiles: usize, flops: u64) {
    if let Some(p) = prof {
        for tid in 0..n {
            let (tb, te) = chunk_range(ntiles, tid, n);
            p.add_flops(tid, flops * (te - tb) as u64 / ntiles as u64);
        }
    }
}

/// Full-field memory sweeps per fused CG iteration (operator pass with
/// fused dot + combined x/r update + p xpay).
pub const CG_FUSED_SWEEPS: f64 = 3.0;
/// Sweeps per unfused CG iteration (operator, dot, axpy, axpy, norm², xpay).
pub const CG_UNFUSED_SWEEPS: f64 = 6.0;
/// Sweeps per fused BiCGStab iteration.
pub const BICGSTAB_FUSED_SWEEPS: f64 = 6.0;
/// Sweeps per unfused BiCGStab iteration.
pub const BICGSTAB_UNFUSED_SWEEPS: f64 = 15.0;

/// Shared read-only view of a whole field behind a [`SendPtr`].
/// (`pub(crate)`: the block solver's team regions use the same views.)
///
/// # Safety
/// No thread may hold a `&mut` into the same range concurrently.
pub(crate) unsafe fn ro<'a, T>(p: SendPtr<T>, len: usize) -> &'a [T] {
    std::slice::from_raw_parts(p.0 as *const T, len)
}

/// Shared read-only view of the range `[offset, offset + len)` only —
/// used for a thread's own-shard reads so the reference never overlaps
/// the ranges other threads are concurrently writing.
///
/// # Safety
/// No thread may hold a `&mut` into this range concurrently.
pub(crate) unsafe fn ro_at<'a, T>(p: SendPtr<T>, offset: usize, len: usize) -> &'a [T] {
    std::slice::from_raw_parts(p.0.add(offset) as *const T, len)
}

/// Per-iteration outcome, written by tid 0 inside the region and read
/// by the master loop after the region completes (every thread computes
/// the same reductions from the same tile partials, so tid 0's record
/// is what all threads acted on).
#[derive(Clone, Copy, Default)]
struct IterOut {
    /// 0 = full iteration; the other codes mirror the unfused solver's
    /// early exits (see `bicgstab`)
    kind: u8,
    rr: f64,
    rho: Complex,
}

/// Thread-parallel fused CG on the hermitian positive-definite normal
/// operator. Behaves exactly like [`super::cg`] (same signature modulo
/// the team, same convergence criterion, bitwise-identical residual
/// history) but runs each iteration as one parallel region of 3 fused
/// sweeps.
pub fn cg<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
) -> SolveStats {
    cg_profiled(op, team, x, b, tol, maxiter, None)
}

/// [`cg`] with the FAPP-analog profiler attached: kernel sweeps are
/// charged to [`Phase::Bulk`], fused BLAS sweeps to [`Phase::Blas`],
/// in-region waits to [`Phase::Barrier`], and each thread's tile-share
/// of the solve flops to its flop counter. Timing never feeds back
/// into the arithmetic, so the residual history is bitwise identical
/// to the unprofiled solve.
#[allow(clippy::too_many_arguments)]
pub fn cg_profiled<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
) -> SolveStats {
    let flops_apply = op.flops_per_apply();
    let view = op.fused_view();
    let ntiles = view.ntiles();
    let vpt = view.vals_per_tile();
    let vlen = view.vlen();
    let len = view.field_len();
    let n = team.nthreads();
    let nreal = len as u64;

    let bnorm2 = b.norm2();
    let mut flops = fl::norm2_flops(nreal);
    if bnorm2 == 0.0 {
        x.fill(R::ZERO);
        return SolveStats {
            iterations: 0,
            converged: true,
            rel_residual: 0.0,
            history: vec![],
            flops: 0,
            sweeps_per_iter: CG_FUSED_SWEEPS,
            threads: n,
            knob_sources: None,
        };
    }
    let limit = tol * tol * bnorm2;

    let mut r = b.clone();
    let mut ap = b.zeros_like();
    let mut dot_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut rr_partials: Vec<f64> = vec![0.0; ntiles];
    let mut rr;

    if x.is_zero() {
        // zero initial guess: r = b, |r|² = |b|² — no operator apply
        rr = bnorm2;
    } else {
        // one region: ap = A x, then r = b - ap fused with |r|²
        let ap_ptr = SendPtr(ap.data.as_mut_ptr());
        let r_ptr = SendPtr(r.data.as_mut_ptr());
        let x_raw = SendPtr(x.data.as_mut_ptr());
        let rr_ptr = SendPtr(rr_partials.as_mut_ptr());
        team.run(|tid, bar| unsafe {
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(tid, n, bar, ap_ptr, x_raw.0 as *const R, None)
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let (tb, te) = chunk_range(ntiles, tid, n);
            let r_t = r_ptr.slice_mut(tb * vpt, (te - tb) * vpt);
            let ap_s = ro::<R>(ap_ptr, len);
            scoped(prof, tid, Phase::Blas, || {
                blas::axpy_norm2_slice(
                    r_t,
                    -R::ONE,
                    &ap_s[tb * vpt..te * vpt],
                    vlen,
                    rr_ptr.slice_mut(tb, te - tb),
                )
            });
        });
        rr = rr_partials.iter().sum();
        flops += flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
    }

    let mut p = r.clone();
    let mut history = Vec::new();
    let mut iterations = 0;

    let x_ptr = SendPtr(x.data.as_mut_ptr());
    let r_ptr = SendPtr(r.data.as_mut_ptr());
    let p_ptr = SendPtr(p.data.as_mut_ptr());
    let ap_ptr = SendPtr(ap.data.as_mut_ptr());
    let dot_ptr = SendPtr(dot_partials.as_mut_ptr());
    let rr_ptr = SendPtr(rr_partials.as_mut_ptr());

    while iterations < maxiter && rr > limit {
        let rr_iter = rr;
        team.run(|tid, bar| unsafe {
            // sweep 1: ap = A p with fused tails and p·Ap capture
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    ap_ptr,
                    p_ptr.0 as *const R,
                    Some((p_ptr.0 as *const R, dot_ptr)),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            // every thread combines the same partials in tile order,
            // so alpha is identical everywhere (and to the serial run)
            let pap: f64 = ro::<[f64; 3]>(dot_ptr, ntiles).iter().map(|t| t[0]).sum();
            let alpha = rr_iter / pap;
            let (tb, te) = chunk_range(ntiles, tid, n);
            // sweep 2: x += alpha p ; r -= alpha ap ; per-tile |r|²
            scoped(prof, tid, Phase::Blas, || {
                blas::cg_update_slice(
                    x_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    ro_at::<R>(p_ptr, tb * vpt, (te - tb) * vpt),
                    ro_at::<R>(ap_ptr, tb * vpt, (te - tb) * vpt),
                    R::from_f64(alpha),
                    R::from_f64(-alpha),
                    vlen,
                    rr_ptr.slice_mut(tb, te - tb),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let rr_new: f64 = ro::<f64>(rr_ptr, ntiles).iter().sum();
            let beta = R::from_f64(rr_new / rr_iter);
            // sweep 3: p = beta p + r
            scoped(prof, tid, Phase::Blas, || {
                blas::xpay_slice(
                    p_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    beta,
                    ro_at::<R>(r_ptr, tb * vpt, (te - tb) * vpt),
                )
            });
        });
        rr = rr_partials.iter().sum();
        flops += flops_apply
            + fl::dot_re_flops(nreal)
            + 2 * fl::axpy_flops(nreal)
            + fl::norm2_flops(nreal)
            + fl::xpay_flops(nreal);
        iterations += 1;
        history.push((rr / bnorm2).sqrt());
    }

    charge_flops(prof, n, ntiles, flops);
    SolveStats {
        iterations,
        converged: rr <= limit,
        rel_residual: (rr / bnorm2).sqrt(),
        history,
        flops,
        sweeps_per_iter: CG_FUSED_SWEEPS,
        threads: n,
        knob_sources: None,
    }
}

/// Thread-parallel fused BiCGStab on the non-hermitian M-hat. Same
/// algorithm, breakdown handling and (bitwise) residual history as
/// [`super::bicgstab`], in 6 fused sweeps per iteration on the team.
pub fn bicgstab<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
) -> SolveStats {
    bicgstab_profiled(op, team, x, b, tol, maxiter, None)
}

/// [`bicgstab`] with the profiler attached — same phase charging rules
/// as [`cg_profiled`], same bitwise-unchanged numerics.
#[allow(clippy::too_many_arguments)]
pub fn bicgstab_profiled<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
) -> SolveStats {
    let flops_apply = op.flops_per_apply();
    let view = op.fused_view();
    let ntiles = view.ntiles();
    let vpt = view.vals_per_tile();
    let vlen = view.vlen();
    let len = view.field_len();
    let n = team.nthreads();
    let nreal = len as u64;

    let bnorm2 = b.norm2();
    let mut flops = fl::norm2_flops(nreal);
    if bnorm2 == 0.0 {
        x.fill(R::ZERO);
        return SolveStats {
            iterations: 0,
            converged: true,
            rel_residual: 0.0,
            history: vec![],
            flops: 0,
            sweeps_per_iter: BICGSTAB_FUSED_SWEEPS,
            threads: n,
            knob_sources: None,
        };
    }
    let limit = tol * tol * bnorm2;

    let mut r = b.clone();
    let mut t = b.zeros_like();
    let mut rr;
    let mut rr_partials: Vec<f64> = vec![0.0; ntiles];

    if x.is_zero() {
        rr = bnorm2;
    } else {
        let t_ptr = SendPtr(t.data.as_mut_ptr());
        let r_ptr = SendPtr(r.data.as_mut_ptr());
        let x_raw = SendPtr(x.data.as_mut_ptr());
        let rr_ptr = SendPtr(rr_partials.as_mut_ptr());
        team.run(|tid, bar| unsafe {
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(tid, n, bar, t_ptr, x_raw.0 as *const R, None)
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let (tb, te) = chunk_range(ntiles, tid, n);
            scoped(prof, tid, Phase::Blas, || {
                blas::axpy_norm2_slice(
                    r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    -R::ONE,
                    ro_at::<R>(t_ptr, tb * vpt, (te - tb) * vpt),
                    vlen,
                    rr_ptr.slice_mut(tb, te - tb),
                )
            });
        });
        rr = rr_partials.iter().sum();
        flops += flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
    }

    let rhat = r.clone();
    let mut p = r.clone();
    let mut v = b.zeros_like();
    // rho = <rhat, r> = |r|² at start (rhat == r), but compute it like
    // the unfused solver does so the value is grouping-identical
    let mut rho = rhat.dot(&r);
    flops += fl::cdot_flops(nreal);
    let mut history = Vec::new();
    let mut iterations = 0;

    let mut v_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut s_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut t_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut r_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut out = IterOut::default();

    let x_ptr = SendPtr(x.data.as_mut_ptr());
    let r_ptr = SendPtr(r.data.as_mut_ptr());
    let p_ptr = SendPtr(p.data.as_mut_ptr());
    let v_ptr = SendPtr(v.data.as_mut_ptr());
    let t_ptr = SendPtr(t.data.as_mut_ptr());
    let rhat_raw = SendPtr(rhat.data.as_ptr() as *mut R);
    let vp_ptr = SendPtr(v_partials.as_mut_ptr());
    let sp_ptr = SendPtr(s_partials.as_mut_ptr());
    let tp_ptr = SendPtr(t_partials.as_mut_ptr());
    let rp_ptr = SendPtr(r_partials.as_mut_ptr());
    let out_ptr = SendPtr(&mut out as *mut IterOut);

    while iterations < maxiter && rr > limit {
        let rho_c = rho;
        team.run(|tid, bar| unsafe {
            let (tb, te) = chunk_range(ntiles, tid, n);
            let record = |o: IterOut| {
                if tid == 0 {
                    // master-thread-only write; read after the region
                    unsafe { *out_ptr.0 = o };
                }
            };
            // sweep 1: v = A p with fused <rhat, v> capture
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    v_ptr,
                    p_ptr.0 as *const R,
                    Some((rhat_raw.0 as *const R, vp_ptr)),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let vp = ro::<[f64; 3]>(vp_ptr, ntiles);
            let rhat_v = Complex::new(
                vp.iter().map(|t| t[0]).sum(),
                vp.iter().map(|t| t[1]).sum(),
            );
            if rhat_v.abs() < 1e-300 {
                record(IterOut { kind: 1, rr: 0.0, rho: rho_c });
                return; // breakdown (matches the unfused solver)
            }
            let alpha = rho_c * rhat_v.conj().scale(1.0 / rhat_v.norm2());
            let ma = -alpha;
            // sweep 2: s = r - alpha v (in place in r) with |s|² capture
            scoped(prof, tid, Phase::Blas, || {
                blas::caxpy_capture_slice(
                    r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(ma.re),
                    R::from_f64(ma.im),
                    ro_at::<R>(v_ptr, tb * vpt, (te - tb) * vpt),
                    None,
                    vlen,
                    sp_ptr.slice_mut(tb, te - tb),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let snorm: f64 =
                ro::<[f64; 3]>(sp_ptr, ntiles).iter().map(|t| t[2]).sum();
            if snorm <= limit {
                // converged at the half step: x += alpha p and stop
                scoped(prof, tid, Phase::Blas, || {
                    blas::caxpy_slice(
                        x_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                        R::from_f64(alpha.re),
                        R::from_f64(alpha.im),
                        ro_at::<R>(p_ptr, tb * vpt, (te - tb) * vpt),
                        vlen,
                    )
                });
                record(IterOut { kind: 2, rr: snorm, rho: rho_c });
                return;
            }
            // sweep 3: t = A s with fused <s, t> and |t|² capture
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    t_ptr,
                    r_ptr.0 as *const R,
                    Some((r_ptr.0 as *const R, tp_ptr)),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let tp = ro::<[f64; 3]>(tp_ptr, ntiles);
            // the capture conjugates s; ts = <t, s> conjugates t, so
            // flip the imaginary part (exact, hence bit-identical)
            let ts = Complex::new(
                tp.iter().map(|t| t[0]).sum(),
                -tp.iter().map(|t| t[1]).sum::<f64>(),
            );
            let tt: f64 = tp.iter().map(|t| t[2]).sum();
            if tt == 0.0 {
                record(IterOut { kind: 3, rr: 0.0, rho: rho_c });
                return; // breakdown
            }
            let omega = ts.scale(1.0 / tt);
            // sweep 4: x += alpha p + omega s (s lives in r)
            scoped(prof, tid, Phase::Blas, || {
                blas::caxpy2_slice(
                    x_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(alpha.re),
                    R::from_f64(alpha.im),
                    ro_at::<R>(p_ptr, tb * vpt, (te - tb) * vpt),
                    R::from_f64(omega.re),
                    R::from_f64(omega.im),
                    ro_at::<R>(r_ptr, tb * vpt, (te - tb) * vpt),
                    vlen,
                )
            });
            let mo = -omega;
            // sweep 5: r = s - omega t with <rhat, r> and |r|² capture
            scoped(prof, tid, Phase::Blas, || {
                blas::caxpy_capture_slice(
                    r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(mo.re),
                    R::from_f64(mo.im),
                    ro_at::<R>(t_ptr, tb * vpt, (te - tb) * vpt),
                    Some(ro_at::<R>(rhat_raw, tb * vpt, (te - tb) * vpt)),
                    vlen,
                    rp_ptr.slice_mut(tb, te - tb),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let rp = ro::<[f64; 3]>(rp_ptr, ntiles);
            let rr_new: f64 = rp.iter().map(|t| t[2]).sum();
            let rho_new = Complex::new(
                rp.iter().map(|t| t[0]).sum(),
                rp.iter().map(|t| t[1]).sum(),
            );
            if rho_c.abs() < 1e-300 || omega.abs() < 1e-300 {
                record(IterOut { kind: 4, rr: rr_new, rho: rho_new });
                return; // breakdown after the updates, like unfused
            }
            let beta = (rho_new * alpha)
                * (rho_c * omega).conj().scale(1.0 / (rho_c * omega).norm2());
            // sweep 6: p = beta (p - omega v) + r
            scoped(prof, tid, Phase::Blas, || {
                blas::p_update_slice(
                    p_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(mo.re),
                    R::from_f64(mo.im),
                    ro_at::<R>(v_ptr, tb * vpt, (te - tb) * vpt),
                    R::from_f64(beta.re),
                    R::from_f64(beta.im),
                    ro_at::<R>(r_ptr, tb * vpt, (te - tb) * vpt),
                    vlen,
                )
            });
            record(IterOut { kind: 0, rr: rr_new, rho: rho_new });
        });

        // master: act on tid 0's record (all threads computed the same)
        match out.kind {
            1 => {
                flops += flops_apply + fl::cdot_flops(nreal);
                break;
            }
            2 => {
                flops += flops_apply
                    + fl::cdot_flops(nreal)
                    + fl::caxpy_flops(nreal)
                    + fl::norm2_flops(nreal)
                    + fl::caxpy_flops(nreal);
                rr = out.rr;
                iterations += 1;
                history.push((rr / bnorm2).sqrt());
                break;
            }
            3 => {
                flops += 2 * flops_apply
                    + 2 * fl::cdot_flops(nreal)
                    + fl::caxpy_flops(nreal)
                    + 2 * fl::norm2_flops(nreal);
                break;
            }
            kind => {
                // full iteration (kind 0) or post-update breakdown (4):
                // norm² sweeps are |s|², |t|² and the final |r|²
                flops += 2 * flops_apply
                    + 3 * fl::cdot_flops(nreal)
                    + 4 * fl::caxpy_flops(nreal)
                    + 3 * fl::norm2_flops(nreal);
                rr = out.rr;
                iterations += 1;
                history.push((rr / bnorm2).sqrt());
                if kind == 4 {
                    break;
                }
                rho = out.rho;
                flops +=
                    fl::caxpy_flops(nreal) + fl::cscale_flops(nreal) + fl::axpy_flops(nreal);
            }
        }
    }

    charge_flops(prof, n, ntiles, flops);
    SolveStats {
        iterations,
        converged: rr <= limit,
        rel_residual: (rr / bnorm2).sqrt(),
        history,
        flops,
        sweeps_per_iter: BICGSTAB_FUSED_SWEEPS,
        threads: n,
        knob_sources: None,
    }
}
