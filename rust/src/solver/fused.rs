//! Thread-parallel fused solver pipeline.
//!
//! Each Krylov iteration runs as **one** [`Team`] parallel region: the
//! operator's kernel phases and the BLAS-1 sweeps are tile-sharded over
//! the persistent workers, synchronized by the in-region
//! [`crate::coordinator::TeamBarrier`], with reductions accumulated as
//! per-tile f64 partials and combined (in tile order) at the barriers.
//! Relative to the generic [`super::cg`] / [`super::bicgstab`] loops
//! this collapses a CG iteration from 6 full-field memory sweeps
//! (operator, p·Ap dot, two axpy, norm², xpay) to 3 fused passes:
//!
//! 1. `Ap = A p` with the `-kappa²`/gamma5 tails *and* the `p·Ap`
//!    reduction folded into the kernel's store loop;
//! 2. `x += alpha p` ∥ `r -= alpha Ap` ∥ `|r|²` in one pass;
//! 3. `p = beta p + r`.
//!
//! BiCGStab drops from 15 passes to 6 the same way.
//!
//! Because every reduction uses the canonical per-tile grouping of
//! [`crate::field::blas`] and every fused update replicates the
//! elementwise expressions of its two-pass reference, the residual
//! histories are **bitwise identical** to the unfused single-threaded
//! solvers at any thread count — threading changes who computes a tile,
//! never how a sum is associated. The unfused generic solvers remain
//! the reference implementation (and serve operators, like the
//! distributed or PJRT-backed ones, that cannot expose tile phases).
//!
//! Health guard: non-finite iteration scalars are detected *inside* the
//! parallel region (every thread combines the same partials, so every
//! thread takes the same early-exit branch and the barriers stay
//! matched) and recorded like the breakdown codes; the master loop
//! surfaces them as interrupts and the guarded wrappers restart from
//! the warm iterate, exactly as the unfused solvers do.

use crate::algebra::{Complex, Real};
use crate::coordinator::operator::FusedSolvable;
use crate::coordinator::profiler::{Phase, Profiler};
use crate::coordinator::team::{chunk_range, SendPtr, Team};
use crate::dslash::flops as fl;
use crate::field::snapshot::FieldSnap;
use crate::field::{blas, FermionField};

use super::checkpoint::{
    Checkpointer, SolverState, FAMILY_FUSED_BICGSTAB, FAMILY_FUSED_CG,
};
use super::health::{
    HealthConfig, HealthGuard, Interrupt, SolveError, StagnationTracker,
};
use super::SolveStats;

/// Time `f` into (tid, phase) when a profiler is attached, else just
/// run it — lets one solver body serve both the bare and the
/// `--profile` paths with zero overhead when `prof` is `None`.
#[inline]
pub(crate) fn scoped<T>(
    prof: Option<&Profiler>,
    tid: usize,
    phase: Phase,
    f: impl FnOnce() -> T,
) -> T {
    match prof {
        Some(p) => p.scope(tid, phase, f),
        None => f(),
    }
}

/// Charge each thread its tile-share of the solve's total flops (the
/// fused pipeline shards every sweep by `chunk_range` over tiles, so
/// the share is exact up to the chunk remainder).
pub(crate) fn charge_flops(prof: Option<&Profiler>, n: usize, ntiles: usize, flops: u64) {
    if let Some(p) = prof {
        for tid in 0..n {
            let (tb, te) = chunk_range(ntiles, tid, n);
            p.add_flops(tid, flops * (te - tb) as u64 / ntiles as u64);
        }
    }
}

/// Full-field memory sweeps per fused CG iteration (operator pass with
/// fused dot + combined x/r update + p xpay).
pub const CG_FUSED_SWEEPS: f64 = 3.0;
/// Sweeps per unfused CG iteration (operator, dot, axpy, axpy, norm², xpay).
pub const CG_UNFUSED_SWEEPS: f64 = 6.0;
/// Sweeps per fused BiCGStab iteration.
pub const BICGSTAB_FUSED_SWEEPS: f64 = 6.0;
/// Sweeps per unfused BiCGStab iteration.
pub const BICGSTAB_UNFUSED_SWEEPS: f64 = 15.0;

/// Shared read-only view of a whole field behind a [`SendPtr`].
/// (`pub(crate)`: the block solver's team regions use the same views.)
///
/// # Safety
/// No thread may hold a `&mut` into the same range concurrently.
pub(crate) unsafe fn ro<'a, T>(p: SendPtr<T>, len: usize) -> &'a [T] {
    std::slice::from_raw_parts(p.0 as *const T, len)
}

/// Shared read-only view of the range `[offset, offset + len)` only —
/// used for a thread's own-shard reads so the reference never overlaps
/// the ranges other threads are concurrently writing.
///
/// # Safety
/// No thread may hold a `&mut` into this range concurrently.
pub(crate) unsafe fn ro_at<'a, T>(p: SendPtr<T>, offset: usize, len: usize) -> &'a [T] {
    std::slice::from_raw_parts(p.0.add(offset) as *const T, len)
}

/// Per-iteration outcome, written by tid 0 inside the region and read
/// by the master loop after the region completes (every thread computes
/// the same reductions from the same tile partials, so tid 0's record
/// is what all threads acted on).
#[derive(Clone, Copy, Default)]
struct IterOut {
    /// 0 = full iteration; 1-4 mirror the unfused solver's breakdown
    /// exits (see `bicgstab`); 5 = non-finite scalar *before* any
    /// update (solution iterate untouched); 6 = non-finite after the
    /// updates (iteration not counted); 7 = iteration complete but the
    /// next direction is poisoned (counted, then interrupted)
    kind: u8,
    rr: f64,
    rho: Complex,
    /// which scalar went non-finite (kinds 5-7)
    what: &'static str,
}

/// Thread-parallel fused CG on the hermitian positive-definite normal
/// operator. Behaves exactly like [`super::cg`] (same signature modulo
/// the team, same convergence criterion, bitwise-identical residual
/// history) but runs each iteration as one parallel region of 3 fused
/// sweeps.
pub fn cg<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
) -> SolveStats {
    cg_profiled(op, team, x, b, tol, maxiter, None)
}

/// [`cg`] with the FAPP-analog profiler attached: kernel sweeps are
/// charged to [`Phase::Bulk`], fused BLAS sweeps to [`Phase::Blas`],
/// in-region waits to [`Phase::Barrier`], and each thread's tile-share
/// of the solve flops to its flop counter. Timing never feeds back
/// into the arithmetic, so the residual history is bitwise identical
/// to the unprofiled solve.
#[allow(clippy::too_many_arguments)]
pub fn cg_profiled<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
) -> SolveStats {
    match cg_guarded(op, team, x, b, tol, maxiter, prof, &HealthConfig::default()) {
        Ok(stats) => stats,
        Err(e) => e.into_stats(CG_FUSED_SWEEPS, 1),
    }
}

/// Fused CG under the solver health guard (see [`super::cg_guarded`]
/// for the restart semantics).
#[allow(clippy::too_many_arguments)]
pub fn cg_guarded<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
    health: &HealthConfig,
) -> Result<SolveStats, SolveError> {
    cg_guarded_ckpt(op, team, x, b, tol, maxiter, prof, health, None, None)
}

/// Cross-iteration fused-CG state restored from a checkpoint. The
/// fused pipeline shares state shape with [`super::cg`] (x, r, p, rr):
/// the same iteration-boundary contract makes fused checkpoints
/// resumable bitwise.
struct CgResume<R: Real> {
    r: FermionField<R>,
    p: FermionField<R>,
    rr: f64,
}

/// [`cg_guarded`] with a checkpoint sink and/or resume state (see
/// [`super::cg_guarded_ckpt`] for the bitwise-resume contract).
#[allow(clippy::too_many_arguments)]
pub fn cg_guarded_ckpt<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
    health: &HealthConfig,
    mut ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
) -> Result<SolveStats, SolveError> {
    let mut guard = HealthGuard::new(health);
    let mut history = Vec::new();
    let mut flops = 0u64;
    let mut pack = None;
    if let Some(st) = resume {
        if st.family != FAMILY_FUSED_CG {
            return Err(SolveError::checkpoint(format!(
                "checkpoint holds family tag {}, not fused cg",
                st.family
            )));
        }
        st.restore_into("x", &mut x.data).map_err(SolveError::checkpoint)?;
        let mut r = b.zeros_like();
        st.restore_into("r", &mut r.data).map_err(SolveError::checkpoint)?;
        let mut p = b.zeros_like();
        st.restore_into("p", &mut p.data).map_err(SolveError::checkpoint)?;
        let rr = *st
            .scalars
            .first()
            .ok_or_else(|| SolveError::checkpoint("missing rr scalar"))?;
        guard.restarts = st.restarts as usize;
        history = st.history.clone();
        flops = st.flops;
        op.restore_fault_cursors(&st.fault_cursors);
        pack = Some(CgResume { r, p, rr });
    }
    let c0 = op.comm_counters();
    let z0 = op.comm_zero_fills();
    let counters = |op: &A| {
        let c1 = op.comm_counters();
        (c1.0 - c0.0, c1.1 - c0.1, op.comm_zero_fills() - z0)
    };
    let ntiles = op.fused_view().ntiles();
    let n = team.nthreads();
    // flops already charged-and-discarded by restarts: the profiler's
    // per-thread counters only ever see the surviving attempt's share
    // (stats.flops stays cumulative across attempts)
    let mut flops_at_restart = 0u64;
    loop {
        match cg_attempt(
            op,
            team,
            x,
            b,
            tol,
            maxiter,
            prof,
            health,
            &mut history,
            &mut flops,
            guard.restarts,
            ckpt.as_deref_mut(),
            &mut pack,
        ) {
            Ok(mut stats) => {
                if stats.converged && health.drift_tol > 0.0 {
                    let ratio = super::health::drift_ratio(
                        op,
                        x,
                        b,
                        stats.rel_residual,
                        &mut flops,
                    );
                    if !ratio.is_finite() || ratio > health.drift_tol {
                        guard.absorb(
                            Interrupt::Drift { iteration: history.len(), ratio },
                            &history,
                            counters(op),
                        )?;
                        if let Some(p) = prof {
                            p.restart_reset();
                        }
                        flops_at_restart = flops;
                        continue;
                    }
                    stats.flops = flops;
                }
                guard.finish(&mut stats, counters(op));
                charge_flops(prof, n, ntiles, flops - flops_at_restart);
                return Ok(stats);
            }
            Err(int) => {
                guard.absorb(int, &history, counters(op))?;
                if let Some(p) = prof {
                    p.restart_reset();
                }
                flops_at_restart = flops;
            }
        }
    }
}

/// One guarded fused-CG attempt (`history`/`flops` accumulate across
/// attempts, the global iteration number is `history.len()`).
#[allow(clippy::too_many_arguments)]
fn cg_attempt<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
    health: &HealthConfig,
    history: &mut Vec<f64>,
    flops: &mut u64,
    restarts: usize,
    mut ckpt: Option<&mut Checkpointer>,
    resume: &mut Option<CgResume<R>>,
) -> Result<SolveStats, Interrupt> {
    let flops_apply = op.flops_per_apply();
    let view = op.fused_view();
    let ntiles = view.ntiles();
    let vpt = view.vals_per_tile();
    let vlen = view.vlen();
    let len = view.field_len();
    let n = team.nthreads();
    let nreal = len as u64;
    let finish = |history: &[f64], flops: u64, converged: bool, rel: f64| SolveStats {
        iterations: history.len(),
        converged,
        rel_residual: rel,
        history: history.to_vec(),
        flops,
        sweeps_per_iter: CG_FUSED_SWEEPS,
        threads: n,
        knob_sources: None,
        restarts: 0,
        health_events: 0,
        retransmits: 0,
        timeouts: 0,
        zero_fills: 0,
    };

    let resumed = resume.take();
    op.fault_hook(history.len())
        .map_err(|err| Interrupt::Comm { err, iteration: history.len() })?;
    let bnorm2 = b.norm2();
    if resumed.is_none() {
        *flops += fl::norm2_flops(nreal);
    }
    if bnorm2 == 0.0 {
        x.fill(R::ZERO);
        return Ok(finish(&[], 0, true, 0.0));
    }
    let limit = tol * tol * bnorm2;

    let mut ap = b.zeros_like();
    let mut dot_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut rr_partials: Vec<f64> = vec![0.0; ntiles];
    let (mut r, mut p, mut rr);

    if let Some(rs) = resumed {
        // checkpoint resume: restored state reproduces the interrupted
        // run's iteration boundary bit-for-bit
        r = rs.r;
        p = rs.p;
        rr = rs.rr;
    } else {
        r = b.clone();
        if x.is_zero() {
            // zero initial guess: r = b, |r|² = |b|² — no operator apply
            rr = bnorm2;
        } else {
            // one region: ap = A x, then r = b - ap fused with |r|²
            let ap_ptr = SendPtr(ap.data.as_mut_ptr());
            let r_ptr = SendPtr(r.data.as_mut_ptr());
            let x_raw = SendPtr(x.data.as_mut_ptr());
            let rr_ptr = SendPtr(rr_partials.as_mut_ptr());
            // SAFETY: all raw access in this region is sharded per tid
            // (chunk_range tile shards / apply_team); shared partials and the
            // IterOut slot are read only after a barrier (or region end)
            // publishes the writes.
            team.run(|tid, bar| unsafe {
                scoped(prof, tid, Phase::Bulk, || {
                    view.apply_team(tid, n, bar, ap_ptr, x_raw.0 as *const R, None)
                });
                scoped(prof, tid, Phase::Barrier, || bar.wait());
                let (tb, te) = chunk_range(ntiles, tid, n);
                let r_t = r_ptr.slice_mut(tb * vpt, (te - tb) * vpt);
                let ap_s = ro::<R>(ap_ptr, len);
                scoped(prof, tid, Phase::Blas, || {
                    blas::axpy_norm2_slice(
                        r_t,
                        -R::ONE,
                        &ap_s[tb * vpt..te * vpt],
                        vlen,
                        rr_ptr.slice_mut(tb, te - tb),
                    )
                });
            });
            rr = blas::reduce_partials(&rr_partials);
            *flops += flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
        }
        if !rr.is_finite() {
            // poisoned warm iterate: fall back to a cold restart
            x.fill(R::ZERO);
            return Err(Interrupt::NonFinite {
                what: "initial |r|^2",
                iteration: history.len(),
            });
        }
        p = r.clone();
    }
    let mut out = IterOut::default();
    let mut stag = StagnationTracker::new(health.stagnation_window);

    let x_ptr = SendPtr(x.data.as_mut_ptr());
    let r_ptr = SendPtr(r.data.as_mut_ptr());
    let p_ptr = SendPtr(p.data.as_mut_ptr());
    let ap_ptr = SendPtr(ap.data.as_mut_ptr());
    let dot_ptr = SendPtr(dot_partials.as_mut_ptr());
    let rr_ptr = SendPtr(rr_partials.as_mut_ptr());
    let out_ptr = SendPtr(&mut out as *mut IterOut);

    while history.len() < maxiter && rr > limit {
        let iteration = history.len();
        if let Some(p) = prof {
            p.set_iter(iteration);
        }
        op.fault_hook(iteration)
            .map_err(|err| Interrupt::Comm { err, iteration })?;
        if let Some(ck) = ckpt.as_deref_mut() {
            if ck.due(iteration as u64) {
                let mut st = SolverState::new(FAMILY_FUSED_CG, iteration as u64);
                st.restarts = restarts as u64;
                st.flops = *flops;
                st.scalars = vec![rr];
                st.history = history.clone();
                st.fields = vec![
                    FieldSnap::of_fermion("x", x),
                    FieldSnap::of_fermion("r", &r),
                    FieldSnap::of_fermion("p", &p),
                ];
                scoped(prof, 0, Phase::Checkpoint, || ck.save_lin(st, op));
            }
        }
        let rr_iter = rr;
        // SAFETY: all raw access in this region is sharded per tid
        // (chunk_range tile shards / apply_team); shared partials and the
        // IterOut slot are read only after a barrier (or region end)
        // publishes the writes.
        team.run(|tid, bar| unsafe {
            let record = |o: IterOut| {
                if tid == 0 {
                    // SAFETY: master-thread-only write, no concurrent
                    // access; the main loop reads it after the region.
                    unsafe { *out_ptr.0 = o };
                }
            };
            // sweep 1: ap = A p with fused tails and p·Ap capture
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    ap_ptr,
                    p_ptr.0 as *const R,
                    Some((p_ptr.0 as *const R, dot_ptr)),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            // every thread combines the same partials in tile order,
            // so alpha is identical everywhere (and to the serial run)
            let pap: f64 = ro::<[f64; 3]>(dot_ptr, ntiles).iter().map(|t| t[0]).sum();
            let alpha = rr_iter / pap;
            if !pap.is_finite() || !alpha.is_finite() {
                // uniform early exit on every thread *before* any
                // update: x stays warm for the guard's restart
                record(IterOut { kind: 5, rr: rr_iter, rho: Complex::default(), what: "pAp" });
                return;
            }
            let (tb, te) = chunk_range(ntiles, tid, n);
            // sweep 2: x += alpha p ; r -= alpha ap ; per-tile |r|²
            scoped(prof, tid, Phase::Blas, || {
                blas::cg_update_slice(
                    x_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    ro_at::<R>(p_ptr, tb * vpt, (te - tb) * vpt),
                    ro_at::<R>(ap_ptr, tb * vpt, (te - tb) * vpt),
                    R::from_f64(alpha),
                    R::from_f64(-alpha),
                    vlen,
                    rr_ptr.slice_mut(tb, te - tb),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let rr_new = blas::reduce_partials(ro::<f64>(rr_ptr, ntiles));
            let beta = R::from_f64(rr_new / rr_iter);
            // sweep 3: p = beta p + r
            scoped(prof, tid, Phase::Blas, || {
                blas::xpay_slice(
                    p_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    beta,
                    ro_at::<R>(r_ptr, tb * vpt, (te - tb) * vpt),
                )
            });
            record(IterOut { kind: 0, rr: rr_new, rho: Complex::default(), what: "" });
        });
        if out.kind == 5 {
            return Err(Interrupt::NonFinite { what: out.what, iteration });
        }
        rr = blas::reduce_partials(&rr_partials);
        *flops += flops_apply
            + fl::dot_re_flops(nreal)
            + 2 * fl::axpy_flops(nreal)
            + fl::norm2_flops(nreal)
            + fl::xpay_flops(nreal);
        if !rr.is_finite() {
            return Err(Interrupt::NonFinite { what: "|r|^2", iteration });
        }
        let rel = (rr / bnorm2).sqrt();
        history.push(rel);
        if rr > limit && stag.stalled(rel) {
            return Err(Interrupt::Stagnation { iteration: history.len() });
        }
    }

    if let Some(err) = op.comm_fault() {
        return Err(Interrupt::Comm { err, iteration: history.len() });
    }
    Ok(finish(history, *flops, rr <= limit, (rr / bnorm2).sqrt()))
}

/// Thread-parallel fused BiCGStab on the non-hermitian M-hat. Same
/// algorithm, breakdown handling and (bitwise) residual history as
/// [`super::bicgstab`], in 6 fused sweeps per iteration on the team.
pub fn bicgstab<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
) -> SolveStats {
    bicgstab_profiled(op, team, x, b, tol, maxiter, None)
}

/// [`bicgstab`] with the profiler attached — same phase charging rules
/// as [`cg_profiled`], same bitwise-unchanged numerics.
#[allow(clippy::too_many_arguments)]
pub fn bicgstab_profiled<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
) -> SolveStats {
    match bicgstab_guarded(op, team, x, b, tol, maxiter, prof, &HealthConfig::default())
    {
        Ok(stats) => stats,
        Err(e) => e.into_stats(BICGSTAB_FUSED_SWEEPS, 1),
    }
}

/// Fused BiCGStab under the solver health guard (see
/// [`super::cg_guarded`] for the restart semantics).
#[allow(clippy::too_many_arguments)]
pub fn bicgstab_guarded<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
    health: &HealthConfig,
) -> Result<SolveStats, SolveError> {
    bicgstab_guarded_ckpt(op, team, x, b, tol, maxiter, prof, health, None, None)
}

/// Cross-iteration fused-BiCGStab state restored on resume; `v`/`t`
/// are recomputed before first read, so the checkpoint carries the
/// same state as the unfused solver's.
struct BiCgResume<R: Real> {
    r: FermionField<R>,
    p: FermionField<R>,
    rhat: FermionField<R>,
    rr: f64,
    rho: Complex,
}

/// [`bicgstab_guarded`] with a checkpoint sink and/or resume state
/// (see [`super::cg_guarded_ckpt`] for the bitwise-resume contract).
#[allow(clippy::too_many_arguments)]
pub fn bicgstab_guarded_ckpt<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
    health: &HealthConfig,
    mut ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
) -> Result<SolveStats, SolveError> {
    let mut guard = HealthGuard::new(health);
    let mut history = Vec::new();
    let mut flops = 0u64;
    let mut pack = None;
    if let Some(st) = resume {
        if st.family != FAMILY_FUSED_BICGSTAB {
            return Err(SolveError::checkpoint(format!(
                "checkpoint holds family tag {}, not fused bicgstab",
                st.family
            )));
        }
        let mut r = b.zeros_like();
        let mut p = b.zeros_like();
        let mut rhat = b.zeros_like();
        st.restore_into("x", &mut x.data).map_err(SolveError::checkpoint)?;
        st.restore_into("r", &mut r.data).map_err(SolveError::checkpoint)?;
        st.restore_into("p", &mut p.data).map_err(SolveError::checkpoint)?;
        st.restore_into("rhat", &mut rhat.data)
            .map_err(SolveError::checkpoint)?;
        if st.scalars.len() < 3 {
            return Err(SolveError::checkpoint("missing bicgstab scalars"));
        }
        let rr = st.scalars[0];
        let rho = Complex::new(st.scalars[1], st.scalars[2]);
        guard.restarts = st.restarts as usize;
        history = st.history.clone();
        flops = st.flops;
        op.restore_fault_cursors(&st.fault_cursors);
        pack = Some(BiCgResume { r, p, rhat, rr, rho });
    }
    let c0 = op.comm_counters();
    let z0 = op.comm_zero_fills();
    let counters = |op: &A| {
        let c1 = op.comm_counters();
        (c1.0 - c0.0, c1.1 - c0.1, op.comm_zero_fills() - z0)
    };
    let ntiles = op.fused_view().ntiles();
    let n = team.nthreads();
    // see cg_guarded: restart boundaries fold the failed attempt's
    // profiler state into the restart bucket and snapshot the flops
    let mut flops_at_restart = 0u64;
    loop {
        match bicgstab_attempt(
            op,
            team,
            x,
            b,
            tol,
            maxiter,
            prof,
            health,
            &mut history,
            &mut flops,
            guard.restarts,
            ckpt.as_deref_mut(),
            &mut pack,
        ) {
            Ok(mut stats) => {
                if stats.converged && health.drift_tol > 0.0 {
                    let ratio = super::health::drift_ratio(
                        op,
                        x,
                        b,
                        stats.rel_residual,
                        &mut flops,
                    );
                    if !ratio.is_finite() || ratio > health.drift_tol {
                        guard.absorb(
                            Interrupt::Drift { iteration: history.len(), ratio },
                            &history,
                            counters(op),
                        )?;
                        if let Some(p) = prof {
                            p.restart_reset();
                        }
                        flops_at_restart = flops;
                        continue;
                    }
                    stats.flops = flops;
                }
                guard.finish(&mut stats, counters(op));
                charge_flops(prof, n, ntiles, flops - flops_at_restart);
                return Ok(stats);
            }
            Err(int) => {
                guard.absorb(int, &history, counters(op))?;
                if let Some(p) = prof {
                    p.restart_reset();
                }
                flops_at_restart = flops;
            }
        }
    }
}

/// One guarded fused-BiCGStab attempt (`history`/`flops` accumulate
/// across attempts, the global iteration number is `history.len()`).
#[allow(clippy::too_many_arguments)]
fn bicgstab_attempt<R: Real, A: FusedSolvable<R>>(
    op: &mut A,
    team: &mut Team,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    prof: Option<&Profiler>,
    health: &HealthConfig,
    history: &mut Vec<f64>,
    flops: &mut u64,
    restarts: usize,
    mut ckpt: Option<&mut Checkpointer>,
    resume: &mut Option<BiCgResume<R>>,
) -> Result<SolveStats, Interrupt> {
    let flops_apply = op.flops_per_apply();
    let view = op.fused_view();
    let ntiles = view.ntiles();
    let vpt = view.vals_per_tile();
    let vlen = view.vlen();
    let len = view.field_len();
    let n = team.nthreads();
    let nreal = len as u64;
    let finish = |history: &[f64], flops: u64, converged: bool, rel: f64| SolveStats {
        iterations: history.len(),
        converged,
        rel_residual: rel,
        history: history.to_vec(),
        flops,
        sweeps_per_iter: BICGSTAB_FUSED_SWEEPS,
        threads: n,
        knob_sources: None,
        restarts: 0,
        health_events: 0,
        retransmits: 0,
        timeouts: 0,
        zero_fills: 0,
    };

    let resumed = resume.take();
    op.fault_hook(history.len())
        .map_err(|err| Interrupt::Comm { err, iteration: history.len() })?;
    let bnorm2 = b.norm2();
    if resumed.is_none() {
        *flops += fl::norm2_flops(nreal);
    }
    if bnorm2 == 0.0 {
        x.fill(R::ZERO);
        return Ok(finish(&[], 0, true, 0.0));
    }
    let limit = tol * tol * bnorm2;

    let mut t = b.zeros_like();
    let mut rr_partials: Vec<f64> = vec![0.0; ntiles];
    let (mut r, rhat, mut p, mut rr, mut rho);

    if let Some(rs) = resumed {
        // checkpoint resume: restored state reproduces the interrupted
        // run's iteration boundary bit-for-bit
        r = rs.r;
        p = rs.p;
        rhat = rs.rhat;
        rr = rs.rr;
        rho = rs.rho;
    } else {
        r = b.clone();
        if x.is_zero() {
            rr = bnorm2;
        } else {
            let t_ptr = SendPtr(t.data.as_mut_ptr());
            let r_ptr = SendPtr(r.data.as_mut_ptr());
            let x_raw = SendPtr(x.data.as_mut_ptr());
            let rr_ptr = SendPtr(rr_partials.as_mut_ptr());
            // SAFETY: all raw access in this region is sharded per tid
            // (chunk_range tile shards / apply_team); shared partials and the
            // IterOut slot are read only after a barrier (or region end)
            // publishes the writes.
            team.run(|tid, bar| unsafe {
                scoped(prof, tid, Phase::Bulk, || {
                    view.apply_team(tid, n, bar, t_ptr, x_raw.0 as *const R, None)
                });
                scoped(prof, tid, Phase::Barrier, || bar.wait());
                let (tb, te) = chunk_range(ntiles, tid, n);
                scoped(prof, tid, Phase::Blas, || {
                    blas::axpy_norm2_slice(
                        r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                        -R::ONE,
                        ro_at::<R>(t_ptr, tb * vpt, (te - tb) * vpt),
                        vlen,
                        rr_ptr.slice_mut(tb, te - tb),
                    )
                });
            });
            rr = blas::reduce_partials(&rr_partials);
            *flops += flops_apply + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
        }
        if !rr.is_finite() {
            // poisoned warm iterate: fall back to a cold restart
            x.fill(R::ZERO);
            return Err(Interrupt::NonFinite {
                what: "initial |r|^2",
                iteration: history.len(),
            });
        }

        rhat = r.clone();
        p = r.clone();
        // rho = <rhat, r> = |r|² at start (rhat == r), but compute it
        // like the unfused solver does so the value is grouping-identical
        rho = rhat.dot(&r);
        *flops += fl::cdot_flops(nreal);
        if !rho.re.is_finite() || !rho.im.is_finite() {
            return Err(Interrupt::NonFinite {
                what: "rho",
                iteration: history.len(),
            });
        }
    }
    let mut v = b.zeros_like();
    let mut stag = StagnationTracker::new(health.stagnation_window);

    let mut v_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut s_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut t_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut r_partials: Vec<[f64; 3]> = vec![[0.0; 3]; ntiles];
    let mut out = IterOut::default();

    let x_ptr = SendPtr(x.data.as_mut_ptr());
    let r_ptr = SendPtr(r.data.as_mut_ptr());
    let p_ptr = SendPtr(p.data.as_mut_ptr());
    let v_ptr = SendPtr(v.data.as_mut_ptr());
    let t_ptr = SendPtr(t.data.as_mut_ptr());
    let rhat_raw = SendPtr(rhat.data.as_ptr() as *mut R);
    let vp_ptr = SendPtr(v_partials.as_mut_ptr());
    let sp_ptr = SendPtr(s_partials.as_mut_ptr());
    let tp_ptr = SendPtr(t_partials.as_mut_ptr());
    let rp_ptr = SendPtr(r_partials.as_mut_ptr());
    let out_ptr = SendPtr(&mut out as *mut IterOut);

    while history.len() < maxiter && rr > limit {
        let iteration = history.len();
        if let Some(p) = prof {
            p.set_iter(iteration);
        }
        op.fault_hook(iteration)
            .map_err(|err| Interrupt::Comm { err, iteration })?;
        if let Some(ck) = ckpt.as_deref_mut() {
            if ck.due(iteration as u64) {
                let mut st =
                    SolverState::new(FAMILY_FUSED_BICGSTAB, iteration as u64);
                st.restarts = restarts as u64;
                st.flops = *flops;
                st.scalars = vec![rr, rho.re, rho.im];
                st.history = history.clone();
                st.fields = vec![
                    FieldSnap::of_fermion("x", x),
                    FieldSnap::of_fermion("r", &r),
                    FieldSnap::of_fermion("p", &p),
                    FieldSnap::of_fermion("rhat", &rhat),
                ];
                scoped(prof, 0, Phase::Checkpoint, || ck.save_lin(st, op));
            }
        }
        let rho_c = rho;
        // SAFETY: all raw access in this region is sharded per tid
        // (chunk_range tile shards / apply_team); shared partials and the
        // IterOut slot are read only after a barrier (or region end)
        // publishes the writes.
        team.run(|tid, bar| unsafe {
            let (tb, te) = chunk_range(ntiles, tid, n);
            let record = |o: IterOut| {
                if tid == 0 {
                    // SAFETY: master-thread-only write, no concurrent
                    // access; the main loop reads it after the region.
                    unsafe { *out_ptr.0 = o };
                }
            };
            let cfin = |c: Complex| c.re.is_finite() && c.im.is_finite();
            // sweep 1: v = A p with fused <rhat, v> capture
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    v_ptr,
                    p_ptr.0 as *const R,
                    Some((rhat_raw.0 as *const R, vp_ptr)),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let vp = ro::<[f64; 3]>(vp_ptr, ntiles);
            let rhat_v = Complex::new(
                vp.iter().map(|t| t[0]).sum(),
                vp.iter().map(|t| t[1]).sum(),
            );
            // non-finite check precedes the breakdown test (NaN fails
            // `< 1e-300`); every thread branches identically
            if !cfin(rhat_v) {
                record(IterOut { kind: 5, rr, rho: rho_c, what: "rhat·v" });
                return;
            }
            if rhat_v.abs() < 1e-300 {
                record(IterOut { kind: 1, rr: 0.0, rho: rho_c, what: "" });
                return; // breakdown (matches the unfused solver)
            }
            let alpha = rho_c * rhat_v.conj().scale(1.0 / rhat_v.norm2());
            if !cfin(alpha) {
                record(IterOut { kind: 5, rr, rho: rho_c, what: "alpha" });
                return;
            }
            let ma = -alpha;
            // sweep 2: s = r - alpha v (in place in r) with |s|² capture
            scoped(prof, tid, Phase::Blas, || {
                blas::caxpy_capture_slice(
                    r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(ma.re),
                    R::from_f64(ma.im),
                    ro_at::<R>(v_ptr, tb * vpt, (te - tb) * vpt),
                    None,
                    vlen,
                    sp_ptr.slice_mut(tb, te - tb),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let snorm: f64 =
                ro::<[f64; 3]>(sp_ptr, ntiles).iter().map(|t| t[2]).sum();
            if !snorm.is_finite() {
                // x untouched this iteration — still warm
                record(IterOut { kind: 5, rr, rho: rho_c, what: "|s|^2" });
                return;
            }
            if snorm <= limit {
                // converged at the half step: x += alpha p and stop
                scoped(prof, tid, Phase::Blas, || {
                    blas::caxpy_slice(
                        x_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                        R::from_f64(alpha.re),
                        R::from_f64(alpha.im),
                        ro_at::<R>(p_ptr, tb * vpt, (te - tb) * vpt),
                        vlen,
                    )
                });
                record(IterOut { kind: 2, rr: snorm, rho: rho_c, what: "" });
                return;
            }
            // sweep 3: t = A s with fused <s, t> and |t|² capture
            scoped(prof, tid, Phase::Bulk, || {
                view.apply_team(
                    tid,
                    n,
                    bar,
                    t_ptr,
                    r_ptr.0 as *const R,
                    Some((r_ptr.0 as *const R, tp_ptr)),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let tp = ro::<[f64; 3]>(tp_ptr, ntiles);
            // the capture conjugates s; ts = <t, s> conjugates t, so
            // flip the imaginary part (exact, hence bit-identical)
            let ts = Complex::new(
                tp.iter().map(|t| t[0]).sum(),
                -tp.iter().map(|t| t[1]).sum::<f64>(),
            );
            let tt: f64 = tp.iter().map(|t| t[2]).sum();
            if !cfin(ts) || !tt.is_finite() {
                record(IterOut { kind: 5, rr, rho: rho_c, what: "t·s / |t|^2" });
                return;
            }
            if tt == 0.0 {
                record(IterOut { kind: 3, rr: 0.0, rho: rho_c, what: "" });
                return; // breakdown
            }
            let omega = ts.scale(1.0 / tt);
            if !cfin(omega) {
                record(IterOut { kind: 5, rr, rho: rho_c, what: "omega" });
                return;
            }
            // sweep 4: x += alpha p + omega s (s lives in r)
            scoped(prof, tid, Phase::Blas, || {
                blas::caxpy2_slice(
                    x_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(alpha.re),
                    R::from_f64(alpha.im),
                    ro_at::<R>(p_ptr, tb * vpt, (te - tb) * vpt),
                    R::from_f64(omega.re),
                    R::from_f64(omega.im),
                    ro_at::<R>(r_ptr, tb * vpt, (te - tb) * vpt),
                    vlen,
                )
            });
            let mo = -omega;
            // sweep 5: r = s - omega t with <rhat, r> and |r|² capture
            scoped(prof, tid, Phase::Blas, || {
                blas::caxpy_capture_slice(
                    r_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(mo.re),
                    R::from_f64(mo.im),
                    ro_at::<R>(t_ptr, tb * vpt, (te - tb) * vpt),
                    Some(ro_at::<R>(rhat_raw, tb * vpt, (te - tb) * vpt)),
                    vlen,
                    rp_ptr.slice_mut(tb, te - tb),
                )
            });
            scoped(prof, tid, Phase::Barrier, || bar.wait());
            let rp = ro::<[f64; 3]>(rp_ptr, ntiles);
            let rr_new: f64 = rp.iter().map(|t| t[2]).sum();
            let rho_new = Complex::new(
                rp.iter().map(|t| t[0]).sum(),
                rp.iter().map(|t| t[1]).sum(),
            );
            if !rr_new.is_finite() {
                // updates already applied: the iteration is not counted
                record(IterOut { kind: 6, rr: rr_new, rho: rho_new, what: "|r|^2" });
                return;
            }
            if !cfin(rho_new) {
                // iteration completed with a finite residual; count it,
                // then interrupt before the poisoned direction update
                record(IterOut { kind: 7, rr: rr_new, rho: rho_new, what: "rho" });
                return;
            }
            if rho_c.abs() < 1e-300 || omega.abs() < 1e-300 {
                record(IterOut { kind: 4, rr: rr_new, rho: rho_new, what: "" });
                return; // breakdown after the updates, like unfused
            }
            let beta = (rho_new * alpha)
                * (rho_c * omega).conj().scale(1.0 / (rho_c * omega).norm2());
            if !cfin(beta) {
                record(IterOut { kind: 7, rr: rr_new, rho: rho_new, what: "beta" });
                return;
            }
            // sweep 6: p = beta (p - omega v) + r
            scoped(prof, tid, Phase::Blas, || {
                blas::p_update_slice(
                    p_ptr.slice_mut(tb * vpt, (te - tb) * vpt),
                    R::from_f64(mo.re),
                    R::from_f64(mo.im),
                    ro_at::<R>(v_ptr, tb * vpt, (te - tb) * vpt),
                    R::from_f64(beta.re),
                    R::from_f64(beta.im),
                    ro_at::<R>(r_ptr, tb * vpt, (te - tb) * vpt),
                    vlen,
                )
            });
            record(IterOut { kind: 0, rr: rr_new, rho: rho_new, what: "" });
        });

        // master: act on tid 0's record (all threads computed the same)
        match out.kind {
            5 => {
                return Err(Interrupt::NonFinite { what: out.what, iteration });
            }
            6 => {
                return Err(Interrupt::NonFinite { what: out.what, iteration });
            }
            7 => {
                rr = out.rr;
                history.push((rr / bnorm2).sqrt());
                return Err(Interrupt::NonFinite {
                    what: out.what,
                    iteration: history.len(),
                });
            }
            1 => {
                *flops += flops_apply + fl::cdot_flops(nreal);
                break;
            }
            2 => {
                *flops += flops_apply
                    + fl::cdot_flops(nreal)
                    + fl::caxpy_flops(nreal)
                    + fl::norm2_flops(nreal)
                    + fl::caxpy_flops(nreal);
                rr = out.rr;
                history.push((rr / bnorm2).sqrt());
                break;
            }
            3 => {
                *flops += 2 * flops_apply
                    + 2 * fl::cdot_flops(nreal)
                    + fl::caxpy_flops(nreal)
                    + 2 * fl::norm2_flops(nreal);
                break;
            }
            kind => {
                // full iteration (kind 0) or post-update breakdown (4):
                // norm² sweeps are |s|², |t|² and the final |r|²
                *flops += 2 * flops_apply
                    + 3 * fl::cdot_flops(nreal)
                    + 4 * fl::caxpy_flops(nreal)
                    + 3 * fl::norm2_flops(nreal);
                rr = out.rr;
                let rel = (rr / bnorm2).sqrt();
                history.push(rel);
                if kind == 4 {
                    break;
                }
                rho = out.rho;
                *flops +=
                    fl::caxpy_flops(nreal) + fl::cscale_flops(nreal) + fl::axpy_flops(nreal);
                if rr > limit && stag.stalled(rel) {
                    return Err(Interrupt::Stagnation { iteration: history.len() });
                }
            }
        }
    }

    if let Some(err) = op.comm_fault() {
        return Err(Interrupt::Comm { err, iteration: history.len() });
    }
    Ok(finish(history, *flops, rr <= limit, (rr / bnorm2).sqrt()))
}
