//! Iterative solvers for the even-odd preconditioned Wilson system.
//!
//! * [`cg`] — conjugate gradient on the hermitian positive-definite
//!   normal operator `M-hat^dag M-hat` (CGNR).
//! * [`bicgstab`] — BiCGStab directly on the non-hermitian `M-hat`.
//! * [`mixed`] — mixed-precision iterative refinement: f64 outer defect
//!   correction around an f32 inner CG/BiCGStab.
//! * [`fused`] — the thread-parallel fused pipeline: whole iterations on
//!   the worker team, kernel + BLAS-1 sweeps fused (3 sweeps per CG
//!   iteration instead of 6), residual histories bitwise identical to
//!   the unfused solvers at any thread count.
//! * [`block`] — multi-RHS batched solvers on the block field: one gauge
//!   stream feeds N right-hand sides per sweep, per-RHS scalars keep
//!   every system on its independent trajectory, and per-RHS stopping
//!   masks let converged systems drop out of the kernel work. Like the
//!   single-RHS fused pipeline, every batched iteration is ONE team
//!   region (operator phases + masked BLAS sweeps on the in-region
//!   barrier).
//!
//! The generic solvers are generic over
//! [`crate::coordinator::operator::LinearOperator`] and the
//! [`crate::algebra::Real`] field scalar; dot products go through
//! `reduce_sum` (always f64) so the same code runs single-rank and
//! distributed (allreduce), native and PJRT-backed, at either precision.
//! The fused solvers additionally require
//! [`crate::coordinator::operator::FusedSolvable`] (native single-rank
//! operators) for tile-phased applies; the block solvers require its
//! multi-RHS analog [`crate::coordinator::operator::MultiFusedSolvable`].

mod bicgstab;
pub mod block;
pub mod checkpoint;
mod cg;
pub mod fused;
pub mod health;
pub mod mixed;
pub mod residual;

pub use bicgstab::{bicgstab, bicgstab_guarded, bicgstab_guarded_ckpt};
pub use checkpoint::{
    load_latest, read_state_file, restore_from_buddy, BuddyCopy, CheckpointError,
    Checkpointer, CkptOpts, SolverState,
};
pub use block::{
    block_bicgstab, block_bicgstab_generic, block_bicgstab_generic_guarded,
    block_bicgstab_generic_guarded_ckpt, block_bicgstab_generic_guarded_profiled,
    block_bicgstab_profiled, block_cg, block_cg_generic, block_cg_generic_guarded,
    block_cg_generic_guarded_ckpt, block_cg_generic_guarded_profiled,
    block_cg_profiled, BlockSolveStats, RhsStats,
};
pub use cg::{cg, cg_guarded, cg_guarded_ckpt};
pub use health::{
    HealthConfig, HealthEvent, HealthEventKind, HealthGuard, Interrupt,
    SolveError, SolveErrorKind,
};
pub use mixed::{
    mixed_refinement, mixed_refinement_guarded, mixed_refinement_team,
    mixed_refinement_team_profiled, mixed_refinement_team_profiled_ckpt,
    InnerAlgorithm, MixedStats,
};

/// Convergence record of one solve.
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    pub converged: bool,
    /// |r| / |b| at exit (recursive residual)
    pub rel_residual: f64,
    /// |r|/|b| after each iteration
    pub history: Vec<f64>,
    /// total flops of the solve: operator applications plus the BLAS-1
    /// axpy/xpay and dot/norm reductions of the iteration
    pub flops: u64,
    /// full-field memory sweeps one iteration of this solver streams
    /// (an operator apply counts as one pass; each separate BLAS-1 pass
    /// counts one) — 6 for unfused CG, 3 for the fused pipeline
    pub sweeps_per_iter: f64,
    /// worker-team threads the solve ran on (1 = serial); records the
    /// auto-selected count when `solver.threads` was left unset
    pub threads: usize,
    /// where each performance knob came from (CLI/config, tune cache,
    /// or static heuristic) — filled by the solve driver when knob
    /// resolution ran, `None` for direct library calls
    pub knob_sources: Option<String>,
    /// Krylov restarts the health guard performed after recoverable
    /// events (non-finite scalars, stagnation, residual drift)
    pub restarts: usize,
    /// health-guard events observed (restarts plus fatal diagnoses)
    pub health_events: usize,
    /// halo messages healed from the sender-side retransmit store
    pub retransmits: u64,
    /// recv/collective deadlines that expired (including recovered ones)
    pub timeouts: u64,
    /// halo buffers the transport zero-filled after failed recvs — any
    /// nonzero value means sweeps ran on fabricated data and the solve
    /// ended in (or recovered through) a transport fault
    pub zero_fills: u64,
}
