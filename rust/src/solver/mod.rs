//! Iterative solvers for the even-odd preconditioned Wilson system.
//!
//! * [`cg`] — conjugate gradient on the hermitian positive-definite
//!   normal operator `M-hat^dag M-hat` (CGNR).
//! * [`bicgstab`] — BiCGStab directly on the non-hermitian `M-hat`.
//! * [`mixed`] — mixed-precision iterative refinement: f64 outer defect
//!   correction around an f32 inner CG/BiCGStab.
//!
//! All are generic over [`crate::coordinator::operator::LinearOperator`]
//! and the [`crate::algebra::Real`] field scalar; dot products go through
//! `reduce_sum` (always f64) so the same code runs single-rank and
//! distributed (allreduce), native and PJRT-backed, at either precision.

mod bicgstab;
mod cg;
pub mod mixed;
pub mod residual;

pub use bicgstab::bicgstab;
pub use cg::cg;
pub use mixed::{mixed_refinement, InnerAlgorithm, MixedStats};

/// Convergence record of one solve.
#[derive(Clone, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    pub converged: bool,
    /// |r| / |b| at exit (recursive residual)
    pub rel_residual: f64,
    /// |r|/|b| after each iteration
    pub history: Vec<f64>,
    /// total flops spent in operator applications
    pub flops: u64,
}
