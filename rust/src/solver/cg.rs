//! Conjugate gradient for hermitian positive-definite operators, generic
//! over the field precision. Scalars alpha/beta are computed from f64
//! reductions and rounded into the field precision for the axpy updates.

use crate::algebra::Real;
use crate::coordinator::operator::LinearOperator;
use crate::dslash::flops as fl;
use crate::field::FermionField;

use super::fused::CG_UNFUSED_SWEEPS;
use super::SolveStats;

/// Solve `A x = b` with CG. `x` holds the initial guess on entry and the
/// solution on exit. Convergence criterion: `|r| <= tol * |b|`.
pub fn cg<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
) -> SolveStats {
    let bnorm2 = op.reduce_sum(b.norm2());
    let nreal = b.data.len() as u64;
    let mut flops = fl::norm2_flops(nreal);
    if bnorm2 == 0.0 {
        x.fill(R::ZERO);
        return SolveStats {
            iterations: 0,
            converged: true,
            rel_residual: 0.0,
            history: vec![],
            flops: 0,
            sweeps_per_iter: CG_UNFUSED_SWEEPS,
            threads: 1,
            knob_sources: None,
        };
    }
    let limit = tol * tol * bnorm2;

    // r = b - A x; for the common zero initial guess skip the operator
    // apply entirely (r = b and |r|² = |b|² are already known). The
    // skip must be agreed globally — `apply`/`reduce_sum` are
    // collective for distributed operators, so a rank-local decision
    // would mismatch the collectives.
    let x_zero = op.reduce_sum(if x.is_zero() { 0.0 } else { 1.0 }) == 0.0;
    let mut r = b.clone();
    let mut ap = b.zeros_like();
    let mut rr;
    if x_zero {
        rr = bnorm2;
    } else {
        op.apply(&mut ap, x);
        r.axpy(-R::ONE, &ap);
        rr = op.reduce_sum(r.norm2());
        flops += op.flops_per_apply() + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
    }
    let mut p = r.clone();
    let mut history = Vec::new();

    let mut iterations = 0;
    while iterations < maxiter && rr > limit {
        op.apply(&mut ap, &p);
        let pap = op.reduce_sum(p.dot_re(&ap));
        debug_assert!(pap.is_finite());
        let alpha = rr / pap;
        x.axpy(R::from_f64(alpha), &p);
        r.axpy(R::from_f64(-alpha), &ap);
        let rr_new = op.reduce_sum(r.norm2());
        let beta = R::from_f64(rr_new / rr);
        // p = r + beta p
        p.xpay(beta, &r);
        flops += op.flops_per_apply()
            + fl::dot_re_flops(nreal)
            + 2 * fl::axpy_flops(nreal)
            + fl::norm2_flops(nreal)
            + fl::xpay_flops(nreal);
        rr = rr_new;
        iterations += 1;
        history.push((rr / bnorm2).sqrt());
    }

    SolveStats {
        iterations,
        converged: rr <= limit,
        rel_residual: (rr / bnorm2).sqrt(),
        history,
        flops,
        sweeps_per_iter: CG_UNFUSED_SWEEPS,
        threads: 1,
        knob_sources: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::operator::NativeMdagM;
    use crate::field::GaugeField;
    use crate::lattice::{Geometry, LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn cg_converges_on_mdagm() {
        let g = geom();
        let mut rng = Rng::seeded(101);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);
        let mut x = FermionField::zeros(&g);
        let stats = cg(&mut op, &mut x, &b, 1e-8, 500);
        assert!(stats.converged, "CG did not converge: {stats:?}");
        // true residual
        let mut ax = FermionField::zeros(&g);
        op.apply(&mut ax, &x);
        ax.axpy(-1.0, &b);
        let rel = (ax.norm2() / b.norm2()).sqrt();
        assert!(rel < 1e-5, "true residual {rel}");
        // history is monotically recorded (not necessarily monotone in
        // value, but has one entry per iteration)
        assert_eq!(stats.history.len(), stats.iterations);
        assert!(stats.flops > 0);
    }

    #[test]
    fn cg_zero_rhs() {
        let g = geom();
        let mut rng = Rng::seeded(102);
        let u = GaugeField::random(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);
        let b = FermionField::zeros(&g);
        let mut x = FermionField::gaussian(&g, &mut rng);
        let stats = cg(&mut op, &mut x, &b, 1e-8, 100);
        assert!(stats.converged);
        assert_eq!(x.norm2(), 0.0);
    }

    #[test]
    fn cg_warm_start_converges_faster() {
        let g = geom();
        let mut rng = Rng::seeded(103);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);

        let mut x_cold = FermionField::zeros(&g);
        let cold = cg(&mut op, &mut x_cold, &b, 1e-8, 500);

        // warm start from the solution: should converge immediately
        let mut x_warm = x_cold.clone();
        let warm = cg(&mut op, &mut x_warm, &b, 1e-6, 500);
        assert!(warm.iterations <= 2, "warm start took {}", warm.iterations);
        assert!(cold.iterations > warm.iterations);
    }

    #[test]
    fn cg_respects_maxiter() {
        let g = geom();
        let mut rng = Rng::seeded(104);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);
        let mut x = FermionField::zeros(&g);
        let stats = cg(&mut op, &mut x, &b, 1e-14, 3);
        assert_eq!(stats.iterations, 3);
        assert!(!stats.converged);
    }
}
