//! Conjugate gradient for hermitian positive-definite operators, generic
//! over the field precision. Scalars alpha/beta are computed from f64
//! reductions and rounded into the field precision for the axpy updates.
//!
//! The guarded entry point [`cg_guarded`] wraps the iteration in the
//! solver health guard: non-finite iteration scalars abort the attempt
//! *before* the solution update, the guard restarts the Krylov process
//! from the warm iterate (bounded by `solver.max_restarts`), and
//! transport faults surface as typed [`SolveError`]s. The fault-free
//! path is bitwise identical to the unguarded history.

use crate::algebra::Real;
use crate::coordinator::operator::LinearOperator;
use crate::dslash::flops as fl;
use crate::field::snapshot::FieldSnap;
use crate::field::FermionField;

use super::checkpoint::{Checkpointer, SolverState, FAMILY_CG};
use super::fused::CG_UNFUSED_SWEEPS;
use super::health::{
    HealthConfig, HealthGuard, Interrupt, SolveError, StagnationTracker,
};
use super::SolveStats;

/// Solve `A x = b` with CG. `x` holds the initial guess on entry and the
/// solution on exit. Convergence criterion: `|r| <= tol * |b|`.
///
/// Runs under a default health guard; failures fold into a
/// non-converged [`SolveStats`]. Use [`cg_guarded`] for the typed error.
pub fn cg<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
) -> SolveStats {
    match cg_guarded(op, x, b, tol, maxiter, &HealthConfig::default()) {
        Ok(stats) => stats,
        Err(e) => e.into_stats(CG_UNFUSED_SWEEPS, 1),
    }
}

/// CG under the solver health guard: recoverable events (non-finite
/// pAp/|r|², stagnation, residual drift) restart the Krylov process
/// from the warm iterate up to `health.max_restarts` times; transport
/// faults and an exhausted budget return a typed [`SolveError`].
pub fn cg_guarded<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
) -> Result<SolveStats, SolveError> {
    cg_guarded_ckpt(op, x, b, tol, maxiter, health, None, None)
}

/// Cross-iteration Krylov state restored from a checkpoint, consumed by
/// the first attempt after a resume.
struct CgResume<R: Real> {
    r: FermionField<R>,
    p: FermionField<R>,
    rr: f64,
}

/// [`cg_guarded`] with a checkpoint sink and/or a resume state. `ckpt`
/// saves complete solver state on its cadence; `resume` restores a
/// state saved by this family and continues with a residual history
/// bitwise identical to the uninterrupted run from that iteration on.
#[allow(clippy::too_many_arguments)]
pub fn cg_guarded_ckpt<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    mut ckpt: Option<&mut Checkpointer>,
    resume: Option<&SolverState>,
) -> Result<SolveStats, SolveError> {
    let mut guard = HealthGuard::new(health);
    let mut history = Vec::new();
    let mut flops = 0u64;
    let mut pack = None;
    if let Some(st) = resume {
        if st.family != FAMILY_CG {
            return Err(SolveError::checkpoint(format!(
                "checkpoint holds family tag {}, not cg",
                st.family
            )));
        }
        st.restore_into("x", &mut x.data).map_err(SolveError::checkpoint)?;
        let mut r = b.zeros_like();
        st.restore_into("r", &mut r.data).map_err(SolveError::checkpoint)?;
        let mut p = b.zeros_like();
        st.restore_into("p", &mut p.data).map_err(SolveError::checkpoint)?;
        let rr = *st
            .scalars
            .first()
            .ok_or_else(|| SolveError::checkpoint("missing rr scalar"))?;
        guard.restarts = st.restarts as usize;
        history = st.history.clone();
        flops = st.flops;
        op.restore_fault_cursors(&st.fault_cursors);
        pack = Some(CgResume { r, p, rr });
    }
    let c0 = op.comm_counters();
    let z0 = op.comm_zero_fills();
    let counters = |op: &A| {
        let c1 = op.comm_counters();
        (c1.0 - c0.0, c1.1 - c0.1, op.comm_zero_fills() - z0)
    };
    loop {
        match cg_attempt(
            op,
            x,
            b,
            tol,
            maxiter,
            health,
            &mut history,
            &mut flops,
            guard.restarts,
            ckpt.as_deref_mut(),
            &mut pack,
        ) {
            Ok(mut stats) => {
                // Drift check at apparent convergence: the recursive
                // residual can silently diverge from the true one; a
                // restart recomputes r = b - A x and iterates on truth.
                if stats.converged && health.drift_tol > 0.0 {
                    let ratio = super::health::drift_ratio(
                        op,
                        x,
                        b,
                        stats.rel_residual,
                        &mut flops,
                    );
                    if !ratio.is_finite() || ratio > health.drift_tol {
                        guard.absorb(
                            Interrupt::Drift { iteration: history.len(), ratio },
                            &history,
                            counters(op),
                        )?;
                        continue;
                    }
                    stats.flops = flops;
                }
                guard.finish(&mut stats, counters(op));
                return Ok(stats);
            }
            Err(int) => {
                guard.absorb(int, &history, counters(op))?;
            }
        }
    }
}

/// One guarded CG attempt: runs until convergence, the (global) maxiter
/// budget, or an interrupt. `history` and `flops` accumulate across
/// attempts; the global iteration number is `history.len()`.
#[allow(clippy::too_many_arguments)]
fn cg_attempt<R: Real, A: LinearOperator<R>>(
    op: &mut A,
    x: &mut FermionField<R>,
    b: &FermionField<R>,
    tol: f64,
    maxiter: usize,
    health: &HealthConfig,
    history: &mut Vec<f64>,
    flops: &mut u64,
    restarts: usize,
    mut ckpt: Option<&mut Checkpointer>,
    resume: &mut Option<CgResume<R>>,
) -> Result<SolveStats, Interrupt> {
    let finish = |history: &[f64], flops: u64, converged: bool, rel: f64| SolveStats {
        iterations: history.len(),
        converged,
        rel_residual: rel,
        history: history.to_vec(),
        flops,
        sweeps_per_iter: CG_UNFUSED_SWEEPS,
        threads: 1,
        knob_sources: None,
        restarts: 0,
        health_events: 0,
        retransmits: 0,
        timeouts: 0,
        zero_fills: 0,
    };
    let resumed = resume.take();
    op.fault_hook(history.len())
        .map_err(|err| Interrupt::Comm { err, iteration: history.len() })?;
    let bnorm2 = op.reduce_sum(b.norm2());
    let nreal = b.data.len() as u64;
    if resumed.is_none() {
        *flops += fl::norm2_flops(nreal);
    }
    if bnorm2 == 0.0 {
        x.fill(R::ZERO);
        return Ok(finish(&[], 0, true, 0.0));
    }
    let limit = tol * tol * bnorm2;

    let mut ap = b.zeros_like();
    let (mut r, mut p, mut rr);
    if let Some(rs) = resumed {
        // A checkpoint resume: the cross-iteration state (r, p, rr) is
        // restored bit-for-bit, so the loop below continues exactly
        // where the interrupted run's iteration boundary was.
        r = rs.r;
        p = rs.p;
        rr = rs.rr;
    } else {
        // r = b - A x; for the common zero initial guess skip the
        // operator apply entirely (r = b and |r|² = |b|² are already
        // known). The skip must be agreed globally — `apply`/
        // `reduce_sum` are collective for distributed operators, so a
        // rank-local decision would mismatch the collectives.
        let x_zero = op.reduce_sum(if x.is_zero() { 0.0 } else { 1.0 }) == 0.0;
        r = b.clone();
        if x_zero {
            rr = bnorm2;
        } else {
            op.apply(&mut ap, x);
            r.axpy(-R::ONE, &ap);
            rr = op.reduce_sum(r.norm2());
            *flops +=
                op.flops_per_apply() + fl::axpy_flops(nreal) + fl::norm2_flops(nreal);
        }
        if !rr.is_finite() {
            // the warm iterate itself is poisoned: nothing to preserve,
            // so fall back to a cold restart before giving up
            x.fill(R::ZERO);
            return Err(Interrupt::NonFinite {
                what: "initial |r|^2",
                iteration: history.len(),
            });
        }
        p = r.clone();
    }
    let mut stag = StagnationTracker::new(health.stagnation_window);

    while history.len() < maxiter && rr > limit {
        let iteration = history.len();
        op.fault_hook(iteration)
            .map_err(|err| Interrupt::Comm { err, iteration })?;
        if let Some(ck) = ckpt.as_deref_mut() {
            if ck.due(iteration as u64) {
                let mut st = SolverState::new(FAMILY_CG, iteration as u64);
                st.restarts = restarts as u64;
                st.flops = *flops;
                st.scalars = vec![rr];
                st.history = history.clone();
                st.fields = vec![
                    FieldSnap::of_fermion("x", x),
                    FieldSnap::of_fermion("r", &r),
                    FieldSnap::of_fermion("p", &p),
                ];
                ck.save_lin(st, op);
            }
        }
        op.apply(&mut ap, &p);
        let pap = op.reduce_sum(p.dot_re(&ap));
        if !pap.is_finite() {
            return Err(Interrupt::NonFinite { what: "pAp", iteration });
        }
        let alpha = rr / pap;
        if !alpha.is_finite() {
            return Err(Interrupt::NonFinite { what: "alpha", iteration });
        }
        // residual update first: if |r|² goes non-finite the solution
        // iterate has not been touched yet and stays warm for a restart
        r.axpy(R::from_f64(-alpha), &ap);
        let rr_new = op.reduce_sum(r.norm2());
        if !rr_new.is_finite() {
            return Err(Interrupt::NonFinite { what: "|r|^2", iteration });
        }
        x.axpy(R::from_f64(alpha), &p);
        let beta = R::from_f64(rr_new / rr);
        // p = r + beta p
        p.xpay(beta, &r);
        *flops += op.flops_per_apply()
            + fl::dot_re_flops(nreal)
            + 2 * fl::axpy_flops(nreal)
            + fl::norm2_flops(nreal)
            + fl::xpay_flops(nreal);
        rr = rr_new;
        let rel = (rr / bnorm2).sqrt();
        history.push(rel);
        if rr > limit && stag.stalled(rel) {
            return Err(Interrupt::Stagnation { iteration: history.len() });
        }
    }

    // A transport fault zero-fills halos rather than panicking, so a
    // "converged" residual after a fault is not trustworthy: surface
    // the recorded fault instead of the stats.
    if let Some(err) = op.comm_fault() {
        return Err(Interrupt::Comm { err, iteration: history.len() });
    }
    Ok(finish(history, *flops, rr <= limit, (rr / bnorm2).sqrt()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::operator::NativeMdagM;
    use crate::field::GaugeField;
    use crate::lattice::{Geometry, LatticeDims, Tiling};
    use crate::util::rng::Rng;

    fn geom() -> Geometry {
        Geometry::single_rank(
            LatticeDims::new(4, 4, 4, 4).unwrap(),
            Tiling::new(2, 2).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn cg_converges_on_mdagm() {
        let g = geom();
        let mut rng = Rng::seeded(101);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);
        let mut x = FermionField::zeros(&g);
        let stats = cg(&mut op, &mut x, &b, 1e-8, 500);
        assert!(stats.converged, "CG did not converge: {stats:?}");
        // true residual
        let mut ax = FermionField::zeros(&g);
        op.apply(&mut ax, &x);
        ax.axpy(-1.0, &b);
        let rel = (ax.norm2() / b.norm2()).sqrt();
        assert!(rel < 1e-5, "true residual {rel}");
        // history is monotically recorded (not necessarily monotone in
        // value, but has one entry per iteration)
        assert_eq!(stats.history.len(), stats.iterations);
        assert!(stats.flops > 0);
        // no health events on the clean path
        assert_eq!(stats.restarts, 0);
        assert_eq!(stats.health_events, 0);
    }

    #[test]
    fn cg_zero_rhs() {
        let g = geom();
        let mut rng = Rng::seeded(102);
        let u = GaugeField::random(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);
        let b = FermionField::zeros(&g);
        let mut x = FermionField::gaussian(&g, &mut rng);
        let stats = cg(&mut op, &mut x, &b, 1e-8, 100);
        assert!(stats.converged);
        assert_eq!(x.norm2(), 0.0);
    }

    #[test]
    fn cg_warm_start_converges_faster() {
        let g = geom();
        let mut rng = Rng::seeded(103);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);

        let mut x_cold = FermionField::zeros(&g);
        let cold = cg(&mut op, &mut x_cold, &b, 1e-8, 500);

        // warm start from the solution: should converge immediately
        let mut x_warm = x_cold.clone();
        let warm = cg(&mut op, &mut x_warm, &b, 1e-6, 500);
        assert!(warm.iterations <= 2, "warm start took {}", warm.iterations);
        assert!(cold.iterations > warm.iterations);
    }

    #[test]
    fn cg_respects_maxiter() {
        let g = geom();
        let mut rng = Rng::seeded(104);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);
        let mut x = FermionField::zeros(&g);
        let stats = cg(&mut op, &mut x, &b, 1e-14, 3);
        assert_eq!(stats.iterations, 3);
        assert!(!stats.converged);
    }

    #[test]
    fn cg_guarded_matches_unguarded_bitwise() {
        let g = geom();
        let mut rng = Rng::seeded(105);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = NativeMdagM::new(&g, u, 0.12f32);

        let mut x1 = FermionField::zeros(&g);
        let plain = cg(&mut op, &mut x1, &b, 1e-8, 500);
        let mut x2 = FermionField::zeros(&g);
        let strict = cg_guarded(
            &mut op,
            &mut x2,
            &b,
            1e-8,
            500,
            &HealthConfig {
                stagnation_window: 50,
                drift_tol: 100.0,
                ..Default::default()
            },
        )
        .expect("clean solve");
        assert_eq!(plain.history, strict.history, "guard changed the history");
        assert_eq!(x1.data, x2.data, "guard changed the iterates");
        assert_eq!(strict.restarts, 0);
    }

    /// Operator that reports NaN reductions for a window of calls:
    /// exercises the restart path without touching the transport.
    struct FlakyOp {
        inner: NativeMdagM<f32>,
        calls: usize,
        nan_from: usize,
        nan_until: usize,
    }

    impl LinearOperator<f32> for FlakyOp {
        fn apply(&mut self, out: &mut FermionField<f32>, input: &FermionField<f32>) {
            self.inner.apply(out, input);
        }
        fn flops_per_apply(&self) -> u64 {
            self.inner.flops_per_apply()
        }
        fn reduce_sum(&mut self, v: f64) -> f64 {
            self.calls += 1;
            if self.calls >= self.nan_from && self.calls < self.nan_until {
                f64::NAN
            } else {
                v
            }
        }
    }

    #[test]
    fn cg_guarded_restarts_on_nan_scalar() {
        let g = geom();
        let mut rng = Rng::seeded(106);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = FlakyOp {
            inner: NativeMdagM::new(&g, u.clone(), 0.12f32),
            calls: 0,
            nan_from: 10,
            nan_until: 11,
        };
        let mut x = FermionField::zeros(&g);
        let stats = cg_guarded(&mut op, &mut x, &b, 1e-8, 500, &HealthConfig::default())
            .expect("one NaN window is recoverable");
        assert!(stats.converged, "{stats:?}");
        assert_eq!(stats.restarts, 1);
        assert_eq!(stats.health_events, 1);
        // the solve still reaches the true solution
        let mut clean = NativeMdagM::new(&g, u, 0.12f32);
        let mut ax = FermionField::zeros(&g);
        clean.apply(&mut ax, &x);
        ax.axpy(-1.0, &b);
        let rel = (ax.norm2() / b.norm2()).sqrt();
        assert!(rel < 1e-5, "true residual {rel}");
    }

    #[test]
    fn cg_guarded_exhausts_restarts_on_persistent_nan() {
        let g = geom();
        let mut rng = Rng::seeded(107);
        let u = GaugeField::random(&g, &mut rng);
        let b = FermionField::gaussian(&g, &mut rng);
        let mut op = FlakyOp {
            inner: NativeMdagM::new(&g, u, 0.12f32),
            calls: 0,
            nan_from: 5,
            nan_until: usize::MAX,
        };
        let mut x = FermionField::zeros(&g);
        let err = cg_guarded(&mut op, &mut x, &b, 1e-8, 500, &HealthConfig::default())
            .expect_err("persistent NaN must exhaust the budget");
        assert!(matches!(
            err.kind,
            crate::solver::SolveErrorKind::RestartsExhausted
        ));
        // default budget: 3 restarts + the final fatal event
        assert_eq!(err.events.len(), 4);
    }
}
