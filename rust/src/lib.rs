//! # lqcd — even-odd Wilson fermion matrix on a SIMD-tiled lattice
//!
//! A reproduction of *“Wilson matrix kernel for lattice QCD on A64FX
//! architecture”* (Kanamori, Nitadori, Matsufuru; HPCAsia 2023 workshops,
//! DOI 10.1145/3581576.3581610) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — the Pallas even-odd hopping kernel (`python/compile/kernels/`),
//!   AOT-lowered to HLO text at build time.
//! * **L2** — the JAX even-odd preconditioned operator and solver graphs
//!   (`python/compile/model.py`).
//! * **L3** — this crate: the parallel runtime. Simulated-MPI rank world,
//!   halo exchange with the paper's EO1 (pack) / EO2 (unpack) kernels,
//!   thread team with bulk/boundary overlap, FAPP-analog profiler, CG /
//!   BiCGStab drivers, a PJRT runtime executing the AOT artifacts, and a
//!   complete *native* even-odd Wilson dslash — the “ACLE” analog — with
//!   lane-shuffle stencil shifts (`sel`/`tbl`/`ext`/`compact` analogs),
//!   plus the gather-indexed and plain-scalar variants the paper profiles
//!   against (Fig. 8, §4.2).
//!
//! The benchmark harness ([`harness`]) regenerates every table and figure
//! of the paper's evaluation; see `DESIGN.md` for the experiment index and
//! `EXPERIMENTS.md` for measured results.

pub mod algebra;
pub mod analysis;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod dslash;
pub mod field;
pub mod harness;
pub mod lattice;
pub mod perf;
pub mod runtime;
pub mod solver;
pub mod util;

/// Floating-point operations per lattice site for one application of the
/// full Wilson matrix `D_W` in the QXS counting convention (paper §2).
pub const FLOP_PER_SITE: u64 = 1368;
