//! The invariant linter: a line/token-level scanner (no full parser)
//! that walks `rust/src` and mechanically enforces the repo's hand-kept
//! correctness invariants as named, individually-suppressable rules.
//!
//! | rule             | invariant                                                        |
//! |------------------|------------------------------------------------------------------|
//! | `safety-comment` | every `unsafe` block/fn/impl is justified by `// SAFETY:`        |
//! | `raw-f64-accum`  | scalar partial sums use `field::blas::reduce_partials*`          |
//! | `tag-registry`   | wire tags are minted only by `comm::tags`                        |
//! | `config-doc`     | every key parsed in `config/run.rs` appears in `example.toml`    |
//! | `adhoc-json`     | machine-readable output goes through `util::json`, not `format!` |
//!
//! Suppression: a trailing or immediately-preceding comment of the form
//! `// lint: allow(rule-name)` (several rules comma-separated) silences
//! that rule on that line. Suppressions are counted and reported, so a
//! drive-by `allow` shows up in review and in the findings JSON.
//!
//! The scanner is deliberately token-level: it classifies each source
//! line into code / comment / string regions (handling nested block
//! comments, raw strings and char literals), then matches patterns in
//! the right region. That bounds what it can see — a raw accumulation
//! through a pointer with an innocent name will slip by — but it also
//! means zero dependencies, microsecond scans, and no false positives
//! from macro-expanded code it cannot resolve.

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::JsonWriter;

/// Every rule the scanner knows, with a one-line description (shown by
/// `lqcd lint --rules` and in ARCHITECTURE.md's rule table).
pub const RULES: &[(&str, &str)] = &[
    (
        "safety-comment",
        "every `unsafe` block/fn/impl carries a `// SAFETY:` (or `# Safety` doc) justification",
    ),
    (
        "raw-f64-accum",
        "scalar partial sums route through field::blas::reduce_partials* / the reduce_caps* family",
    ),
    (
        "tag-registry",
        "wire tags are minted only by comm::tags (no ad-hoc bit-63 namespaces or tag fns)",
    ),
    (
        "config-doc",
        "every config key parsed in config/run.rs is documented in configs/example.toml",
    ),
    (
        "adhoc-json",
        "machine-readable output goes through util::json, not hand-assembled format! strings",
    ),
];

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Result of a whole-tree scan.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub suppressed: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable findings document (the CI artifact).
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.obj_begin();
        w.key("files_scanned");
        w.uint(self.files_scanned as u64);
        w.key("suppressed");
        w.uint(self.suppressed as u64);
        w.key("count");
        w.uint(self.findings.len() as u64);
        w.key("findings");
        w.arr_begin();
        for f in &self.findings {
            w.obj_begin();
            w.key("rule");
            w.str_val(f.rule);
            w.key("file");
            w.str_val(&f.file);
            w.key("line");
            w.uint(f.line as u64);
            w.key("msg");
            w.str_val(&f.msg);
            w.obj_end();
        }
        w.arr_end();
        w.obj_end();
        w.finish()
    }
}

// ---------------------------------------------------------------------
// line classification
// ---------------------------------------------------------------------

/// One source line split into regions. `code` has comments removed and
/// string/char-literal *contents* blanked (quotes kept), so token rules
/// never fire on text inside literals. `code_strings` keeps literal
/// contents (comments still removed) for rules that must inspect what a
/// `format!` assembles. `comment` is everything inside `//`/`/* */`.
#[derive(Debug, Default, Clone)]
struct LineView {
    code: String,
    code_strings: String,
    comment: String,
}

#[derive(Clone, Copy, PartialEq)]
enum Ctx {
    Code,
    Str,
    RawStr(u8),
    BlockComment(u32),
}

/// Split a whole file; handles multi-line strings and nested block
/// comments across line boundaries.
fn classify(text: &str) -> Vec<LineView> {
    let mut out = Vec::new();
    let mut ctx = Ctx::Code;
    for line in text.lines() {
        let mut v = LineView::default();
        let bytes: Vec<char> = line.chars().collect();
        let mut i = 0;
        let n = bytes.len();
        let mut line_comment = false;
        while i < n {
            let c = bytes[i];
            let next = if i + 1 < n { bytes[i + 1] } else { '\0' };
            match ctx {
                Ctx::BlockComment(depth) => {
                    if c == '*' && next == '/' {
                        v.comment.push_str("*/");
                        i += 2;
                        ctx = if depth > 1 { Ctx::BlockComment(depth - 1) } else { Ctx::Code };
                    } else if c == '/' && next == '*' {
                        v.comment.push_str("/*");
                        i += 2;
                        ctx = Ctx::BlockComment(depth + 1);
                    } else {
                        v.comment.push(c);
                        i += 1;
                    }
                }
                Ctx::Str => {
                    v.code_strings.push(c);
                    if c == '\\' {
                        if i + 1 < n {
                            v.code_strings.push(next);
                        }
                        v.code.push(' ');
                        v.code.push(' ');
                        i += 2;
                    } else if c == '"' {
                        v.code.push('"');
                        i += 1;
                        ctx = Ctx::Code;
                    } else {
                        v.code.push(' ');
                        i += 1;
                    }
                }
                Ctx::RawStr(hashes) => {
                    // a raw string ends at `"` followed by `hashes` #s
                    if c == '"' {
                        let mut k = 0usize;
                        while k < hashes as usize && i + 1 + k < n && bytes[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes as usize {
                            v.code_strings.push('"');
                            v.code.push('"');
                            for _ in 0..k {
                                v.code_strings.push('#');
                                v.code.push('#');
                            }
                            i += 1 + k;
                            ctx = Ctx::Code;
                            continue;
                        }
                    }
                    v.code_strings.push(c);
                    v.code.push(' ');
                    i += 1;
                }
                Ctx::Code => {
                    if line_comment {
                        v.comment.push(c);
                        i += 1;
                    } else if c == '/' && next == '/' {
                        line_comment = true;
                        v.comment.push_str("//");
                        i += 2;
                    } else if c == '/' && next == '*' {
                        ctx = Ctx::BlockComment(1);
                        v.comment.push_str("/*");
                        i += 2;
                    } else if c == '"' {
                        v.code.push('"');
                        v.code_strings.push('"');
                        ctx = Ctx::Str;
                        i += 1;
                    } else if c == 'r' && (next == '"' || next == '#') {
                        // raw string r"..." / r#"..."# (or an identifier
                        // like `r#foo`; the quote check below settles it)
                        let mut k = 0usize;
                        while i + 1 + k < n && bytes[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if i + 1 + k < n && bytes[i + 1 + k] == '"' {
                            v.code.push('r');
                            v.code_strings.push('r');
                            for _ in 0..k {
                                v.code.push('#');
                                v.code_strings.push('#');
                            }
                            v.code.push('"');
                            v.code_strings.push('"');
                            ctx = Ctx::RawStr(k as u8);
                            i += 2 + k;
                        } else {
                            v.code.push(c);
                            v.code_strings.push(c);
                            i += 1;
                        }
                    } else if c == '\'' {
                        // char literal vs lifetime: a literal closes
                        // within a few chars (`'x'`, `'\n'`, `'\\''`)
                        let lit_len = if next == '\\' && i + 3 < n && bytes[i + 3] == '\'' {
                            Some(4)
                        } else if i + 2 < n && next != '\\' && bytes[i + 2] == '\'' {
                            Some(3)
                        } else {
                            None
                        };
                        match lit_len {
                            Some(l) => {
                                v.code.push('\'');
                                v.code_strings.push('\'');
                                for _ in 1..l - 1 {
                                    v.code.push(' ');
                                    v.code_strings.push(' ');
                                }
                                v.code.push('\'');
                                v.code_strings.push('\'');
                                i += l;
                            }
                            None => {
                                v.code.push(c);
                                v.code_strings.push(c);
                                i += 1;
                            }
                        }
                    } else {
                        v.code.push(c);
                        v.code_strings.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(v);
    }
    out
}

/// Does `hay` contain `needle` as a standalone token (neighbours are not
/// identifier chars)?
fn has_token(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let at = from + pos;
        let before_ok = at == 0
            || !hay[..at].chars().next_back().map_or(false, |c| c.is_alphanumeric() || c == '_');
        let after = at + needle.len();
        let after_ok = after >= hay.len()
            || !hay[after..].chars().next().map_or(false, |c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        from = at + needle.len();
    }
    false
}

// ---------------------------------------------------------------------
// suppression + scan state
// ---------------------------------------------------------------------

/// Rules a `// lint: allow(a, b)` comment names.
fn parse_allows(comment: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = comment[from..].find("lint: allow(") {
        let start = from + pos + "lint: allow(".len();
        if let Some(end) = comment[start..].find(')') {
            for rule in comment[start..start + end].split(',') {
                out.push(rule.trim().to_string());
            }
            from = start + end;
        } else {
            break;
        }
    }
    out
}

/// Scan one file's source text. `path` is the repo-relative path with
/// `/` separators (used for allowlists). Returns findings plus how many
/// would-be findings an inline `lint: allow` suppressed.
pub fn lint_source(path: &str, text: &str) -> (Vec<Finding>, usize) {
    let lines = classify(text);
    let raw: Vec<&str> = text.lines().collect();

    // suppressions: same line or the line immediately after the comment
    let mut allowed: Vec<Vec<String>> = vec![Vec::new(); lines.len() + 1];
    for (i, v) in lines.iter().enumerate() {
        for rule in parse_allows(&v.comment) {
            allowed[i].push(rule.clone());
            if i + 1 < allowed.len() {
                allowed[i + 1].push(rule);
            }
        }
    }

    let in_blas = path.ends_with("field/blas.rs");
    let in_tags = path.ends_with("comm/tags.rs");
    let in_json = path.ends_with("util/json.rs");

    // the escaped-quote-colon JSON signature, built char-wise so this
    // file's own source never matches it
    let json_sig: String = ['\\', '"', ':'].iter().collect();

    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    let mut emit = |rule: &'static str, line_ix: usize, msg: String, allowed: &[String]| {
        if allowed.iter().any(|r| r == rule) {
            suppressed += 1;
        } else {
            findings.push(Finding { rule, file: path.to_string(), line: line_ix + 1, msg });
        }
    };

    let mut test_region = false;
    let mut depth: i32 = 0;
    // (enclosing-depth, name) of each fn we are inside
    let mut fn_stack: Vec<(i32, String)> = Vec::new();

    for (i, v) in lines.iter().enumerate() {
        let code = v.code.as_str();
        if code.contains("#[cfg(test)]") {
            test_region = true;
        }

        // track the enclosing fn name (approximate: formatted code only)
        if let Some(pos) = find_fn_decl(code) {
            let name: String = code[pos..]
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                fn_stack.push((depth, name));
            }
        }

        // rule: safety-comment
        if has_token(code, "unsafe") {
            let justified = v.comment.contains("SAFETY")
                || v.comment.contains("# Safety")
                || preceding_comment_has_safety(&lines, &raw, i);
            if !justified {
                emit(
                    "safety-comment",
                    i,
                    "`unsafe` without an adjacent `// SAFETY:` justification".to_string(),
                    &allowed[i],
                );
            }
        }

        // rule: raw-f64-accum
        if !in_blas {
            let fn_ok = fn_stack
                .last()
                .map_or(false, |(_, name)| name.starts_with("reduce_"));
            let lower = code.to_ascii_lowercase();
            let accum = code.contains("+=") || code.contains(".sum(") || code.contains(".sum::<");
            if !fn_ok && accum && lower.contains("partial") {
                emit(
                    "raw-f64-accum",
                    i,
                    "raw accumulation over partials; use field::blas::reduce_partials* \
                     or a reduce_caps* helper (canonical tile-order grouping)"
                        .to_string(),
                    &allowed[i],
                );
            }
        }

        // rule: tag-registry
        if !in_tags && !test_region {
            let despaced: String = code.chars().filter(|c| !c.is_whitespace()).collect();
            let ck_shift = ["<<", "63"].concat();
            if despaced.contains(&ck_shift) {
                emit(
                    "tag-registry",
                    i,
                    "bit-63 tag namespace minted outside comm::tags (use tags::ckpt_buddy)"
                        .to_string(),
                    &allowed[i],
                );
            }
            let tag_fn = ["fn", "tag("].concat();
            let tag_fn_multi = ["fn", "tag_multi("].concat();
            if despaced.contains(&tag_fn) || despaced.contains(&tag_fn_multi) {
                emit(
                    "tag-registry",
                    i,
                    "tag-constructor fn declared outside comm::tags".to_string(),
                    &allowed[i],
                );
            }
        }

        // rule: adhoc-json (string contents count, comments do not)
        if !in_json && !test_region && v.code_strings.contains(&json_sig) {
            emit(
                "adhoc-json",
                i,
                "hand-assembled JSON string; emit through util::json::JsonWriter".to_string(),
                &allowed[i],
            );
        }

        // update depth last so a fn declared on this line scopes its body
        for c in code.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        while fn_stack.last().map_or(false, |(d, _)| depth <= *d) {
            fn_stack.pop();
        }
    }

    (findings, suppressed)
}

/// Position just past `fn ` in a declaration, if the line declares one.
fn find_fn_decl(code: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(pos) = code[from..].find("fn ") {
        let at = from + pos;
        let before_ok = at == 0
            || !code[..at].chars().next_back().map_or(false, |c| c.is_alphanumeric() || c == '_');
        if before_ok {
            return Some(at + 3);
        }
        from = at + 3;
    }
    None
}

/// Walk upward over the contiguous comment/attribute block above line
/// `i` looking for a SAFETY justification.
fn preceding_comment_has_safety(lines: &[LineView], raw: &[&str], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let v = &lines[j];
        let code_trim = v.code.trim();
        let is_attr = code_trim.starts_with("#[") || code_trim.starts_with("#!");
        let comment_only = code_trim.is_empty() && !v.comment.is_empty();
        let blank = raw[j].trim().is_empty();
        if v.comment.contains("SAFETY") || v.comment.contains("# Safety") {
            return true;
        }
        if blank || (!comment_only && !is_attr) {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------------
// config-doc (cross-file)
// ---------------------------------------------------------------------

/// Keys `config/run.rs` reads, with the line each first appears on.
pub fn parsed_config_keys(run_rs: &str) -> Vec<(String, usize)> {
    let lines = classify(run_rs);
    let mut out: Vec<(String, usize)> = Vec::new();
    for (i, v) in lines.iter().enumerate() {
        let s = &v.code_strings;
        for pat in ["get(\"", "_or(\""] {
            let mut from = 0;
            while let Some(pos) = s[from..].find(pat) {
                let start = from + pos + pat.len();
                if let Some(end) = s[start..].find('"') {
                    let key = &s[start..start + end];
                    let valid = !key.is_empty()
                        && key
                            .chars()
                            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '.');
                    if valid && !out.iter().any(|(k, _)| k == key) {
                        out.push((key.to_string(), i + 1));
                    }
                    from = start + end;
                } else {
                    break;
                }
            }
        }
    }
    out
}

/// Keys `configs/example.toml` documents: active *or* commented-out
/// (`#key = ...` under a `[section]` / `#[section]` header counts —
/// the doc requirement is that the key is discoverable, not enabled).
pub fn documented_toml_keys(toml: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut section = String::new();
    for line in toml.lines() {
        let mut t = line.trim();
        while let Some(rest) = t.strip_prefix('#') {
            t = rest.trim();
        }
        if let Some(rest) = t.strip_prefix('[') {
            if let Some(end) = rest.find(']') {
                section = rest[..end].trim().to_string();
            }
            continue;
        }
        if let Some(eq) = t.find('=') {
            let key: String = t[..eq].trim().to_string();
            let valid = !key.is_empty()
                && key
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
            if valid {
                let full = if section.is_empty() {
                    key
                } else {
                    format!("{section}.{key}")
                };
                if !out.contains(&full) {
                    out.push(full);
                }
            }
        }
    }
    out
}

/// The `config-doc` rule: every parsed key must be documented.
pub fn check_config_doc(run_rs_path: &str, run_rs: &str, example_toml: &str) -> Vec<Finding> {
    let documented = documented_toml_keys(example_toml);
    parsed_config_keys(run_rs)
        .into_iter()
        .filter(|(key, _)| !documented.iter().any(|d| d == key))
        .map(|(key, line)| Finding {
            rule: "config-doc",
            file: run_rs_path.to_string(),
            line,
            msg: format!("config key {key:?} is parsed here but not documented in configs/example.toml"),
        })
        .collect()
}

// ---------------------------------------------------------------------
// tree walk
// ---------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("lint: cannot read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("lint: walk error under {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map_or(false, |x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan the whole tree rooted at the repo checkout (`rust/src` sources
/// plus the `config-doc` cross-check against `configs/example.toml`).
pub fn lint_tree(repo_root: &Path) -> Result<LintReport, String> {
    let src = repo_root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files)?;
    files.sort();

    let mut report = LintReport::default();
    let mut run_rs: Option<(String, String)> = None;
    for file in &files {
        let rel = file
            .strip_prefix(repo_root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(file)
            .map_err(|e| format!("lint: cannot read {}: {e}", file.display()))?;
        if rel.ends_with("config/run.rs") {
            run_rs = Some((rel.clone(), text.clone()));
        }
        let (findings, suppressed) = lint_source(&rel, &text);
        report.findings.extend(findings);
        report.suppressed += suppressed;
        report.files_scanned += 1;
    }

    if let Some((rel, text)) = run_rs {
        let toml_path = repo_root.join("configs").join("example.toml");
        let toml = fs::read_to_string(&toml_path)
            .map_err(|e| format!("lint: cannot read {}: {e}", toml_path.display()))?;
        report.findings.extend(check_config_doc(&rel, &text, &toml));
    }

    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}
