//! In-tree correctness tooling: a zero-dependency invariant linter for
//! the source tree ([`lint`]) and a deterministic model checker for the
//! team/comm/telemetry concurrency protocols ([`model`]).
//!
//! Both are wired into the `lqcd lint` subcommand and run as a CI gate;
//! see ARCHITECTURE.md "Correctness tooling" for the rule table and the
//! checker's scope and bounds.

pub mod lint;
pub mod model;

pub use lint::{lint_tree, Finding, LintReport};
pub use model::{check, run_suite, CheckOpts, CheckReport};
