//! A loom-style deterministic model checker: small explicit-state models
//! of the tree's lock-free protocols, explored exhaustively by a DFS
//! scheduler with a bounded number of preemptions.
//!
//! Each model is a hand-written state machine whose `step(tid)` performs
//! exactly one shared-memory action (one atomic load, store or RMW of
//! the real protocol), so every interleaving the hardware could produce
//! at that granularity corresponds to one DFS schedule. The checker
//! explores them all (deduplicating states by fingerprint), detecting
//!
//! * assertion violations inside a step (torn publish, early release),
//! * lost signals: no thread runnable but the model is not finished
//!   (this is exactly what a lost wakeup looks like — a waiter parked
//!   on a condition nobody will ever flip again),
//! * bad final states (`finale`).
//!
//! Modelled protocols (see the shipping code for the real thing):
//!
//! * [`BarrierModel`] — `coordinator::team::TeamBarrier`: sense-reversing
//!   count/generation barrier, both barrier kinds, reused across
//!   iterations. A seeded [`BarrierBug::LostWakeup`] mutant (sampling
//!   the generation *after* registering arrival) must be caught.
//! * [`RingModel`] — `perf::telemetry::Ring`: single-writer span buffer
//!   with saturating drop-count, drained after quiesce. A seeded
//!   [`RingVariant::TornPublish`] mutant (publishing the length before
//!   the record, with an eager drain) must be caught.
//! * [`RecvModel`] — `comm::world::Comm::recv`: per-(peer, tag) sequence
//!   numbers, out-of-order pending stash, duplicate drop, and the
//!   retransmit-store fetch on a gap — no loss, no reorder, no
//!   duplication under any schedule.
//!
//! Scheduling bound: preemptions (switching away from a thread that
//! could still run) are capped, as in CHESS-style checkers — every
//! schedule with at most that many preemptions is covered. Voluntary
//! switches (the running thread blocks or finishes) are free, so the
//! bound never hides a deadlock.

use std::collections::HashSet;

/// A small concurrent protocol model. One `step` = one shared-memory
/// action; `enabled` gates blocked threads (a parked waiter whose wake
/// condition is false is simply not enabled).
pub trait Model: Clone {
    fn nthreads(&self) -> usize;
    /// Thread finished its whole program.
    fn done(&self, tid: usize) -> bool;
    /// Thread could take a step right now (false = blocked).
    fn enabled(&self, tid: usize) -> bool;
    /// Perform thread `tid`'s next action. `Err` = invariant violated.
    fn step(&mut self, tid: usize) -> Result<(), String>;
    /// Check the final state once every thread is done.
    fn finale(&self) -> Result<(), String>;
    /// Serialize the complete state (for fingerprint deduplication).
    fn encode(&self, out: &mut Vec<u64>);
}

/// Checker options. `max_preemptions` bounds forced context switches per
/// schedule; 4 is exhaustive-in-practice for these model sizes while
/// keeping the state space in the tens of thousands.
#[derive(Clone, Copy, Debug)]
pub struct CheckOpts {
    pub max_preemptions: usize,
}

impl Default for CheckOpts {
    fn default() -> CheckOpts {
        CheckOpts { max_preemptions: 4 }
    }
}

/// A violating schedule: which thread stepped, in order, plus what broke.
#[derive(Clone, Debug)]
pub struct Violation {
    pub message: String,
    pub schedule: Vec<usize>,
}

/// What the exploration covered and whether anything broke.
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    /// complete schedules that ran to a checked final state
    pub schedules: u64,
    /// distinct (state, scheduler) points visited
    pub states: u64,
    pub violation: Option<Violation>,
}

impl CheckReport {
    pub fn passed(&self) -> bool {
        self.violation.is_none()
    }
}

fn fnv1a(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Exhaustively explore every schedule of `model` within the preemption
/// bound. Stops at the first violation (its schedule is reported).
pub fn check<M: Model>(model: &M, opts: &CheckOpts) -> CheckReport {
    let mut report = CheckReport::default();
    let mut memo: HashSet<u64> = HashSet::new();
    let mut trace: Vec<usize> = Vec::new();
    dfs(model, None, opts.max_preemptions, &mut memo, &mut trace, &mut report);
    report
}

fn dfs<M: Model>(
    m: &M,
    cur: Option<usize>,
    budget: usize,
    memo: &mut HashSet<u64>,
    trace: &mut Vec<usize>,
    report: &mut CheckReport,
) -> bool {
    let n = m.nthreads();
    if (0..n).all(|t| m.done(t)) {
        report.schedules += 1;
        if let Err(msg) = m.finale() {
            report.violation = Some(Violation { message: format!("final state: {msg}"), schedule: trace.clone() });
            return true;
        }
        return false;
    }

    let enabled: Vec<usize> = (0..n).filter(|&t| !m.done(t) && m.enabled(t)).collect();
    if enabled.is_empty() {
        let blocked: Vec<String> =
            (0..n).filter(|&t| !m.done(t)).map(|t| format!("t{t}")).collect();
        report.violation = Some(Violation {
            message: format!("lost signal: {} blocked forever (deadlock)", blocked.join(", ")),
            schedule: trace.clone(),
        });
        return true;
    }

    let mut key = Vec::with_capacity(16);
    m.encode(&mut key);
    key.push(cur.map_or(u64::MAX, |c| c as u64));
    key.push(budget as u64);
    if !memo.insert(fnv1a(&key)) {
        return false;
    }
    report.states += 1;

    for &t in &enabled {
        // switching away from a thread that could still run costs one
        // preemption; taking over from a blocked/done thread is free
        let cost = match cur {
            Some(c) if c != t && !m.done(c) && m.enabled(c) => 1,
            _ => 0,
        };
        if cost > budget {
            continue;
        }
        let mut next = m.clone();
        trace.push(t);
        if let Err(msg) = next.step(t) {
            report.violation = Some(Violation { message: msg, schedule: trace.clone() });
            return true;
        }
        if dfs(&next, Some(t), budget - cost, memo, trace, report) {
            return true;
        }
        trace.pop();
    }
    false
}

// ---------------------------------------------------------------------
// TeamBarrier model
// ---------------------------------------------------------------------

/// Mirror of `coordinator::BarrierKind` (redeclared so the models stay
/// a closed, dependency-free world).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BarrierKind {
    /// waiters re-check the generation in a spin loop
    Spin,
    /// waiters check once, then park until the generation moves (the
    /// condvar path; the check→park window is modelled as two steps)
    Sleep,
}

/// Seeded barrier mutants the checker must provably catch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BarrierBug {
    /// sample the generation *after* registering arrival — the classic
    /// lost wakeup: the last arrival can bump the generation in the
    /// window, and the waiter then waits for a change that already
    /// happened
    LostWakeup,
}

/// Per-thread program counter for [`BarrierModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BPc {
    /// about to sample the generation (shipping order)
    LoadGen,
    /// sampled `gen`, about to `count.fetch_add`
    Add { gen: u64 },
    /// mutant order: about to `count.fetch_add` *first*
    AddFirst,
    /// mutant order: arrived, about to sample the generation — races
    /// the last arrival's bump
    LoadGenLate,
    /// last arrival: about to reset the count
    Reset,
    /// last arrival: about to bump the generation
    Bump,
    /// spinning until `generation != gen`
    SpinWait { gen: u64 },
    /// sleep kind: about to test the condition before parking
    SleepCheck { gen: u64 },
    /// parked until `generation != gen`
    Parked { gen: u64 },
    /// all iterations complete
    Finished,
}

/// Small model of `TeamBarrier::wait`, reused for `iters` iterations by
/// `n` threads (sense reversal: the count resets, the generation is the
/// sense).
#[derive(Clone)]
pub struct BarrierModel {
    n: usize,
    iters: u64,
    kind: BarrierKind,
    bug: Option<BarrierBug>,
    count: u64,
    generation: u64,
    /// arrivals registered per iteration (checker bookkeeping)
    arrivals: Vec<u64>,
    pc: Vec<BPc>,
    iter: Vec<u64>,
}

impl BarrierModel {
    pub fn new(n: usize, iters: u64, kind: BarrierKind, bug: Option<BarrierBug>) -> BarrierModel {
        BarrierModel {
            n,
            iters,
            kind,
            bug,
            count: 0,
            generation: 0,
            arrivals: vec![0; iters as usize],
            pc: vec![if bug.is_some() { BPc::AddFirst } else { BPc::LoadGen }; n],
            iter: vec![0; n],
        }
    }

    fn start_pc(&self) -> BPc {
        match self.bug {
            // the mutant arrives first and samples the generation late
            Some(BarrierBug::LostWakeup) => BPc::AddFirst,
            None => BPc::LoadGen,
        }
    }

    /// Register the arrival; `Ok(true)` means this was the last one.
    fn arrive(&mut self, tid: usize) -> Result<bool, String> {
        self.count += 1;
        let it = self.iter[tid] as usize;
        self.arrivals[it] += 1;
        if self.count > self.n as u64 {
            return Err(format!(
                "torn reuse: {} arrivals on a barrier of {} (count not reset before reuse)",
                self.count, self.n
            ));
        }
        Ok(self.count == self.n as u64)
    }

    fn release(&mut self, tid: usize) -> Result<(), String> {
        let it = self.iter[tid] as usize;
        if self.arrivals[it] != self.n as u64 {
            return Err(format!(
                "early release: thread {tid} passed barrier iteration {it} after only {}/{} arrivals",
                self.arrivals[it], self.n
            ));
        }
        self.iter[tid] += 1;
        self.pc[tid] = if self.iter[tid] == self.iters { BPc::Finished } else { self.start_pc() };
        Ok(())
    }
}

impl BarrierModel {
    fn wait_pc(&self, gen: u64) -> BPc {
        match self.kind {
            BarrierKind::Spin => BPc::SpinWait { gen },
            BarrierKind::Sleep => BPc::SleepCheck { gen },
        }
    }
}

impl Model for BarrierModel {
    fn nthreads(&self) -> usize {
        self.n
    }

    fn done(&self, tid: usize) -> bool {
        self.pc[tid] == BPc::Finished
    }

    fn enabled(&self, tid: usize) -> bool {
        match self.pc[tid] {
            BPc::SpinWait { gen } | BPc::Parked { gen } => self.generation != gen,
            BPc::Finished => false,
            _ => true,
        }
    }

    fn step(&mut self, tid: usize) -> Result<(), String> {
        match self.pc[tid] {
            BPc::LoadGen => {
                self.pc[tid] = BPc::Add { gen: self.generation };
                Ok(())
            }
            BPc::Add { gen } => {
                let last = self.arrive(tid)?;
                self.pc[tid] = if last { BPc::Reset } else { self.wait_pc(gen) };
                Ok(())
            }
            BPc::AddFirst => {
                // mutant: register arrival first; the generation sample
                // comes later, racing the last arrival's bump
                let last = self.arrive(tid)?;
                self.pc[tid] = if last { BPc::Reset } else { BPc::LoadGenLate };
                Ok(())
            }
            BPc::LoadGenLate => {
                let gen = self.generation;
                self.pc[tid] = self.wait_pc(gen);
                Ok(())
            }
            BPc::Reset => {
                self.count = 0;
                self.pc[tid] = BPc::Bump;
                Ok(())
            }
            BPc::Bump => {
                self.generation += 1;
                self.release(tid)
            }
            BPc::SpinWait { gen } => {
                debug_assert!(self.generation != gen, "stepped a blocked spinner");
                self.release(tid)
            }
            BPc::SleepCheck { gen } => {
                if self.generation != gen {
                    self.release(tid)
                } else {
                    // condition still false: park (the lost-wakeup
                    // window between the check and the park)
                    self.pc[tid] = BPc::Parked { gen };
                    Ok(())
                }
            }
            BPc::Parked { gen } => {
                debug_assert!(self.generation != gen, "woke a parked waiter early");
                self.release(tid)
            }
            BPc::Finished => Err(format!("stepped finished thread {tid}")),
        }
    }

    fn finale(&self) -> Result<(), String> {
        if self.count != 0 {
            return Err(format!("count {} left after final release (expected 0)", self.count));
        }
        if self.generation != self.iters {
            return Err(format!(
                "generation {} after {} iterations (one bump per iteration expected)",
                self.generation, self.iters
            ));
        }
        for (it, &a) in self.arrivals.iter().enumerate() {
            if a != self.n as u64 {
                return Err(format!("iteration {it} saw {a}/{} arrivals", self.n));
            }
        }
        Ok(())
    }

    fn encode(&self, out: &mut Vec<u64>) {
        out.push(self.count);
        out.push(self.generation);
        for &a in &self.arrivals {
            out.push(a);
        }
        for (tid, pc) in self.pc.iter().enumerate() {
            out.push(self.iter[tid]);
            out.push(match *pc {
                BPc::LoadGen => 1,
                BPc::Add { gen } => 2 | (gen << 8),
                BPc::AddFirst => 3,
                BPc::LoadGenLate => 4,
                BPc::Reset => 5,
                BPc::Bump => 6,
                BPc::SpinWait { gen } => 7 | (gen << 8),
                BPc::SleepCheck { gen } => 8 | (gen << 8),
                BPc::Parked { gen } => 9 | (gen << 8),
                BPc::Finished => 10,
            });
        }
    }
}

// ---------------------------------------------------------------------
// telemetry span-ring model
// ---------------------------------------------------------------------

/// Which ring protocol to model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingVariant {
    /// the shipping protocol: record fully written, then the length
    /// published; the drain runs only after the writer quiesces
    Shipping,
    /// seeded mutant: length published *before* the record is written,
    /// and the drain may run concurrently — the checker must observe a
    /// torn (unwritten) record under some schedule
    TornPublish,
}

/// Model of one `perf::telemetry::Ring` per writer: bounded span buffer
/// with a saturating drop counter, drained once. Thread `nwriters` is
/// the drainer.
#[derive(Clone)]
pub struct RingModel {
    variant: RingVariant,
    cap: usize,
    to_write: Vec<usize>,
    // per ring: published records, staged-but-unpublished value,
    // published length, drop count, writer progress, quiesced flag
    slots: Vec<Vec<u64>>,
    staged: Vec<Option<u64>>,
    len: Vec<usize>,
    dropped: Vec<u64>,
    written: Vec<usize>,
    stage: Vec<u8>,
    quiesced: Vec<bool>,
    drained: bool,
}

impl RingModel {
    /// `to_write[w]` spans pushed by writer `w` into its own ring of
    /// capacity `cap` (values `1..=to_write[w]`, so a torn slot reads 0).
    pub fn new(variant: RingVariant, cap: usize, to_write: &[usize]) -> RingModel {
        let nw = to_write.len();
        RingModel {
            variant,
            cap,
            to_write: to_write.to_vec(),
            slots: vec![vec![0; cap]; nw],
            staged: vec![None; nw],
            len: vec![0; nw],
            dropped: vec![0; nw],
            written: vec![0; nw],
            stage: vec![0; nw],
            quiesced: vec![false; nw],
            drained: false,
        }
    }

    fn nwriters(&self) -> usize {
        self.to_write.len()
    }
}

impl Model for RingModel {
    fn nthreads(&self) -> usize {
        self.nwriters() + 1
    }

    fn done(&self, tid: usize) -> bool {
        if tid < self.nwriters() {
            self.quiesced[tid]
        } else {
            self.drained
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        if tid < self.nwriters() {
            !self.quiesced[tid]
        } else if self.drained {
            false
        } else {
            match self.variant {
                // the shipping drain quiesces the team first
                RingVariant::Shipping => self.quiesced.iter().all(|&q| q),
                RingVariant::TornPublish => true,
            }
        }
    }

    fn step(&mut self, tid: usize) -> Result<(), String> {
        let w = tid;
        if w < self.nwriters() {
            if self.written[w] == self.to_write[w] {
                self.quiesced[w] = true;
                return Ok(());
            }
            let value = (self.written[w] + 1) as u64;
            match (self.stage[w], self.variant) {
                (0, _) if self.len[w] >= self.cap => {
                    // over capacity: count the drop (single step — the
                    // shipping code is one saturating fetch_add)
                    self.dropped[w] += 1;
                    self.written[w] += 1;
                }
                (0, RingVariant::Shipping) => {
                    // write the record fully...
                    self.staged[w] = Some(value);
                    self.stage[w] = 1;
                }
                (1, RingVariant::Shipping) => {
                    // ...then publish the length
                    let v = self.staged[w].take().ok_or("publish with nothing staged")?;
                    self.slots[w][self.len[w]] = v;
                    self.len[w] += 1;
                    self.written[w] += 1;
                    self.stage[w] = 0;
                }
                (0, RingVariant::TornPublish) => {
                    // mutant: bump the length first — the slot is
                    // visible to a concurrent drain before it is written
                    self.len[w] += 1;
                    self.staged[w] = Some(value);
                    self.stage[w] = 1;
                }
                (1, RingVariant::TornPublish) => {
                    let v = self.staged[w].take().ok_or("publish with nothing staged")?;
                    self.slots[w][self.len[w] - 1] = v;
                    self.written[w] += 1;
                    self.stage[w] = 0;
                }
                _ => return Err("writer in impossible stage".to_string()),
            }
            Ok(())
        } else {
            // drain: read every ring's published prefix + drop count
            for r in 0..self.nwriters() {
                let kept = self.len[r];
                for (k, &v) in self.slots[r][..kept].iter().enumerate() {
                    if v != (k + 1) as u64 {
                        return Err(format!(
                            "torn publish: ring {r} slot {k} drained as {v} (expected {})",
                            k + 1
                        ));
                    }
                }
                let expect_kept = self.to_write[r].min(self.cap);
                if self.quiesced[r]
                    && (kept != expect_kept
                        || self.dropped[r] != (self.to_write[r] - expect_kept) as u64)
                {
                    return Err(format!(
                        "drop accounting: ring {r} drained {kept} records + {} drops (expected {expect_kept} + {})",
                        self.dropped[r],
                        self.to_write[r] - expect_kept
                    ));
                }
            }
            self.drained = true;
            Ok(())
        }
    }

    fn finale(&self) -> Result<(), String> {
        if !self.drained {
            return Err("nothing drained".to_string());
        }
        Ok(())
    }

    fn encode(&self, out: &mut Vec<u64>) {
        out.push(u64::from(self.drained));
        for w in 0..self.nwriters() {
            out.push(self.written[w] as u64);
            out.push(self.len[w] as u64);
            out.push(self.dropped[w]);
            out.push(u64::from(self.stage[w]));
            out.push(self.staged[w].unwrap_or(0));
            out.push(u64::from(self.quiesced[w]));
            for &s in &self.slots[w] {
                out.push(s);
            }
        }
    }
}

// ---------------------------------------------------------------------
// retransmit-store recv state machine
// ---------------------------------------------------------------------

/// Transport fault injected into [`RecvModel`]'s sender.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvFault {
    None,
    /// message `i` reaches the retransmit store but never the channel
    /// (the receiver must heal it via the deadline → store fetch path)
    Drop(usize),
    /// message `i` arrives twice (the second copy must be discarded by
    /// the sequence check)
    Duplicate(usize),
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum SendPc {
    Store(usize),
    Transmit(usize),
    TransmitDup(usize),
    MarkDone,
    Done,
}

/// Model of the `Comm::recv` sequencing state machine against one
/// sender: in-order channel with gaps/duplicates, per-pair expected
/// sequence number, pending stash for early messages, duplicate drop,
/// and the retransmit-store fetch once the channel is exhausted (the
/// model's stand-in for the recv deadline expiring).
#[derive(Clone)]
pub struct RecvModel {
    k: usize,
    fault: RecvFault,
    channel: Vec<u64>,
    store: Vec<bool>,
    sender: SendPc,
    expect: u64,
    pending: Vec<u64>,
    got: Vec<u64>,
    fetches: u64,
    dup_drops: u64,
}

impl RecvModel {
    /// `k` messages (seq `0..k`) from one sender under `fault`.
    pub fn new(k: usize, fault: RecvFault) -> RecvModel {
        RecvModel {
            k,
            fault,
            channel: Vec::new(),
            store: vec![false; k],
            sender: if k == 0 { SendPc::MarkDone } else { SendPc::Store(0) },
            expect: 0,
            pending: Vec::new(),
            got: Vec::new(),
            fetches: 0,
            dup_drops: 0,
        }
    }

    fn sender_done(&self) -> bool {
        self.sender == SendPc::Done
    }

    fn accept(&mut self, seq: u64) {
        self.got.push(seq);
        self.expect += 1;
    }
}

impl Model for RecvModel {
    fn nthreads(&self) -> usize {
        2
    }

    fn done(&self, tid: usize) -> bool {
        match tid {
            0 => self.sender_done(),
            // the receiver also drains trailing duplicates, so a late
            // copy is visibly dropped rather than left in flight
            _ => self.expect as usize >= self.k && self.channel.is_empty() && self.sender_done(),
        }
    }

    fn enabled(&self, tid: usize) -> bool {
        match tid {
            0 => !self.sender_done(),
            _ => {
                if self.expect as usize >= self.k {
                    // only leftover traffic remains
                    return !self.channel.is_empty();
                }
                // runnable when something can make progress; otherwise
                // the receiver is inside its recv deadline, blocked
                self.pending.contains(&self.expect)
                    || !self.channel.is_empty()
                    || self.sender_done()
            }
        }
    }

    fn step(&mut self, tid: usize) -> Result<(), String> {
        if tid == 0 {
            self.sender = match self.sender {
                // the shipping send records the payload in the
                // retransmit store before transmitting
                SendPc::Store(i) => {
                    self.store[i] = true;
                    match self.fault {
                        RecvFault::Drop(d) if d == i => {
                            if i + 1 < self.k {
                                SendPc::Store(i + 1)
                            } else {
                                SendPc::MarkDone
                            }
                        }
                        _ => SendPc::Transmit(i),
                    }
                }
                SendPc::Transmit(i) => {
                    self.channel.push(i as u64);
                    match self.fault {
                        RecvFault::Duplicate(d) if d == i => SendPc::TransmitDup(i),
                        _ if i + 1 < self.k => SendPc::Store(i + 1),
                        _ => SendPc::MarkDone,
                    }
                }
                SendPc::TransmitDup(i) => {
                    self.channel.push(i as u64);
                    if i + 1 < self.k {
                        SendPc::Store(i + 1)
                    } else {
                        SendPc::MarkDone
                    }
                }
                SendPc::MarkDone => SendPc::Done,
                SendPc::Done => return Err("stepped finished sender".to_string()),
            };
            return Ok(());
        }

        // receiver
        if let Some(pos) = self.pending.iter().position(|&s| s == self.expect) {
            let seq = self.pending.remove(pos);
            self.accept(seq);
            return Ok(());
        }
        if !self.channel.is_empty() {
            let seq = self.channel.remove(0);
            if seq < self.expect {
                self.dup_drops += 1;
            } else if seq == self.expect {
                self.accept(seq);
            } else {
                if self.pending.contains(&seq) {
                    return Err(format!("pending stash already holds seq {seq}"));
                }
                self.pending.push(seq);
            }
            return Ok(());
        }
        // channel empty and the sender is finished: the recv deadline
        // expires and the transport falls back to the retransmit store
        let want = self.expect as usize;
        if !self.store[want] {
            return Err(format!("lost message: seq {want} in neither channel nor store"));
        }
        self.fetches += 1;
        self.accept(want as u64);
        Ok(())
    }

    fn finale(&self) -> Result<(), String> {
        let want: Vec<u64> = (0..self.k as u64).collect();
        if self.got != want {
            return Err(format!("delivered {:?}, expected {want:?} (loss or reorder)", self.got));
        }
        let expect_dups = u64::from(matches!(self.fault, RecvFault::Duplicate(_)));
        if self.dup_drops != expect_dups {
            return Err(format!("{} duplicate drops, expected {expect_dups}", self.dup_drops));
        }
        if !self.pending.is_empty() {
            return Err(format!("{} messages stranded in the pending stash", self.pending.len()));
        }
        if matches!(self.fault, RecvFault::Drop(_)) && self.fetches == 0 {
            return Err("dropped message was never healed from the store".to_string());
        }
        Ok(())
    }

    fn encode(&self, out: &mut Vec<u64>) {
        out.push(self.expect);
        out.push(self.fetches);
        out.push(self.dup_drops);
        out.push(match self.sender {
            SendPc::Store(i) => 1 | ((i as u64) << 8),
            SendPc::Transmit(i) => 2 | ((i as u64) << 8),
            SendPc::TransmitDup(i) => 3 | ((i as u64) << 8),
            SendPc::MarkDone => 4,
            SendPc::Done => 5,
        });
        out.push(self.channel.len() as u64);
        out.extend_from_slice(&self.channel);
        out.push(self.pending.len() as u64);
        out.extend_from_slice(&self.pending);
        for &b in &self.store {
            out.push(u64::from(b));
        }
        out.push(self.got.len() as u64);
    }
}

// ---------------------------------------------------------------------
// the standard suite
// ---------------------------------------------------------------------

/// One suite entry: model name, whether a violation is the *expected*
/// outcome (seeded mutants), and what actually happened.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub name: &'static str,
    pub expect_violation: bool,
    pub report: CheckReport,
}

impl SuiteResult {
    pub fn ok(&self) -> bool {
        self.report.passed() != self.expect_violation
    }
}

/// The checked configurations `lqcd lint --model-check` runs: every
/// shipping protocol at 2 and 3 threads must pass exhaustively, and
/// every seeded mutant must be caught.
pub fn run_suite(opts: &CheckOpts) -> Vec<SuiteResult> {
    let mut out = Vec::new();
    let mut push = |name, expect_violation, report| {
        out.push(SuiteResult { name, expect_violation, report });
    };

    for &(n, iters) in &[(2usize, 3u64), (3, 2)] {
        for &kind in &[BarrierKind::Spin, BarrierKind::Sleep] {
            let name = match (n, kind) {
                (2, BarrierKind::Spin) => "barrier/spin/2x3",
                (2, BarrierKind::Sleep) => "barrier/sleep/2x3",
                (3, BarrierKind::Spin) => "barrier/spin/3x2",
                _ => "barrier/sleep/3x2",
            };
            push(name, false, check(&BarrierModel::new(n, iters, kind, None), opts));
        }
    }
    push(
        "barrier/mutant-lost-wakeup/2x1",
        true,
        check(&BarrierModel::new(2, 1, BarrierKind::Spin, Some(BarrierBug::LostWakeup)), opts),
    );
    push(
        "barrier/mutant-lost-wakeup/sleep/3x1",
        true,
        check(&BarrierModel::new(3, 1, BarrierKind::Sleep, Some(BarrierBug::LostWakeup)), opts),
    );

    push("ring/1w+drain", false, check(&RingModel::new(RingVariant::Shipping, 2, &[4]), opts));
    push(
        "ring/2w+drain",
        false,
        check(&RingModel::new(RingVariant::Shipping, 2, &[3, 2]), opts),
    );
    push(
        "ring/mutant-torn-publish",
        true,
        check(&RingModel::new(RingVariant::TornPublish, 2, &[2]), opts),
    );

    push("recv/clean", false, check(&RecvModel::new(3, RecvFault::None), opts));
    push("recv/drop", false, check(&RecvModel::new(3, RecvFault::Drop(1)), opts));
    push("recv/duplicate", false, check(&RecvModel::new(3, RecvFault::Duplicate(0)), opts));

    out
}
