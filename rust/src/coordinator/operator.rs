//! Linear operators for the iterative solvers.
//!
//! The solvers are generic over [`LinearOperator`]; implementations here
//! wrap the native kernels (single-rank periodic and distributed) — the
//! PJRT-backed operator lives in [`crate::runtime`].

use crate::comm::Comm;
use crate::dslash::{full, HoppingEo};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, Parity};

use super::driver::DistHopping;
use super::profiler::Profiler;
use super::team::Team;

/// An operator on even-parity fermion fields.
pub trait LinearOperator {
    /// out = A psi.
    fn apply(&mut self, out: &mut FermionField, psi: &FermionField);

    /// Flop per application (QXS convention), for harness reporting.
    fn flops_per_apply(&self) -> u64;

    /// Sum a scalar across ranks (identity for single-rank operators).
    fn reduce_sum(&mut self, v: f64) -> f64 {
        v
    }
}

/// Native single-rank M-hat = 1 - kappa^2 H_eo H_oe (Eq. 4 LHS).
pub struct NativeMeo {
    hop: HoppingEo,
    u: GaugeField,
    kappa: f32,
    tmp: FermionField,
    half_volume: usize,
}

impl NativeMeo {
    pub fn new(geom: &Geometry, u: GaugeField, kappa: f32) -> NativeMeo {
        NativeMeo {
            hop: HoppingEo::new(geom),
            u,
            kappa,
            tmp: FermionField::zeros(geom),
            half_volume: geom.local.half_volume(),
        }
    }

    pub fn gauge(&self) -> &GaugeField {
        &self.u
    }

    pub fn hopping(&self) -> &HoppingEo {
        &self.hop
    }

    pub fn kappa(&self) -> f32 {
        self.kappa
    }
}

impl LinearOperator for NativeMeo {
    fn apply(&mut self, out: &mut FermionField, psi: &FermionField) {
        full::meo(&self.hop, out, &mut self.tmp, &self.u, psi, self.kappa);
    }

    fn flops_per_apply(&self) -> u64 {
        crate::dslash::flops::meo_flops(self.half_volume)
    }
}

/// Native single-rank normal operator M-hat^dag M-hat (hermitian positive
/// definite; what CG solves).
pub struct NativeMdagM {
    inner: NativeMeo,
    mid: FermionField,
}

impl NativeMdagM {
    pub fn new(geom: &Geometry, u: GaugeField, kappa: f32) -> NativeMdagM {
        NativeMdagM {
            inner: NativeMeo::new(geom, u, kappa),
            mid: FermionField::zeros(geom),
        }
    }

    pub fn meo(&mut self) -> &mut NativeMeo {
        &mut self.inner
    }
}

impl LinearOperator for NativeMdagM {
    fn apply(&mut self, out: &mut FermionField, psi: &FermionField) {
        // mid = M psi ; out = g5 M g5 mid
        let mut m_psi = std::mem::replace(&mut self.mid, FermionField::zeros_like_hack());
        self.inner.apply(&mut m_psi, psi);
        m_psi.gamma5();
        self.inner.apply(out, &m_psi);
        out.gamma5();
        // undo gamma5 on mid before stashing it back (content irrelevant)
        self.mid = m_psi;
    }

    fn flops_per_apply(&self) -> u64 {
        2 * self.inner.flops_per_apply()
    }
}

impl FermionField {
    /// Internal helper: placeholder value swapped out during MdagM apply.
    fn zeros_like_hack() -> FermionField {
        // an empty field; immediately replaced. Uses a minimal layout.
        FermionField {
            layout: crate::lattice::EoLayout {
                nt: 0,
                nz: 0,
                nyt: 0,
                nxt: 0,
                tiling: crate::lattice::Tiling::new(2, 1).unwrap(),
            },
            data: Vec::new(),
        }
    }
}

/// Distributed M-hat over the rank world: two distributed hoppings plus
/// the axpy; dot-product reductions go through the communicator.
pub struct DistMeo<'a> {
    pub dist: &'a DistHopping,
    pub u: &'a GaugeField,
    pub kappa: f32,
    pub comm: &'a mut Comm,
    pub team: &'a mut Team,
    pub prof: &'a Profiler,
    pub tmp: FermionField,
    half_volume: usize,
}

impl<'a> DistMeo<'a> {
    pub fn new(
        geom: &Geometry,
        dist: &'a DistHopping,
        u: &'a GaugeField,
        kappa: f32,
        comm: &'a mut Comm,
        team: &'a mut Team,
        prof: &'a Profiler,
    ) -> DistMeo<'a> {
        DistMeo {
            dist,
            u,
            kappa,
            comm,
            team,
            prof,
            tmp: FermionField::zeros(geom),
            half_volume: geom.local.half_volume(),
        }
    }
}

impl LinearOperator for DistMeo<'_> {
    fn apply(&mut self, out: &mut FermionField, psi: &FermionField) {
        self.dist
            .hopping(&mut self.tmp, self.u, psi, Parity::Odd, self.comm, self.team, self.prof);
        self.dist
            .hopping(out, self.u, &self.tmp, Parity::Even, self.comm, self.team, self.prof);
        out.xpay(-(self.kappa * self.kappa), psi);
    }

    fn flops_per_apply(&self) -> u64 {
        crate::dslash::flops::meo_flops(self.half_volume)
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.comm.allreduce_sum(v)
    }
}

/// gamma5-wrapped normal operator over any M-hat-like operator: CGNR on
/// the distributed or PJRT operator reuses this.
pub struct NormalOp<A: LinearOperator> {
    pub inner: A,
    mid: FermionField,
}

impl<A: LinearOperator> NormalOp<A> {
    pub fn new(inner: A, geom: &Geometry) -> NormalOp<A> {
        NormalOp {
            inner,
            mid: FermionField::zeros(geom),
        }
    }
}

impl<A: LinearOperator> LinearOperator for NormalOp<A> {
    fn apply(&mut self, out: &mut FermionField, psi: &FermionField) {
        let mut m_psi = std::mem::replace(&mut self.mid, FermionField::zeros_like_hack());
        self.inner.apply(&mut m_psi, psi);
        m_psi.gamma5();
        self.inner.apply(out, &m_psi);
        out.gamma5();
        self.mid = m_psi;
    }

    fn flops_per_apply(&self) -> u64 {
        2 * self.inner.flops_per_apply()
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.inner.reduce_sum(v)
    }
}
