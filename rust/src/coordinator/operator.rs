//! Linear operators for the iterative solvers.
//!
//! The solvers are generic over [`LinearOperator`]; implementations here
//! wrap the native kernels (single-rank periodic and distributed) — the
//! PJRT-backed operator lives in [`crate::runtime`].
//!
//! Every operator is generic over the [`Real`] field scalar (default
//! `f32`): `kappa`, the internal scratch fields and the gauge storage all
//! follow the operator's precision, while `reduce_sum` stays f64 at every
//! precision (global reductions are always accumulated wide).

use crate::algebra::Real;
use crate::comm::{Comm, CommScalar};
use crate::dslash::{full, HoppingEo};
use crate::field::{FermionField, GaugeField};
use crate::lattice::{Geometry, Parity};

use super::driver::DistHopping;
use super::profiler::Profiler;
use super::team::Team;

/// An operator on even-parity fermion fields of precision `R`.
pub trait LinearOperator<R: Real = f32> {
    /// out = A psi.
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>);

    /// Flop per application (QXS convention), for harness reporting.
    fn flops_per_apply(&self) -> u64;

    /// Sum a scalar across ranks (identity for single-rank operators);
    /// always f64 regardless of the field precision.
    fn reduce_sum(&mut self, v: f64) -> f64 {
        v
    }
}

/// Native single-rank M-hat = 1 - kappa^2 H_eo H_oe (Eq. 4 LHS).
pub struct NativeMeo<R: Real = f32> {
    hop: HoppingEo,
    u: GaugeField<R>,
    kappa: R,
    tmp: FermionField<R>,
    half_volume: usize,
}

impl<R: Real> NativeMeo<R> {
    pub fn new(geom: &Geometry, u: GaugeField<R>, kappa: R) -> NativeMeo<R> {
        NativeMeo {
            hop: HoppingEo::new(geom),
            u,
            kappa,
            tmp: FermionField::zeros(geom),
            half_volume: geom.local.half_volume(),
        }
    }

    pub fn gauge(&self) -> &GaugeField<R> {
        &self.u
    }

    pub fn hopping(&self) -> &HoppingEo {
        &self.hop
    }

    pub fn kappa(&self) -> R {
        self.kappa
    }
}

impl<R: Real> LinearOperator<R> for NativeMeo<R> {
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        full::meo(&self.hop, out, &mut self.tmp, &self.u, psi, self.kappa);
    }

    fn flops_per_apply(&self) -> u64 {
        crate::dslash::flops::meo_flops(self.half_volume)
    }
}

/// Native single-rank normal operator M-hat^dag M-hat (hermitian positive
/// definite; what CG solves).
pub struct NativeMdagM<R: Real = f32> {
    inner: NativeMeo<R>,
    mid: FermionField<R>,
}

impl<R: Real> NativeMdagM<R> {
    pub fn new(geom: &Geometry, u: GaugeField<R>, kappa: R) -> NativeMdagM<R> {
        NativeMdagM {
            inner: NativeMeo::new(geom, u, kappa),
            mid: FermionField::zeros(geom),
        }
    }

    pub fn meo(&mut self) -> &mut NativeMeo<R> {
        &mut self.inner
    }
}

impl<R: Real> LinearOperator<R> for NativeMdagM<R> {
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        // mid = M psi ; out = g5 M g5 mid
        let mut m_psi = std::mem::replace(&mut self.mid, FermionField::placeholder());
        self.inner.apply(&mut m_psi, psi);
        m_psi.gamma5();
        self.inner.apply(out, &m_psi);
        out.gamma5();
        // undo gamma5 on mid before stashing it back (content irrelevant)
        self.mid = m_psi;
    }

    fn flops_per_apply(&self) -> u64 {
        2 * self.inner.flops_per_apply()
    }
}

/// Distributed M-hat over the rank world: two distributed hoppings plus
/// the axpy; dot-product reductions go through the communicator.
pub struct DistMeo<'a, R: Real + CommScalar = f32> {
    pub dist: &'a DistHopping,
    pub u: &'a GaugeField<R>,
    pub kappa: R,
    pub comm: &'a mut Comm,
    pub team: &'a mut Team,
    pub prof: &'a Profiler,
    pub tmp: FermionField<R>,
    half_volume: usize,
}

impl<'a, R: Real + CommScalar> DistMeo<'a, R> {
    pub fn new(
        geom: &Geometry,
        dist: &'a DistHopping,
        u: &'a GaugeField<R>,
        kappa: R,
        comm: &'a mut Comm,
        team: &'a mut Team,
        prof: &'a Profiler,
    ) -> DistMeo<'a, R> {
        DistMeo {
            dist,
            u,
            kappa,
            comm,
            team,
            prof,
            tmp: FermionField::zeros(geom),
            half_volume: geom.local.half_volume(),
        }
    }
}

impl<R: Real + CommScalar> LinearOperator<R> for DistMeo<'_, R> {
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        self.dist
            .hopping(&mut self.tmp, self.u, psi, Parity::Odd, self.comm, self.team, self.prof);
        self.dist
            .hopping(out, self.u, &self.tmp, Parity::Even, self.comm, self.team, self.prof);
        out.xpay(-(self.kappa * self.kappa), psi);
    }

    fn flops_per_apply(&self) -> u64 {
        crate::dslash::flops::meo_flops(self.half_volume)
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.comm.allreduce_sum(v)
    }
}

/// gamma5-wrapped normal operator over any M-hat-like operator: CGNR on
/// the distributed or PJRT operator reuses this.
pub struct NormalOp<A, R: Real = f32> {
    pub inner: A,
    mid: FermionField<R>,
}

impl<A, R: Real> NormalOp<A, R>
where
    A: LinearOperator<R>,
{
    pub fn new(inner: A, geom: &Geometry) -> NormalOp<A, R> {
        NormalOp {
            inner,
            mid: FermionField::zeros(geom),
        }
    }
}

impl<A, R: Real> LinearOperator<R> for NormalOp<A, R>
where
    A: LinearOperator<R>,
{
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        let mut m_psi = std::mem::replace(&mut self.mid, FermionField::placeholder());
        self.inner.apply(&mut m_psi, psi);
        m_psi.gamma5();
        self.inner.apply(out, &m_psi);
        out.gamma5();
        self.mid = m_psi;
    }

    fn flops_per_apply(&self) -> u64 {
        2 * self.inner.flops_per_apply()
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.inner.reduce_sum(v)
    }
}
