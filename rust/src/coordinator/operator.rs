//! Linear operators for the iterative solvers.
//!
//! The solvers are generic over [`LinearOperator`]; implementations here
//! wrap the native kernels (single-rank periodic and distributed) — the
//! PJRT-backed operator lives in [`crate::runtime`].
//!
//! Every operator is generic over the [`Real`] field scalar (default
//! `f32`): `kappa`, the internal scratch fields and the gauge storage all
//! follow the operator's precision, while `reduce_sum` stays f64 at every
//! precision (global reductions are always accumulated wide).

use crate::algebra::Real;
use crate::comm::{tags, validate_wire_format, Comm, CommError, CommScalar};
use crate::dslash::{
    full, DotCapture, HoppingEo, LinkSource, Links, MultiDotCapture, MultiStoreTail,
    StoreTail,
};
use crate::field::{FermionField, GaugeField, MultiFermionField};
use crate::lattice::{EoLayout, Geometry, Parity, SC2};

use super::driver::{DistHopping, MultiHopTail};
use super::profiler::Profiler;
use super::team::{chunk_range, SendPtr, Team, TeamBarrier};

/// An operator on even-parity fermion fields of precision `R`.
pub trait LinearOperator<R: Real = f32> {
    /// out = A psi.
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>);

    /// Flop per application (QXS convention), for harness reporting.
    fn flops_per_apply(&self) -> u64;

    /// Sum a scalar across ranks (identity for single-rank operators);
    /// always f64 regardless of the field precision.
    fn reduce_sum(&mut self, v: f64) -> f64 {
        v
    }

    /// Per-iteration fault hook, called by the solver health guard at
    /// the top of every iteration: distributed operators apply
    /// rank-level fault injections (stall/kill) and surface any fault
    /// already recorded by the transport. No-op for single-rank
    /// operators.
    fn fault_hook(&mut self, _iteration: usize) -> Result<(), CommError> {
        Ok(())
    }

    /// The first transport fault the underlying communicator hit, if
    /// any (sticky; `None` for single-rank operators).
    fn comm_fault(&self) -> Option<CommError> {
        None
    }

    /// `(retransmits, timeouts)` recovery counters of the underlying
    /// transport; zeros for single-rank operators.
    fn comm_counters(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Halo buffers the transport had to zero-fill after failed recvs
    /// (`CommStats::zero_fills`); zero for single-rank operators.
    fn comm_zero_fills(&self) -> u64 {
        0
    }

    /// Fault-plan matching-send cursors of the underlying transport,
    /// captured into checkpoints so a resumed solve replays the
    /// remaining fault schedule faithfully. Empty for single-rank
    /// operators.
    fn fault_cursors(&self) -> Vec<u64> {
        Vec::new()
    }

    /// Restore cursors saved by [`LinearOperator::fault_cursors`].
    fn restore_fault_cursors(&mut self, _saved: &[u64]) {}

    /// Phase 2 of the checkpoint commit: collective AND of "my
    /// generation file is durably on disk". Identity for single-rank
    /// operators; distributed operators reduce across ranks and report
    /// `false` when the transport is poisoned, so no rank commits a
    /// generation another rank lost.
    fn ckpt_all_committed(&mut self, ok: bool) -> bool {
        ok
    }

    /// Ring-exchange of checkpoint payloads for the buddy scheme: send
    /// ours to rank+1, return rank-1's. `None` for single-rank
    /// operators or when the transport is already poisoned.
    fn ckpt_buddy_exchange(&mut self, _payload: &[f64], _gen: u64) -> Option<Vec<f64>> {
        None
    }
}

/// Native single-rank M-hat = 1 - kappa^2 H_eo H_oe (Eq. 4 LHS).
pub struct NativeMeo<R: Real = f32> {
    hop: HoppingEo,
    u: Links<R>,
    kappa: R,
    tmp: FermionField<R>,
    half_volume: usize,
}

impl<R: Real> NativeMeo<R> {
    pub fn new(geom: &Geometry, u: GaugeField<R>, kappa: R) -> NativeMeo<R> {
        NativeMeo::with_links(geom, Links::Full(u), kappa)
    }

    /// Construct from an explicit link source (full or two-row
    /// compressed) — what `gauge.compression` routes through.
    pub fn with_links(geom: &Geometry, u: Links<R>, kappa: R) -> NativeMeo<R> {
        NativeMeo {
            hop: HoppingEo::new(geom),
            u,
            kappa,
            tmp: FermionField::zeros(geom),
            half_volume: geom.local.half_volume(),
        }
    }

    pub fn links(&self) -> &Links<R> {
        &self.u
    }

    pub fn hopping(&self) -> &HoppingEo {
        &self.hop
    }

    pub fn kappa(&self) -> R {
        self.kappa
    }
}

impl<R: Real> LinearOperator<R> for NativeMeo<R> {
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        // M-hat = 1 - kappa^2 H_eo H_oe with the xpay tail fused into
        // the second hopping's store (bit-identical to `full::meo`, one
        // fewer full-field sweep).
        self.hop.apply(&mut self.tmp, &self.u, psi, Parity::Odd);
        let ntiles = self.hop.layout.ntiles();
        self.hop.apply_tiles_fused(
            &mut out.data,
            &self.u,
            &self.tmp.data,
            Parity::Even,
            0,
            ntiles,
            StoreTail::Xpay {
                a: -(self.kappa * self.kappa),
                b: &psi.data,
            },
            None,
        );
    }

    fn flops_per_apply(&self) -> u64 {
        crate::dslash::flops::meo_links_flops(self.half_volume, self.u.reals_per_link())
    }
}

/// Native single-rank normal operator M-hat^dag M-hat (hermitian positive
/// definite; what CG solves).
pub struct NativeMdagM<R: Real = f32> {
    inner: NativeMeo<R>,
    mid: FermionField<R>,
}

impl<R: Real> NativeMdagM<R> {
    pub fn new(geom: &Geometry, u: GaugeField<R>, kappa: R) -> NativeMdagM<R> {
        NativeMdagM::with_links(geom, Links::Full(u), kappa)
    }

    /// Construct from an explicit link source (full or two-row).
    pub fn with_links(geom: &Geometry, u: Links<R>, kappa: R) -> NativeMdagM<R> {
        NativeMdagM {
            inner: NativeMeo::with_links(geom, u, kappa),
            mid: FermionField::zeros(geom),
        }
    }

    pub fn meo(&mut self) -> &mut NativeMeo<R> {
        &mut self.inner
    }
}

impl<R: Real> LinearOperator<R> for NativeMdagM<R> {
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        // M^dag M = (g5 M g5)(M): both gamma5 passes and both xpay
        // tails are fused into the even-parity hopping stores, so the
        // whole normal apply is four kernel sweeps and nothing else.
        // Bit-identical to the unfused apply/gamma5 sequence.
        let NativeMdagM { inner, mid } = self;
        let a = -(inner.kappa * inner.kappa);
        let ntiles = inner.hop.layout.ntiles();
        // mid = g5 (M psi)
        inner.hop.apply(&mut inner.tmp, &inner.u, psi, Parity::Odd);
        inner.hop.apply_tiles_fused(
            &mut mid.data,
            &inner.u,
            &inner.tmp.data,
            Parity::Even,
            0,
            ntiles,
            StoreTail::Gamma5Xpay { a, b: &psi.data },
            None,
        );
        // out = g5 (M mid)
        inner.hop.apply(&mut inner.tmp, &inner.u, mid, Parity::Odd);
        inner.hop.apply_tiles_fused(
            &mut out.data,
            &inner.u,
            &inner.tmp.data,
            Parity::Even,
            0,
            ntiles,
            StoreTail::Gamma5Xpay { a, b: &mid.data },
            None,
        );
    }

    fn flops_per_apply(&self) -> u64 {
        2 * self.inner.flops_per_apply()
    }
}

/// The pre-fusion normal operator: `full::meo` with separate xpay
/// tails followed by separate in-place gamma5 passes — exactly the
/// pipeline [`NativeMdagM`]'s fused store tails replace. Kept as the
/// reference baseline for the equivalence tests and the solver bench:
/// bit-identical results to [`NativeMdagM`], more memory sweeps.
pub struct UnfusedMdagM<R: Real = f32> {
    hop: HoppingEo,
    u: GaugeField<R>,
    kappa: R,
    tmp: FermionField<R>,
    mid: FermionField<R>,
    half_volume: usize,
}

impl<R: Real> UnfusedMdagM<R> {
    pub fn new(geom: &Geometry, u: GaugeField<R>, kappa: R) -> UnfusedMdagM<R> {
        UnfusedMdagM {
            hop: HoppingEo::new(geom),
            u,
            kappa,
            tmp: FermionField::zeros(geom),
            mid: FermionField::zeros(geom),
            half_volume: geom.local.half_volume(),
        }
    }
}

impl<R: Real> LinearOperator<R> for UnfusedMdagM<R> {
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        full::meo(&self.hop, &mut self.mid, &mut self.tmp, &self.u, psi, self.kappa);
        self.mid.gamma5();
        full::meo(&self.hop, out, &mut self.tmp, &self.u, &self.mid, self.kappa);
        out.gamma5();
    }

    fn flops_per_apply(&self) -> u64 {
        2 * crate::dslash::flops::meo_flops(self.half_volume)
    }
}

/// Raw, team-shareable view of a native operator: everything a worker
/// thread needs to run the operator's tile-sharded kernel phases inside
/// one [`Team`] parallel region. Obtained via [`FusedSolvable`]; the
/// view holds the operator mutably borrowed, so no other access can
/// race the scratch fields it exposes as raw pointers.
pub struct FusedView<'a, R: Real> {
    hop: &'a HoppingEo,
    u: &'a Links<R>,
    /// the fused xpay-tail coefficient, -kappa²
    a: R,
    /// odd-parity hopping scratch, written tile-sharded
    tmp: SendPtr<R>,
    /// even-parity scratch for the normal operator's mid field
    /// (`None` selects the plain M-hat, `Some` the M^dag M pipeline)
    mid: Option<SendPtr<R>>,
    field_len: usize,
    ntiles: usize,
    vlen: usize,
}

impl<R: Real> FusedView<'_, R> {
    pub fn ntiles(&self) -> usize {
        self.ntiles
    }

    pub fn vals_per_tile(&self) -> usize {
        SC2 * self.vlen
    }

    pub fn vlen(&self) -> usize {
        self.vlen
    }

    pub fn field_len(&self) -> usize {
        self.field_len
    }

    /// Apply `out = A psi` from inside a team parallel region, with an
    /// optional fused dot capture `dot = (with, partials)` recording
    /// per-tile `[Re⟨with, out⟩, Im⟨with, out⟩, |out|²]`.
    ///
    /// Internal kernel phases synchronize on `bar`; tiles are sharded
    /// by `tid` with [`chunk_range`], matching the ownership the BLAS-1
    /// phases of the fused solvers use.
    ///
    /// # Safety
    ///
    /// Every thread of an `n`-thread region must call this exactly once
    /// with identical arguments (`tid` excepted). `out`, `psi` and
    /// `dot.0` must point to fields of this operator's layout
    /// (`field_len` values; `partials` to `ntiles` entries), none of
    /// them aliasing each other or the view's scratch. `out` and the
    /// partials are written tile-sharded; the caller must pass another
    /// barrier before reading them.
    pub unsafe fn apply_team(
        &self,
        tid: usize,
        n: usize,
        bar: &TeamBarrier,
        out: SendPtr<R>,
        psi: *const R,
        dot: Option<(*const R, SendPtr<[f64; 3]>)>,
    ) {
        let vpt = self.vals_per_tile();
        let (tb, te) = chunk_range(self.ntiles, tid, n);
        let len = self.field_len;
        let psi_s = std::slice::from_raw_parts(psi, len);
        let capture = |dot: Option<(*const R, SendPtr<[f64; 3]>)>| {
            // SAFETY: same contract as this fn — `with` points to a full
            // field, the partials shard [tb, te) is owned by this thread
            dot.map(|(w, p)| unsafe {
                DotCapture {
                    with: std::slice::from_raw_parts(w, len),
                    partials: p.slice_mut(tb, te - tb),
                }
            })
        };

        // phase 1: tmp = H_oe psi
        {
            let tmp_tiles = self.tmp.slice_mut(tb * vpt, (te - tb) * vpt);
            self.hop.apply_tiles_fused(
                tmp_tiles, self.u, psi_s, Parity::Odd, tb, te,
                StoreTail::Assign, None,
            );
        }
        bar.wait();
        match self.mid {
            None => {
                // phase 2: out = psi - kappa² H_eo tmp (+ capture)
                let tmp_s = std::slice::from_raw_parts(self.tmp.0 as *const R, len);
                let out_tiles = out.slice_mut(tb * vpt, (te - tb) * vpt);
                self.hop.apply_tiles_fused(
                    out_tiles, self.u, tmp_s, Parity::Even, tb, te,
                    StoreTail::Xpay { a: self.a, b: psi_s },
                    capture(dot),
                );
            }
            Some(mid) => {
                // phase 2: mid = g5 (psi - kappa² H_eo tmp)
                {
                    let tmp_s =
                        std::slice::from_raw_parts(self.tmp.0 as *const R, len);
                    let mid_tiles = mid.slice_mut(tb * vpt, (te - tb) * vpt);
                    self.hop.apply_tiles_fused(
                        mid_tiles, self.u, tmp_s, Parity::Even, tb, te,
                        StoreTail::Gamma5Xpay { a: self.a, b: psi_s },
                        None,
                    );
                }
                bar.wait();
                let mid_s = std::slice::from_raw_parts(mid.0 as *const R, len);
                // phase 3: tmp = H_oe mid
                {
                    let tmp_tiles = self.tmp.slice_mut(tb * vpt, (te - tb) * vpt);
                    self.hop.apply_tiles_fused(
                        tmp_tiles, self.u, mid_s, Parity::Odd, tb, te,
                        StoreTail::Assign, None,
                    );
                }
                bar.wait();
                // phase 4: out = g5 (mid - kappa² H_eo tmp) (+ capture)
                let tmp_s = std::slice::from_raw_parts(self.tmp.0 as *const R, len);
                let out_tiles = out.slice_mut(tb * vpt, (te - tb) * vpt);
                self.hop.apply_tiles_fused(
                    out_tiles, self.u, tmp_s, Parity::Even, tb, te,
                    StoreTail::Gamma5Xpay { a: self.a, b: mid_s },
                    capture(dot),
                );
            }
        }
    }
}

/// A native single-rank operator the fused solver pipeline can run
/// tile-sharded on the worker team ([`crate::solver::fused`]).
pub trait FusedSolvable<R: Real>: LinearOperator<R> {
    /// Borrow the raw view used inside team parallel regions. The
    /// operator stays mutably borrowed while the view lives.
    fn fused_view(&mut self) -> FusedView<'_, R>;
}

impl<R: Real> FusedSolvable<R> for NativeMeo<R> {
    fn fused_view(&mut self) -> FusedView<'_, R> {
        FusedView {
            a: -(self.kappa * self.kappa),
            tmp: SendPtr(self.tmp.data.as_mut_ptr()),
            mid: None,
            field_len: self.tmp.data.len(),
            ntiles: self.hop.layout.ntiles(),
            vlen: self.hop.layout.vlen(),
            hop: &self.hop,
            u: &self.u,
        }
    }
}

impl<R: Real> FusedSolvable<R> for NativeMdagM<R> {
    fn fused_view(&mut self) -> FusedView<'_, R> {
        let NativeMdagM { inner, mid } = self;
        FusedView {
            a: -(inner.kappa * inner.kappa),
            tmp: SendPtr(inner.tmp.data.as_mut_ptr()),
            mid: Some(SendPtr(mid.data.as_mut_ptr())),
            field_len: mid.data.len(),
            ntiles: inner.hop.layout.ntiles(),
            vlen: inner.hop.layout.vlen(),
            hop: &inner.hop,
            u: &inner.u,
        }
    }
}

/// A multi-RHS operator on block fermion fields: applies to all active
/// right-hand sides of a [`MultiFermionField`] in one batched pass that
/// streams the gauge field once per site, tile-sharded over the worker
/// [`Team`] ([`crate::solver::block`] drives this).
pub trait MultiOperator<R: Real> {
    /// Number of interleaved right-hand sides this operator is sized for.
    fn nrhs(&self) -> usize;

    /// out_r = A psi_r for every RHS with `active[r]`; masked RHS are
    /// neither read nor written. With `dot = Some((with, partials))` the
    /// kernel captures `[Re⟨with_r, out_r⟩, Im⟨with_r, out_r⟩, |out_r|²]`
    /// per (site tile, RHS) into `partials[tile * nrhs + r]` (canonical
    /// grouping; masked entries untouched) — the block solver's `p·Ap`
    /// reductions cost no extra sweep.
    fn apply_multi(
        &mut self,
        team: &mut Team,
        out: &mut MultiFermionField<R>,
        psi: &MultiFermionField<R>,
        active: &[bool],
        dot: Option<(&MultiFermionField<R>, &mut [[f64; 3]])>,
    );

    /// Flop per application of one RHS (QXS convention); the block
    /// solver multiplies by the number of *active* RHS so `SolveStats`
    /// flops scale honestly with the mask, not with `nrhs`.
    fn flops_per_apply_rhs(&self) -> u64;

    /// Flops of per-apply work *shared* across the RHS — e.g. the
    /// two-row link rebuild, done once per site tile no matter how many
    /// RHS consume the tile. The block solver charges this once per
    /// batched apply (with any active RHS), never per RHS.
    fn flops_per_apply_shared(&self) -> u64 {
        0
    }

    /// Combine per-(site tile, RHS) capture partials
    /// (`partials[tile * nrhs + r]`) into per-RHS `[Re, Im, |·|²]` sums
    /// in the **canonical site-tile grouping**. Single-rank operators
    /// sum their local tiles in tile order (this default); distributed
    /// operators gather every rank's partials and fold them in *global*
    /// site-tile order, so solver scalars are bitwise independent of the
    /// rank decomposition. Entries of masked RHS may hold stale data —
    /// callers only read the RHS they wrote this sweep.
    fn reduce_caps(&mut self, partials: &[[f64; 3]]) -> Vec<[f64; 3]> {
        reduce_caps_tile_order(partials, self.nrhs())
    }

    /// Collective OR of a per-rank flag (identity for single-rank
    /// operators): lets the generic block solvers take globally
    /// consistent control-flow decisions — e.g. warm-start detection —
    /// without divergent collective sequences across ranks.
    fn reduce_any(&mut self, v: bool) -> bool {
        v
    }

    /// Per-iteration fault hook (see
    /// [`LinearOperator::fault_hook`]): rank-level fault injection and
    /// transport-fault surfacing for the block solvers.
    fn fault_hook(&mut self, _iteration: usize) -> Result<(), CommError> {
        Ok(())
    }

    /// The first transport fault the underlying communicator hit, if
    /// any (sticky; `None` for single-rank operators).
    fn comm_fault(&self) -> Option<CommError> {
        None
    }

    /// `(retransmits, timeouts)` recovery counters of the underlying
    /// transport; zeros for single-rank operators.
    fn comm_counters(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Halo buffers the transport had to zero-fill after failed recvs
    /// (`CommStats::zero_fills`); zero for single-rank operators.
    fn comm_zero_fills(&self) -> u64 {
        0
    }

    /// Fault-plan matching-send cursors (see
    /// [`LinearOperator::fault_cursors`]).
    fn fault_cursors(&self) -> Vec<u64> {
        Vec::new()
    }

    /// Restore cursors saved by [`MultiOperator::fault_cursors`].
    fn restore_fault_cursors(&mut self, _saved: &[u64]) {}

    /// Phase 2 of the checkpoint commit (see
    /// [`LinearOperator::ckpt_all_committed`]).
    fn ckpt_all_committed(&mut self, ok: bool) -> bool {
        ok
    }

    /// Buddy-copy ring exchange (see
    /// [`LinearOperator::ckpt_buddy_exchange`]).
    fn ckpt_buddy_exchange(&mut self, _payload: &[f64], _gen: u64) -> Option<Vec<f64>> {
        None
    }
}

/// Fold per-(site tile, RHS) partials into per-RHS sums in site-tile
/// order — the canonical reduction grouping every solver in the repo
/// shares (each component accumulates tile-by-tile, exactly like
/// [`MultiFermionField::norm2_per_rhs`] and the block solvers' in-region
/// `sum_cap`).
pub fn reduce_caps_tile_order(partials: &[[f64; 3]], nrhs: usize) -> Vec<[f64; 3]> {
    debug_assert_eq!(partials.len() % nrhs, 0);
    let ntiles = partials.len() / nrhs;
    let mut out = vec![[0.0f64; 3]; nrhs];
    for t in 0..ntiles {
        for (r, acc) in out.iter_mut().enumerate() {
            let p = &partials[t * nrhs + r];
            acc[0] += p[0];
            acc[1] += p[1];
            acc[2] += p[2];
        }
    }
    out
}

/// Multi-RHS native single-rank M-hat: the batched analog of
/// [`NativeMeo`], two multi-hopping phases with the `-kappa²` xpay tail
/// fused into the second store, run as ONE team region per apply
/// (in-region [`TeamBarrier`] between the phases). Per-RHS results
/// bit-match [`NativeMeo::apply`] on the demuxed fields.
pub struct MultiNativeMeo<R: Real = f32> {
    hop: HoppingEo,
    u: Links<R>,
    kappa: R,
    tmp: MultiFermionField<R>,
    half_volume: usize,
    nrhs: usize,
}

impl<R: Real> MultiNativeMeo<R> {
    pub fn new(geom: &Geometry, u: GaugeField<R>, kappa: R, nrhs: usize) -> MultiNativeMeo<R> {
        MultiNativeMeo::with_links(geom, Links::Full(u), kappa, nrhs)
    }

    /// Construct from an explicit link source (full or two-row). The
    /// compressed source composes with multi-RHS amortization: each
    /// link tile is reconstructed once per site tile and consumed by
    /// all N right-hand sides while hot.
    pub fn with_links(
        geom: &Geometry,
        u: Links<R>,
        kappa: R,
        nrhs: usize,
    ) -> MultiNativeMeo<R> {
        MultiNativeMeo {
            hop: HoppingEo::new(geom),
            u,
            kappa,
            tmp: MultiFermionField::zeros(geom, nrhs),
            half_volume: geom.local.half_volume(),
            nrhs,
        }
    }

    pub fn kappa(&self) -> R {
        self.kappa
    }

    pub fn links(&self) -> &Links<R> {
        &self.u
    }
}

impl<R: Real> MultiOperator<R> for MultiNativeMeo<R> {
    fn nrhs(&self) -> usize {
        self.nrhs
    }

    fn apply_multi(
        &mut self,
        team: &mut Team,
        out: &mut MultiFermionField<R>,
        psi: &MultiFermionField<R>,
        active: &[bool],
        dot: Option<(&MultiFermionField<R>, &mut [[f64; 3]])>,
    ) {
        debug_assert_eq!(psi.nrhs, self.nrhs);
        debug_assert_eq!(out.nrhs, self.nrhs);
        apply_multi_via_view(self.multi_fused_view(), team, out, psi, active, dot);
    }

    fn flops_per_apply_rhs(&self) -> u64 {
        // per-RHS arithmetic only; the link rebuild is shared (below)
        crate::dslash::flops::meo_flops(self.half_volume)
    }

    fn flops_per_apply_shared(&self) -> u64 {
        // two-row reconstruction happens once per link tile per apply
        // and feeds every RHS — charging it per RHS would overstate the
        // executed arithmetic exactly where the bench tracks it
        crate::dslash::flops::meo_links_flops(self.half_volume, self.u.reals_per_link())
            - crate::dslash::flops::meo_flops(self.half_volume)
    }
}

/// Multi-RHS native normal operator M-hat^dag M-hat: four batched
/// hopping phases with both gamma5/xpay tails fused into the
/// even-parity stores, like [`NativeMdagM`] but for N interleaved RHS.
pub struct MultiMdagM<R: Real = f32> {
    inner: MultiNativeMeo<R>,
    mid: MultiFermionField<R>,
}

impl<R: Real> MultiMdagM<R> {
    pub fn new(geom: &Geometry, u: GaugeField<R>, kappa: R, nrhs: usize) -> MultiMdagM<R> {
        MultiMdagM::with_links(geom, Links::Full(u), kappa, nrhs)
    }

    /// Construct from an explicit link source (full or two-row).
    pub fn with_links(geom: &Geometry, u: Links<R>, kappa: R, nrhs: usize) -> MultiMdagM<R> {
        MultiMdagM {
            inner: MultiNativeMeo::with_links(geom, u, kappa, nrhs),
            mid: MultiFermionField::zeros(geom, nrhs),
        }
    }

    pub fn meo(&mut self) -> &mut MultiNativeMeo<R> {
        &mut self.inner
    }
}

impl<R: Real> MultiOperator<R> for MultiMdagM<R> {
    fn nrhs(&self) -> usize {
        self.inner.nrhs
    }

    fn apply_multi(
        &mut self,
        team: &mut Team,
        out: &mut MultiFermionField<R>,
        psi: &MultiFermionField<R>,
        active: &[bool],
        dot: Option<(&MultiFermionField<R>, &mut [[f64; 3]])>,
    ) {
        debug_assert_eq!(psi.nrhs, self.inner.nrhs);
        apply_multi_via_view(self.multi_fused_view(), team, out, psi, active, dot);
    }

    fn flops_per_apply_rhs(&self) -> u64 {
        2 * self.inner.flops_per_apply_rhs()
    }

    fn flops_per_apply_shared(&self) -> u64 {
        2 * self.inner.flops_per_apply_shared()
    }
}

/// Run one full multi-RHS operator apply as a single team region over a
/// [`MultiFusedView`] (the phases synchronize on the in-region barrier).
fn apply_multi_via_view<R: Real>(
    view: MultiFusedView<'_, R>,
    team: &mut Team,
    out: &mut MultiFermionField<R>,
    psi: &MultiFermionField<R>,
    active: &[bool],
    dot: Option<(&MultiFermionField<R>, &mut [[f64; 3]])>,
) {
    let n = team.nthreads();
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    // raw pointers cross the closure only inside SendPtr wrappers
    let psi_ptr = SendPtr(psi.data.as_ptr() as *mut R);
    let dot = dot.map(|(w, p)| {
        debug_assert_eq!(p.len(), view.ntiles() * view.nrhs());
        (SendPtr(w.data.as_ptr() as *mut R), SendPtr(p.as_mut_ptr()))
    });
    // SAFETY: out/psi are live fields of the view's layout; the view's
    // scratch is exclusively borrowed through the operator, and every
    // thread calls apply_team exactly once with identical arguments.
    team.run(|tid, bar| unsafe {
        view.apply_team(
            tid,
            n,
            bar,
            out_ptr,
            psi_ptr.0 as *const R,
            active,
            dot.map(|(w, p)| (w.0 as *const R, p)),
        );
    });
}

/// Raw, team-shareable view of a multi-RHS native operator: the batched
/// analog of [`FusedView`]. One [`Team::run`] region can execute the
/// operator's multi-hopping phases (synchronized on the in-region
/// [`TeamBarrier`]) plus the block solver's masked BLAS-1 sweeps —
/// which is how [`crate::solver::block`] runs a whole batched iteration
/// as a single parallel region.
pub struct MultiFusedView<'a, R: Real> {
    hop: &'a HoppingEo,
    u: &'a Links<R>,
    /// the fused xpay-tail coefficient, -kappa²
    a: R,
    /// odd-parity batched scratch, written tile-sharded
    tmp: SendPtr<R>,
    /// even-parity scratch for the normal operator's mid block field
    /// (`None` selects the plain M-hat, `Some` the M^dag M pipeline)
    mid: Option<SendPtr<R>>,
    nrhs: usize,
    /// block-field length: `spinor_len * nrhs`
    field_len: usize,
    ntiles: usize,
    vlen: usize,
}

impl<R: Real> MultiFusedView<'_, R> {
    pub fn ntiles(&self) -> usize {
        self.ntiles
    }

    pub fn nrhs(&self) -> usize {
        self.nrhs
    }

    /// Scalar values per RHS sub-tile.
    pub fn vals_per_tile(&self) -> usize {
        SC2 * self.vlen
    }

    pub fn vlen(&self) -> usize {
        self.vlen
    }

    pub fn field_len(&self) -> usize {
        self.field_len
    }

    /// Apply `out_r = A psi_r` for every active RHS from inside a team
    /// parallel region, with an optional fused per-(site tile, RHS) dot
    /// capture (`partials[tile * nrhs + r]`, masked entries untouched).
    ///
    /// # Safety
    ///
    /// Same contract as [`FusedView::apply_team`], with block-field
    /// lengths: every thread of an `n`-thread region calls this exactly
    /// once with identical arguments (`tid` excepted); `out`, `psi` and
    /// `dot.0` point to block fields of this operator's layout
    /// (`field_len` values; partials to `ntiles * nrhs` entries), none
    /// aliasing each other or the view's scratch. `out` and the partials
    /// are written tile-sharded; pass a barrier before reading them.
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn apply_team(
        &self,
        tid: usize,
        n: usize,
        bar: &TeamBarrier,
        out: SendPtr<R>,
        psi: *const R,
        active: &[bool],
        dot: Option<(*const R, SendPtr<[f64; 3]>)>,
    ) {
        let vpt = self.vals_per_tile();
        let nrhs = self.nrhs;
        let (tb, te) = chunk_range(self.ntiles, tid, n);
        let len = self.field_len;
        let psi_s = std::slice::from_raw_parts(psi, len);
        let capture = |dot: Option<(*const R, SendPtr<[f64; 3]>)>| {
            // SAFETY: same contract as this fn — `with` points to a full
            // block field, the partials shard [tb, te) is thread-owned
            dot.map(|(w, p)| unsafe {
                MultiDotCapture {
                    with: std::slice::from_raw_parts(w, len),
                    partials: p.slice_mut(tb * nrhs, (te - tb) * nrhs),
                }
            })
        };

        // phase 1: tmp = H_oe psi
        {
            let tmp_tiles = self.tmp.slice_mut(tb * nrhs * vpt, (te - tb) * nrhs * vpt);
            self.hop.apply_tiles_multi(
                tmp_tiles, self.u, psi_s, Parity::Odd, tb, te, nrhs, active,
                MultiStoreTail::Assign, None,
            );
        }
        bar.wait();
        match self.mid {
            None => {
                // phase 2: out = psi - kappa² H_eo tmp (+ capture)
                let tmp_s = std::slice::from_raw_parts(self.tmp.0 as *const R, len);
                let out_tiles = out.slice_mut(tb * nrhs * vpt, (te - tb) * nrhs * vpt);
                self.hop.apply_tiles_multi(
                    out_tiles, self.u, tmp_s, Parity::Even, tb, te, nrhs, active,
                    MultiStoreTail::Xpay { a: self.a, b: psi_s },
                    capture(dot),
                );
            }
            Some(mid) => {
                // phase 2: mid = g5 (psi - kappa² H_eo tmp)
                {
                    let tmp_s =
                        std::slice::from_raw_parts(self.tmp.0 as *const R, len);
                    let mid_tiles =
                        mid.slice_mut(tb * nrhs * vpt, (te - tb) * nrhs * vpt);
                    self.hop.apply_tiles_multi(
                        mid_tiles, self.u, tmp_s, Parity::Even, tb, te, nrhs, active,
                        MultiStoreTail::Gamma5Xpay { a: self.a, b: psi_s },
                        None,
                    );
                }
                bar.wait();
                let mid_s = std::slice::from_raw_parts(mid.0 as *const R, len);
                // phase 3: tmp = H_oe mid
                {
                    let tmp_tiles =
                        self.tmp.slice_mut(tb * nrhs * vpt, (te - tb) * nrhs * vpt);
                    self.hop.apply_tiles_multi(
                        tmp_tiles, self.u, mid_s, Parity::Odd, tb, te, nrhs, active,
                        MultiStoreTail::Assign, None,
                    );
                }
                bar.wait();
                // phase 4: out = g5 (mid - kappa² H_eo tmp) (+ capture)
                let tmp_s = std::slice::from_raw_parts(self.tmp.0 as *const R, len);
                let out_tiles = out.slice_mut(tb * nrhs * vpt, (te - tb) * nrhs * vpt);
                self.hop.apply_tiles_multi(
                    out_tiles, self.u, tmp_s, Parity::Even, tb, te, nrhs, active,
                    MultiStoreTail::Gamma5Xpay { a: self.a, b: mid_s },
                    capture(dot),
                );
            }
        }
    }
}

/// A multi-RHS operator the block solvers can run as ONE team region
/// per batched iteration (operator phases + masked BLAS-1 sweeps inside
/// a single [`Team::run`] job).
pub trait MultiFusedSolvable<R: Real>: MultiOperator<R> {
    /// Borrow the raw view used inside team parallel regions. The
    /// operator stays mutably borrowed while the view lives.
    fn multi_fused_view(&mut self) -> MultiFusedView<'_, R>;
}

impl<R: Real> MultiFusedSolvable<R> for MultiNativeMeo<R> {
    fn multi_fused_view(&mut self) -> MultiFusedView<'_, R> {
        MultiFusedView {
            a: -(self.kappa * self.kappa),
            tmp: SendPtr(self.tmp.data.as_mut_ptr()),
            mid: None,
            nrhs: self.nrhs,
            field_len: self.tmp.data.len(),
            ntiles: self.hop.layout.ntiles(),
            vlen: self.hop.layout.vlen(),
            hop: &self.hop,
            u: &self.u,
        }
    }
}

impl<R: Real> MultiFusedSolvable<R> for MultiMdagM<R> {
    fn multi_fused_view(&mut self) -> MultiFusedView<'_, R> {
        let MultiMdagM { inner, mid } = self;
        MultiFusedView {
            a: -(inner.kappa * inner.kappa),
            tmp: SendPtr(inner.tmp.data.as_mut_ptr()),
            mid: Some(SendPtr(mid.data.as_mut_ptr())),
            nrhs: inner.nrhs,
            field_len: mid.data.len(),
            ntiles: inner.hop.layout.ntiles(),
            vlen: inner.hop.layout.vlen(),
            hop: &inner.hop,
            u: &inner.u,
        }
    }
}

/// Distributed M-hat over the rank world: two distributed hoppings plus
/// the axpy; dot-product reductions go through the communicator.
pub struct DistMeo<'a, R: Real + CommScalar = f32, U: LinkSource<R> = GaugeField<R>> {
    pub dist: &'a DistHopping,
    /// the link source — a plain [`GaugeField`], a compressed field, or
    /// the runtime-selected [`Links`] sum; bulk kernel and EO2 merge
    /// both stream it (halos carry only spinors, so compression never
    /// touches the wire)
    pub u: &'a U,
    pub kappa: R,
    pub comm: &'a mut Comm,
    pub team: &'a mut Team,
    pub prof: &'a Profiler,
    pub tmp: FermionField<R>,
    half_volume: usize,
}

impl<'a, R: Real + CommScalar, U: LinkSource<R>> DistMeo<'a, R, U> {
    pub fn new(
        geom: &Geometry,
        dist: &'a DistHopping,
        u: &'a U,
        kappa: R,
        comm: &'a mut Comm,
        team: &'a mut Team,
        prof: &'a Profiler,
    ) -> DistMeo<'a, R, U> {
        DistMeo {
            dist,
            u,
            kappa,
            comm,
            team,
            prof,
            tmp: FermionField::zeros(geom),
            half_volume: geom.local.half_volume(),
        }
    }
}

impl<R: Real + CommScalar, U: LinkSource<R>> LinearOperator<R> for DistMeo<'_, R, U> {
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        // M-hat = 1 - kappa² H_eo H_oe with the xpay tail fused into the
        // second hopping's pipeline (bulk store when nothing
        // communicates, the EO2 merge pass otherwise) — bit-identical to
        // the separate xpay sweep this replaces, one fewer full-field
        // pass per apply.
        self.dist
            .hopping(&mut self.tmp, self.u, psi, Parity::Odd, self.comm, self.team, self.prof);
        self.dist.hopping_fused(
            out,
            self.u,
            &self.tmp,
            Parity::Even,
            self.comm,
            self.team,
            self.prof,
            -(self.kappa * self.kappa),
            psi,
        );
    }

    fn flops_per_apply(&self) -> u64 {
        crate::dslash::flops::meo_links_flops(self.half_volume, self.u.reals_per_link())
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.comm.allreduce_sum(v)
    }

    fn fault_hook(&mut self, iteration: usize) -> Result<(), CommError> {
        self.comm.iteration_hook(iteration)
    }

    fn comm_fault(&self) -> Option<CommError> {
        self.comm.comm_fault()
    }

    fn comm_counters(&self) -> (u64, u64) {
        let st = self.comm.stats();
        (st.retransmits, st.timeouts)
    }

    fn comm_zero_fills(&self) -> u64 {
        self.comm.stats().zero_fills
    }

    fn fault_cursors(&self) -> Vec<u64> {
        self.comm.fault_cursors()
    }

    fn restore_fault_cursors(&mut self, saved: &[u64]) {
        self.comm.restore_fault_cursors(saved);
    }

    fn ckpt_all_committed(&mut self, ok: bool) -> bool {
        ckpt_all_committed(self.comm, ok)
    }

    fn ckpt_buddy_exchange(&mut self, payload: &[f64], gen: u64) -> Option<Vec<f64>> {
        ckpt_buddy_exchange(self.comm, payload, gen)
    }
}

/// Phase 2 of the two-phase checkpoint commit: AND of `ok` across the
/// world. A poisoned transport (dead peer, expired deadline) must veto
/// the commit — `allreduce_any` degrades to its local argument once
/// poisoned, which would otherwise read as "everyone is fine".
fn ckpt_all_committed(comm: &mut Comm, ok: bool) -> bool {
    let any_failed = comm.allreduce_any(!ok);
    !any_failed && comm.comm_fault().is_none()
}

/// Buddy-copy ring exchange: checkpoint payloads ride the ordinary
/// transport (the [`tags::ckpt_buddy`] namespace, disjoint from every
/// halo/handshake tag) so they enjoy the same retransmit healing.
fn ckpt_buddy_exchange(comm: &mut Comm, payload: &[f64], gen: u64) -> Option<Vec<f64>> {
    if comm.nranks < 2 || comm.comm_fault().is_some() {
        return None;
    }
    let to = (comm.rank + 1) % comm.nranks;
    let from = (comm.rank + comm.nranks - 1) % comm.nranks;
    let tag = tags::ckpt_buddy(gen);
    comm.send(to, tag, payload.to_vec());
    comm.recv::<f64>(from, tag).ok()
}

/// (rank, local tile) pairs covering the whole decomposed lattice, in
/// **global** site-tile order — the fold order of the distributed
/// multi-RHS reductions. Every rank computes the same table from the
/// geometry alone (the [`Geometry`] carries global dims, grid and
/// tiling), so no communication is needed to agree on it.
fn global_tile_order(geom: &Geometry) -> Vec<(u32, u32)> {
    let grid = geom.grid;
    let gg = Geometry::single_rank(geom.global, geom.tiling)
        .expect("global geometry is valid whenever the per-rank one is");
    let glayout = EoLayout::new(&gg);
    let (vx, vy) = (geom.tiling.vx(), geom.tiling.vy());
    let mut entries: Vec<(usize, u32, u32)> = Vec::new();
    for rank in 0..grid.size() {
        let lg = Geometry::for_rank(geom.global, grid, rank, geom.tiling)
            .expect("every rank of a valid decomposition has a geometry");
        let ll = EoLayout::new(&lg);
        let origin = lg.origin();
        // tile-coordinate offset of this rank: local extents divide by
        // the tiling, so the origin lands on a tile boundary
        let (ot, oz) = (origin[3], origin[2]);
        let (oyt, oxt) = (origin[1] / vy, (origin[0] / 2) / vx);
        for lt in 0..ll.ntiles() {
            let (t, z, yt, xt) = ll.tile_coords(lt);
            let g = glayout.tile_index(ot + t, oz + z, oyt + yt, oxt + xt);
            entries.push((g, rank as u32, lt as u32));
        }
    }
    entries.sort_unstable();
    debug_assert_eq!(entries.len(), glayout.ntiles());
    entries.into_iter().map(|(_, r, lt)| (r, lt)).collect()
}

/// Distributed multi-RHS M-hat: the batched analog of [`DistMeo`] and
/// the rank-decomposed analog of [`MultiNativeMeo`]. Both hopping
/// applications run the bulk/EO1/EO2 overlap phases of
/// [`DistHopping::hopping_multi`], so per application there is ONE halo
/// message per direction/orientation for all active RHS (RHS-innermost
/// on the wire; converged RHS cost zero bytes), the gauge stream — full
/// or two-row compressed — is consumed once per site tile for all N
/// RHS, and the `-kappa²` xpay tail is fused into the second hopping's
/// store (bulk or EO2 merge). Per-RHS output bit-matches [`DistMeo`] on
/// the demuxed fields at any precision, grid and mask.
///
/// Reductions ([`MultiOperator::reduce_caps`]) gather every rank's
/// per-tile partials and fold them in *global* site-tile order, so the
/// solver scalars (alpha, beta, residual norms) are bitwise identical
/// to the single-rank block solver's grouping regardless of the rank
/// count.
pub struct DistMultiMeo<'a, R: Real + CommScalar = f32, U: LinkSource<R> = GaugeField<R>> {
    pub dist: &'a DistHopping,
    pub u: &'a U,
    pub kappa: R,
    pub comm: &'a mut Comm,
    pub prof: &'a Profiler,
    tmp: MultiFermionField<R>,
    nrhs: usize,
    half_volume: usize,
    /// (rank, local tile) in global site-tile order (see `reduce_caps`)
    reduce_order: std::sync::Arc<Vec<(u32, u32)>>,
}

impl<'a, R: Real + CommScalar, U: LinkSource<R>> DistMultiMeo<'a, R, U> {
    /// Construct the operator, running the wire-format handshake: if the
    /// ranks disagree on precision or batch width the structured
    /// [`CommError`] names every rank's view — surfaced here, before any
    /// halo payload could be posted.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        geom: &Geometry,
        dist: &'a DistHopping,
        u: &'a U,
        kappa: R,
        nrhs: usize,
        comm: &'a mut Comm,
        prof: &'a Profiler,
    ) -> Result<DistMultiMeo<'a, R, U>, CommError> {
        validate_wire_format::<R>(comm, nrhs, &vec![true; nrhs])?;
        Ok(DistMultiMeo {
            dist,
            u,
            kappa,
            comm,
            prof,
            tmp: MultiFermionField::zeros(geom, nrhs),
            nrhs,
            half_volume: geom.local.half_volume(),
            reduce_order: std::sync::Arc::new(global_tile_order(geom)),
        })
    }

    /// Gather-and-fold reduction shared with [`DistMultiMdagM`].
    fn reduce_caps_global(
        comm: &Comm,
        reduce_order: &[(u32, u32)],
        partials: &[[f64; 3]],
        nrhs: usize,
    ) -> Vec<[f64; 3]> {
        let flat: Vec<f64> = partials.iter().flat_map(|p| p.iter().copied()).collect();
        let all = comm.allgather_f64(&flat);
        let mut out = vec![[0.0f64; 3]; nrhs];
        for &(rank, lt) in reduce_order {
            let row = &all[rank as usize];
            for (r, acc) in out.iter_mut().enumerate() {
                let base = (lt as usize * nrhs + r) * 3;
                acc[0] += row[base];
                acc[1] += row[base + 1];
                acc[2] += row[base + 2];
            }
        }
        out
    }
}

impl<R: Real + CommScalar, U: LinkSource<R>> MultiOperator<R> for DistMultiMeo<'_, R, U> {
    fn nrhs(&self) -> usize {
        self.nrhs
    }

    fn apply_multi(
        &mut self,
        team: &mut Team,
        out: &mut MultiFermionField<R>,
        psi: &MultiFermionField<R>,
        active: &[bool],
        dot: Option<(&MultiFermionField<R>, &mut [[f64; 3]])>,
    ) {
        debug_assert_eq!(psi.nrhs, self.nrhs);
        debug_assert_eq!(out.nrhs, self.nrhs);
        // M-hat = 1 - kappa² H_eo H_oe, xpay tail fused into the second
        // hopping's pipeline (bulk store without comm, EO2 merge with)
        self.dist.hopping_multi(
            &mut self.tmp,
            self.u,
            psi,
            Parity::Odd,
            active,
            self.comm,
            team,
            self.prof,
            MultiHopTail::Assign,
        );
        self.dist.hopping_multi(
            out,
            self.u,
            &self.tmp,
            Parity::Even,
            active,
            self.comm,
            team,
            self.prof,
            MultiHopTail::Xpay {
                a: -(self.kappa * self.kappa),
                b: psi,
            },
        );
        // the store completes only after the EO2 merge, so the dot
        // capture is a post-pass here (same per-tile values as the
        // native kernels' fused capture — identical function, same data)
        if let Some((with, partials)) = dot {
            with.cdot_norm2_partials(out, active, partials);
        }
    }

    fn flops_per_apply_rhs(&self) -> u64 {
        crate::dslash::flops::meo_flops(self.half_volume)
    }

    fn flops_per_apply_shared(&self) -> u64 {
        crate::dslash::flops::meo_links_flops(self.half_volume, self.u.reals_per_link())
            - crate::dslash::flops::meo_flops(self.half_volume)
    }

    fn reduce_caps(&mut self, partials: &[[f64; 3]]) -> Vec<[f64; 3]> {
        Self::reduce_caps_global(self.comm, &self.reduce_order, partials, self.nrhs)
    }

    fn reduce_any(&mut self, v: bool) -> bool {
        self.comm.allreduce_any(v)
    }

    fn fault_hook(&mut self, iteration: usize) -> Result<(), CommError> {
        self.comm.iteration_hook(iteration)
    }

    fn comm_fault(&self) -> Option<CommError> {
        self.comm.comm_fault()
    }

    fn comm_counters(&self) -> (u64, u64) {
        let st = self.comm.stats();
        (st.retransmits, st.timeouts)
    }

    fn comm_zero_fills(&self) -> u64 {
        self.comm.stats().zero_fills
    }

    fn fault_cursors(&self) -> Vec<u64> {
        self.comm.fault_cursors()
    }

    fn restore_fault_cursors(&mut self, saved: &[u64]) {
        self.comm.restore_fault_cursors(saved);
    }

    fn ckpt_all_committed(&mut self, ok: bool) -> bool {
        ckpt_all_committed(self.comm, ok)
    }

    fn ckpt_buddy_exchange(&mut self, payload: &[f64], gen: u64) -> Option<Vec<f64>> {
        ckpt_buddy_exchange(self.comm, payload, gen)
    }
}

/// Distributed multi-RHS normal operator M-hat^dag M-hat: four batched
/// distributed hoppings with both gamma5/xpay tails fused into the
/// even-parity pipelines (bulk store or EO2 merge), like
/// [`MultiMdagM`] over the rank world. What the distributed block CGNR
/// solves.
pub struct DistMultiMdagM<'a, R: Real + CommScalar = f32, U: LinkSource<R> = GaugeField<R>> {
    inner: DistMultiMeo<'a, R, U>,
    mid: MultiFermionField<R>,
}

impl<'a, R: Real + CommScalar, U: LinkSource<R>> DistMultiMdagM<'a, R, U> {
    /// Construct, running the same wire-format handshake as
    /// [`DistMultiMeo::new`].
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        geom: &Geometry,
        dist: &'a DistHopping,
        u: &'a U,
        kappa: R,
        nrhs: usize,
        comm: &'a mut Comm,
        prof: &'a Profiler,
    ) -> Result<DistMultiMdagM<'a, R, U>, CommError> {
        Ok(DistMultiMdagM {
            inner: DistMultiMeo::new(geom, dist, u, kappa, nrhs, comm, prof)?,
            mid: MultiFermionField::zeros(geom, nrhs),
        })
    }
}

impl<R: Real + CommScalar, U: LinkSource<R>> MultiOperator<R> for DistMultiMdagM<'_, R, U> {
    fn nrhs(&self) -> usize {
        self.inner.nrhs
    }

    fn apply_multi(
        &mut self,
        team: &mut Team,
        out: &mut MultiFermionField<R>,
        psi: &MultiFermionField<R>,
        active: &[bool],
        dot: Option<(&MultiFermionField<R>, &mut [[f64; 3]])>,
    ) {
        let DistMultiMdagM { inner, mid } = self;
        debug_assert_eq!(psi.nrhs, inner.nrhs);
        let a = -(inner.kappa * inner.kappa);
        // mid = g5 (psi - kappa² H_eo H_oe psi)
        inner.dist.hopping_multi(
            &mut inner.tmp, inner.u, psi, Parity::Odd, active, inner.comm, team,
            inner.prof, MultiHopTail::Assign,
        );
        inner.dist.hopping_multi(
            mid, inner.u, &inner.tmp, Parity::Even, active, inner.comm, team,
            inner.prof, MultiHopTail::Gamma5Xpay { a, b: psi },
        );
        // out = g5 (mid - kappa² H_eo H_oe mid)
        inner.dist.hopping_multi(
            &mut inner.tmp, inner.u, mid, Parity::Odd, active, inner.comm, team,
            inner.prof, MultiHopTail::Assign,
        );
        inner.dist.hopping_multi(
            out, inner.u, &inner.tmp, Parity::Even, active, inner.comm, team,
            inner.prof, MultiHopTail::Gamma5Xpay { a, b: mid },
        );
        if let Some((with, partials)) = dot {
            with.cdot_norm2_partials(out, active, partials);
        }
    }

    fn flops_per_apply_rhs(&self) -> u64 {
        2 * self.inner.flops_per_apply_rhs()
    }

    fn flops_per_apply_shared(&self) -> u64 {
        2 * self.inner.flops_per_apply_shared()
    }

    fn reduce_caps(&mut self, partials: &[[f64; 3]]) -> Vec<[f64; 3]> {
        self.inner.reduce_caps(partials)
    }

    fn reduce_any(&mut self, v: bool) -> bool {
        self.inner.reduce_any(v)
    }

    fn fault_hook(&mut self, iteration: usize) -> Result<(), CommError> {
        self.inner.fault_hook(iteration)
    }

    fn comm_fault(&self) -> Option<CommError> {
        self.inner.comm_fault()
    }

    fn comm_counters(&self) -> (u64, u64) {
        self.inner.comm_counters()
    }

    fn comm_zero_fills(&self) -> u64 {
        self.inner.comm_zero_fills()
    }

    fn fault_cursors(&self) -> Vec<u64> {
        self.inner.fault_cursors()
    }

    fn restore_fault_cursors(&mut self, saved: &[u64]) {
        self.inner.restore_fault_cursors(saved);
    }

    fn ckpt_all_committed(&mut self, ok: bool) -> bool {
        ckpt_all_committed(self.inner.comm, ok)
    }

    fn ckpt_buddy_exchange(&mut self, payload: &[f64], gen: u64) -> Option<Vec<f64>> {
        ckpt_buddy_exchange(self.inner.comm, payload, gen)
    }
}

/// gamma5-wrapped normal operator over any M-hat-like operator: CGNR on
/// the distributed or PJRT operator reuses this.
pub struct NormalOp<A, R: Real = f32> {
    pub inner: A,
    mid: FermionField<R>,
}

impl<A, R: Real> NormalOp<A, R>
where
    A: LinearOperator<R>,
{
    pub fn new(inner: A, geom: &Geometry) -> NormalOp<A, R> {
        NormalOp {
            inner,
            mid: FermionField::zeros(geom),
        }
    }
}

impl<A, R: Real> LinearOperator<R> for NormalOp<A, R>
where
    A: LinearOperator<R>,
{
    fn apply(&mut self, out: &mut FermionField<R>, psi: &FermionField<R>) {
        let mut m_psi = std::mem::replace(&mut self.mid, FermionField::placeholder());
        self.inner.apply(&mut m_psi, psi);
        m_psi.gamma5();
        self.inner.apply(out, &m_psi);
        out.gamma5();
        self.mid = m_psi;
    }

    fn flops_per_apply(&self) -> u64 {
        2 * self.inner.flops_per_apply()
    }

    fn reduce_sum(&mut self, v: f64) -> f64 {
        self.inner.reduce_sum(v)
    }

    fn fault_hook(&mut self, iteration: usize) -> Result<(), CommError> {
        self.inner.fault_hook(iteration)
    }

    fn comm_fault(&self) -> Option<CommError> {
        self.inner.comm_fault()
    }

    fn comm_counters(&self) -> (u64, u64) {
        self.inner.comm_counters()
    }

    fn comm_zero_fills(&self) -> u64 {
        self.inner.comm_zero_fills()
    }

    fn fault_cursors(&self) -> Vec<u64> {
        self.inner.fault_cursors()
    }

    fn restore_fault_cursors(&mut self, saved: &[u64]) {
        self.inner.restore_fault_cursors(saved);
    }

    fn ckpt_all_committed(&mut self, ok: bool) -> bool {
        self.inner.ckpt_all_committed(ok)
    }

    fn ckpt_buddy_exchange(&mut self, payload: &[f64], gen: u64) -> Option<Vec<f64>> {
        self.inner.ckpt_buddy_exchange(payload, gen)
    }
}
