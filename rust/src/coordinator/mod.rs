//! L3 coordinator: persistent thread team (OpenMP analog), the
//! EO1 -> bulk ∥ comm -> EO2 distributed hopping driver, the FAPP-analog
//! profiler, and operator compositions for the solvers.

pub mod driver;
pub mod operator;
pub mod profiler;
pub mod team;

pub use driver::{DistHopping, Eo2Schedule, MultiHopTail};
pub use profiler::{Phase, Profiler, Report};
pub use team::{BarrierKind, Team, TeamBarrier};
