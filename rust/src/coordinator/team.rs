//! Persistent thread team — the OpenMP analog (paper §3.6: 12 threads per
//! MPI process, one process per CMG).
//!
//! Workers are spawned once and re-used across parallel regions. Region
//! completion is detected by the caller counting worker check-ins; the
//! wait flavor is either a spin loop (the `FLIB_BARRIER=HARD` hardware
//! barrier analog — the paper reports ~20% gain at its smallest lattice)
//! or yield/condvar sleeping (the software-barrier analog). `harness`
//! benches the two against each other.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Barrier/wakeup flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BarrierKind {
    /// busy-wait on atomics (FLIB_BARRIER=HARD analog)
    Spin,
    /// mutex + condvar + yields (software barrier analog)
    Sleep,
}

type Job = Arc<dyn Fn(usize) + Send + Sync + 'static>;

struct Shared {
    kind: BarrierKind,
    /// (epoch, job); epoch increments once per parallel region
    job: Mutex<(u64, Option<Job>)>,
    job_cv: Condvar,
    /// epoch visible to spinning workers without taking the lock
    epoch_hint: AtomicU64,
    /// number of workers that finished the current region
    done: AtomicUsize,
    shutdown: AtomicUsize,
}

/// Persistent worker team of `n` threads (tids 0..n; tid 0 is the caller).
pub struct Team {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    epoch: u64,
    n: usize,
}

impl Team {
    pub fn new(n: usize, kind: BarrierKind) -> Team {
        assert!(n >= 1);
        let shared = Arc::new(Shared {
            kind,
            job: Mutex::new((0, None)),
            job_cv: Condvar::new(),
            epoch_hint: AtomicU64::new(0),
            done: AtomicUsize::new(0),
            shutdown: AtomicUsize::new(0),
        });
        let workers = (1..n)
            .map(|tid| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(tid, sh))
            })
            .collect();
        Team {
            shared,
            workers,
            epoch: 0,
            n,
        }
    }

    pub fn nthreads(&self) -> usize {
        self.n
    }

    pub fn barrier_kind(&self) -> BarrierKind {
        self.shared.kind
    }

    /// Run a generic job `f(tid, barrier)` on all threads: the team
    /// analog of one OpenMP parallel region with in-region barriers.
    ///
    /// Unlike the dslash phases driven through [`Team::parallel`] (one
    /// region per phase), a `run` job can synchronize *inside* the
    /// region via the supplied [`TeamBarrier`] — the fused solver
    /// pipeline uses this to execute a whole CG/BiCGStab iteration
    /// (kernel phases, BLAS-1 sweeps, reductions) in a single region.
    pub fn run<F>(&mut self, f: F)
    where
        F: Fn(usize, &TeamBarrier) + Send + Sync,
    {
        let bar = TeamBarrier::new(self.n, self.shared.kind);
        self.parallel(|tid| f(tid, &bar));
    }

    /// Run `f(tid)` on all threads (caller participates as tid 0) and
    /// return once every thread finished its share.
    pub fn parallel<F>(&mut self, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if self.n == 1 {
            f(0);
            return;
        }
        self.epoch += 1;
        let job: Arc<dyn Fn(usize) + Send + Sync + '_> = Arc::new(f);
        // SAFETY: transmute only erases the closure's lifetime to 'static.
        // The completion wait below blocks until all n-1 workers reported
        // done with this epoch, and the job slot is cleared before
        // `parallel` returns, so no worker can touch the closure (or the
        // locals it borrows) after it goes out of scope.
        let job: Job = unsafe { std::mem::transmute(job) };
        {
            let mut slot = self.shared.job.lock().unwrap();
            *slot = (self.epoch, Some(job.clone()));
        }
        self.shared.epoch_hint.store(self.epoch, Ordering::Release);
        self.shared.job_cv.notify_all();

        job(0);
        drop(job);

        // wait for all n-1 workers to check in, then reset for next region
        while self.shared.done.load(Ordering::Acquire) < self.n - 1 {
            match self.shared.kind {
                BarrierKind::Spin => std::hint::spin_loop(),
                BarrierKind::Sleep => std::thread::yield_now(),
            }
        }
        self.shared.done.store(0, Ordering::Release);
        let mut slot = self.shared.job.lock().unwrap();
        slot.1 = None; // drop the erased closure before returning
    }
}

fn worker_loop(tid: usize, sh: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        // wait for a new epoch
        match sh.kind {
            BarrierKind::Spin => loop {
                if sh.shutdown.load(Ordering::Acquire) == 1 {
                    return;
                }
                if sh.epoch_hint.load(Ordering::Acquire) > seen {
                    break;
                }
                std::hint::spin_loop();
            },
            BarrierKind::Sleep => {
                let mut slot = sh.job.lock().unwrap();
                loop {
                    if sh.shutdown.load(Ordering::Acquire) == 1 {
                        return;
                    }
                    if slot.0 > seen {
                        break;
                    }
                    let (s, _t) = sh
                        .job_cv
                        .wait_timeout(slot, std::time::Duration::from_millis(1))
                        .unwrap();
                    slot = s;
                }
            }
        }
        let job = {
            let slot = sh.job.lock().unwrap();
            seen = slot.0;
            slot.1.clone()
        };
        if let Some(job) = job {
            job(tid);
            drop(job);
            sh.done.fetch_add(1, Ordering::AcqRel);
        }
    }
}

impl Drop for Team {
    fn drop(&mut self) {
        self.shared.shutdown.store(1, Ordering::Release);
        self.shared.job_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Reusable in-region barrier for [`Team::run`] jobs (sense-reversing).
///
/// All `n` threads of the region must call [`TeamBarrier::wait`]; the
/// call returns once every thread has arrived. The wait flavor follows
/// the team's [`BarrierKind`]: `Spin` busy-waits (the FLIB hardware
/// barrier analog), `Sleep` yields (safe when the team is oversubscribed
/// on fewer cores). The release does an Acquire/Release handoff, so
/// writes made before `wait` by any thread are visible to every thread
/// after it returns.
pub struct TeamBarrier {
    n: usize,
    kind: BarrierKind,
    /// threads arrived in the current generation
    count: AtomicUsize,
    /// generation counter (flips the "sense" each time the barrier opens)
    generation: AtomicU64,
}

impl TeamBarrier {
    pub fn new(n: usize, kind: BarrierKind) -> TeamBarrier {
        TeamBarrier {
            n,
            kind,
            count: AtomicUsize::new(0),
            generation: AtomicU64::new(0),
        }
    }

    /// Block until all `n` threads of the region have arrived.
    pub fn wait(&self) {
        if self.n == 1 {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            // last arrival: reset and open the next generation
            self.count.store(0, Ordering::Release);
            self.generation.fetch_add(1, Ordering::AcqRel);
        } else {
            while self.generation.load(Ordering::Acquire) == gen {
                match self.kind {
                    BarrierKind::Spin => std::hint::spin_loop(),
                    BarrierKind::Sleep => std::thread::yield_now(),
                }
            }
        }
    }
}

/// Static equal-count split of `[0, len)` for thread `tid` of `n`.
#[inline]
pub fn chunk_range(len: usize, tid: usize, n: usize) -> (usize, usize) {
    let base = len / n;
    let rem = len % n;
    let begin = tid * base + tid.min(rem);
    let end = begin + base + usize::from(tid < rem);
    (begin, end)
}

/// A pointer wrapper that lets the team write disjoint regions of one
/// buffer from multiple threads. Callers must guarantee disjointness.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);
// SAFETY: SendPtr carries a bare pointer; moving it to another thread is
// sound because every dereference goes through `slice_mut`, whose contract
// obliges the caller to access only disjoint regions concurrently.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: sharing &SendPtr only copies the pointer value; see Send above.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// # Safety
    /// The region `[offset, offset+len)` must not be aliased by any other
    /// concurrent access.
    pub unsafe fn slice_mut(&self, offset: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(offset), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_partition() {
        for (len, n) in [(100, 12), (7, 3), (5, 8), (0, 4)] {
            let mut total = 0;
            let mut prev_end = 0;
            for tid in 0..n {
                let (b, e) = chunk_range(len, tid, n);
                assert_eq!(b, prev_end);
                prev_end = e;
                total += e - b;
            }
            assert_eq!(total, len);
            assert_eq!(prev_end, len);
        }
    }

    #[test]
    fn team_runs_all_tids() {
        for kind in [BarrierKind::Sleep, BarrierKind::Spin] {
            let mut team = Team::new(4, kind);
            let hits = AtomicU64::new(0);
            team.parallel(|tid| {
                hits.fetch_add(1 << (8 * tid), Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 0x01010101, "{kind:?}");
        }
    }

    #[test]
    fn team_many_sequential_regions() {
        for kind in [BarrierKind::Sleep, BarrierKind::Spin] {
            let mut team = Team::new(3, kind);
            let counter = AtomicU64::new(0);
            for _ in 0..100 {
                team.parallel(|_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
            assert_eq!(counter.load(Ordering::Relaxed), 300, "{kind:?}");
        }
    }

    #[test]
    fn team_writes_disjoint_regions() {
        let mut team = Team::new(4, BarrierKind::Sleep);
        let mut buf = vec![0u32; 100];
        let ptr = SendPtr(buf.as_mut_ptr());
        team.parallel(|tid| {
            let (b, e) = chunk_range(100, tid, 4);
            // SAFETY: chunk_range partitions [0, 100) disjointly by tid.
            let slice = unsafe { ptr.slice_mut(b, e - b) };
            for (i, v) in slice.iter_mut().enumerate() {
                *v = (b + i) as u32;
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn closures_can_borrow_locals() {
        let mut team = Team::new(2, BarrierKind::Sleep);
        let data = vec![1u64, 2, 3, 4];
        let sum = AtomicU64::new(0);
        team.parallel(|tid| {
            let (b, e) = chunk_range(data.len(), tid, 2);
            sum.fetch_add(data[b..e].iter().sum::<u64>(), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn run_job_with_in_region_barrier() {
        // phase 1 writes per-thread slots, barrier, phase 2 reads ALL
        // slots: any missed synchronization shows up as a zero sum.
        for kind in [BarrierKind::Sleep, BarrierKind::Spin] {
            let n = 4;
            let mut team = Team::new(n, kind);
            let mut slots = vec![0u64; n];
            let ptr = SendPtr(slots.as_mut_ptr());
            let sums = AtomicU64::new(0);
            team.run(|tid, bar| {
                // SAFETY: slot `tid` is written by this thread only.
                unsafe { ptr.slice_mut(tid, 1)[0] = (tid as u64) + 1 };
                bar.wait();
                // SAFETY: the barrier publishes every slot before any
                // thread reads, and nobody writes after it.
                let total: u64 = (0..n).map(|i| unsafe { *ptr.0.add(i) }).sum();
                sums.fetch_add(total, Ordering::Relaxed);
            });
            // every thread saw the full 1+2+3+4
            assert_eq!(sums.load(Ordering::Relaxed), 10 * n as u64, "{kind:?}");
        }
    }

    #[test]
    fn barrier_reusable_across_many_phases() {
        for kind in [BarrierKind::Sleep, BarrierKind::Spin] {
            let n = 3;
            let mut team = Team::new(n, kind);
            let counter = AtomicU64::new(0);
            team.run(|_tid, bar| {
                for phase in 0..50u64 {
                    // all threads must agree on the phase count so far
                    assert_eq!(
                        counter.load(Ordering::SeqCst) % (n as u64),
                        0,
                        "phase {phase} entered before the last one drained"
                    );
                    bar.wait();
                    counter.fetch_add(1, Ordering::SeqCst);
                    bar.wait();
                }
            });
            assert_eq!(counter.load(Ordering::SeqCst), 50 * n as u64, "{kind:?}");
        }
    }

    #[test]
    fn single_thread_barrier_is_noop() {
        let bar = TeamBarrier::new(1, BarrierKind::Spin);
        bar.wait();
        bar.wait();
    }

    #[test]
    fn single_thread_team_inline() {
        let mut team = Team::new(1, BarrierKind::Spin);
        let cell = AtomicU64::new(0);
        team.parallel(|tid| {
            assert_eq!(tid, 0);
            cell.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(cell.load(Ordering::Relaxed), 1);
    }

    use std::sync::atomic::AtomicU64;
}
